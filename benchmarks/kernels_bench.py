"""Kernel microbenchmarks: wall-time per call (CPU interpret / jnp ref) plus
the derived HBM-traffic model that matters on the TPU target.

Wall times on this CPU container do NOT reflect TPU performance; the derived
column reports the analytic bytes-moved model (the quantity the fused
kernels improve): unfused QR bag = 3·L·D reads/writes per pooled row vs
fused = 2·L·D reads + D writes.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.monotonic()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.monotonic() - t0) / iters * 1e6


def rows():
    from repro.kernels import ops, ref
    out = []
    m, q, d = 2048, 16, 128
    wr = jax.random.normal(jax.random.PRNGKey(0), (m, d), jnp.float32)
    wq = jax.random.normal(jax.random.PRNGKey(1), (q, d), jnp.float32)
    n = 512
    idx = jax.random.randint(jax.random.PRNGKey(2), (n,), 0, m * q)

    ref_fn = jax.jit(lambda i: ref.qr_gather_ref(i % m, i // m, wr, wq))
    us = _time(ref_fn, idx)
    bytes_unfused = n * d * 4 * 3  # two gathered rows written + read + result
    out.append(("kernel/qr_gather/ref_jnp", round(us, 1),
                f"hbm_bytes_unfused={bytes_unfused}"))
    us = _time(lambda i: ops.qr_lookup(i, wr, wq), idx)
    bytes_fused = n * d * 4 * 2 + n * d * 4  # reads + single write
    out.append(("kernel/qr_gather/pallas_interpret", round(us, 1),
                f"hbm_bytes_fused={bytes_fused}"))

    b, l = 32, 8
    idx2 = jax.random.randint(jax.random.PRNGKey(3), (b, l), 0, m * q)
    mask = jnp.ones((b, l), jnp.float32)
    ref_bag = jax.jit(lambda i: ref.qr_embedding_bag_ref(i % m, i // m, mask, wr, wq))
    us = _time(ref_bag, idx2)
    out.append(("kernel/qr_bag/ref_jnp", round(us, 1),
                f"hbm_bytes_unfused={b * l * d * 4 * 3 + b * d * 4}"))
    us = _time(lambda i: ops.qr_bag_lookup(i, mask, wr, wq), idx2)
    out.append(("kernel/qr_bag/pallas_interpret", round(us, 1),
                f"hbm_bytes_fused={b * l * d * 4 * 2 + b * d * 4}"))

    x = jax.random.normal(jax.random.PRNGKey(4), (256, 27, 16), jnp.float32)
    us = _time(jax.jit(ref.dot_interaction_ref), x)
    out.append(("kernel/dot_interact/ref_jnp", round(us, 1),
                "flops=%d" % (2 * 256 * 27 * 27 * 16)))
    us = _time(lambda x: ops.dlrm_interact(x), x)
    out.append(("kernel/dot_interact/pallas_interpret", round(us, 1),
                "vmem_tile=(8,27,16)"))
    return out
