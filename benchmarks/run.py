"""Benchmark harness — one section per paper table/figure + kernels + roofline.

Prints ``name,us_per_call,derived`` CSV.  Paper experiments run on the
seeded synthetic Criteo-shaped stream at reduced scale (CPU container);
EXPERIMENTS.md compares the trends against the paper's absolute numbers.

A section that raises is reported as a ``<section>/ERROR`` row; every
section still runs, but the process then exits 1 so CI's bench lane
fails instead of silently shipping a broken benchmark.  ``--only``
filters sections by substring; ``REPRO_BENCH_INJECT_ERROR=1`` adds a
deliberately-failing section (used to verify the CI lane actually turns
red on errors).
"""

from __future__ import annotations

import argparse
import os
import sys


def _injected_error():
    raise RuntimeError("injected benchmark failure (REPRO_BENCH_INJECT_ERROR)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="run only sections whose qualified name "
                         "(module.function) contains this substring")
    args = ap.parse_args(argv)

    from . import kernels_bench, paper_tables, plan_bench, roofline

    sections = [paper_tables.fig4, paper_tables.fig5, paper_tables.fig6,
                paper_tables.table1, kernels_bench.rows, roofline.rows,
                plan_bench.rows]
    if os.environ.get("REPRO_BENCH_INJECT_ERROR"):
        sections.append(_injected_error)
    if args.only:
        sections = [fn for fn in sections
                    if args.only in f"{fn.__module__}.{fn.__name__}"]

    failures: list[str] = []
    print("name,us_per_call,derived")
    for fn in sections:
        try:
            rows = fn()
        except Exception as e:  # keep the harness running; surface the error
            rows = [(f"{fn.__module__}.{fn.__name__}/ERROR", 0, repr(e)[:120])]
        for name, us, derived in rows:
            if "/ERROR" in name:
                failures.append(name)
            print(f"{name},{us},{derived}")
            sys.stdout.flush()
    if failures:
        print(f"# {len(failures)} section(s) failed: {', '.join(failures)}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
