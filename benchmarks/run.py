"""Benchmark harness — one section per paper table/figure + kernels + roofline.

Prints ``name,us_per_call,derived`` CSV.  Paper experiments run on the
seeded synthetic Criteo-shaped stream at reduced scale (CPU container);
EXPERIMENTS.md compares the trends against the paper's absolute numbers.
"""

from __future__ import annotations

import sys


def main() -> None:
    sections = []
    from . import kernels_bench, paper_tables, roofline

    print("name,us_per_call,derived")
    for fn in (paper_tables.fig4, paper_tables.fig5, paper_tables.fig6,
               paper_tables.table1, kernels_bench.rows, roofline.rows):
        try:
            rows = fn()
        except Exception as e:  # keep the harness running; surface the error
            rows = [(f"{fn.__module__}.{fn.__name__}/ERROR", 0, repr(e)[:120])]
        for name, us, derived in rows:
            print(f"{name},{us},{derived}")
            sys.stdout.flush()
        sections.append(fn.__name__)


if __name__ == "__main__":
    main()
