"""Memory-planner benchmark: budget sweep vs the uniform-hashing control,
uniform-width vs mixed-dimension.

For each rec arch (the paper's DLRM + DCN, reduced Criteo configs) the
bench streams per-feature frequency stats from the synthetic Criteo
generator, then solves the budgeted allocation at
``{0.05, 0.125, 0.25, 0.5}×`` of the all-full-table bytes and compares
the planner against a uniform-hashing baseline *at the same budget and
under the same byte accounting*.  Each cell is solved twice: at the
uniform width D and with the mixed-dimension ladder {D/4, D/2, D}
(``plan.dim_ladder``) — the width axis the dim-aware proxy prices.

Built-in acceptance checks (any failure -> ``/ERROR`` row + exit 1, the
``dist_bench``/``serve_bench`` contract):

* **budget respected** — planned bytes <= budget at every cell, exactly
  (the plan's claimed bytes must also equal ``num_params x 4`` of the
  modules ``make_embedding`` actually builds from it — for the mixed-dim
  plan checked *per table*, so per-feature width drift fails the bench,
  not just a test);
* **beats uniform hashing** — the planner's frequency-weighted quality
  proxy is *strictly* above the uniform-hash control at every budget;
* **mixed-dim beats uniform-dim** — the mixed-dimension plan never
  scores below the same-budget uniform-width plan, and *strictly* beats
  it at the 0.125× budget (the deployment point the issue pins);
* **complementary** — every compositional choice (qr / mixed_radix)
  passes ``core.partitions.is_complementary`` (brute force; reduced
  sizes are all below the check cap);
* **monotone** — plan quality never decreases as the budget grows, in
  both the uniform-width and the mixed-dim sweeps.

Parked upgrades (``plan.notes["parked"]`` — hull upgrades that did not
fit the budget) ride in every CSV row and in the JSON so a budget sweep
can't silently under-allocate (the ROADMAP "no silent caps" rule).

Artifacts: ``artifacts/bench/BENCH_plan.json`` (+ each solved plan under
``artifacts/plans/``), a compact mirror at the repo top level
(``BENCH_plan.json``: totals + acceptance booleans, the perf-trajectory
hook), and CSV on stdout (``name,us_per_call,derived``).

Usage::

    python -m benchmarks.plan_bench --stats-batches 24
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

ART = "artifacts/bench"
ARCHS = ("dlrm-criteo", "dcn-criteo")
BUDGET_FRACS = (0.05, 0.125, 0.25, 0.5)
# the budget where the mixed-dim plan must *strictly* beat uniform-width
MIXED_STRICT_FRAC = 0.125


def _stats_for(arch: str, num_batches: int, batch_size: int):
    from repro.configs import get_arch
    from repro.data.criteo import CriteoSpec
    from repro.plan import stats_from_criteo

    cfg = get_arch(arch).config(reduced=True)
    spec = CriteoSpec(table_sizes=cfg.table_sizes, zipf=1.5, noise=0.5)
    return cfg, stats_from_criteo(spec, num_batches=num_batches,
                                  batch_size=batch_size)


def _plan_cell(arch: str, cfg, stats, frac: float, save: bool) -> dict:
    from repro.core import make_embedding
    from repro.plan import (build_plan, dim_ladder, full_table_bytes,
                            plan_path, uniform_hash_plan)

    dim = cfg.emb_dim
    full = full_table_bytes(cfg.table_sizes, dim)
    budget = int(full * frac)
    uniform = uniform_hash_plan(stats, dim, budget, arch=arch)
    t0 = time.monotonic()
    plan = build_plan(stats, dim, budget, arch=arch, baseline=uniform)
    solve_s = time.monotonic() - t0
    t0 = time.monotonic()
    mixed = build_plan(stats, dim, budget, arch=f"{arch}-mixed",
                       baseline=uniform, dims=dim_ladder(dim))
    mixed_solve_s = time.monotonic() - t0
    if save:
        plan.save(plan_path(arch, budget))
        mixed.save(plan_path(f"{arch}-mixed", budget))

    # executable round-trip: the bytes the plan claims are the bytes the
    # factory builds (f32 train domain: 4 B per parameter); for the
    # mixed-dim plan the check is per table so width drift can't cancel
    built_params = sum(
        make_embedding(n, dim, plan, feature=i).num_params
        for i, n in enumerate(cfg.table_sizes))
    mixed_built_ok = all(
        make_embedding(n, dim, mixed, feature=i).num_params * 4
        == mixed.tables[i].train_bytes
        for i, n in enumerate(cfg.table_sizes))
    comp_ok = all(t.complementary is True
                  for p in (plan, mixed) for t in p.tables
                  if t.kind in ("qr", "mixed_radix", "crt"))
    return {
        "arch": arch, "budget_frac": frac, "budget_bytes": budget,
        "full_bytes": full, "plan_bytes": plan.total_bytes,
        "built_bytes": built_params * 4,
        "uniform_bytes": uniform.total_bytes,
        "quality": plan.quality, "uniform_quality": uniform.quality,
        "kinds": plan.summary()["kinds"],
        "parked": len(plan.notes.get("parked", [])),
        "leftover_bytes": plan.notes.get("leftover_bytes", 0),
        "mixed_quality": mixed.quality,
        "mixed_bytes": mixed.total_bytes,
        "mixed_built_bytes_ok": mixed_built_ok,
        "mixed_dims": mixed.summary()["dims"],
        "mixed_kinds": mixed.summary()["kinds"],
        "mixed_parked": len(mixed.notes.get("parked", [])),
        "compositional_complementary": comp_ok,
        "solve_ms": round(solve_s * 1e3, 2),
        "mixed_solve_ms": round(mixed_solve_s * 1e3, 2),
    }


def bench(stats_batches: int, batch_size: int, save_plans: bool) -> dict:
    rows = []
    for arch in ARCHS:
        cfg, stats = _stats_for(arch, stats_batches, batch_size)
        for frac in BUDGET_FRACS:
            rows.append(_plan_cell(arch, cfg, stats, frac, save_plans))
    return {"archs": list(ARCHS), "budget_fracs": list(BUDGET_FRACS),
            "stats_batches": stats_batches, "batch_size": batch_size,
            "rows": rows}


def check(report: dict) -> list[tuple[str, str]]:
    """(name, message) per failed acceptance check; empty = all green."""
    failures = []
    by_arch: dict[str, list] = {}
    for r in report["rows"]:
        cell = f"{r['arch']}/b{r['budget_frac']:g}"
        by_arch.setdefault(r["arch"], []).append(r)
        if r["plan_bytes"] > r["budget_bytes"]:
            failures.append((cell, f"planned bytes {r['plan_bytes']} exceed "
                                   f"budget {r['budget_bytes']}"))
        if r["built_bytes"] != r["plan_bytes"]:
            failures.append((cell, f"cost-model drift: plan claims "
                                   f"{r['plan_bytes']} B, make_embedding "
                                   f"builds {r['built_bytes']} B"))
        if not r["quality"] > r["uniform_quality"]:
            failures.append((cell, f"plan quality {r['quality']:.6f} does not "
                                   f"beat uniform hashing "
                                   f"{r['uniform_quality']:.6f}"))
        if r["mixed_bytes"] > r["budget_bytes"]:
            failures.append((cell, f"mixed-dim planned bytes "
                                   f"{r['mixed_bytes']} exceed budget "
                                   f"{r['budget_bytes']}"))
        if not r["mixed_built_bytes_ok"]:
            failures.append((cell, "mixed-dim cost-model drift: a table's "
                                   "built bytes differ from its planned "
                                   "train_bytes"))
        if r["mixed_quality"] < r["quality"] - 1e-12:
            failures.append((cell, f"mixed-dim quality "
                                   f"{r['mixed_quality']:.8f} fell below the "
                                   f"uniform-dim plan's {r['quality']:.8f}"))
        if r["budget_frac"] == MIXED_STRICT_FRAC \
                and not r["mixed_quality"] > r["quality"]:
            failures.append((cell, f"mixed-dim quality "
                                   f"{r['mixed_quality']:.8f} does not "
                                   f"strictly beat the uniform-dim plan's "
                                   f"{r['quality']:.8f} at the "
                                   f"{MIXED_STRICT_FRAC:g}x budget"))
        if not r["compositional_complementary"]:
            failures.append((cell, "a compositional choice failed "
                                   "is_complementary"))
    for arch, cells in by_arch.items():
        cells = sorted(cells, key=lambda r: r["budget_frac"])
        for a, b in zip(cells, cells[1:]):
            if b["quality"] < a["quality"] - 1e-12:
                failures.append(
                    (f"{arch}/b{b['budget_frac']:g}",
                     f"quality {b['quality']:.6f} dropped below the "
                     f"smaller budget's {a['quality']:.6f}"))
            if b["mixed_quality"] < a["mixed_quality"] - 1e-12:
                failures.append(
                    (f"{arch}/b{b['budget_frac']:g}",
                     f"mixed-dim quality {b['mixed_quality']:.6f} dropped "
                     f"below the smaller budget's "
                     f"{a['mixed_quality']:.6f}"))
    return failures


def summarize(report: dict) -> dict:
    """The compact top-level mirror (``BENCH_plan.json`` at the repo
    root): totals + acceptance booleans, the schema the perf-trajectory
    tooling consumes — keep keys stable."""
    rows = report["rows"]
    failed = report.get("checks_failed", [])
    strict = [r for r in rows if r["budget_frac"] == MIXED_STRICT_FRAC]
    return {
        "bench": "plan",
        "source": os.path.join(ART, "BENCH_plan.json"),
        "cells": len(rows),
        "archs": report["archs"],
        "budget_fracs": report["budget_fracs"],
        "quality_mean": sum(r["quality"] for r in rows) / max(1, len(rows)),
        "mixed_quality_mean": sum(r["mixed_quality"] for r in rows)
        / max(1, len(rows)),
        "parked_total": sum(r["parked"] + r["mixed_parked"] for r in rows),
        "acceptance": {
            "budget_respected": all(r["plan_bytes"] <= r["budget_bytes"]
                                    and r["mixed_bytes"] <= r["budget_bytes"]
                                    for r in rows),
            "built_bytes_match": all(r["built_bytes"] == r["plan_bytes"]
                                     and r["mixed_built_bytes_ok"]
                                     for r in rows),
            "beats_uniform_hash": all(r["quality"] > r["uniform_quality"]
                                      for r in rows),
            "mixed_strictly_beats_unidim": all(
                r["mixed_quality"] > r["quality"] for r in strict)
            and bool(strict),
            "complementary": all(r["compositional_complementary"]
                                 for r in rows),
            "all_checks_passed": not failed,
        },
        "checks_failed": failed,
    }


def rows():
    """Fast planner section for ``benchmarks.run``: one arch, two budgets,
    a short stats stream — catches wiring rot, not statistics."""
    out = []
    cfg, stats = _stats_for("dlrm-criteo", num_batches=6, batch_size=256)
    for frac in (0.05, 0.25):
        r = _plan_cell("dlrm-criteo", cfg, stats, frac, save=False)
        ok = (r["plan_bytes"] <= r["budget_bytes"]
              and r["quality"] > r["uniform_quality"]
              and r["mixed_bytes"] <= r["budget_bytes"]
              and r["mixed_built_bytes_ok"]
              and r["mixed_quality"] >= r["quality"] - 1e-12
              and r["compositional_complementary"])
        name = f"plan/{r['arch']}/b{frac:g}" + ("" if ok else "/ERROR")
        out.append((name, r["solve_ms"] * 1e3,
                    f"quality={r['quality']:.4f};"
                    f"mixed={r['mixed_quality']:.4f};"
                    f"uniform={r['uniform_quality']:.4f};"
                    f"bytes={r['plan_bytes']}/{r['budget_bytes']};"
                    f"parked={r['parked']}+{r['mixed_parked']}"))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--stats-batches", type=int,
                    default=int(os.environ.get("REPRO_BENCH_STATS_BATCHES", 24)),
                    help="synthetic Criteo batches streamed into the "
                         "frequency histograms")
    ap.add_argument("--batch-size", type=int, default=512)
    ap.add_argument("--no-save-plans", dest="save_plans",
                    action="store_false", default=True,
                    help="skip writing the solved plans to artifacts/plans/")
    ap.add_argument("--out", default=os.path.join(ART, "BENCH_plan.json"))
    ap.add_argument("--summary-out", default="BENCH_plan.json",
                    help="compact top-level mirror (totals + acceptance "
                         "booleans) for the perf-trajectory tooling")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    try:
        report = bench(args.stats_batches, args.batch_size, args.save_plans)
    except Exception as e:
        print(f"plan_bench/ERROR,0,{repr(e)[:160]}")
        return 1
    for r in report["rows"]:
        print(f"plan/{r['arch']}/b{r['budget_frac']:g},"
              f"{r['solve_ms'] * 1e3:.0f},"
              f"quality={r['quality']:.6f};"
              f"mixed={r['mixed_quality']:.6f};"
              f"uniform={r['uniform_quality']:.6f};"
              f"bytes={r['plan_bytes']}/{r['budget_bytes']};"
              f"parked={r['parked']}+{r['mixed_parked']};"
              f"dims={'+'.join(f'{k}:{v}' for k, v in sorted(r['mixed_dims'].items(), key=lambda kv: int(kv[0])))};"
              f"kinds={'+'.join(f'{k}:{v}' for k, v in sorted(r['kinds'].items()))}")
        sys.stdout.flush()
    failures = check(report)
    report["checks_failed"] = [f"{n}: {m}" for n, m in failures]
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, default=float)
    with open(args.summary_out, "w") as f:
        json.dump(summarize(report), f, indent=1, default=float)
    for name, msg in failures:
        print(f"plan/check/{name}/ERROR,0,{msg}")
    if failures:
        print(f"# {len(failures)} plan_bench check(s) failed", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
