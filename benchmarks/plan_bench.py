"""Memory-planner benchmark: budget sweep vs the uniform-hashing control.

For each rec arch (the paper's DLRM + DCN, reduced Criteo configs) the
bench streams per-feature frequency stats from the synthetic Criteo
generator, then solves the budgeted allocation at
``{0.05, 0.125, 0.25, 0.5}×`` of the all-full-table bytes and compares
the planner against a uniform-hashing baseline *at the same budget and
under the same byte accounting*.

Built-in acceptance checks (any failure -> ``/ERROR`` row + exit 1, the
``dist_bench``/``serve_bench`` contract):

* **budget respected** — planned bytes <= budget at every cell, exactly
  (the plan's claimed bytes must also equal ``num_params x 4`` of the
  modules ``make_embedding`` actually builds from it — cost-model drift
  fails the bench, not just a test);
* **beats uniform hashing** — the planner's frequency-weighted quality
  proxy is *strictly* above the uniform-hash control at every budget;
* **complementary** — every compositional choice (qr / mixed_radix)
  passes ``core.partitions.is_complementary`` (brute force; reduced
  sizes are all below the check cap);
* **monotone** — plan quality never decreases as the budget grows.

Artifacts: ``artifacts/bench/BENCH_plan.json`` (+ each solved plan under
``artifacts/plans/``) and CSV on stdout (``name,us_per_call,derived``).

Usage::

    python -m benchmarks.plan_bench --stats-batches 24
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

ART = "artifacts/bench"
ARCHS = ("dlrm-criteo", "dcn-criteo")
BUDGET_FRACS = (0.05, 0.125, 0.25, 0.5)


def _stats_for(arch: str, num_batches: int, batch_size: int):
    from repro.configs import get_arch
    from repro.data.criteo import CriteoSpec
    from repro.plan import stats_from_criteo

    cfg = get_arch(arch).config(reduced=True)
    spec = CriteoSpec(table_sizes=cfg.table_sizes, zipf=1.5, noise=0.5)
    return cfg, stats_from_criteo(spec, num_batches=num_batches,
                                  batch_size=batch_size)


def _plan_cell(arch: str, cfg, stats, frac: float, save: bool) -> dict:
    from repro.core import make_embedding
    from repro.plan import (build_plan, full_table_bytes, plan_path,
                            uniform_hash_plan)

    dim = cfg.emb_dim
    full = full_table_bytes(cfg.table_sizes, dim)
    budget = int(full * frac)
    uniform = uniform_hash_plan(stats, dim, budget, arch=arch)
    t0 = time.monotonic()
    plan = build_plan(stats, dim, budget, arch=arch, baseline=uniform)
    solve_s = time.monotonic() - t0
    if save:
        plan.save(plan_path(arch, budget))

    # executable round-trip: the bytes the plan claims are the bytes the
    # factory builds (f32 train domain: 4 B per parameter)
    built_params = sum(
        make_embedding(n, dim, plan, feature=i).num_params
        for i, n in enumerate(cfg.table_sizes))
    comp_ok = all(t.complementary is True for t in plan.tables
                  if t.kind in ("qr", "mixed_radix", "crt"))
    return {
        "arch": arch, "budget_frac": frac, "budget_bytes": budget,
        "full_bytes": full, "plan_bytes": plan.total_bytes,
        "built_bytes": built_params * 4,
        "uniform_bytes": uniform.total_bytes,
        "quality": plan.quality, "uniform_quality": uniform.quality,
        "kinds": plan.summary()["kinds"],
        "compositional_complementary": comp_ok,
        "solve_ms": round(solve_s * 1e3, 2),
    }


def bench(stats_batches: int, batch_size: int, save_plans: bool) -> dict:
    rows = []
    for arch in ARCHS:
        cfg, stats = _stats_for(arch, stats_batches, batch_size)
        for frac in BUDGET_FRACS:
            rows.append(_plan_cell(arch, cfg, stats, frac, save_plans))
    return {"archs": list(ARCHS), "budget_fracs": list(BUDGET_FRACS),
            "stats_batches": stats_batches, "batch_size": batch_size,
            "rows": rows}


def check(report: dict) -> list[tuple[str, str]]:
    """(name, message) per failed acceptance check; empty = all green."""
    failures = []
    by_arch: dict[str, list] = {}
    for r in report["rows"]:
        cell = f"{r['arch']}/b{r['budget_frac']:g}"
        by_arch.setdefault(r["arch"], []).append(r)
        if r["plan_bytes"] > r["budget_bytes"]:
            failures.append((cell, f"planned bytes {r['plan_bytes']} exceed "
                                   f"budget {r['budget_bytes']}"))
        if r["built_bytes"] != r["plan_bytes"]:
            failures.append((cell, f"cost-model drift: plan claims "
                                   f"{r['plan_bytes']} B, make_embedding "
                                   f"builds {r['built_bytes']} B"))
        if not r["quality"] > r["uniform_quality"]:
            failures.append((cell, f"plan quality {r['quality']:.6f} does not "
                                   f"beat uniform hashing "
                                   f"{r['uniform_quality']:.6f}"))
        if not r["compositional_complementary"]:
            failures.append((cell, "a compositional choice failed "
                                   "is_complementary"))
    for arch, cells in by_arch.items():
        cells = sorted(cells, key=lambda r: r["budget_frac"])
        for a, b in zip(cells, cells[1:]):
            if b["quality"] < a["quality"] - 1e-12:
                failures.append(
                    (f"{arch}/b{b['budget_frac']:g}",
                     f"quality {b['quality']:.6f} dropped below the "
                     f"smaller budget's {a['quality']:.6f}"))
    return failures


def rows():
    """Fast planner section for ``benchmarks.run``: one arch, two budgets,
    a short stats stream — catches wiring rot, not statistics."""
    out = []
    cfg, stats = _stats_for("dlrm-criteo", num_batches=6, batch_size=256)
    for frac in (0.05, 0.25):
        r = _plan_cell("dlrm-criteo", cfg, stats, frac, save=False)
        ok = (r["plan_bytes"] <= r["budget_bytes"]
              and r["quality"] > r["uniform_quality"]
              and r["compositional_complementary"])
        name = f"plan/{r['arch']}/b{frac:g}" + ("" if ok else "/ERROR")
        out.append((name, r["solve_ms"] * 1e3,
                    f"quality={r['quality']:.4f};"
                    f"uniform={r['uniform_quality']:.4f};"
                    f"bytes={r['plan_bytes']}/{r['budget_bytes']}"))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--stats-batches", type=int,
                    default=int(os.environ.get("REPRO_BENCH_STATS_BATCHES", 24)),
                    help="synthetic Criteo batches streamed into the "
                         "frequency histograms")
    ap.add_argument("--batch-size", type=int, default=512)
    ap.add_argument("--no-save-plans", dest="save_plans",
                    action="store_false", default=True,
                    help="skip writing the solved plans to artifacts/plans/")
    ap.add_argument("--out", default=os.path.join(ART, "BENCH_plan.json"))
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    try:
        report = bench(args.stats_batches, args.batch_size, args.save_plans)
    except Exception as e:
        print(f"plan_bench/ERROR,0,{repr(e)[:160]}")
        return 1
    for r in report["rows"]:
        print(f"plan/{r['arch']}/b{r['budget_frac']:g},"
              f"{r['solve_ms'] * 1e3:.0f},"
              f"quality={r['quality']:.6f};"
              f"uniform={r['uniform_quality']:.6f};"
              f"bytes={r['plan_bytes']}/{r['budget_bytes']};"
              f"kinds={'+'.join(f'{k}:{v}' for k, v in sorted(r['kinds'].items()))}")
        sys.stdout.flush()
    failures = check(report)
    report["checks_failed"] = [f"{n}: {m}" for n, m in failures]
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, default=float)
    for name, msg in failures:
        print(f"plan/check/{name}/ERROR,0,{msg}")
    if failures:
        print(f"# {len(failures)} plan_bench check(s) failed", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
