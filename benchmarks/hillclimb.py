"""Perf-iteration driver: recompile one cell, print its roofline terms.

Usage:
  PYTHONPATH=src python -m benchmarks.hillclimb <arch> <shape> <tag> [--multi]
        [--embedding qr]

Writes artifacts/perf/<tag>__<arch>__<shape>__<mesh>.json and prints the
three terms + dominant + roofline fraction, for the EXPERIMENTS.md §Perf
log.  Iterations toggle code (constraints, accum, block sizes) between runs.
"""

import sys


def main():
    args = [a for a in sys.argv[1:] if not a.startswith("--")]
    multi = "--multi" in sys.argv
    emb = "qr"
    for i, a in enumerate(sys.argv):
        if a == "--embedding":
            emb = sys.argv[i + 1]
    arch, shape, tag = args[0], args[1], args[2]

    from repro.launch.dryrun import run_cell
    rec = run_cell(arch, shape, multi, f"artifacts/perf/{tag}", force=True,
                   embedding=emb)
    if not rec.get("ok"):
        print("FAIL:", rec.get("error"))
        raise SystemExit(1)
    from benchmarks.roofline import analyze_cell
    c = analyze_cell(rec)
    print(f"[{tag}] {arch}/{shape} mesh={'multi' if multi else 'pod'} emb={emb}")
    print(f"  compute_t={c['compute_t_s']:.4g}s memory_t={c['memory_t_s']:.4g}s "
          f"collective_t={c['collective_t_s']:.4g}s dominant={c['dominant']}")
    print(f"  MODEL/HLO={c['model_over_hlo_flops']:.3f} "
          f"roofline_frac={c['roofline_frac']:.4f} HBM={c['hbm_fit_gb']:.1f}GB")


if __name__ == "__main__":
    # must set XLA_FLAGS before jax import — reuse dryrun's module-level env
    import os
    os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")
    main()
