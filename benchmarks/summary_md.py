"""Markdown digest of every ``artifacts/bench/BENCH_*.json``.

Renders one section per bench artifact — pass/fail status from its
``checks_failed`` list, the headline scalars (QPS, p99, wire/table
bytes, ratios, parity booleans), and a compact table for row-shaped
reports — as GitHub-flavored markdown on stdout.  The CI bench-smoke
lane appends it to ``$GITHUB_STEP_SUMMARY`` so a PR's bench numbers are
readable without downloading artifacts.

Tolerant by design: a missing directory, a missing file, or malformed
JSON becomes a note in the output, never an exception — the summary
step must not mask the real bench failure signal.

Usage::

    python -m benchmarks.summary_md [--dir artifacts/bench] \
        >> "$GITHUB_STEP_SUMMARY"
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

# headline scalar keys, in display order, picked up wherever they appear
# at the top level of a report (or one level down in a sub-dict)
_HEADLINE = ("qps", "qps_max", "qps_1dev", "qps_8dev_projected", "p50_ms",
             "p99_ms", "wire_bytes", "hlo_wire_bytes", "bytes_per_device",
             "table_bytes_per_device", "bytes_ratio", "ratio",
             "int8_vs_none_ratio", "parity_bitwise", "parity_bitwise_cache",
             "bitwise", "bitwise_cache", "cache_hit_rate", "hit_rate",
             "devices", "requests", "waves")
# row-table columns worth showing, in priority order
_ROW_COLS = ("name", "arch", "path", "policy", "mode", "section", "qps",
             "p50_ms", "p99_ms", "step_time_us", "us_per_call",
             "wire_bytes", "hlo_wire_bytes", "bytes_ratio", "loss",
             "loss_after_steps", "hit_rate")
_MAX_ROWS = 24


def _fmt(v) -> str:
    if v is None:
        return ""
    if isinstance(v, bool):
        return "yes" if v else "no"
    if isinstance(v, float):
        return f"{v:,.3f}" if abs(v) < 100 else f"{v:,.0f}"
    if isinstance(v, int):
        return f"{v:,}"
    return str(v)


def _scalars(report: dict) -> list[tuple[str, object]]:
    """Headline (key, value) pairs from the report's top level and one
    sub-dict level down, first occurrence per key wins."""
    found: dict[str, object] = {}
    levels = [("", report)] + [
        (f"{k}.", v) for k, v in report.items() if isinstance(v, dict)]
    for _prefix, d in levels:
        for k, v in d.items():
            if k in _HEADLINE and k not in found \
                    and isinstance(v, (int, float, bool)):
                found[k] = v
    return [(k, found[k]) for k in _HEADLINE if k in found]


def _row_table(rows: list) -> list[str]:
    rows = [r for r in rows if isinstance(r, dict)]
    if not rows:
        return []
    cols = [c for c in _ROW_COLS if any(c in r for r in rows)][:8]
    if not cols:
        return []
    out = ["| " + " | ".join(cols) + " |",
           "|" + "---|" * len(cols)]
    for r in rows[:_MAX_ROWS]:
        out.append("| " + " | ".join(
            _fmt(r[c]) if c in r else "" for c in cols) + " |")
    if len(rows) > _MAX_ROWS:
        out.append(f"\n_... {len(rows) - _MAX_ROWS} more rows in the "
                   "artifact_")
    return out


def _stage_table(breakdown: dict) -> list[str]:
    """Per-lane stage-latency breakdown (the obs lane's wave anatomy:
    where a wave actually spends its time)."""
    out = []
    for lane, stages in breakdown.items():
        rows = [(s, d) for s, d in stages.items()
                if isinstance(d, dict) and d.get("count")]
        if not rows:
            continue
        total = sum(d["sum"] for s, d in rows
                    if s not in ("queue_wait", "pad")) or 1.0
        out += [f"**stage breakdown — {lane}**", "",
                "| stage | mean ms | p99 ms | share |", "|---|---|---|---|"]
        for s, d in rows:
            mean_ms = d["sum"] * 1e3 / d["count"]
            share = ("" if s in ("queue_wait", "pad")
                     else f"{d['sum'] / total:.1%}")
            out.append(f"| {s} | {mean_ms:.3f} | "
                       f"{d.get('p99', 0) * 1e3:.3f} | {share} |")
        out.append("")
    return out


def _collision_md(tables: dict) -> list[str]:
    """Predicted-vs-observed collision-mass table per arch — the
    planner's proxy against what serving traffic actually measured."""
    out = []
    for arch, rows in tables.items():
        rows = [r for r in rows if isinstance(r, dict)]
        if not rows:
            continue
        out += [f"**collision mass (predicted vs observed) — {arch}**", "",
                "| feature | kind | dim | lookups | predicted | observed |",
                "|---|---|---|---|---|---|"]
        for r in rows:
            out.append(
                f"| {r.get('feature')} | {r.get('kind', '')} | "
                f"{r.get('dim', '')} | {_fmt(r.get('observed_lookups'))} | "
                f"{_sci(r.get('predicted_collision_mass'))} | "
                f"{_sci(r.get('measured_collision_mass'))} |")
        out.append("")
    return out


def _recovery_table(rows: list) -> list[str]:
    """Warm-vs-cold loss recovery after a plan swap (the drift bench's
    lane 5): eval loss per train step for the migrated warm start against
    a cold re-init of the same re-solved plan."""
    rows = [r for r in rows if isinstance(r, dict) and "step" in r]
    if not rows:
        return []
    out = ["**loss recovery after re-plan (warm migrate vs cold re-init)**",
           "", "| step | warm | cold | warm - cold |", "|---|---|---|---|"]
    for r in rows[:_MAX_ROWS]:
        w, c = r.get("loss_warm"), r.get("loss_cold")
        delta = "" if w is None or c is None else f"{w - c:+.4f}"
        out.append(f"| {r['step']} | {_fmt(w)} | {_fmt(c)} | {delta} |")
    out.append("")
    return out


def _sci(v) -> str:
    try:
        return f"{float(v):.2e}"
    except (TypeError, ValueError):
        return ""


def section(path: str) -> list[str]:
    name = os.path.basename(path)
    try:
        with open(path) as f:
            report = json.load(f)
    except Exception as e:  # malformed artifact: report, don't raise
        return [f"### {name}", "", f"could not parse: `{e!r}`", ""]
    lines = [f"### {name}", ""]
    if not isinstance(report, dict):
        return lines + [f"unexpected payload type `{type(report).__name__}`",
                        ""]
    failed = report.get("checks_failed")
    if failed is not None:
        lines.append("**PASS** — all acceptance checks green" if not failed
                     else "**FAIL** — " + "; ".join(map(str, failed)))
        lines.append("")
    scalars = _scalars(report)
    if scalars:
        lines += ["| metric | value |", "|---|---|"]
        lines += [f"| {k} | {_fmt(v)} |" for k, v in scalars]
        lines.append("")
    table = _row_table(report.get("rows", []))
    if table:
        lines += table + [""]
    if isinstance(report.get("stage_breakdown"), dict):
        lines += _stage_table(report["stage_breakdown"])
    if isinstance(report.get("collision_tables"), dict):
        lines += _collision_md(report["collision_tables"])
    if isinstance(report.get("recovery"), list):
        lines += _recovery_table(report["recovery"])
    return lines


def analysis_section(path: str) -> list[str]:
    """Render a ``repro.analysis`` JSON report (the CI analysis lane's
    ``--out`` artifact): overall verdict, per-pass roll-up, and the
    inline waivers so suppressions stay reviewable."""
    lines = ["## Static analysis", ""]
    try:
        with open(path) as f:
            rep = json.load(f)
    except Exception as e:
        return lines + [f"could not parse `{path}`: `{e!r}`", ""]
    counts = rep.get("counts", {})
    verdict = ("**PASS** — no unsuppressed findings" if rep.get("ok")
               else f"**FAIL** — {counts.get('unsuppressed', '?')} "
                    "unsuppressed finding(s)")
    lines += [verdict + f" ({counts.get('suppressed', 0)} suppressed)", "",
              "| pass | layer | findings | seconds |", "|---|---|---|---|"]
    for p in rep.get("passes", []):
        lines.append(f"| {p.get('id')} ({p.get('name', '')}) | "
                     f"L{p.get('layer')} | {p.get('findings', 0)} | "
                     f"{p.get('seconds', 0)} |")
    lines.append("")
    findings = [f for f in rep.get("findings", []) if isinstance(f, dict)]
    if findings:
        lines += ["| rule | where | message | |", "|---|---|---|---|"]
        for f in findings[:_MAX_ROWS]:
            anchor = f.get("path", "")
            if f.get("line"):
                anchor += f":{f['line']}"
            tag = "waived" if f.get("suppressed") else "**live**"
            lines.append(f"| {f.get('rule')} | `{anchor}` | "
                         f"{f.get('message', '')} | {tag} |")
        lines.append("")
    return lines


def render(bench_dir: str, analysis: str | None = None,
           bench: bool = True) -> str:
    lines: list[str] = []
    if analysis is not None:
        lines += analysis_section(analysis)
    if bench:
        lines += ["## Benchmark summary", ""]
        paths = sorted(glob.glob(os.path.join(bench_dir, "BENCH_*.json")))
        if not paths:
            lines.append(f"_no `BENCH_*.json` artifacts under "
                         f"`{bench_dir}`_")
        for p in paths:
            lines += section(p)
    return "\n".join(lines).rstrip() + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="artifacts/bench")
    ap.add_argument("--analysis", default=None,
                    help="also render this repro.analysis JSON report")
    ap.add_argument("--no-bench", action="store_true",
                    help="skip the BENCH_*.json sections (analysis-lane "
                         "summaries)")
    args = ap.parse_args(argv)
    # default: pick up the analysis report when it exists next to the
    # bench artifacts, so the bench lane's summary shows both
    analysis = args.analysis
    if analysis is None \
            and os.path.exists(os.path.join("artifacts", "analysis",
                                            "report.json")):
        analysis = os.path.join("artifacts", "analysis", "report.json")
    sys.stdout.write(render(args.dir, analysis=analysis,
                            bench=not args.no_bench))
    return 0


if __name__ == "__main__":
    sys.exit(main())
