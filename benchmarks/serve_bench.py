"""Quantized-serving benchmark: {f32, bf16, int8} x {cache off, on}.

For each rec arch (the paper's DLRM + DCN, reduced Criteo configs at the
deployment embedding dim D=64 — at D=16 the per-row scale/zp meta alone
is 5% of the f32 bytes and the 0.27x acceptance bar is unreachable by
arithmetic, not by implementation), the bench:

1. trains the f32 model briefly on the synthetic Criteo stream (so logits
   carry the planted signal and the AUC proxy is meaningful);
2. post-training-quantizes the tables (``repro.serve.quantize``) and
   reports table bytes vs f32;
3. scores a fixed held-out batch under each mode and reports the BCE loss
   + ranking-AUC deltas vs f32;
4. drives the microbatched ``RecsysEngine`` with a Zipfian multi-hot
   request stream (the criteo generator's skew), cache off and on, and
   reports p50/p99 wave latency, QPS, and cache hit rate.

Built-in acceptance checks (any failure -> ``/ERROR`` row + exit 1, same
contract as ``dist_bench``):

* int8 table bytes <= 0.27x f32;
* every int8 table row dequantizes within its per-row bound
  (``|dequant - w| <= scale/2``);
* quantized BCE loss within ``LOSS_TOL`` and AUC within ``AUC_TOL`` of
  f32 on the fixed batch;
* cache-on rows see hit rate > 0 under the Zipfian stream.

The request stream includes **empty bags** (every 4th request drops one
feature's ids — legal Criteo traffic the engine must pool to the zero
vector), so the whole sweep regression-tests that path.

A **mixed-dimension lane** per arch additionally solves a mixed-dim plan
at 0.125x full-table bytes (``plan.dim_ladder``: {D/4, D/2, D}), builds
the model from it, and serves the stream int8 + cache-on.  Acceptance:
built table bytes equal the plan's per-table claim (f32 *and* serve-int8
domains), the plan's widths are genuinely per-feature (>= 2 distinct),
the host cache+projection path matches the in-graph path to 1e-3, and
hit rate > 0.  (The 0.27x bar is a D=64 number — narrow rows amortize
the 3 B scale/zp meta over fewer dims, so the mixed lane gates on exact
serve-domain accounting instead.)

Artifacts: ``artifacts/bench/BENCH_serve.json``, a compact top-level
mirror (``BENCH_serve.json``: totals + acceptance booleans, the
perf-trajectory hook), and CSV on stdout (``name,us_per_call,derived``).

Usage::

    python -m benchmarks.serve_bench --steps 30
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

ART = "artifacts/bench"
INT8_BYTES_BAR = 0.27
LOSS_TOL = 0.05      # abs BCE delta vs f32 on the fixed batch
AUC_TOL = 0.02       # abs ranking-AUC delta vs f32
SERVE_EMB_DIM = 64
ARCHS = ("dlrm-criteo", "dcn-criteo")
MODES = ("f32", "bf16", "int8")
OBS_QPS_RATIO_MIN = 0.98   # obs-on QPS >= this fraction of obs-off
OBS_STAGE_TOL = 0.10       # |stage-sum / wave-latency - 1| bound


def _auc(logits, labels) -> float:
    """Rank-based AUC (the Wilcoxon statistic) — the CTR quality proxy."""
    import numpy as np
    logits = np.asarray(logits, np.float64)
    labels = np.asarray(labels) > 0.5
    pos, neg = logits[labels], logits[~labels]
    if not len(pos) or not len(neg):
        return 0.5
    ranks = np.argsort(np.argsort(np.concatenate([pos, neg]))) + 1.0
    return (ranks[:len(pos)].sum() - len(pos) * (len(pos) + 1) / 2) \
        / (len(pos) * len(neg))


def _build(arch: str):
    import jax

    from repro.configs import get_arch
    from repro.data.criteo import CriteoSpec, batch_at
    from repro.optim import optimizers as opt
    from repro.train.loop import init_state, make_train_step

    mod = get_arch(arch)
    cfg = dataclasses.replace(mod.config(reduced=True),
                              emb_dim=SERVE_EMB_DIM)
    api = mod.api(cfg)
    spec = CriteoSpec(table_sizes=cfg.table_sizes, zipf=1.5, noise=0.5)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, spec, params, batch_at, opt, init_state, make_train_step


def _train(api, spec, params, batch_at, init_state, make_train_step,
           steps: int):
    import jax
    state = init_state(params, api.optimizer)
    step = jax.jit(make_train_step(api.loss_fn, api.optimizer))
    for i in range(steps):
        state, m = step(state, batch_at(0, i, 128, spec))
    jax.block_until_ready(m["loss"])
    return state["params"]


def _requests(cfg, spec, batch_at, n: int, max_bag: int = 24):
    """Deterministic Zipfian multi-hot stream with history-length bags
    (1..``max_bag``, cycling) — **including empty bags** (every 4th
    request drops one feature's bag entirely, the Criteo-traffic case the
    engine must pool to zero).  Ids are zipf-skewed per table, matching
    the synthetic criteo generator's skew, so a hot-row cache sees the
    high-hit-rate regime production embedding servers are built for.
    Long bags matter for the cache lanes: the in-graph path pays
    gather + dequant + QR-combine *per lookup* while the device cache
    pays one f32 slab gather, so the win scales with bag length."""
    import numpy as np
    f = len(cfg.table_sizes)
    rng = np.random.default_rng(1234)
    dense = np.asarray(batch_at(0, 101, n, spec)["dense"], np.float32)
    out = []
    for r in range(n):
        length = 1 + (r * 7) % max_bag
        bags = [list(((rng.zipf(spec.zipf, size=length) - 1) % s)
                     .astype(int)) for s in cfg.table_sizes]
        if r % 4 == 0:
            bags[r % f] = []  # legal empty bag -> exact zero-vector pool
        out.append((dense[r], bags))
    return out


def _run_warm_then_timed(engines, reqs, reps: int = 5, per_rep=None):
    """The shared measurement protocol: two warm passes (the first fills
    any cache and compiles the miss-path shapes, the second sees the
    filled cache and compiles every (B, L) bucket's *hit*-path shapes —
    so the timed pass measures steady-state hot traffic, the regime
    repeated Zipfian streams converge to, not jit compilation), reset
    metrics and cache counters (resident bytes kept), then the timed
    pass.  The timed pass runs ``reps`` times and each engine keeps its
    best-QPS rep (minimum-noise estimator: this box is a shared CPU, and
    the occasional scheduler stall says nothing about the engine).
    Returns the last rep's per-request uid tuples, each engine's
    completed map, and the per-engine best metrics.  Pass a list as
    ``per_rep`` to also receive every rep's per-engine metrics — paired
    within a rep, so A/B comparisons can cancel common-mode box noise."""
    for _warm_pass in range(2):
        for d, b in reqs:
            for e in engines:
                e.submit(d, b)
        for e in engines:
            e.run_until_drained()
    best = [None] * len(engines)
    for _rep in range(reps):
        for e in engines:
            # reset_metrics drops cache traffic counters too (resident
            # bytes survive), so warm-up never leaks into hit rates
            e.reset_metrics()
        uids = [tuple(e.submit(d, b) for e in engines) for d, b in reqs]
        done = [e.run_until_drained() for e in engines]
        metrics = [e.metrics() for e in engines]
        if per_rep is not None:
            per_rep.append(metrics)
        for i, m in enumerate(metrics):
            if best[i] is None or m["qps"] > best[i]["qps"]:
                best[i] = m
    return uids, done, best


def _engine_cell(cfg, qparams, reqs, *, cache_rows: int, max_batch: int,
                 batching: str = "continuous"):
    from repro.serve.cache import DeviceHotRowCache
    from repro.serve.recsys import RecsysEngine

    # cache-on lanes use the device-resident cache (the serving hot path);
    # the host HotRowCache stays covered by tests as the compat path
    cache = DeviceHotRowCache(capacity_rows=cache_rows) if cache_rows \
        else None
    eng = RecsysEngine(cfg, qparams, max_batch=max_batch, cache=cache,
                       batching=batching)
    _, _, (m,) = _run_warm_then_timed([eng], reqs)
    return m


def _mixed_dim_cell(arch: str, cfg, reqs, max_batch: int) -> dict:
    """Mixed-dimension serving lane: solve a mixed-dim plan at 0.125x of
    the full-table bytes (the plan_bench strict-beat point), build the
    model from it, quantize int8, and serve the same request stream with
    the hot-row cache on — cache-on scores must match the cache-off
    (in-graph) path, hit rate must be positive, and every built table's
    bytes must equal the plan's claim (per-feature width drift fails)."""
    import dataclasses as dc
    import time as _time

    import jax

    from repro.core import make_embedding
    from repro.plan import dim_ladder, full_table_bytes, plan_for_config
    from repro.serve.cache import DeviceHotRowCache
    from repro.serve.quantize import memory_report, quantize_params
    from repro.serve.recsys import RecsysEngine

    dim = cfg.emb_dim
    budget = int(full_table_bytes(cfg.table_sizes, dim) * 0.125)
    plan = plan_for_config(cfg, budget, arch=f"{arch}-mixed",
                           num_batches=8, batch_size=256,
                           dims=dim_ladder(dim))
    built_ok = all(
        make_embedding(n, dim, plan, feature=i).num_params * 4
        == plan.tables[i].train_bytes
        for i, n in enumerate(cfg.table_sizes))
    pcfg = dc.replace(cfg, embedding=plan)
    from repro.configs import get_arch
    api = get_arch(arch).api(pcfg)
    params = api.init(jax.random.PRNGKey(0))
    qparams = quantize_params(params)
    rep = memory_report(params, qparams)
    # the serve-domain twin of the built-bytes check: the quantized tables
    # must weigh exactly what the plan's serve_int8 domain claimed (the
    # 0.27x bar is a D=64 number — narrow rows amortize the 3 B scale/zp
    # meta over fewer dims, so the *accounting*, not the bar, is the gate)
    planned_serve_bytes = sum(t.serve_bytes_int8 for t in plan.tables)

    t0 = _time.monotonic()
    eng_c = RecsysEngine(pcfg, qparams, max_batch=max_batch,
                         cache=DeviceHotRowCache(capacity_rows=4096))
    eng_n = RecsysEngine(pcfg, qparams, max_batch=max_batch)
    uids, (done_c, done_n), (m, _mn) = _run_warm_then_timed(
        [eng_c, eng_n], reqs)
    max_dscore = max(abs(done_c[a].score - done_n[b].score)
                     for a, b in uids)
    return {
        "arch": arch, "mode": "int8-mixed-plan", "cache": "on",
        "batching": "continuous",
        "budget_bytes": budget, "plan_bytes": plan.total_bytes,
        "plan_dims": sorted(set(plan.table_dims)),
        "plan_built_bytes_ok": built_ok,
        "table_bytes_f32": rep["f32_table_bytes"],
        "table_bytes": rep["quant_table_bytes"],
        "planned_serve_bytes": planned_serve_bytes,
        "bytes_ratio": rep["ratio"],
        "table_dims": rep["table_dims"],
        "cache_vs_ingraph_max_dscore": max_dscore,
        "p50_ms": m["p50_ms"], "p99_ms": m["p99_ms"], "qps": m["qps"],
        "waves": m["waves"],
        "hit_rate": (m.get("cache") or {}).get("hit_rate"),
        "cache_stats": m.get("cache"),
        "wall_s": round(_time.monotonic() - t0, 2),
    }


def _obs_lane(arch: str, cfg, spec, reqs, max_batch: int):
    """Observability lane: the mixed-dim planned model (hash/QR tables
    guarantee nonzero collision mass on both the predicted and measured
    side), int8 + device cache, run obs-OFF and obs-ON through the same
    warm+timed protocol (7 paired reps) per batching mode.  Returns per-(arch,
    batching) comparison rows, the per-feature predicted-vs-observed
    collision table, and the continuous lane's ``Obs`` (for the trace /
    metrics CI artifacts)."""
    import dataclasses as dc

    import jax

    from repro.configs import get_arch
    from repro.obs import Obs
    from repro.plan import build_plan, dim_ladder, full_table_bytes
    from repro.plan.freq import stats_from_criteo
    from repro.serve.cache import DeviceHotRowCache
    from repro.serve.quantize import quantize_params
    from repro.serve.recsys import RecsysEngine

    dim = cfg.emb_dim
    budget = int(full_table_bytes(cfg.table_sizes, dim) * 0.125)
    # keep the training-stream stats: they are the predicted side of the
    # collision table (same knobs the mixed lane's plan_for_config uses)
    stats = stats_from_criteo(spec, num_batches=8, batch_size=256)
    plan = build_plan(stats, dim, budget, arch=f"{arch}-obs",
                      dims=dim_ladder(dim))
    pcfg = dc.replace(cfg, embedding=plan)
    api = get_arch(arch).api(pcfg)
    qparams = quantize_params(api.init(jax.random.PRNGKey(0)))

    rows, art_obs, obs = [], None, None
    for batching in ("continuous", "waves"):
        t0 = time.monotonic()
        obs = Obs(trace=True, collisions=True)
        eng_off = RecsysEngine(pcfg, qparams, max_batch=max_batch,
                               cache=DeviceHotRowCache(capacity_rows=4096),
                               batching=batching)
        eng_on = RecsysEngine(pcfg, qparams, max_batch=max_batch,
                              cache=DeviceHotRowCache(capacity_rows=4096),
                              batching=batching, obs=obs)
        per_rep = []
        uids, (done_off, done_on), (m_off, m_on) = _run_warm_then_timed(
            [eng_off, eng_on], reqs, reps=7, per_rep=per_rep)
        eng_on.metrics()  # folds cache stats into the registry gauges
        ss = eng_on.stage_summary()
        # the overhead gate asks "does obs *systematically* cost > 2%?" —
        # one clean paired rep under the bar refutes that, so gate on the
        # best per-rep ratio (pairing cancels common-mode box noise that
        # the ratio-of-bests estimator re-introduces)
        ratios = [on["qps"] / off["qps"] for off, on in per_rep
                  if off["qps"] > 0]
        rows.append({
            "arch": arch, "batching": batching,
            "qps_off": m_off["qps"], "qps_on": m_on["qps"],
            "qps_ratio": max(ratios) if ratios else 0.0,
            "p99_ms_off": m_off["p99_ms"], "p99_ms_on": m_on["p99_ms"],
            "stage_sum_ratio": ss["partition"]["ratio"],
            "stage_breakdown": {s: ss[s] for s in
                                ("queue_wait", "pad", "probe", "dense",
                                 "inflight", "miss_gather", "flush")},
            "scores_identical": all(done_on[b].score == done_off[a].score
                                    for a, b in uids),
            "trace_events": len(obs.tracer),
            "wall_s": round(time.monotonic() - t0, 2),
        })
        if batching == "continuous":
            art_obs = obs
    # the collision table rides on the last lane's telemetry (collisions
    # accumulate across warm-up + reps — more traffic, tighter estimate)
    from repro.models.dlrm import tables_for
    table = obs.collisions.report(tables_for(pcfg),
                                  predicted_stats=stats, plan=plan)
    return rows, table, art_obs


def bench(steps: int, requests: int, max_batch: int) -> dict:
    import numpy as np

    from repro.serve.quantize import (dequantize_table, is_quantized_table,
                                      memory_report, paths_and_leaves,
                                      quantize_params)

    rows = []
    mixed_rows = []
    obs_rows = []
    collision_tables = {}
    for arch in ARCHS:
        cfg, api, spec, params0, batch_at, _, init_state, make_train_step = \
            _build(arch)
        params = _train(api, spec, params0, batch_at, init_state,
                        make_train_step, steps)
        fixed = batch_at(0, 9999, 512, spec)
        base_loss = float(api.loss_fn(params, fixed)[0])
        base_auc = _auc(api.predict(params, fixed), fixed["label"])
        reqs = _requests(cfg, spec, batch_at, requests)
        for mode in MODES:
            qparams = quantize_params(params, mode=mode)
            rep = memory_report(params, qparams)
            loss = float(api.loss_fn(qparams, fixed)[0])
            auc = _auc(api.predict(qparams, fixed), fixed["label"])
            row_bound_ok, max_row_err_frac = True, 0.0
            if mode == "int8":
                # per-row bound: |dequant - w| <= scale/2, paired by path
                base_by_path = dict(paths_and_leaves(params))
                for path, qt in paths_and_leaves(qparams):
                    if not is_quantized_table(qt):
                        continue
                    w = base_by_path[path]
                    err = np.abs(np.asarray(dequantize_table(qt))
                                 - np.asarray(w, np.float32))
                    bound = 0.5 * np.asarray(qt["scale"], np.float32) + 1e-8
                    frac = float((err / bound).max())
                    max_row_err_frac = max(max_row_err_frac, frac)
                    row_bound_ok &= bool((err <= bound).all())
            lanes = [(0, "continuous"), (4096, "continuous")]
            if mode == "int8":
                # legacy lock-step lanes ride along on the quantized mode
                # so the continuous-batching gain stays measured
                lanes += [(0, "waves"), (4096, "waves")]
            for cache_rows, batching in lanes:
                t0 = time.monotonic()
                m = _engine_cell(cfg, qparams, reqs,
                                 cache_rows=cache_rows, max_batch=max_batch,
                                 batching=batching)
                rows.append({
                    "arch": arch, "mode": mode,
                    "cache": "on" if cache_rows else "off",
                    "batching": batching,
                    "table_bytes_f32": rep["f32_table_bytes"],
                    "table_bytes": rep["quant_table_bytes"],
                    "bytes_ratio": rep["ratio"],
                    "loss_f32": base_loss, "loss": loss,
                    "auc_f32": base_auc, "auc": auc,
                    "row_bound_ok": row_bound_ok,
                    "max_row_err_frac": max_row_err_frac,
                    "p50_ms": m["p50_ms"], "p99_ms": m["p99_ms"],
                    "qps": m["qps"], "waves": m["waves"],
                    "buckets": [list(b) for b in m["buckets"]],
                    "hit_rate": (m.get("cache") or {}).get("hit_rate"),
                    "cache_stats": m.get("cache"),
                    "wall_s": round(time.monotonic() - t0, 2),
                })
        mixed_rows.append(_mixed_dim_cell(arch, cfg, reqs, max_batch))
        o_rows, o_table, o_art = _obs_lane(arch, cfg, spec, reqs, max_batch)
        obs_rows.extend(o_rows)
        collision_tables[arch] = o_table
        if o_art is not None and arch == ARCHS[0]:
            # CI artifacts: the first arch's continuous obs lane
            o_art.save(
                metrics_path=os.path.join(ART, "obs_metrics.jsonl"),
                trace_path=os.path.join(ART, "obs_trace.json"))
    return {"requests": requests, "max_batch": max_batch,
            "train_steps": steps, "emb_dim": SERVE_EMB_DIM, "rows": rows,
            "mixed_rows": mixed_rows, "obs_rows": obs_rows,
            "collision_tables": collision_tables}


def check(report: dict) -> list[tuple[str, str]]:
    """(name, message) per failed acceptance check; empty = all green."""
    failures = []
    for r in report["rows"]:
        cell = f"{r['arch']}/{r['mode']}/cache_{r['cache']}/{r['batching']}"
        if r["p99_ms"] > 10 * r["p50_ms"] + 10:
            failures.append((cell, f"p99 {r['p99_ms']:.1f} ms unbounded "
                                   f"vs p50 {r['p50_ms']:.1f} ms"))
        if r["mode"] == "int8":
            if r["bytes_ratio"] > INT8_BYTES_BAR:
                failures.append((cell, f"int8 table bytes {r['bytes_ratio']:.3f}x "
                                       f"f32 > {INT8_BYTES_BAR}"))
            if not r["row_bound_ok"]:
                failures.append((cell, "per-row dequant error exceeds scale/2 "
                                       f"(max {r['max_row_err_frac']:.3f}x bound)"))
        if r["mode"] != "f32":
            dl = abs(r["loss"] - r["loss_f32"])
            da = abs(r["auc"] - r["auc_f32"])
            if dl > LOSS_TOL:
                failures.append((cell, f"loss delta {dl:.4f} > {LOSS_TOL}"))
            if da > AUC_TOL:
                failures.append((cell, f"auc delta {da:.4f} > {AUC_TOL}"))
        if r["cache"] == "on" and not (r["hit_rate"] or 0) > 0:
            failures.append((cell, "cache enabled but hit rate is 0 under "
                                   "the Zipfian stream"))
    for r in report.get("mixed_rows", []):
        cell = f"{r['arch']}/{r['mode']}"
        if not r["plan_built_bytes_ok"]:
            failures.append((cell, "a mixed-dim table's built bytes differ "
                                   "from its planned train_bytes"))
        if r["plan_bytes"] > r["budget_bytes"]:
            failures.append((cell, f"mixed-dim plan bytes {r['plan_bytes']} "
                                   f"exceed budget {r['budget_bytes']}"))
        if len(r["plan_dims"]) < 2:
            # gate on the plan's per-feature widths, not physical sub-table
            # widths (op="concat" splits sub-tables to dim/k and would
            # false-pass a uniform plan)
            failures.append((cell, f"plan produced uniform widths "
                                   f"{r['plan_dims']} — the mixed-dim lane "
                                   f"must exercise per-feature row widths"))
        if r["cache_vs_ingraph_max_dscore"] > 1e-3:
            failures.append((cell, f"cache-path scores diverge from the "
                                   f"in-graph path by "
                                   f"{r['cache_vs_ingraph_max_dscore']:.2e}"))
        if not (r["hit_rate"] or 0) > 0:
            failures.append((cell, "cache enabled but hit rate is 0 under "
                                   "the Zipfian stream"))
        if r["table_bytes"] != r["planned_serve_bytes"]:
            failures.append((cell, f"quantized table bytes "
                                   f"{r['table_bytes']} differ from the "
                                   f"plan's serve_int8 claim "
                                   f"{r['planned_serve_bytes']}"))
    for name, (on, off) in _cache_pairs(report).items():
        if not on["qps"] > off["qps"]:
            failures.append((name, f"device cache on ({on['qps']:.0f} qps) "
                                   f"does not beat cache off "
                                   f"({off['qps']:.0f} qps)"))
    for r in report.get("obs_rows", []):
        cell = f"{r['arch']}/obs/{r['batching']}"
        if r["qps_ratio"] < OBS_QPS_RATIO_MIN:
            failures.append((cell, f"obs-on best paired qps ratio "
                                   f"{r['qps_ratio']:.3f} < "
                                   f"{OBS_QPS_RATIO_MIN} (best qps "
                                   f"on/off {r['qps_on']:.0f}/"
                                   f"{r['qps_off']:.0f})"))
        if abs(r["stage_sum_ratio"] - 1.0) > OBS_STAGE_TOL:
            failures.append((cell, f"stage-timeline sum is "
                                   f"{r['stage_sum_ratio']:.3f}x the "
                                   f"measured wave latency (tol "
                                   f"{OBS_STAGE_TOL})"))
        if not r["scores_identical"]:
            failures.append((cell, "obs-on scores differ from obs-off "
                                   "(observability must be read-only)"))
    for arch, table in report.get("collision_tables", {}).items():
        ok = any(_finite_nonzero(t.get("predicted_collision_mass"))
                 and _finite_nonzero(t.get("measured_collision_mass"))
                 for t in table)
        if not ok:
            failures.append((f"{arch}/obs/collisions",
                             "no feature has nonzero finite predicted AND "
                             "measured collision mass"))
    return failures


def _finite_nonzero(x) -> bool:
    import math
    return x is not None and math.isfinite(x) and x != 0.0


def _cache_pairs(report: dict) -> dict:
    """int8 cache-on/off row pairs per (arch, batching) — the lanes the
    "hot-row cache must pay for itself" acceptance is judged on (int8 is
    the serving deployment mode; f32/bf16 lanes are parity context)."""
    by = {(r["arch"], r["mode"], r["cache"], r["batching"]): r
          for r in report["rows"]}
    pairs = {}
    for (arch, mode, cache, batching), r in by.items():
        if mode != "int8" or cache != "on":
            continue
        off = by.get((arch, mode, "off", batching))
        if off is not None:
            pairs[f"{arch}/{mode}/{batching}"] = (r, off)
    return pairs


def summarize(report: dict) -> dict:
    """The compact top-level mirror (``BENCH_serve.json`` at the repo
    root): totals + acceptance booleans, the schema the perf-trajectory
    tooling consumes — keep keys stable."""
    rows = report["rows"]
    mixed = report.get("mixed_rows", [])
    failed = report.get("checks_failed", [])
    int8 = [r for r in rows if r["mode"] == "int8"]
    on = [r for r in rows if r["cache"] == "on"] + mixed
    pairs = _cache_pairs(report)
    return {
        "bench": "serve",
        "source": os.path.join(ART, "BENCH_serve.json"),
        "cells": len(rows) + len(mixed),
        "emb_dim": report["emb_dim"],
        "int8_bytes_ratio_max": max((r["bytes_ratio"] for r in int8),
                                    default=0.0),
        "qps_max": max((r["qps"] for r in rows + mixed), default=0.0),
        "hit_rate_min": min(((r["hit_rate"] or 0.0) for r in on),
                            default=0.0),
        # every lane lands here: arch/mode/cache/batching -> its numbers
        # (the perf-trajectory hook graphs these per lane)
        "lanes": {
            f"{r['arch']}/{r['mode']}/cache_{r['cache']}/{r['batching']}": {
                "qps": r["qps"], "p50_ms": r["p50_ms"],
                "p99_ms": r["p99_ms"], "hit_rate": r["hit_rate"],
            } for r in rows + mixed},
        "cache_speedup_min": min(
            (on_r["qps"] / off_r["qps"]
             for on_r, off_r in pairs.values() if off_r["qps"] > 0),
            default=0.0),
        "acceptance": {
            "int8_bytes_bar": all(r["bytes_ratio"] <= INT8_BYTES_BAR
                                  for r in int8),
            "mixed_serve_bytes_match": all(
                r["table_bytes"] == r["planned_serve_bytes"]
                for r in mixed) and bool(mixed),
            "row_bound": all(r["row_bound_ok"] for r in int8),
            "parity": all(abs(r["loss"] - r["loss_f32"]) <= LOSS_TOL
                          and abs(r["auc"] - r["auc_f32"]) <= AUC_TOL
                          for r in rows if r["mode"] != "f32"),
            "cache_hits": all((r["hit_rate"] or 0) > 0 for r in on),
            "cache_on_beats_off": bool(pairs) and all(
                on_r["qps"] > off_r["qps"]
                for on_r, off_r in pairs.values()),
            "p99_bounded": all(r["p99_ms"] <= 10 * r["p50_ms"] + 10
                               for r in rows + mixed),
            "mixed_dim_serves": bool(mixed) and all(
                r["plan_built_bytes_ok"] and len(r["plan_dims"]) >= 2
                and r["cache_vs_ingraph_max_dscore"] <= 1e-3
                for r in mixed),
            "all_checks_passed": not failed,
        },
        "checks_failed": failed,
    }


def summarize_obs(report: dict) -> dict:
    """The compact ``BENCH_obs.json`` mirror: obs-overhead + stage-sum +
    collision acceptance, the schema the CI obs gate consumes."""
    obs_rows = report.get("obs_rows", [])
    tables = report.get("collision_tables", {})
    failed = [f for f in report.get("checks_failed", []) if "/obs" in f]
    return {
        "bench": "obs",
        "source": os.path.join(ART, "BENCH_serve.json"),
        "lanes": {f"{r['arch']}/{r['batching']}": {
            "qps_on": r["qps_on"], "qps_off": r["qps_off"],
            "qps_ratio": r["qps_ratio"],
            "stage_sum_ratio": r["stage_sum_ratio"],
        } for r in obs_rows},
        "qps_ratio_min": min((r["qps_ratio"] for r in obs_rows),
                             default=0.0),
        "stage_breakdown": {r["arch"] + "/" + r["batching"]:
                            r["stage_breakdown"] for r in obs_rows},
        "collision_tables": tables,
        "acceptance": {
            "obs_overhead": bool(obs_rows) and all(
                r["qps_ratio"] >= OBS_QPS_RATIO_MIN for r in obs_rows),
            "stage_sum_within_tol": bool(obs_rows) and all(
                abs(r["stage_sum_ratio"] - 1.0) <= OBS_STAGE_TOL
                for r in obs_rows),
            "obs_readonly": all(r["scores_identical"] for r in obs_rows),
            "collision_predicted_vs_observed": bool(tables) and all(
                any(_finite_nonzero(t.get("predicted_collision_mass"))
                    and _finite_nonzero(t.get("measured_collision_mass"))
                    for t in table) for table in tables.values()),
            "all_checks_passed": not failed,
        },
        "checks_failed": failed,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int,
                    default=int(os.environ.get("REPRO_BENCH_STEPS", 30)),
                    help="f32 pre-training steps per arch")
    ap.add_argument("--requests", type=int, default=192)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--out", default=os.path.join(ART, "BENCH_serve.json"))
    ap.add_argument("--summary-out", default="BENCH_serve.json",
                    help="compact top-level mirror (totals + acceptance "
                         "booleans) for the perf-trajectory tooling")
    ap.add_argument("--obs-out", default="BENCH_obs.json",
                    help="top-level mirror of the obs-lane summary "
                         "(overhead ratio, stage breakdown, collision "
                         "table)")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    try:
        report = bench(args.steps, args.requests, args.max_batch)
    except Exception as e:
        print(f"serve_bench/ERROR,0,{repr(e)[:160]}")
        return 1
    for r in report["rows"]:
        hr = "" if r["hit_rate"] is None else f";hit_rate={r['hit_rate']:.3f}"
        print(f"serve/{r['arch']}/{r['mode']}/cache_{r['cache']}"
              f"/{r['batching']},"
              f"{r['p50_ms'] * 1e3:.0f},"
              f"bytes_ratio={r['bytes_ratio']:.3f};qps={r['qps']:.1f};"
              f"p99_ms={r['p99_ms']:.1f};dloss={abs(r['loss'] - r['loss_f32']):.4f}"
              f"{hr}")
        sys.stdout.flush()
    for r in report["mixed_rows"]:
        print(f"serve/{r['arch']}/{r['mode']}/cache_{r['cache']}"
              f"/{r['batching']},"
              f"{r['p50_ms'] * 1e3:.0f},"
              f"bytes_ratio={r['bytes_ratio']:.3f};qps={r['qps']:.1f};"
              f"dims={'x'.join(map(str, r['plan_dims']))};"
              f"dscore={r['cache_vs_ingraph_max_dscore']:.1e};"
              f"hit_rate={(r['hit_rate'] or 0):.3f}")
        sys.stdout.flush()
    for r in report.get("obs_rows", []):
        print(f"serve/{r['arch']}/obs/{r['batching']},"
              f"{r['p99_ms_on'] * 1e3:.0f},"
              f"qps_ratio={r['qps_ratio']:.3f};"
              f"stage_sum_ratio={r['stage_sum_ratio']:.3f};"
              f"qps_on={r['qps_on']:.1f};qps_off={r['qps_off']:.1f}")
        sys.stdout.flush()
    failures = check(report)
    report["checks_failed"] = [f"{n}: {m}" for n, m in failures]
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, default=float)
    with open(args.summary_out, "w") as f:
        json.dump(summarize(report), f, indent=1, default=float)
    obs_summary = summarize_obs(report)
    with open(os.path.join(ART, "BENCH_obs.json"), "w") as f:
        json.dump(obs_summary, f, indent=1, default=float)
    with open(args.obs_out, "w") as f:
        json.dump(obs_summary, f, indent=1, default=float)
    for name, msg in failures:
        print(f"serve/check/{name}/ERROR,0,{msg}")
    if failures:
        print(f"# {len(failures)} serve_bench check(s) failed", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
