"""Quantized-serving benchmark: {f32, bf16, int8} x {cache off, on}.

For each rec arch (the paper's DLRM + DCN, reduced Criteo configs at the
deployment embedding dim D=64 — at D=16 the per-row scale/zp meta alone
is 5% of the f32 bytes and the 0.27x acceptance bar is unreachable by
arithmetic, not by implementation), the bench:

1. trains the f32 model briefly on the synthetic Criteo stream (so logits
   carry the planted signal and the AUC proxy is meaningful);
2. post-training-quantizes the tables (``repro.serve.quantize``) and
   reports table bytes vs f32;
3. scores a fixed held-out batch under each mode and reports the BCE loss
   + ranking-AUC deltas vs f32;
4. drives the microbatched ``RecsysEngine`` with a Zipfian multi-hot
   request stream (the criteo generator's skew), cache off and on, and
   reports p50/p99 wave latency, QPS, and cache hit rate.

Built-in acceptance checks (any failure -> ``/ERROR`` row + exit 1, same
contract as ``dist_bench``):

* int8 table bytes <= 0.27x f32;
* every int8 table row dequantizes within its per-row bound
  (``|dequant - w| <= scale/2``);
* quantized BCE loss within ``LOSS_TOL`` and AUC within ``AUC_TOL`` of
  f32 on the fixed batch;
* cache-on rows see hit rate > 0 under the Zipfian stream.

Artifacts: ``artifacts/bench/BENCH_serve.json`` + CSV on stdout
(``name,us_per_call,derived``).

Usage::

    python -m benchmarks.serve_bench --steps 30
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

ART = "artifacts/bench"
INT8_BYTES_BAR = 0.27
LOSS_TOL = 0.05      # abs BCE delta vs f32 on the fixed batch
AUC_TOL = 0.02       # abs ranking-AUC delta vs f32
SERVE_EMB_DIM = 64
ARCHS = ("dlrm-criteo", "dcn-criteo")
MODES = ("f32", "bf16", "int8")


def _auc(logits, labels) -> float:
    """Rank-based AUC (the Wilcoxon statistic) — the CTR quality proxy."""
    import numpy as np
    logits = np.asarray(logits, np.float64)
    labels = np.asarray(labels) > 0.5
    pos, neg = logits[labels], logits[~labels]
    if not len(pos) or not len(neg):
        return 0.5
    ranks = np.argsort(np.argsort(np.concatenate([pos, neg]))) + 1.0
    return (ranks[:len(pos)].sum() - len(pos) * (len(pos) + 1) / 2) \
        / (len(pos) * len(neg))


def _build(arch: str):
    import jax

    from repro.configs import get_arch
    from repro.data.criteo import CriteoSpec, batch_at
    from repro.optim import optimizers as opt
    from repro.train.loop import init_state, make_train_step

    mod = get_arch(arch)
    cfg = dataclasses.replace(mod.config(reduced=True),
                              emb_dim=SERVE_EMB_DIM)
    api = mod.api(cfg)
    spec = CriteoSpec(table_sizes=cfg.table_sizes, zipf=1.5, noise=0.5)
    params = api.init(jax.random.PRNGKey(0))
    return cfg, api, spec, params, batch_at, opt, init_state, make_train_step


def _train(api, spec, params, batch_at, init_state, make_train_step,
           steps: int):
    import jax
    state = init_state(params, api.optimizer)
    step = jax.jit(make_train_step(api.loss_fn, api.optimizer))
    for i in range(steps):
        state, m = step(state, batch_at(0, i, 128, spec))
    jax.block_until_ready(m["loss"])
    return state["params"]


def _requests(cfg, spec, batch_at, n: int):
    """Deterministic Zipfian multi-hot stream: bag lengths cycle 1..3, ids
    drawn from the synthetic criteo generator (zipf-skewed per table)."""
    import numpy as np
    f = len(cfg.table_sizes)
    dense = np.asarray(batch_at(0, 101, n, spec)["dense"], np.float32)
    ids = np.stack([np.asarray(batch_at(0, 200 + j, n, spec)["sparse"])
                    for j in range(3)])  # (3, n, F)
    out = []
    for r in range(n):
        bags = [[int(ids[j, r, i]) for j in range(1 + r % 3)]
                for i in range(f)]
        out.append((dense[r], bags))
    return out


def _engine_cell(cfg, qparams, reqs, *, cache_rows: int, max_batch: int):
    from repro.serve.cache import CacheStats, HotRowCache
    from repro.serve.recsys import RecsysEngine

    cache = HotRowCache(capacity_rows=cache_rows) if cache_rows else None
    eng = RecsysEngine(cfg, qparams, max_batch=max_batch, cache=cache)
    # warm pass: compiles every (B, L) bucket + miss-gather shape and fills
    # the cache, so the timed pass measures steady-state hot traffic (the
    # regime repeated Zipfian streams converge to), not jit compilation
    for d, b in reqs:
        eng.submit(d, b)
    eng.run_until_drained()
    eng.reset_metrics()
    if cache is not None:
        cache.stats = CacheStats(bytes_cached=cache.stats.bytes_cached)
    for d, b in reqs:
        eng.submit(d, b)
    eng.run_until_drained()
    return eng.metrics()


def bench(steps: int, requests: int, max_batch: int) -> dict:
    import numpy as np

    from repro.serve.quantize import (dequantize_table, is_quantized_table,
                                      memory_report, paths_and_leaves,
                                      quantize_params)

    rows = []
    for arch in ARCHS:
        cfg, api, spec, params0, batch_at, _, init_state, make_train_step = \
            _build(arch)
        params = _train(api, spec, params0, batch_at, init_state,
                        make_train_step, steps)
        fixed = batch_at(0, 9999, 512, spec)
        base_loss = float(api.loss_fn(params, fixed)[0])
        base_auc = _auc(api.predict(params, fixed), fixed["label"])
        reqs = _requests(cfg, spec, batch_at, requests)
        for mode in MODES:
            qparams = quantize_params(params, mode=mode)
            rep = memory_report(params, qparams)
            loss = float(api.loss_fn(qparams, fixed)[0])
            auc = _auc(api.predict(qparams, fixed), fixed["label"])
            row_bound_ok, max_row_err_frac = True, 0.0
            if mode == "int8":
                # per-row bound: |dequant - w| <= scale/2, paired by path
                base_by_path = dict(paths_and_leaves(params))
                for path, qt in paths_and_leaves(qparams):
                    if not is_quantized_table(qt):
                        continue
                    w = base_by_path[path]
                    err = np.abs(np.asarray(dequantize_table(qt))
                                 - np.asarray(w, np.float32))
                    bound = 0.5 * np.asarray(qt["scale"], np.float32) + 1e-8
                    frac = float((err / bound).max())
                    max_row_err_frac = max(max_row_err_frac, frac)
                    row_bound_ok &= bool((err <= bound).all())
            for cache_rows in (0, 4096):
                t0 = time.monotonic()
                m = _engine_cell(cfg, qparams, reqs,
                                 cache_rows=cache_rows, max_batch=max_batch)
                rows.append({
                    "arch": arch, "mode": mode,
                    "cache": "on" if cache_rows else "off",
                    "table_bytes_f32": rep["f32_table_bytes"],
                    "table_bytes": rep["quant_table_bytes"],
                    "bytes_ratio": rep["ratio"],
                    "loss_f32": base_loss, "loss": loss,
                    "auc_f32": base_auc, "auc": auc,
                    "row_bound_ok": row_bound_ok,
                    "max_row_err_frac": max_row_err_frac,
                    "p50_ms": m["p50_ms"], "p99_ms": m["p99_ms"],
                    "qps": m["qps"], "waves": m["waves"],
                    "buckets": [list(b) for b in m["buckets"]],
                    "hit_rate": (m.get("cache") or {}).get("hit_rate"),
                    "cache_stats": m.get("cache"),
                    "wall_s": round(time.monotonic() - t0, 2),
                })
    return {"requests": requests, "max_batch": max_batch,
            "train_steps": steps, "emb_dim": SERVE_EMB_DIM, "rows": rows}


def check(report: dict) -> list[tuple[str, str]]:
    """(name, message) per failed acceptance check; empty = all green."""
    failures = []
    for r in report["rows"]:
        cell = f"{r['arch']}/{r['mode']}/cache_{r['cache']}"
        if r["mode"] == "int8":
            if r["bytes_ratio"] > INT8_BYTES_BAR:
                failures.append((cell, f"int8 table bytes {r['bytes_ratio']:.3f}x "
                                       f"f32 > {INT8_BYTES_BAR}"))
            if not r["row_bound_ok"]:
                failures.append((cell, "per-row dequant error exceeds scale/2 "
                                       f"(max {r['max_row_err_frac']:.3f}x bound)"))
        if r["mode"] != "f32":
            dl = abs(r["loss"] - r["loss_f32"])
            da = abs(r["auc"] - r["auc_f32"])
            if dl > LOSS_TOL:
                failures.append((cell, f"loss delta {dl:.4f} > {LOSS_TOL}"))
            if da > AUC_TOL:
                failures.append((cell, f"auc delta {da:.4f} > {AUC_TOL}"))
        if r["cache"] == "on" and not (r["hit_rate"] or 0) > 0:
            failures.append((cell, "cache enabled but hit rate is 0 under "
                                   "the Zipfian stream"))
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int,
                    default=int(os.environ.get("REPRO_BENCH_STEPS", 30)),
                    help="f32 pre-training steps per arch")
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--out", default=os.path.join(ART, "BENCH_serve.json"))
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    try:
        report = bench(args.steps, args.requests, args.max_batch)
    except Exception as e:
        print(f"serve_bench/ERROR,0,{repr(e)[:160]}")
        return 1
    for r in report["rows"]:
        hr = "" if r["hit_rate"] is None else f";hit_rate={r['hit_rate']:.3f}"
        print(f"serve/{r['arch']}/{r['mode']}/cache_{r['cache']},"
              f"{r['p50_ms'] * 1e3:.0f},"
              f"bytes_ratio={r['bytes_ratio']:.3f};qps={r['qps']:.1f};"
              f"p99_ms={r['p99_ms']:.1f};dloss={abs(r['loss'] - r['loss_f32']):.4f}"
              f"{hr}")
        sys.stdout.flush()
    failures = check(report)
    report["checks_failed"] = [f"{n}: {m}" for n, m in failures]
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, default=float)
    for name, msg in failures:
        print(f"serve/check/{name}/ERROR,0,{msg}")
    if failures:
        print(f"# {len(failures)} serve_bench check(s) failed", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
