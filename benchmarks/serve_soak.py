"""Serving soak: a sustained Zipfian stream through the device-cache
engine, with a hard wall-clock guard and a committed p99 baseline.

Where ``serve_bench`` measures lanes on a short fixed request set, the
soak answers the operational questions: does the continuous-batching
engine survive *minutes* of open-ended traffic without latency drift,
queue buildup, memory creep (slabs are preallocated — resident bytes
must go flat once the hot set is cached), or a hang?

Protocol:

1. build the int8 dlrm engine + ``DeviceHotRowCache``, continuous
   batching (the deployment configuration);
2. warm until the hit rate saturates (excluded from stats);
3. stream Zipfian requests for ``--duration`` seconds (default 30,
   env ``REPRO_SOAK_DURATION``), reaping continuously and recording
   per-wave latencies;
4. a ``SIGALRM`` fires at ``4 x duration`` — if the engine hangs, the
   run dies with an ``/ERROR`` row and exit 1 instead of wedging CI
   (CI additionally wraps the step in a ``timeout``);
5. p99 is gated against ``benchmarks/baselines/serve_soak.json`` —
   regress past ``P99_REGRESSION_X`` and the run fails.  The factor is
   deliberately loose: CI boxes are noisy-neighbor CPUs and the gate
   exists to catch order-of-magnitude regressions (a recompile leaking
   into steady state, a host-side O(n) creep), not 10% jitter.

``--update-baseline`` rewrites the committed baseline from this run.

Artifacts: ``artifacts/bench/BENCH_serve_soak.json`` + CSV rows on
stdout (``name,us_per_call,derived``; failures print ``/ERROR`` rows
and exit 1 — the same contract as the other benches).

Usage::

    python -m benchmarks.serve_soak --duration 30
"""

from __future__ import annotations

import argparse
import json
import os
import signal
import sys
import time

ART = "artifacts/bench"
BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                        "serve_soak.json")
P99_REGRESSION_X = 4.0   # fail if p99 exceeds baseline by this factor
HARD_TIMEOUT_X = 4       # SIGALRM at duration * this (hang guard)
CHUNK = 64               # requests submitted per pump


class SoakHang(RuntimeError):
    pass


def _alarm(signum, frame):
    raise SoakHang("hard wall-clock timeout — engine hung?")


def _stream(cfg, spec, batch_at, seed: int, n: int, max_bag: int = 24):
    """One chunk of the endless Zipf stream (same shape as the
    serve_bench stream: cycling bag lengths, empty bags included)."""
    import numpy as np
    f = len(cfg.table_sizes)
    rng = np.random.default_rng(seed)
    dense = np.asarray(batch_at(0, 101 + seed, n, spec)["dense"],
                       np.float32)
    out = []
    for r in range(n):
        length = 1 + (r * 7) % max_bag
        bags = [list(((rng.zipf(spec.zipf, size=length) - 1) % s)
                     .astype(int)) for s in cfg.table_sizes]
        if r % 4 == 0:
            bags[r % f] = []
        out.append((dense[r], bags))
    return out


TIMESERIES = os.path.join(ART, "serve_soak_timeseries.csv")


def _timeseries(samples: list[dict], lat: list[float]) -> list[dict]:
    """Fold per-pump snapshots into per-second rows (p99, QPS, hit rate,
    resident cache bytes) — the CI artifact that localizes a soak
    regression in time instead of smearing it over the whole run."""
    import numpy as np
    rows, prev = [], {"t": 0.0, "waves": 0, "served": 0,
                      "hits": 0, "lookups": 0}
    by_sec: dict[int, dict] = {}
    for s in samples:
        by_sec[int(s["t"])] = s  # last pump snapshot in each second wins
    for sec in sorted(by_sec):
        s = by_sec[sec]
        window = lat[prev["waves"]:s["waves"]]
        dt = s["t"] - prev["t"]
        dlook = s["lookups"] - prev["lookups"]
        rows.append({
            "t_s": sec + 1,
            "p99_ms": (float(np.percentile(window, 99)) * 1e3
                       if window else 0.0),
            "qps": (s["served"] - prev["served"]) / dt if dt > 0 else 0.0,
            "hit_rate": ((s["hits"] - prev["hits"]) / dlook
                         if dlook > 0 else 0.0),
            "bytes_cached": s["bytes"],
        })
        prev = s
    return rows


def soak(duration_s: float, max_batch: int = 32) -> dict:
    from benchmarks.serve_bench import _build
    from repro.serve.cache import DeviceHotRowCache
    from repro.serve.quantize import quantize_params
    from repro.serve.recsys import RecsysEngine

    cfg, api, spec, params, batch_at, *_ = _build("dlrm-criteo")
    qparams = quantize_params(params, mode="int8")
    eng = RecsysEngine(cfg, qparams, max_batch=max_batch,
                       cache=DeviceHotRowCache(capacity_rows=8192),
                       batching="continuous")

    # warm: the hot-pool seeds (the catalog steady-state traffic draws
    # from — resident after this) plus a couple of fresh-seed chunks so
    # the *mixed* hit/miss shapes (small pow2 miss-gather and scatter
    # counts) are compiled too — without this, shape compiles masquerade
    # as latency for the first soak minute
    for warm_seed in (1, 2, 3, 4, 1, 10_001, 10_002):
        for d, b in _stream(cfg, spec, batch_at, warm_seed, CHUNK):
            eng.submit(d, b)
        eng.run_until_drained()
    # reset_metrics drops the cache traffic counters too (resident bytes
    # survive) — warm-up never leaks into steady-state hit rates
    eng.reset_metrics()

    # arm the hang guard only now: build + jit warmup above are allowed
    # to be slow (compilation), the streaming loop below is not
    if hasattr(signal, "SIGALRM"):
        signal.alarm(int(duration_s * HARD_TIMEOUT_X) + 10)

    # steady-state traffic: Zipf draws over the warmed hot pool (seeds
    # cycle, so the catalog is finite like a production corpus), with a
    # genuinely fresh chunk every 8th pump so cold rows keep flowing
    # through the miss/admission path inside the timed window
    bytes_samples = []
    pump_samples = []
    t0 = time.monotonic()
    pump, served = 0, 0
    while time.monotonic() - t0 < duration_s:
        seed = 20_000 + pump if pump % 8 == 7 else 1 + pump % 4
        for d, b in _stream(cfg, spec, batch_at, seed, CHUNK):
            eng.submit(d, b)
        pump += 1
        while eng._queue or eng._inflight:
            served += len(eng.step())
        bytes_samples.append(eng.cache.stats.bytes_cached)
        pump_samples.append({
            "t": time.monotonic() - t0,
            "waves": len(eng.wave_latencies_s),
            "served": served,
            "hits": eng.cache.stats.hits,
            "lookups": eng.cache.stats.lookups,
            "bytes": eng.cache.stats.bytes_cached,
        })
    wall = time.monotonic() - t0
    ts_rows = _timeseries(pump_samples, eng.wave_latencies_s)

    m = eng.metrics()
    # memory-creep guard: the Zipf tail legitimately trickles admissions
    # forever, but the rate must *decelerate* (the hot set saturates) and
    # residency must respect the slab capacity
    mid = len(bytes_samples) // 2
    first = bytes_samples[mid] - bytes_samples[0] if mid else 0
    last = bytes_samples[-1] - bytes_samples[mid] if mid else 0
    cap_bytes = 8192 * cfg.emb_dim * 4
    return {
        "duration_s": round(wall, 2),
        "served": served,
        "qps": m["qps"],
        "p50_ms": m["p50_ms"],
        "p99_ms": m["p99_ms"],
        "waves": m["waves"],
        "hit_rate": (m.get("cache") or {}).get("hit_rate"),
        "bytes_cached": eng.cache.stats.bytes_cached,
        "bytes_growth_first_half": first,
        "bytes_growth_last_half": last,
        "bytes_ok": last <= max(first, 4096) and
        eng.cache.stats.bytes_cached <= cap_bytes,
        "max_batch": max_batch,
        "batching": "continuous",
        "mode": "int8",
        "timeseries": ts_rows,
    }


def check(report: dict, baseline: dict | None) -> list[tuple[str, str]]:
    failures = []
    if report["served"] < 1:
        failures.append(("served", "soak served zero requests"))
    if not report.get("timeseries"):
        failures.append(("timeseries", "soak produced no per-second "
                                       "timeseries rows"))
    if not (report["hit_rate"] or 0) > 0.5:
        failures.append(("hit_rate", f"hit rate {report['hit_rate']} "
                                     "never saturated under Zipf traffic"))
    if not report["bytes_ok"]:
        failures.append(
            ("bytes", f"cache residency creep: growth accelerated "
                      f"({report['bytes_growth_first_half']} B first half "
                      f"-> {report['bytes_growth_last_half']} B last half) "
                      f"or capacity exceeded"))
    if baseline is not None:
        bar = baseline["p99_ms"] * P99_REGRESSION_X
        if report["p99_ms"] > bar:
            failures.append(
                ("p99", f"p99 {report['p99_ms']:.2f} ms exceeds "
                        f"{P99_REGRESSION_X}x baseline "
                        f"({baseline['p99_ms']:.2f} ms)"))
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float,
                    default=float(os.environ.get("REPRO_SOAK_DURATION", 30)))
    ap.add_argument("--max-batch", type=int, default=32)
    ap.add_argument("--update-baseline", action="store_true")
    ap.add_argument("--out", default=os.path.join(ART,
                                                  "BENCH_serve_soak.json"))
    ap.add_argument("--timeseries-out", default=TIMESERIES,
                    help="per-second timeseries CSV "
                         "(t_s,p99_ms,qps,hit_rate,bytes_cached)")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    if hasattr(signal, "SIGALRM"):
        signal.signal(signal.SIGALRM, _alarm)  # armed inside soak()
    try:
        report = soak(args.duration, args.max_batch)
    except SoakHang as e:
        print(f"serve_soak/ERROR,0,{e}")
        return 1
    except Exception as e:
        print(f"serve_soak/ERROR,0,{repr(e)[:160]}")
        return 1
    finally:
        if hasattr(signal, "SIGALRM"):
            signal.alarm(0)

    baseline = None
    if os.path.exists(BASELINE):
        with open(BASELINE) as f:
            baseline = json.load(f)
    failures = check(report, baseline)
    report["checks_failed"] = [f"{n}: {m}" for n, m in failures]
    report["baseline_p99_ms"] = baseline["p99_ms"] if baseline else None

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, default=float)
    with open(args.timeseries_out, "w") as f:
        f.write("t_s,p99_ms,qps,hit_rate,bytes_cached\n")
        for r in report["timeseries"]:
            f.write(f"{r['t_s']},{r['p99_ms']:.3f},{r['qps']:.1f},"
                    f"{r['hit_rate']:.4f},{r['bytes_cached']}\n")
    if args.update_baseline:
        os.makedirs(os.path.dirname(BASELINE), exist_ok=True)
        with open(BASELINE, "w") as f:
            json.dump({"p99_ms": report["p99_ms"], "qps": report["qps"],
                       "duration_s": report["duration_s"]}, f, indent=1)

    print(f"serve_soak/int8/cache_on/continuous,"
          f"{report['p50_ms'] * 1e3:.0f},"
          f"qps={report['qps']:.1f};p99_ms={report['p99_ms']:.2f};"
          f"served={report['served']};hit_rate={report['hit_rate']:.3f};"
          f"wall_s={report['duration_s']}")
    for name, msg in failures:
        print(f"serve_soak/check/{name}/ERROR,0,{msg}")
    if failures:
        print(f"# {len(failures)} serve_soak check(s) failed",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
