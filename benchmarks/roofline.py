"""Roofline analysis from dry-run artifacts (deliverable g).

Per (arch × shape × mesh) cell, derive the three roofline terms from the
compiled per-chip program (hardware: TPU v5e):

    compute_t    = HLO_FLOPs_per_chip / 197 TFLOP/s
    memory_t     = HLO_bytes_per_chip / 819 GB/s
    collective_t = wire_bytes_per_chip / 50 GB/s (ICI link)

FLOPs/bytes come from the scan-aware HLO analyzer (launch/hlo_analysis.py —
XLA's own cost_analysis does not multiply while bodies).  MODEL_FLOPS uses
exact parameter counts from the config (6·N·D train, 2·N·D inference, N
excluding embedding-table rows, MoE counting active experts only), so the
ratio MODEL/HLO exposes remat and padding waste.  The reported
``roofline_frac`` is useful-compute time over the dominant term — an upper
bound on achievable MFU for this lowering.
"""

from __future__ import annotations

import glob
import json
import os
from functools import lru_cache

import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


@lru_cache(maxsize=None)
def model_flops_coeffs(arch: str):
    """(N_dense_active, N_embed) parameter counts for the MODEL_FLOPS term."""
    import jax

    from repro.configs import get_arch
    from repro.optim.optimizers import leaf_paths
    mod = get_arch(arch)
    cfg = mod.config()
    api = mod.api(cfg)
    structs = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    paths = leaf_paths(structs)
    leaves = jax.tree.leaves(structs)
    n_embed = n_moe = n_other = 0
    for p, l in zip(paths, leaves):
        n = int(np.prod(l.shape))
        if "embed/" in p or p.startswith("embed"):
            n_embed += n
        elif "/moe/w" in p:
            n_moe += n
        else:
            n_other += n
    moe_cfg = getattr(cfg, "moe", None)
    active_frac = (moe_cfg.top_k / moe_cfg.n_experts) if moe_cfg else 0.0
    n_active = n_other + n_moe * active_frac
    return n_active, n_embed


def model_flops(arch: str, shape_name: str, devices: int) -> float:
    from repro.configs import SHAPES
    shape = SHAPES[shape_name]
    n_active, _ = model_flops_coeffs(arch)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        total = 2.0 * n_active * tokens
    return total / devices


def analyze_cell(record: dict) -> dict:
    fl = record["flops_per_chip"]
    hbm = record["hbm_bytes_per_chip"]
    coll = record["collective_wire_bytes_per_chip"]
    terms = {"compute": fl / PEAK_FLOPS, "memory": hbm / HBM_BW,
             "collective": coll / ICI_BW}
    dominant = max(terms, key=terms.get)
    mf = model_flops(record["arch"], record["shape"], record["devices"])
    useful_t = mf / PEAK_FLOPS
    bound_t = max(terms.values())
    return {
        "arch": record["arch"], "shape": record["shape"], "mesh": record["mesh"],
        "compute_t_s": terms["compute"], "memory_t_s": terms["memory"],
        "collective_t_s": terms["collective"], "dominant": dominant,
        "model_flops_per_chip": mf,
        "model_over_hlo_flops": mf / fl if fl else 0.0,
        "roofline_frac": useful_t / bound_t if bound_t else 0.0,
        "hbm_fit_gb": (record["memory_analysis"].get("argument_size_in_bytes", 0)
                       + record["memory_analysis"].get(
                           "temp_tpu_expected_bytes",
                           record["memory_analysis"].get("temp_size_in_bytes", 0))) / 2**30,
    }


def load_cells(art_dir: str = "artifacts/dryrun"):
    out = []
    for f in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        r = json.load(open(f))
        if r.get("ok"):
            out.append(analyze_cell(r))
    return out


def rows(art_dir: str = "artifacts/dryrun"):
    """CSV rows for benchmarks/run.py (single-pod mesh = the §Roofline table)."""
    cells = load_cells(art_dir)
    out = []
    for c in cells:
        if "multipod" in c["mesh"]:
            continue
        name = f"roofline/{c['arch']}/{c['shape']}"
        bound_ms = max(c["compute_t_s"], c["memory_t_s"], c["collective_t_s"]) * 1e3
        out.append((name, round(bound_ms * 1e3, 1),
                    f"dominant={c['dominant']};frac={c['roofline_frac']:.3f}"))
    os.makedirs("artifacts/bench", exist_ok=True)
    with open("artifacts/bench/roofline.json", "w") as f:
        json.dump(cells, f, indent=1)
    return out


def markdown_table(art_dir: str = "artifacts/dryrun", mesh_filter: str = "pod_16x16"):
    cells = [c for c in load_cells(art_dir) if c["mesh"] == mesh_filter]
    lines = ["| arch | shape | compute s | memory s | collective s | dominant | MODEL/HLO | roofline frac | HBM GB |",
             "|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['compute_t_s']:.3g} | "
            f"{c['memory_t_s']:.3g} | {c['collective_t_s']:.3g} | {c['dominant']} | "
            f"{c['model_over_hlo_flops']:.2f} | {c['roofline_frac']:.3f} | "
            f"{c['hbm_fit_gb']:.1f} |")
    return "\n".join(lines)
