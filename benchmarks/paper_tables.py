"""One benchmark per paper table/figure (§5), on the seeded synthetic
Criteo-shaped stream (see DESIGN.md §7 — relative claims, not absolute
Criteo losses).  Every function returns CSV rows (name, us_per_call,
derived) and writes a artifact JSON under artifacts/bench/.
"""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from repro.configs.common import Shape
from repro.train.loop import init_state, make_train_step

ART = "artifacts/bench"
# CI's bench-smoke lane shrinks this via the env var; trends survive, minutes don't.
TRAIN_STEPS = int(os.environ.get("REPRO_BENCH_STEPS", "400"))
EVAL_STEPS = 12
BATCH = 256
SHAPE = Shape("bench", 1, BATCH, "train")


def _train_eval(mod, *, embedding, num_collisions=4, threshold=0, op="mult",
                steps=TRAIN_STEPS, seed=0, **cfg_kw):
    """Train a reduced config; return (test_loss, test_acc, n_params, us/step)."""
    cfg = mod.config(reduced=True, embedding=embedding,
                     num_collisions=num_collisions, threshold=threshold, op=op,
                     **cfg_kw)
    a = mod.api(cfg)
    params = a.init(jax.random.PRNGKey(seed))
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    state = init_state(params, a.optimizer)
    step = jax.jit(make_train_step(a.loss_fn, a.optimizer))
    state, m = step(state, a.batch_fn(0, SHAPE))  # compile
    jax.block_until_ready(m["loss"])
    t0 = time.monotonic()
    for i in range(1, steps):
        state, m = step(state, a.batch_fn(i, SHAPE))
    jax.block_until_ready(m["loss"])
    us = (time.monotonic() - t0) / max(steps - 1, 1) * 1e6
    # held-out eval: steps beyond the training range
    eval_fn = jax.jit(a.loss_fn)
    losses, accs = [], []
    for i in range(10_000, 10_000 + EVAL_STEPS):
        loss, metrics = eval_fn(state["params"], a.batch_fn(i, SHAPE))
        losses.append(float(loss))
        accs.append(float(metrics.get("acc", np.nan)))
    return float(np.mean(losses)), float(np.mean(accs)), n_params, us


def _emit(tag, rows):
    os.makedirs(ART, exist_ok=True)
    with open(os.path.join(ART, tag + ".json"), "w") as f:
        json.dump(rows, f, indent=1)


def _paper_kinds(op_list=("hash", "mult", "add", "concat", "feature")):
    for kind in op_list:
        if kind == "hash":
            yield "hash", "hash", "mult"
        elif kind == "feature":
            yield "feature", "feature", "mult"
        else:
            yield kind, "qr", kind


def fig4():
    """Fig.4: full vs hashing trick vs QR (mult) on DLRM + DCN (4 collisions)."""
    from repro.configs import dcn_criteo, dlrm_criteo
    rows, art = [], {}
    for net, mod in (("dlrm", dlrm_criteo), ("dcn", dcn_criteo)):
        for name, kind in (("full", "full"), ("hash", "hash"), ("qr_mult", "qr")):
            loss, acc, n, us = _train_eval(mod, embedding=kind, num_collisions=4)
            rows.append((f"fig4/{net}/{name}", us, f"test_loss={loss:.4f}"))
            art[f"{net}/{name}"] = {"loss": loss, "acc": acc, "params": n}
    _emit("fig4", art)
    return rows


def fig5():
    """Fig.5: params vs test loss across collision counts × operations."""
    from repro.configs import dlrm_criteo
    rows, art = [], {}
    base_loss, _, base_n, us = _train_eval(dlrm_criteo, embedding="full")
    art["full/0"] = {"loss": base_loss, "params": base_n}
    rows.append(("fig5/dlrm/full/c0", us, f"test_loss={base_loss:.4f}"))
    for c in (2, 4, 60):
        for label, kind, op in _paper_kinds():
            loss, acc, n, us = _train_eval(dlrm_criteo, embedding=kind,
                                           num_collisions=c, op=op)
            art[f"{label}/{c}"] = {"loss": loss, "acc": acc, "params": n}
            rows.append((f"fig5/dlrm/{label}/c{c}", us,
                         f"test_loss={loss:.4f};params={n}"))
    _emit("fig5", art)
    return rows


def fig6():
    """Fig.6/Table 4: thresholding sweep at 4 collisions (mult op)."""
    from repro.configs import dlrm_criteo
    rows, art = [], {}
    for thr in (0, 200, 2000, 20000):
        loss, acc, n, us = _train_eval(dlrm_criteo, embedding="qr",
                                       num_collisions=4, threshold=thr)
        art[str(thr)] = {"loss": loss, "acc": acc, "params": n}
        rows.append((f"fig6/dlrm/qr_mult/thr{thr}", us,
                     f"test_loss={loss:.4f};params={n}"))
    _emit("fig6", art)
    return rows


def table1():
    """Table 1/2: path-based compositional embeddings, MLP width sweep."""
    from repro.configs import dlrm_criteo
    rows, art = [], {}
    for hidden in (16, 32, 64, 128):
        loss, acc, n, us = _train_eval(dlrm_criteo, embedding="path",
                                       num_collisions=4, path_hidden=hidden)
        art[str(hidden)] = {"loss": loss, "acc": acc, "params": n}
        rows.append((f"table1/dlrm/path/h{hidden}", us,
                     f"test_loss={loss:.4f};params={n}"))
    _emit("table1", art)
    return rows
