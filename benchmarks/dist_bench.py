"""Wire-bytes vs step-time benchmark for the compression policy engine.

Runs the paper's DLRM (reduced Criteo config) on the 8-forced-host-device
mesh under each compression policy, through both distributed grad paths:

* ``dp``   — ``make_dp_train_step`` (replicated params, compressed
  all-reduce);
* ``fsdp`` — ``make_fsdp_train_step`` (reduce-scatter grads, sharded opt
  state, param all-gather).

Per (path × policy) row it reports the **accounted** per-chip collective
wire bytes (``repro.dist.accounting``), the **HLO cross-check** (the same
ring formulas applied to the compiled step by ``launch.hlo_analysis`` —
what XLA actually put on the wire), measured step time, and the loss
after ``--steps`` training steps (compression must not wreck
convergence, or the wire saving is fiction).

Artifacts: ``artifacts/bench/BENCH_dist.json`` + CSV on stdout
(``name,us_per_call,derived``).  Exits non-zero — with ``/ERROR`` rows —
if any section raises, if accounting and HLO disagree by more than 10%,
or if the int8 policy fails to cut DP wire bytes below 0.3× of
``mode="none"`` (the acceptance bar: 1 B/elem both phases vs 4 B/elem ⇒
~0.25× + scale scalars).

Usage::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m benchmarks.dist_bench --steps 30
"""

from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import sys
import time

ART = "artifacts/bench"
HLO_MATCH_TOL = 0.10
INT8_RATIO_BAR = 0.30
# loss/bce/acc pmeans in the step (loss and bce CSE into one all-reduce is
# sub-1e-5 of the total; we count all three)
SCALAR_ALLREDUCES = 3


def _build():
    import jax

    from repro.configs import dlrm_criteo
    from repro.data.criteo import CriteoSpec, batch_at

    cfg = dlrm_criteo.config(reduced=True)
    api = dlrm_criteo.api(cfg)
    spec = CriteoSpec(table_sizes=cfg.table_sizes, zipf=1.5, noise=0.5)
    params = api.init(jax.random.PRNGKey(0))
    batcher = lambda i: batch_at(0, i, 256, spec)
    return api, params, batcher


def _measure(step, state, batcher, steps, warmup=2):
    import jax
    state, m = step(state, batcher(0))  # compile + first step
    jax.block_until_ready(m["loss"])
    t0 = time.monotonic()
    timed = 0
    for i in range(1, steps):
        state, m = step(state, batcher(i))
        if i == warmup:
            jax.block_until_ready(m["loss"])
            t0 = time.monotonic()
        timed = i - warmup
    jax.block_until_ready(m["loss"])
    us = (time.monotonic() - t0) / max(timed, 1) * 1e6
    return float(m["loss"]), us


def bench(steps: int, policies: list[str], paths: list[str]) -> dict:
    import jax

    from repro.dist import AUTO, accounting
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.train.loop import (init_dp_state, init_fsdp_state,
                                  make_dp_train_step, make_fsdp_train_step)

    api, params, batcher = _build()
    n = jax.device_count()
    mesh = jax.make_mesh((n,), ("data",))
    rows = []
    for path in paths:
        for name in policies:
            pol = AUTO if name == "auto" else name
            t0 = time.monotonic()
            if path == "dp":
                state = init_dp_state(params, api.optimizer, compress=pol)
                step = make_dp_train_step(api.loss_fn, api.optimizer, mesh,
                                          compress=pol)
                acct = accounting.dp_step_wire_bytes(
                    params, pol, n, scalar_allreduces=SCALAR_ALLREDUCES)
            else:
                state = init_fsdp_state(params, api.optimizer, mesh, policy=pol)
                step = make_fsdp_train_step(api.loss_fn, api.optimizer, mesh,
                                            params, policy=pol)
                acct = accounting.fsdp_step_wire_bytes(
                    params, api.optimizer, mesh, pol,
                    scalar_allreduces=SCALAR_ALLREDUCES)
            # one wrapper per lane: lower/compile and the timed run share
            # the same jit cache, so _measure never recompiles the step
            jitted = jax.jit(step)  # repro: noqa[JIT-001] step is a fresh closure per (path, policy) lane — one wrapper per lane is the minimum
            with mesh:
                compiled = jitted.lower(state, batcher(0)).compile()
                compile_s = time.monotonic() - t0
                cost = analyze_hlo(compiled.as_text(), total_devices=n)
                loss, us = _measure(jitted, state, batcher, steps)
            rows.append({
                "path": path, "policy": name, "devices": n,
                "wire_bytes": acct["total_bytes"],
                "wire_bytes_grads": acct["grad_bytes"],
                "wire_bytes_param_gather": acct["param_gather_bytes"],
                "hlo_wire_bytes": cost.collective_bytes,
                "hlo_collectives": cost.collectives,
                "step_time_us": round(us, 1),
                "loss_after_steps": loss, "train_steps": steps,
                "compile_s": round(compile_s, 2),
            })
    return {"arch": "dlrm-criteo(reduced)", "batch": 256, "devices": n,
            "rows": rows}


def check(report: dict) -> list[tuple[str, str]]:
    """(name, message) per failed acceptance check; empty = all green."""
    failures = []
    by = {(r["path"], r["policy"]): r for r in report["rows"]}
    for r in report["rows"]:
        hlo = r["hlo_wire_bytes"]
        if hlo <= 0:
            failures.append((f"{r['path']}/{r['policy']}",
                             "no collectives found in compiled HLO"))
            continue
        rel = abs(r["wire_bytes"] - hlo) / hlo
        if rel > HLO_MATCH_TOL:
            failures.append(
                (f"{r['path']}/{r['policy']}",
                 f"accounting {r['wire_bytes']:.0f} vs HLO {hlo:.0f} "
                 f"differs {rel:.1%} > {HLO_MATCH_TOL:.0%}"))
    if ("dp", "int8") in by and ("dp", "none") in by:
        ratio = by[("dp", "int8")]["hlo_wire_bytes"] \
            / by[("dp", "none")]["hlo_wire_bytes"]
        report["int8_vs_none_ratio"] = ratio
        if ratio >= INT8_RATIO_BAR:
            failures.append(("dp/int8",
                             f"wire ratio {ratio:.3f} >= {INT8_RATIO_BAR}"))
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30,
                    help="training steps per (path, policy) cell")
    ap.add_argument("--policies", default="none,bf16,int8,auto")
    ap.add_argument("--paths", default="dp,fsdp")
    ap.add_argument("--out", default=os.path.join(ART, "BENCH_dist.json"))
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    try:
        report = bench(args.steps, args.policies.split(","),
                       args.paths.split(","))
    except Exception as e:
        print(f"dist_bench/ERROR,0,{repr(e)[:160]}")
        return 1
    for r in report["rows"]:
        print(f"dist/{r['path']}/{r['policy']},{r['step_time_us']},"
              f"wire_bytes={r['wire_bytes']:.0f};hlo={r['hlo_wire_bytes']:.0f};"
              f"loss={r['loss_after_steps']:.4f}")
    failures = check(report)
    report["checks_failed"] = [f"{n}: {m}" for n, m in failures]
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, default=float)
    for name, msg in failures:
        print(f"dist/check/{name}/ERROR,0,{msg}")
    if failures:
        print(f"# {len(failures)} dist_bench check(s) failed", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
