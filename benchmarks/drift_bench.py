"""Drift re-planning benchmark: the ``repro.online`` closed loop, end to end.

The scenario is the ROADMAP's streaming-drift story.  A DLRM serves a
synthetic Criteo stream whose plan-time traffic is highly concentrated
(``CriteoSpec(zipf=8)`` — most mass on the head ids), so the planner,
solving a 1/8-of-full byte budget, compresses the hot features onto small
QR structures whose *predicted* collision mass is tiny.  Then the stream
drifts (``data.criteo.DriftSpec``): the popularity head rotates by half
of each table and the zipf exponent flattens to 0.7 — yesterday's point
mass spreads over the whole catalog and the starved tables start
colliding in ways the plan never priced.

Lanes (all booleans pinned in ``BENCH_drift.json["acceptance"]`` and
gated in CI like the obs lane):

1. **calibration** — ``plan.quality.fit_collision_scale`` fits the
   analytic proxy against measured per-feature masses over stationary
   windows; the fitted ``k`` feeds ``DriftThresholds.collision_scale``
   so a systematic proxy bias can't masquerade as drift.
2. **detector precision** — the ``ReplanController`` watches stationary
   windows: zero fires expected.
3. **detector recall + closed loop** — the same engine's traffic drifts;
   the detector must fire within the drift phase, and the fire runs the
   whole loop: ``build_plan`` on the decayed streaming stats →
   ``migrate_params`` → ``swap_plan`` (drain, invalidate, install, warm).
   The re-solved plan must respect the byte budget (solver invariant,
   transferred to the migrated state by construction).
4. **p99 through swap** — per-wave latencies over the drift phase of the
   controller run vs a control run serving identical traffic with no
   controller; ``p99_swap <= P99_FACTOR * p99_noswap + P99_SLACK_MS``.
5. **recovery** — train the old plan on stationary traffic, then compare
   warm-start (``migrate_params`` + ``migrate_opt_state``) against cold
   re-init of the re-solved plan, both trained on the drifted stream;
   warm must start better and stay better on average.  The per-step
   table lands in ``artifacts/bench/drift_recovery.csv`` and the report's
   ``recovery`` rows (rendered by ``summary_md``).

Usage::

    python -m benchmarks.drift_bench --steps 30
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

ART = "artifacts/bench"

SIZES = (4000, 2000, 1000, 500)
EMB_DIM = 16
BUDGET_FRAC = 8           # plan budget = full f32 table bytes / this
ZIPF_BEFORE = 8.0         # plan-time concentration (head holds most mass)
ZIPF_AFTER = 0.7          # drifted exponent: support spreads
ROTATE_FRAC = 0.5
DRIFT_STEP = 10_000       # generator step where the shift begins

REL_GAP = 0.6             # fire at measured > k*pred*(1+rel)+abs
ABS_GAP = 1e-4
HYSTERESIS = 2
COOLDOWN = 2

P99_FACTOR = 3.0          # p99 through swap vs no-swap control
P99_SLACK_MS = 5.0

MAX_BATCH = 16
CAL_WINDOWS = 4           # stationary windows fitting collision_scale
STAT_WINDOWS = 4          # detector-precision windows
DRIFT_WINDOWS = 6         # recall/closed-loop windows
WARMUP_WINDOWS = 2        # excluded from latency samples (compiles)


def _spec():
    from repro.data.criteo import CriteoSpec
    return CriteoSpec(table_sizes=SIZES, dense_dim=13, zipf=ZIPF_BEFORE,
                      noise=0.5)


def _drift():
    from repro.data.criteo import DriftSpec
    return DriftSpec(shift_step=DRIFT_STEP, rotate_frac=ROTATE_FRAC,
                     zipf_after=ZIPF_AFTER)


def _cfg(plan):
    # dlrm-criteo by name (so the controller's re-solve resolves the same
    # arch api), but with bench-sized towers — the lanes measure the
    # embedding path, not MLP throughput
    from repro.models.dlrm import DLRMConfig
    return DLRMConfig(name="dlrm-criteo", table_sizes=SIZES,
                      emb_dim=EMB_DIM, bottom_mlp=(64, 32), top_mlp=(32,),
                      embedding=plan)


def _window_batches(start_step: int, n: int, batch: int, drifted: bool):
    """``n`` generator batches for one serving window; the drift phase
    offsets past ``DRIFT_STEP`` so ``drifted_batch_at`` shifts."""
    from repro.data.criteo import drifted_batch_at
    base = DRIFT_STEP if drifted else 0
    return [drifted_batch_at(0, base + start_step + t, batch, _spec(),
                             _drift()) for t in range(n)]


def _requests_from_batch(batch):
    """One request per batch row: dense vector + single-id bags (the
    telemetry sees exactly the generator's id stream)."""
    import numpy as np
    dense = np.asarray(batch["dense"])
    sparse = np.asarray(batch["sparse"])
    return [(dense[r], [[int(sparse[r, f])] for f in range(sparse.shape[1])])
            for r in range(sparse.shape[0])]


def _serve_window(engine, batches, latencies=None):
    """Serve a window wave by wave (one ``max_batch`` chunk per timed
    drain, so each sample is one wave's latency)."""
    for b in batches:
        reqs = _requests_from_batch(b)
        for lo in range(0, len(reqs), MAX_BATCH):
            for d, bags in reqs[lo:lo + MAX_BATCH]:
                engine.submit(d, bags)
            t0 = time.perf_counter()
            engine.run_until_drained()
            if latencies is not None:
                latencies.append((time.perf_counter() - t0) * 1e3)


def _measured_window_masses(modules, batches):
    """Per-feature proxy mass of one window's id stream — the same
    estimator ``CollisionTelemetry.measured_collision_mass`` computes
    (the streaming/telemetry crosscheck test pins that equality)."""
    from repro.obs.collision import predicted_collision_mass
    from repro.plan.freq import stats_from_batches
    window = stats_from_batches(batches, SIZES)
    return [predicted_collision_mass(m, s)
            for m, s in zip(modules, window)]


def bench(steps: int, window_batches: int, batch: int) -> dict:
    import jax
    import numpy as np

    from repro.data.criteo import drifted_batch_at
    from repro.models.dlrm import dlrm_init, dlrm_loss_fn, tables_for
    from repro.obs import Obs
    from repro.obs.collision import predicted_collision_mass
    from repro.online import (ReplanController, migrate_opt_state,
                              migrate_params)
    from repro.online.drift import DriftThresholds
    from repro.optim import optimizers as opt
    from repro.plan.freq import StreamingStats, stats_from_batches
    from repro.plan.planner import build_plan, full_table_bytes
    from repro.plan.quality import fit_collision_scale
    from repro.serve.cache import DeviceHotRowCache
    from repro.serve.quantize import quantize_params
    from repro.serve.recsys import RecsysEngine
    from repro.train.loop import init_state, make_train_step

    # ---- plan on the stationary (concentrated) stream
    plan_stats = stats_from_batches(
        [drifted_batch_at(0, t, batch, _spec(), _drift())
         for t in range(12)], SIZES)
    full = full_table_bytes(SIZES, EMB_DIM)
    budget = full // BUDGET_FRAC
    plan0 = build_plan(plan_stats, EMB_DIM, budget, arch="dlrm-criteo-drift")
    cfg0 = _cfg(plan0)
    params0 = dlrm_init(jax.random.PRNGKey(0), cfg0)
    modules0 = tables_for(cfg0)
    predicted0 = [predicted_collision_mass(m, s)
                  for m, s in zip(modules0, plan_stats)]

    # ---- lane 1: fit the proxy scale on stationary windows
    pairs = []
    for w in range(CAL_WINDOWS):
        measured = _measured_window_masses(
            modules0, _window_batches(100 + w * window_batches,
                                      window_batches, batch, drifted=False))
        pairs += [(p, m) for p, m in zip(predicted0, measured) if p > 0]
    scale = fit_collision_scale(pairs)
    thresholds = DriftThresholds(rel_gap=REL_GAP, abs_gap=ABS_GAP,
                                 min_lookups=MAX_BATCH * 4,
                                 hysteresis=HYSTERESIS, cooldown=COOLDOWN,
                                 collision_scale=scale)

    # ---- lanes 2-4: one engine through stationary then drifted traffic,
    # with the controller in the loop; a twin engine serves the identical
    # stream uncontrolled (the p99 baseline)
    def make_engine():
        return RecsysEngine(cfg0, quantize_params(params0, mode="int8"),
                            max_batch=MAX_BATCH,
                            cache=DeviceHotRowCache(capacity_rows=2048),
                            batching="waves", obs=Obs(collisions=True))

    eng = make_engine()
    ctrl = ReplanController(eng, budget_bytes=budget, thresholds=thresholds,
                            decay=0.8, quantize="int8",
                            plan_stats=plan_stats)
    control = make_engine()   # obs on too: identical work per wave

    lat_swap: list = []
    lat_ctrl: list = []
    decisions = []
    for w in range(WARMUP_WINDOWS + STAT_WINDOWS):
        batches = _window_batches(1000 + w * window_batches, window_batches,
                                  batch, drifted=False)
        warm = w < WARMUP_WINDOWS
        _serve_window(eng, batches, None if warm else lat_swap)
        _serve_window(control, batches, None if warm else lat_ctrl)
        control._obs.collisions.reset()
        d = ctrl.check()
        decisions.append({"phase": "stationary", "fired": bool(d and d.fired),
                          "over": list(d.over) if d else []})
    fires_stationary = ctrl.detector.fires

    swap_window = None
    for w in range(DRIFT_WINDOWS):
        batches = _window_batches(2000 + w * window_batches, window_batches,
                                  batch, drifted=True)
        _serve_window(eng, batches, lat_swap)
        _serve_window(control, batches, lat_ctrl)
        control._obs.collisions.reset()
        d = ctrl.check()
        decisions.append({"phase": "drift", "fired": bool(d and d.fired),
                          "over": list(d.over) if d else []})
        if d and d.fired and swap_window is None:
            swap_window = w
    fires_drift = ctrl.detector.fires - fires_stationary

    p99_swap = float(np.percentile(lat_swap, 99))
    p99_noswap = float(np.percentile(lat_ctrl, 99))
    p50_swap = float(np.percentile(lat_swap, 50))
    p50_noswap = float(np.percentile(lat_ctrl, 50))

    # ---- lane 5: recovery — warm-start vs cold re-init on the drifted
    # stream, from a briefly-trained old-plan model
    loss_jit = jax.jit(lambda p, b: dlrm_loss_fn(p, b, cfg0)[0])
    step0 = jax.jit(make_train_step(lambda p, b: dlrm_loss_fn(p, b, cfg0),
                                    opt.adagrad(1e-2)))
    state = init_state(params0, opt.adagrad(1e-2))
    for t in range(steps):
        state, _ = step0(state, drifted_batch_at(0, t, batch, _spec(),
                                                 _drift()))
    trained = state["params"]

    # re-solve on the drifted traffic through the decayed streaming view
    stream = StreamingStats(SIZES, decay=0.8)
    for t in range(8):
        stream.update(drifted_batch_at(0, DRIFT_STEP + 3000 + t, batch,
                                       _spec(), _drift()))
    plan1 = build_plan(stream.all_stats(), EMB_DIM, budget,
                       arch="dlrm-criteo-drift-replan")
    cfg1 = _cfg(plan1)
    fresh = dlrm_init(jax.random.PRNGKey(7), cfg1)
    migrated, mreport = migrate_params(cfg0, trained, cfg1, fresh)
    optimizer = opt.adagrad(1e-2)
    warm_opt, opt_dec = migrate_opt_state(trained, state["opt"], migrated,
                                          optimizer)
    opt_counts = {k: sum(1 for v in opt_dec.values() if v == k)
                  for k in ("carried", "reset")}

    step1 = jax.jit(make_train_step(lambda p, b: dlrm_loss_fn(p, b, cfg1),
                                    opt.adagrad(1e-2)))
    loss1_jit = jax.jit(lambda p, b: dlrm_loss_fn(p, b, cfg1)[0])
    eval_batch = drifted_batch_at(0, DRIFT_STEP + 90_000, 1024, _spec(),
                                  _drift())
    warm_state = dict(init_state(migrated, optimizer), opt=warm_opt)
    cold_state = init_state(fresh, optimizer)
    recovery = []
    eval_every = max(1, steps // 6)
    for t in range(steps + 1):
        if t % eval_every == 0 or t == steps:
            recovery.append({
                "step": t,
                "loss_warm": float(loss1_jit(warm_state["params"],
                                             eval_batch)),
                "loss_cold": float(loss1_jit(cold_state["params"],
                                             eval_batch)),
            })
        if t == steps:
            break
        b = drifted_batch_at(0, DRIFT_STEP + 4000 + t, batch, _spec(),
                             _drift())
        warm_state, _ = step1(warm_state, b)
        cold_state, _ = step1(cold_state, b)

    warm0, cold0 = recovery[0]["loss_warm"], recovery[0]["loss_cold"]
    warm_mean = sum(r["loss_warm"] for r in recovery) / len(recovery)
    cold_mean = sum(r["loss_cold"] for r in recovery) / len(recovery)

    return {
        "sizes": list(SIZES),
        "emb_dim": EMB_DIM,
        "budget_bytes": budget,
        "full_bytes": full,
        "zipf_before": ZIPF_BEFORE,
        "zipf_after": ZIPF_AFTER,
        "collision_scale": scale,
        "thresholds": dataclasses.asdict(thresholds),
        "plan0_kinds": [t.kind for t in plan0.tables],
        "predicted_masses": predicted0,
        "decisions": decisions,
        "fires_stationary": fires_stationary,
        "fires_drift": fires_drift,
        "swap_window": swap_window,
        "replans": ctrl.replans,
        "controller_checks": ctrl.checks,
        "p50_ms_swap": p50_swap, "p99_ms_swap": p99_swap,
        "p50_ms_noswap": p50_noswap, "p99_ms_noswap": p99_noswap,
        "waves_timed": len(lat_swap),
        "plan1_kinds": [t.kind for t in plan1.tables],
        "plan1_total_bytes": plan1.total_bytes,
        "migration": mreport["counts"],
        "migration_dense": mreport["dense"],
        "opt_moments": opt_counts,
        "recovery": recovery,
        "train_steps": steps,
        "warm_first": warm0, "cold_first": cold0,
        "warm_mean": warm_mean, "cold_mean": cold_mean,
    }


def check(report: dict) -> list:
    failed = []

    def expect(name, ok, msg):
        if not ok:
            failed.append((name, msg))

    expect("scale_fitted",
           report["collision_scale"] > 0, "fit_collision_scale <= 0")
    expect("detector_quiet_on_stationary", report["fires_stationary"] == 0,
           f"{report['fires_stationary']} fires on stationary traffic")
    expect("detector_fires_on_drift", report["fires_drift"] >= 1,
           "no fire across the drift phase")
    expect("replanned_and_swapped", len(report["replans"]) >= 1,
           "controller never re-planned")
    for r in report["replans"]:
        expect("migration_within_budget",
               r["plan"]["total_bytes"] <= r["plan"]["budget_bytes"],
               f"re-plan {r['plan']['total_bytes']} B over budget "
               f"{r['plan']['budget_bytes']} B")
    expect("p99_through_swap_bounded",
           report["p99_ms_swap"]
           <= P99_FACTOR * report["p99_ms_noswap"] + P99_SLACK_MS,
           f"p99 {report['p99_ms_swap']:.2f} ms vs bound "
           f"{P99_FACTOR:.1f}*{report['p99_ms_noswap']:.2f}+{P99_SLACK_MS}")
    expect("warm_beats_cold_at_start",
           report["warm_first"] < report["cold_first"],
           f"warm first-eval {report['warm_first']:.4f} >= cold "
           f"{report['cold_first']:.4f}")
    expect("warm_beats_cold_on_average",
           report["warm_mean"] < report["cold_mean"],
           f"warm mean {report['warm_mean']:.4f} >= cold "
           f"{report['cold_mean']:.4f}")
    return failed


def summarize(report: dict) -> dict:
    """Compact top-level mirror: headline scalars + acceptance booleans."""
    failed = [f"{n}: {m}" for n, m in check(report)]
    return {
        "bench": "drift",
        "collision_scale": report["collision_scale"],
        "fires_stationary": report["fires_stationary"],
        "fires_drift": report["fires_drift"],
        "replans": len(report["replans"]),
        "p99_ms_swap": report["p99_ms_swap"],
        "p99_ms_noswap": report["p99_ms_noswap"],
        "warm_first": report["warm_first"],
        "cold_first": report["cold_first"],
        "warm_mean": report["warm_mean"],
        "cold_mean": report["cold_mean"],
        "recovery": report["recovery"],
        "acceptance": {
            "scale_fitted": report["collision_scale"] > 0,
            "detector_quiet_on_stationary":
                report["fires_stationary"] == 0,
            "detector_fires_on_drift": report["fires_drift"] >= 1,
            "replanned_and_swapped": len(report["replans"]) >= 1,
            "migration_within_budget": all(
                r["plan"]["total_bytes"] <= r["plan"]["budget_bytes"]
                for r in report["replans"]) and bool(report["replans"]),
            "p99_through_swap_bounded":
                report["p99_ms_swap"]
                <= P99_FACTOR * report["p99_ms_noswap"] + P99_SLACK_MS,
            "warm_beats_cold":
                report["warm_first"] < report["cold_first"]
                and report["warm_mean"] < report["cold_mean"],
            "all_checks_passed": not failed,
        },
        "checks_failed": failed,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int,
                    default=int(os.environ.get("REPRO_BENCH_STEPS", 30)),
                    help="recovery-lane train steps per arm")
    ap.add_argument("--window-batches", type=int, default=2,
                    help="generator batches per serving window")
    ap.add_argument("--batch", type=int, default=192,
                    help="generator batch size (rows per batch)")
    ap.add_argument("--out", default=os.path.join(ART, "BENCH_drift.json"))
    ap.add_argument("--summary-out", default="BENCH_drift.json",
                    help="compact top-level mirror (headlines + acceptance "
                         "booleans) for the perf-trajectory tooling")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    try:
        report = bench(args.steps, args.window_batches, args.batch)
    except Exception as e:
        print(f"drift_bench/ERROR,0,{repr(e)[:160]}")
        return 1
    print(f"drift/calibration,0,collision_scale={report['collision_scale']:.3f};"
          f"plan0={'|'.join(report['plan0_kinds'])}")
    print(f"drift/detect/stationary,0,fires={report['fires_stationary']};"
          f"checks={report['controller_checks']}")
    print(f"drift/detect/drift,0,fires={report['fires_drift']};"
          f"swap_window={report['swap_window']};"
          f"replans={len(report['replans'])}")
    print(f"drift/swap,{report['p99_ms_swap'] * 1e3:.0f},"
          f"p99_ms_swap={report['p99_ms_swap']:.2f};"
          f"p99_ms_noswap={report['p99_ms_noswap']:.2f};"
          f"p50_ms_swap={report['p50_ms_swap']:.2f};"
          f"waves={report['waves_timed']}")
    print(f"drift/recovery,0,warm_first={report['warm_first']:.4f};"
          f"cold_first={report['cold_first']:.4f};"
          f"warm_mean={report['warm_mean']:.4f};"
          f"cold_mean={report['cold_mean']:.4f}")
    sys.stdout.flush()

    failures = check(report)
    report["checks_failed"] = [f"{n}: {m}" for n, m in failures]
    report["acceptance"] = summarize(report)["acceptance"]
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, default=float)
    with open(args.summary_out, "w") as f:
        json.dump(summarize(report), f, indent=1, default=float)
    with open(os.path.join(ART, "drift_recovery.csv"), "w") as f:
        f.write("step,loss_warm,loss_cold\n")
        for r in report["recovery"]:
            f.write(f"{r['step']},{r['loss_warm']:.6f},"
                    f"{r['loss_cold']:.6f}\n")
    for name, msg in failures:
        print(f"drift/check/{name}/ERROR,0,{msg}")
    if failures:
        print(f"# {len(failures)} drift_bench check(s) failed",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
