"""Sharded-serving benchmark: plan-aware placement on an 8-device mesh.

Runs the paper's DLRM (reduced Criteo config, deployment D=64) from a
solved mixed-dimension memory plan, int8-quantized, through the sharded
``RecsysEngine`` path on the 8-forced-host-device mesh
(``dist.serve_placement``: replicate small sub-tables, row-shard big
ones, fetch remote rows over the two-phase all-to-all exchange) and
gates on four acceptance rows, ``/ERROR`` + exit 1 on any failure
(``dist_bench`` contract):

* **placement** — per-device table bytes under the placement stay within
  the plan's even share plus the replication overhead the policy chose:
  ``bytes/device <= plan_total/N + replicated + row-pad``; and the
  placement annotation round-trips through the plan JSON;
* **wire** — ``dist.accounting.serve_wave_wire_bytes`` (ring formulas)
  equals the HLO analyzer's collective bytes for the *compiled* sharded
  embed program **exactly** — static shapes, pure data movement, no
  tolerance;
* **parity** — sharded logits are **bit-identical** to a single-host
  engine serving the same stream (cache off and cache on; the sharded
  per-device program at batch ``B/N`` is the same XLA program as the
  single-host wave at batch ``B/N``), with empty bags in the stream; the
  cache lane must also see a positive hit rate;
* **qps** — projected per-host throughput of the sharded engine beats
  the single-host engine.  Host-device emulation timeshares all N
  "devices" on one physical host, so the raw wall-clock measures N
  devices' work serially; the projection divides wave wall time by N —
  the per-host time a real N-host mesh would see — and is reported next
  to the raw number, never in its place.

Artifacts: ``artifacts/bench/BENCH_serve_dist.json`` + a compact
top-level mirror (``BENCH_serve_dist.json``) + CSV on stdout
(``name,us_per_call,derived``).

Usage::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python -m benchmarks.serve_dist_bench --requests 512
"""

from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import dataclasses
import json
import sys
import time

ART = "artifacts/bench"
PLAN_PATH = "artifacts/plans/serve_dist_plan.json"
SERVE_EMB_DIM = 64
PLAN_BUDGET = 1 << 20          # serve-int8 domain bytes for the plan
MESH_DEVICES = 8
MAX_BATCH = 256                # global; per-device bucket = 256/8 = 32
MAX_BAG = 8


def _requests(cfg, n: int, max_bag: int = MAX_BAG):
    """Deterministic Zipfian multi-hot stream with **empty bags** (every
    4th request drops one feature's bag) and the bag-length bucket pinned:
    every 32-request block carries at least one ``max_bag``-length bag, so
    the single-host engine's per-wave ``L`` bucket always equals the
    sharded engine's global one and the parity row compares identical
    program shapes."""
    import numpy as np
    f = len(cfg.table_sizes)
    rng = np.random.default_rng(1234)
    out = []
    for r in range(n):
        length = max_bag if r % 32 == 0 else 1 + (r * 7) % max_bag
        dense = rng.normal(size=(13,)).astype(np.float32)
        bags = [list(((rng.zipf(1.5, size=length) - 1) % s).astype(int))
                for s in cfg.table_sizes]
        if r % 4 == 1:
            bags[r % f] = []   # legal empty bag -> exact zero-vector pool
        out.append((dense, bags))
    return out


def _build():
    import jax

    from repro.configs import dlrm_criteo as mod
    from repro.plan import plan_for_config
    from repro.serve.quantize import quantize_params

    base = dataclasses.replace(mod.config(reduced=True),
                               emb_dim=SERVE_EMB_DIM)
    plan = plan_for_config(base, PLAN_BUDGET, arch="dlrm-criteo",
                           bytes_domain="serve_int8",
                           dims=(SERVE_EMB_DIM // 4, SERVE_EMB_DIM // 2,
                                 SERVE_EMB_DIM))
    cfg = mod.config(reduced=True, plan=plan)
    api = mod.api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    qparams = quantize_params(params, mode="int8")
    return cfg, plan, params, qparams


def _scores(engine, reqs):
    import numpy as np
    uids = [engine.submit(d, b) for d, b in reqs]
    done = engine.run_until_drained()
    return np.asarray([done[u].score for u in uids], np.float32)


def _qps(engine, reqs, reps: int) -> float:
    best = 0.0
    for _ in range(reps + 1):          # first rep warms every bucket
        engine.reset_metrics()
        _scores(engine, reqs)
        best = max(best, engine.metrics()["qps"])
    return best


def bench(requests: int, reps: int) -> dict:
    import jax
    import numpy as np

    from repro.dist.accounting import serve_wave_wire_bytes
    from repro.dist.serve_placement import plan_placement
    from repro.launch.hlo_analysis import analyze_hlo
    from repro.plan import MemoryPlan
    from repro.serve.cache import DeviceHotRowCache
    from repro.serve.quantize import memory_report
    from repro.serve.recsys import RecsysEngine

    n = MESH_DEVICES
    if jax.device_count() < n:
        raise RuntimeError(f"need {n} devices, have {jax.device_count()} "
                           "(set XLA_FLAGS=--xla_force_host_platform_"
                           f"device_count={n})")
    cfg, plan, params, qparams = _build()
    reqs = _requests(cfg, requests)

    # ---- placement + plan annotation round-trip
    placement = plan_placement(qparams, n, plan=plan)
    plan.annotate_placement(placement)
    plan.save(PLAN_PATH)
    rt = MemoryPlan.load(PLAN_PATH).serve_placement()
    rep = memory_report(params, qparams, placement=placement)
    placement_row = {
        **placement.summary(),
        "plan_total_bytes": plan.total_bytes,
        "bound_bytes": (plan.total_bytes // n + placement.replicated_bytes()
                        + placement.pad_bytes()),
        "table_bytes_per_device": rep["placement"]["table_bytes_per_device"],
        "roundtrip_ok": rt is not None and rt.as_dict() == placement.as_dict(),
    }

    # ---- engines (sharded params are placed by the engine itself)
    t0 = time.monotonic()
    eng1 = RecsysEngine(cfg, qparams, max_batch=MAX_BATCH // n,
                        batching="waves")
    eng8 = RecsysEngine(cfg, qparams, max_batch=MAX_BATCH,
                        batching="waves", mesh_devices=n,
                        placement=placement)
    eng8c = RecsysEngine(cfg, qparams, max_batch=MAX_BATCH,
                         batching="waves", mesh_devices=n,
                         placement=placement,
                         cache=DeviceHotRowCache(capacity_rows=1 << 15))

    # ---- wire bytes: accounted vs compiled HLO, exact
    bb, lb = MAX_BATCH, MAX_BAG
    f = len(cfg.table_sizes)
    idx = jax.numpy.zeros((bb, f, lb), jax.numpy.int32)
    mask = jax.numpy.zeros((bb, f, lb), jax.numpy.float32)
    compiled = eng8._sharded_embed.lower(eng8.params, idx, mask).compile()
    cost = analyze_hlo(compiled.as_text(), total_devices=n)
    acct = serve_wave_wire_bytes(placement, bb // n, lb)
    wire_row = {
        "wire_bytes": acct["total_bytes"],
        "hlo_wire_bytes": cost.collective_bytes,
        "hlo_collectives": cost.collectives,
        "lookups_per_device": acct["lookups_per_device"],
        "sharded_sub_tables": len(placement.sharded),
    }

    # ---- parity: bit-identical logits, cache off and on
    s1 = _scores(eng1, reqs)
    s8 = _scores(eng8, reqs)
    _scores(eng8c, reqs)               # warm pass fills the cache
    s8c = _scores(eng8c, reqs)
    hit_rate = eng8c.metrics()["cache"]["hit_rate"]
    parity_row = {
        "bitwise": bool(np.array_equal(s1, s8)),
        "bitwise_cache": bool(np.array_equal(s1, s8c)),
        "maxdiff": float(np.abs(s1 - s8).max()),
        "maxdiff_cache": float(np.abs(s1 - s8c).max()),
        "cache_hit_rate": float(hit_rate),
        "requests": requests,
    }
    setup_s = time.monotonic() - t0

    # ---- throughput: raw + per-host projection
    qps1 = _qps(RecsysEngine(cfg, qparams, max_batch=MAX_BATCH), reqs, reps)
    eng8q = RecsysEngine(cfg, qparams, max_batch=MAX_BATCH, mesh_devices=n,
                         placement=placement)
    qps8_raw = _qps(eng8q, reqs, reps)
    qps_row = {"qps_1dev": qps1, "qps_8dev_raw": qps8_raw,
               "qps_8dev_projected": qps8_raw * n, "projection_factor": n,
               "emulated": True}

    return {"arch": "dlrm-criteo(reduced,plan)", "devices": n,
            "max_batch": MAX_BATCH, "max_bag": MAX_BAG,
            "setup_s": round(setup_s, 2),
            "placement": placement_row, "wire": wire_row,
            "parity": parity_row, "qps": qps_row}


def check(report: dict) -> list[tuple[str, str]]:
    """(name, message) per failed acceptance check; empty = all green."""
    failures = []
    p = report["placement"]
    if p["table_bytes_per_device"] > p["bound_bytes"]:
        failures.append(("placement",
                         f"{p['table_bytes_per_device']} B/device exceeds "
                         f"plan_total/N + replication = {p['bound_bytes']}"))
    if not p["roundtrip_ok"]:
        failures.append(("placement",
                         "placement annotation did not round-trip through "
                         "the plan JSON"))
    w = report["wire"]
    if w["sharded_sub_tables"] == 0:
        failures.append(("wire", "placement sharded nothing — the exchange "
                                 "path was never exercised"))
    if abs(w["wire_bytes"] - w["hlo_wire_bytes"]) > 0.5:
        failures.append(("wire",
                         f"accounted {w['wire_bytes']:.0f} != HLO "
                         f"{w['hlo_wire_bytes']:.0f} (exact match required)"))
    par = report["parity"]
    if not par["bitwise"]:
        failures.append(("parity", f"sharded logits differ from single-host "
                                   f"by {par['maxdiff']:.3e}"))
    if not par["bitwise_cache"]:
        failures.append(("parity", f"cache-on sharded logits differ "
                                   f"by {par['maxdiff_cache']:.3e}"))
    if not par["cache_hit_rate"] > 0:
        failures.append(("parity", "sharded device cache saw no hits"))
    q = report["qps"]
    if q["qps_8dev_projected"] < q["qps_1dev"]:
        failures.append(("qps",
                         f"projected {q['qps_8dev_projected']:.0f} qps on "
                         f"{MESH_DEVICES} devices < single-host "
                         f"{q['qps_1dev']:.0f}"))
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=512)
    ap.add_argument("--reps", type=int, default=2,
                    help="timed reps per QPS lane (best-of, after warm)")
    ap.add_argument("--out", default=os.path.join(ART, "BENCH_serve_dist.json"))
    ap.add_argument("--mirror", default="BENCH_serve_dist.json",
                    help="compact top-level mirror (totals + acceptance "
                         "booleans)")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    try:
        report = bench(args.requests, args.reps)
    except Exception as e:
        print(f"serve_dist_bench/ERROR,0,{repr(e)[:160]}")
        return 1
    p, w = report["placement"], report["wire"]
    par, q = report["parity"], report["qps"]
    print(f"serve_dist/placement,0,bytes_per_device="
          f"{p['table_bytes_per_device']};bound={p['bound_bytes']};"
          f"sharded={p['row_sharded']};replicated={p['replicated']}")
    print(f"serve_dist/wire,0,acct={w['wire_bytes']:.0f};"
          f"hlo={w['hlo_wire_bytes']:.0f}")
    print(f"serve_dist/parity,0,bitwise={int(par['bitwise'])};"
          f"bitwise_cache={int(par['bitwise_cache'])};"
          f"hit_rate={par['cache_hit_rate']:.3f}")
    print(f"serve_dist/qps,0,qps1={q['qps_1dev']:.1f};"
          f"qps8_raw={q['qps_8dev_raw']:.1f};"
          f"qps8_proj={q['qps_8dev_projected']:.1f}")
    failures = check(report)
    report["checks_failed"] = [f"{n}: {m}" for n, m in failures]
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=1, default=float)
    if args.mirror:
        mirror = {"devices": report["devices"],
                  "bytes_per_device": p["table_bytes_per_device"],
                  "wire_bytes": w["wire_bytes"],
                  "parity_bitwise": par["bitwise"],
                  "parity_bitwise_cache": par["bitwise_cache"],
                  "qps_1dev": q["qps_1dev"],
                  "qps_8dev_projected": q["qps_8dev_projected"],
                  "checks_failed": report["checks_failed"]}
        with open(args.mirror, "w") as fh:
            json.dump(mirror, fh, indent=1, default=float)
    for name, msg in failures:
        print(f"serve_dist/check/{name}/ERROR,0,{msg}")
    if failures:
        print(f"# {len(failures)} serve_dist_bench check(s) failed",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
