"""Subsystem package."""
