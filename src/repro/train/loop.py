"""Fault-tolerant training loop.

Properties engineered for 1000+-node runs and tested here at small scale:

* **restart determinism** — data is stateless-per-step and the PRNG is
  folded from the step counter, so kill-at-step-k + resume replays the
  exact stream; the restart test asserts bitwise-equal losses.
* **atomic async checkpoints** — see ``repro.ckpt``; the loop resumes from
  the newest *valid* checkpoint (corrupt/partial ones are skipped).
* **straggler watchdog** — per-step wall time is tracked; steps slower
  than ``watchdog_factor ×`` the running median are logged as straggler
  events (on a real cluster this feeds the reshard/evict policy; here it
  surfaces in metrics so tests can assert on it).
* **gradient compression** — optional bf16/int8 error-feedback reduction
  for the data-parallel axis (shard_map path; see repro.dist.compress).
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..ckpt import checkpoint as ckpt
from ..dist.compress import ef_psum_grads, init_error_state
from ..optim.optimizers import Optimizer, clip_by_global_norm

__all__ = ["TrainConfig", "init_state", "make_train_step", "make_dp_train_step",
           "Trainer", "SimulatedFailure"]


class SimulatedFailure(RuntimeError):
    """Raised by the loop's fault-injection hook (tests)."""


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    num_steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep: int = 3
    clip_norm: Optional[float] = None
    watchdog_factor: float = 3.0


def init_state(params, optimizer: Optimizer):
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(loss_fn, optimizer: Optimizer, *, clip_norm=None,
                    accum: int = 1, accum_dtype=jnp.float32):
    """Standard pjit-able step: grads → (clip) → optimizer → new state.

    ``accum`` > 1 enables gradient accumulation: the global batch is split
    into ``accum`` microbatches processed by a ``lax.scan`` (activation
    memory ÷ accum — what lets the 34B+ archs fit 16 GB/chip at the
    assigned train_4k batch of 256 sequences).  Gradients accumulate in
    f32; loss/metrics are microbatch means, bitwise independent of accum
    for linear losses.
    """

    def _grads(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def step(state, batch):
        if accum == 1:
            (loss, metrics), grads = _grads(state["params"], batch)
        else:
            from ..dist.sharding import constrain_batch

            def split(x):
                mb = x.reshape((accum, x.shape[0] // accum) + x.shape[1:])
                return mb

            micro = jax.tree.map(split, batch)

            def mb_step(carry, mbatch):
                g_acc, loss_acc = carry
                mbatch = jax.tree.map(constrain_batch, mbatch)
                (loss, metrics), g = _grads(state["params"], mbatch)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), g_acc, g)
                return (g_acc, loss_acc + loss), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype),
                              state["params"])
            (grads, loss_sum), metricss = jax.lax.scan(
                mb_step, (g0, jnp.float32(0.0)), micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
            metrics = jax.tree.map(lambda m: m.mean(), metricss)
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
            metrics = dict(metrics, grad_norm=gnorm)
        new_params, new_opt = optimizer.update(grads, state["opt"],
                                               state["params"], state["step"])
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        return new_state, dict(metrics, loss=loss)

    return step


def make_dp_train_step(loss_fn, optimizer: Optimizer, mesh, *,
                       compress: str = "bf16", clip_norm=None, axis: str = "data"):
    """Explicit data-parallel step via shard_map with compressed grad reduction.

    Params/opt-state replicated; batch sharded over ``axis``; gradients
    reduced with bf16/int8 error feedback (state carried in ``state['err']``).
    The per-replica update math is identical, so replicas stay bitwise
    consistent without re-broadcast.
    """
    from jax.experimental.shard_map import shard_map

    def _step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"], batch)
        grads, new_err = ef_psum_grads(grads, state["err"], axis_name=axis,
                                       mode=compress)
        loss = jax.lax.pmean(loss, axis)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, axis), metrics)
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
            metrics = dict(metrics, grad_norm=gnorm)
        new_params, new_opt = optimizer.update(grads, state["opt"],
                                               state["params"], state["step"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1, "err": new_err}
        return new_state, dict(metrics, loss=loss)

    return shard_map(_step, mesh=mesh,
                     in_specs=(P(), P(axis)),
                     out_specs=(P(), P()),
                     check_rep=False)


def init_dp_state(params, optimizer: Optimizer):
    grads_like = params
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32), "err": init_error_state(grads_like)}


class Trainer:
    def __init__(self, train_step, cfg: TrainConfig, *, batch_at: Callable[[int], Any]):
        self.train_step = jax.jit(train_step)
        self.cfg = cfg
        self.batch_at = batch_at
        self.checkpointer = (ckpt.AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep)
                             if cfg.ckpt_dir else None)
        self.straggler_events: list[tuple[int, float]] = []

    def resume_or(self, state):
        """Resume from the newest valid checkpoint, else the given state."""
        if self.cfg.ckpt_dir:
            step, restored, _ = ckpt.restore_latest(self.cfg.ckpt_dir, state)
            if restored is not None:
                return restored
        return state

    def run(self, state, *, fail_at_step: Optional[int] = None):
        cfg = self.cfg
        history = []
        durations: list[float] = []
        start = int(state["step"])
        for step in range(start, cfg.num_steps):
            if fail_at_step is not None and step == fail_at_step:
                raise SimulatedFailure(f"injected failure at step {step}")
            batch = self.batch_at(step)
            t0 = time.monotonic()
            state, metrics = self.train_step(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.monotonic() - t0
            if len(durations) >= 5:
                med = statistics.median(durations[-50:])
                if dt > cfg.watchdog_factor * med:
                    self.straggler_events.append((step, dt / med))
            durations.append(dt)
            if step % cfg.log_every == 0 or step == cfg.num_steps - 1:
                history.append((step, float(metrics["loss"])))
            if self.checkpointer and (step + 1) % cfg.ckpt_every == 0:
                self.checkpointer.save(step + 1, state)
        if self.checkpointer:
            self.checkpointer.save(cfg.num_steps, state)
            self.checkpointer.wait()
        return state, history
