"""Fault-tolerant training loop.

Properties engineered for 1000+-node runs and tested here at small scale:

* **restart determinism** — data is stateless-per-step and the PRNG is
  folded from the step counter, so kill-at-step-k + resume replays the
  exact stream; the restart test asserts bitwise-equal losses.
* **atomic async checkpoints** — see ``repro.ckpt``; the loop resumes from
  the newest *valid* checkpoint (corrupt/partial ones are skipped).
* **straggler watchdog** — per-step wall time is tracked; steps slower
  than ``watchdog_factor ×`` the running median are logged as straggler
  events (on a real cluster this feeds the reshard/evict policy; here it
  surfaces in metrics so tests can assert on it).
* **gradient compression** — bf16/int8 error-feedback reduction for the
  data-parallel axis, uniform or per-leaf via a ``CompressionPolicy``
  (shard_map path; see repro.dist.compress / repro.dist.policy).
* **reduce-scatter FSDP grad path** — ``make_fsdp_train_step`` reduce-
  scatters compressed gradients, applies the optimizer on each device's
  shard (opt state sharded: per-device optimizer memory ÷ N), and
  all-gathers the updated params.  Scatter dims come from the sharding
  rule engine (``sharding.scatter_dims``).
"""

from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..ckpt import checkpoint as ckpt
from ..dist.compress import (_bf16_from_wire, _bf16_to_wire, _reduce_leaf,
                             _reduce_scatter_leaf, ef_psum_grads,
                             init_error_state, resolve_modes)
from ..optim.optimizers import (Optimizer, clip_by_global_norm, leaf_paths,
                                state_structs)

__all__ = ["TrainConfig", "init_state", "make_train_step", "make_dp_train_step",
           "make_fsdp_train_step", "init_dp_state", "init_fsdp_state",
           "fsdp_plan", "Trainer", "SimulatedFailure"]


class SimulatedFailure(RuntimeError):
    """Raised by the loop's fault-injection hook (tests)."""


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    num_steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep: int = 3
    clip_norm: Optional[float] = None
    watchdog_factor: float = 3.0


def init_state(params, optimizer: Optimizer):
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32)}


def make_train_step(loss_fn, optimizer: Optimizer, *, clip_norm=None,
                    accum: int = 1, accum_dtype=jnp.float32):
    """Standard pjit-able step: grads → (clip) → optimizer → new state.

    ``accum`` > 1 enables gradient accumulation: the global batch is split
    into ``accum`` microbatches processed by a ``lax.scan`` (activation
    memory ÷ accum — what lets the 34B+ archs fit 16 GB/chip at the
    assigned train_4k batch of 256 sequences).  Gradients accumulate in
    f32; loss/metrics are microbatch means, bitwise independent of accum
    for linear losses.
    """

    def _grads(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def step(state, batch):
        if accum == 1:
            (loss, metrics), grads = _grads(state["params"], batch)
        else:
            from ..dist.sharding import constrain_batch

            def split(x):
                mb = x.reshape((accum, x.shape[0] // accum) + x.shape[1:])
                return mb

            micro = jax.tree.map(split, batch)

            def mb_step(carry, mbatch):
                g_acc, loss_acc = carry
                mbatch = jax.tree.map(constrain_batch, mbatch)
                (loss, metrics), g = _grads(state["params"], mbatch)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(accum_dtype), g_acc, g)
                return (g_acc, loss_acc + loss), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, accum_dtype),
                              state["params"])
            (grads, loss_sum), metricss = jax.lax.scan(
                mb_step, (g0, jnp.float32(0.0)), micro)
            grads = jax.tree.map(lambda g: g / accum, grads)
            loss = loss_sum / accum
            metrics = jax.tree.map(lambda m: m.mean(), metricss)
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
            metrics = dict(metrics, grad_norm=gnorm)
        new_params, new_opt = optimizer.update(grads, state["opt"],
                                               state["params"], state["step"])
        new_state = {"params": new_params, "opt": new_opt, "step": state["step"] + 1}
        return new_state, dict(metrics, loss=loss)

    return step


def _resolve_compress(compress):
    """``"auto"`` / policy / mode string / per-leaf tree → ef_psum_grads mode."""
    from ..dist.policy import resolve_policy
    if isinstance(compress, str):
        return resolve_policy(compress)
    return compress


def make_dp_train_step(loss_fn, optimizer: Optimizer, mesh, *,
                       compress="bf16", clip_norm=None, axis: str = "data"):
    """Explicit data-parallel step via shard_map with compressed grad reduction.

    Params/opt-state replicated; batch sharded over ``axis``; gradients
    reduced with bf16/int8 error feedback (state carried in ``state['err']``).
    ``compress`` is a mode string, ``"auto"``, a ``CompressionPolicy``, or a
    per-leaf mode pytree.  The per-replica update math is identical, so
    replicas stay bitwise consistent without re-broadcast.
    """
    from jax.experimental.shard_map import shard_map
    compress = _resolve_compress(compress)

    def _step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"], batch)
        grads, new_err = ef_psum_grads(grads, state["err"], axis_name=axis,
                                       mode=compress)
        loss = jax.lax.pmean(loss, axis)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, axis), metrics)
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
            metrics = dict(metrics, grad_norm=gnorm)
        new_params, new_opt = optimizer.update(grads, state["opt"],
                                               state["params"], state["step"])
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1, "err": new_err}
        return new_state, dict(metrics, loss=loss)

    return shard_map(_step, mesh=mesh,
                     in_specs=(P(), P(axis)),
                     out_specs=(P(), P()),
                     check_rep=False)


def init_dp_state(params, optimizer: Optimizer, compress=None):
    """State for ``make_dp_train_step``.  Pass the same ``compress`` policy as
    the step so error-feedback state is allocated only for compressed leaves."""
    err = init_error_state(
        params, _resolve_compress(compress) if compress is not None else None)
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32), "err": err}


# --------------------------------------------------------------- FSDP path


def _axis_size(mesh, axis: str) -> int:
    return dict(mesh.shape).get(axis, 1)


def fsdp_plan(params_like, optimizer: Optimizer, mesh, *, policy="auto",
              axis: str = "data"):
    """Per-leaf FSDP plan: ``[(path, shape, mode, scatter_dim | None)]``.

    The scatter dim is the first ``sharding.scatter_dims`` candidate along
    which every optimizer-state leaf of that param is sliceable (its size
    there equals the param's — e.g. row-wise Adagrad's ``(rows, 1)``
    accumulator admits dim 0 only, Adafactor's factored stats admit none,
    so those leaves safely fall back to the replicated all-reduce path).
    """
    from ..dist.sharding import scatter_dims
    paths = leaf_paths(params_like)
    leaves = jax.tree.leaves(params_like)
    modes = resolve_modes(params_like, _resolve_compress(policy))
    opt_structs = state_structs(optimizer, params_like)
    plan = []
    for path, leaf, mode, entry in zip(paths, leaves, modes, opt_structs):
        shape = tuple(leaf.shape)
        dim = None
        for d in scatter_dims(path, shape, mesh, axis):
            if all(len(s.shape) > d and s.shape[d] == shape[d]
                   for s in jax.tree.leaves(entry)):
                dim = d
                break
        plan.append((path, shape, mode, dim))
    return plan


def make_fsdp_train_step(loss_fn, optimizer: Optimizer, mesh, params_like, *,
                         policy="auto", clip_norm=None, axis: str = "data",
                         param_gather_dtype="float32"):
    """Reduce-scatter FSDP step: compressed gradients land as shards.

    Per leaf (scatter dim from ``fsdp_plan``): reduce-scatter the
    compressed gradient over ``axis``, apply the optimizer to this
    device's param shard against its **sharded optimizer state**
    (per-device optimizer memory ÷ N — for DLRM-scale models the
    optimizer accumulators rival the embedding tables themselves), then
    all-gather the updated shards back into replicated params for the
    next forward.  Leaves with no viable scatter dim take the replicated
    compressed all-reduce path; the two coexist in one step.

    ``params_like`` (arrays or ShapeDtypeStructs) fixes leaf paths/shapes
    at trace time.  Error-feedback residuals are genuinely per-device
    here: state ``err`` leaves are ``(n_devices, *leaf_shape)`` arrays
    sharded over ``axis`` (use ``init_fsdp_state``).  Supported
    optimizers are those whose ``update_leaf`` is element-wise or
    row-preserving along the scatter dim (SGD/Adagrad/Adam; row-wise
    Adagrad scatters rows); Adafactor leaves fall back to all-reduce
    automatically.

    ``param_gather_dtype="bfloat16"`` halves the param all-gather wire
    (the FSDP step's other big collective): updated shards ride as
    bitcast uint16 — the same trick as the compressed grad exchanges,
    since a plain bf16 all-gather gets silently retyped f32 on backends
    without native bf16 collectives — and each device then overwrites its
    own slice with its exact f32 shard, so the *master* shard never loses
    precision; only the replicated copies of **other** devices' shards
    are bf16-rounded (one bf16 ulp on the forward, ~2^-9 relative).
    """
    from jax.experimental.shard_map import shard_map
    n = _axis_size(mesh, axis)
    gather_bf16 = jnp.dtype(param_gather_dtype) == jnp.bfloat16
    if not gather_bf16 and jnp.dtype(param_gather_dtype) != jnp.float32:
        raise ValueError(f"param_gather_dtype must be float32 or bfloat16, "
                         f"got {param_gather_dtype!r}")
    plan = fsdp_plan(params_like, optimizer, mesh, policy=policy, axis=axis)
    treedef = jax.tree.structure(params_like)
    opt_structs = state_structs(optimizer, params_like)

    def _opt_spec(entry, dim):
        if dim is None:
            return jax.tree.map(lambda s: P(), entry)
        return jax.tree.map(lambda s: P(*([None] * dim + [axis])), entry)

    state_specs = {
        "params": P(),
        "opt": [_opt_spec(entry, dim)
                for entry, (_, _, _, dim) in zip(opt_structs, plan)],
        "step": P(),
        "err": P(axis),
    }

    def _step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"], batch)
        idx = jax.lax.axis_index(axis)
        flat_g = jax.tree.leaves(grads)
        flat_p = jax.tree.leaves(state["params"])
        flat_e = jax.tree.leaves(state["err"])

        red, new_err, p_local = [], [], []
        for g, p, e_blk, (_path, shape, mode, dim) in zip(flat_g, flat_p,
                                                         flat_e, plan):
            e = e_blk.reshape(e_blk.shape[1:])  # drop the device dim
            if dim is None:
                r, ne = _reduce_leaf(g, e, axis, mode)
                r = r.astype(jnp.float32)
                p_loc = p
            else:
                r, ne = _reduce_scatter_leaf(g, e, axis, mode, dim)
                shard = shape[dim] // n
                p_loc = jax.lax.dynamic_slice_in_dim(p, idx * shard, shard,
                                                     axis=dim)
            red.append(r)
            new_err.append(ne.reshape((1,) + ne.shape))
            p_local.append(p_loc)

        if clip_norm is not None:
            # shard-aware global norm: scattered leaves psum their shard
            # energy; replicated leaves are already identical everywhere.
            local = sum(jnp.sum(jnp.square(r))
                        for r, (_, _, _, d) in zip(red, plan) if d is not None)
            scat = jax.lax.psum(local, axis) if not isinstance(local, int) else 0.0
            rep = sum(jnp.sum(jnp.square(r))
                      for r, (_, _, _, d) in zip(red, plan) if d is None)
            gnorm = jnp.sqrt(scat + rep)
            scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-9))
            red = [r * scale for r in red]
            metrics = dict(metrics, grad_norm=gnorm)

        g_tree = jax.tree.unflatten(treedef, red)
        p_tree = jax.tree.unflatten(treedef, p_local)
        new_p_local, new_opt = optimizer.update(g_tree, state["opt"], p_tree,
                                                state["step"])
        new_params = []
        for np_loc, (_path, shape, _mode, dim) in zip(
                jax.tree.leaves(new_p_local), plan):
            if dim is None:
                new_params.append(np_loc)
            elif gather_bf16:
                wire = jax.lax.all_gather(
                    _bf16_to_wire(np_loc.astype(jnp.float32)), axis,
                    axis=dim, tiled=True)
                full = _bf16_from_wire(wire).astype(np_loc.dtype)
                # this device's master shard stays exact
                full = jax.lax.dynamic_update_slice_in_dim(
                    full, np_loc, idx * (shape[dim] // n), axis=dim)
                new_params.append(full)
            else:
                new_params.append(jax.lax.all_gather(np_loc, axis,
                                                     axis=dim, tiled=True))
        loss = jax.lax.pmean(loss, axis)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, axis), metrics)
        new_state = {"params": jax.tree.unflatten(treedef, new_params),
                     "opt": new_opt, "step": state["step"] + 1,
                     "err": jax.tree.unflatten(treedef, new_err)}
        return new_state, dict(metrics, loss=loss)

    return shard_map(_step, mesh=mesh,
                     in_specs=(state_specs, P(axis)),
                     out_specs=(state_specs, P()),
                     check_rep=False)


def init_fsdp_state(params, optimizer: Optimizer, mesh, *, policy="auto",
                    axis: str = "data"):
    """State for ``make_fsdp_train_step``: per-device error-feedback
    residuals (``(n, *shape)``, sharded over ``axis`` by the step's
    in_specs), residual placeholders for uncompressed leaves."""
    n = _axis_size(mesh, axis)
    modes = resolve_modes(params, _resolve_compress(policy))
    leaves, treedef = jax.tree.flatten(params)
    # placeholder for uncompressed leaves is (n,) — a per-device 0-d
    # residual, so the step's reshape(shape[1:]) broadcasts without
    # promoting rank-0 gradients to (1,)
    err = [jnp.zeros((n,) if m == "none" else (n,) + jnp.shape(g),
                     jnp.float32)
           for g, m in zip(leaves, modes)]
    return {"params": params, "opt": optimizer.init(params),
            "step": jnp.zeros((), jnp.int32),
            "err": jax.tree.unflatten(treedef, err)}


class Trainer:
    def __init__(self, train_step, cfg: TrainConfig, *,
                 batch_at: Callable[[int], Any], obs=None, step_wire=None):
        """``obs`` (an ``repro.obs.Obs``) turns on per-step spans and
        counters; ``step_wire`` is an accounted wire-byte report for one
        step (``dist.accounting.grad_wire_bytes`` /
        ``dp_step_wire_bytes`` / ``fsdp_step_wire_bytes`` output) — its
        per-leaf entries become per-leaf wire counters incremented every
        step, so the registry shows what the collectives actually carry.
        Both default off; the obs-off loop is unchanged."""
        self.train_step = jax.jit(train_step)
        self.cfg = cfg
        self.batch_at = batch_at
        self.checkpointer = (ckpt.AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep)
                             if cfg.ckpt_dir else None)
        self.straggler_events: list[tuple[int, float]] = []
        self._obs = obs
        if obs is not None:
            self._h_step = obs.histogram(
                "train_step_seconds", "per-step wall time").labels()
            self._c_steps = obs.counter(
                "train_steps_total", "optimizer steps taken").labels()
            self._c_strag = obs.counter(
                "train_straggler_events_total",
                "steps slower than watchdog_factor x running median").labels()
            self._wire_handles: list[tuple[Any, float]] = []
            if step_wire is not None:
                c = obs.counter(
                    "train_wire_bytes_total",
                    "accounted collective wire bytes (per leaf)")
                per_leaf = step_wire.get("per_leaf") or []
                for e in per_leaf:
                    self._wire_handles.append(
                        (c.labels(leaf=e["path"], mode=e["mode"]),
                         float(e["wire_bytes"])))
                accounted = sum(b for _, b in self._wire_handles)
                rest = float(step_wire.get("total_bytes", 0.0)) - accounted
                if rest > 0:  # param gathers / scalar overhead / no-leaf
                    self._wire_handles.append(
                        (c.labels(leaf="_other", mode="aggregate"), rest))

    def resume_or(self, state):
        """Resume from the newest valid checkpoint, else the given state."""
        if self.cfg.ckpt_dir:
            step, restored, _ = ckpt.restore_latest(self.cfg.ckpt_dir, state)
            if restored is not None:
                return restored
        return state

    def run(self, state, *, fail_at_step: Optional[int] = None):
        cfg = self.cfg
        history = []
        durations: list[float] = []
        start = int(state["step"])
        for step in range(start, cfg.num_steps):
            if fail_at_step is not None and step == fail_at_step:
                raise SimulatedFailure(f"injected failure at step {step}")
            batch = self.batch_at(step)
            t0 = time.monotonic()
            state, metrics = self.train_step(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.monotonic() - t0
            straggled = False
            if len(durations) >= 5:
                med = statistics.median(durations[-50:])
                if dt > cfg.watchdog_factor * med:
                    self.straggler_events.append((step, dt / med))
                    straggled = True
            durations.append(dt)
            if self._obs is not None:
                self._h_step.observe(dt)
                self._c_steps.inc()
                if straggled:
                    self._c_strag.inc()
                for h, b in self._wire_handles:
                    h.inc(b)
                if self._obs.tracer is not None:
                    self._obs.tracer.complete("train_step", t0, dt, step=step)
            if step % cfg.log_every == 0 or step == cfg.num_steps - 1:
                history.append((step, float(metrics["loss"])))
            if self.checkpointer and (step + 1) % cfg.ckpt_every == 0:
                self.checkpointer.save(step + 1, state)
        if self.checkpointer:
            self.checkpointer.save(cfg.num_steps, state)
            self.checkpointer.wait()
        return state, history
