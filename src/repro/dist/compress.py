"""Error-feedback compressed gradient reduction (bf16 / int8).

Data-parallel training all-reduces one full gradient copy per step; at
production scale that is the wire-dominant collective.  Compressing the
reduction to bf16 (2 B/elem) or int8 (1 B/elem + one f32 scale per leaf)
cuts that 2–4×, and **error feedback** (Karimireddy et al., 2019) keeps
the *time-averaged* update unbiased: the residual each compression step
throws away is carried forward and added to the next gradient, so the sum
of emitted gradients telescopes to the sum of true gradients.

``mode`` may be a single string or a **per-leaf pytree / flat list** of
strings (see ``repro.dist.policy`` for the rule engine that produces
one), and ``init_error_state`` allocates residual state only for leaves
that actually compress (a 0-d placeholder otherwise).

Wire formats (what actually crosses the links, per ``shard_map`` axis).
Both compressed modes use a **two-phase exchange** instead of a plain
``psum`` of the narrow dtype — a ``psum`` of int8 must widen to int32 to
sum without overflow (4 B/elem: no saving), and backends without native
narrow-dtype arithmetic (XLA CPU) silently upcast a bf16 all-reduce to
f32.  Pure data movement (``all_to_all`` / ``all_gather``) keeps the
compressed dtype on every backend:

* Phase 1: compress locally (bf16 cast, or int8 with a ``pmax``-shared
  scale) and ``all_to_all`` the payload so each device owns one shard of
  every peer's compressed gradient; sum it **in f32** (int32 for int8 —
  exact: ≤ 127·n), in a fixed order, so the reduction is deterministic
  and never accumulates in bf16.
* Phase 2: re-compress the shard mean and ``all_gather`` it.

Each phase moves (n−1)/n · payload bytes → 2(n−1)/n · {2 B, 1 B}/elem vs
2(n−1)/n · 4 B for an f32 all-reduce: **2× / 4× less wire**.  All inputs
to phase 2 are bitwise identical across replicas, so every replica emits
the same reduced gradient and the per-replica optimizer updates stay in
lock-step without a re-broadcast.  Phase 1's compression error is
telescoped by error feedback; phase 2's (one compression step of the
*mean* gradient — bf16 ulp ≈ 0.2%, int8 ≤ 0.4%, shared by all replicas)
is, for int8, *also* telescoped: **two-level error feedback** charges
each device ``n×`` its own shard's requantization residual (it computed
that shard's mean exactly), so the residual re-enters the next step's
mean exactly once and the emitted-gradient sum telescopes over both
levels (``two_level=True``, the default).

``ef_psum_scatter_grads``-style building blocks for the FSDP path live
in ``_reduce_scatter_leaf`` (used by ``train.loop.make_fsdp_train_step``):
same compression, but the reduction lands as a shard (reduce-scatter /
int8 ``all_to_all``), skipping phase 2 entirely.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["quantize_int8", "init_error_state", "ef_psum_grads", "MODES",
           "resolve_modes"]

MODES = ("none", "bf16", "int8")


def quantize_int8(x):
    """Symmetric per-tensor int8 quantisation.

    Returns ``(q, scale)`` with ``q`` int8 in [-127, 127] and
    ``x ≈ q * scale``; round-to-nearest bounds the error by ``scale / 2``.
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax / 127.0, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def resolve_modes(tree_like, mode) -> list[str]:
    """Per-leaf mode list for ``tree_like``: accepts a single mode string, a
    flat list, a pytree of strings, or a policy object with ``.modes()``."""
    n_leaves = len(jax.tree.leaves(tree_like))
    if hasattr(mode, "modes"):  # CompressionPolicy (duck-typed: no import cycle)
        flat = mode.modes(tree_like)
    elif isinstance(mode, str):
        flat = [mode] * n_leaves
    else:
        flat = jax.tree.leaves(mode, is_leaf=lambda x: isinstance(x, str))
    if len(flat) != n_leaves:
        raise ValueError("mode tree does not match gradient tree "
                         f"({len(flat)} vs {n_leaves} leaves)")
    for m in flat:
        if m not in MODES:
            raise ValueError(f"unknown compression mode {m!r}; "
                             f"expected one of {MODES}")
    return flat


def init_error_state(grads_like, mode=None):
    """Zero residual per gradient leaf (f32 regardless of grad dtype).

    With ``mode`` (string / pytree / policy), residual state is allocated
    **only for compressed leaves**; ``"none"`` leaves get a 0-d placeholder —
    on a billion-parameter model whose large leaves are the only compressed
    ones, that is the difference between doubling gradient memory and not.
    """
    leaves, treedef = jax.tree.flatten(grads_like)
    modes = (["__full__"] * len(leaves) if mode is None
             else resolve_modes(grads_like, mode))
    out = [jnp.zeros(() if m == "none" else jnp.shape(g), jnp.float32)
           for g, m in zip(leaves, modes)]
    return jax.tree.unflatten(treedef, out)


def _bf16_to_wire(x):
    """bf16 values → uint16 bit pattern.  Collectives carry the integer
    payload: backends without native bf16 collectives (XLA CPU float
    normalization) would otherwise silently retype them to f32 — 2× the
    wire bytes this mode exists to save.  Bitcast is free; integer data
    movement is supported everywhere."""
    return lax.bitcast_convert_type(x.astype(jnp.bfloat16), jnp.uint16)


def _bf16_from_wire(u):
    return lax.bitcast_convert_type(u, jnp.bfloat16).astype(jnp.float32)


def _shared_scale(v, axis_name):
    """Quantisation scale agreed across the axis (pmax) so integer partial
    sums are exact and bitwise identical on every replica."""
    amax = jnp.max(jnp.abs(v))
    if axis_name:
        amax = lax.pmax(amax, axis_name)
    return jnp.maximum(amax / 127.0, jnp.finfo(jnp.float32).tiny)


def _quant(v, scale):
    return jnp.clip(jnp.round(v / scale), -127, 127).astype(jnp.int8)


def _compressed_allreduce_mean(v, axis_name, mode, two_level=True):
    """Two-phase compressed-on-the-wire mean-all-reduce (module docstring).

    Returns ``(mean, charged)``: the replicated mean estimate and what
    error feedback charges this device for — its decompressed phase-1
    contribution, minus (with ``two_level``, int8 only) the phase-2
    requantization residual of its own shard scaled by ``n``.

    Two-level error feedback: phase 2 re-quantizes the already-reduced
    shard mean ``y`` to ``out = q2·scale2``, losing ``r2 = y - out`` — an
    error *outside* plain EF (which only telescopes phase-1 loss), so it
    used to bias every step by one int8 step of the mean.  Each device
    knows ``r2`` exactly for its own shard (it computed ``y`` there), so
    it charges ``n·r2`` at its shard's positions: summed over the axis
    each shard's residual enters the next step's mean exactly once, and
    the emitted-gradient sum telescopes over *both* compression levels.
    """
    n = lax.psum(1, axis_name)
    if mode == "bf16":
        payload = _bf16_to_wire(v)  # uint16 bits on the wire
        deq = _bf16_from_wire(payload)
    else:  # int8
        scale = _shared_scale(v, axis_name)
        payload = _quant(v, scale)
        deq = payload.astype(jnp.float32) * scale
    if n == 1:
        return deq, deq
    flat = payload.reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    # phase 1: each device ends up holding every peer's copy of its shard
    mine = lax.all_to_all(flat.reshape(n, -1), axis_name,
                          split_axis=0, concat_axis=0)
    corr = None
    if mode == "bf16":
        y = jnp.sum(_bf16_from_wire(mine), axis=0) / n
        gathered = lax.all_gather(_bf16_to_wire(y), axis_name, tiled=True)
        out = _bf16_from_wire(gathered)
    else:
        shard_sum = jnp.sum(mine.astype(jnp.int32), axis=0)  # exact: ≤ 127·n
        y = shard_sum.astype(jnp.float32) * (scale / n)
        scale2 = _shared_scale(y, axis_name)
        q2 = _quant(y, scale2)
        gathered = lax.all_gather(q2, axis_name, tiled=True)
        out = gathered.astype(jnp.float32) * scale2
        if two_level:
            r2 = y - q2.astype(jnp.float32) * scale2  # this shard's phase-2 loss
            corr = lax.dynamic_update_slice(
                jnp.zeros(flat.shape, jnp.float32), n * r2,
                (lax.axis_index(axis_name) * y.shape[0],))
    if pad:
        out = out[:-pad]
        corr = corr[:-pad] if corr is not None else None
    charged = deq if corr is None else deq - corr.reshape(v.shape)
    return out.reshape(v.shape), charged


def _reduce_leaf(g, e, axis_name, mode, two_level=True):
    """Compressed mean-all-reduce of one leaf → (reduced_full, new_err)."""
    v = g.astype(jnp.float32) + e
    if mode == "none":
        out = lax.pmean(v, axis_name) if axis_name else v
        return out.astype(g.dtype), jnp.zeros_like(e)
    if mode == "bf16":
        if axis_name:
            out, deq = _compressed_allreduce_mean(v, axis_name, mode)
        else:
            out = deq = v.astype(jnp.bfloat16).astype(jnp.float32)
        return out.astype(g.dtype), v - deq
    if mode == "int8":
        if axis_name:
            out, deq = _compressed_allreduce_mean(v, axis_name, mode,
                                                  two_level=two_level)
        else:
            q, scale = quantize_int8(v)
            deq = q.astype(jnp.float32) * scale
            out = deq
        return out.astype(g.dtype), v - deq
    raise ValueError(f"unknown compression mode {mode!r}; expected one of {MODES}")


def _reduce_scatter_leaf(g, e, axis_name, mode, dim):
    """Compressed mean-reduce-scatter of one leaf along concrete ``dim``.

    Returns ``(shard, new_err)``: this device's shard of the mean gradient
    (``shape[dim] / n`` along ``dim``) and the full-shape residual.  The
    compressed paths stop after phase 1 of the two-phase exchange — the
    shard sum *is* the reduce-scatter, so only (n−1)/n · {2, 1} B/elem
    crosses the wire (2× / 4× less than an f32 reduce-scatter).
    """
    n = lax.psum(1, axis_name)
    v = g.astype(jnp.float32) + e
    if n == 1:
        red, new_e = _reduce_leaf(g, e, None, mode)
        return red.astype(jnp.float32), new_e
    if mode == "none":
        shard = lax.psum_scatter(v, axis_name, scatter_dimension=dim,
                                 tiled=True) / n
        return shard, jnp.zeros_like(e)
    if mode == "bf16":
        c = _bf16_to_wire(v)
        mine = lax.all_to_all(c, axis_name, split_axis=dim, concat_axis=dim,
                              tiled=True)
        # dim is now n consecutive blocks of shape[dim]//n, one per peer
        split = mine.shape[:dim] + (n, mine.shape[dim] // n) + mine.shape[dim + 1:]
        shard = jnp.sum(_bf16_from_wire(mine.reshape(split)), axis=dim) / n
        return shard, v - _bf16_from_wire(c)
    if mode == "int8":
        scale = _shared_scale(v, axis_name)
        q = _quant(v, scale)
        mine = lax.all_to_all(q, axis_name, split_axis=dim, concat_axis=dim,
                              tiled=True)
        split = mine.shape[:dim] + (n, mine.shape[dim] // n) + mine.shape[dim + 1:]
        shard_sum = jnp.sum(mine.reshape(split).astype(jnp.int32), axis=dim)
        shard = shard_sum.astype(jnp.float32) * (scale / n)
        return shard, v - q.astype(jnp.float32) * scale
    raise ValueError(f"unknown compression mode {mode!r}; expected one of {MODES}")


def ef_psum_grads(grads, err, *, axis_name=None, mode="bf16",
                  two_level=True):
    """Compressed (mean-)reduction of a gradient tree with error feedback.

    Args:
      grads: gradient pytree.
      err: residual pytree from the previous step (``init_error_state`` to
        start); same treedef as ``grads``.
      axis_name: mapped axis to reduce over (``shard_map``/``pmap`` body),
        or ``None`` for local compression only.
      mode: ``"none" | "bf16" | "int8"``, a per-leaf pytree / flat list of
        those, or a ``policy.CompressionPolicy``.
      two_level: carry the int8 phase-2 requantization residual into the
        error state as well (``_compressed_allreduce_mean`` docstring), so
        the time-averaged update telescopes over both compression levels.
        On by default; off reproduces the single-level behaviour (one int8
        step of the mean per step of standing bias).

    Returns ``(reduced_grads, new_err)``.  The reduction is a *mean* over
    the axis, matching a per-shard-mean loss.
    """
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    if len(flat_e) != len(flat_g):
        raise ValueError("error state does not match gradient tree "
                         f"({len(flat_e)} vs {len(flat_g)} leaves)")
    modes = resolve_modes(grads, mode)
    out = [_reduce_leaf(g, e, axis_name, m, two_level=two_level)
           for g, e, m in zip(flat_g, flat_e, modes)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))
