"""Error-feedback compressed gradient reduction (bf16 / int8).

Data-parallel training all-reduces one full gradient copy per step; at
production scale that is the wire-dominant collective.  Compressing the
reduction to bf16 (2 B/elem) or int8 (1 B/elem + one f32 scale per leaf)
cuts that 2–4×, and **error feedback** (Karimireddy et al., 2019) keeps
the *time-averaged* update unbiased: the residual each compression step
throws away is carried forward and added to the next gradient, so the sum
of emitted gradients telescopes to the sum of true gradients.

Works in two modes:
  * ``axis_name=None`` — local compression only (single-process tests,
    gradient-accumulation inner loops);
  * ``axis_name="data"`` under ``shard_map`` — the compressed values are
    what crosses the wire: ``psum`` of bf16, or of int8 widened to int32
    with a ``pmax``-shared scale (integer accumulation → bitwise identical
    results on every replica, which is what keeps the per-replica
    optimizer updates in lock-step without a re-broadcast).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["quantize_int8", "init_error_state", "ef_psum_grads", "MODES"]

MODES = ("none", "bf16", "int8")


def quantize_int8(x):
    """Symmetric per-tensor int8 quantisation.

    Returns ``(q, scale)`` with ``q`` int8 in [-127, 127] and
    ``x ≈ q * scale``; round-to-nearest bounds the error by ``scale / 2``.
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax / 127.0, jnp.finfo(jnp.float32).tiny)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def init_error_state(grads_like):
    """Zero residual per gradient leaf (kept in f32 regardless of grad dtype)."""
    return jax.tree.map(lambda g: jnp.zeros(jnp.shape(g), jnp.float32), grads_like)


def _reduce_leaf(g, e, axis_name, mode):
    v = g.astype(jnp.float32) + e
    if mode == "none":
        out = lax.pmean(v, axis_name) if axis_name else v
        return out.astype(g.dtype), jnp.zeros_like(e)
    if mode == "bf16":
        c = v.astype(jnp.bfloat16)
        deq = c.astype(jnp.float32)
        if axis_name:
            n = lax.psum(1, axis_name)
            out = lax.psum(c, axis_name).astype(jnp.float32) / n
        else:
            out = deq
        return out.astype(g.dtype), v - deq
    if mode == "int8":
        if axis_name:
            # share one scale so integer partial sums are exact + deterministic
            amax = lax.pmax(jnp.max(jnp.abs(v)), axis_name)
            scale = jnp.maximum(amax / 127.0, jnp.finfo(jnp.float32).tiny)
            q = jnp.clip(jnp.round(v / scale), -127, 127).astype(jnp.int8)
            n = lax.psum(1, axis_name)
            out = lax.psum(q.astype(jnp.int32), axis_name).astype(jnp.float32) \
                * scale / n
        else:
            q, scale = quantize_int8(v)
            out = q.astype(jnp.float32) * scale
        deq = q.astype(jnp.float32) * scale
        return out.astype(g.dtype), v - deq
    raise ValueError(f"unknown compression mode {mode!r}; expected one of {MODES}")


def ef_psum_grads(grads, err, *, axis_name=None, mode: str = "bf16"):
    """Compressed (mean-)reduction of a gradient tree with error feedback.

    Args:
      grads: gradient pytree.
      err: residual pytree from the previous step (``init_error_state`` to
        start); same treedef as ``grads``.
      axis_name: mapped axis to reduce over (``shard_map``/``pmap`` body),
        or ``None`` for local compression only.
      mode: ``"none" | "bf16" | "int8"``.

    Returns ``(reduced_grads, new_err)``.  The reduction is a *mean* over
    the axis, matching a per-shard-mean loss.
    """
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    if len(flat_e) != len(flat_g):
        raise ValueError("error state does not match gradient tree "
                         f"({len(flat_e)} vs {len(flat_g)} leaves)")
    out = [_reduce_leaf(g, e, axis_name, mode) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))
