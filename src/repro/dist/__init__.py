"""Distribution subsystem: sharding rule engine + compressed collectives.

``repro.dist.sharding`` maps parameter paths to valid ``PartitionSpec``s
(never emitting an axis a dim cannot divide) and provides the in-model
activation pinning helpers (``constrain`` / ``constrain_batch``).

``repro.dist.compress`` implements bf16/int8 error-feedback gradient
reduction used by the explicit data-parallel (shard_map) train step.
"""

from . import compress, sharding
from .compress import ef_psum_grads, init_error_state, quantize_int8
from .sharding import (INFERENCE_OVERRIDES, batch_axes, constrain,
                       constrain_batch, fit_template, model_divides,
                       set_batch_shard_axes, spec_for, tree_shardings)

__all__ = [
    "sharding", "compress",
    "spec_for", "tree_shardings", "batch_axes", "constrain",
    "constrain_batch", "set_batch_shard_axes", "model_divides",
    "fit_template", "INFERENCE_OVERRIDES",
    "quantize_int8", "init_error_state", "ef_psum_grads",
]
