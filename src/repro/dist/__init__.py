"""Distribution subsystem: sharding rules, compression policy, collectives.

``repro.dist.sharding`` maps parameter paths to valid ``PartitionSpec``s
(never emitting an axis a dim cannot divide) and provides the in-model
activation pinning helpers (``constrain`` / ``constrain_batch``).

``repro.dist.compress`` implements bf16/int8 error-feedback gradient
reduction (true int8-on-the-wire exchanges) used by the explicit
data-parallel and FSDP (reduce-scatter) train steps.

``repro.dist.policy`` maps each gradient leaf to a compression mode via
a path+shape rule table (int8 tables / bf16 dense / none for small or
precision-critical leaves).

``repro.dist.accounting`` prices a step's collectives in wire bytes per
chip, cross-checkable against the HLO analyzer.

``repro.dist.serve_placement`` places quantized serving tables across a
device mesh from the memory plan's byte accounting (replicate small,
row-shard big) and implements the two-phase all-to-all row exchange the
sharded serve path fetches remote rows through.
"""

from . import accounting, compress, policy, serve_placement, sharding
from .compress import ef_psum_grads, init_error_state, quantize_int8, resolve_modes
from .policy import AUTO, CompressionPolicy, resolve_policy
from .serve_placement import (ServePlacement, SubTablePlacement,
                              exchange_rows, place_params, plan_placement)
from .sharding import (INFERENCE_OVERRIDES, batch_axes, constrain,
                       constrain_batch, fit_template, model_divides,
                       placement_overrides, placement_specs, scatter_dims,
                       set_batch_shard_axes, spec_for, tree_shardings)

__all__ = [
    "sharding", "compress", "policy", "accounting", "serve_placement",
    "spec_for", "tree_shardings", "batch_axes", "constrain",
    "constrain_batch", "set_batch_shard_axes", "model_divides",
    "fit_template", "INFERENCE_OVERRIDES", "scatter_dims",
    "placement_overrides", "placement_specs",
    "quantize_int8", "init_error_state", "ef_psum_grads", "resolve_modes",
    "AUTO", "CompressionPolicy", "resolve_policy",
    "ServePlacement", "SubTablePlacement", "plan_placement",
    "place_params", "exchange_rows",
]
