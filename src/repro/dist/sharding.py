"""Path+shape sharding rule engine.

One place decides how every tensor in the system is laid out on a mesh:

* ``spec_for(path, shape, mesh)`` — parameter path (``"/"``-joined, see
  ``repro.optim.optimizers.leaf_paths``) + shape → ``PartitionSpec``.
  Rules are a small ordered table of ``(path regex, template)`` pairs;
  the first matching rule wins, then the template is *fitted* to the
  concrete shape: an axis group whose size does not divide a dim is moved
  to the first free dim it does divide, or dropped.  The engine therefore
  **never emits an invalid spec** — GSPMD would reject (or silently pad)
  an axis that does not divide its dim.

* ``tree_shardings(structs, mesh, overrides)`` — whole-pytree version,
  returning ``NamedSharding``s in tree order.

* ``constrain`` / ``constrain_batch`` — in-model activation pinning
  (``with_sharding_constraint``) that degrades to a no-op when there is
  no ambient mesh (plain jit / eager tests) or when the named axes are
  manual (inside ``shard_map``), so model code never has to branch on the
  execution context.

Mesh axis conventions (see ``repro.launch.mesh``): ``model`` is the
tensor-parallel axis; every other axis (``data``, and ``pod`` on
multi-pod meshes) is data-parallel.  The symbol ``"dp"`` in templates and
``constrain`` calls expands to the data-parallel axis group.

Rule table (first match wins; see README "Sharding rules"):

====================================  ==========================  =============
path pattern                          template                    example leaf
====================================  ==========================  =============
embed* / wte / tok_emb / table(s)     ("model", None)             embedding rows
lm_head / head / logits / unembed     ("model", "dp")             output head
moe / expert(s)                       ("model", "dp", None)       (E, D, F) stack
1-D / scalar leaves                   ()                          norm gains
default rank-N dense                  (None, …, "dp", "model")    mlp wi/wo
====================================  ==========================  =============
"""

from __future__ import annotations

import re
from typing import Optional, Sequence

import jax
import numpy as np
from jax.interpreters import pxla
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

__all__ = [
    "RULES", "INFERENCE_OVERRIDES", "spec_for", "tree_shardings",
    "fit_template", "batch_axes", "constrain", "constrain_batch",
    "set_batch_shard_axes", "model_divides", "scatter_dims",
    "placement_overrides", "placement_specs",
]


# ------------------------------------------------------------------ rule table


RULES: tuple[tuple[str, tuple], ...] = (
    # Embedding / hash tables: row-sharded over `model` — the paper's
    # memory-dominant tensors; each chip holds a slice of the rows.
    (r"(^|/)(embed\w*|wte|tok_emb|tables?)(/|$)|(^|/)table_\d+($|/)",
     ("model", None)),
    # Output head: 2-D ("model", data-group) — TP on d_model, FSDP on vocab.
    (r"(^|/)(lm_head|head|logits|unembed|out_head)(/|$)",
     ("model", "dp")),
    # Stacked expert weights (E, d_in, d_out): expert-parallel over `model`,
    # FSDP over the data group on d_in.
    (r"(^|/)(moe|experts?)(/|$)",
     ("model", "dp", None)),
)


def _default_template(rank: int) -> tuple:
    """Generic dense leaf: TP on the last dim, FSDP on the one before."""
    if rank < 2:
        return ()
    return (None,) * (rank - 2) + ("dp", "model")


# "Same rules, minus FSDP": at inference weights are read-only, so
# gathering them over the data group every step buys nothing — keep only
# the tensor-parallel placements.  Passed as ``overrides`` to
# ``tree_shardings`` / ``param_structs`` by the dry-run machinery.
NO_FSDP = "no_fsdp"
INFERENCE_OVERRIDES: tuple[tuple[str, object], ...] = ((r".*", NO_FSDP),)


def placement_overrides(placement) -> tuple[tuple[str, tuple], ...]:
    """Override rules for plan-aware *serving* placement.

    Each row-sharded sub-table of a ``dist.serve_placement.ServePlacement``
    gets a path-exact rule splitting its rows over the ``data`` axis; a
    trailing catch-all replicates everything else (serving weights are
    read-only — the same no-FSDP rationale as ``INFERENCE_OVERRIDES``,
    and the dense stage runs per-device on its batch slice with full
    weights).  Feed to ``spec_for``/``tree_shardings`` like any override
    table; first match wins, so the sharded-table rules lead.
    """
    rules = [(rf"^{re.escape(e.path)}($|/)", ("data", None))
             for e in placement.entries if e.strategy == "row_shard"]
    rules.append((r".*", ()))
    return tuple(rules)


def placement_specs(params, placement):
    """``PartitionSpec`` pytree for serve-time placement — the
    ``shard_map`` in_specs of the sharded wave program.  Row-sharded
    sub-table leaves (rows pre-padded to a multiple of N, so the fitter
    never relocates the axis) get ``P("data", None)``; every other leaf
    replicates."""
    from ..optim.optimizers import leaf_paths
    overrides = placement_overrides(placement)
    sizes = {"data": placement.n_devices}
    leaves, treedef = jax.tree.flatten(params)
    paths = leaf_paths(params)
    specs = [fit_template(_template_for(p, len(l.shape), overrides),
                          l.shape, sizes, batch=("data",))
             if getattr(l, "ndim", 0) > 1 else P()
             for p, l in zip(paths, leaves)]
    return jax.tree.unflatten(treedef, specs)


# ------------------------------------------------------ batch-axes module state

# What the symbol "dp" means for in-model `constrain` calls, and the size of
# the model axis for `model_divides`.  `lowerables` (configs/common.py) sets
# these from the target mesh before tracing; the defaults match a plain
# ("data", "model") mesh so direct model calls under `with mesh:` also work.
_BATCH_AXES: tuple[str, ...] = ("data",)
_MODEL_SIZE: int = 1


def set_batch_shard_axes(axes: Sequence[str], model_size: int = 1) -> None:
    """Configure the data-parallel axis group (and model size) used by
    ``constrain``/``constrain_batch``/``model_divides`` during tracing."""
    global _BATCH_AXES, _MODEL_SIZE
    _BATCH_AXES = tuple(axes) or ("data",)
    _MODEL_SIZE = max(int(model_size), 1)


def batch_axes(mesh) -> tuple[str, ...]:
    """The mesh's data-parallel axis group: every axis except ``model``."""
    return tuple(a for a in mesh.axis_names if a != "model")


def model_divides(n: int) -> bool:
    """True when ``n`` can be evenly sharded over the model axis."""
    return n % _MODEL_SIZE == 0


# ------------------------------------------------------------------ the engine


def _group_size(group: tuple[str, ...], sizes: dict[str, int]) -> int:
    return int(np.prod([sizes[a] for a in group], dtype=np.int64)) if group else 1


def fit_template(template: Sequence, shape: Sequence[int],
                 sizes: dict[str, int],
                 batch: tuple[str, ...] = ("data",)) -> P:
    """Fit a rule template to a concrete shape given mesh axis sizes.

    Template entries per leading dim: ``None``, ``"model"``, ``"dp"`` (the
    data-parallel group), an axis name, or a tuple of axis names.  Axes not
    present in ``sizes`` are dropped.  A group whose size does not divide
    its dim is relocated to the first free dim it does divide (left to
    right), else dropped — the returned spec is always valid for ``shape``.
    """
    rank = len(shape)
    if rank <= 1:
        return P()
    resolved: list[tuple[str, ...]] = []
    for ent in list(template)[:rank]:
        if ent is None:
            resolved.append(())
            continue
        group = batch if ent == "dp" else (tuple(ent) if isinstance(ent, (tuple, list))
                                           else (ent,))
        resolved.append(tuple(a for a in group if a in sizes))
    resolved += [()] * (rank - len(resolved))

    spec: list[tuple[str, ...]] = [()] * rank
    used: set[str] = set()
    homeless: list[tuple[str, ...]] = []
    for i, group in enumerate(resolved):
        group = tuple(a for a in group if a not in used)
        if not group:
            continue
        n = _group_size(group, sizes)
        if shape[i] > 0 and shape[i] % n == 0:
            spec[i] = group
            used.update(group)
        else:
            homeless.append(group)
    for group in homeless:
        group = tuple(a for a in group if a not in used)
        if not group:
            continue
        n = _group_size(group, sizes)
        for i in range(rank):
            if not spec[i] and shape[i] > 0 and shape[i] % n == 0:
                spec[i] = group
                used.update(group)
                break

    def ent(g: tuple[str, ...]):
        if not g:
            return None
        return g[0] if len(g) == 1 else g

    return P(*[ent(g) for g in spec])


def _template_for(path: str, rank: int,
                  overrides: Optional[Sequence[tuple[str, object]]] = None):
    for pattern, template in tuple(overrides or ()) + RULES:
        if re.search(pattern, path):
            if template == NO_FSDP:
                base = _template_for(path, rank, overrides=None)
                return tuple(None if e == "dp" else e for e in base)
            return template
    return _default_template(rank)


def spec_for(path: str, shape: Sequence[int], mesh,
             overrides: Optional[Sequence[tuple[str, object]]] = None) -> P:
    """PartitionSpec for one parameter leaf.  1-D/scalar leaves replicate;
    everything else goes through the rule table + shape fitting."""
    if len(shape) <= 1:
        return P()
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return fit_template(_template_for(path, len(shape), overrides), shape,
                        sizes, batch=batch_axes(mesh))


def scatter_dims(path: str, shape: Sequence[int], mesh,
                 axis: str = "data") -> tuple[int, ...]:
    """Candidate reduce-scatter dims for one leaf, best first.

    The dim the rule engine (``spec_for``) assigns to ``axis`` leads — the
    gradient shard then has the same layout the FSDP param shard would —
    followed by every other dim the axis size divides (left to right).
    Dims the axis size does not divide are never returned, so the caller
    can reduce-scatter any returned dim without padding.
    """
    shape = tuple(shape)
    n = dict(mesh.shape).get(axis, 1)
    spec = spec_for(path, shape, mesh)
    preferred = [i for i, ent in enumerate(spec)
                 if ent is not None
                 and axis in (ent if isinstance(ent, tuple) else (ent,))]
    order = preferred + [i for i in range(len(shape)) if i not in preferred]
    return tuple(i for i in order if shape[i] > 0 and shape[i] % n == 0)


def tree_shardings(structs, mesh, overrides=None):
    """``NamedSharding`` per leaf of ``structs`` (tree order preserved)."""
    from ..optim.optimizers import leaf_paths
    leaves, treedef = jax.tree.flatten(structs)
    paths = leaf_paths(structs)
    out = [NamedSharding(mesh, spec_for(p, l.shape, mesh, overrides))
           for p, l in zip(paths, leaves)]
    return jax.tree.unflatten(treedef, out)


# --------------------------------------------------------- activation pinning


def _ambient_mesh():
    try:
        mesh = pxla.thread_resources.env.physical_mesh
    except Exception:  # pragma: no cover - jax internals moved
        return None
    return None if mesh.empty else mesh


def _manual_axes() -> frozenset:
    """Axis names currently bound manually (shard_map/pmap bodies) — specs on
    these would make ``with_sharding_constraint`` fail at lowering time."""
    try:
        from jax._src.core import get_axis_env
        return frozenset(get_axis_env().axis_sizes)
    except Exception:  # pragma: no cover - jax internals moved
        return frozenset()


def constrain(x, *axes):
    """``with_sharding_constraint`` with one entry per leading dim.

    Entries: ``None``, ``"model"``, ``"dp"`` (expands to the configured
    data-parallel axis group), an axis name, or a tuple of names.  Missing
    trailing entries replicate.  Degrades to identity when there is no
    ambient mesh, inside ``shard_map`` (manual axes), or when a dim cannot
    divide the requested axis group — model code calls this unconditionally.
    """
    mesh = _ambient_mesh()
    if mesh is None or not hasattr(x, "shape"):
        return x
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    manual = _manual_axes()
    shape = x.shape
    spec: list = [None] * len(shape)
    nontrivial = False
    for i, ent in enumerate(axes[:len(shape)]):
        if ent is None:
            continue
        group = _BATCH_AXES if ent == "dp" else (tuple(ent) if isinstance(ent, (tuple, list))
                                                 else (ent,))
        group = tuple(a for a in group if a in sizes and a not in manual)
        if not group:
            continue
        n = _group_size(group, sizes)
        if shape[i] % n != 0 or shape[i] == 0:
            continue
        spec[i] = group[0] if len(group) == 1 else group
        nontrivial = True
    if not nontrivial:
        return x
    return jax.lax.with_sharding_constraint(x, P(*spec))


def constrain_batch(x):
    """Pin dim 0 (the batch dim) to the data-parallel axis group.  No-op
    outside a mesh context and for scalars."""
    ndim = getattr(x, "ndim", 0)
    if not ndim:
        return x
    return constrain(x, "dp", *([None] * (ndim - 1)))
