"""Per-leaf gradient-compression policy engine.

PR 1's ``ef_psum_grads`` applied one compression mode to every gradient
leaf.  At production scale that is the wrong trade everywhere at once:
embedding-table gradients are the wire-dominant tensors and tolerate
aggressive int8 (error feedback absorbs the quantisation), dense matmul
gradients want bf16, and norm gains / biases / tiny leaves are not worth
compressing at all — their bytes are noise but their precision is not.

This module maps each gradient leaf (parameter path + shape) to a mode,
in the style of ``sharding.RULES``: an ordered ``(path regex, mode)``
table, first match wins, with a size/rank gate applied before the table
(norms, biases, and any leaf under ``min_compress_elems`` elements get
``small_mode`` regardless of name).  The resolved per-leaf mode pytree
threads straight through ``compress.ef_psum_grads`` and
``compress.init_error_state`` — error-feedback state is allocated only
for leaves that actually compress (a zero-d placeholder otherwise).

Extend by adding a ``(regex, mode)`` pair to a policy's ``rules`` —
do **not** hardcode modes at call sites (see README "Compression policy
& wire bytes").
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Sequence

from .compress import MODES

__all__ = ["POLICY_RULES", "CompressionPolicy", "AUTO", "resolve_policy"]


# Ordered (path regex, mode) table — same path idiom as sharding.RULES.
POLICY_RULES: tuple[tuple[str, str], ...] = (
    # Embedding / hash tables: the paper's memory-dominant tensors are also
    # the wire-dominant gradients; int8 + error feedback.
    (r"(^|/)(embed\w*|wte|tok_emb|tables?)(/|$)|(^|/)table_\d+($|/)", "int8"),
    # Norm / gain / bias leaves by name (rank-2 norm scales exist in some
    # archs, so the rank gate alone is not enough).
    (r"(^|/)(norm\w*|ln\w*|layernorm|rmsnorm|scale|gain|bias)($|/)", "none"),
)


@dataclasses.dataclass(frozen=True)
class CompressionPolicy:
    """Path+shape → compression mode.

    Resolution order for ``mode_for(path, shape)``:
      1. rank ≤ 1 or fewer than ``min_compress_elems`` elements →
         ``small_mode`` (compressing a bias saves nothing and risks the
         precision-critical leaves);
      2. first matching ``(regex, mode)`` rule in ``rules``;
      3. ``default`` (dense matmul gradients → bf16).
    """

    rules: tuple[tuple[str, str], ...] = POLICY_RULES
    default: str = "bf16"
    min_compress_elems: int = 2048
    small_mode: str = "none"

    def __post_init__(self):
        for _, mode in tuple(self.rules) + (("", self.default),
                                            ("", self.small_mode)):
            if mode not in MODES:
                raise ValueError(
                    f"unknown compression mode {mode!r}; expected one of {MODES}")

    def mode_for(self, path: str, shape: Sequence[int]) -> str:
        shape = tuple(shape)
        if len(shape) <= 1 or math.prod(shape) < self.min_compress_elems:
            return self.small_mode
        for pattern, mode in self.rules:
            if re.search(pattern, path):
                return mode
        return self.default

    def tree(self, tree_like):
        """Pytree of mode strings matching ``tree_like``'s structure."""
        import jax

        from ..optim.optimizers import leaf_paths
        leaves, treedef = jax.tree.flatten(tree_like)
        paths = leaf_paths(tree_like)
        return jax.tree.unflatten(
            treedef, [self.mode_for(p, l.shape) for p, l in zip(paths, leaves)])

    def modes(self, tree_like) -> list[str]:
        """Flat per-leaf mode list in ``jax.tree.leaves`` order."""
        import jax
        return jax.tree.leaves(self.tree(tree_like),
                               is_leaf=lambda x: isinstance(x, str))


# The default policy: int8 tables, bf16 dense, none for norms/bias/small.
AUTO = CompressionPolicy()


def resolve_policy(policy) -> "CompressionPolicy | str":
    """Accepts a mode string, ``"auto"``, or a CompressionPolicy."""
    if isinstance(policy, CompressionPolicy):
        return policy
    if policy == "auto":
        return AUTO
    if policy in MODES:
        return policy
    raise ValueError(f"unknown compression policy {policy!r}; expected one of "
                     f"{MODES + ('auto',)} or a CompressionPolicy")
