"""Per-step collective wire-byte accounting from a grad tree + policy.

Computes, analytically, how many bytes each device puts on the wire per
training step under a compression policy — the quantity the policy
engine exists to shrink — using the *same ring formulas per chip* as the
HLO analyzer (``launch.hlo_analysis``), so the two are directly
cross-checkable (``benchmarks/dist_bench.py`` asserts they agree within
10% on the compiled step):

    all-reduce       2·(n−1)/n · bytes
    all-gather       (n−1)/n · gathered bytes
    reduce-scatter   (n−1) · shard bytes  =  (n−1)/n · full bytes
    all-to-all       (n−1)/n · bytes

Per-mode wire cost of reducing one leaf of E elements (see
``compress``'s module docstring for the exchanges):

==========  =============================  =============================
mode        DP all-reduce path             FSDP reduce-scatter path
==========  =============================  =============================
``none``    2(n−1)/n · 4E                  (n−1)/n · 4E
``bf16``    2(n−1)/n · 2E′                 (n−1)/n · 2E
``int8``    2(n−1)/n · 1E′ + scales        (n−1)/n · 1E + scale
==========  =============================  =============================

(E′ = E padded to a multiple of n — the compressed all-reduce is the
two-phase all_to_all + all_gather exchange over the flattened leaf;
"scales" are the pmax-shared f32 scalar all-reduces, int8 only.)  The FSDP path additionally all-gathers every
updated param shard: (n−1)/n · 4E per scattered leaf — reported
separately so "gradient wire" and "param wire" stay distinguishable.
"""

from __future__ import annotations

import math

import jax

from ..optim.optimizers import leaf_paths
from .compress import resolve_modes

__all__ = ["leaf_reduce_bytes", "grad_wire_bytes", "dp_step_wire_bytes",
           "fsdp_step_wire_bytes", "ring_all_reduce_bytes",
           "ring_all_gather_bytes", "ring_reduce_scatter_bytes",
           "ring_all_to_all_bytes", "serve_exchange_wire_bytes",
           "serve_wave_wire_bytes"]

_SCALE_BYTES = 4  # one f32 scalar per pmax-shared quantisation scale


def ring_all_reduce_bytes(nbytes: float, n: int) -> float:
    return 2.0 * (n - 1) / n * nbytes


def ring_all_gather_bytes(gathered_nbytes: float, n: int) -> float:
    return (n - 1) / n * gathered_nbytes


def ring_reduce_scatter_bytes(full_nbytes: float, n: int) -> float:
    return (n - 1) / n * full_nbytes


def ring_all_to_all_bytes(nbytes: float, n: int) -> float:
    return (n - 1) / n * nbytes


def leaf_reduce_bytes(mode: str, nelems: int, n: int, *,
                      pattern: str = "all_reduce") -> float:
    """Wire bytes per chip to reduce one gradient leaf.

    ``pattern``: ``"all_reduce"`` (DP step — every device ends with the
    full reduced leaf) or ``"reduce_scatter"`` (FSDP step — each device
    ends with its shard; no phase-2 gather for int8).
    """
    if n <= 1 or nelems == 0:
        return 0.0
    if mode == "none":
        full = 4.0 * nelems
        return (ring_all_reduce_bytes(full, n) if pattern == "all_reduce"
                else ring_reduce_scatter_bytes(full, n))
    if mode == "bf16":
        if pattern == "all_reduce":
            padded = 2.0 * math.ceil(nelems / n) * n
            return (ring_all_to_all_bytes(padded, n)
                    + ring_all_gather_bytes(padded, n))
        return ring_reduce_scatter_bytes(2.0 * nelems, n)
    if mode == "int8":
        scale = ring_all_reduce_bytes(_SCALE_BYTES, n)
        if pattern == "all_reduce":
            padded = float(math.ceil(nelems / n) * n)
            return (ring_all_to_all_bytes(padded, n)
                    + ring_all_gather_bytes(padded, n) + 2 * scale)
        return ring_all_to_all_bytes(float(nelems), n) + scale
    raise ValueError(f"unknown compression mode {mode!r}")


def grad_wire_bytes(grads_like, policy, n: int, *, pattern: str = "all_reduce",
                    scattered=None) -> dict:
    """Per-leaf + aggregate reduction wire bytes for a gradient tree.

    ``policy`` is anything ``compress.resolve_modes`` accepts (mode string,
    per-leaf tree, ``CompressionPolicy``).  ``scattered`` (optional, per
    leaf, flat) marks which leaves actually reduce-scatter; unscattered
    leaves fall back to the all-reduce pattern (mirroring
    ``train.loop.fsdp_plan``'s fallback).
    """
    leaves = jax.tree.leaves(grads_like)
    paths = leaf_paths(grads_like)
    modes = resolve_modes(grads_like, policy)
    if scattered is None:
        scattered = [pattern == "reduce_scatter"] * len(leaves)
    per_leaf = []
    per_mode: dict[str, float] = {}
    total = 0.0
    for path, leaf, mode, scat in zip(paths, leaves, modes, scattered):
        nelems = int(math.prod(leaf.shape)) if leaf.shape else 1
        b = leaf_reduce_bytes(mode, nelems, n,
                              pattern="reduce_scatter" if scat else "all_reduce")
        per_leaf.append({"path": path, "mode": mode, "nelems": nelems,
                         "wire_bytes": b})
        per_mode[mode] = per_mode.get(mode, 0.0) + b
        total += b
    return {"total_bytes": total, "per_mode": per_mode, "per_leaf": per_leaf,
            "n_devices": n, "pattern": pattern}


def serve_exchange_wire_bytes(lookups: int, width: int, n: int, *,
                              quantized: bool = True,
                              row_dtype_bytes: int = 4) -> dict:
    """Per-chip wire bytes of one row-sharded serve exchange
    (``dist.serve_placement.exchange_rows``) for one sub-table and wave.

    The exchange is two all-to-all phases over ``(n, C)``-shaped buffers
    (C = ``lookups``, this device's row fetches for the wave):

    * **ids out** — one int32 global row id per lookup slot, every slot
      shipped (the send buffer is dense): ``(n−1)/n · 4·n·C``;
    * **rows back** — per lookup slot, the stored row at its stored
      width: quantized tables ship ``q`` int8 ``(n, C, w)`` + ``scale``
      bf16-as-uint16 ``(n, C, 1)`` + ``zp`` int8 ``(n, C, 1)`` (int8
      stays on the wire; dequant happens at the requesting device);
      dense tables ship ``row_dtype_bytes`` per element.

    Static shapes, pure data movement — no reduction, no tolerance: the
    serve_dist bench asserts this equals the HLO analyzer's collective
    bytes for the compiled wave program *exactly*.
    """
    ids = ring_all_to_all_bytes(4.0 * n * lookups, n)
    if quantized:
        rows = (ring_all_to_all_bytes(1.0 * n * lookups * width, n)
                + ring_all_to_all_bytes(2.0 * n * lookups, n)
                + ring_all_to_all_bytes(1.0 * n * lookups, n))
    else:
        rows = ring_all_to_all_bytes(
            float(row_dtype_bytes) * n * lookups * width, n)
    return {"ids_bytes": ids, "rows_bytes": rows,
            "total_bytes": ids + rows}


def serve_wave_wire_bytes(placement, batch_per_device: int,
                          bag_len: int) -> dict:
    """Per-chip wire bytes of one sharded serve wave: the sum of
    ``serve_exchange_wire_bytes`` over the placement's row-sharded
    sub-tables, each fetching ``batch_per_device · bag_len`` rows.
    Replicated sub-tables cost nothing — that is the point of the
    replication threshold."""
    n = placement.n_devices
    lookups = batch_per_device * bag_len
    per_entry = []
    total = 0.0
    for e in placement.sharded:
        # stored element width of a dense sub-table (4 f32, 2 bf16) —
        # recoverable from the placement's byte accounting
        dtype_bytes = (e.bytes_total // max(e.rows * e.width, 1)
                       if not e.quantized else 4)
        b = serve_exchange_wire_bytes(lookups, e.width, n,
                                      quantized=e.quantized,
                                      row_dtype_bytes=dtype_bytes)
        per_entry.append({"path": e.path, "width": e.width,
                          "quantized": e.quantized, **b})
        total += b["total_bytes"]
    return {"total_bytes": total, "lookups_per_device": lookups,
            "n_devices": n, "per_entry": per_entry}


def _scalar_overhead(n: int, n_scalars: int) -> float:
    """f32 scalar all-reduces outside the grad reduction (loss/metric pmeans)."""
    return n_scalars * ring_all_reduce_bytes(4.0, n)


def dp_step_wire_bytes(params_like, policy, n: int, *,
                       scalar_allreduces: int = 0) -> dict:
    """Accounted wire bytes for one ``make_dp_train_step`` step."""
    grads = grad_wire_bytes(params_like, policy, n, pattern="all_reduce")
    overhead = _scalar_overhead(n, scalar_allreduces)
    return {"grad_bytes": grads["total_bytes"], "param_gather_bytes": 0.0,
            "overhead_bytes": overhead,
            "total_bytes": grads["total_bytes"] + overhead,
            "per_mode": grads["per_mode"], "n_devices": n}


def fsdp_step_wire_bytes(params_like, optimizer, mesh, policy, *,
                         axis: str = "data", scalar_allreduces: int = 0,
                         param_gather_dtype="float32") -> dict:
    """Accounted wire bytes for one ``make_fsdp_train_step`` step: compressed
    grad reduce-scatter + all-gather of every scattered param shard
    (f32, or 2 B/elem with ``param_gather_dtype="bfloat16"``)."""
    from ..train.loop import fsdp_plan
    import jax.numpy as jnp
    n = dict(mesh.shape).get(axis, 1)
    plan = fsdp_plan(params_like, optimizer, mesh, policy=policy, axis=axis)
    scattered = [dim is not None for (_, _, _, dim) in plan]
    grads = grad_wire_bytes(params_like, policy, n, pattern="reduce_scatter",
                            scattered=scattered)
    gbytes = float(jnp.dtype(param_gather_dtype).itemsize)
    gather = sum(ring_all_gather_bytes(gbytes * math.prod(shape), n)
                 for (_, shape, _, dim) in plan if dim is not None)
    overhead = _scalar_overhead(n, scalar_allreduces)
    return {"grad_bytes": grads["total_bytes"], "param_gather_bytes": gather,
            "overhead_bytes": overhead,
            "total_bytes": grads["total_bytes"] + gather + overhead,
            "per_mode": grads["per_mode"], "n_devices": n,
            "n_scattered": sum(scattered), "n_leaves": len(plan)}
