"""Plan-aware placement of serving tables across a device mesh.

Production recsys serves tables too big for one host; this module decides,
from the :class:`~repro.plan.memory_plan.MemoryPlan`'s per-table byte
accounting (or the built params when no plan is given), where each
sub-table lives on an N-device serving mesh:

* **replicate** sub-tables below a replication-byte threshold — small /
  hot tables (the QR quotient side, narrow mixed-dimension tables) are
  cheaper to copy everywhere than to chat about;
* **row-shard** everything else contiguously over the ``data`` axis:
  device ``d`` owns rows ``[d*R/N, (d+1)*R/N)`` — itself a quotient
  partition of the row space, the paper's own machinery applied to
  placement.  Rows are padded up to a multiple of N so the spec engine
  never meets an indivisible axis.

Lookups into a row-sharded sub-table route through
:func:`exchange_rows` — a **two-phase all-to-all** mirroring the
train-side compressed collectives (``dist.compress``): phase 1 ships
each lookup's row id to the owning device, phase 2 ships the rows home.
Quantized tables keep **int8 on the wire** (q int8, scale bf16 bitcast
to uint16, zp int8) and dequantize at the requesting device with exactly
the ``core.compositional.table_rows`` arithmetic, so the exchanged rows
are bit-identical to a local gather.  ``dist.accounting.
serve_exchange_wire_bytes`` prices the exchange with the same ring
formulas the HLO analyzer uses; ``benchmarks/serve_dist_bench.py``
asserts they match the compiled program's collectives *exactly*.

The default threshold derives from the plan: ``total_table_bytes /
(4·N)`` — any sub-table worth more than a quarter of a device's even
share earns sharding; everything smaller replicates.  This bounds
per-device bytes by ``total/N + replicated`` (the bench's acceptance
row) while keeping the quotient sides of QR pairs local.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding

from ..core.compositional import is_quantized_table

__all__ = ["SubTablePlacement", "ServePlacement", "plan_placement",
           "place_params", "exchange_rows", "sub_table_items",
           "REPLICATION_DIVISOR"]

# threshold = total_table_bytes / (REPLICATION_DIVISOR * n_devices):
# a sub-table bigger than 1/4 of a device's even share is worth sharding
REPLICATION_DIVISOR = 4


@dataclasses.dataclass(frozen=True)
class SubTablePlacement:
    """Where one sub-table (one partition's rows) lives on the mesh."""

    feature: int
    table_key: str          # "table" | "table_0" | "table_1" | ...
    path: str               # "tables/<feature>/<table_key>"
    rows: int
    padded_rows: int        # rows rounded up to a multiple of n (row_shard)
    width: int
    bytes_total: int        # stored bytes (q+scale+zp for quantized tables)
    strategy: str           # "replicate" | "row_shard"
    quantized: bool

    @property
    def pad_bytes(self) -> int:
        return (self.bytes_total * (self.padded_rows - self.rows)
                // max(self.rows, 1))

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "SubTablePlacement":
        return cls(**d)


@dataclasses.dataclass
class ServePlacement:
    """The full placement decision for one model's tables on N devices."""

    n_devices: int
    threshold_bytes: int
    entries: list[SubTablePlacement] = dataclasses.field(default_factory=list)

    def entry(self, feature: int, table_key: str) -> SubTablePlacement:
        for e in self.entries:
            if e.feature == feature and e.table_key == table_key:
                return e
        raise KeyError(f"no placement entry for tables/{feature}/{table_key}")

    @property
    def sharded(self) -> list[SubTablePlacement]:
        return [e for e in self.entries if e.strategy == "row_shard"]

    @property
    def replicated(self) -> list[SubTablePlacement]:
        return [e for e in self.entries if e.strategy == "replicate"]

    def total_bytes(self) -> int:
        return sum(e.bytes_total for e in self.entries)

    def replicated_bytes(self) -> int:
        return sum(e.bytes_total for e in self.replicated)

    def pad_bytes(self) -> int:
        return sum(e.pad_bytes for e in self.sharded)

    def bytes_per_device(self) -> int:
        """Resident table bytes on one device: every replicated sub-table
        in full plus an even 1/N share of each padded row-sharded one."""
        shard = sum((e.bytes_total + e.pad_bytes) // self.n_devices
                    for e in self.sharded)
        return self.replicated_bytes() + shard

    def replicated_features(self, n_features: int) -> np.ndarray:
        """Bool per feature: every sub-table replicated (locally resident)
        — the set the device hot-row cache may hold in sharded serving."""
        out = np.ones(n_features, bool)
        for e in self.sharded:
            out[e.feature] = False
        return out

    def rows_per_device(self, e: SubTablePlacement) -> int:
        return e.padded_rows // self.n_devices

    def summary(self) -> dict:
        return {"n_devices": self.n_devices,
                "threshold_bytes": self.threshold_bytes,
                "sub_tables": len(self.entries),
                "row_sharded": len(self.sharded),
                "replicated": len(self.replicated),
                "total_bytes": self.total_bytes(),
                "replicated_bytes": self.replicated_bytes(),
                "pad_bytes": self.pad_bytes(),
                "bytes_per_device": self.bytes_per_device()}

    def as_dict(self) -> dict:
        return {"n_devices": self.n_devices,
                "threshold_bytes": self.threshold_bytes,
                "entries": [e.as_dict() for e in self.entries]}

    @classmethod
    def from_dict(cls, d: dict) -> "ServePlacement":
        return cls(n_devices=d["n_devices"],
                   threshold_bytes=d["threshold_bytes"],
                   entries=[SubTablePlacement.from_dict(e)
                            for e in d["entries"]])


def _leaf_bytes(leaf) -> int:
    if is_quantized_table(leaf):
        return sum(_leaf_bytes(v) for v in leaf.values())
    n = int(math.prod(leaf.shape)) if leaf.shape else 1
    return n * jnp.dtype(leaf.dtype).itemsize


def sub_table_items(params) -> list[tuple[int, str, object]]:
    """``(feature, table_key, leaf)`` per sub-table of ``params["tables"]``
    (a leaf is a 2-D array or a quantized-table dict), in feature order."""
    out = []
    for i, tp in enumerate(params["tables"]):
        for key in sorted(tp):
            out.append((i, key, tp[key]))
    return out


def plan_placement(params, n_devices: int, *, plan=None,
                   threshold_bytes: int | None = None) -> ServePlacement:
    """Place every sub-table of ``params["tables"]`` on ``n_devices``.

    Byte accounting comes from the built arrays (authoritative — they are
    what gets resident); the ``plan`` supplies the threshold's byte base
    when given (``plan.total_bytes``, the planner's claim, which
    ``plan_bench`` already pins to the built bytes).  ``n_devices == 1``
    replicates everything — the placement degenerates to single-host
    serving and the engine takes the unsharded path.
    """
    items = sub_table_items(params)
    total = sum(_leaf_bytes(leaf) for _, _, leaf in items)
    if threshold_bytes is None:
        base = int(getattr(plan, "total_bytes", 0) or 0) or total
        threshold_bytes = max(1, base // (REPLICATION_DIVISOR
                                          * max(n_devices, 1)))
    entries = []
    for feature, key, leaf in items:
        if is_quantized_table(leaf):
            rows, width = int(leaf["q"].shape[0]), int(leaf["q"].shape[1])
        else:
            rows, width = int(leaf.shape[0]), int(leaf.shape[1])
        nbytes = _leaf_bytes(leaf)
        shard = (n_devices > 1 and nbytes > threshold_bytes
                 and rows >= n_devices)
        padded = (-rows % n_devices) + rows if shard else rows
        entries.append(SubTablePlacement(
            feature=feature, table_key=key, path=f"tables/{feature}/{key}",
            rows=rows, padded_rows=padded, width=width, bytes_total=nbytes,
            strategy="row_shard" if shard else "replicate",
            quantized=is_quantized_table(leaf)))
    return ServePlacement(n_devices=n_devices,
                          threshold_bytes=int(threshold_bytes),
                          entries=entries)


def _pad_rows(leaf, padded_rows: int):
    def pad(x):
        extra = padded_rows - x.shape[0]
        if extra <= 0:
            return x
        return jnp.concatenate(
            [x, jnp.zeros((extra,) + x.shape[1:], x.dtype)])
    if is_quantized_table(leaf):
        return {k: pad(v) for k, v in leaf.items()}
    return pad(leaf)


def place_params(params, placement: ServePlacement, mesh):
    """Pad + device_put the param tree per the placement.

    Row-sharded sub-tables land row-split over the mesh's ``data`` axis
    (rows pre-padded to ``padded_rows`` so the split is always even);
    everything else — replicated sub-tables, MLPs, projections —
    replicates (serving weights are read-only, so FSDP-style gathering
    buys nothing; same rationale as ``sharding.INFERENCE_OVERRIDES``).
    Returns ``(placed_params, spec_tree)`` where ``spec_tree`` is the
    matching ``PartitionSpec`` pytree (the ``shard_map`` in_spec).
    """
    from .sharding import placement_specs
    params = dict(params)
    tables = [dict(tp) for tp in params["tables"]]
    for e in placement.sharded:
        tables[e.feature][e.table_key] = _pad_rows(
            tables[e.feature][e.table_key], e.padded_rows)
    params["tables"] = tables
    specs = placement_specs(params, placement)
    placed = jax.tree.map(
        lambda leaf, spec: jax.device_put(leaf, NamedSharding(mesh, spec)),
        params, specs)
    return placed, specs


# ------------------------------------------------------------- the exchange


def _wire(x, axis: str):
    """Phase-2 all-to-all with the compressed dtype kept on the wire.

    bf16 rides as uint16 (``dist.compress``'s bitcast idiom — some
    backends widen bf16 collectives); int8/f32/int32 go as themselves.
    """
    if x.dtype == jnp.bfloat16:
        home = lax.all_to_all(lax.bitcast_convert_type(x, jnp.uint16),
                              axis, split_axis=0, concat_axis=0)
        return lax.bitcast_convert_type(home, jnp.bfloat16)
    return lax.all_to_all(x, axis, split_axis=0, concat_axis=0)


def exchange_rows(leaf, ids, n: int, rows_per_device: int,
                  axis: str = "data"):
    """Fetch rows of a row-sharded sub-table from their owning devices.

    Runs inside ``shard_map`` over mesh axis ``axis`` (size ``n``).
    ``leaf`` is the *local* row shard (array or quantized dict, rows =
    ``rows_per_device``); ``ids`` is this device's lookup tensor of
    global row ids (any shape, int).  Two-phase, mirroring the train-side
    compressed collectives:

    1. ids out: each lookup's global id maps to ``(owner, local_row)``;
       ids pack into an ``(n, C)`` send buffer (C = lookups) and
       all-to-all to their owners;
    2. rows back: owners gather their local rows and all-to-all them
       home, int8/bf16 staying narrow on the wire; quantized rows
       dequantize *after* the trip with ``table_rows``' exact arithmetic.

    Unused send slots carry id 0 (in-range; the per-lookup unpermute
    ignores them), so the result is bit-identical to a local gather from
    the unsharded table — the parity the serve_dist tests pin.
    """
    shape = ids.shape
    flat = ids.reshape(-1).astype(jnp.int32)
    c = flat.shape[0]
    owners = flat // rows_per_device
    local = flat % rows_per_device
    # position of each lookup within its owner's bucket: one-hot cumsum
    onehot = (owners[:, None] == jnp.arange(n, dtype=jnp.int32)[None, :]
              ).astype(jnp.int32)
    pos = jnp.take_along_axis(jnp.cumsum(onehot, axis=0),
                              owners[:, None], axis=1)[:, 0] - 1
    send = jnp.zeros((n, c), jnp.int32).at[owners, pos].set(local)
    recv = lax.all_to_all(send, axis, split_axis=0, concat_axis=0)

    def route(shard):
        rows = jnp.take(shard, recv, axis=0)        # (n, C, w)
        return _wire(rows, axis)[owners, pos]       # (C, w)

    if is_quantized_table(leaf):
        q = route(leaf["q"]).astype(jnp.float32)
        zp = route(leaf["zp"]).astype(jnp.float32)
        scale = route(leaf["scale"]).astype(jnp.float32)
        out = (q - zp) * scale                      # == table_rows bits
        return out.reshape(shape + (out.shape[-1],))
    out = route(leaf)
    return out.reshape(shape + (out.shape[-1],))
