"""Synthetic LM token stream: seeded, stateless-per-step, learnable.

Sequences follow a planted order-1 Markov chain with a low-rank transition
structure, so a real LM reduces loss well below uniform entropy — enough
to exercise the full training path (and the QR-compressed vocab embedding)
without a corpus.  ``batch_at(seed, step, ...)`` is pure: restart-safe.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["batch_at", "frames_at", "patches_at"]


def batch_at(seed: int, step: int, batch_size: int, seq_len: int, vocab: int,
             rank: int = 8):
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k0, kseq = jax.random.split(key)
    # low-rank markov logits: T[v] ~ U[v] @ V  (planted, seed-stable)
    ku, kv = jax.random.split(jax.random.PRNGKey(seed ^ 0x5EED))
    u = jax.random.normal(ku, (vocab, rank))
    v = jax.random.normal(kv, (rank, vocab))
    start = jax.random.randint(k0, (batch_size,), 0, vocab)

    def step_fn(tok, k):
        logits = u[tok] @ v * 2.0
        nxt = jax.random.categorical(k, logits)
        return nxt, nxt

    keys = jax.random.split(kseq, seq_len)
    _, toks = jax.lax.scan(lambda c, k: step_fn(c, k), start, keys)
    tokens = jnp.concatenate([start[:, None], toks.T], axis=1)  # (B, S+1)
    return {"tokens": tokens[:, :-1].astype(jnp.int32),
            "labels": tokens[:, 1:].astype(jnp.int32),
            "mask": jnp.ones((batch_size, seq_len), jnp.float32)}


def frames_at(seed: int, step: int, batch_size: int, n_frames: int, d_model: int):
    """Stub audio-frame embeddings for the seamless frontend."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed ^ 0xA0D10), step)
    return jax.random.normal(key, (batch_size, n_frames, d_model)) * 0.1


def patches_at(seed: int, step: int, batch_size: int, n_patches: int, d_model: int):
    """Stub anyres patch embeddings for the llava frontend."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed ^ 0x1A6E), step)
    return jax.random.normal(key, (batch_size, n_patches, d_model)) * 0.1
