"""Sharded, prefetching host→device data loader.

``ShardedLoader`` wraps a stateless ``batch_at(step)`` function and:
  * slices out this host's shard of the global batch (multi-host SPMD:
    every process feeds only its addressable devices);
  * ``jax.device_put``s with the batch ``NamedSharding`` so pjit consumes
    data without a gather;
  * prefetches ``depth`` batches on a background thread (hides host input
    latency — the straggler-mitigation lever for input-bound steps);
  * is restartable: ``seek(step)`` repositions the stream exactly.
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Optional

import jax

__all__ = ["ShardedLoader", "host_slice"]


def host_slice(batch, *, process_index=None, process_count=None):
    """This host's rows of a global batch (dim 0 split across processes)."""
    pi = jax.process_index() if process_index is None else process_index
    pc = jax.process_count() if process_count is None else process_count
    if pc == 1:
        return batch

    def slc(x):
        n = x.shape[0]
        per = n // pc
        return x[pi * per : (pi + 1) * per]

    return jax.tree.map(slc, batch)


class ShardedLoader:
    def __init__(self, batch_at: Callable[[int], dict], *, sharding=None,
                 depth: int = 2, start_step: int = 0):
        self._batch_at = batch_at
        self._sharding = sharding
        self._depth = depth
        self._step = start_step
        self._q: Optional[queue.Queue] = None
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None

    def seek(self, step: int):
        self._shutdown()
        self._step = step

    def _produce(self, start: int):
        step = start
        while not self._stop.is_set():
            batch = self._batch_at(step)
            batch = host_slice(batch)
            if self._sharding is not None:
                batch = jax.device_put(batch, self._sharding)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def _ensure(self):
        if self._thread is None or not self._thread.is_alive():
            self._stop.clear()
            self._q = queue.Queue(maxsize=self._depth)
            self._thread = threading.Thread(
                target=self._produce, args=(self._step,), daemon=True)
            self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        self._ensure()
        step, batch = self._q.get()
        self._step = step + 1
        return batch

    def _shutdown(self):
        if self._thread is not None:
            self._stop.set()
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=2.0)
            self._thread = None

    def close(self):
        self._shutdown()
