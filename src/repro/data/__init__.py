"""Subsystem package."""
