"""Criteo-format data: synthetic generator + TSV reader.

The Criteo Kaggle dataset (13 dense + 26 categorical columns, ~45M rows)
is not redistributable offline, so experiments use a *seeded synthetic
stream* that reproduces its statistical shape:

  * categorical draws are power-law (Zipf-ish) — category frequency skew is
    what makes the paper's thresholding and collision analysis meaningful;
  * labels come from a planted logistic model over (a) dense features and
    (b) low-order harmonics of the category indices, so models have real
    signal to learn and loss curves discriminate between full / hash / QR
    embeddings (the paper's Fig. 4 comparison);
  * generation is stateless-per-step: ``batch_at(seed, step)`` — restartable
    training replays the exact stream (fault-tolerance requirement).

``read_tsv`` parses the real Criteo format (label \\t 13 ints \\t 26 hex
cats) for when the actual dataset is available.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["CriteoSpec", "DriftSpec", "KAGGLE_TABLE_SIZES", "batch_at",
           "drifted_batch_at", "read_tsv"]

# Criteo Kaggle per-feature cardinalities (rounded, public statistics).
KAGGLE_TABLE_SIZES = (
    1460, 583, 10131227, 2202608, 305, 24, 12517, 633, 3, 93145,
    5683, 8351593, 3194, 27, 14992, 5461306, 10, 5652, 2173, 4,
    7046547, 18, 15, 286181, 105, 142572,
)


@dataclasses.dataclass(frozen=True)
class CriteoSpec:
    table_sizes: tuple[int, ...] = KAGGLE_TABLE_SIZES
    dense_dim: int = 13
    zipf: float = 3.0          # idx = floor(S * u^zipf): higher = more skew
    noise: float = 1.0


def batch_at(seed: int, step: int, batch_size: int, spec: CriteoSpec):
    """Deterministic batch for (seed, step).  Returns {dense, sparse, label}."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    kd, ks, kl = jax.random.split(key, 3)
    dense = jax.random.normal(kd, (batch_size, spec.dense_dim))
    u = jax.random.uniform(ks, (batch_size, len(spec.table_sizes)))
    sizes = jnp.asarray(spec.table_sizes)
    sparse = jnp.floor((u ** spec.zipf) * sizes).astype(jnp.int32)
    sparse = jnp.minimum(sparse, sizes - 1)

    # planted logistic signal: dense weights + category harmonics
    n_tab = len(spec.table_sizes)
    w_dense = _planted(seed, "wd", (spec.dense_dim,))
    a = _planted(seed, "a", (n_tab,))
    c = _planted(seed, "c", (n_tab,)) * 5.0
    score = dense @ w_dense + (jnp.sin(sparse * c) * a).sum(-1)
    noise = spec.noise * jax.random.normal(kl, (batch_size,))
    label = (score + noise > 0).astype(jnp.float32)
    return {"dense": dense, "sparse": sparse, "label": label}


@dataclasses.dataclass(frozen=True)
class DriftSpec:
    """Injected traffic drift for the synthetic stream (the ROADMAP's
    streaming-drift scenario).  Two mechanisms, both stateless per
    ``(seed, step)`` so drifted streams replay exactly like ``batch_at``:

    * **Zipf shift** — from ``shift_step`` on, the popularity head
      *rotates* by ``rotate_frac`` of each table (yesterday's hot ids go
      cold, previously-cold mid-range ids become the head) and the zipf
      exponent moves to ``zipf_after``.  A flatter exponent means more
      effective categories, which is what actually moves measured
      collision mass on hashed/QR tables — pure rotation alone barely
      does, because ``x mod m`` maps a consecutive hot head to distinct
      rows wherever it starts.
    * **flash crowd** — during ``[crowd_step, crowd_step + crowd_len)``
      a ``crowd_frac`` share of every feature's draws redirects to one
      fixed (previously cold) crowd id per feature.
    """
    shift_step: int | None = None
    rotate_frac: float = 0.5
    zipf_after: float | None = None
    crowd_step: int | None = None
    crowd_len: int = 0
    crowd_frac: float = 0.0

    def active(self, step: int) -> bool:
        shifted = self.shift_step is not None and step >= self.shift_step
        crowded = (self.crowd_step is not None and self.crowd_frac > 0
                   and self.crowd_step <= step < self.crowd_step
                   + self.crowd_len)
        return shifted or crowded


def drifted_batch_at(seed: int, step: int, batch_size: int,
                     spec: CriteoSpec, drift: DriftSpec | None = None):
    """``batch_at`` with ``drift`` applied to the categorical draws.

    Inactive drift (pre-``shift_step``, outside the crowd window, or
    ``drift=None``) is bitwise ``batch_at`` — same keys, same op order.
    When active, the drifted ids feed the *same* planted logistic label
    model, so the labels reflect the traffic actually drawn and a model
    trained pre-drift has genuinely stale embeddings to recover from.
    """
    if drift is None or not drift.active(step):
        return batch_at(seed, step, batch_size, spec)
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    kd, ks, kl = jax.random.split(key, 3)
    dense = jax.random.normal(kd, (batch_size, spec.dense_dim))
    u = jax.random.uniform(ks, (batch_size, len(spec.table_sizes)))
    sizes = jnp.asarray(spec.table_sizes)
    shifted = drift.shift_step is not None and step >= drift.shift_step
    zipf = spec.zipf
    if shifted and drift.zipf_after is not None:
        zipf = drift.zipf_after
    sparse = jnp.floor((u ** zipf) * sizes).astype(jnp.int32)
    sparse = jnp.minimum(sparse, sizes - 1)
    if shifted and drift.rotate_frac:
        off = jnp.floor(sizes * drift.rotate_frac).astype(jnp.int32)
        sparse = (sparse + off[None, :]) % sizes
    if (drift.crowd_step is not None and drift.crowd_frac > 0
            and drift.crowd_step <= step < drift.crowd_step + drift.crowd_len):
        kc = jax.random.fold_in(ks, 1)
        pick = jax.random.uniform(kc, sparse.shape) < drift.crowd_frac
        crowd_ids = ((2 * sizes) // 3).astype(jnp.int32)
        sparse = jnp.where(pick, crowd_ids[None, :], sparse)

    n_tab = len(spec.table_sizes)
    w_dense = _planted(seed, "wd", (spec.dense_dim,))
    a = _planted(seed, "a", (n_tab,))
    c = _planted(seed, "c", (n_tab,)) * 5.0
    score = dense @ w_dense + (jnp.sin(sparse * c) * a).sum(-1)
    noise = spec.noise * jax.random.normal(kl, (batch_size,))
    label = (score + noise > 0).astype(jnp.float32)
    return {"dense": dense, "sparse": sparse, "label": label}


def _planted(seed: int, tag: str, shape):
    # zlib.crc32, NOT hash(): python string hashing is randomized per process
    # (PYTHONHASHSEED), which silently made the planted task non-reproducible
    # across runs (caught by a cross-process loss-ordering flake).
    import zlib
    h = zlib.crc32(f"{seed}:{tag}".encode())
    key = jax.random.PRNGKey(h % (2 ** 31))
    return jax.random.normal(key, shape) / np.sqrt(shape[0])


def read_tsv(path: str, spec: CriteoSpec, batch_size: int, hash_to_size: bool = True):
    """Stream real Criteo TSV rows as model batches (log-transform on dense)."""
    dense_buf, sparse_buf, label_buf = [], [], []
    sizes = np.asarray(spec.table_sizes)
    with open(path) as f:
        for line in f:
            parts = line.rstrip("\n").split("\t")
            label = float(parts[0] or 0)
            dense = [float(x) if x else 0.0 for x in parts[1 : 1 + spec.dense_dim]]
            dense = np.log1p(np.maximum(np.asarray(dense), 0.0))
            cats = [int(x, 16) if x else 0 for x in parts[1 + spec.dense_dim :]]
            cats = np.asarray(cats, np.int64)
            if hash_to_size:
                cats = cats % sizes
            dense_buf.append(dense)
            sparse_buf.append(cats)
            label_buf.append(label)
            if len(label_buf) == batch_size:
                yield {"dense": jnp.asarray(np.stack(dense_buf), jnp.float32),
                       "sparse": jnp.asarray(np.stack(sparse_buf), jnp.int32),
                       "label": jnp.asarray(np.asarray(label_buf), jnp.float32)}
                dense_buf, sparse_buf, label_buf = [], [], []
