"""Sharded, fault-tolerant checkpointing (pure numpy+JSON, no orbax).

Layout per step::

    <dir>/step_000100/
        manifest.json      # tree structure, shapes, dtypes, sha256 per file
        <leaf-path>.npy    # one file per leaf (process-0 gathers, or
                           # per-process addressable shards on multihost)

Fault-tolerance properties:
  * **atomic publish** — written to ``step_X.tmp`` then ``os.replace``d, so
    a crash mid-write never yields a half checkpoint that restore trusts;
  * **integrity** — restore verifies sha256 per leaf and falls back to the
    newest *valid* checkpoint (``restore_latest`` walks backwards);
  * **async** — ``AsyncCheckpointer`` snapshots device arrays to host then
    writes on a background thread (training continues during I/O);
  * **elastic restore** — ``restore(..., shardings=...)`` device_puts each
    leaf with the *target* mesh's NamedSharding, so a checkpoint written on
    one mesh restores onto a different mesh/pod-count (elastic scaling).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading

import jax
import numpy as np

__all__ = ["save", "restore", "restore_latest", "latest_step", "AsyncCheckpointer",
           "available_steps"]


def _leaf_files(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    names = []
    for path, _ in flat:
        parts = []
        for k in path:
            for attr in ("key", "idx", "name"):
                if hasattr(k, attr):
                    parts.append(str(getattr(k, attr)))
                    break
            else:
                parts.append(str(k))
        names.append("_".join(parts).replace("/", "_"))
    # disambiguate duplicates deterministically
    seen: dict[str, int] = {}
    out = []
    for n in names:
        c = seen.get(n, 0)
        seen[n] = c + 1
        out.append(f"{n}__{c}" if c else n)
    return out


def _sha256(path):
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3, extra: dict | None = None):
    """Write checkpoint for ``step``; prunes to the newest ``keep``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = jax.tree.flatten(tree)
    files = _leaf_files(tree)
    manifest = {"step": step, "treedef": str(treedef), "extra": extra or {},
                "leaves": []}
    for leaf, fname in zip(leaves, files):
        arr = np.asarray(jax.device_get(leaf))
        fpath = os.path.join(tmp, fname + ".npy")
        np.save(fpath, arr)
        manifest["leaves"].append({
            "file": fname + ".npy", "shape": list(arr.shape),
            "dtype": str(arr.dtype), "sha256": _sha256(fpath)})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int):
    steps = available_steps(ckpt_dir)
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def available_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = available_steps(ckpt_dir)
    return steps[-1] if steps else None


def _valid(path: str, verify: bool) -> bool:
    mpath = os.path.join(path, "manifest.json")
    if not os.path.exists(mpath):
        return False
    try:
        with open(mpath) as f:
            manifest = json.load(f)
        for entry in manifest["leaves"]:
            fpath = os.path.join(path, entry["file"])
            if not os.path.exists(fpath):
                return False
            if verify and _sha256(fpath) != entry["sha256"]:
                return False
        return True
    except (json.JSONDecodeError, KeyError, OSError):
        return False


def restore(ckpt_dir: str, step: int, like, *, shardings=None, verify: bool = True):
    """Load checkpoint ``step`` into the structure of ``like``.

    ``shardings``: optional pytree (or prefix) of NamedShardings — leaves
    are device_put with them, enabling restore onto a different mesh.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    if not _valid(path, verify):
        raise IOError(f"checkpoint at {path} is missing or corrupt")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves_like, treedef = jax.tree.flatten(like)
    entries = manifest["leaves"]
    if len(entries) != len(leaves_like):
        raise ValueError(f"checkpoint has {len(entries)} leaves, expected {len(leaves_like)}")
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(entries))
    if len(shard_leaves) == 1 and len(entries) > 1:
        shard_leaves = shard_leaves * len(entries)
    out = []
    for entry, like_leaf, shd in zip(entries, leaves_like, shard_leaves):
        arr = np.load(os.path.join(path, entry["file"]))
        want_dtype = getattr(like_leaf, "dtype", arr.dtype)
        arr = arr.astype(want_dtype)
        out.append(jax.device_put(arr, shd) if shd is not None else jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out), manifest


def restore_latest(ckpt_dir: str, like, *, shardings=None, verify: bool = True):
    """Restore the newest checkpoint whose integrity check passes."""
    for step in reversed(available_steps(ckpt_dir)):
        path = os.path.join(ckpt_dir, f"step_{step:08d}")
        if _valid(path, verify):
            tree, manifest = restore(ckpt_dir, step, like, shardings=shardings,
                                     verify=False)
            return step, tree, manifest
    return None, None, None


class AsyncCheckpointer:
    """Snapshot-to-host immediately, persist on a background thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    def save(self, step: int, tree, extra: dict | None = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _run():
            try:
                save(self.ckpt_dir, step, host_tree, keep=self.keep, extra=extra)
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
