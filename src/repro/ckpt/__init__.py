"""Subsystem package."""
