"""Budgeted allocation: Lagrangian-greedy knapsack over candidate ladders.

The planner's optimization problem is a multiple-choice knapsack — pick
exactly one candidate per feature, maximize total quality subject to a
global byte budget.  Exact MCKP is NP-hard; the classic Lagrangian
relaxation is exact on *concave* per-feature frontiers and runs in
``O(F · L log L)``:

1. per feature, reduce the candidate ladder to its **upper convex hull**
   in (bytes, quality) — dominated and non-concave points can never be
   picked by any Lagrange multiplier;
2. start every feature at its cheapest hull point (the all-minimum
   allocation — feasibility floor);
3. repeatedly apply the hull upgrade with the best marginal
   ``dquality/dbyte`` that still fits the remaining budget.

Because hull slopes decrease along each ladder, the greedy sequence is
exactly the sweep of the Lagrange multiplier from +inf down to 0, so the
result matches the relaxed optimum at every budget it passes through —
and, operationally, a larger budget's solution is a superset of a
smaller one's upgrades, which makes total quality **monotone
non-decreasing in budget** (a planner invariant the tests pin).
"""

from __future__ import annotations

import heapq
from typing import Callable, Sequence

from .candidates import Candidate

__all__ = ["concave_frontier", "solve_budget", "InfeasibleBudget"]


class InfeasibleBudget(ValueError):
    """Budget below the sum of every feature's cheapest candidate."""


def concave_frontier(cands: Sequence[Candidate],
                     cost: Callable[[Candidate], int]) -> list[Candidate]:
    """Upper convex hull of (cost, quality), cost strictly increasing."""
    pts = sorted(cands, key=lambda c: (cost(c), -c.quality))
    # drop points not strictly better than a cheaper one (dominated)
    mono: list[Candidate] = []
    for c in pts:
        if mono and cost(c) == cost(mono[-1]):
            continue
        if mono and c.quality <= mono[-1].quality:
            continue
        mono.append(c)
    # Graham-scan style hull: slopes must strictly decrease
    hull: list[Candidate] = []
    for c in mono:
        while len(hull) >= 2:
            a, b = hull[-2], hull[-1]
            s_ab = (b.quality - a.quality) / (cost(b) - cost(a))
            s_bc = (c.quality - b.quality) / (cost(c) - cost(b))
            if s_bc >= s_ab:  # b is under the a--c chord: never optimal
                hull.pop()
            else:
                break
        hull.append(c)
    return hull


def solve_budget(ladders: Sequence[Sequence[Candidate]], budget: int,
                 cost: Callable[[Candidate], int],
                 notes: dict | None = None) -> list[Candidate]:
    """One candidate per feature, total cost <= budget, greedy-optimal
    quality (module docstring).  Raises ``InfeasibleBudget`` if even the
    all-cheapest allocation overshoots.

    ``notes`` (optional dict, filled in place) records what the solve
    silently left on the table — the ROADMAP "no silent caps" rule:

    * ``parked``        — one entry per feature whose next hull upgrade
      did not fit the remaining budget (feature, the upgrade's label,
      extra bytes it needed, quality it would have added);
    * ``hull_dropped``  — ladder candidates not on any hull (dominated,
      non-concave, or an equal-cost duplicate — never pickable);
    * ``leftover_bytes`` — budget minus achieved bytes.
    """
    fronts = [concave_frontier(l, cost) for l in ladders]
    if any(not f for f in fronts):
        raise ValueError("every feature needs at least one candidate")
    chosen = [0] * len(fronts)
    spent = sum(cost(f[0]) for f in fronts)
    if spent > budget:
        raise InfeasibleBudget(
            f"budget {budget} B < floor allocation {spent} B "
            f"(sum of cheapest candidates)")

    def push(heap, fi):
        ci = chosen[fi]
        if ci + 1 < len(fronts[fi]):
            cur, nxt = fronts[fi][ci], fronts[fi][ci + 1]
            dq = nxt.quality - cur.quality
            db = cost(nxt) - cost(cur)
            heapq.heappush(heap, (-dq / db, fi, ci, db))

    heap: list = []
    for fi in range(len(fronts)):
        push(heap, fi)
    parked: list[dict] = []
    # upgrades that momentarily don't fit are parked; a cheaper upgrade
    # elsewhere can't change their cost, but applying others never frees
    # bytes either — so parked entries stay parked (budget only shrinks).
    while heap:
        neg_slope, fi, ci, db = heapq.heappop(heap)
        if chosen[fi] != ci:  # stale entry (already upgraded past it)
            continue
        if spent + db > budget:
            nxt = fronts[fi][ci + 1]
            parked.append({"feature": nxt.feature, "upgrade": nxt.label,
                           "extra_bytes": int(db),
                           "dquality": nxt.quality - fronts[fi][ci].quality})
            continue  # park: this feature is done at this budget
        chosen[fi] = ci + 1
        spent += db
        push(heap, fi)
    if notes is not None:
        notes["parked"] = sorted(parked, key=lambda p: p["feature"])
        notes["hull_dropped"] = sum(
            len(l) - len(f) for l, f in zip(ladders, fronts))
        notes["leftover_bytes"] = int(budget - spent)
    return [f[c] for f, c in zip(fronts, chosen)]
