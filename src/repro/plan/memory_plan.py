"""The serializable ``MemoryPlan`` artifact — one machine-generated answer
to "where do the embedding bytes go".

A plan is a list of per-feature table choices plus the bookkeeping that
makes it auditable: budget and domain it was solved under, achieved
bytes, proxy quality vs the uniform-hashing baseline, and per-table
diagnostics (partition row counts, bucket entropies, complementarity).
It is a plain JSON file under ``artifacts/plans/`` so training, serving,
and benches all consume the identical decision.

Executability contract: ``spec_for(feature)`` returns the exact
``EmbeddingSpec`` the factory builds from — ``core.factory.make_embedding``
accepts a plan directly (the from-plan path), and the round-trip
plan → JSON → ``make_embedding`` → ``num_params`` is byte-stable (tested).
"""

from __future__ import annotations

import dataclasses
import json
import os

from ..core.factory import EmbeddingSpec

__all__ = ["TablePlan", "MemoryPlan", "PLAN_DIR", "plan_path"]

PLAN_DIR = os.path.join("artifacts", "plans")
SCHEMA_VERSION = 1


def plan_path(arch: str, budget_bytes: int, base: str = PLAN_DIR) -> str:
    mb = budget_bytes / 2 ** 20
    return os.path.join(base, f"{arch}_{mb:g}mb.json")


@dataclasses.dataclass(frozen=True)
class TablePlan:
    """The chosen configuration of one categorical feature's table."""

    feature: int
    num_categories: int
    kind: str                       # full | hash | qr | mixed_radix
    num_collisions: int = 4
    ms: tuple[int, ...] = ()
    op: str = "mult"
    rows: int = 0
    train_bytes: int = 0
    serve_bytes_int8: int = 0
    quality: float = 1.0
    entropies: tuple[float, ...] = ()
    complementary: bool | None = None   # None: by-theorem, not brute-checked
    dim: int = 0                    # table width; 0 = the plan's emb_dim

    def spec(self) -> EmbeddingSpec:
        return EmbeddingSpec(kind=self.kind, num_collisions=self.num_collisions,
                             ms=tuple(self.ms), op=self.op)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["ms"] = list(self.ms)
        d["entropies"] = list(self.entropies)
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "TablePlan":
        d = dict(d)
        d["ms"] = tuple(d.get("ms", ()))
        d["entropies"] = tuple(d.get("entropies", ()))
        return cls(**d)


@dataclasses.dataclass
class MemoryPlan:
    """A solved byte allocation across every categorical feature."""

    arch: str
    emb_dim: int
    budget_bytes: int
    bytes_domain: str               # train_f32 | serve_int8
    total_bytes: int                # achieved, in the budget domain
    full_bytes: int                 # the all-full-table cost, same domain
    quality: float                  # mean per-feature proxy quality
    baseline_quality: float         # uniform hashing at the same budget
    tables: list[TablePlan] = dataclasses.field(default_factory=list)
    # solver bookkeeping (parked upgrades, hull drops, leftover bytes —
    # the "no silent caps" audit trail); free-form JSON-safe dict.
    notes: dict = dataclasses.field(default_factory=dict)

    # models ask ``cfg.embedding.kind`` to detect feature-generation mode;
    # a plan is never that, so it reports its own kind.
    @property
    def kind(self) -> str:
        return "plan"

    @property
    def table_sizes(self) -> tuple[int, ...]:
        return tuple(t.num_categories for t in self.tables)

    def spec_for(self, feature: int, num_categories: int | None = None,
                 dim: int | None = None) -> EmbeddingSpec:
        """The per-feature EmbeddingSpec — the factory's from-plan hook.

        Validates that the caller's geometry matches what the plan was
        solved for; a silent mismatch would build a model the planner
        never scored.
        """
        if not 0 <= feature < len(self.tables):
            raise ValueError(f"plan for {self.arch!r} has "
                             f"{len(self.tables)} tables, no feature {feature}")
        t = self.tables[feature]
        if num_categories is not None and num_categories != t.num_categories:
            raise ValueError(
                f"plan table {feature} was solved for {t.num_categories} "
                f"categories, model has {num_categories} — regenerate the plan")
        if dim is not None and dim != self.emb_dim:
            raise ValueError(f"plan was solved at emb_dim={self.emb_dim}, "
                             f"model uses {dim} — regenerate the plan")
        return t.spec()

    def dim_for(self, feature: int) -> int:
        """The planned table width of ``feature`` — ``emb_dim`` unless the
        planner chose a reduced (mixed-dimension) width.  The factory
        builds the table at this width; the models project back to
        ``emb_dim`` for the interaction."""
        if not 0 <= feature < len(self.tables):
            raise ValueError(f"plan for {self.arch!r} has "
                             f"{len(self.tables)} tables, no feature {feature}")
        return self.tables[feature].dim or self.emb_dim

    @property
    def table_dims(self) -> tuple[int, ...]:
        return tuple(self.dim_for(i) for i in range(len(self.tables)))

    def validate_sizes(self, table_sizes) -> None:
        if tuple(table_sizes) != self.table_sizes:
            raise ValueError(
                f"plan table sizes {self.table_sizes} do not match the "
                f"config's {tuple(table_sizes)} — regenerate the plan")

    def summary(self) -> dict:
        kinds: dict[str, int] = {}
        dims: dict[int, int] = {}
        for t in self.tables:
            kinds[t.kind] = kinds.get(t.kind, 0) + 1
            d = t.dim or self.emb_dim
            dims[d] = dims.get(d, 0) + 1
        return {"arch": self.arch, "emb_dim": self.emb_dim,
                "bytes_domain": self.bytes_domain,
                "budget_bytes": self.budget_bytes,
                "total_bytes": self.total_bytes,
                "budget_frac_of_full": self.total_bytes / self.full_bytes
                if self.full_bytes else 0.0,
                "quality": self.quality,
                "baseline_quality": self.baseline_quality,
                "kinds": kinds, "dims": {str(k): v for k, v
                                         in sorted(dims.items())},
                "parked": len(self.notes.get("parked", []))}

    def annotate_placement(self, placement) -> None:
        """Record a serving placement (``dist.serve_placement.
        ServePlacement``) in the plan's notes so the JSON artifact carries
        where each sub-table lives on the serving mesh — round-trips
        through ``to_json``/``from_json`` like every note."""
        self.notes["serve_placement"] = placement.as_dict()

    def serve_placement(self):
        """The annotated serving placement, or ``None``."""
        d = self.notes.get("serve_placement")
        if d is None:
            return None
        from ..dist.serve_placement import ServePlacement
        return ServePlacement.from_dict(d)

    def to_json(self) -> str:
        return json.dumps(
            {"schema": SCHEMA_VERSION, "arch": self.arch,
             "emb_dim": self.emb_dim, "budget_bytes": self.budget_bytes,
             "bytes_domain": self.bytes_domain,
             "total_bytes": self.total_bytes, "full_bytes": self.full_bytes,
             "quality": self.quality,
             "baseline_quality": self.baseline_quality,
             "notes": self.notes,
             "tables": [t.as_dict() for t in self.tables]}, indent=1)

    @classmethod
    def from_json(cls, text: str) -> "MemoryPlan":
        d = json.loads(text)
        schema = d.pop("schema", SCHEMA_VERSION)
        if schema != SCHEMA_VERSION:
            raise ValueError(f"unsupported plan schema {schema}")
        tables = [TablePlan.from_dict(t) for t in d.pop("tables")]
        return cls(tables=tables, notes=d.pop("notes", {}), **d)

    def save(self, path: str) -> str:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            f.write(self.to_json())
        return path

    @classmethod
    def load(cls, path: str) -> "MemoryPlan":
        with open(path) as f:
            return cls.from_json(f.read())
