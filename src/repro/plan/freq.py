"""Per-feature category-frequency statistics — the planner's input signal.

The paper's compression choices only pay off because category traffic is
heavily skewed (Zipfian Criteo features): a table whose traffic
concentrates on a few categories tolerates aggressive hashing, while a
flat high-cardinality feature needs its bytes.  ``FeatureStats`` captures
that skew as an *empirical* distribution over the observed support:

* ``ids``   — unique category ids seen in the stream (sorted int64);
* ``probs`` — their empirical probabilities (sums to 1 over the support).

Unobserved categories carry zero empirical mass, so the frequency-weighted
quality proxy (``plan.quality``) is exact for the measured traffic and
simply ignores never-seen rows — the same rows a serving cache never
touches.

Constructors cover the two sourcing modes the planner supports:

* ``stats_from_batches`` / ``stats_from_criteo`` — streamed from real
  batches (the synthetic Criteo generator in this repo, a TSV reader in
  production);
* ``power_law_stats`` — closed-form Zipf(alpha) support for tests and
  quick synthesis, no data pass needed.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

__all__ = ["FeatureStats", "stats_from_batches", "stats_from_criteo",
           "power_law_stats"]


@dataclasses.dataclass(frozen=True)
class FeatureStats:
    """Empirical category distribution of one categorical feature."""

    size: int            # cardinality |S| of the feature
    ids: np.ndarray      # (u,) unique observed category ids, sorted
    probs: np.ndarray    # (u,) empirical probabilities, sum == 1

    def __post_init__(self):
        if len(self.ids) != len(self.probs):
            raise ValueError("ids and probs must be parallel arrays")
        if len(self.ids) and int(self.ids.max()) >= self.size:
            raise ValueError(f"observed id {int(self.ids.max())} >= size {self.size}")

    @property
    def support(self) -> int:
        return len(self.ids)

    @property
    def top_mass(self) -> float:
        """Traffic share of the single hottest category (skew headline)."""
        return float(self.probs.max()) if len(self.probs) else 0.0

    def as_dict(self) -> dict:
        return {"size": self.size, "support": self.support,
                "top_mass": self.top_mass}


def _stats_from_counts(size: int, counts: dict[int, int]) -> FeatureStats:
    ids = np.asarray(sorted(counts), np.int64)
    c = np.asarray([counts[i] for i in ids], np.float64)
    total = c.sum()
    probs = c / total if total else c
    return FeatureStats(size=size, ids=ids, probs=probs)


def stats_from_batches(batches: Iterable, table_sizes: Sequence[int],
                       key: str = "sparse") -> list[FeatureStats]:
    """Accumulate per-feature histograms from a stream of training batches.

    ``batches`` yields dicts with an int id array under ``key`` of shape
    ``(B, F)`` one-hot or ``(B, F, L)`` multi-hot (negative ids are treated
    as padding and skipped).  One pass, O(unique ids) memory per feature.
    """
    sizes = list(table_sizes)
    counts: list[dict[int, int]] = [{} for _ in sizes]
    for batch in batches:
        arr = np.asarray(batch[key] if isinstance(batch, dict) else batch)
        if arr.ndim == 2:
            arr = arr[..., None]
        if arr.shape[1] != len(sizes):
            raise ValueError(f"batch has {arr.shape[1]} features, "
                             f"expected {len(sizes)}")
        for f in range(len(sizes)):
            ids, n = np.unique(arr[:, f, :].reshape(-1), return_counts=True)
            keep = ids >= 0
            for i, c in zip(ids[keep], n[keep]):
                counts[f][int(i)] = counts[f].get(int(i), 0) + int(c)
    return [_stats_from_counts(s, c) for s, c in zip(sizes, counts)]


def stats_from_criteo(spec, num_batches: int = 32, batch_size: int = 512,
                      seed: int = 0) -> list[FeatureStats]:
    """Stream the synthetic Criteo generator (``data.criteo.batch_at``) —
    the same distribution training consumes, so the plan optimizes the
    traffic the model will actually see."""
    from ..data.criteo import batch_at
    return stats_from_batches(
        (batch_at(seed, step, batch_size, spec) for step in range(num_batches)),
        spec.table_sizes)


def power_law_stats(size: int, alpha: float = 1.2,
                    max_support: int = 100_000) -> FeatureStats:
    """Closed-form Zipf(alpha) stats: ``p_i ∝ (i+1)^-alpha`` over the first
    ``min(size, max_support)`` categories (the tail past ``max_support``
    carries negligible mass for alpha > 1; tests use this for speed)."""
    u = min(size, max_support)
    ids = np.arange(u, dtype=np.int64)
    probs = (ids + 1.0) ** (-alpha)
    probs /= probs.sum()
    return FeatureStats(size=size, ids=ids, probs=probs)
