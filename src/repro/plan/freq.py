"""Per-feature category-frequency statistics — the planner's input signal.

The paper's compression choices only pay off because category traffic is
heavily skewed (Zipfian Criteo features): a table whose traffic
concentrates on a few categories tolerates aggressive hashing, while a
flat high-cardinality feature needs its bytes.  ``FeatureStats`` captures
that skew as an *empirical* distribution over the observed support:

* ``ids``   — unique category ids seen in the stream (sorted int64);
* ``probs`` — their empirical probabilities (sums to 1 over the support).

Unobserved categories carry zero empirical mass, so the frequency-weighted
quality proxy (``plan.quality``) is exact for the measured traffic and
simply ignores never-seen rows — the same rows a serving cache never
touches.

Constructors cover the two sourcing modes the planner supports:

* ``stats_from_batches`` / ``stats_from_criteo`` — streamed from real
  batches (the synthetic Criteo generator in this repo, a TSV reader in
  production);
* ``power_law_stats`` — closed-form Zipf(alpha) support for tests and
  quick synthesis, no data pass needed.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

import numpy as np

__all__ = ["FeatureStats", "stats_from_batches", "stats_from_criteo",
           "power_law_stats", "merge_stats", "StreamingStats"]


@dataclasses.dataclass(frozen=True)
class FeatureStats:
    """Empirical category distribution of one categorical feature."""

    size: int            # cardinality |S| of the feature
    ids: np.ndarray      # (u,) unique observed category ids, sorted
    probs: np.ndarray    # (u,) empirical probabilities, sum == 1

    def __post_init__(self):
        if len(self.ids) != len(self.probs):
            raise ValueError("ids and probs must be parallel arrays")
        if len(self.ids) and int(self.ids.max()) >= self.size:
            raise ValueError(f"observed id {int(self.ids.max())} >= size {self.size}")

    @property
    def support(self) -> int:
        return len(self.ids)

    @property
    def top_mass(self) -> float:
        """Traffic share of the single hottest category (skew headline)."""
        return float(self.probs.max()) if len(self.probs) else 0.0

    def as_dict(self) -> dict:
        return {"size": self.size, "support": self.support,
                "top_mass": self.top_mass}


def _stats_from_counts(size: int, counts: dict[int, int]) -> FeatureStats:
    ids = np.asarray(sorted(counts), np.int64)
    c = np.asarray([counts[i] for i in ids], np.float64)
    total = c.sum()
    probs = c / total if total else c
    return FeatureStats(size=size, ids=ids, probs=probs)


def stats_from_batches(batches: Iterable, table_sizes: Sequence[int],
                       key: str = "sparse") -> list[FeatureStats]:
    """Accumulate per-feature histograms from a stream of training batches.

    ``batches`` yields dicts with an int id array under ``key`` of shape
    ``(B, F)`` one-hot or ``(B, F, L)`` multi-hot (negative ids are treated
    as padding and skipped).  One pass, O(unique ids) memory per feature.
    """
    sizes = list(table_sizes)
    counts: list[dict[int, int]] = [{} for _ in sizes]
    for batch in batches:
        arr = np.asarray(batch[key] if isinstance(batch, dict) else batch)
        if arr.ndim == 2:
            arr = arr[..., None]
        if arr.shape[1] != len(sizes):
            raise ValueError(f"batch has {arr.shape[1]} features, "
                             f"expected {len(sizes)}")
        for f in range(len(sizes)):
            ids, n = np.unique(arr[:, f, :].reshape(-1), return_counts=True)
            keep = ids >= 0
            for i, c in zip(ids[keep], n[keep]):
                counts[f][int(i)] = counts[f].get(int(i), 0) + int(c)
    return [_stats_from_counts(s, c) for s, c in zip(sizes, counts)]


def stats_from_criteo(spec, num_batches: int = 32, batch_size: int = 512,
                      seed: int = 0) -> list[FeatureStats]:
    """Stream the synthetic Criteo generator (``data.criteo.batch_at``) —
    the same distribution training consumes, so the plan optimizes the
    traffic the model will actually see."""
    from ..data.criteo import batch_at
    return stats_from_batches(
        (batch_at(seed, step, batch_size, spec) for step in range(num_batches)),
        spec.table_sizes)


def merge_stats(a: FeatureStats, b: FeatureStats,
                weight_a: float = 1.0, weight_b: float = 1.0) -> FeatureStats:
    """Weighted union-support merge of two empirical distributions.

    The result's probability for id ``i`` is
    ``(weight_a * p_a(i) + weight_b * p_b(i)) / (weight_a + weight_b)``
    (treating absent ids as zero mass), so merging a window of ``n_a``
    lookups with one of ``n_b`` lookups under ``weight=lookups`` is exactly
    the pooled empirical distribution.  Exponential decay is the same
    operation with a down-weighted left side (``StreamingStats``).
    """
    if a.size != b.size:
        raise ValueError(f"size mismatch: {a.size} vs {b.size}")
    if weight_a < 0 or weight_b < 0:
        raise ValueError("weights must be >= 0")
    wa = weight_a if len(a.ids) else 0.0
    wb = weight_b if len(b.ids) else 0.0
    total = wa + wb
    if total == 0:
        return FeatureStats(size=a.size, ids=np.empty(0, np.int64),
                            probs=np.empty(0, np.float64))
    ids = np.union1d(a.ids, b.ids)
    probs = np.zeros(len(ids), np.float64)
    if wa:
        probs[np.searchsorted(ids, a.ids)] += wa * np.asarray(a.probs)
    if wb:
        probs[np.searchsorted(ids, b.ids)] += wb * np.asarray(b.probs)
    return FeatureStats(size=a.size, ids=ids, probs=probs / total)


class StreamingStats:
    """Per-feature decayed frequency accumulator over live batches.

    The online re-planning loop needs two views of traffic: a *short*
    window (the drift detector's, reset every check) and a *long* decayed
    history to re-solve the plan from — a re-solve on one noisy window
    would thrash.  This class is the long view: each ``update`` first
    multiplies every accumulated weight by ``decay`` and then adds the
    new observation counts, so a category's weight is a geometric sum
    over its appearance history and dead categories fade out instead of
    pinning bytes forever.

    ``decay=1.0`` accumulates exactly like ``stats_from_batches`` (tested
    equal).  ``max_support`` (optional) bounds per-feature memory by
    dropping the lowest-weight ids after each update — drops are counted
    in ``pruned`` per feature, never silent.
    """

    def __init__(self, table_sizes: Sequence[int], decay: float = 1.0,
                 max_support: int | None = None):
        if not 0.0 < decay <= 1.0:
            raise ValueError(f"decay={decay} must be in (0, 1]")
        self.table_sizes = tuple(int(s) for s in table_sizes)
        self.decay = float(decay)
        self.max_support = max_support
        self._ids = [np.empty(0, np.int64) for _ in self.table_sizes]
        self._weights = [np.empty(0, np.float64) for _ in self.table_sizes]
        self.pruned = [0] * len(self.table_sizes)
        self.updates = 0

    def _merge_feature(self, f: int, ids: np.ndarray, w: np.ndarray) -> None:
        cat_ids = np.concatenate([self._ids[f], ids])
        cat_w = np.concatenate([self._weights[f] * self.decay, w])
        uniq, inv = np.unique(cat_ids, return_inverse=True)
        weights = np.bincount(inv, weights=cat_w)
        if self.max_support is not None and len(uniq) > self.max_support:
            keep = np.sort(np.argsort(weights)[-self.max_support:])
            self.pruned[f] += len(uniq) - self.max_support
            uniq, weights = uniq[keep], weights[keep]
        self._ids[f], self._weights[f] = uniq, weights

    def update(self, batch, key: str = "sparse") -> None:
        """Fold one training batch (``(B, F)`` or ``(B, F, L)`` id array,
        negatives = padding) into the decayed history.  One decay step per
        call, applied to every feature."""
        arr = np.asarray(batch[key] if isinstance(batch, dict) else batch)
        if arr.ndim == 2:
            arr = arr[..., None]
        if arr.shape[1] != len(self.table_sizes):
            raise ValueError(f"batch has {arr.shape[1]} features, "
                             f"expected {len(self.table_sizes)}")
        self.updates += 1
        for f in range(len(self.table_sizes)):
            ids, counts = np.unique(arr[:, f, :].reshape(-1),
                                    return_counts=True)
            keep = ids >= 0
            self._merge_feature(f, ids[keep].astype(np.int64),
                                counts[keep].astype(np.float64))

    def update_stats(self, window: Sequence[FeatureStats],
                     lookups: Sequence[int]) -> None:
        """Fold one telemetry window (per-feature ``FeatureStats`` + their
        lookup counts, e.g. ``CollisionTelemetry.all_observed_stats()``)
        into the history — the serving-side twin of ``update``."""
        if len(window) != len(self.table_sizes):
            raise ValueError("window has wrong feature count")
        self.updates += 1
        for f, st in enumerate(window):
            w = float(lookups[f]) * np.asarray(st.probs, np.float64)
            self._merge_feature(f, np.asarray(st.ids, np.int64), w)

    def snapshot(self, feature: int) -> FeatureStats:
        w = self._weights[feature]
        total = w.sum()
        probs = w / total if total else w.copy()
        return FeatureStats(size=self.table_sizes[feature],
                            ids=self._ids[feature].copy(), probs=probs)

    def all_stats(self) -> list[FeatureStats]:
        """Per-feature ``FeatureStats`` of the decayed history — feed to
        ``build_plan`` for the drift-triggered re-solve."""
        return [self.snapshot(f) for f in range(len(self.table_sizes))]


def power_law_stats(size: int, alpha: float = 1.2,
                    max_support: int = 100_000) -> FeatureStats:
    """Closed-form Zipf(alpha) stats: ``p_i ∝ (i+1)^-alpha`` over the first
    ``min(size, max_support)`` categories (the tail past ``max_support``
    carries negligible mass for alpha > 1; tests use this for speed)."""
    u = min(size, max_support)
    ids = np.arange(u, dtype=np.int64)
    probs = (ids + 1.0) ** (-alpha)
    probs /= probs.sum()
    return FeatureStats(size=size, ids=ids, probs=probs)
