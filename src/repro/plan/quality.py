"""Analytic quality proxy for embedding-table configurations.

One unified score covers every candidate family the planner enumerates,
built from the *frequency-weighted row-sharing* of each partition:

For a partition ``P_j`` with bucket masses ``M_b = sum_{i in b} p_i``, a
category ``i`` shares its table row with foreign traffic mass

    sigma_j(i) = M_{b_j(i)} - p_i .

The proxy **loss** of a configuration with partitions ``P_1..P_k`` is the
expected product of sharings under the traffic distribution:

    L = sum_i p_i * prod_j sigma_j(i)          quality = 1 - L in [0, 1].

Why this shape:

* **hashing** (single remainder partition, k=1) reduces to the expected
  frequency-weighted *collision mass* ``sum_b M_b^2 - sum_i p_i^2`` — the
  probability that a second frequency-weighted draw lands on the same
  (shared, hence corrupted) row;
* a **full table** has singleton buckets, sigma = 0 everywhere, quality 1;
* a **complementary compositional** family (QR, mixed radix) never fully
  collides — code tuples are injective (``partitions.is_complementary``)
  — so its residual degradation is the chance that *every* component row
  of a category is also serving foreign traffic: the product above.  More
  partitions or bigger tables shrink it multiplicatively, matching the
  paper's observed full > QR > hashing quality ordering at equal bytes.

``partition_diagnostics`` additionally reports per-partition normalized
bucket entropy (how evenly traffic spreads over a table's rows — low
entropy means the table wastes rows on cold buckets) and the
code-uniqueness flag from ``is_complementary``; the bench and the plan
JSON carry both.

**Dim-aware scoring** (``dim_proxy_quality``): embedding *width* is the
planner's second axis (Mixed Dimension Embeddings, Ginart et al. 2019 —
the complement to the paper's row reduction).  Two effects, both concave
in width:

* **capacity** — a feature with traffic perplexity ``exp(H)`` needs
  roughly ``log2(1+exp(H)) / BITS_PER_DIM`` dims to keep its effective
  categories apart; width below that required dim discounts quality by
  ``(dim/d_req)^DIM_BETA`` (skewed features have tiny perplexity, so
  they shed width for free — the mixed-dim literature's core claim);
* **sharing amplification** — a *shared* row that is also narrow has
  less spare capacity to encode both traffics apart, so the sharing
  loss is amplified by ``(full_dim/dim)^DIM_ALPHA``.

At ``dim == full_dim`` both factors are exactly 1 and the score reduces
to ``proxy_quality`` — uniform-width plans are byte-identical to the
pre-dim planner.  The exponents are calibrated against the plan-bench
budget sweep (``fit_width_exponent`` refits ``DIM_BETA`` from measured
(width, quality) pairs when real sweep data is available).
"""

from __future__ import annotations

import math
from typing import Sequence

import numpy as np

from ..core.compositional import (CompositionalEmbedding, FullEmbedding,
                                  HashEmbedding)
from ..core.partitions import (Partition, RemainderPartition, is_complementary,
                               naive_partition)
from .freq import FeatureStats

__all__ = ["module_partitions", "sharing", "proxy_loss", "proxy_quality",
           "partition_entropy", "partition_diagnostics",
           "complementary_flag", "COMPLEMENTARY_CHECK_MAX",
           "required_dim", "width_factor", "dim_proxy_loss",
           "dim_proxy_quality", "fit_width_exponent", "fit_collision_scale",
           "DIM_ALPHA", "DIM_BETA", "BITS_PER_DIM"]

# is_complementary is a brute-force O(size) scan; above this we trust the
# constructors' by-theorem guarantee (paper appendix) instead of checking.
COMPLEMENTARY_CHECK_MAX = 200_000

# Width-model exponents (module docstring): DIM_ALPHA amplifies the
# sharing loss of narrow rows, DIM_BETA discounts capacity below the
# required dim, BITS_PER_DIM converts traffic perplexity to a required
# width.  Calibrated against the plan_bench budget sweep; refit DIM_BETA
# with ``fit_width_exponent`` when measured (width, quality) data exists.
DIM_ALPHA = 0.5
DIM_BETA = 0.5
BITS_PER_DIM = 1.6


def module_partitions(module) -> tuple[Partition, ...]:
    """The partition family an embedding module realizes — the factory's
    modules are the ground truth, so planner scores and built models can
    never disagree about structure."""
    if isinstance(module, CompositionalEmbedding):
        return tuple(module.partitions)
    if isinstance(module, HashEmbedding):
        return (RemainderPartition(size=module.num_categories,
                                   num_buckets=module.m, m=module.m),)
    if isinstance(module, FullEmbedding):
        return tuple(naive_partition(module.num_categories))
    # path-based etc.: fall back to declared partitions if present
    parts = getattr(module, "partitions", None)
    if parts:
        return tuple(parts)
    raise TypeError(f"no partition view for module {type(module).__name__}")


def _buckets(partition: Partition, ids: np.ndarray) -> np.ndarray:
    return np.asarray(partition.bucket(ids)).astype(np.int64)


def sharing(partition: Partition, stats: FeatureStats) -> np.ndarray:
    """sigma_j(i) per observed id: foreign traffic mass on i's bucket.

    Uses unique+inverse instead of a dense ``num_buckets`` bincount so a
    10M-row full table costs O(support), not O(rows).
    """
    if not len(stats.ids):
        return np.zeros(0, np.float64)
    b = _buckets(partition, stats.ids)
    uniq, inv = np.unique(b, return_inverse=True)
    loads = np.bincount(inv, weights=stats.probs)
    return np.maximum(loads[inv] - stats.probs, 0.0)


def proxy_loss(partitions: Sequence[Partition], stats: FeatureStats) -> float:
    """Expected product-of-sharings (module docstring) — in [0, 1]."""
    if not len(stats.ids):
        return 0.0
    sig = np.ones(len(stats.ids), np.float64)
    for p in partitions:
        sig *= sharing(p, stats)
        if not sig.any():
            return 0.0
    return float(np.clip((stats.probs * sig).sum(), 0.0, 1.0))


def proxy_quality(partitions: Sequence[Partition], stats: FeatureStats) -> float:
    return 1.0 - proxy_loss(partitions, stats)


def required_dim(stats: FeatureStats) -> float:
    """Width a feature needs before capacity stops binding:
    ``log2(1 + exp(H)) / BITS_PER_DIM`` where ``exp(H)`` is the traffic
    perplexity (effective category count).  A near-deterministic feature
    (perplexity ~1) needs ~1 dim; a flat 2k-effective-category feature
    needs the full deployment width."""
    if not len(stats.ids):
        return 1.0
    p = stats.probs[stats.probs > 0]
    perp = math.exp(float(-(p * np.log(p)).sum()))
    return max(1.0, math.log2(1.0 + perp) / BITS_PER_DIM)


def width_factor(dim: int, full_dim: int, stats: FeatureStats,
                 beta: float = DIM_BETA) -> float:
    """Concave capacity discount in [0, 1]: ``(dim/d_req)^beta`` below the
    required dim, exactly 1 at ``dim >= min(full_dim, required_dim)`` —
    so full-width candidates always score as the dim-unaware proxy."""
    d_req = min(float(full_dim), required_dim(stats))
    return min(1.0, float(dim) / d_req) ** beta


def dim_proxy_loss(partitions: Sequence[Partition], stats: FeatureStats,
                   dim: int, full_dim: int,
                   alpha: float = DIM_ALPHA) -> float:
    """Sharing loss amplified by ``(full_dim/dim)^alpha``: a narrow shared
    row has less spare capacity to keep its foreign traffic apart."""
    amp = (float(full_dim) / float(dim)) ** alpha
    return min(1.0, proxy_loss(partitions, stats) * amp)


def dim_proxy_quality(partitions: Sequence[Partition], stats: FeatureStats,
                      dim: int, full_dim: int) -> float:
    """Dim-aware quality (module docstring) — equals ``proxy_quality``
    exactly at ``dim == full_dim``."""
    return width_factor(dim, full_dim, stats) * (
        1.0 - dim_proxy_loss(partitions, stats, dim, full_dim))


def fit_width_exponent(samples: Sequence[tuple[float, float]]) -> float:
    """Least-squares fit of the concave width exponent from measured
    ``(width_ratio, quality_ratio)`` pairs (quality at reduced width over
    quality at full width, both in (0, 1]): the ``beta`` minimizing
    ``sum (log q - beta * log r)^2``.  This is the calibration hook the
    module docstring promises — feed it the serve/plan sweep's measured
    deltas to recalibrate ``DIM_BETA``."""
    num = den = 0.0
    for r, q in samples:
        if not (0.0 < r <= 1.0 and 0.0 < q <= 1.0):
            raise ValueError(f"ratios must be in (0, 1], got {(r, q)}")
        if r == 1.0:
            continue  # no width reduction: carries no exponent signal
        lr, lq = math.log(r), math.log(q)
        num += lr * lq
        den += lr * lr
    if den == 0.0:
        raise ValueError("need at least one sample with width_ratio < 1")
    return num / den


def fit_collision_scale(samples: Sequence[tuple[float, float]]) -> float:
    """Calibrate the analytic collision proxy against measured masses.

    ``samples`` are per-feature ``(predicted, measured)`` collision-mass
    pairs — exactly the columns ``BENCH_obs.json`` pins (``predicted_
    collision_mass`` from plan-time stats, ``measured_collision_mass``
    from served traffic).  Returns the scale ``k`` minimizing
    ``sum (measured - k * predicted)^2`` (through the origin: both
    quantities vanish together on a collision-free table), i.e.
    ``k = sum(p*m) / sum(p^2)``.  ``k == 1`` means the proxy is
    calibrated; the drift detector multiplies its predicted baseline by
    ``k`` so a systematic proxy bias is not mistaken for drift.

    Pairs with ``predicted == 0`` carry no scale signal and are skipped —
    a zero-predicted feature with nonzero measured mass is *drift*, not
    miscalibration, and is the detector's job.  Raises when no pair has
    ``predicted > 0`` (the width-axis twin ``fit_width_exponent`` follows
    the same no-signal contract).
    """
    num = den = 0.0
    for p, m in samples:
        if p < 0.0 or m < 0.0:
            raise ValueError(f"collision masses must be >= 0, got {(p, m)}")
        if p == 0.0:
            continue
        num += p * m
        den += p * p
    if den == 0.0:
        raise ValueError("need at least one sample with predicted mass > 0")
    return num / den


def partition_entropy(partition: Partition, stats: FeatureStats) -> float:
    """Normalized frequency-weighted bucket entropy H(M)/log(num_buckets):
    1.0 = traffic spread evenly over the rows, 0.0 = one bucket soaks up
    everything (rows mostly wasted)."""
    if partition.num_buckets <= 1 or not len(stats.ids):
        return 1.0
    b = _buckets(partition, stats.ids)
    uniq, inv = np.unique(b, return_inverse=True)
    loads = np.bincount(inv, weights=stats.probs)
    loads = loads[loads > 0]
    h = float(-(loads * np.log(loads)).sum())
    return min(1.0, h / math.log(partition.num_buckets))


def complementary_flag(partitions: Sequence[Partition],
                       size: int) -> bool | None:
    """Code-uniqueness flag without needless brute force: a lone partition
    decides by pigeonhole (injective iff it has a bucket per category —
    our single-partition modules are identity/mod maps), otherwise the
    O(size) ``is_complementary`` scan runs up to the cap; above it the
    constructors' by-theorem guarantee stands (``None``)."""
    if len(partitions) == 1:
        return partitions[0].num_buckets >= size
    if size <= COMPLEMENTARY_CHECK_MAX:
        return bool(is_complementary(partitions, size))
    return None


def partition_diagnostics(partitions: Sequence[Partition],
                          stats: FeatureStats) -> dict:
    """Per-family diagnostics carried into the plan JSON: entropies, the
    code-uniqueness (complementarity) flag, and the scalar proxy."""
    return {
        "entropies": [round(partition_entropy(p, stats), 6)
                      for p in partitions],
        "complementary": complementary_flag(partitions, stats.size),
        "quality": proxy_quality(partitions, stats),
    }
