"""Top-level planning entry points: stats → candidates → solver → MemoryPlan.

``build_plan`` is the pure core (explicit stats in, plan out);
``plan_for_config`` is the convenience wrapper training/serving/benches
call — it streams frequency stats from the synthetic Criteo generator at
the config's table sizes and solves for a byte budget.

``uniform_hash_plan`` is the control arm: one global compression factor,
every table hashed by the same ratio — the strongest *non-adaptive*
baseline at a given budget, and the bar ``plan_bench`` requires the
planner to beat at every swept budget.  ``build_plan`` scores its own
copy of that baseline for the plan's ``baseline_quality`` field; pass
``baseline=`` (an already-solved uniform plan for the same stats/budget)
to skip recomputing it.
"""

from __future__ import annotations

from typing import Sequence

from ..core.factory import EmbeddingSpec
from .candidates import (Candidate, bytes_per_row, candidate_for,
                         enumerate_candidates)
from .freq import FeatureStats, stats_from_criteo
from .memory_plan import MemoryPlan, TablePlan
from .quality import (complementary_flag, module_partitions,
                      partition_entropy)
from .solver import InfeasibleBudget, solve_budget

__all__ = ["build_plan", "uniform_hash_plan", "plan_for_config",
           "full_table_bytes"]


def full_table_bytes(table_sizes: Sequence[int], dim: int,
                     domain: str = "train_f32") -> int:
    """The all-full-table cost — budgets are usually fractions of this."""
    return sum(table_sizes) * bytes_per_row(dim, domain)


def _table_plan(cand: Candidate, stats: FeatureStats, dim: int) -> TablePlan:
    # the candidate already carries cost and quality from the factory-built
    # module; only the per-partition diagnostics remain to compute
    from ..core.factory import make_embedding
    width = cand.dim or dim
    parts = module_partitions(make_embedding(cand.num_categories, width,
                                             cand.spec))
    s = cand.spec
    return TablePlan(
        feature=cand.feature, num_categories=cand.num_categories,
        kind=s.kind, num_collisions=s.num_collisions, ms=tuple(s.ms), op=s.op,
        rows=cand.rows, train_bytes=cand.train_bytes,
        serve_bytes_int8=cand.serve_bytes_int8,
        quality=cand.quality,
        entropies=tuple(round(partition_entropy(p, stats), 6) for p in parts),
        complementary=complementary_flag(parts, cand.num_categories),
        dim=width)


def _mean_quality(tables) -> float:
    return sum(t.quality for t in tables) / max(1, len(tables))


def _as_memory_plan(chosen: Sequence[Candidate], stats, dim, budget_bytes,
                    arch, bytes_domain, baseline_quality,
                    notes: dict | None = None) -> MemoryPlan:
    tables = [_table_plan(c, st, dim) for c, st in zip(chosen, stats)]
    total = sum(c.bytes(bytes_domain) for c in chosen)
    return MemoryPlan(
        arch=arch, emb_dim=dim, budget_bytes=int(budget_bytes),
        bytes_domain=bytes_domain, total_bytes=int(total),
        full_bytes=full_table_bytes([s.size for s in stats], dim,
                                    bytes_domain),
        quality=_mean_quality(tables),
        baseline_quality=baseline_quality, tables=tables,
        notes=notes or {})


def build_plan(stats: Sequence[FeatureStats], dim: int, budget_bytes: int, *,
               arch: str = "custom", bytes_domain: str = "train_f32",
               op: str = "mult",
               baseline: MemoryPlan | None = None,
               dims: Sequence[int] | None = None) -> MemoryPlan:
    """Solve the budgeted allocation and emit an executable ``MemoryPlan``.

    ``baseline``: a ``uniform_hash_plan`` already solved for the same
    stats/budget/domain; omitted, one is scored internally (its mean
    quality fills ``baseline_quality``).

    ``dims``: optional width ladder (e.g. ``dim_ladder(dim)`` = {D/4,
    D/2, D}) — the mixed-dimension axis.  Default: uniform width ``dim``
    (byte-identical to the pre-dim planner).  The emitted plan's
    ``notes`` carry the solver's parked-upgrade / hull-drop audit trail.
    """
    ladders = [enumerate_candidates(f, st, dim, op=op,
                                    bytes_domain=bytes_domain,
                                    dims=tuple(dims) if dims else None)
               for f, st in enumerate(stats)]
    notes: dict = {}
    chosen = solve_budget(ladders, budget_bytes,
                          lambda c: c.bytes(bytes_domain), notes=notes)
    total = sum(c.bytes(bytes_domain) for c in chosen)
    assert total <= budget_bytes, (total, budget_bytes)  # solver invariant
    if dims:
        notes["dim_ladder"] = sorted(set(int(d) for d in dims))
    if baseline is None:
        baseline_q = _mean_quality(_uniform_candidates(
            stats, dim, budget_bytes, bytes_domain))
    else:
        baseline_q = baseline.quality
    return _as_memory_plan(chosen, stats, dim, budget_bytes, arch,
                           bytes_domain, baseline_q, notes=notes)


def _uniform_candidates(stats, dim, budget_bytes,
                        bytes_domain) -> list[Candidate]:
    """One global hash ratio ``r`` (rows_i = max(1, floor(r·n_i))), the
    largest that fits the budget (binary search, same byte accounting as
    the planner's candidates)."""
    sizes = [s.size for s in stats]
    per_row = bytes_per_row(dim, bytes_domain)

    def bytes_at(r: float) -> int:
        return sum(max(1, min(n, int(r * n))) * per_row for n in sizes)

    if bytes_at(0.0) > budget_bytes:
        raise InfeasibleBudget(
            f"budget {budget_bytes} B < one row per table "
            f"({bytes_at(0.0)} B) in domain {bytes_domain}")
    lo, hi = 0.0, 1.0
    for _ in range(60):
        mid = (lo + hi) / 2
        if bytes_at(mid) <= budget_bytes:
            lo = mid
        else:
            hi = mid
    out = []
    for f, st in enumerate(stats):
        n = st.size
        m = max(1, min(n, int(lo * n)))
        # the factory's hash path sizes m = ceil(n/c); invert to a c that
        # reproduces at most m rows so the baseline is executable too
        c = max(1, -(-n // m))
        spec = EmbeddingSpec(kind="full" if m >= n else "hash",
                             num_collisions=c)
        out.append(candidate_for(f, st, dim, spec))
    return out


def uniform_hash_plan(stats: Sequence[FeatureStats], dim: int,
                      budget_bytes: int, *, arch: str = "custom",
                      bytes_domain: str = "train_f32") -> MemoryPlan:
    """The non-adaptive control as a full (executable) ``MemoryPlan``."""
    chosen = _uniform_candidates(stats, dim, budget_bytes, bytes_domain)
    return _as_memory_plan(chosen, stats, dim, budget_bytes, arch,
                           bytes_domain,
                           baseline_quality=_mean_quality(chosen))


def plan_for_config(cfg, budget_bytes: int, *, arch: str | None = None,
                    bytes_domain: str = "train_f32", num_batches: int = 32,
                    batch_size: int = 512, zipf: float = 1.5,
                    noise: float = 0.5, seed: int = 0,
                    dims: Sequence[int] | None = None) -> MemoryPlan:
    """Plan for a rec model config (``DLRMConfig`` / ``DCNConfig``):
    streams frequency stats from the synthetic Criteo generator at the
    config's table sizes (the same zipf the training configs use), then
    solves at ``budget_bytes``.  ``dims`` enables the mixed-dimension
    width ladder (``build_plan`` docstring)."""
    from ..data.criteo import CriteoSpec
    spec = CriteoSpec(table_sizes=tuple(cfg.table_sizes), zipf=zipf,
                      noise=noise)
    stats = stats_from_criteo(spec, num_batches=num_batches,
                              batch_size=batch_size, seed=seed)
    op = getattr(getattr(cfg, "embedding", None), "op", "mult")
    if op not in ("mult", "add", "concat"):
        op = "mult"
    return build_plan(stats, cfg.emb_dim, budget_bytes,
                      arch=arch or getattr(cfg, "name", "custom"),
                      bytes_domain=bytes_domain, op=op, dims=dims)
