"""repro.plan — frequency-aware memory-budget planner for embedding tables.

The paper turns embedding memory into a *structure* knob (complementary
partitions); this subsystem turns it into an *allocation* decision: given
per-feature cardinalities, empirical category-frequency histograms, and a
global byte budget, choose a per-feature configuration (full / hashed /
QR / generalized-QR, with train-f32 or serve-int8 byte accounting) that
maximizes a frequency-weighted analytic quality proxy — and emit it as a
serializable, executable ``MemoryPlan``.

Pipeline::

    freq.stats_from_criteo / power_law_stats      # traffic histograms
      -> candidates.enumerate_candidates          # spec ladder per feature
      -> solver.solve_budget                      # Lagrangian-greedy knapsack
      -> MemoryPlan (artifacts/plans/*.json)      # consumed by train/serve

Consumers: ``core.factory.make_embedding`` builds directly from a plan
(``feature=`` selects the table), ``launch.train`` / ``launch.serve``
take ``--plan`` / ``--plan-budget-mb``, and ``benchmarks/plan_bench.py``
sweeps budgets against the uniform-hashing control.
"""

from .candidates import (Candidate, candidate_specs, dim_ladder,
                         enumerate_candidates)
from .freq import (FeatureStats, power_law_stats, stats_from_batches,
                   stats_from_criteo)
from .memory_plan import PLAN_DIR, MemoryPlan, TablePlan, plan_path
from .planner import (build_plan, full_table_bytes, plan_for_config,
                      uniform_hash_plan)
from .quality import (dim_proxy_loss, dim_proxy_quality, fit_width_exponent,
                      module_partitions, partition_diagnostics,
                      partition_entropy, proxy_loss, proxy_quality,
                      required_dim, sharing, width_factor)
from .solver import InfeasibleBudget, concave_frontier, solve_budget

__all__ = [
    "FeatureStats", "stats_from_batches", "stats_from_criteo",
    "power_law_stats",
    "Candidate", "candidate_specs", "enumerate_candidates", "dim_ladder",
    "proxy_loss", "proxy_quality", "sharing", "partition_entropy",
    "partition_diagnostics", "module_partitions",
    "dim_proxy_loss", "dim_proxy_quality", "width_factor", "required_dim",
    "fit_width_exponent",
    "concave_frontier", "solve_budget", "InfeasibleBudget",
    "TablePlan", "MemoryPlan", "PLAN_DIR", "plan_path",
    "build_plan", "uniform_hash_plan", "plan_for_config", "full_table_bytes",
]
