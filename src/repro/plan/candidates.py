"""Candidate embedding configurations per feature.

Every candidate is an ``EmbeddingSpec`` the factory already understands —
enumeration *builds the module through* ``core.factory.make_embedding``
and reads rows / partitions off the result, so the planner's cost and
quality models are definitionally consistent with what training will
instantiate (the plan→``make_embedding``→``num_params`` round-trip test
pins this).

Families enumerated per feature of cardinality ``n``:

* ``full``        — the |S|·D anchor (quality 1);
* ``hash``        — remainder-only at collision factors ``c`` (rows
  ``ceil(n/c)``): the lossy baseline ladder, and the only family that can
  go arbitrarily small (down to one row), so every budget is feasible;
* ``qr``          — quotient–remainder pairs at the same ladder (rows
  ``ceil(n/c) + c``-ish, paper Alg. 2);
* ``mixed_radix`` — generalized QR at k balanced radices (rows
  ``~k·n^(1/k)``, the cheapest complementary family).

Costs are reported in two byte domains sharing one accounting, summed
over the module's *physical* sub-tables ``(rows_j, width_j)`` (exact for
``op="concat"``, where sub-table widths are ``dim/k``):

* ``train_bytes``      — Σ rows_j · width_j · 4 (f32 training tables);
* ``serve_bytes_int8`` — Σ rows_j · ``row_bytes(width_j, "int8")`` (the
  width+3 B/row post-training-quantized wire format) — the serve-time
  budget domain.

**Mixed dimensions**: pass ``dims=dim_ladder(D)`` ({D/4, D/2, D}) to
cross-product every spec with a width axis — each candidate is then built
at its own ``dim`` and scored with the dim-aware proxy
(``quality.dim_proxy_quality``), and the solver folds the cross-product
into the same per-feature convex-hull frontier (still exact MCKP).
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from ..core.factory import EmbeddingSpec, _balanced_radices, make_embedding
from ..serve.quantize import row_bytes
from .freq import FeatureStats
from .quality import dim_proxy_quality, module_partitions

__all__ = ["Candidate", "enumerate_candidates", "HASH_LADDER", "QR_LADDER",
           "MIXED_RADIX_KS", "candidate_specs", "candidate_for",
           "module_tables", "bytes_per_row", "BYTE_DOMAINS", "dim_ladder"]

BYTE_DOMAINS = ("train_f32", "serve_int8")


def bytes_per_row(dim: int, domain: str) -> int:
    """Bytes per ``dim``-wide table row in a solve domain — the single
    domain→cost mapping the candidate ladder, the solver's cost function,
    and ``planner.full_table_bytes`` all share (a new domain, e.g. 4-bit
    tables, is added here once)."""
    if domain == "train_f32":
        return 4 * dim
    if domain == "serve_int8":
        return row_bytes(dim, "int8")
    raise ValueError(f"unknown byte domain {domain!r}; "
                     f"expected one of {BYTE_DOMAINS}")

HASH_LADDER = (2, 4, 8, 16, 32, 64, 128, 256, 1024)
QR_LADDER = (2, 4, 8, 16, 32, 64, 128)
MIXED_RADIX_KS = (2, 3)


def dim_ladder(full_dim: int) -> tuple[int, ...]:
    """The default mixed-dimension width ladder {D/4, D/2, D} — the second
    knapsack axis the planner cross-products with the structural specs."""
    return tuple(sorted({max(1, full_dim // 4), max(1, full_dim // 2),
                         full_dim}))


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One scored configuration of one feature's table.

    ``rows`` and both byte costs are derived from the *physical* tables
    the factory builds (``(rows_j, width_j)`` per partition), so they stay
    exact for ``op="concat"`` where sub-table widths are ``dim/k`` and
    ``num_params`` is not a multiple of ``dim``.  ``dim`` is the table's
    *embedding width* — mixed-dimension plans carry a per-feature dim and
    the models project back to the interaction width.
    """

    feature: int
    num_categories: int
    spec: EmbeddingSpec
    rows: int                 # total physical rows across sub-tables
    train_bytes: int          # f32 training bytes: sum rows_j * width_j * 4
    serve_bytes_int8: int     # sum rows_j * row_bytes(width_j, "int8")
    quality: float
    dim: int = 0              # embedding width this candidate was built at

    @property
    def label(self) -> str:
        s = self.spec
        if s.kind in ("hash", "qr"):
            base = f"{s.kind}/c{s.num_collisions}"
        elif s.kind == "mixed_radix":
            base = f"mr/{'x'.join(map(str, s.ms))}"
        else:
            base = s.kind
        return f"{base}@d{self.dim}" if self.dim else base

    def bytes(self, domain: str = "train_f32") -> int:
        if domain == "train_f32":
            return self.train_bytes
        if domain == "serve_int8":
            return self.serve_bytes_int8
        raise ValueError(f"unknown byte domain {domain!r}")


def module_tables(module) -> list[tuple[int, int]]:
    """Physical ``(rows, width)`` per sub-table — the ground truth both
    byte domains cost against (``sum(r*w) == module.num_params``)."""
    from ..core.compositional import (CompositionalEmbedding, FullEmbedding,
                                      HashEmbedding)
    if isinstance(module, CompositionalEmbedding):
        return [(p.num_buckets, d)
                for p, d in zip(module.partitions, module.dims)]
    if isinstance(module, HashEmbedding):
        return [(module.m, module.dim)]
    if isinstance(module, FullEmbedding):
        return [(module.num_categories, module.dim)]
    raise TypeError(f"no table view for module {type(module).__name__}")


def candidate_for(feature: int, stats: FeatureStats, dim: int,
                  spec: EmbeddingSpec, param_dtype=jnp.float32,
                  full_dim: int | None = None) -> Candidate:
    """Build + score one spec through the factory (the single source of
    structure for cost, quality, and the eventual model).  ``dim`` is the
    width the table is built at; ``full_dim`` (default ``dim``) is the
    model's interaction width the dim-aware proxy scores against."""
    module = make_embedding(stats.size, dim, spec, param_dtype)
    tables = module_tables(module)
    assert sum(r * w for r, w in tables) == module.num_params
    return Candidate(
        feature=feature, num_categories=stats.size, spec=spec,
        rows=sum(r for r, _ in tables),
        train_bytes=sum(r * w * 4 for r, w in tables),
        serve_bytes_int8=sum(r * row_bytes(w, "int8") for r, w in tables),
        quality=dim_proxy_quality(module_partitions(module), stats,
                                  dim, full_dim or dim),
        dim=dim)


def candidate_specs(n: int, *, op: str = "mult",
                    hash_ladder=HASH_LADDER, qr_ladder=QR_LADDER,
                    mixed_radix_ks=MIXED_RADIX_KS) -> list[EmbeddingSpec]:
    """The raw spec ladder for a feature of cardinality ``n`` (pre-scoring)."""
    specs = [EmbeddingSpec(kind="full")]
    for c in hash_ladder:
        if c >= 2 and -(-n // c) < n:
            specs.append(EmbeddingSpec(kind="hash", num_collisions=c))
    for c in qr_ladder:
        if c >= 2 and c < n:
            specs.append(EmbeddingSpec(kind="qr", num_collisions=c, op=op))
    for k in mixed_radix_ks:
        if n >= 2 ** k:  # k digits need at least 2 values each
            specs.append(EmbeddingSpec(kind="mixed_radix",
                                       ms=_balanced_radices(n, k), op=op))
    return specs


def enumerate_candidates(feature: int, stats: FeatureStats, dim: int, *,
                         op: str = "mult", param_dtype=jnp.float32,
                         extra_specs=(),
                         bytes_domain: str = "train_f32",
                         dims: tuple[int, ...] | None = None
                         ) -> list[Candidate]:
    """Score the spec ladder for one feature, deduplicated by cost in the
    *solve domain* (keep the best quality per distinct cost; drop configs
    costlier than full — two specs can tie on train bytes yet differ on
    serve-int8 bytes, so the dedup key must match the budget's domain).
    Always contains at least the one-row hash, so any global budget
    >= F·D·4 bytes is satisfiable.

    ``dims`` is the width axis: every spec is enumerated at every width
    (default: ``(dim,)`` — the uniform-width ladder, byte-identical to the
    pre-dim planner).  ``dim`` stays the model's interaction width the
    dim-aware proxy scores against; a full-width full table is the only
    quality-1 anchor, so the `full@D` cost cap applies across widths."""
    n = stats.size
    widths = tuple(dims) if dims else (dim,)
    if any(w < 1 or w > dim for w in widths):
        raise ValueError(f"candidate widths {widths} must be in [1, {dim}]")
    full_cost = n * bytes_per_row(dim, bytes_domain)
    by_cost: dict[int, Candidate] = {}

    def admit(spec, width):
        cand = candidate_for(feature, stats, width, spec, param_dtype,
                             full_dim=dim)
        cost = cand.bytes(bytes_domain)
        if cost >= full_cost and not (spec.kind == "full" and width == dim):
            return  # costs at least the full@D table: dominated
        best = by_cost.get(cost)
        if best is None or cand.quality > best.quality:
            by_cost[cost] = cand

    for width in widths:
        for spec in list(candidate_specs(n, op=op)) + list(extra_specs):
            admit(spec, width)
    # guarantee a floor candidate (hash down to 1 row) for feasibility
    if min(c.rows * c.dim for c in by_cost.values()) > min(widths):
        admit(EmbeddingSpec(kind="hash", num_collisions=max(2, n)),
              min(widths))
    return [by_cost[b] for b in sorted(by_cost)]
