"""Online re-planning: drift detection, live table migration, hot-swap.

The paper plans its compositional tables once, offline.  This package
keeps a *running* system matched to drifting traffic (the ROADMAP's
streaming-drift scenario), closing the loop PR 8's collision telemetry
opened:

* ``drift``      — ``DriftDetector``: measured-vs-predicted collision-mass
  gap per feature, with hysteresis + cooldown (noise never re-solves);
* ``migrate``    — warm-start a new plan's tables from the old structure
  by the partitions' own index maps; optimizer moments carried per-leaf;
* ``controller`` — ``ReplanController``: telemetry window → decayed
  ``StreamingStats`` → detector → ``build_plan`` on observed traffic →
  ``migrate_params`` → ``RecsysEngine.swap_plan``.

``benchmarks/drift_bench.py`` proves the loop end to end and CI gates it.
"""

from .controller import ReplanController
from .drift import DriftDecision, DriftDetector, DriftThresholds
from .migrate import (migrate_feature, migrate_opt_state, migrate_params,
                      representative_ids)

__all__ = ["DriftDecision", "DriftDetector", "DriftThresholds",
           "ReplanController", "migrate_feature", "migrate_opt_state",
           "migrate_params", "representative_ids"]
