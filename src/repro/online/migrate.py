"""Live table migration between memory plans — warm-start, never retrain.

When the online controller re-solves the plan for drifted traffic, the new
table structures (full / hash / QR / mixed-radix, possibly new widths)
start life as random inits.  Serving them cold would throw away everything
the old tables learned and tank quality until a retrain catches up.  This
module folds the *old* structure's learned state into the new one using
the partitions' own index maps:

* every new sub-table row has a **representative raw id** — the smallest
  category id landing in that bucket (closed form for the remainder /
  quotient / mixed-radix families, a scan for explicit partitions);
* the old model's *combined* embedding at those representatives (via
  ``module.apply``, so quantized tables dequantize exactly as serving
  does) becomes the new row, carried across width changes through the
  per-feature projections (project old→interaction width, then
  least-squares back through the new projection);
* for compositional targets one **carrier** partition receives the folded
  rows and the others start neutral (ones for ``mult``, zeros for
  ``add``), so the combined embedding of every id whose representative is
  itself — in particular the Zipf-hot head ids ``0..m-1`` of a
  head-injective carrier — is *exactly* the old model's row.  ``concat``
  targets fold per-partition slices instead (same head-exactness).
* **same-spec tables are copied bitwise** (modulo dequantization), and
  full→full is an identity copy — the property tests pin both;
* optimizer moments migrate per-leaf by path+shape match (carried) or
  reset to the optimizer's init, with every choice recorded.

The migrated tree has *exactly* the new init's structure and shapes, so
it can never exceed the new plan's byte budget — the solver invariant
(``total <= budget``) transfers to the migrated state by construction.
"""

from __future__ import annotations

import numpy as np

__all__ = ["representative_ids", "migrate_feature", "migrate_params",
           "migrate_opt_state"]

_CHUNK = 8192  # fill-gather chunk for old-row evaluation (one compile)


# ------------------------------------------------------------ index maps

def representative_ids(partition) -> np.ndarray:
    """Smallest raw id per bucket, ``(num_buckets,)`` int64 — the planner's
    index maps inverted.  Closed forms for the arithmetic families:

    * remainder ``x % m``          → ``b``  (ids 0..m-1 are their own reps)
    * quotient  ``x // m``         → ``b * m``
    * mixed-radix ``(x // M) % m`` → ``b * M``

    Buckets no id reaches (padding buckets of a clipped radix product)
    get rep ``size - 1`` — harmless, they receive no traffic.  Explicit
    partitions scan their table for first occurrences.
    """
    from ..core.partitions import (ExplicitPartition, GeneralizedQRPartition,
                                   QuotientPartition, RemainderPartition)
    n, size = partition.num_buckets, partition.size
    if isinstance(partition, RemainderPartition):
        reps = np.arange(n, dtype=np.int64) * 1  # bucket b <- id b
    elif isinstance(partition, QuotientPartition):
        reps = np.arange(n, dtype=np.int64) * partition.m
    elif isinstance(partition, GeneralizedQRPartition):
        reps = np.arange(n, dtype=np.int64) * partition.divisor
    elif isinstance(partition, ExplicitPartition):
        reps = np.full(n, size - 1, np.int64)
        buckets = np.asarray(partition.table[:size], np.int64)
        # reversed so the *first* occurrence wins the assignment
        uniq, first = np.unique(buckets, return_index=True)
        reps[uniq] = first
    else:  # generic fallback: brute-force bucket scan
        buckets = np.asarray(partition.bucket(np.arange(size)), np.int64)
        reps = np.full(n, size - 1, np.int64)
        uniq, first = np.unique(buckets, return_index=True)
        reps[uniq] = first
    return np.minimum(reps, size - 1)


def _head_injective(partition) -> bool:
    """True when ``bucket(x) == x`` for every ``x < num_buckets`` — such a
    partition's head rows fold exactly (the Zipf head lives there)."""
    from ..core.partitions import (GeneralizedQRPartition, QuotientPartition,
                                   RemainderPartition)
    if isinstance(partition, RemainderPartition):
        return True
    if isinstance(partition, GeneralizedQRPartition):
        return partition.divisor == 1
    if isinstance(partition, QuotientPartition):
        return partition.m == 1
    return False


def _carrier_index(partitions) -> int:
    """Which partition receives the folded rows: prefer head-injective
    (hot head ids are preserved exactly), then the most buckets (most of
    the old state survives)."""
    return min(range(len(partitions)),
               key=lambda j: (not _head_injective(partitions[j]),
                              -partitions[j].num_buckets))


# ------------------------------------------------------------ row folding

def _old_rows(old_mod, old_tp, ids: np.ndarray) -> np.ndarray:
    """Combined (dequantized) f32 rows of the old model at raw ``ids`` —
    chunked ``module.apply``, the same math serving's miss path runs."""
    import jax.numpy as jnp
    out = []
    for lo in range(0, len(ids), _CHUNK):
        chunk = ids[lo:lo + _CHUNK]
        pad = _CHUNK - len(chunk)
        if pad:  # stable shape: one compile for any rep count
            chunk = np.concatenate([chunk, np.repeat(chunk[-1:], pad)])
        rows = old_mod.apply(old_tp, jnp.asarray(chunk, jnp.int32))
        out.append(np.asarray(rows, np.float32)[:_CHUNK - pad if pad else None])
    return np.concatenate(out) if out else np.empty((0, old_mod.out_dim),
                                                    np.float32)


def _to_width(rows: np.ndarray, old_proj, new_proj, d_new: int) -> np.ndarray:
    """Carry ``(n, d_old)`` rows to the new table width.  Equal widths pass
    through (the projection itself is carried separately); otherwise rows
    go old→interaction width through the old projection and back down
    through the pseudo-inverse of the new one, so
    ``migrated_row @ new_proj ≈ old_row @ old_proj`` — the interaction
    tower sees (approximately) the features it was trained on."""
    if rows.shape[1] == d_new:
        return rows
    e = rows if old_proj is None else rows @ np.asarray(old_proj, np.float32)
    if e.shape[1] == d_new:
        return e
    return e @ np.linalg.pinv(np.asarray(new_proj, np.float32))


def _dequant_leaf(leaf):
    from ..core.compositional import is_quantized_table
    from ..serve.quantize import dequantize_table
    if is_quantized_table(leaf):
        return np.asarray(dequantize_table(leaf), np.float32)
    return np.asarray(leaf)


def _same_module(a, b) -> bool:
    try:
        return bool(a == b)
    except Exception:  # ExplicitPartition array equality is ambiguous
        return False


def migrate_feature(old_mod, old_tp, new_mod, new_tp, *,
                    old_proj=None, new_proj=None):
    """Warm-start one feature's new table params from its old state.

    Returns ``(table_params, proj_entry, decision)`` where ``table_params``
    matches ``new_tp``'s structure/shapes/dtypes exactly, ``proj_entry``
    is the per-feature projection to install (None when the new width is
    the interaction width), and ``decision`` is the JSON-safe audit record
    for the plan notes.
    """
    import jax.numpy as jnp

    from ..core.compositional import CompositionalEmbedding
    d_new = new_mod.out_dim
    decision = {"from": type(old_mod).__name__, "to": type(new_mod).__name__,
                "from_dim": int(old_mod.out_dim), "to_dim": int(d_new)}

    same = _same_module(old_mod, new_mod) and all(
        _dequant_leaf(old_tp[k]).shape == tuple(new_tp[k].shape)
        for k in new_tp if k in old_tp)
    if same and set(old_tp) == set(new_tp):
        out = {k: jnp.asarray(_dequant_leaf(old_tp[k]), new_tp[k].dtype)
               for k in new_tp}
        decision["decision"] = "copied"
        pe = old_proj if old_proj is not None else new_proj
        return out, pe, decision

    decision["decision"] = "folded"
    if isinstance(new_mod, CompositionalEmbedding):
        from ..plan.quality import module_partitions
        parts = module_partitions(new_mod)
        out = {}
        if new_mod.op == "concat":
            # per-partition slice folding: every table takes its dims
            # slice of the target row at its own representatives, so any
            # id whose reps are all itself reproduces the old row exactly
            decision["carrier"] = "concat-all"
            off = 0
            for j, (p, d_j) in enumerate(zip(parts, new_mod.dims)):
                rows = _to_width(_old_rows(old_mod, old_tp,
                                           representative_ids(p)),
                                 old_proj, new_proj, d_new)
                out[f"table_{j}"] = jnp.asarray(rows[:, off:off + d_j],
                                                new_tp[f"table_{j}"].dtype)
                off += d_j
        else:
            ci = _carrier_index(parts)
            decision["carrier"] = ci
            neutral = (np.ones if new_mod.op == "mult" else np.zeros)
            for j, p in enumerate(parts):
                key = f"table_{j}"
                if j == ci:
                    rows = _to_width(_old_rows(old_mod, old_tp,
                                               representative_ids(p)),
                                     old_proj, new_proj, d_new)
                else:
                    rows = neutral((p.num_buckets, new_mod.dims[j]),
                                   np.float32)
                out[key] = jnp.asarray(rows, new_tp[key].dtype)
    else:
        # Full / Hash target: a single table whose rows 0..rows-1 are the
        # canonical ids themselves (hash folds mod m — head-injective)
        from ..plan.quality import module_partitions
        (p,) = module_partitions(new_mod)
        rows = _to_width(_old_rows(old_mod, old_tp, representative_ids(p)),
                         old_proj, new_proj, d_new)
        out = {"table": jnp.asarray(rows, new_tp["table"].dtype)}

    if old_mod.out_dim == d_new and old_proj is not None:
        pe, decision["proj"] = old_proj, "carried"
    elif new_proj is not None:
        pe, decision["proj"] = new_proj, "fresh"
    else:
        pe = None
    return out, pe, decision


# ------------------------------------------------------------ whole trees

def _shapes_match(a, b) -> bool:
    import jax
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    return (len(la) == len(lb)
            and all(getattr(x, "shape", None) == getattr(y, "shape", None)
                    and getattr(x, "dtype", None) == getattr(y, "dtype", None)
                    for x, y in zip(la, lb)))


def migrate_params(old_cfg, old_params, new_cfg, new_params):
    """Warm-start a full param tree for ``new_cfg`` from ``old_params``.

    ``new_params`` is a fresh init for the new config — it supplies the
    target structure/shapes (and the fallback values for anything that
    cannot be carried).  Dense towers carry wholesale when their shapes
    match (F and the interaction width are unchanged across re-plans, so
    they always do in the online loop).  Returns ``(params, report)``;
    stash ``report`` in the new plan's ``notes["migration"]`` so the swap
    is auditable.
    """
    from ..models.dlrm import tables_for
    if tuple(old_cfg.table_sizes) != tuple(new_cfg.table_sizes):
        raise ValueError("migration keeps the feature set: table_sizes "
                         f"{old_cfg.table_sizes} vs {new_cfg.table_sizes}")
    if old_cfg.emb_dim != new_cfg.emb_dim:
        raise ValueError("interaction width must match across plans "
                         f"({old_cfg.emb_dim} vs {new_cfg.emb_dim})")
    old_modules, new_modules = tables_for(old_cfg), tables_for(new_cfg)
    report = {"features": [], "dense": {}}
    out = {}
    for k in new_params:
        if k in ("tables", "proj"):
            continue
        if k in old_params and _shapes_match(old_params[k], new_params[k]):
            out[k] = old_params[k]
            report["dense"][k] = "carried"
        else:
            out[k] = new_params[k]
            report["dense"][k] = "reset"
    old_proj_all = old_params.get("proj", {})
    new_proj_all = new_params.get("proj", {})
    tables, proj = [], {}
    for i, (om, nm) in enumerate(zip(old_modules, new_modules)):
        tp, pe, dec = migrate_feature(
            om, old_params["tables"][i], nm, new_params["tables"][i],
            old_proj=old_proj_all.get(str(i)),
            new_proj=new_proj_all.get(str(i)))
        tables.append(tp)
        if nm.out_dim != new_cfg.emb_dim and pe is not None:
            proj[str(i)] = pe
        dec["feature"] = i
        report["features"].append(dec)
    out["tables"] = tables
    if proj:
        out["proj"] = proj
    kinds = [d["decision"] for d in report["features"]]
    report["counts"] = {k: kinds.count(k) for k in sorted(set(kinds))}
    return out, report


def migrate_opt_state(old_params, old_state, new_params, optimizer):
    """Carry optimizer moments across a migration, per-leaf.

    The optimizer state is a flat list in ``jax.tree.leaves`` order; leaves
    are matched by their '/'-joined tree path (``optim.leaf_paths``) and
    carried when path, shape, and dtype all agree — anything else (new
    sub-tables, changed widths) resets to ``optimizer.init_leaf``.  Returns
    ``(state, decisions)`` with one ``"carried"``/``"reset"`` per new-tree
    path, recorded in the migration report.
    """
    import jax

    from ..optim.optimizers import leaf_paths
    old_by_path = dict(zip(leaf_paths(old_params),
                           zip(jax.tree.leaves(old_params), old_state)))
    state, decisions = [], {}
    for path, leaf in zip(leaf_paths(new_params),
                          jax.tree.leaves(new_params)):
        prev = old_by_path.get(path)
        if (prev is not None and prev[0].shape == leaf.shape
                and prev[0].dtype == leaf.dtype):
            state.append(prev[1])
            decisions[path] = "carried"
        else:
            state.append(optimizer.init_leaf(leaf))
            decisions[path] = "reset"
    return state, decisions
