"""The closed loop: watch → detect → re-solve → migrate → hot-swap.

``ReplanController`` wraps a running ``RecsysEngine`` (single-host, obs
with collision telemetry attached) and turns the planner from a one-shot
tool into a control system.  Each ``check()``:

1. reads the telemetry's current *window* (per-feature observed stats and
   the measured collision masses), folds it into a long-horizon decayed
   ``StreamingStats``, and resets the telemetry so the next window is
   independent;
2. asks the ``DriftDetector`` whether the measured-vs-predicted gap has
   persisted past hysteresis (the first window baselines the detector
   instead of judging, when no plan-time stats were given);
3. on fire: re-solves ``build_plan`` on the *decayed streaming* stats
   (not the single noisy window), warm-starts the new tables from the
   running params (``online.migrate``), re-quantizes to the engine's
   serving mode, and ``engine.swap_plan``s — then rebases the detector on
   the new structures' predicted masses under the same stats the plan was
   solved from, with a full cooldown.

Everything is synchronous and in-process by design: re-solve + migration
for the reduced config costs milliseconds-to-seconds, and the engine's
drain-then-install swap keeps it off the wave path.  ``launch.serve
--replan-interval`` runs this against live traffic.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from ..obs.collision import predicted_collision_mass
from ..plan.freq import StreamingStats
from .drift import DriftDecision, DriftDetector, DriftThresholds

__all__ = ["ReplanController"]


class ReplanController:
    def __init__(self, engine, *, budget_bytes: int,
                 thresholds: Optional[DriftThresholds] = None,
                 decay: float = 0.8,
                 dims: Optional[Sequence[int]] = None,
                 quantize: Optional[str] = None,
                 plan_stats: Optional[Sequence] = None,
                 seed: int = 0):
        """``budget_bytes`` bounds every re-solve (train_f32 domain, the
        same knob ``build_plan`` takes).  ``plan_stats`` are the stats the
        *current* plan was solved from — given, the detector starts armed;
        omitted, the first served window becomes the baseline (boot
        traffic is presumed normal).  ``quantize`` re-applies the engine's
        serving mode ("int8"/"bf16") to migrated params; ``decay`` is the
        per-window factor of the streaming history; ``dims`` forwards a
        width ladder to ``build_plan``."""
        if engine._n_shards > 1:
            raise NotImplementedError("online re-planning is single-host "
                                      "(swap_plan contract)")
        obs = engine._obs
        if obs is None or obs.collisions is None:
            raise ValueError("ReplanController needs an engine with obs "
                             "collision telemetry attached "
                             "(Obs(collisions=True))")
        self.engine = engine
        self.budget_bytes = int(budget_bytes)
        self.thresholds = thresholds or DriftThresholds()
        self.dims = tuple(dims) if dims else None
        self.quantize = quantize
        self.seed = seed
        self.stream = StreamingStats(engine.cfg.table_sizes, decay=decay)
        self.detector: Optional[DriftDetector] = None
        if plan_stats is not None:
            self.detector = DriftDetector.from_stats(
                engine.modules, plan_stats, self.thresholds)
        self.checks = 0
        self.replans: list[dict] = []
        self.last_decision: Optional[DriftDecision] = None

    # ------------------------------------------------------------ the loop

    def check(self) -> Optional[DriftDecision]:
        """One control-loop tick.  Returns the window's ``DriftDecision``
        (None when the window was empty or only baselined the detector);
        a fired decision has already re-planned and swapped by the time
        this returns — the report is appended to ``self.replans``."""
        tele = self.engine._obs.collisions
        if tele.waves == 0:
            return None
        self.checks += 1
        window = tele.all_observed_stats()
        lookups = [tele.observed_lookups(i)
                   for i in range(len(window))]
        self.stream.update_stats(window, lookups)
        if self.detector is None:
            # bootstrap: the first window defines "normal"
            self.detector = DriftDetector.from_stats(
                self.engine.modules, window, self.thresholds)
            tele.reset()
            return None
        decision = self.detector.check(tele)
        tele.reset()
        self.last_decision = decision
        if decision.fired:
            self.replans.append(self.replan(trigger=decision))
        return decision

    def replan(self, trigger: Optional[DriftDecision] = None) -> dict:
        """Re-solve on the streaming stats, migrate, swap, rebase.

        Public so a caller can force a re-plan (e.g. an operator knob)
        without waiting for the detector."""
        import jax

        from ..configs import get_arch
        from ..plan.planner import build_plan
        from .migrate import migrate_params

        engine = self.engine
        stats = self.stream.all_stats()
        old_cfg = engine.cfg
        plan = build_plan(stats, old_cfg.emb_dim, self.budget_bytes,
                          arch=f"{old_cfg.name}-online",
                          dims=self.dims)
        new_cfg = dataclasses.replace(old_cfg, embedding=plan)
        api = get_arch(old_cfg.name).api(new_cfg)
        fresh = api.init(jax.random.PRNGKey(self.seed))
        migrated, mreport = migrate_params(old_cfg, engine.params,
                                           new_cfg, fresh)
        plan.notes["migration"] = mreport
        if self.quantize:
            from ..serve.quantize import quantize_params
            migrated = quantize_params(migrated, mode=self.quantize)
        swap = engine.swap_plan(new_cfg, migrated)
        self.detector.rebase(
            engine.modules,
            [predicted_collision_mass(m, s)
             for m, s in zip(engine.modules, stats)])
        return {
            "trigger": None if trigger is None else {
                "over": list(trigger.over),
                "gaps": {str(k): list(v) for k, v in trigger.gaps.items()}},
            "plan": {"total_bytes": plan.total_bytes,
                     "budget_bytes": plan.budget_bytes,
                     "quality": plan.quality,
                     "kinds": [t.kind for t in plan.tables]},
            "migration": mreport["counts"],
            "swap": swap,
        }
