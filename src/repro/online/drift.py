"""Drift detection on the measured-vs-predicted collision gap.

``obs.CollisionTelemetry`` evaluates the planner's own collision-mass
proxy on the ids serving actually saw; the plan was solved to make the
*predicted* value small.  When traffic drifts — the popularity head moves,
the histogram flattens — the measured mass rises above the prediction on
the hashed/QR tables, because more (or different) effective categories now
share rows.  That one-sided gap is the re-plan trigger.

The detector judges telemetry *windows* (the controller resets the
telemetry between checks) and is deliberately sluggish:

* a feature is **over** when ``measured > scale * predicted * (1 + rel)
  + abs`` — ``scale`` comes from ``plan.quality.fit_collision_scale`` so
  a systematic proxy bias is calibrated away, ``rel``/``abs`` absorb
  sampling noise, and features with fewer than ``min_lookups`` window
  lookups abstain entirely (an empty window proves nothing);
* **hysteresis**: only ``hysteresis`` *consecutive* over-windows fire —
  a single noisy window never triggers a re-solve;
* **cooldown**: after a fire (or a ``rebase`` to a fresh plan) the next
  ``cooldown`` checks cannot fire, so the loop cannot thrash while the
  newly-migrated tables settle.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

__all__ = ["DriftThresholds", "DriftDecision", "DriftDetector"]


@dataclasses.dataclass(frozen=True)
class DriftThresholds:
    """Knobs of the one-sided gap test (module docstring)."""
    rel_gap: float = 0.5        # fire at measured > scale*pred*(1+rel)+abs
    abs_gap: float = 1e-3
    min_lookups: int = 256      # windows thinner than this abstain
    hysteresis: int = 2         # consecutive over-windows needed to fire
    cooldown: int = 3           # post-fire quiet checks
    collision_scale: float = 1.0  # fit_collision_scale calibration


@dataclasses.dataclass(frozen=True)
class DriftDecision:
    """One window's verdict: which features exceeded the gap, the
    per-feature (predicted, measured) pairs behind it, and the detector's
    streak/cooldown state after this check."""
    fired: bool
    over: tuple[int, ...]
    gaps: dict
    streak: int
    cooldown: int


class DriftDetector:
    """Windowed measured-vs-predicted gap test with hysteresis+cooldown."""

    def __init__(self, modules: Sequence, predicted: Sequence[float],
                 thresholds: Optional[DriftThresholds] = None):
        if len(modules) != len(predicted):
            raise ValueError("one predicted mass per module")
        self.modules = list(modules)
        self.predicted = [float(p) for p in predicted]
        self.thresholds = thresholds or DriftThresholds()
        self._streak = 0
        self._cooldown = 0
        self.checks = 0
        self.fires = 0

    @classmethod
    def from_stats(cls, modules: Sequence, stats: Sequence,
                   thresholds: Optional[DriftThresholds] = None
                   ) -> "DriftDetector":
        """Baseline the prediction from the stats the current plan was
        solved on (or, bootstrapping, from the first served window)."""
        from ..obs.collision import predicted_collision_mass
        return cls(modules,
                   [predicted_collision_mass(m, s)
                    for m, s in zip(modules, stats)], thresholds)

    def check(self, telemetry) -> DriftDecision:
        """Judge one telemetry window.  Does not reset the telemetry —
        that is the caller's windowing decision (the controller resets
        after folding the window into its streaming history)."""
        th = self.thresholds
        self.checks += 1
        over, gaps = [], {}
        for i, mod in enumerate(self.modules):
            if telemetry.observed_lookups(i) < th.min_lookups:
                continue
            measured = telemetry.measured_collision_mass(mod, i)
            predicted = th.collision_scale * self.predicted[i]
            gaps[i] = (predicted, measured)
            if measured > predicted * (1.0 + th.rel_gap) + th.abs_gap:
                over.append(i)
        self._streak = self._streak + 1 if over else 0
        fired = bool(over) and self._streak >= th.hysteresis \
            and self._cooldown == 0
        if fired:
            self.fires += 1
            self._streak = 0
            self._cooldown = th.cooldown
        elif self._cooldown:
            self._cooldown -= 1
        return DriftDecision(fired=fired, over=tuple(over), gaps=gaps,
                             streak=self._streak, cooldown=self._cooldown)

    def rebase(self, modules: Sequence, predicted: Sequence[float]) -> None:
        """Point the detector at a freshly-installed plan: new structures,
        new predicted baseline, streak cleared, and a full cooldown so the
        first post-swap windows (mid-migration traffic, cold moments)
        cannot immediately re-fire."""
        if len(modules) != len(predicted):
            raise ValueError("one predicted mass per module")
        self.modules = list(modules)
        self.predicted = [float(p) for p in predicted]
        self._streak = 0
        self._cooldown = self.thresholds.cooldown
