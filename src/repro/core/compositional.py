"""Operation-based compositional embeddings (paper §2, §4).

Functional modules: frozen-dataclass configs with ``init(key) -> params``
(a dict of jnp arrays) and ``apply(params, idx) -> embeddings``.  All
``apply`` methods accept arbitrary-rank integer index arrays and return
``idx.shape + (dim,)`` activations, and are jit/vmap/pjit friendly.

Pooled ("bag") lookups for multi-hot features sum masked rows; the fused
Pallas TPU kernels in ``repro.kernels`` implement the same contracts (their
``ref.py`` oracles call into this module).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .partitions import Partition, qr_partitions

__all__ = [
    "FullEmbedding",
    "HashEmbedding",
    "CompositionalEmbedding",
    "qr_embedding",
    "bag_pool",
    "table_rows",
    "is_quantized_table",
]

OPS = ("mult", "add", "concat")


def _uniform(key, shape, scale, dtype):
    return jax.random.uniform(key, shape, minval=-scale, maxval=scale, dtype=dtype)


def is_quantized_table(leaf) -> bool:
    """The serving stack's row-quantized table wire format (the single
    predicate every consumer — gathers, kernels, byte accounting — uses)."""
    return isinstance(leaf, dict) and "q" in leaf and "scale" in leaf


def table_rows(table, idx):
    """Gather rows from a dense *or* row-quantized table.

    The serving stack (``repro.serve.quantize``) replaces table leaves with
    ``{"q": int8 (rows, D), "scale": bf16 (rows, 1), "zp": int8 (rows, 1)}``
    pytrees; every ``apply`` path below funnels through here, so the same
    model code serves f32, bf16, and int8 tables.  Only the gathered rows
    are dequantized (``scale * (q - zp)``, f32) — the full-precision table
    never materialises, which is the serve-time memory win.
    """
    if is_quantized_table(table):
        q = jnp.take(table["q"], idx, axis=0).astype(jnp.float32)
        zp = jnp.take(table["zp"], idx, axis=0).astype(jnp.float32)
        scale = jnp.take(table["scale"], idx, axis=0).astype(jnp.float32)
        return (q - zp) * scale
    return jnp.take(table, idx, axis=0)


def _gather(gather, table, idx, key):
    """Route one sub-table lookup through ``gather`` when given.

    ``gather(table_leaf, row_ids, sub_key) -> rows`` replaces the local
    ``table_rows`` take — the hook the sharded serve path uses to fetch
    remotely-resident rows over the mesh (``dist.serve_placement``)
    through the *same* ``apply``/``bag_pool`` combine code as the local
    path, so the two are bit-identical by construction.
    """
    if gather is None:
        return table_rows(table, idx)
    return gather(table, idx, key)


@dataclasses.dataclass(frozen=True)
class FullEmbedding:
    """The baseline |S| x D table (paper Fig. 1 / 'Full')."""

    num_categories: int
    dim: int
    param_dtype: jnp.dtype = jnp.float32

    def init(self, key):
        scale = (1.0 / self.num_categories) ** 0.5
        return {"table": _uniform(key, (self.num_categories, self.dim), scale, self.param_dtype)}

    def apply(self, params, idx, gather=None):
        return _gather(gather, params["table"], idx, "table")

    @property
    def num_params(self) -> int:
        return self.num_categories * self.dim

    @property
    def out_dim(self) -> int:
        return self.dim


@dataclasses.dataclass(frozen=True)
class HashEmbedding:
    """Hashing trick (paper Alg. 1): ``x -> table[x mod m]`` — lossy baseline."""

    num_categories: int
    dim: int
    m: int = 1
    param_dtype: jnp.dtype = jnp.float32

    def init(self, key):
        scale = (1.0 / self.num_categories) ** 0.5
        return {"table": _uniform(key, (self.m, self.dim), scale, self.param_dtype)}

    def apply(self, params, idx, gather=None):
        return _gather(gather, params["table"], jnp.asarray(idx) % self.m,
                       "table")

    @property
    def num_params(self) -> int:
        return self.m * self.dim

    @property
    def out_dim(self) -> int:
        return self.dim


@dataclasses.dataclass(frozen=True)
class CompositionalEmbedding:
    """Operation-based compositional embedding over complementary partitions.

    One table per partition (rows = that partition's bucket count); per-index
    rows are combined with ``op`` in {mult, add, concat} (paper eq. 6).  With
    the QR pair this is exactly Algorithm 2.  ``dims`` gives each table's
    embedding width: for mult/add all must equal ``dim``; for concat they
    must sum to ``dim`` (defaults to an even split).
    """

    num_categories: int
    dim: int
    partitions: tuple[Partition, ...] = ()
    op: str = "mult"
    dims: tuple[int, ...] = ()
    param_dtype: jnp.dtype = jnp.float32

    def __post_init__(self):
        if self.op not in OPS:
            raise ValueError(f"op={self.op!r} not in {OPS}")
        if not self.partitions:
            raise ValueError("need at least one partition")
        k = len(self.partitions)
        if not self.dims:
            if self.op == "concat":
                base = self.dim // k
                dims = [base] * k
                dims[-1] += self.dim - base * k
            else:
                dims = [self.dim] * k
            object.__setattr__(self, "dims", tuple(dims))
        if self.op == "concat":
            if sum(self.dims) != self.dim:
                raise ValueError(f"concat dims {self.dims} must sum to {self.dim}")
        elif any(d != self.dim for d in self.dims):
            raise ValueError(f"{self.op} requires all dims == {self.dim}, got {self.dims}")

    def init(self, key):
        # Matches the reference DLRM QR implementation: every table is drawn
        # uniform(-sqrt(1/|S|), sqrt(1/|S|)).  For `mult` the product of k
        # such rows has scale |S|^{-k/2}; we compensate so the *combined*
        # embedding matches the full table's scale (important for training
        # parity — confirmed by the Fig.4-style benchmark).
        keys = jax.random.split(key, len(self.partitions))
        scale = (1.0 / self.num_categories) ** 0.5
        if self.op == "mult":
            scale = scale ** (1.0 / len(self.partitions))
        return {
            f"table_{j}": _uniform(k, (p.num_buckets, d), scale, self.param_dtype)
            for j, (p, d, k) in enumerate(zip(self.partitions, self.dims, keys))
        }

    def partition_embeddings(self, params, idx, gather=None):
        """Per-partition rows (the 'feature generation' mode, paper §4)."""
        idx = jnp.asarray(idx)
        return [
            _gather(gather, params[f"table_{j}"], p.bucket(idx), f"table_{j}")
            for j, p in enumerate(self.partitions)
        ]

    def apply(self, params, idx, gather=None):
        zs = self.partition_embeddings(params, idx, gather=gather)
        if self.op == "concat":
            return jnp.concatenate(zs, axis=-1)
        if self.op == "add":
            return sum(zs[1:], zs[0])
        out = zs[0]
        for z in zs[1:]:
            out = out * z
        return out

    @property
    def num_params(self) -> int:
        return sum(p.num_buckets * d for p, d in zip(self.partitions, self.dims))

    @property
    def out_dim(self) -> int:
        return self.dim


def qr_embedding(
    num_categories: int,
    dim: int,
    num_collisions: int = 4,
    op: str = "mult",
    param_dtype: jnp.dtype = jnp.float32,
) -> CompositionalEmbedding:
    """Quotient–remainder trick (paper Alg. 2) with the paper's knob.

    ``num_collisions`` c enforces ~c categories per remainder bucket, i.e.
    remainder table of ``m = ceil(|S|/c)`` rows and quotient table of ``c``
    rows — an ~c× parameter reduction (paper §5.3 "4 hash collisions").
    """
    m = max(1, -(-num_categories // max(1, num_collisions)))
    return CompositionalEmbedding(
        num_categories=num_categories,
        dim=dim,
        partitions=tuple(qr_partitions(num_categories, m)),
        op=op,
        param_dtype=param_dtype,
    )


def bag_pool(module, params, idx, mask=None, gather=None):
    """Sum-pooled multi-hot lookup: ``sum_l emb(idx[..., l]) * mask[..., l]``.

    ``idx``: int array ``(..., L)``; ``mask``: optional ``(..., L)`` (1 keeps
    the row).  Returns ``(..., dim)``.  This is the contract the fused
    Pallas ``embedding_bag`` kernel implements.  ``gather`` substitutes
    the row fetch (see ``_gather``) — the sharded serve path's hook.
    """
    emb = module.apply(params, idx, gather=gather)  # (..., L, D)
    # pool in f32, round once (accumulation-audit convention): a bf16
    # running sum would round every one of the L adds
    pooled = emb.astype(jnp.float32)
    if mask is not None:
        pooled = pooled * mask[..., None].astype(jnp.float32)
    return pooled.sum(axis=-2).astype(emb.dtype)
