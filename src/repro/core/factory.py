"""Config-driven embedding construction, including the paper's thresholding.

``EmbeddingSpec`` is the single knob surface exposed through model configs
(`--arch` files set ``embedding=EmbeddingSpec(kind="qr", ...)``).  The
factory applies the paper's §5.4 thresholding rule: tables with at most
``threshold`` categories keep a full table; only larger tables are
compressed.

The from-plan path: ``spec`` may also be a ``repro.plan.MemoryPlan``
(duck-typed via ``spec_for`` — no import cycle), in which case ``feature``
selects the per-feature spec the planner solved for; the plan validates
cardinality and embedding dim so a stale plan fails loudly instead of
silently building un-scored tables.  Mixed-dimension plans additionally
carry a per-feature table width (``plan.dim_for``): the module is built
at that width (its ``out_dim`` reports it), and the models project each
feature back to the interaction width ``dim``.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from .compositional import CompositionalEmbedding, FullEmbedding, HashEmbedding, qr_embedding
from .partitions import crt_partitions, generalized_qr_partitions, qr_partitions
from .path import PathBasedEmbedding

__all__ = ["EmbeddingSpec", "make_embedding"]

KINDS = ("full", "hash", "qr", "mixed_radix", "crt", "path", "feature")


@dataclasses.dataclass(frozen=True)
class EmbeddingSpec:
    kind: str = "full"
    num_collisions: int = 4     # paper's compression knob (≈ model-size reduction factor)
    op: str = "mult"            # mult | add | concat  (paper §4 operations)
    threshold: int = 0          # tables with <= threshold rows stay full (paper §5.4)
    ms: tuple[int, ...] = ()    # explicit radices/moduli for mixed_radix / crt
    path_hidden: int = 64       # paper table 1/2 MLP width

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"kind={self.kind!r} not in {KINDS}")


def make_embedding(num_categories: int, dim: int, spec: EmbeddingSpec,
                   param_dtype=jnp.float32, feature: int | None = None):
    """Build the embedding module for one categorical feature/table.

    ``spec`` is an ``EmbeddingSpec`` or a ``repro.plan.MemoryPlan``; a plan
    requires ``feature`` (the categorical feature index) to pick the table
    choice the planner made for it.
    """
    if hasattr(spec, "spec_for"):  # MemoryPlan: resolve the per-feature spec
        if feature is None:
            raise ValueError("building from a MemoryPlan requires feature=<i> "
                             "(the categorical feature index)")
        plan = spec
        spec = plan.spec_for(feature, num_categories=num_categories, dim=dim)
        width = plan.dim_for(feature) if hasattr(plan, "dim_for") else dim
        if not 1 <= width <= dim:
            raise ValueError(f"plan table {feature} has width {width} outside "
                             f"[1, emb_dim={dim}] — regenerate the plan")
        dim = width
    if spec.kind == "full" or num_categories <= max(spec.threshold, 1):
        return FullEmbedding(num_categories, dim, param_dtype)
    c = max(1, spec.num_collisions)
    m = -(-num_categories // c)  # remainder-table rows
    if spec.kind == "hash":
        return HashEmbedding(num_categories, dim, m=m, param_dtype=param_dtype)
    if spec.kind in ("qr", "feature"):
        # `feature` reuses the QR tables; models call partition_embeddings()
        # instead of apply() to treat each partition as its own sparse feature.
        return qr_embedding(num_categories, dim, num_collisions=c, op=spec.op,
                            param_dtype=param_dtype)
    if spec.kind == "mixed_radix":
        ms = spec.ms or _balanced_radices(num_categories, 3)
        return CompositionalEmbedding(
            num_categories, dim,
            partitions=tuple(generalized_qr_partitions(num_categories, ms)),
            op=spec.op, param_dtype=param_dtype)
    if spec.kind == "crt":
        if not spec.ms:
            raise ValueError("crt requires explicit pairwise-coprime spec.ms")
        return CompositionalEmbedding(
            num_categories, dim,
            partitions=tuple(crt_partitions(num_categories, spec.ms)),
            op=spec.op, param_dtype=param_dtype)
    if spec.kind == "path":
        return PathBasedEmbedding(
            num_categories, dim,
            partitions=tuple(qr_partitions(num_categories, m)),
            hidden=spec.path_hidden, param_dtype=param_dtype)
    raise AssertionError(spec.kind)


def _balanced_radices(size: int, k: int) -> tuple[int, ...]:
    """k near-equal radices with product >= size (optimal O(k·size^{1/k}·D))."""
    base = int(round(size ** (1.0 / k)))
    while True:
        ms = [base] * (k - 1)
        last = -(-size // max(1, base ** (k - 1)))
        ms.append(max(last, 1))
        prod = 1
        for m in ms:
            prod *= m
        if prod >= size:
            return tuple(ms)
        base += 1
