"""Complementary partitions of a category set (paper §3).

A partition of ``S = {0, ..., size-1}`` is represented by a bucketing
function ``idx -> bucket`` with ``num_buckets`` buckets; equivalence classes
are the preimages of buckets.  A family ``P_1..P_k`` is *complementary*
(Definition 1) iff the code tuple ``x -> (p_1(x), ..., p_k(x))`` is
injective on S — i.e. any two distinct categories land in different buckets
under at least one partition.

All ``bucket`` implementations are pure jnp and safe to call under jit with
traced index arrays (they are also fine with plain numpy ints).
"""

from __future__ import annotations

import dataclasses
import math
from functools import reduce
from typing import Sequence

import jax.numpy as jnp
import numpy as np

__all__ = [
    "Partition",
    "RemainderPartition",
    "QuotientPartition",
    "GeneralizedQRPartition",
    "ExplicitPartition",
    "naive_partition",
    "qr_partitions",
    "generalized_qr_partitions",
    "crt_partitions",
    "is_complementary",
    "codes_for",
    "min_collision_free_m",
]


@dataclasses.dataclass(frozen=True)
class Partition:
    """Base class: a partition of {0..size-1} into ``num_buckets`` buckets."""

    size: int
    num_buckets: int

    def bucket(self, idx):  # pragma: no cover - abstract
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class RemainderPartition(Partition):
    """``p(x) = x mod m`` (paper §3.1 ex. 2, the 'hashing trick' partition)."""

    m: int = 1

    def bucket(self, idx):
        return jnp.asarray(idx) % self.m


@dataclasses.dataclass(frozen=True)
class QuotientPartition(Partition):
    """``p(x) = x \\ m`` (integer division; paper §3.1 ex. 2)."""

    m: int = 1

    def bucket(self, idx):
        return jnp.asarray(idx) // self.m


@dataclasses.dataclass(frozen=True)
class GeneralizedQRPartition(Partition):
    """``p(x) = (x \\ M_j) mod m_j`` — mixed-radix digit (paper §3.1 ex. 3)."""

    divisor: int = 1  # M_j = prod_{i<j} m_i
    modulus: int = 1  # m_j

    def bucket(self, idx):
        return (jnp.asarray(idx) // self.divisor) % self.modulus


@dataclasses.dataclass(frozen=True)
class ExplicitPartition(Partition):
    """Partition given by an explicit bucket table (e.g. car make/year).

    ``table[i]`` is the bucket of category ``i``.  Covers the paper's
    "inherent characteristics" partitions; the table lives on host as numpy
    and is closed over as a constant under jit.
    """

    table: np.ndarray = None  # type: ignore[assignment]

    def bucket(self, idx):
        return jnp.asarray(self.table)[jnp.asarray(idx)]


def naive_partition(size: int) -> list[Partition]:
    """Singleton partition — full embedding table (paper §3.1 ex. 1)."""
    return [GeneralizedQRPartition(size=size, num_buckets=size, divisor=1, modulus=size)]


def qr_partitions(size: int, m: int) -> list[Partition]:
    """Quotient–remainder pair (paper §2 / §3.1 ex. 2).

    ``m`` is the remainder-table size (the paper's "number of hash
    collisions" per row is ~size/m ... actually collisions = size/m for the
    remainder table alone; QR keeps uniqueness via the quotient table of
    ``ceil(size/m)`` rows).
    """
    if not (1 <= m <= size):
        raise ValueError(f"m={m} must be in [1, size={size}]")
    q = math.ceil(size / m)
    return [
        RemainderPartition(size=size, num_buckets=m, m=m),
        QuotientPartition(size=size, num_buckets=q, m=m),
    ]


def generalized_qr_partitions(size: int, ms: Sequence[int]) -> list[Partition]:
    """Mixed-radix decomposition into k digits (paper §3.1 ex. 3)."""
    ms = list(ms)
    if reduce(lambda a, b: a * b, ms, 1) < size:
        raise ValueError(f"prod({ms}) < size={size}: partitions not complementary")
    parts: list[Partition] = []
    divisor = 1
    for m in ms:
        parts.append(
            GeneralizedQRPartition(size=size, num_buckets=m, divisor=divisor, modulus=m)
        )
        divisor *= m
    return parts


def crt_partitions(size: int, ms: Sequence[int]) -> list[Partition]:
    """Chinese-remainder partitions (paper §3.1 ex. 4): pairwise-coprime moduli."""
    ms = list(ms)
    for i in range(len(ms)):
        for j in range(i + 1, len(ms)):
            if math.gcd(ms[i], ms[j]) != 1:
                raise ValueError(f"moduli {ms[i]} and {ms[j]} are not coprime")
    if reduce(lambda a, b: a * b, ms, 1) < size:
        raise ValueError(f"prod({ms}) < size={size}: CRT map not injective on S")
    return [RemainderPartition(size=size, num_buckets=m, m=m) for m in ms]


def codes_for(partitions: Sequence[Partition], idx) -> jnp.ndarray:
    """Stack of bucket codes, shape ``idx.shape + (k,)``."""
    return jnp.stack([p.bucket(idx) for p in partitions], axis=-1)


def is_complementary(partitions: Sequence[Partition], size: int | None = None) -> bool:
    """Brute-force Definition 1 check: code tuples injective on {0..size-1}.

    Intended for tests and config validation on modest ``size``; the
    constructors above are complementary by theorem (proofs in the paper's
    appendix), this verifies arbitrary/explicit families.
    """
    size = size if size is not None else partitions[0].size
    idx = np.arange(size)
    codes = np.stack([np.asarray(p.bucket(idx)) for p in partitions], axis=-1)
    return len(np.unique(codes, axis=0)) == size


def min_collision_free_m(size: int) -> int:
    """The m minimising total QR rows m + ceil(size/m): m* = ceil(sqrt(size))."""
    return max(1, math.isqrt(size - 1) + 1) if size > 1 else 1
