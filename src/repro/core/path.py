"""Path-based compositional embeddings (paper §4.1, eq. 7).

The first partition indexes a base embedding table; every further partition
selects a *transformation* (here a 1-hidden-layer MLP, matching the paper's
§5.5 experiments) from a per-bucket parameter bank, and the embedding is the
composition ``M_{k,p_k(x)} ∘ ... ∘ M_{2,p_2(x)} (W e_{p_1(x)})``.

Per-bucket MLP parameters are stored stacked ``(num_buckets, ...)`` and
gathered by bucket index, so the whole lookup stays a fixed-shape gather +
einsum program (pjit/scan friendly; no per-example python control flow).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .partitions import Partition

__all__ = ["PathBasedEmbedding"]


@dataclasses.dataclass(frozen=True)
class PathBasedEmbedding:
    num_categories: int
    dim: int
    partitions: tuple[Partition, ...] = ()
    hidden: int = 64  # paper sweeps {16, 32, 64, 128}; 64 is their best
    param_dtype: jnp.dtype = jnp.float32

    def __post_init__(self):
        if len(self.partitions) < 2:
            raise ValueError("path-based embeddings need >= 2 partitions")

    def init(self, key):
        k0, *keys = jax.random.split(key, 2 * len(self.partitions))
        scale = (1.0 / self.num_categories) ** 0.5
        params = {
            "table": jax.random.uniform(
                k0, (self.partitions[0].num_buckets, self.dim),
                minval=-scale, maxval=scale, dtype=self.param_dtype,
            )
        }
        d, h = self.dim, self.hidden
        for j, part in enumerate(self.partitions[1:], start=1):
            ka, kb = keys[2 * j - 2], keys[2 * j - 1]
            n = part.num_buckets
            # LeCun-uniform per slice; biases zero.
            params[f"mlp_{j}"] = {
                "w1": jax.random.uniform(ka, (n, d, h), minval=-(1 / d) ** 0.5,
                                         maxval=(1 / d) ** 0.5, dtype=self.param_dtype),
                "b1": jnp.zeros((n, h), self.param_dtype),
                "w2": jax.random.uniform(kb, (n, h, d), minval=-(1 / h) ** 0.5,
                                         maxval=(1 / h) ** 0.5, dtype=self.param_dtype),
                "b2": jnp.zeros((n, d), self.param_dtype),
            }
        return params

    def apply(self, params, idx):
        idx = jnp.asarray(idx)
        h = jnp.take(params["table"], self.partitions[0].bucket(idx), axis=0)
        for j, part in enumerate(self.partitions[1:], start=1):
            b = part.bucket(idx)
            mlp = params[f"mlp_{j}"]
            w1 = jnp.take(mlp["w1"], b, axis=0)  # (..., D, H)
            b1 = jnp.take(mlp["b1"], b, axis=0)
            w2 = jnp.take(mlp["w2"], b, axis=0)  # (..., H, D)
            b2 = jnp.take(mlp["b2"], b, axis=0)
            h = jax.nn.relu(jnp.einsum("...d,...dh->...h", h, w1) + b1)
            h = jnp.einsum("...h,...hd->...d", h, w2) + b2
        return h

    @property
    def num_params(self) -> int:
        n = self.partitions[0].num_buckets * self.dim
        d, h = self.dim, self.hidden
        for part in self.partitions[1:]:
            n += part.num_buckets * (d * h + h + h * d + d)
        return n

    @property
    def out_dim(self) -> int:
        return self.dim
