"""The paper's contribution: compositional embeddings over complementary partitions."""

from .compositional import (
    CompositionalEmbedding,
    FullEmbedding,
    HashEmbedding,
    bag_pool,
    is_quantized_table,
    qr_embedding,
    table_rows,
)
from .factory import EmbeddingSpec, make_embedding
from .partitions import (
    ExplicitPartition,
    GeneralizedQRPartition,
    Partition,
    QuotientPartition,
    RemainderPartition,
    codes_for,
    crt_partitions,
    generalized_qr_partitions,
    is_complementary,
    min_collision_free_m,
    naive_partition,
    qr_partitions,
)
from .path import PathBasedEmbedding

__all__ = [
    "CompositionalEmbedding", "FullEmbedding", "HashEmbedding", "bag_pool",
    "qr_embedding", "table_rows", "is_quantized_table", "EmbeddingSpec",
    "make_embedding", "Partition",
    "RemainderPartition", "QuotientPartition", "GeneralizedQRPartition",
    "ExplicitPartition", "codes_for", "crt_partitions",
    "generalized_qr_partitions", "is_complementary", "min_collision_free_m",
    "naive_partition", "qr_partitions", "PathBasedEmbedding",
]
