"""Layer-2 jaxpr walker: every ``dot_general``/``reduce_sum`` reachable
from the registered kernel programs must accumulate in f32 (or wider).

This makes the PR 2 f32-accumulation audit permanent: each program in
``programs.kernel_programs`` traces to a jaxpr (``pallas_call`` bodies
and control-flow branches included, recursively) and every floating-
point contraction/reduction equation must produce an f32+ output.  A
bf16 ``reduce_sum`` — the L-adds-each-round bug the embedding-bag audit
originally caught at L=16, D=128 — fails here without ever touching
hardware.

Integer reductions (mask counts, index arithmetic) are exempt; so is
anything already f32/f64 on the way in.
"""

from __future__ import annotations

from .findings import Finding
from .registry import Context, register_pass
from .programs import kernel_programs

__all__ = ["iter_equations", "audit_program"]

_AUDITED_PRIMITIVES = ("dot_general", "reduce_sum")


def _subjaxprs(params: dict):
    """Jaxpr-valued params of an equation — pallas_call's ``jaxpr``,
    cond branches, scan/while bodies — discovered structurally so new
    higher-order primitives are covered without a registry."""
    import jax.core as jcore
    closed = getattr(jcore, "ClosedJaxpr", ())
    plain = getattr(jcore, "Jaxpr", ())
    for v in params.values():
        for item in (v if isinstance(v, (list, tuple)) else (v,)):
            if isinstance(item, closed):
                yield item.jaxpr
            elif isinstance(item, plain):
                yield item


def iter_equations(jaxpr):
    """Depth-first over every equation, recursing into sub-jaxprs."""
    for eqn in jaxpr.eqns:
        yield eqn
        for sub in _subjaxprs(eqn.params):
            yield from iter_equations(sub)


def _is_float(aval) -> bool:
    # jnp.issubdtype, not np: bfloat16 is an ml_dtypes extension that
    # numpy's hierarchy does not classify as floating
    import jax.numpy as jnp
    dtype = getattr(aval, "dtype", None)
    return dtype is not None and jnp.issubdtype(dtype, jnp.floating)


def _is_narrow(aval) -> bool:
    import numpy as np
    return _is_float(aval) and np.dtype(aval.dtype).itemsize < 4


def audit_program(fn, args, name: str) -> list[Finding]:
    """Trace ``fn(*args)`` and flag narrow-accumulating equations."""
    import jax
    findings = []
    try:
        closed = jax.make_jaxpr(fn)(*args)
    except Exception as e:
        return [Finding(rule="ACC-002", path=f"analysis://jaxpr/{name}",
                        line=0, layer=2,
                        message=f"program failed to trace: {e!r}")]
    for eqn in iter_equations(closed.jaxpr):
        prim = eqn.primitive.name
        if prim not in _AUDITED_PRIMITIVES:
            continue
        if not any(_is_float(v.aval) for v in eqn.invars
                   if hasattr(v, "aval")):
            continue   # integer/bool reduction: not an accumulation hazard
        narrow = [v for v in eqn.outvars if _is_narrow(v.aval)]
        if narrow:
            dtypes = ", ".join(str(v.aval.dtype) for v in narrow)
            findings.append(Finding(
                rule="ACC-002", path=f"analysis://jaxpr/{name}", line=0,
                layer=2,
                message=f"{prim} accumulates in {dtypes} (< f32) — "
                        "upcast operands or set preferred_element_type"))
    return findings


@register_pass("ACC-002", "jaxpr-f32-accumulation", 2,
               "traced dot_general/reduce_sum from kernel programs "
               "must accumulate in f32")
def jaxpr_pass(ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    audited = []
    for prog in kernel_programs():
        fn, args = prog.build()
        findings += audit_program(fn, args, prog.name)
        audited.append(prog.name)
    ctx.notes["ACC-002"] = {"programs_audited": audited}
    return findings
