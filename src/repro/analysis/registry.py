"""Pluggable pass registry + shared analysis context.

A *pass* is a function ``(ctx: Context) -> list[Finding]`` registered
under a stable rule id.  Layer 1 passes are pure-AST (stdlib ``ast``
over the source tree, no jax import); layer 2 passes trace or compile
real programs to jaxpr/HLO — never to hardware — so they need jax and a
(possibly forced-host-device) backend.

The CLI runs every registered pass by default; ``--select``/``--skip``
and ``--layer`` narrow the set.  New invariants plug in by decorating a
function with :func:`register_pass` from any module imported by
``analysis.cli`` — the registry is the extension point the ISSUE's
"candidate zoo about to grow" concern asks for.
"""

from __future__ import annotations

import ast
import dataclasses
import os
from typing import Callable

from .findings import Finding

__all__ = ["PassInfo", "PASSES", "register_pass", "Context",
           "DEFAULT_SCAN_DIRS", "EXCLUDE_PARTS"]

# directories scanned by AST passes, relative to the repo root
DEFAULT_SCAN_DIRS = ("src", "benchmarks", "tests")
# path components that exclude a file from the default scan: the seeded-
# violation fixtures *must* trip the analyzer when pointed at directly,
# and must not fail the clean-tree gate
EXCLUDE_PARTS = ("analysis_fixtures", "__pycache__", ".git")


@dataclasses.dataclass(frozen=True)
class PassInfo:
    id: str                    # rule id, e.g. "ACC-001"
    name: str                  # short slug, e.g. "kernel-accumulation"
    layer: int                 # 1 = AST, 2 = trace-level
    description: str
    fn: Callable[["Context"], list[Finding]]


PASSES: dict[str, PassInfo] = {}


def register_pass(id: str, name: str, layer: int, description: str):
    def deco(fn):
        if id in PASSES:
            raise ValueError(f"duplicate analysis pass id {id!r}")
        PASSES[id] = PassInfo(id=id, name=name, layer=layer,
                              description=description, fn=fn)
        return fn
    return deco


class Context:
    """Shared state for one analyzer run: the scan root, parsed-AST cache,
    and knobs the CLI threads through (extra plan paths, fixture paths).

    ``paths`` (when given) replaces the default ``src``/``benchmarks``/
    ``tests`` walk — the fixture tests point a context straight at one
    seeded-violation file.
    """

    def __init__(self, root: str = ".", *, paths: list[str] | None = None,
                 plan_paths: list[str] | None = None):
        self.root = os.path.abspath(root)
        self.paths = paths
        self.plan_paths = list(plan_paths or [])
        self._sources: dict[str, str] | None = None
        self._trees: dict[str, ast.AST] = {}
        self.notes: dict[str, object] = {}   # per-pass scratch/telemetry

    # ------------------------------------------------------------ sources

    def _walk(self) -> list[str]:
        if self.paths is not None:
            out = []
            for p in self.paths:
                p = p if os.path.isabs(p) else os.path.join(self.root, p)
                if os.path.isdir(p):
                    for dirpath, dirnames, filenames in os.walk(p):
                        dirnames[:] = [d for d in dirnames
                                       if d not in EXCLUDE_PARTS]
                        out += [os.path.join(dirpath, f) for f in filenames
                                if f.endswith(".py")]
                elif p.endswith(".py"):
                    out.append(p)
            return sorted(out)
        out = []
        for base in DEFAULT_SCAN_DIRS:
            top = os.path.join(self.root, base)
            if not os.path.isdir(top):
                continue
            for dirpath, dirnames, filenames in os.walk(top):
                dirnames[:] = [d for d in dirnames
                               if d not in EXCLUDE_PARTS]
                if any(part in EXCLUDE_PARTS
                       for part in dirpath.split(os.sep)):
                    continue
                out += [os.path.join(dirpath, f) for f in filenames
                        if f.endswith(".py")]
        return sorted(out)

    def sources(self) -> dict[str, str]:
        """repo-relative path -> file text, cached for the whole run."""
        if self._sources is None:
            self._sources = {}
            for p in self._walk():
                rel = os.path.relpath(p, self.root)
                try:
                    with open(p, encoding="utf-8") as f:
                        self._sources[rel] = f.read()
                except OSError:
                    continue
        return self._sources

    def tree(self, rel_path: str) -> ast.AST | None:
        if rel_path not in self._trees:
            text = self.sources().get(rel_path)
            if text is None:
                return None
            try:
                self._trees[rel_path] = ast.parse(text, filename=rel_path)
            except SyntaxError:
                self._trees[rel_path] = None  # ruff's E9 lane owns these
        return self._trees[rel_path]

    def iter_trees(self):
        for rel in self.sources():
            t = self.tree(rel)
            if t is not None:
                yield rel, t
