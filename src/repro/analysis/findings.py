"""Findings, ``# repro: noqa[RULE-ID]`` suppressions, and output formats.

A :class:`Finding` is one violation of one registered rule, anchored to a
repo-relative path and 1-based line (layer-2 auditors that certify traced
programs rather than source lines anchor to the *program registry* entry
that failed, with line 0 — there is no source line to suppress, which is
deliberate: trace-level invariants cannot be waived inline).

Suppression follows the linter convention the repo already uses for ruff,
with a namespaced marker so the two never collide::

    acc = rows.sum(axis=0)          # repro: noqa[ACC-001] scratch is f32
    t0 = time.monotonic()           # repro: noqa — host-side metrics

``# repro: noqa[A, B]`` waives rules A and B on that line; a bare
``# repro: noqa`` waives every rule.  Suppressed findings stay in the
JSON report (``suppressed: true``) so CI artifacts show what was waived,
but they do not fail the run.
"""

from __future__ import annotations

import dataclasses
import json
import re

__all__ = ["Finding", "suppressions_for", "apply_suppressions",
           "format_findings", "report_dict", "FORMATS"]

FORMATS = ("human", "json", "github")

_NOQA_RE = re.compile(r"#\s*repro:\s*noqa(?:\[([A-Za-z0-9_,\s-]+)\])?")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str            # registered rule id, e.g. "ACC-001"
    path: str            # repo-relative path ("analysis://..." for layer 2)
    line: int            # 1-based; 0 = not source-anchored
    message: str
    layer: int = 1
    suppressed: bool = False

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def suppressions_for(text: str) -> dict[int, frozenset[str] | None]:
    """Map of 1-based line -> waived rule ids (``None`` = all rules)."""
    out: dict[int, frozenset[str] | None] = {}
    for i, line in enumerate(text.splitlines(), start=1):
        m = _NOQA_RE.search(line)
        if not m:
            continue
        rules = m.group(1)
        if rules is None:
            out[i] = None
        else:
            ids = frozenset(r.strip().upper() for r in rules.split(",")
                            if r.strip())
            out[i] = ids or None
    return out


def apply_suppressions(findings: list[Finding],
                       text_for: dict[str, str]) -> list[Finding]:
    """Mark findings whose anchor line carries a matching noqa.

    ``text_for`` maps repo-relative path -> file text (the analyzer's
    source cache); findings for paths outside it pass through unchanged.
    """
    cache: dict[str, dict] = {}
    out = []
    for f in findings:
        text = text_for.get(f.path)
        if text is None or f.line <= 0:
            out.append(f)
            continue
        if f.path not in cache:
            cache[f.path] = suppressions_for(text)
        waived = cache[f.path].get(f.line, ...)
        if waived is ... :
            out.append(f)
        elif waived is None or f.rule.upper() in waived:
            out.append(dataclasses.replace(f, suppressed=True))
        else:
            out.append(f)
    return out


def report_dict(findings: list[Finding], passes: list[dict],
                root: str) -> dict:
    """The JSON artifact: every finding (suppressed ones marked), the
    per-pass roll-up, and the overall verdict CI gates on."""
    live = [f for f in findings if not f.suppressed]
    return {
        "root": root,
        "ok": not live,
        "findings": [f.as_dict() for f in findings],
        "counts": {"total": len(findings), "unsuppressed": len(live),
                   "suppressed": len(findings) - len(live)},
        "passes": passes,
    }


def _human(findings: list[Finding]) -> str:
    lines = []
    for f in findings:
        sup = "  [suppressed]" if f.suppressed else ""
        anchor = f"{f.path}:{f.line}" if f.line > 0 else f.path
        lines.append(f"{anchor}: {f.rule} {f.message}{sup}")
    live = sum(1 for f in findings if not f.suppressed)
    lines.append(f"{live} finding(s), "
                 f"{len(findings) - live} suppressed")
    return "\n".join(lines)


def _github(findings: list[Finding]) -> str:
    """GitHub workflow annotations: ``::error`` per unsuppressed finding
    (suppressed ones become notices so the waiver stays visible)."""
    lines = []
    for f in findings:
        kind = "notice" if f.suppressed else "error"
        msg = f"{f.rule} {f.message}".replace("%", "%25") \
            .replace("\r", "%0D").replace("\n", "%0A")
        loc = f"file={f.path},line={max(f.line, 1)}" if f.line > 0 \
            else f"file={f.path}"
        lines.append(f"::{kind} {loc},title={f.rule}::{msg}")
    live = sum(1 for f in findings if not f.suppressed)
    lines.append(f"{live} unsuppressed finding(s)")
    return "\n".join(lines)


def format_findings(findings: list[Finding], fmt: str, *,
                    passes: list[dict] | None = None,
                    root: str = ".") -> str:
    if fmt == "human":
        return _human(findings)
    if fmt == "github":
        return _github(findings)
    if fmt == "json":
        return json.dumps(report_dict(findings, passes or [], root),
                          indent=2)
    raise ValueError(f"format {fmt!r} not in {FORMATS}")
