"""``python -m repro.analysis`` — see ``cli``.

The host-device count must be forced *before* jax initializes: the
wire auditor compiles real collectives and refuses to run vacuously on
a single device.  Respecting an explicit XLA_FLAGS lets CI (or a user)
choose its own mesh size.
"""

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

from repro.analysis.cli import main  # noqa: E402

main()
