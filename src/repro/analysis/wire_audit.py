"""Layer-2 collective auditor: ``dist.accounting`` closed forms must
equal lowered-HLO wire bytes for every registered exchange — exactly.

Generalizes the dist_bench / serve_dist_bench spot checks into one pass
over a program registry.  Each program is the *pure exchange* (not a
full train step): the dp compressed all-reduce (``ef_psum_grads``), the
FSDP compressed reduce-scatter + f32 param all-gather, and the sharded
serve row exchange (``exchange_rows``), compiled on the host mesh and
priced by ``launch.hlo_analysis.analyze_hlo``.  Pure exchanges carry no
optimizer fusion noise, so the tolerance is **zero bytes** — any drift
between a closed form and what XLA actually puts on the wire is a bug
in one of them.

Programs compile to HLO text only — nothing executes.  Needs >= 2
devices (CI forces 8 host devices via XLA_FLAGS); on one device the
pass emits a loud finding rather than passing vacuously.

``REPRO_ANALYSIS_INJECT=wire`` perturbs the closed form (test hook,
mirroring ``REPRO_BENCH_INJECT_ERROR``) so the fixture suite can prove
a real mismatch fails the run.
"""

from __future__ import annotations

import os

from .findings import Finding
from .registry import Context, register_pass

__all__ = ["wire_programs", "audit_exchange"]

_RULE = "WIRE-001"


def _mesh_and_n():
    import jax
    n = jax.device_count()
    if n < 2:
        return None, n
    return jax.make_mesh((n,), ("data",)), n


def _dp_psum(mode: str):
    """(name, build) for the compressed dp mean-all-reduce of a small
    grads tree — the exchange ``make_dp_train_step`` runs per step."""
    def build(mesh, n):
        import jax
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from ..dist import accounting
        from ..dist.compress import ef_psum_grads, init_error_state
        grads = {"table": jnp.zeros((64, 16)), "w": jnp.zeros((33, 7)),
                 "b": jnp.zeros((7,))}
        err = init_error_state(grads)

        def body(g, e):
            return ef_psum_grads(g, e, axis_name="data", mode=mode)

        fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(P(), P()),
                               out_specs=(P(), P()), check_rep=False))
        lowered = fn.lower(grads, err)
        closed = accounting.grad_wire_bytes(
            grads, mode, n, pattern="all_reduce")["total_bytes"]
        return lowered, closed
    return f"dp_psum[{mode}]", build


def _fsdp(mode: str):
    """Compressed reduce-scatter per leaf + f32 all-gather of the updated
    shard — the two collectives of ``make_fsdp_train_step``."""
    def build(mesh, n):
        import math
        import jax
        import jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from ..dist import accounting
        from ..dist.compress import _reduce_scatter_leaf, init_error_state
        leaves = {"table": jnp.zeros((64, 16)), "w": jnp.zeros((40, 8))}
        err = init_error_state(leaves)

        def body(g, e):
            outs, new_e = {}, {}
            for k in g:
                shard, ne = _reduce_scatter_leaf(g[k], e[k], "data", mode, 0)
                outs[k] = jax.lax.all_gather(shard, "data", tiled=True)
                new_e[k] = ne
            return outs, new_e

        fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(P(), P()),
                               out_specs=(P(), P()), check_rep=False))
        lowered = fn.lower(leaves, err)
        closed = sum(
            accounting.leaf_reduce_bytes(mode, math.prod(v.shape), n,
                                         pattern="reduce_scatter")
            + accounting.ring_all_gather_bytes(4.0 * math.prod(v.shape), n)
            for v in leaves.values())
        return lowered, closed
    return f"fsdp_rs_gather[{mode}]", build


def _serve_exchange(quantized: bool):
    """The two-phase sharded-serve row fetch (``exchange_rows``) for one
    sub-table and wave."""
    def build(mesh, n):
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from ..dist import accounting
        from ..dist.serve_placement import exchange_rows
        from ..serve.quantize import quantize_table
        rows_total, width, lookups = 8 * n, 16, 24
        rpd = rows_total // n
        rng = np.random.default_rng(0)
        w = jnp.asarray(rng.normal(size=(rows_total, width)), jnp.float32)
        ids = jnp.asarray(rng.integers(0, rows_total, (n, lookups)),
                          jnp.int32)
        leaf = quantize_table(w) if quantized else w
        spec = ({"q": P("data"), "scale": P("data"), "zp": P("data")}
                if quantized else P("data"))

        def body(leaf, ids):
            return exchange_rows(leaf, ids, n, rpd, axis="data")

        fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(spec, P("data")),
                               out_specs=P("data"), check_rep=False))
        lowered = fn.lower(leaf, ids)
        closed = accounting.serve_exchange_wire_bytes(
            lookups, width, n, quantized=quantized,
            row_dtype_bytes=4)["total_bytes"]
        return lowered, closed
    return f"serve_exchange[{'int8' if quantized else 'f32'}]", build


def wire_programs():
    """Every registered (name, build) exchange the auditor certifies."""
    progs = [_dp_psum(m) for m in ("none", "bf16", "int8")]
    progs += [_fsdp(m) for m in ("none", "bf16", "int8")]
    progs += [_serve_exchange(q) for q in (False, True)]
    return progs


def audit_exchange(name, build, mesh, n) -> tuple[Finding | None, dict]:
    """Compile one exchange and compare closed-form vs HLO bytes."""
    from ..launch.hlo_analysis import analyze_hlo
    anchor = f"analysis://wire/{name}"
    try:
        lowered, closed = build(mesh, n)
        compiled = lowered.compile()
        cost = analyze_hlo(compiled.as_text(), total_devices=n)
    except Exception as e:
        return (Finding(rule=_RULE, path=anchor, line=0, layer=2,
                        message=f"exchange failed to compile: {e!r}"),
                {"name": name, "error": repr(e)})
    if os.environ.get("REPRO_ANALYSIS_INJECT") == "wire":
        closed += 64.0   # test hook: prove a mismatch fails the run
    row = {"name": name, "closed_form_bytes": closed,
           "hlo_bytes": cost.collective_bytes, "devices": n}
    if abs(closed - cost.collective_bytes) > 1e-6:
        return (Finding(
            rule=_RULE, path=anchor, line=0, layer=2,
            message=f"accounting closed form ({closed:.0f} B) != compiled "
                    f"HLO wire bytes ({cost.collective_bytes:.0f} B) on "
                    f"{n} devices — dist.accounting and the lowered "
                    "exchange have drifted apart"), row)
    return None, row


@register_pass(_RULE, "wire-accounting", 2,
               "dist.accounting closed forms == lowered-HLO wire bytes "
               "for every registered exchange")
def wire_pass(ctx: Context) -> list[Finding]:
    mesh, n = _mesh_and_n()
    if mesh is None:
        return [Finding(
            rule=_RULE, path="analysis://wire", line=0, layer=2,
            message=f"only {n} device(s) visible — the wire audit needs a "
                    "multi-device mesh (set XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8); refusing "
                    "to pass vacuously")]
    findings, rows = [], []
    for name, build in wire_programs():
        f, row = audit_exchange(name, build, mesh, n)
        rows.append(row)
        if f is not None:
            findings.append(f)
    ctx.notes[_RULE] = {"exchanges": rows}
    return findings
