"""Registry of traceable exemplar programs for the layer-2 auditors.

Each entry builds ``(fn, args)`` pairs ready for ``jax.make_jaxpr`` (the
f32-accumulation audit) with *worst-case* low-precision operands: bf16
tables wherever the kernel accepts dense tables, int8 + meta on the
quantized paths.  If a kernel accumulates in its input dtype anywhere,
these programs — not a lucky f32 default — are what exposes it.

Programs trace only — nothing here runs to hardware.  The registry is
the extension point: a new kernel family registers its exemplar here and
is certified on every analyzer run from then on.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

__all__ = ["KernelProgram", "kernel_programs"]


@dataclasses.dataclass(frozen=True)
class KernelProgram:
    name: str
    build: Callable[[], tuple]   # () -> (fn, args tuple)
    notes: str = ""


def _bf16_qr_bag_kernel():
    import jax.numpy as jnp
    import numpy as np
    from ..kernels.embedding_bag import qr_embedding_bag
    rng = np.random.default_rng(0)
    b, l, m, q, d = 4, 8, 16, 8, 32
    rem = jnp.asarray(rng.integers(0, m, (b, l)), jnp.int32)
    quo = jnp.asarray(rng.integers(0, q, (b, l)), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, (b, l)), jnp.float32)
    w_rem = jnp.asarray(rng.normal(size=(m, d)), jnp.bfloat16)
    w_quo = jnp.asarray(rng.normal(size=(q, d)), jnp.bfloat16)

    def fn(rem, quo, mask, w_rem, w_quo):
        return qr_embedding_bag(rem, quo, mask, w_rem, w_quo, op="mult",
                                interpret=True)
    return fn, (rem, quo, mask, w_rem, w_quo)


def _bf16_qr_gather_kernel():
    import jax.numpy as jnp
    import numpy as np
    from ..kernels.qr_gather import qr_gather
    rng = np.random.default_rng(1)
    n, m, q, d = 32, 16, 8, 32
    rem = jnp.asarray(rng.integers(0, m, (n,)), jnp.int32)
    quo = jnp.asarray(rng.integers(0, q, (n,)), jnp.int32)
    w_rem = jnp.asarray(rng.normal(size=(m, d)), jnp.bfloat16)
    w_quo = jnp.asarray(rng.normal(size=(q, d)), jnp.bfloat16)

    def fn(rem, quo, w_rem, w_quo):
        return qr_gather(rem, quo, w_rem, w_quo, op="add", interpret=True)
    return fn, (rem, quo, w_rem, w_quo)


def _int8_qr_gather_kernel():
    import jax.numpy as jnp
    import numpy as np
    from ..kernels.qr_gather import qr_gather_quant
    rng = np.random.default_rng(2)
    n, m, q, d = 32, 16, 8, 32
    rem = jnp.asarray(rng.integers(0, m, (n,)), jnp.int32)
    quo = jnp.asarray(rng.integers(0, q, (n,)), jnp.int32)
    w_rem = jnp.asarray(rng.integers(-127, 128, (m, d)), jnp.int8)
    w_quo = jnp.asarray(rng.integers(-127, 128, (q, d)), jnp.int8)
    rm = jnp.asarray(rng.uniform(0.01, 0.1, (m, 2)), jnp.float32)
    qm = jnp.asarray(rng.uniform(0.01, 0.1, (q, 2)), jnp.float32)

    def fn(rem, quo, w_rem, w_quo, rm, qm):
        return qr_gather_quant(rem, quo, w_rem, w_quo, rm, qm,
                               op="mult", interpret=True)
    return fn, (rem, quo, w_rem, w_quo, rm, qm)


def _bf16_fused_serve_kernel():
    import jax.numpy as jnp
    import numpy as np
    from ..kernels.serve_path import fused_serve_pool
    rng = np.random.default_rng(3)
    b, l, m, d, d_out = 4, 8, 16, 16, 32
    idx = jnp.asarray(rng.integers(0, m, (b, l)), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, (b, l)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(m, d)), jnp.bfloat16)
    proj = jnp.asarray(rng.normal(size=(d, d_out)), jnp.bfloat16)

    def fn(idx, mask, w, proj):
        return fused_serve_pool(idx, mask, w, proj=proj, interpret=True)
    return fn, (idx, mask, w, proj)


def _int8_fused_serve_kernel():
    import jax.numpy as jnp
    import numpy as np
    from ..kernels.serve_path import fused_serve_pool
    rng = np.random.default_rng(4)
    b, l, m, d = 4, 8, 16, 32
    idx_a = jnp.asarray(rng.integers(0, m, (b, l)), jnp.int32)
    idx_b = jnp.asarray(rng.integers(0, m, (b, l)), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, (b, l)), jnp.float32)
    w_a = jnp.asarray(rng.integers(-127, 128, (m, d)), jnp.int8)
    w_b = jnp.asarray(rng.integers(-127, 128, (m, d)), jnp.int8)
    meta = jnp.asarray(rng.uniform(0.01, 0.1, (m, 2)), jnp.float32)

    def fn(idx_a, mask, w_a, idx_b, w_b, meta):
        return fused_serve_pool(idx_a, mask, w_a, idx_b=idx_b, w_b=w_b,
                                meta_a=meta, meta_b=meta, op="mult",
                                interpret=True)
    return fn, (idx_a, mask, w_a, idx_b, w_b, meta)


def _bf16_qr_bag_jnp():
    import jax.numpy as jnp
    import numpy as np
    from ..kernels.ops import qr_bag_lookup
    rng = np.random.default_rng(5)
    b, l, m, q, d = 4, 8, 16, 8, 32
    idx = jnp.asarray(rng.integers(0, m * q, (b, l)), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, (b, l)), jnp.float32)
    w_rem = jnp.asarray(rng.normal(size=(m, d)), jnp.bfloat16)
    w_quo = jnp.asarray(rng.normal(size=(q, d)), jnp.bfloat16)

    def fn(idx, mask, w_rem, w_quo):
        return qr_bag_lookup(idx, mask, w_rem, w_quo, op="concat",
                             use_kernel=False)
    return fn, (idx, mask, w_rem, w_quo)


def _bf16_bag_pool():
    import jax
    import jax.numpy as jnp
    import numpy as np
    from ..core.compositional import bag_pool, qr_embedding
    rng = np.random.default_rng(6)
    size, d, b, l = 96, 32, 4, 8
    mod = qr_embedding(size, d, num_collisions=4, op="mult",
                       param_dtype=jnp.bfloat16)
    params = mod.init(jax.random.PRNGKey(0))
    idx = jnp.asarray(rng.integers(0, size, (b, l)), jnp.int32)
    mask = jnp.asarray(rng.integers(0, 2, (b, l)), jnp.float32)

    def fn(params, idx, mask):
        return bag_pool(mod, params, idx, mask=mask)
    return fn, (params, idx, mask)


def _bf16_dot_interaction():
    import jax.numpy as jnp
    import numpy as np
    from ..kernels.dot_interaction import dot_interaction
    rng = np.random.default_rng(7)
    b, f, d = 8, 4, 16
    x = jnp.asarray(rng.normal(size=(b, f, d)), jnp.bfloat16)

    def fn(x):
        return dot_interaction(x, interpret=True)
    return fn, (x,)


def kernel_programs() -> list[KernelProgram]:
    """Every serve/train-kernel-reachable program the f32-accumulation
    audit certifies, with worst-case bf16/int8 operands."""
    return [
        KernelProgram("embedding_bag.qr_embedding_bag[bf16]",
                      _bf16_qr_bag_kernel,
                      "fused QR bag kernel, bf16 tables"),
        KernelProgram("qr_gather.qr_gather[bf16]", _bf16_qr_gather_kernel,
                      "fused QR gather kernel, bf16 tables"),
        KernelProgram("qr_gather.qr_gather_quant[int8]",
                      _int8_qr_gather_kernel,
                      "fused int8-dequant QR gather kernel"),
        KernelProgram("serve_path.fused_serve_pool[bf16+proj]",
                      _bf16_fused_serve_kernel,
                      "fused serve kernel, bf16 table + projection"),
        KernelProgram("serve_path.fused_serve_pool[int8 qr]",
                      _int8_fused_serve_kernel,
                      "fused serve kernel, quantized QR pair"),
        KernelProgram("ops.qr_bag_lookup[bf16 jnp]", _bf16_qr_bag_jnp,
                      "jnp fallback bag path (concat op), bf16 tables"),
        KernelProgram("compositional.bag_pool[bf16 qr]", _bf16_bag_pool,
                      "model-side pooled lookup, bf16 QR module"),
        KernelProgram("dot_interaction.dot_interaction[bf16]",
                      _bf16_dot_interaction,
                      "DLRM pairwise-dot kernel, bf16 features"),
    ]
