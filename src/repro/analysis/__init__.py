"""``repro.analysis`` — static invariant checker for the repo's
hardest-won properties (see the module docstrings of each pass):

* layer 1 — pure-AST lint passes (``ast_passes``), no jax needed;
* layer 2 — trace-level auditors (``jaxpr_audit``, ``wire_audit``,
  ``jit_audit``, ``injectivity``) that run programs to jaxpr/HLO,
  never to hardware.

Run ``python -m repro.analysis`` (see ``cli``) or call
:func:`load_passes` + :func:`registry.PASSES` programmatically.
"""

from .findings import Finding, format_findings, report_dict  # noqa: F401
from .registry import PASSES, Context, register_pass  # noqa: F401

_LAYER1_MODULES = ("ast_passes",)
_LAYER2_MODULES = ("jaxpr_audit", "wire_audit", "jit_audit", "injectivity")


def load_passes(layer: str = "all") -> dict:
    """Import the pass modules (side effect: registration) and return the
    registry.  ``layer``: ``"1"`` (AST only — no jax import), ``"2"``,
    or ``"all"``."""
    import importlib
    mods = ()
    if layer in ("1", "all"):
        mods += _LAYER1_MODULES
    if layer in ("2", "all"):
        mods += _LAYER2_MODULES
    for m in mods:
        importlib.import_module(f".{m}", __name__)
    return PASSES
