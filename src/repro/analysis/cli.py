"""Command line for the static invariant checker.

``python -m repro.analysis`` runs every registered pass over the repo
and exits 1 if any *unsuppressed* finding remains — the CI analysis
lane is exactly that call with ``--format github``.

Selection::

    python -m repro.analysis --layer 1              # AST only, no jax
    python -m repro.analysis --select ACC-001,WIRE-001
    python -m repro.analysis --skip INJ-001
    python -m repro.analysis --paths src/repro/kernels
    python -m repro.analysis --plan artifacts/plans/custom.json
    python -m repro.analysis --list                 # show the registry

``--out report.json`` writes the full JSON report (all findings
including suppressed ones, per-pass telemetry) regardless of the
display format — CI uploads it as an artifact and
``benchmarks.summary_md`` renders it.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

from . import load_passes
from .findings import (FORMATS, Finding, apply_suppressions,
                       format_findings, report_dict)
from .registry import Context

__all__ = ["run", "main"]


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static invariant checker (AST lint + jaxpr/HLO "
                    "auditors); exits 1 on unsuppressed findings")
    p.add_argument("--root", default=".", help="repo root to scan")
    p.add_argument("--format", dest="fmt", default="human",
                   choices=FORMATS)
    p.add_argument("--out", default=None,
                   help="also write the full JSON report here")
    p.add_argument("--layer", default="all", choices=("1", "2", "all"),
                   help="1 = AST passes only (no jax import), 2 = "
                        "trace-level auditors only")
    p.add_argument("--select", default=None,
                   help="comma-separated rule ids to run (default: all)")
    p.add_argument("--skip", default=None,
                   help="comma-separated rule ids to skip")
    p.add_argument("--paths", nargs="*", default=None,
                   help="scan these files/dirs instead of "
                        "src/benchmarks/tests (AST passes)")
    p.add_argument("--plan", action="append", default=[],
                   help="extra MemoryPlan JSON for the injectivity "
                        "certifier (repeatable)")
    p.add_argument("--list", action="store_true",
                   help="list registered passes and exit")
    return p


def _ids(csv: str | None) -> set[str] | None:
    if csv is None:
        return None
    return {s.strip().upper() for s in csv.split(",") if s.strip()}


def run(argv: list[str] | None = None) -> int:
    args = _parser().parse_args(argv)
    passes = load_passes(args.layer)
    if args.list:
        for info in sorted(passes.values(), key=lambda i: (i.layer, i.id)):
            print(f"{info.id:10s} L{info.layer} {info.name:24s} "
                  f"{info.description}")
        return 0
    select, skip = _ids(args.select), _ids(args.skip)
    ctx = Context(root=args.root, paths=args.paths,
                  plan_paths=args.plan)
    findings: list[Finding] = []
    pass_rows: list[dict] = []
    for info in sorted(passes.values(), key=lambda i: (i.layer, i.id)):
        if select is not None and info.id.upper() not in select:
            continue
        if skip is not None and info.id.upper() in skip:
            continue
        t0 = time.monotonic()
        try:
            found = list(info.fn(ctx))
        except Exception as e:
            # a crashed pass is a failed run, not a silent skip
            found = [Finding(rule=info.id, layer=info.layer,
                             path=f"analysis://pass/{info.id}", line=0,
                             message=f"pass crashed: {e!r}")]
        findings += found
        pass_rows.append({
            "id": info.id, "name": info.name, "layer": info.layer,
            "description": info.description,
            "seconds": round(time.monotonic() - t0, 3),
            "findings": len(found),
            "notes": ctx.notes.get(info.id),
        })
    findings = apply_suppressions(findings, ctx.sources())
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    print(format_findings(findings, args.fmt, passes=pass_rows,
                          root=args.root))
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(report_dict(findings, pass_rows, args.root), f,
                      indent=2)
    return 1 if any(not f.suppressed for f in findings) else 0


def main() -> None:
    sys.exit(run())
