"""Layer-2 jit-cache watcher: replay a canonical request stream through
``RecsysEngine`` and fail if compile counts exceed the pow2-bucket bound.

The engine's whole latency story rests on one invariant: every wave pads
to a (pow2 batch, pow2 bag) bucket, so the number of distinct compiled
programs is O(log max_batch · log max_bag) — bounded, and zero once the
bucket grid is warm.  A padding regression that leaks one unbucketed
shape into the hot path silently turns p99 into a compile storm; this
pass catches it as arithmetic:

* after draining a deterministic stream spanning the bucket grid, the
  embed program may have compiled at most once per (batch, bag) bucket
  seen, and the dense program at most once per batch bucket;
* replaying the *same* stream must add **zero** new compiles.

Uses :meth:`RecsysEngine.compile_count` (cache introspection, no
timing); if the installed jax cannot report cache sizes the pass emits a
loud finding rather than passing vacuously.  Everything runs on one CPU
device with a tiny model — ~seconds, no hardware claims.
"""

from __future__ import annotations

from .findings import Finding
from .registry import Context, register_pass

__all__ = ["replay_and_audit"]

_RULE = "JIT-002"
_ANCHOR = "analysis://jit/recsys-replay"


def _canonical_stream(sizes, n_requests: int = 40, max_bag: int = 8):
    """Deterministic request stream spanning bag buckets {1, 2, 4, 8}."""
    import numpy as np
    rng = np.random.default_rng(0)
    reqs = []
    for _ in range(n_requests):
        bag_len = int(rng.integers(1, max_bag + 1))
        reqs.append((rng.normal(size=13),
                     [list(rng.integers(0, s, size=bag_len)) for s in sizes]))
    return reqs


def _build_engine():
    import jax
    from ..core.factory import EmbeddingSpec
    from ..models.dlrm import DLRMConfig, dlrm_init
    from ..serve.quantize import quantize_params
    from ..serve.recsys import RecsysEngine
    cfg = DLRMConfig(table_sizes=(100, 500, 33), emb_dim=16,
                     bottom_mlp=(32, 16), top_mlp=(32,),
                     embedding=EmbeddingSpec(kind="qr", num_collisions=4,
                                             threshold=40))
    params = quantize_params(dlrm_init(jax.random.PRNGKey(0), cfg))
    return RecsysEngine(cfg, params, max_batch=8)


def replay_and_audit(engine=None) -> tuple[list[Finding], dict]:
    """Drain the canonical stream twice; return (findings, telemetry)."""
    findings: list[Finding] = []
    if engine is None:
        engine = _build_engine()
    reqs = _canonical_stream(engine.cfg.table_sizes)
    for dense, bags in reqs:
        engine.submit(dense, bags)
    engine.run_until_drained()
    counts = engine.compile_count()
    per = counts["per_program"]
    if all(v is None for v in per.values()):
        return ([Finding(rule=_RULE, path=_ANCHOR, line=0, layer=2,
                         message="jit cache sizes unavailable on this jax "
                                 "version — the compile-count bound cannot "
                                 "be checked; refusing to pass vacuously")],
                {"counts": counts})
    buckets = engine.buckets_seen
    batch_buckets = {bb for bb, _ in buckets}
    bounds = {"embed": len(buckets), "dense": len(batch_buckets)}
    for prog, bound in bounds.items():
        got = per.get(prog)
        if got is not None and got > bound:
            findings.append(Finding(
                rule=_RULE, path=_ANCHOR, line=0, layer=2,
                message=f"{prog} program compiled {got}x for "
                        f"{bound} pow2 bucket(s) {sorted(buckets)} — "
                        "a shape escaped the bucket grid"))
    # steady state: the identical stream must not compile anything new
    for dense, bags in reqs:
        engine.submit(dense, bags)
    engine.run_until_drained()
    after = engine.compile_count()
    if after["total"] != counts["total"]:
        findings.append(Finding(
            rule=_RULE, path=_ANCHOR, line=0, layer=2,
            message=f"replaying the identical stream added "
                    f"{after['total'] - counts['total']} compile(s) — the "
                    "warm path is not shape-stable"))
    telemetry = {"first_pass": counts, "replay": after,
                 "buckets_seen": sorted(buckets), "bounds": bounds,
                 "requests": len(reqs) * 2}
    return findings, telemetry


@register_pass(_RULE, "jit-cache-bound", 2,
               "RecsysEngine compile count stays within the pow2-bucket "
               "bound over a canonical replay")
def jit_cache_pass(ctx: Context) -> list[Finding]:
    findings, telemetry = replay_and_audit()
    ctx.notes[_RULE] = telemetry
    return findings
