"""Layer-1 AST passes (stdlib ``ast`` only — no jax import).

Six rules over ``src``/``benchmarks``/``tests``:

=========  ==================================================================
ACC-001    kernel files: ``sum``/``dot``/``@`` on ref-derived data with no
           f32 upcast (``.astype(jnp.float32)`` / ``preferred_element_type``)
           in the expression's dataflow
JIT-001    ``jax.jit`` constructed inside a loop, or jit-then-call in one
           expression (``jax.jit(f)(x)``) — a fresh cache per call
OBS-001    f-string / ``str(x)`` label values flowing into metric
           ``.labels()``/``.inc``/``.set``/``.observe`` — unbounded series
           cardinality
DET-001    wall-clock / RNG calls in kernel files, or inside jit/shard_map/
           pallas-traced function bodies (where they freeze into constants)
EXC-001    bare ``except:``
DON-001    use of a buffer after it was passed at a donated position of a
           ``jax.jit(..., donate_argnums=...)`` callable
=========  ==================================================================

Layer 1 is deliberately conservative: it flags what it can *prove* from
the source expression, and the layer-2 jaxpr auditor (``jaxpr_audit``)
carries the real accumulation guarantee — e.g. plain ``acc += x`` into a
scratch ref is not flagged here because the scratch dtype is not visible
in the expression, but the traced kernel's ``reduce_sum`` dtype is.
"""

from __future__ import annotations

import ast

from .findings import Finding
from .registry import Context, register_pass

__all__ = []

_F32_NAMES = ("float32", "f32")
_REDUCER_FUNCS = {"jnp.sum", "jnp.dot", "jnp.matmul", "jnp.einsum",
                  "jax.numpy.sum", "jax.numpy.dot", "jax.numpy.matmul",
                  "jax.numpy.einsum", "lax.dot_general",
                  "jax.lax.dot_general", "pl.dot"}
_CLOCK_CALLS = {"time.time", "time.monotonic", "time.perf_counter",
                "time.process_time", "time.time_ns", "time.monotonic_ns",
                "time.perf_counter_ns", "datetime.now", "datetime.utcnow",
                "datetime.datetime.now", "datetime.datetime.utcnow"}
_RNG_PREFIXES = ("random.", "np.random.", "numpy.random.")
_METRIC_METHODS = {"labels", "inc", "set", "observe"}


def _dotted(node) -> str | None:
    """Dotted name of a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_kernel_file(rel_path: str) -> bool:
    return "kernels" in rel_path.replace("\\", "/").split("/")


def _mentions_f32(node) -> bool:
    d = _dotted(node)
    if d and d.rsplit(".", 1)[-1] in _F32_NAMES:
        return True
    return isinstance(node, ast.Constant) and node.value in ("float32", "f32")


def _has_f32_evidence(node) -> bool:
    """True if the expression subtree upcasts to f32 anywhere: an
    ``.astype(float32)`` call, a ``preferred_element_type=f32`` kwarg, or
    an f32 ``dtype=`` kwarg."""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            if isinstance(sub.func, ast.Attribute) \
                    and sub.func.attr == "astype" \
                    and any(_mentions_f32(a) for a in sub.args):
                return True
            for kw in sub.keywords:
                if kw.arg in ("preferred_element_type", "dtype") \
                        and _mentions_f32(kw.value):
                    return True
    return False


def _names_in(node) -> set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


# --------------------------------------------------------------- ACC-001

class _AccVisitor(ast.NodeVisitor):
    """Per-function dataflow over ref-derived values in a kernel file.

    Params ending in ``_ref`` (and a ``*refs`` vararg) seed the tainted
    set; assignments propagate it, except that an RHS carrying f32
    evidence moves the target to the clean set.  Reductions touching a
    tainted name without local f32 evidence are flagged.
    """

    def __init__(self, rel_path: str, findings: list[Finding]):
        self.rel = rel_path
        self.findings = findings

    def visit_FunctionDef(self, node):
        self._check_function(node)
        # nested defs handled inside _check_function

    visit_AsyncFunctionDef = visit_FunctionDef

    def _check_function(self, fn):
        args = fn.args
        names = [a.arg for a in args.args + args.posonlyargs
                 + args.kwonlyargs]
        if args.vararg:
            names.append(args.vararg.arg)
        tainted = {n for n in names if n.endswith("_ref") or n == "refs"}
        clean: set[str] = set()

        def is_tainted(expr) -> bool:
            for n in _names_in(expr):
                if n in clean:
                    continue
                if n in tainted or n.endswith("_ref"):
                    return True
            return False

        def scan(stmts):
            for st in stmts:
                if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    self._check_function(st)
                    continue
                for node in ast.walk(st):
                    red = self._reduction(node)
                    if red and is_tainted(node) \
                            and not _has_f32_evidence(node):
                        self.findings.append(Finding(
                            rule="ACC-001", path=self.rel,
                            line=node.lineno,
                            message=f"{red} over ref-derived data with no "
                                    "f32 upcast in the expression "
                                    "(low-precision accumulation)"))
                if isinstance(st, ast.Assign) and len(st.targets) == 1 \
                        and isinstance(st.targets[0], ast.Name):
                    tgt = st.targets[0].id
                    if is_tainted(st.value):
                        if _has_f32_evidence(st.value):
                            clean.add(tgt)
                            tainted.discard(tgt)
                        else:
                            tainted.add(tgt)
                            clean.discard(tgt)
                # recurse into compound statements' bodies
                for field in ("body", "orelse", "finalbody"):
                    sub = getattr(st, field, None)
                    if sub and not isinstance(
                            st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                        scan(sub)

        scan(fn.body)

    @staticmethod
    def _reduction(node) -> str | None:
        if isinstance(node, ast.BinOp) and isinstance(node.op, ast.MatMult):
            return "matmul (@)"
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr == "sum":
                return ".sum()"
            d = _dotted(node.func)
            if d in _REDUCER_FUNCS:
                return d
        return None


@register_pass("ACC-001", "kernel-accumulation", 1,
               "low-precision accumulation on refs in kernel files")
def acc_pass(ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    for rel, tree in ctx.iter_trees():
        if not _is_kernel_file(rel):
            continue
        _AccVisitor(rel, findings).visit(tree)
    return findings


# --------------------------------------------------------------- JIT-001

def _is_jit_ctor(node) -> bool:
    if not isinstance(node, ast.Call):
        return False
    d = _dotted(node.func)
    if d in ("jax.jit", "jit"):
        return True
    # functools.partial(jax.jit, ...) builds the same fresh-cache wrapper
    if d == "functools.partial" and node.args \
            and _dotted(node.args[0]) in ("jax.jit", "jit"):
        return True
    return False


@register_pass("JIT-001", "per-call-jit", 1,
               "jax.jit constructed inside a loop or jit-then-call "
               "in one expression (re-jit hazard)")
def jit_pass(ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    seen: set[tuple[str, int, str]] = set()

    def emit(rel, line, kind, message):
        if (rel, line, kind) not in seen:     # nested loops: flag once
            seen.add((rel, line, kind))
            findings.append(Finding(rule="JIT-001", path=rel, line=line,
                                    message=message))

    for rel, tree in ctx.iter_trees():
        for node in ast.walk(tree):
            if isinstance(node, (ast.For, ast.While)):
                for sub in ast.walk(node):
                    if sub is node:
                        continue
                    if _is_jit_ctor(sub):
                        emit(rel, sub.lineno, "loop",
                             "jax.jit constructed inside a loop — each "
                             "iteration builds a fresh wrapper with an "
                             "empty compile cache")
            if isinstance(node, ast.Call) and _is_jit_ctor(node.func):
                emit(rel, node.lineno, "call",
                     "jit-then-call in one expression (jax.jit(f)(x)) — "
                     "the wrapper and its cache are discarded after the "
                     "call")
    return findings


# --------------------------------------------------------------- OBS-001

def _unbounded_label(value) -> str | None:
    if isinstance(value, ast.JoinedStr) \
            and any(isinstance(v, ast.FormattedValue) for v in value.values):
        return "f-string"
    if isinstance(value, ast.Call):
        d = _dotted(value.func)
        if d in ("str", "repr"):
            return f"{d}()"
        if isinstance(value.func, ast.Attribute) \
                and value.func.attr == "format":
            return ".format()"
    return None


@register_pass("OBS-001", "label-cardinality", 1,
               "f-string/str(x) values flowing into metric labels")
def obs_pass(ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    for rel, tree in ctx.iter_trees():
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in _METRIC_METHODS):
                continue
            for kw in node.keywords:
                if kw.arg is None:
                    continue
                why = _unbounded_label(kw.value)
                if why:
                    findings.append(Finding(
                        rule="OBS-001", path=rel, line=node.lineno,
                        message=f"label {kw.arg!r} built from {why} — "
                                "unbounded series cardinality (one "
                                "timeseries per distinct value)"))
    return findings


# --------------------------------------------------------------- DET-001

def _forbidden_call(node) -> str | None:
    if not isinstance(node, ast.Call):
        return None
    d = _dotted(node.func)
    if d is None:
        return None
    if d in _CLOCK_CALLS:
        return d
    if any(d.startswith(p) for p in _RNG_PREFIXES):
        return d         # jax.random is fine: explicit keys, deterministic
    return None


def _traced_function_names(tree) -> set[str]:
    """Names of functions this module traces: passed to jax.jit /
    shard_map / pallas_call, or decorated with a jit form."""
    traced: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            d = _dotted(node.func)
            if d and (d in ("jax.jit", "jit")
                      or d.endswith("shard_map")
                      or d.endswith("pallas_call")):
                for a in node.args[:1]:
                    if isinstance(a, ast.Name):
                        traced.add(a.id)
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in node.decorator_list:
                if _dotted(dec) in ("jax.jit", "jit") or _is_jit_ctor(dec):
                    traced.add(node.name)
    return traced


@register_pass("DET-001", "determinism", 1,
               "wall-clock / RNG reads in kernels or traced bodies")
def det_pass(ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    for rel, tree in ctx.iter_trees():
        if _is_kernel_file(rel):
            for node in ast.walk(tree):
                d = _forbidden_call(node)
                if d:
                    findings.append(Finding(
                        rule="DET-001", path=rel, line=node.lineno,
                        message=f"{d}() in a kernel file — kernels must "
                                "be deterministic pure functions of "
                                "their operands"))
            continue
        traced = _traced_function_names(tree)
        if not traced:
            continue
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name in traced:
                for sub in ast.walk(node):
                    d = _forbidden_call(sub)
                    if d:
                        findings.append(Finding(
                            rule="DET-001", path=rel, line=sub.lineno,
                            message=f"{d}() inside jit-traced "
                                    f"{node.name}() — evaluates once at "
                                    "trace time and freezes into the "
                                    "compiled program"))
    return findings


# --------------------------------------------------------------- EXC-001

@register_pass("EXC-001", "bare-except", 1, "bare except clauses")
def exc_pass(ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    for rel, tree in ctx.iter_trees():
        for node in ast.walk(tree):
            if isinstance(node, ast.ExceptHandler) and node.type is None:
                findings.append(Finding(
                    rule="EXC-001", path=rel, line=node.lineno,
                    message="bare except swallows KeyboardInterrupt/"
                            "SystemExit — name the exceptions"))
    return findings


# --------------------------------------------------------------- DON-001

def _donated_positions(call: ast.Call) -> tuple[int, ...] | None:
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)):
                out = tuple(e.value for e in v.elts
                            if isinstance(e, ast.Constant)
                            and isinstance(e.value, int))
                return out or None
    return None


class _DonationScope:
    """One lexical scope's donating callables and use-after-donate scan."""

    def __init__(self, rel, findings, donors):
        self.rel = rel
        self.findings = findings
        self.donors = dict(donors)   # name -> donated positions

    def scan(self, body):
        # first pass: pick up donor bindings declared in this scope
        for st in body:
            if isinstance(st, ast.Assign) and len(st.targets) == 1 \
                    and isinstance(st.targets[0], ast.Name) \
                    and isinstance(st.value, ast.Call) \
                    and _is_jit_ctor(st.value):
                pos = _donated_positions(st.value)
                if pos:
                    self.donors[st.targets[0].id] = pos
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in st.decorator_list:
                    if isinstance(dec, ast.Call) and _is_jit_ctor(dec):
                        pos = _donated_positions(dec)
                        if pos:
                            self.donors[st.name] = pos
        if not self.donors:
            return
        # second pass: donation sites and later uses, by line number
        donations: list[tuple[str, int]] = []   # (buffer name, call line)
        uses: list[tuple[str, int]] = []
        rebinds: list[tuple[str, int]] = []
        call_arg_lines: set[tuple[str, int]] = set()
        for st in body:
            for node in ast.walk(st):
                if isinstance(node, ast.Call) \
                        and isinstance(node.func, ast.Name) \
                        and node.func.id in self.donors:
                    for p in self.donors[node.func.id]:
                        if p < len(node.args) \
                                and isinstance(node.args[p], ast.Name):
                            name = node.args[p].id
                            donations.append((name, node.lineno))
                            call_arg_lines.add((name, node.lineno))
                if isinstance(node, ast.Name):
                    uses.append((node.id, node.lineno))
                if isinstance(node, ast.Assign):
                    for t in node.targets:
                        if isinstance(t, ast.Name):
                            rebinds.append((t.id, node.lineno))
        for name, dline in donations:
            rebind_after = min((ln for n, ln in rebinds
                                if n == name and ln >= dline),
                               default=None)
            for uname, uline in uses:
                if uname != name or uline <= dline:
                    continue
                if (uname, uline) in call_arg_lines:
                    continue
                if rebind_after is not None and uline >= rebind_after:
                    break
                self.findings.append(Finding(
                    rule="DON-001", path=self.rel, line=uline,
                    message=f"{name!r} used after being donated at line "
                            f"{dline} — a donated buffer's memory is "
                            "reused by the jitted program"))
                break   # one finding per donation site


@register_pass("DON-001", "donated-buffer-reuse", 1,
               "mutation/use of donated buffers after dispatch")
def don_pass(ctx: Context) -> list[Finding]:
    findings: list[Finding] = []
    for rel, tree in ctx.iter_trees():
        module_scope = _DonationScope(rel, findings, {})
        module_scope.scan(tree.body)
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                _DonationScope(rel, findings,
                               module_scope.donors).scan(node.body)
    return findings
