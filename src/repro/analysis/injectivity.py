"""Layer-2 partition-injectivity certifier: prove every ``MemoryPlan``
table spec is a complementary partition family (paper Def. 1).

The planner's entire quality claim rests on compositional tables being
*lossless* codes: the tuple ``x -> (p_1(x), ..., p_k(x))`` must be
injective on ``{0..size-1}``.  The constructors enforce this by raising,
but a plan is a JSON artifact — hand-edited, migrated, or emitted by a
future solver — so the analyzer re-proves it from the artifact alone:

* **exactly**, from structure, for every family the factory builds —
  mixed-radix digit maps (cumulative divisors + ``prod(ms) >= size``),
  quotient/remainder pairs (``x = (x//m)·m + x%m``), CRT remainder sets
  (pairwise coprime + product bound), single tables (pigeonhole both
  directions);
* by brute force (``is_complementary``) for explicit/unrecognized
  families up to ``COMPLEMENTARY_CHECK_MAX`` ids;
* by seeded sampling above that — a found collision is still an exact
  counterexample; a clean sample is reported as *inexact* evidence.

``hash`` tables are lossy by design and never produce a finding; every
other kind must certify injective.  The pass certifies (a) a mini
budget sweep mirroring ``plan_bench`` (both archs x 4 budget fractions,
uniform and mixed-dimension) and (b) every plan JSON under
``artifacts/plans/`` plus any ``--plan`` paths.
"""

from __future__ import annotations

import dataclasses
import glob
import math
import os
from functools import reduce
from typing import Sequence

from .findings import Finding
from .registry import Context, register_pass

__all__ = ["Certificate", "certify_partitions", "certify_table",
           "certify_plan"]

_RULE = "INJ-001"

# brute-force cap, matching plan.quality's complementarity check budget
COMPLEMENTARY_CHECK_MAX = 200_000
_SAMPLE = 4096


@dataclasses.dataclass(frozen=True)
class Certificate:
    """Outcome of one injectivity proof attempt."""

    injective: bool
    exact: bool       # False only for the no-collision-found sample path
    method: str       # mixed-radix | quotient-remainder | crt | pigeonhole
                      # | brute-force | sampled | empty
    detail: str = ""

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _prod(xs) -> int:
    return reduce(lambda a, b: a * b, xs, 1)


def _sampled(partitions, size: int) -> Certificate:
    import numpy as np
    rng = np.random.default_rng(0)
    ids = np.unique(np.concatenate([
        rng.integers(0, size, _SAMPLE),
        np.arange(min(size, 64)),                  # dense low end
        size - 1 - np.arange(min(size, 64)),       # dense high end
    ]))
    codes = np.stack([np.asarray(p.bucket(ids)) for p in partitions],
                     axis=-1)
    uniq = len(np.unique(codes, axis=0))
    if uniq < len(ids):
        return Certificate(False, True, "sampled",
                           f"collision among {len(ids)} sampled ids — "
                           "exact counterexample")
    return Certificate(True, False, "sampled",
                       f"no collision in {len(ids)} sampled ids "
                       f"(size={size} exceeds brute cap)")


def certify_partitions(partitions: Sequence, size: int) -> Certificate:
    """Prove or refute injectivity of the code tuple on {0..size-1}."""
    from ..core.partitions import (GeneralizedQRPartition,
                                   QuotientPartition, RemainderPartition,
                                   is_complementary)
    parts = list(partitions)
    if size <= 1:
        return Certificate(True, True, "empty", "at most one category")
    if not parts:
        return Certificate(False, True, "empty", "no partitions")

    # pigeonhole: fewer code tuples than categories — exact, any family
    total = _prod(p.num_buckets for p in parts)
    if total < size:
        return Certificate(False, True, "pigeonhole",
                           f"prod(num_buckets)={total} < size={size}")

    if all(isinstance(p, GeneralizedQRPartition) for p in parts):
        digits = sorted(parts, key=lambda p: p.divisor)
        divisor = 1
        for p in digits:
            if p.divisor != divisor:
                break
            divisor *= p.modulus
        else:
            # x -> mixed-radix digits is a bijection below prod(ms),
            # and size <= prod(ms) held above
            return Certificate(True, True, "mixed-radix",
                               f"radices {[p.modulus for p in digits]}, "
                               f"prod={divisor} >= size={size}")

    if (len(parts) == 2
            and {type(p) for p in parts}
            == {RemainderPartition, QuotientPartition}
            and len({p.m for p in parts}) == 1):
        m = parts[0].m
        return Certificate(True, True, "quotient-remainder",
                           f"x = (x // {m}) * {m} + x %% {m}")

    if all(isinstance(p, RemainderPartition) for p in parts):
        ms = [p.m for p in parts]
        coprime = all(math.gcd(ms[i], ms[j]) == 1
                      for i in range(len(ms))
                      for j in range(i + 1, len(ms)))
        if coprime:
            # CRT: x mod prod(ms) is determined by the residues, and
            # size <= prod(ms) held above
            return Certificate(True, True, "crt",
                               f"pairwise-coprime moduli {ms}, "
                               f"prod >= size={size}")

    if size <= COMPLEMENTARY_CHECK_MAX:
        ok = is_complementary(parts, size)
        return Certificate(bool(ok), True, "brute-force",
                           f"all {size} code tuples enumerated")
    return _sampled(parts, size)


def _structural_partitions(table) -> list:
    """Partition family implied by a TablePlan's fields, built without
    the raising constructors — a corrupt artifact must *report* as
    non-injective, not crash the certifier."""
    from ..core.factory import _balanced_radices
    from ..core.partitions import (GeneralizedQRPartition,
                                   QuotientPartition, RemainderPartition,
                                   naive_partition)
    size, spec = table.num_categories, table.spec()
    if spec.kind == "full" or size <= max(spec.threshold, 1):
        return list(naive_partition(size))
    c = max(1, spec.num_collisions)
    m = -(-size // c)
    if spec.kind == "hash":
        return [RemainderPartition(size=size, num_buckets=m, m=m)]
    if spec.kind in ("qr", "feature"):
        q = math.ceil(size / m)
        return [RemainderPartition(size=size, num_buckets=m, m=m),
                QuotientPartition(size=size, num_buckets=q, m=m)]
    if spec.kind == "mixed_radix":
        ms = list(spec.ms) or list(_balanced_radices(size, 3))
        parts, divisor = [], 1
        for radix in ms:
            parts.append(GeneralizedQRPartition(
                size=size, num_buckets=radix, divisor=divisor,
                modulus=radix))
            divisor *= radix
        return parts
    if spec.kind == "crt":
        return [RemainderPartition(size=size, num_buckets=radix, m=radix)
                for radix in spec.ms]
    raise ValueError(f"unknown table kind {spec.kind!r}")


def certify_table(table, emb_dim: int) -> tuple[bool, Certificate, str]:
    """(must_be_injective, certificate, partition_source) for one table.

    Prefers the factory's ``module_partitions`` ground truth (the exact
    structure the built model uses); falls back to the structural view
    when the constructors refuse the spec — which is precisely the
    corrupt-artifact case the certifier exists to report.
    """
    from ..core.factory import make_embedding
    from ..plan.quality import module_partitions
    size = table.num_categories
    spec = table.spec()
    # hash is the paper's lossy baseline: collisions are the point
    lossy_ok = spec.kind == "hash" and size > max(spec.threshold, 1)
    try:
        module = make_embedding(size, table.dim or emb_dim, spec)
        parts, source = module_partitions(module), "factory"
    except Exception as e:
        parts, source = _structural_partitions(table), f"structural ({e!r})"
    return (not lossy_ok, certify_partitions(parts, size), source)


def certify_plan(plan, anchor: str) -> tuple[list[Finding], dict]:
    """Certify every table of one MemoryPlan; returns (findings, row)."""
    findings: list[Finding] = []
    certs = []
    for t in plan.tables:
        try:
            required, cert, source = certify_table(t, plan.emb_dim)
        except Exception as e:
            findings.append(Finding(
                rule=_RULE, path=anchor, line=0, layer=2,
                message=f"table {t.feature} ({t.kind}, "
                        f"{t.num_categories} categories) could not be "
                        f"certified: {e!r}"))
            continue
        certs.append({"feature": t.feature, "kind": t.kind,
                      "size": t.num_categories, "required": required,
                      "source": source, **cert.as_dict()})
        if required and not cert.injective:
            findings.append(Finding(
                rule=_RULE, path=anchor, line=0, layer=2,
                message=f"table {t.feature} ({t.kind}, ms={list(t.ms)}, "
                        f"{t.num_categories} categories) is NOT a "
                        f"complementary partition: {cert.method} — "
                        f"{cert.detail}"))
    row = {"plan": anchor, "arch": plan.arch,
           "tables": len(plan.tables),
           "exact": sum(c["exact"] for c in certs),
           "findings": len(findings), "certificates": certs}
    return findings, row


def _sweep_plans(stats_batches: int = 6, batch_size: int = 256):
    """The plan_bench budget sweep in miniature: both archs, all four
    budget fractions, uniform-width and mixed-dimension."""
    from ..configs import get_arch
    from ..data.criteo import CriteoSpec
    from ..plan import (build_plan, dim_ladder, full_table_bytes,
                        stats_from_criteo)
    for arch in ("dlrm-criteo", "dcn-criteo"):
        cfg = get_arch(arch).config(reduced=True)
        spec = CriteoSpec(table_sizes=cfg.table_sizes, zipf=1.5, noise=0.5)
        stats = stats_from_criteo(spec, num_batches=stats_batches,
                                  batch_size=batch_size)
        dim = cfg.emb_dim
        full = full_table_bytes(cfg.table_sizes, dim)
        for frac in (0.05, 0.125, 0.25, 0.5):
            budget = int(full * frac)
            yield (f"analysis://plan/{arch}@{frac}x",
                   build_plan(stats, dim, budget, arch=arch))
            yield (f"analysis://plan/{arch}-mixed@{frac}x",
                   build_plan(stats, dim, budget, arch=f"{arch}-mixed",
                              dims=dim_ladder(dim)))


@register_pass(_RULE, "partition-injectivity", 2,
               "every MemoryPlan table spec certifies as a complementary "
               "partition (exact structural proof where possible)")
def injectivity_pass(ctx: Context) -> list[Finding]:
    from ..plan.memory_plan import MemoryPlan
    findings: list[Finding] = []
    rows = []
    for anchor, plan in _sweep_plans():
        fs, row = certify_plan(plan, anchor)
        findings += fs
        rows.append(row)
    paths = list(ctx.plan_paths or ())
    paths += sorted(glob.glob(os.path.join(ctx.root, "artifacts", "plans",
                                           "*.json")))
    seen = set()
    for path in paths:
        rel = os.path.relpath(path, ctx.root)
        if rel in seen:
            continue
        seen.add(rel)
        try:
            plan = MemoryPlan.load(path)
        except Exception as e:
            findings.append(Finding(
                rule=_RULE, path=rel, line=0, layer=2,
                message=f"plan artifact failed to load: {e!r}"))
            continue
        fs, row = certify_plan(plan, rel)
        findings += fs
        rows.append(row)
    total = sum(r["tables"] for r in rows)
    exact = sum(r["exact"] for r in rows)
    ctx.notes[_RULE] = {"plans": rows, "tables_certified": total,
                        "exact_certificates": exact}
    return findings
