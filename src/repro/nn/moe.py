"""Mixture-of-Experts layer with sort-based (Megablocks-style) dispatch.

Design targets expert parallelism on TPU: expert weights are stacked
``(E, ...)`` and shard over the ``model`` mesh axis; tokens are grouped so
routing/capacity is decided *within a group* (groups shard over ``data``),
keeping the dispatch math local and letting GSPMD lower the
token↔expert-buffer scatter into all-to-alls instead of a global sort.

Dispatch per group (all static shapes, O(N log N) sort — no (N, E) one-hot
materialisation):
  1. top-k routing → (token, expert) pairs;
  2. stable argsort pairs by expert; the start offset of each expert in the
     sorted order comes from a vmapped ``searchsorted`` (no bincount);
  3. rank-within-expert = position − start; slots beyond the static capacity
     ``C = ceil(Nk/E · capacity_factor)`` are dropped (scattered to a
     sacrificial row), matching production capacity semantics;
  4. expert FFN is one batched einsum over the ``(E, C, D)`` buffer;
  5. results unsort + weighted-combine over the k routes.

Shared ("always-on") experts — DeepSeek-V2 style — and a parallel dense
residual branch — Arctic style — are composed in the model layer, not here.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax import lax

from ..dist.sharding import constrain
from .layers import dense_init

__all__ = ["MoEConfig", "moe_init", "moe_apply"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int
    capacity_factor: float = 1.25
    groups: int = 256          # token groups; actual = gcd(N, groups)
    renorm: bool = True        # renormalise top-k gate weights
    aux_weight: float = 0.01   # load-balance loss weight


def moe_init(key, cfg: MoEConfig, param_dtype):
    kr, ki, kg, ko = jax.random.split(key, 4)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    s_in, s_out = (1.0 / d) ** 0.5, (1.0 / f) ** 0.5
    return {
        "router": dense_init(kr, d, e, jnp.float32),  # router in f32 for stable softmax
        "wi": jax.random.normal(ki, (e, d, f), param_dtype) * s_in,
        "wg": jax.random.normal(kg, (e, d, f), param_dtype) * s_in,
        "wo": jax.random.normal(ko, (e, f, d), param_dtype) * s_out,
    }


def moe_apply(params, x, cfg: MoEConfig, compute_dtype):
    """x: (B, S, D) → (out (B, S, D), aux_loss scalar).

    Two sharding regimes (§Perf iteration 3):
      * training / prefill (many tokens): tokens group-sharded over data,
        experts over model — canonical EP; expert weights are FSDP-gathered
        over data per layer (amortised by ~1M tokens).
      * decode (few tokens): the same FSDP gather costs 5.3 GB/layer to
        produce 128 tokens (measured on arctic decode_32k — 97% of its wire
        bytes).  Here token groups are left replicated over data and the
        expert FFN runs on data-sharded weight slices (f-dim), so weights
        never move; only the tiny (g,e,c,D) partial sums are reduced.
    """
    b, s, d = x.shape
    n = b * s
    g = math.gcd(n, cfg.groups)
    ng = n // g
    e, k = cfg.n_experts, cfg.top_k
    nk = ng * k
    cap = max(1, int(math.ceil(nk / e * cfg.capacity_factor)))
    inference = n <= 4096  # decode-scale token counts
    dp = None if inference else "dp"

    # token groups shard over the data axes; expert buffers over `model` (EP).
    xf = constrain(x.reshape(g, ng, d), dp, None, None)
    logits = jnp.einsum("gnd,de->gne", xf.astype(jnp.float32),
                        params["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = lax.top_k(probs, k)  # (G, Ng, k)
    if cfg.renorm:
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    flat_e = idx.reshape(g, nk)
    order = jnp.argsort(flat_e, axis=1, stable=True)            # (G, Nk)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    starts = jax.vmap(lambda se: jnp.searchsorted(se, jnp.arange(e)))(sorted_e)
    pos = jnp.arange(nk)[None, :] - jnp.take_along_axis(starts, sorted_e, axis=1)
    dest = jnp.where(pos < cap, sorted_e * cap + pos, e * cap)   # overflow → row E*C
    # §Perf it.5: GSPMD lost the G-sharding of the dispatch indices through
    # argsort/searchsorted (it picked (None, data) layouts, then had to
    # all-gather (G,Nk,D)-broadcast index grids at every scatter — ~8 GB per
    # layer on deepseek train).  Pin them to the token-group layout.
    order = constrain(order, dp, None)
    dest = constrain(dest, dp, None)

    tok = order // k                                             # source token per slot
    # §Perf it.4: row-gather via vmap, NOT take_along_axis — the latter
    # broadcasts its index array across D ((G,Nk,D) u32 grids that GSPMD
    # then all-gathers: 5×960 MB/layer on deepseek train).  vmap'd indexing
    # lowers to a batched gather with (G,Nk) indices.
    xs = jax.vmap(lambda rows, idx2: rows[idx2])(xf, tok)        # (G, Nk, D)
    xs = constrain(xs.astype(compute_dtype), dp, None, "model")
    buf = jnp.zeros((g, e * cap + 1, d), compute_dtype)
    # scatter with the indexed dim unsharded (D model-sharded is fine);
    # the constraint AFTER the reshape flips D-sharded -> E-sharded, which
    # GSPMD lowers to the canonical MoE all-to-all (token -> expert layout).
    buf = constrain(buf.at[jnp.arange(g)[:, None], dest].set(xs, unique_indices=True, mode='promise_in_bounds'),
                    dp, None, "model")
    ebuf = constrain(buf[:, : e * cap].reshape(g, e, cap, d),
                     dp, "model", None, None)

    wi = params["wi"].astype(compute_dtype)
    wg = params["wg"].astype(compute_dtype)
    wo = params["wo"].astype(compute_dtype)
    h = jnp.einsum("gecd,edf->gecf", ebuf, wi)
    h = h * jax.nn.silu(jnp.einsum("gecd,edf->gecf", ebuf, wg))
    if not inference:
        h = constrain(h, dp, "model", None, None)
    eout = jnp.einsum("gecf,efd->gecd", h, wo)
    eout = constrain(eout, dp, "model", None, None)

    outb = jnp.concatenate(
        [eout.reshape(g, e * cap, d), jnp.zeros((g, 1, d), compute_dtype)], axis=1)
    outb = constrain(outb, dp, None, "model")  # expert -> token all-to-all back
    out_sorted = jax.vmap(lambda rows, idx2: rows[idx2])(outb, dest)  # (G, Nk, D)
    out_flat = jnp.zeros((g, nk, d), compute_dtype)
    out_flat = out_flat.at[jnp.arange(g)[:, None], order].set(out_sorted, unique_indices=True, mode='promise_in_bounds')
    out = (out_flat.reshape(g, ng, k, d)
           * gate[..., None].astype(compute_dtype)).sum(axis=2)
    out = constrain(out, dp, None, None)

    # Switch-style load-balance aux: E * <f_e * P_e>.
    counts = jnp.diff(starts, axis=1, append=jnp.full((g, 1), nk))
    f_e = counts.astype(jnp.float32) / nk
    p_e = probs.mean(axis=1)
    aux = cfg.aux_weight * e * (f_e * p_e).sum(-1).mean()
    return out.reshape(b, s, d).astype(x.dtype), aux
