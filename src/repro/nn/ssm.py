"""Mamba2 block via the SSD (state-space duality) chunked algorithm.

Recurrence (per head h, state (N, P)):
    S_t = exp(dt_t·A_h) · S_{t-1} + dt_t · B_t ⊗ x_t
    y_t = C_t · S_t + D_h · x_t

Training/prefill uses the chunked SSD form: within a chunk of length Q the
quadratic "attention-like" term ``C_i·B_j · exp(cs_i−cs_j) · dt_j`` is a
(Q, Q) matmul (MXU-friendly); across chunks a linear ``lax.scan`` carries
the (H, N, P) state.  All decays are ≤ 1 (A < 0, dt > 0) so the f32 exp is
stable.  Decode is the O(1) recurrence with a (H, N, P) state cache plus a
(d_conv−1)-deep conv window cache.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from .layers import dense, dense_init

__all__ = ["SSMConfig", "ssm_init", "ssm_apply", "ssm_decode", "ssm_make_cache"]


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_model: int
    d_state: int = 128
    expand: int = 2
    d_conv: int = 4
    headdim: int = 64
    chunk: int = 256

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.headdim

    @property
    def d_xbc(self) -> int:
        return self.d_inner + 2 * self.d_state  # x, B, C (single group)


def ssm_init(key, cfg: SSMConfig, param_dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    d_in_proj = 2 * cfg.d_inner + 2 * cfg.d_state + cfg.n_heads  # z, x, B, C, dt
    return {
        "in_proj": dense_init(k1, cfg.d_model, d_in_proj, param_dtype),
        "conv_w": jax.random.normal(k2, (cfg.d_conv, cfg.d_xbc), param_dtype)
                  * (1.0 / cfg.d_conv) ** 0.5,
        "conv_b": jnp.zeros((cfg.d_xbc,), param_dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, cfg.n_heads)).astype(jnp.float32),
        "D": jnp.ones((cfg.n_heads,), jnp.float32),
        "dt_bias": jnp.full((cfg.n_heads,), -2.0, jnp.float32),
        "norm_g": jnp.ones((cfg.d_inner,), param_dtype),
        "out_proj": dense_init(k3, cfg.d_inner, cfg.d_model, param_dtype),
    }


def _split_proj(zxbcdt, cfg):
    di, ds, nh = cfg.d_inner, cfg.d_state, cfg.n_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + cfg.d_xbc]
    dt = zxbcdt[..., di + cfg.d_xbc :]
    return z, xbc, dt


def _gated_norm(y, z, g, eps=1e-6):
    y = y * jax.nn.silu(z.astype(y.dtype))
    y32 = y.astype(jnp.float32)
    y32 = y32 * lax.rsqrt(jnp.mean(y32 * y32, axis=-1, keepdims=True) + eps)
    return (y32 * g.astype(jnp.float32)).astype(y.dtype)


def _causal_conv(xbc, conv_w, conv_b):
    """Depthwise causal conv, window K, via K shifted adds (shard-friendly)."""
    k = conv_w.shape[0]
    out = xbc * conv_w[-1]
    for i in range(1, k):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, : xbc.shape[1]]
        out = out + shifted * conv_w[-1 - i]
    return jax.nn.silu(out + conv_b)


def ssm_apply(params, u, cfg: SSMConfig, compute_dtype, *, return_state: bool = False):
    """u: (B, S, d_model) → (B, S, d_model). S must be a multiple of... any S
    (padded internally to the chunk size)."""
    b, s, _ = u.shape
    from ..dist.sharding import constrain, constrain_batch
    zxbcdt = constrain(dense(params["in_proj"], constrain_batch(u), compute_dtype),
                       "dp", None, "model")
    z, xbc_raw, dt_raw = _split_proj(zxbcdt, cfg)
    xbc = _causal_conv(xbc_raw, params["conv_w"].astype(compute_dtype),
                       params["conv_b"].astype(compute_dtype))
    di, ds, nh, p = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.headdim
    x = xbc[..., :di].reshape(b, s, nh, p)
    bmat = xbc[..., di : di + ds]                     # (B, S, N)
    cmat = xbc[..., di + ds :]                        # (B, S, N)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    a = -jnp.exp(params["A_log"])                                          # (H,) < 0
    da = dt * a                                                            # (B,S,H) < 0

    q = min(cfg.chunk, s)
    pad = (-s) % q
    # padded positions must be identity steps (decay=1, zero input) so the
    # final state returned for prefill is exact: dt=0 achieves both.
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        da = jnp.pad(da, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // q
    xc = x.reshape(b, nc, q, nh, p)
    bc = bmat.reshape(b, nc, q, ds)
    cc = cmat.reshape(b, nc, q, ds)
    dtc = dt.reshape(b, nc, q, nh)
    dac = da.reshape(b, nc, q, nh)

    cs = jnp.cumsum(dac, axis=2)                       # inclusive, (B,nc,Q,H)
    # --- intra-chunk (quadratic within Q) ---
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc, preferred_element_type=jnp.float32)
    decay = jnp.exp(cs[:, :, :, None, :] - cs[:, :, None, :, :])   # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(mask[None, None, :, :, None], decay, 0.0)
    xdt = xc.astype(jnp.float32) * dtc[..., None]
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", cb, decay, xdt)

    # --- inter-chunk state scan ---
    seg = jnp.exp(cs[:, :, -1:, :] - cs)               # decay from t to chunk end
    chunk_states = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", bc.astype(jnp.float32),
                              seg, xdt)                # (B,nc,H,N,P)
    chunk_decay = jnp.exp(cs[:, :, -1, :])             # (B,nc,H)

    def step(s_prev, inp):
        st, dec = inp
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    s0 = jnp.zeros((b, nh, ds, p), jnp.float32)
    s_last, s_prevs = lax.scan(step, s0,
                               (chunk_states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    s_prevs = s_prevs.swapaxes(0, 1)                   # (B,nc,H,N,P): state before chunk
    instate_decay = jnp.exp(cs)                        # decay of boundary state to pos i
    y_inter = jnp.einsum("bcin,bchnp,bcih->bcihp", cc.astype(jnp.float32),
                         s_prevs, instate_decay)

    y = (y_intra + y_inter + params["D"][:, None] * xc.astype(jnp.float32))
    y = y.reshape(b, nc * q, di)[:, :s].astype(compute_dtype)
    y = _gated_norm(y, z, params["norm_g"])
    out = constrain_batch(dense(params["out_proj"], y, compute_dtype))
    if return_state:
        # conv cache holds the raw (pre-conv) projections of the last K-1 steps
        tail = xbc_raw[:, s - (cfg.d_conv - 1):]
        return out, {"ssm": s_last, "conv": tail}
    return out


def ssm_make_cache(batch, cfg: SSMConfig, dtype):
    return {
        "ssm": jnp.zeros((batch, cfg.n_heads, cfg.d_state, cfg.headdim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, cfg.d_xbc), dtype),
    }


def ssm_decode(params, u, cfg: SSMConfig, compute_dtype, cache):
    """Single-token step.  u: (B, 1, d_model) → (out (B,1,d_model), cache)."""
    b = u.shape[0]
    zxbcdt = dense(params["in_proj"], u, compute_dtype)
    z, xbc_t, dt_raw = _split_proj(zxbcdt[:, 0], cfg)
    window = jnp.concatenate([cache["conv"], xbc_t[:, None, :].astype(cache["conv"].dtype)], axis=1)
    conv_w = params["conv_w"].astype(compute_dtype)
    xbc = jax.nn.silu(jnp.einsum("bkc,kc->bc", window.astype(compute_dtype), conv_w)
                      + params["conv_b"].astype(compute_dtype))
    new_conv = window[:, 1:]

    di, ds, nh, p = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.headdim
    x = xbc[:, :di].reshape(b, nh, p).astype(jnp.float32)
    bvec = xbc[:, di : di + ds].astype(jnp.float32)
    cvec = xbc[:, di + ds :].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["A_log"])
    dec = jnp.exp(dt * a)                                                  # (B,H)
    s_new = (cache["ssm"] * dec[..., None, None]
             + jnp.einsum("bn,bh,bhp->bhnp", bvec, dt, x))
    y = jnp.einsum("bn,bhnp->bhp", cvec, s_new) + params["D"][:, None] * x
    y = y.reshape(b, di).astype(compute_dtype)
    y = _gated_norm(y[:, None, :], z[:, None, :], params["norm_g"])
    out = dense(params["out_proj"], y, compute_dtype)
    return out, {"ssm": s_new, "conv": new_conv}
