"""Multi-head Latent Attention (DeepSeek-V2) with absorbed decode path.

Train/prefill: materialise per-head K/V from the KV latent and run flash
attention (per-chip activation cost is fine at 4k–32k with sharding+remat).

Decode: the O(S·H·d) per-head K/V would be ~270 GB at decode_32k, so we use
the *absorbed* form — fold ``W_kb`` into the query and ``W_vb`` after the
attention — attending directly over the cached ``(S, kv_lora + d_rope)``
latent.  That cache compression (576 vs 2·H·d_head floats per token) is the
whole point of MLA and is what the decode dry-run measures.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from .layers import dense, dense_init, flash_attention, rmsnorm, rmsnorm_init, rope

__all__ = ["MLAConfig", "mla_init", "mla_apply", "mla_make_cache"]


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora: int = 1536
    kv_lora: int = 512
    d_nope: int = 128
    d_rope: int = 64
    d_v: int = 128
    rope_theta: float = 1e4


def mla_init(key, cfg: MLAConfig, param_dtype):
    ks = jax.random.split(key, 6)
    h = cfg.n_heads
    return {
        "wq_a": dense_init(ks[0], cfg.d_model, cfg.q_lora, param_dtype),
        "q_norm": rmsnorm_init(cfg.q_lora, param_dtype),
        "wq_b": dense_init(ks[1], cfg.q_lora, h * (cfg.d_nope + cfg.d_rope), param_dtype),
        "wkv_a": dense_init(ks[2], cfg.d_model, cfg.kv_lora + cfg.d_rope, param_dtype),
        "kv_norm": rmsnorm_init(cfg.kv_lora, param_dtype),
        "wk_b": dense_init(ks[3], cfg.kv_lora, h * cfg.d_nope, param_dtype),
        "wv_b": dense_init(ks[4], cfg.kv_lora, h * cfg.d_v, param_dtype),
        "wo": dense_init(ks[5], h * cfg.d_v, cfg.d_model, param_dtype),
    }


def mla_make_cache(batch, max_len, cfg: MLAConfig, dtype):
    return {"ckv": jnp.zeros((batch, max_len, cfg.kv_lora), dtype),
            "krope": jnp.zeros((batch, max_len, cfg.d_rope), dtype)}


def _project_q(params, x, cfg, compute_dtype, positions):
    b, s, _ = x.shape
    q = dense(params["wq_b"], rmsnorm(params["q_norm"], dense(params["wq_a"], x, compute_dtype)),
              compute_dtype).reshape(b, s, cfg.n_heads, cfg.d_nope + cfg.d_rope)
    q_nope, q_rope = q[..., : cfg.d_nope], q[..., cfg.d_nope:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latent(params, x, cfg, compute_dtype, positions):
    kv = dense(params["wkv_a"], x, compute_dtype)
    ckv = rmsnorm(params["kv_norm"], kv[..., : cfg.kv_lora])
    krope = rope(kv[..., None, cfg.kv_lora:], positions, cfg.rope_theta)[..., 0, :]
    return ckv, krope


def mla_apply(params, x, cfg: MLAConfig, compute_dtype, *, positions=None,
              cache=None, cache_index=None):
    b, s, _ = x.shape
    h = cfg.n_heads
    if positions is None:
        positions = jnp.arange(s)[None, :]
    q_nope, q_rope = _project_q(params, x, cfg, compute_dtype, positions)
    ckv, krope = _latent(params, x, cfg, compute_dtype, positions)

    if cache is not None and cache_index is not None:  # absorbed decode
        ckv_c = lax.dynamic_update_slice(cache["ckv"], ckv.astype(cache["ckv"].dtype),
                                         (0, cache_index, 0))
        krope_c = lax.dynamic_update_slice(cache["krope"], krope.astype(cache["krope"].dtype),
                                           (0, cache_index, 0))
        new_cache = {"ckv": ckv_c, "krope": krope_c}
        wk_b = params["wk_b"]["w"].astype(compute_dtype).reshape(cfg.kv_lora, h, cfg.d_nope)
        wv_b = params["wv_b"]["w"].astype(compute_dtype).reshape(cfg.kv_lora, h, cfg.d_v)
        # q absorbed into latent space: (B, s, H, kv_lora)
        q_lat = jnp.einsum("bshn,khn->bshk", q_nope, wk_b)
        scores = (jnp.einsum("bshk,btk->bhst", q_lat, ckv_c.astype(compute_dtype),
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bshr,btr->bhst", q_rope, krope_c.astype(compute_dtype),
                               preferred_element_type=jnp.float32))
        scores = scores * ((cfg.d_nope + cfg.d_rope) ** -0.5)
        kv_len = cache_index + s
        tpos = jnp.arange(ckv_c.shape[1])
        scores = jnp.where(tpos[None, None, None, :] < kv_len, scores, -jnp.inf)
        w = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bhst,btk->bshk", w.astype(compute_dtype),
                           ckv_c.astype(compute_dtype))
        out = jnp.einsum("bshk,khv->bshv", o_lat, wv_b)
        out = out.reshape(b, s, h * cfg.d_v)
        return dense(params["wo"], out, compute_dtype), new_cache

    # train / prefill: materialise per-head K/V, flash attend.  The per-head
    # tensors are the memory hot-spot (S·H·d ≫ S·kv_lora); shard the head
    # dim over `model` (128 heads / 16 = 8 per chip).
    from ..dist.sharding import constrain
    wk_b = params["wk_b"]["w"].astype(compute_dtype).reshape(cfg.kv_lora, h, cfg.d_nope)
    wv_b = params["wv_b"]["w"].astype(compute_dtype).reshape(cfg.kv_lora, h, cfg.d_v)
    k_nope = constrain(jnp.einsum("btk,khn->bthn", ckv, wk_b),
                       "dp", None, "model", None)
    vv = constrain(jnp.einsum("btk,khv->bthv", ckv, wv_b),
                   "dp", None, "model", None)
    kk = jnp.concatenate(
        [k_nope, jnp.broadcast_to(krope[:, :, None, :], (b, s, h, cfg.d_rope))], axis=-1)
    qq = jnp.concatenate([q_nope, q_rope], axis=-1)
    kk = constrain(kk, "dp", None, "model", None)
    qq = constrain(qq, "dp", None, "model", None)
    out = flash_attention(qq, kk, vv, causal=True)
    out = out.reshape(b, s, h * cfg.d_v)
    out_proj = dense(params["wo"], out, compute_dtype)
    if cache is not None:  # prefill populates the latent cache
        # align write values with the (feature-sharded) cache layout, so the
        # DUS doesn't force GSPMD to replicate the whole cache
        ckv_w = constrain(ckv.astype(cache["ckv"].dtype), "dp", None, "model")
        krope_w = krope.astype(cache["krope"].dtype)
        ckv_c = lax.dynamic_update_slice(cache["ckv"], ckv_w, (0, 0, 0))
        krope_c = lax.dynamic_update_slice(cache["krope"], krope_w, (0, 0, 0))
        return out_proj, {"ckv": ckv_c, "krope": krope_c}
    return out_proj
