"""Shared neural-net layers: functional init/apply, pjit/scan friendly.

Conventions:
  * params are plain nested dicts of jnp arrays;
  * ``init_*`` take an explicit PRNG key and shapes; ``apply`` is pure;
  * weights are stored in ``param_dtype`` and cast to ``compute_dtype`` at
    use (mixed precision);
  * attention is memory-linear: a two-level (q-block × kv-block) scan with
    running-max/denominator ("flash") so 32k-token prefill never
    materialises an S×S score matrix.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "dense_init", "dense", "rmsnorm_init", "rmsnorm", "mlp_init", "mlp",
    "rope", "attention_init", "attention", "make_cache", "AttnConfig",
    "flash_attention",
]


# ---------------------------------------------------------------- basics


def dense_init(key, d_in, d_out, param_dtype, scale: float | None = None):
    scale = (1.0 / d_in) ** 0.5 if scale is None else scale
    return {"w": jax.random.normal(key, (d_in, d_out), param_dtype) * scale}


def dense(params, x, compute_dtype):
    return x.astype(compute_dtype) @ params["w"].astype(compute_dtype)


def rmsnorm_init(d, param_dtype):
    return {"g": jnp.ones((d,), param_dtype)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    x32 = x32 * lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * params["g"].astype(jnp.float32)).astype(dt)


def mlp_init(key, d_model, d_ff, param_dtype, kind: str = "swiglu"):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"wo": dense_init(k2, d_ff, d_model, param_dtype)}
    if kind == "swiglu":
        p["wi"] = dense_init(k1, d_model, d_ff, param_dtype)
        p["wg"] = dense_init(k3, d_model, d_ff, param_dtype)
    else:  # gelu / relu
        p["wi"] = dense_init(k1, d_model, d_ff, param_dtype)
    return p


def mlp(params, x, compute_dtype, kind: str = "swiglu"):
    """Transformer FFN with Megatron-style activation pinning.

    §Perf iteration 1: without explicit constraints GSPMD resolved the
    FSDP-sharded weight contraction by resharding *activations* over the
    data axis (f32 all-gather + all-reduce of the full (B,S,D) hidden per
    layer — the dominant wire cost in every train cell).  Pinning
    batch-sharded input → model-sharded FFN hidden → psum output restores
    the canonical TP/FSDP pattern: weights gather (MBs), activations stay
    put.
    """
    from ..dist.sharding import constrain, constrain_batch
    x = constrain_batch(x)
    h = dense(params["wi"], x, compute_dtype)
    if kind == "swiglu":
        h = jax.nn.silu(dense(params["wg"], x, compute_dtype)) * h
    elif kind == "gelu":
        h = jax.nn.gelu(h)
    else:
        h = jax.nn.relu(h)
    if h.ndim == 3:
        h = constrain(h, "dp", None, "model")
    return constrain_batch(dense(params["wo"], h, compute_dtype))


# ---------------------------------------------------------------- rope


def rope(x, positions, theta: float = 1e4):
    """Rotary embedding. x: (..., S, H, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None, None] * freqs  # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------- flash attention


def _attend_block(q, k, v, m, l, acc, mask):
    """One (q-block, kv-block) flash step.  q: (B,Q,Hk,G,D), k/v: (B,K,Hk,D)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)
    s = s * scale + mask  # mask: (Q, K) additive, broadcast
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    alpha = jnp.exp(m - m_new)
    l_new = l * alpha + p.sum(axis=-1)
    pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(v.dtype), v,
                    preferred_element_type=jnp.float32)
    acc_new = acc * alpha[..., None] + pv
    return m_new, l_new, acc_new


# Re-pinning batch sharding on flash-bwd residuals was tried as a fix for
# GSPMD dropping the batch sharding across the custom_vjp boundary; measured
# effect on the 16x16 mesh was the OPPOSITE (conflicting constraints made
# GSPMD replicate the score blocks: 4.5x per-chip FLOPs, 7x temp memory on
# tinyllama train_4k).  Hypothesis refuted — logged in EXPERIMENTS.md §Perf.
_FLASH_BWD_CONSTRAIN = False


def _pad_to_blocks(q, k, v, block_q, block_k):
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    nq, nk = -(-sq // block_q), -(-sk // block_k)
    pad_q, pad_k = nq * block_q - sq, nk * block_k - sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    return q, k, v, nq, nk


def _flash_fwd_impl(q, k, v, causal, q_offset, block_q, block_k, kv_len):
    b, sq, h, dh = q.shape
    sk, hkv, dv = k.shape[1], k.shape[2], v.shape[-1]
    g = h // hkv
    block_q, block_k = min(block_q, sq), min(block_k, sk)
    qp_, kp_, vp_, nq, nk = _pad_to_blocks(q, k, v, block_q, block_k)
    qg = qp_.reshape(b, nq, block_q, hkv, g, dh)
    kg = kp_.reshape(b, nk, block_k, hkv, dh)
    vg = vp_.reshape(b, nk, block_k, hkv, dv)
    q_pos = q_offset + jnp.arange(nq * block_q).reshape(nq, block_q)
    k_pos = jnp.arange(nk * block_k).reshape(nk, block_k)
    valid_k = sk if kv_len is None else kv_len

    def q_step(_, qi):
        qblk = qg[:, qi]
        qp = q_pos[qi]

        def kv_step(carry, kj):
            m, l, acc = carry
            kp = k_pos[kj]
            mask = jnp.zeros((block_q, block_k), jnp.float32)
            if causal:
                mask = jnp.where(qp[:, None] >= kp[None, :], 0.0, -jnp.inf)
            mask = jnp.where(kp[None, :] < valid_k, mask, -jnp.inf)
            return _attend_block(qblk, kg[:, kj], vg[:, kj], m, l, acc, mask), None

        m0 = jnp.full((b, hkv, g, block_q), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, block_q), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, block_q, dv), jnp.float32)
        (m, l, acc), _ = lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]          # (B,Hkv,G,Q,Dv)
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), jnp.inf)
        return None, (out.transpose(0, 3, 1, 2, 4), lse)      # (B,Q,Hkv,G,Dv)

    _, (outs, lses) = lax.scan(q_step, None, jnp.arange(nq))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * block_q, h, dv)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(b, hkv, g, nq * block_q)
    return out[:, :sq].astype(q.dtype), lse[..., :sq]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal, q_offset, block_q, block_k):
    return _flash_fwd_impl(q, k, v, causal, q_offset, block_q, block_k, None)[0]


def _flash_fwd(q, k, v, causal, q_offset, block_q, block_k):
    out, lse = _flash_fwd_impl(q, k, v, causal, q_offset, block_q, block_k, None)
    return out, (q, k, v, out, lse)


def _flash_bwd(causal, q_offset, block_q, block_k, res, dout):
    """Flash backward: recompute score blocks from (q,k,v,out,lse).

    Residuals are O(S·D) — this is what keeps the 32k-token backward pass
    memory-linear (the naive scan-autodiff version stored O(S²) score
    blocks; see EXPERIMENTS.md §Perf).
    """
    q, k, v, out, lse = res
    if _FLASH_BWD_CONSTRAIN:
        from ..dist.sharding import constrain_batch
        # re-pin batch sharding on residuals: GSPMD sometimes drops it across
        # the custom_vjp boundary, replicating the (B,H,G,Sq,K) score blocks.
        q, k, v, out, dout = map(constrain_batch, (q, k, v, out, dout))
    b, sq, h, dh = q.shape
    sk, hkv, dv = k.shape[1], k.shape[2], v.shape[-1]
    g = h // hkv
    scale = dh ** -0.5
    bq, bk = min(block_q, sq), min(block_k, sk)
    qp_, kp_, vp_, nq, nk = _pad_to_blocks(q, k, v, bq, bk)
    spad_q, spad_k = nq * bq, nk * bk
    dout_p = jnp.pad(dout, ((0, 0), (0, spad_q - sq), (0, 0), (0, 0)))
    out_p = jnp.pad(out, ((0, 0), (0, spad_q - sq), (0, 0), (0, 0)))
    lse_p = jnp.pad(lse, ((0, 0), (0, 0), (0, 0), (0, spad_q - sq)),
                    constant_values=jnp.inf)
    qg = qp_.reshape(b, spad_q, hkv, g, dh).astype(jnp.float32)
    dog = dout_p.reshape(b, spad_q, hkv, g, dv).astype(jnp.float32)
    og = out_p.reshape(b, spad_q, hkv, g, dv).astype(jnp.float32)
    dsum = (dog * og).sum(-1)                                  # (B,Sq,Hkv,G)
    dsum = dsum.transpose(0, 2, 3, 1)                          # (B,Hkv,G,Sq)
    kg = kp_.reshape(b, nk, bk, hkv, dh).astype(jnp.float32)
    vg = vp_.reshape(b, nk, bk, hkv, dv).astype(jnp.float32)
    q_pos = q_offset + jnp.arange(spad_q)
    k_pos = jnp.arange(spad_k).reshape(nk, bk)

    def kv_step(dq_acc, kj):
        kb, vb = kg[:, kj], vg[:, kj]
        kp = k_pos[kj]
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kb,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            s = jnp.where(q_pos[None, None, None, :, None] >= kp[None, None, None, None, :],
                          s, -jnp.inf)
        s = jnp.where(kp[None, None, None, None, :] < sk, s, -jnp.inf)
        p = jnp.exp(s - lse_p[..., None])                      # (B,Hkv,G,Sq,K)
        dv_j = jnp.einsum("bhgqk,bqhgv->bkhv", p, dog)
        dp = jnp.einsum("bqhgv,bkhv->bhgqk", dog, vb)
        ds = p * (dp - dsum[..., None])
        dq_acc = dq_acc + jnp.einsum("bhgqk,bkhd->bqhgd", ds, kb) * scale
        dk_j = jnp.einsum("bhgqk,bqhgd->bkhd", ds, qg) * scale
        return dq_acc, (dk_j, dv_j)

    dq0 = jnp.zeros((b, spad_q, hkv, g, dh), jnp.float32)
    dq, (dks, dvs) = lax.scan(kv_step, dq0, jnp.arange(nk))
    dq = dq.reshape(b, spad_q, h, dh)[:, :sq].astype(q.dtype)
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(b, spad_k, hkv, dh)[:, :sk].astype(k.dtype)
    dv_ = dvs.transpose(1, 0, 2, 3, 4).reshape(b, spad_k, hkv, dv)[:, :sk].astype(v.dtype)
    return dq, dk, dv_


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q, k, v, *, causal: bool = True, q_offset: int = 0,
                    block_q: int = 512, block_k: int = 1024,
                    kv_len: Optional[jnp.ndarray] = None):
    """Memory-linear attention with GQA and a flash (recompute) backward.

    q: (B, Sq, H, Dh); k, v: (B, Sk, Hkv, Dv-capable); H % Hkv == 0.
    ``q_offset``: absolute position of q[0].  ``kv_len``: dynamic valid
    length (decode over a cache; that path is not differentiated).
    Returns (B, Sq, H, Dv).
    """
    if kv_len is not None:
        return _flash_fwd_impl(q, k, v, causal, q_offset, block_q, block_k, kv_len)[0]
    return _flash(q, k, v, causal, q_offset, block_q, block_k)


# ---------------------------------------------------------------- attention layer


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    qk_norm: bool = False
    rope_theta: float = 1e4
    causal: bool = True


def attention_init(key, cfg: AttnConfig, param_dtype):
    kq, kk, kv, ko = jax.random.split(key, 4)
    p = {
        "wq": dense_init(kq, cfg.d_model, cfg.n_heads * cfg.d_head, param_dtype),
        "wk": dense_init(kk, cfg.d_model, cfg.n_kv_heads * cfg.d_head, param_dtype),
        "wv": dense_init(kv, cfg.d_model, cfg.n_kv_heads * cfg.d_head, param_dtype),
        "wo": dense_init(ko, cfg.n_heads * cfg.d_head, cfg.d_model, param_dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(cfg.d_head, param_dtype)
        p["k_norm"] = rmsnorm_init(cfg.d_head, param_dtype)
    return p


def make_cache(batch, max_len, n_kv_heads, d_head, dtype):
    return {
        "k": jnp.zeros((batch, max_len, n_kv_heads, d_head), dtype),
        "v": jnp.zeros((batch, max_len, n_kv_heads, d_head), dtype),
    }


def attention(params, x, cfg: AttnConfig, compute_dtype, *, positions=None,
              cache=None, cache_index=None, kv_x=None):
    """Self- or cross-attention.

    Training/prefill: ``cache=None`` → flash attention over x (causal per cfg).
    Decode: pass ``cache`` + scalar ``cache_index``; x is (B, 1, D); returns
    (out, new_cache).  Cross-attention: pass ``kv_x`` (B, Skv, D) (encoder
    memory; non-causal, no rope on cross keys by convention here).
    """
    from ..dist.sharding import constrain, constrain_batch, model_divides
    b, s, _ = x.shape
    src = x if kv_x is None else kv_x
    # §Perf it.1: pin projections (B,S,H·dh) to model-sharded on the flat
    # head dim, but ONLY when the head count divides the model axis — the
    # constraint on padded-head archs (qwen3 40H, yi 56H) forced reshards
    # that regressed prefill 5x (measured; see EXPERIMENTS.md §Perf).
    qm = "model" if model_divides(cfg.n_heads) else None
    km = "model" if model_divides(cfg.n_kv_heads) else None
    q = constrain(dense(params["wq"], x, compute_dtype), "dp", None, qm) \
        .reshape(b, s, cfg.n_heads, cfg.d_head)
    k = constrain(dense(params["wk"], src, compute_dtype), "dp", None, km) \
        .reshape(b, src.shape[1], cfg.n_kv_heads, cfg.d_head)
    v = constrain(dense(params["wv"], src, compute_dtype), "dp", None, km) \
        .reshape(b, src.shape[1], cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    if positions is None:
        positions = jnp.arange(s)[None, :]
    if kv_x is None:  # rope only applies to self-attention
        if cache is not None and cache_index is not None:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
        else:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, jnp.arange(src.shape[1])[None, :], cfg.rope_theta)

    if cache is not None:
        if cache_index is not None:  # decode: write s (=1) new kv rows
            k_cache = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                               (0, cache_index, 0, 0))
            v_cache = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                               (0, cache_index, 0, 0))
            new_cache = {"k": k_cache, "v": v_cache}
            kv_len = cache_index + s
            out = _decode_attend(q, k_cache, v_cache, kv_len, compute_dtype)
        else:  # prefill into cache
            k_cache = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
            v_cache = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
            new_cache = {"k": k_cache, "v": v_cache}
            out = flash_attention(q, k, v, causal=cfg.causal)
        out = constrain(out.reshape(b, s, cfg.n_heads * cfg.d_head),
                        "dp", None, "model")
        return constrain_batch(dense(params["wo"], out, compute_dtype)), new_cache

    out = flash_attention(q, k, v, causal=cfg.causal and kv_x is None)
    out = constrain(out.reshape(b, s, cfg.n_heads * cfg.d_head), "dp", None, "model")
    return constrain_batch(dense(params["wo"], out, compute_dtype))


def cross_kv(params, kv_x, cfg: AttnConfig, compute_dtype):
    """Precompute cross-attention K/V from encoder memory (cache once)."""
    b, skv, _ = kv_x.shape
    k = dense(params["wk"], kv_x, compute_dtype).reshape(b, skv, cfg.n_kv_heads, cfg.d_head)
    v = dense(params["wv"], kv_x, compute_dtype).reshape(b, skv, cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        k = rmsnorm(params["k_norm"], k)
    return k, v


def attention_with_kv(params, x, k, v, cfg: AttnConfig, compute_dtype):
    """Cross-attention against precomputed K/V (decode path; non-causal)."""
    b, s, _ = x.shape
    q = dense(params["wq"], x, compute_dtype).reshape(b, s, cfg.n_heads, cfg.d_head)
    if cfg.qk_norm:
        q = rmsnorm(params["q_norm"], q)
    out = _decode_attend(q, k, v, k.shape[1], compute_dtype)
    out = out.reshape(b, s, cfg.n_heads * cfg.d_head)
    return dense(params["wo"], out, compute_dtype)


def _decode_attend(q, k_cache, v_cache, kv_len, compute_dtype):
    """Single/few-token attention over a cache: O(S) scores, no S×S."""
    b, s, h, dh = q.shape
    hkv = k_cache.shape[2]
    g = h // hkv
    qg = q.reshape(b, s, hkv, g, dh)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k_cache.astype(compute_dtype),
                        preferred_element_type=jnp.float32)
    scores = scores * (dh ** -0.5)
    kpos = jnp.arange(k_cache.shape[1])
    mask = kpos[None, None, None, None, :] < kv_len
    scores = jnp.where(mask, scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(compute_dtype),
                     v_cache.astype(compute_dtype))
    return out.reshape(b, s, h, dh)
