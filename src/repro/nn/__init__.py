"""Subsystem package."""
