"""Jit'd public wrappers around the Pallas kernels.

These are what models call.  Responsibilities:
  * compute quotient/remainder bucket indices (cheap vector ops XLA fuses);
  * choose execution path: real Pallas on TPU, ``interpret=True`` elsewhere
    (this container is CPU-only — interpret mode runs the kernel body in
    Python and is the validation target), or the jnp reference for configs
    the kernels don't cover (op="concat", k>2 partitions);
  * handle padding so callers never see blocking constraints.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .dot_interaction import dot_interaction as _dot_kernel
from .embedding_bag import qr_embedding_bag as _bag_kernel
from .qr_gather import qr_gather as _gather_kernel
from .qr_gather import qr_gather_quant as _gather_quant_kernel
from .serve_path import fused_serve_pool as _serve_kernel

__all__ = ["on_tpu", "qr_lookup", "qr_bag_lookup", "serve_bag_pool",
           "dlrm_interact"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _split_idx(idx, m):
    idx = jnp.asarray(idx, jnp.int32)
    return idx % m, idx // m


from ..core.compositional import is_quantized_table as _is_quant
from ..core.compositional import table_rows


def _rows(table) -> int:
    return (table["q"] if _is_quant(table) else table).shape[0]


def _meta(table):
    """(rows, 2) f32 per-row (scale, zp) — the fused kernel's meta operand."""
    return jnp.concatenate([table["scale"].astype(jnp.float32),
                            table["zp"].astype(jnp.float32)], axis=1)


def qr_lookup(idx, w_rem, w_quo, *, op: str = "mult", use_kernel: bool = True,
              interpret: bool | None = None):
    """QR-trick embedding lookup for arbitrary-rank ``idx``.

    Tables may be dense arrays or row-quantized dicts (``serve.quantize``);
    when both are quantized the fused dequant kernel gathers the int8 rows
    and dequantizes in VMEM during the combine.
    """
    m = _rows(w_rem)
    rem, quo = _split_idx(idx, m)
    if _is_quant(w_rem) or _is_quant(w_quo):
        if use_kernel and op in ("mult", "add") \
                and _is_quant(w_rem) and _is_quant(w_quo):
            interpret = (not on_tpu()) if interpret is None else interpret
            shape = rem.shape
            out = _gather_quant_kernel(rem.reshape(-1), quo.reshape(-1),
                                       w_rem["q"], w_quo["q"],
                                       _meta(w_rem), _meta(w_quo),
                                       op=op, interpret=interpret)
            return out.reshape(*shape, w_rem["q"].shape[1])
        a, b = table_rows(w_rem, rem), table_rows(w_quo, quo)
        if op == "concat":
            return jnp.concatenate([a, b], axis=-1)
        return a * b if op == "mult" else a + b
    if not use_kernel or op == "concat":
        out = ref.qr_gather_ref(rem, quo, w_rem, w_quo, op=op) if op != "concat" \
            else jnp.concatenate([jnp.take(w_rem, rem, axis=0),
                                  jnp.take(w_quo, quo, axis=0)], axis=-1)
        return out
    interpret = (not on_tpu()) if interpret is None else interpret
    shape = rem.shape
    out = _gather_kernel(rem.reshape(-1), quo.reshape(-1), w_rem, w_quo,
                         op=op, interpret=interpret)
    return out.reshape(*shape, w_rem.shape[1])


def qr_bag_lookup(idx, mask, w_rem, w_quo, *, op: str = "mult",
                  use_kernel: bool = True, interpret: bool | None = None):
    """Sum-pooled multi-hot QR lookup: idx/mask ``(B, L)`` -> ``(B, D)``."""
    m = _rows(w_rem)
    rem, quo = _split_idx(idx, m)
    if _is_quant(w_rem) or _is_quant(w_quo):
        # quantized bag path: dequantized rows combined per the op, pooled
        # in f32 (same audit convention as the dense kernel); rows come out
        # f32 so no cast back is needed
        a, b = table_rows(w_rem, rem), table_rows(w_quo, quo)
        if op == "concat":
            rows = jnp.concatenate([a, b], axis=-1)
        else:
            rows = a * b if op == "mult" else a + b
        return (rows * mask[..., None].astype(jnp.float32)).sum(axis=1)
    if not use_kernel or op == "concat":
        if op == "concat":
            # pool in f32: a bf16 running sum rounds every one of the L adds
            # (the bug the embedding-bag kernel audit caught at L=16, D=128)
            rows = jnp.concatenate([jnp.take(w_rem, rem, axis=0),
                                    jnp.take(w_quo, quo, axis=0)],
                                   axis=-1).astype(jnp.float32)
            pooled = (rows * mask[..., None].astype(jnp.float32)).sum(axis=1)
            return pooled.astype(w_rem.dtype)
        return ref.qr_embedding_bag_ref(rem, quo, mask, w_rem, w_quo, op=op)
    interpret = (not on_tpu()) if interpret is None else interpret
    return _bag_kernel(rem, quo, mask, w_rem, w_quo, op=op, interpret=interpret)


def serve_bag_pool(idx, mask, w_a, w_b=None, *, op: str = "mult", proj=None,
                   use_kernel: bool = True, interpret: bool | None = None):
    """Serving hot-path pooled lookup: gather (+dequant) → pool → project.

    The single entry point the serving stack routes through.  ``w_a`` (and
    the optional quotient table ``w_b``) may be dense arrays or
    row-quantized dicts (``serve.quantize``).  With ``w_b`` given, ``idx``
    is raw and split ``(i % m, i // m)`` here; single-table callers
    (full / hash / the engine's device-resident row slab) pass pre-folded
    indices.  ``proj`` is the mixed-dimension ``(d, D)`` projection —
    pooling and projection fuse into the same VMEM pass on the kernel
    path, and the jnp fallback (non-TPU, or op="concat"/mixed-quant pairs
    the kernel doesn't cover) computes the identical math via the
    ``kernels.ref`` oracle.
    """
    quant_a = _is_quant(w_a)
    quant_b = _is_quant(w_b) if w_b is not None else quant_a
    if w_b is not None:
        m = _rows(w_a)
        idx_a, idx_b = _split_idx(idx, m)
    else:
        idx_a, idx_b = jnp.asarray(idx, jnp.int32), None
    fusable = (w_b is None or op in ("mult", "add")) and quant_a == quant_b
    qa = w_a["q"] if quant_a else w_a
    qb = (w_b["q"] if quant_b else w_b) if w_b is not None else None
    ma = _meta(w_a) if quant_a else None
    mb = _meta(w_b) if (w_b is not None and quant_b) else None
    if use_kernel and fusable:
        interpret = (not on_tpu()) if interpret is None else interpret
        return _serve_kernel(idx_a, mask, qa, idx_b=idx_b, w_b=qb,
                             meta_a=ma, meta_b=mb, proj=proj, op=op,
                             interpret=interpret)
    if not fusable:
        # op="concat" / mixed dense+quant pair: gather per table, combine,
        # pool in f32, project — same contract, jnp all the way
        a = table_rows(w_a, idx_a)
        b = table_rows(w_b, idx_b)
        rows = (jnp.concatenate([a, b], axis=-1) if op == "concat"
                else (a * b if op == "mult" else a + b))
        pooled = (rows.astype(jnp.float32)
                  * mask[..., None].astype(jnp.float32)).sum(axis=1)
        quant = quant_a or quant_b
        pooled = pooled.astype(jnp.float32 if quant else a.dtype)
        return pooled if proj is None \
            else pooled.astype(jnp.float32) @ proj.astype(jnp.float32)
    return ref.fused_serve_pool_ref(idx_a, mask, qa, idx_b=idx_b, w_b=qb,
                                    meta_a=ma, meta_b=mb, proj=proj, op=op)


def dlrm_interact(x, *, use_kernel: bool = True, interpret: bool | None = None,
                  block_b: int = 8):
    """DLRM pairwise-dot interaction, padding batch to the kernel block."""
    if not use_kernel:
        return ref.dot_interaction_ref(x)
    interpret = (not on_tpu()) if interpret is None else interpret
    b = x.shape[0]
    pad = (-b) % block_b
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
    out = _dot_kernel(x, block_b=block_b, interpret=interpret)
    return out[:b]
