"""Fused quotient–remainder gather kernel (Pallas TPU).

The paper's Algorithm 2 hot path is ``out[n] = W_rem[i_n mod m] ⊙
W_quo[i_n \\ m]`` — two HBM gathers plus an elementwise combine.  A naive
XLA lowering makes three HBM round-trips (gather, gather, fused-mult writes
back).  This kernel performs both row fetches and the combine in one pass:

* the per-row table indices are **scalar-prefetch** operands, consumed by
  the ``BlockSpec.index_map`` of each table so the pipeline DMAs exactly the
  two needed ``(1, D)`` rows from HBM into VMEM per grid step;
* consecutive grid steps are double-buffered by the Pallas pipeline, so row
  ``n+1``'s DMAs overlap row ``n``'s combine (the TPU-native analogue of the
  fused CUDA embedding kernels the paper's deployment uses);
* the combine (mult/add) happens in VMEM and a single ``(1, D)`` result row
  is written out.

TPU alignment: ``D`` should be a multiple of 128 (true for every assigned
LM arch: 1024–7168).  For small-D recommendation tables (D=16) production
storage would pad rows to the 128-lane tile; tests exercise both aligned
and unaligned D in interpret mode.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["qr_gather", "qr_gather_quant"]


def _kernel(rem_idx_ref, quo_idx_ref, wrem_ref, wquo_ref, out_ref, *, op):
    del rem_idx_ref, quo_idx_ref  # consumed by the index_maps
    # Combine in f32 (accumulation-audit convention shared with
    # embedding_bag.py / dot_interaction.py): bf16 rows are exact in f32,
    # so the only rounding left is the single cast back to the table dtype.
    a = wrem_ref[0, :].astype(jnp.float32)
    b = wquo_ref[0, :].astype(jnp.float32)
    if op == "mult":
        out_ref[0, :] = (a * b).astype(out_ref.dtype)
    elif op == "add":
        out_ref[0, :] = (a + b).astype(out_ref.dtype)
    else:  # pragma: no cover - validated in ops.py
        raise ValueError(op)


@functools.partial(jax.jit, static_argnames=("op", "interpret"))
def qr_gather(rem_idx, quo_idx, w_rem, w_quo, *, op: str = "mult",
              interpret: bool = True):
    """Fused ``w_rem[rem_idx] (mult|add) w_quo[quo_idx]``.

    Args:
      rem_idx, quo_idx: int32 ``(N,)`` bucket indices (precomputed ``i % m``
        and ``i // m`` — cheap vector ops left to XLA).
      w_rem: ``(m, D)`` remainder table.  w_quo: ``(q, D)`` quotient table.
    Returns: ``(N, D)`` combined embedding rows.
    """
    n = rem_idx.shape[0]
    d = w_rem.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, rem, quo: (rem[i], 0)),
            pl.BlockSpec((1, d), lambda i, rem, quo: (quo[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, rem, quo: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_kernel, op=op),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), w_rem.dtype),
        interpret=interpret,
    )(rem_idx.astype(jnp.int32), quo_idx.astype(jnp.int32), w_rem, w_quo)


# ------------------------------------------------------- fused dequant path


def _quant_kernel(rem_idx_ref, quo_idx_ref, wrem_ref, wquo_ref,
                  mrem_ref, mquo_ref, out_ref, *, op):
    del rem_idx_ref, quo_idx_ref  # consumed by the index_maps
    # Serving hot path: the tables stay int8 in HBM and only the two
    # gathered rows are dequantized, *in VMEM*, during the combine — the
    # f32 tables never exist.  meta rows are (scale, zp) per table row;
    # all arithmetic is f32 (accumulation-audit convention), and the row
    # is written out in f32 (quantized serving feeds f32 activations).
    sr = mrem_ref[0, 0].astype(jnp.float32)
    zr = mrem_ref[0, 1].astype(jnp.float32)
    sq = mquo_ref[0, 0].astype(jnp.float32)
    zq = mquo_ref[0, 1].astype(jnp.float32)
    a = (wrem_ref[0, :].astype(jnp.float32) - zr) * sr
    b = (wquo_ref[0, :].astype(jnp.float32) - zq) * sq
    if op == "mult":
        out_ref[0, :] = a * b
    elif op == "add":
        out_ref[0, :] = a + b
    else:  # pragma: no cover - validated in ops.py
        raise ValueError(op)


@functools.partial(jax.jit, static_argnames=("op", "interpret"))
def qr_gather_quant(rem_idx, quo_idx, w_rem, w_quo, rem_meta, quo_meta, *,
                    op: str = "mult", interpret: bool = True):
    """Fused quantized QR gather: int8 rows in, dequant + combine in VMEM.

    Args:
      rem_idx, quo_idx: int32 ``(N,)`` bucket indices.
      w_rem: int8 ``(m, D)``; w_quo: int8 ``(q, D)`` quantized tables.
      rem_meta, quo_meta: f32 ``(rows, 2)`` per-row ``(scale, zp)`` —
        callers build them from the ``serve.quantize`` table dicts (see
        ``ops.qr_lookup``); packing both scalars into one operand keeps
        the kernel at one extra ``(1, 2)`` DMA per table per row.
    Returns: f32 ``(N, D)`` combined dequantized rows.
    """
    n = rem_idx.shape[0]
    d = w_rem.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i, rem, quo: (rem[i], 0)),
            pl.BlockSpec((1, d), lambda i, rem, quo: (quo[i], 0)),
            pl.BlockSpec((1, 2), lambda i, rem, quo: (rem[i], 0)),
            pl.BlockSpec((1, 2), lambda i, rem, quo: (quo[i], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, rem, quo: (i, 0)),
    )
    return pl.pallas_call(
        functools.partial(_quant_kernel, op=op),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=interpret,
    )(rem_idx.astype(jnp.int32), quo_idx.astype(jnp.int32), w_rem, w_quo,
      rem_meta.astype(jnp.float32), quo_meta.astype(jnp.float32))
