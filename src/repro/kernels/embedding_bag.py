"""Fused QR embedding-bag kernel (Pallas TPU): gather + combine + sum-pool.

Multi-hot categorical features (bags of L category ids per example) are
pooled by summation in DLRM-style models.  Unfused, that is ``2·B·L`` row
gathers, a ``(B, L, D)`` intermediate, and a reduction — ``3·B·L·D`` HBM
traffic.  This kernel keeps the ``(1, D)`` accumulator resident in VMEM
across the ``L`` inner grid steps and only writes the pooled ``(B, D)``
result, so HBM traffic drops to ``2·B·L·D`` reads + ``B·D`` writes (the
paper-relevant bandwidth saving: pooling is free).

Grid is ``(B, L)`` with the bag dimension innermost; the output BlockSpec
maps every ``(b, ·)`` step to the same row so the revisited block stays in
VMEM (Pallas only flushes it when ``b`` changes).  Masked entries multiply
by 0 rather than branching, keeping the pipeline dense.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["qr_embedding_bag"]


def _kernel(rem_idx_ref, quo_idx_ref, mask_ref, wrem_ref, wquo_ref, out_ref, *, op):
    del rem_idx_ref, quo_idx_ref
    l = pl.program_id(1)
    # Combine and accumulate in f32: the running bag sum revisits the output
    # block L times, and bf16 accumulation rounds the partial sum every step
    # (worst-case error ~L·|sum|·2⁻⁹ — past the 3e-2 oracle tolerance at
    # L=16, D=128).  Rows are cast on read; the pooled result is cast back
    # to the table dtype outside the kernel.
    w = mask_ref[0, l].astype(jnp.float32)
    a = wrem_ref[0, :].astype(jnp.float32)
    b = wquo_ref[0, :].astype(jnp.float32)
    if op == "mult":
        contrib = a * b * w
    else:  # add
        contrib = (a + b) * w

    @pl.when(l == 0)
    def _init():
        out_ref[0, :] = contrib

    @pl.when(l > 0)
    def _acc():
        out_ref[0, :] = out_ref[0, :] + contrib


@functools.partial(jax.jit, static_argnames=("op", "interpret"))
def qr_embedding_bag(rem_idx, quo_idx, mask, w_rem, w_quo, *, op: str = "mult",
                     interpret: bool = True):
    """``out[b] = sum_l mask[b,l] * (w_rem[rem_idx[b,l]] op w_quo[quo_idx[b,l]])``.

    Args:
      rem_idx, quo_idx: int32 ``(B, L)``.  mask: ``(B, L)`` (0/1 or weights).
      w_rem: ``(m, D)``; w_quo: ``(q, D)``.
    Returns: ``(B, D)`` pooled embeddings.
    """
    b, l = rem_idx.shape
    d = w_rem.shape[1]
    flat_rem = rem_idx.reshape(-1).astype(jnp.int32)
    flat_quo = quo_idx.reshape(-1).astype(jnp.int32)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, l),
        in_specs=[
            pl.BlockSpec((1, l), lambda i, j, rem, quo: (i, 0)),      # mask row
            pl.BlockSpec((1, d), lambda i, j, rem, quo: (rem[i * l + j], 0)),
            pl.BlockSpec((1, d), lambda i, j, rem, quo: (quo[i * l + j], 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i, j, rem, quo: (i, 0)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, op=op),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )(flat_rem, flat_quo, mask.astype(w_rem.dtype), w_rem, w_quo)
    return out.astype(w_rem.dtype)
