"""Pallas TPU kernels for the paper's compute hot-spots (validated interpret=True)."""

from .ops import (dlrm_interact, on_tpu, qr_bag_lookup, qr_lookup,
                  serve_bag_pool)

__all__ = ["dlrm_interact", "on_tpu", "qr_bag_lookup", "qr_lookup",
           "serve_bag_pool"]
