"""Pure-jnp oracles for every Pallas kernel (ground truth for tests/benches)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["qr_gather_ref", "qr_gather_quant_ref", "qr_embedding_bag_ref",
           "dot_interaction_ref"]


def qr_gather_ref(rem_idx, quo_idx, w_rem, w_quo, *, op: str = "mult"):
    a = jnp.take(w_rem, rem_idx, axis=0)
    b = jnp.take(w_quo, quo_idx, axis=0)
    return a * b if op == "mult" else a + b


def _dequant_rows_ref(w, meta, idx):
    """f32 rows from an int8 table + per-row (scale, zp) meta."""
    rows = jnp.take(w, idx, axis=0).astype(jnp.float32)
    m = jnp.take(meta.astype(jnp.float32), idx, axis=0)
    return (rows - m[..., 1:2]) * m[..., 0:1]


def qr_gather_quant_ref(rem_idx, quo_idx, w_rem, w_quo, rem_meta, quo_meta,
                        *, op: str = "mult"):
    a = _dequant_rows_ref(w_rem, rem_meta, rem_idx)
    b = _dequant_rows_ref(w_quo, quo_meta, quo_idx)
    return a * b if op == "mult" else a + b


def qr_embedding_bag_ref(rem_idx, quo_idx, mask, w_rem, w_quo, *, op: str = "mult"):
    # Accumulate the bag sum in f32 (accumulation-audit convention): the
    # oracle must not inherit the bf16 running-sum rounding it exists to
    # catch in the kernels.  Result is cast back to the table dtype.
    rows = qr_gather_ref(rem_idx, quo_idx, w_rem, w_quo, op=op)  # (B, L, D)
    pooled = (rows.astype(jnp.float32)
              * mask[..., None].astype(jnp.float32)).sum(axis=1)
    return pooled.astype(w_rem.dtype)


def dot_interaction_ref(x):
    # f32 MXU accumulation, matching the kernel's preferred_element_type
    scores = jnp.einsum("bfd,bgd->bfg", x, x,
                        preferred_element_type=jnp.float32)
    i, j = np.tril_indices(x.shape[1], k=-1)
    return scores[:, i, j].astype(x.dtype)
