"""Pure-jnp oracles for every Pallas kernel (ground truth for tests/benches)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["qr_gather_ref", "qr_embedding_bag_ref", "dot_interaction_ref"]


def qr_gather_ref(rem_idx, quo_idx, w_rem, w_quo, *, op: str = "mult"):
    a = jnp.take(w_rem, rem_idx, axis=0)
    b = jnp.take(w_quo, quo_idx, axis=0)
    return a * b if op == "mult" else a + b


def qr_embedding_bag_ref(rem_idx, quo_idx, mask, w_rem, w_quo, *, op: str = "mult"):
    rows = qr_gather_ref(rem_idx, quo_idx, w_rem, w_quo, op=op)  # (B, L, D)
    return (rows * mask[..., None].astype(rows.dtype)).sum(axis=1)


def dot_interaction_ref(x):
    scores = jnp.einsum("bfd,bgd->bfg", x, x)
    i, j = np.tril_indices(x.shape[1], k=-1)
    return scores[:, i, j].astype(x.dtype)
