"""Pure-jnp oracles for every Pallas kernel (ground truth for tests/benches)."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["qr_gather_ref", "qr_gather_quant_ref", "qr_embedding_bag_ref",
           "fused_serve_pool_ref", "dot_interaction_ref"]


def qr_gather_ref(rem_idx, quo_idx, w_rem, w_quo, *, op: str = "mult"):
    a = jnp.take(w_rem, rem_idx, axis=0)
    b = jnp.take(w_quo, quo_idx, axis=0)
    return a * b if op == "mult" else a + b


def _dequant_rows_ref(w, meta, idx):
    """f32 rows from an int8 table + per-row (scale, zp) meta."""
    rows = jnp.take(w, idx, axis=0).astype(jnp.float32)
    m = jnp.take(meta.astype(jnp.float32), idx, axis=0)
    return (rows - m[..., 1:2]) * m[..., 0:1]


def qr_gather_quant_ref(rem_idx, quo_idx, w_rem, w_quo, rem_meta, quo_meta,
                        *, op: str = "mult"):
    a = _dequant_rows_ref(w_rem, rem_meta, rem_idx)
    b = _dequant_rows_ref(w_quo, quo_meta, quo_idx)
    return a * b if op == "mult" else a + b


def qr_embedding_bag_ref(rem_idx, quo_idx, mask, w_rem, w_quo, *, op: str = "mult"):
    # Accumulate the bag sum in f32 (accumulation-audit convention): the
    # oracle must not inherit the bf16 running-sum rounding it exists to
    # catch in the kernels.  Result is cast back to the table dtype.
    rows = qr_gather_ref(rem_idx, quo_idx, w_rem, w_quo, op=op)  # (B, L, D)
    pooled = (rows.astype(jnp.float32)
              * mask[..., None].astype(jnp.float32)).sum(axis=1)
    return pooled.astype(w_rem.dtype)


def fused_serve_pool_ref(idx_a, mask, w_a, idx_b=None, w_b=None, meta_a=None,
                         meta_b=None, proj=None, *, op: str = "mult"):
    """Oracle for ``serve_path.fused_serve_pool``: gather (+dequant) →
    combine → masked f32 sum-pool → one rounding to the pool dtype →
    projection.  The combine happens in f32 even for dense bf16 tables
    (bf16 rows are exact in f32), matching the kernel's accumulation-audit
    convention, so the only dtype-dependent rounding is the single cast of
    the pooled bag."""
    quant = meta_a is not None
    if mask.shape[1] == 0:                     # all-empty wave: Lb floors at 1
        b_ = mask.shape[0]
        mask = jnp.zeros((b_, 1), mask.dtype)
        idx_a = jnp.zeros((b_, 1), jnp.int32)
        idx_b = jnp.zeros((b_, 1), jnp.int32) if idx_b is not None else None

    def rows(w, meta, idx):
        r = jnp.take(w, idx, axis=0).astype(jnp.float32)
        if meta is not None:
            m = jnp.take(meta.astype(jnp.float32), idx, axis=0)
            r = (r - m[..., 1:2]) * m[..., 0:1]
        return r

    row = rows(w_a, meta_a, idx_a)
    if idx_b is not None:
        rb = rows(w_b, meta_b, idx_b)
        row = row * rb if op == "mult" else row + rb
    pooled = (row * mask[..., None].astype(jnp.float32)).sum(axis=1)
    pooled = pooled.astype(jnp.float32 if quant else w_a.dtype)
    if proj is None:
        return pooled
    return pooled.astype(jnp.float32) @ proj.astype(jnp.float32)


def dot_interaction_ref(x):
    # f32 MXU accumulation, matching the kernel's preferred_element_type
    scores = jnp.einsum("bfd,bgd->bfg", x, x,
                        preferred_element_type=jnp.float32)
    i, j = np.tril_indices(x.shape[1], k=-1)
    return scores[:, i, j].astype(x.dtype)
