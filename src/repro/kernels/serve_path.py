"""Fused serving hot-path kernel (Pallas TPU): gather → dequant → pool → project.

The serving data path for one categorical feature is

    rows   = dequant(gather(tables, idx))        # int8 rows widen in VMEM
    pooled = sum_l mask[b, l] * combine(rows)    # multi-hot bag pooling
    feat   = pooled @ proj                       # mixed-dim width projection

Unfused that is up to six HBM gathers per row (q/scale/zp per table), a
``(B, L, D)`` f32 intermediate, a reduction, and a separate projection
matmul — the exact chain PR 3's serve numbers showed dominating the hot
path.  This kernel does the whole thing in one VMEM pass:

* per-row table indices are **scalar-prefetch** operands consumed by the
  ``BlockSpec.index_map`` of each table, so the pipeline DMAs exactly the
  needed ``(1, d)`` int8/f32 rows (plus their ``(1, 2)`` scale/zp meta)
  from HBM per grid step, double-buffered across steps;
* dequantization (``(q - zp) * scale``) and the mult/add combine happen in
  VMEM, in f32 (accumulation-audit convention shared with
  ``embedding_bag.py`` — a bf16 running sum rounds every one of the L
  adds);
* the ``(1, d)`` bag accumulator lives in VMEM scratch across the L inner
  grid steps, and on the last step is projected through the resident
  ``(d, D)`` projection — only the final ``(1, D)`` feature row is ever
  written to HBM.

Shapes are degrees of freedom, not special cases: one table (full /
hashing-trick, the caller pre-folds ``idx mod m``) or a QR pair, dense
f32/bf16 or row-quantized int8 tables, projection present (mixed-dimension
plans) or absent (uniform widths).  Empty bags (all-zero mask rows) pool
to the exact zero vector; the wrapper pads ``L=0`` waves to one masked
slot, mirroring the engine's ``Lb >= 1`` floor.

TPU alignment: ``d`` should be a multiple of 128 for production; tests
exercise the full differential grid in interpret mode (this container is
CPU-only — interpret mode runs the kernel body in Python and is the
validation target, same caveat as ``qr_gather.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["fused_serve_pool"]


def _kernel(*refs, op, has_b, quant, project, l_steps, out_dtype, pool_dtype):
    """Ref layout (flags select which slots exist):

    ``[idx_a, (idx_b)] + [mask, w_a, (meta_a), (w_b), (meta_b), (proj)]
    + [out] + [acc]``
    """
    it = iter(refs)
    next(it)                                   # idx_a: consumed by index_maps
    if has_b:
        next(it)                               # idx_b: consumed by index_maps
    mask_ref = next(it)
    wa_ref = next(it)
    ma_ref = next(it) if quant else None
    wb_ref = mb_ref = None
    if has_b:
        wb_ref = next(it)
        mb_ref = next(it) if quant else None
    proj_ref = next(it) if project else None
    out_ref = next(it)
    acc_ref = next(it)

    l = pl.program_id(1)
    w = mask_ref[0, l].astype(jnp.float32)
    a = wa_ref[0, :].astype(jnp.float32)
    if quant:
        a = (a - ma_ref[0, 1].astype(jnp.float32)) \
            * ma_ref[0, 0].astype(jnp.float32)
    if has_b:
        b = wb_ref[0, :].astype(jnp.float32)
        if quant:
            b = (b - mb_ref[0, 1].astype(jnp.float32)) \
                * mb_ref[0, 0].astype(jnp.float32)
        row = a * b if op == "mult" else a + b
    else:
        row = a
    contrib = row * w

    @pl.when(l == 0)
    def _init():
        acc_ref[0, :] = contrib

    @pl.when(l > 0)
    def _acc():
        acc_ref[0, :] = acc_ref[0, :] + contrib

    @pl.when(l == l_steps - 1)
    def _emit():
        # One rounding to the pool dtype (table dtype for dense tables, f32
        # for dequantized rows) *before* the projection — bit-parity with
        # the unfused pool-then-project path the models ship today.
        pooled = acc_ref[0, :].astype(pool_dtype)
        if project:
            out = jnp.dot(pooled[None, :].astype(jnp.float32),
                          proj_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)[0]
        else:
            out = pooled
        out_ref[0, :] = out.astype(out_dtype)


@functools.partial(jax.jit, static_argnames=("op", "interpret"))
def fused_serve_pool(idx_a, mask, w_a, idx_b=None, w_b=None, meta_a=None,
                     meta_b=None, proj=None, *, op: str = "mult",
                     interpret: bool = True):
    """Fused bag lookup: gather (+dequant) → masked sum-pool → project.

    Args:
      idx_a: int32 ``(B, L)`` row indices into ``w_a`` (pre-folded: the
        remainder ``i % m`` for QR pairs, ``i mod m`` for hash tables).
      mask: ``(B, L)`` pool weights (0 drops the slot; an all-zero row —
        an empty bag — pools to the exact zero vector).  ``L=0`` is legal
        and padded to one masked slot.
      w_a: ``(m, d)`` table — f32/bf16 dense, or int8 with ``meta_a``.
      idx_b, w_b: optional quotient side of a QR pair (``op`` combines).
      meta_a, meta_b: f32 ``(rows, 2)`` per-row ``(scale, zp)`` when the
        matching table is int8 (both tables of a pair quantize together).
      proj: optional ``(d, D)`` mixed-dimension projection applied to the
        pooled bag (pooling and projection are both linear, so
        pool-then-project equals the unfused path).
    Returns: ``(B, D)`` features — ``D = proj.shape[1]`` when projecting,
      else ``d``; dtype f32 for quantized/projected paths, the table dtype
      otherwise.
    """
    quant = meta_a is not None
    has_b = idx_b is not None
    project = proj is not None
    if has_b != (w_b is not None) or (quant and has_b) != (meta_b is not None):
        raise ValueError("QR pair / quant meta operands must come in pairs")
    if mask.shape[1] == 0:                     # all-empty wave: Lb floors at 1
        b_ = mask.shape[0]
        mask = jnp.zeros((b_, 1), mask.dtype)
        idx_a = jnp.zeros((b_, 1), jnp.int32)
        idx_b = jnp.zeros((b_, 1), jnp.int32) if has_b else None
    b, l = mask.shape
    d = w_a.shape[1]
    pool_dtype = jnp.float32 if quant else w_a.dtype
    out_dtype = jnp.float32 if (quant or project) else w_a.dtype
    d_out = proj.shape[1] if project else d

    flat_a = idx_a.reshape(-1).astype(jnp.int32)
    prefetch = [flat_a]
    if has_b:
        prefetch.append(idx_b.reshape(-1).astype(jnp.int32))

    def row_a(i, j, ia, *rest):
        return (ia[i * l + j], 0)

    def row_b(i, j, ia, ib):
        return (ib[i * l + j], 0)

    def batch_row(i, j, *_):
        return (i, 0)

    def pinned(i, j, *_):
        return (0, 0)

    in_specs = [pl.BlockSpec((1, l), batch_row),           # mask
                pl.BlockSpec((1, d), row_a)]               # w_a row
    operands = [mask.astype(jnp.float32), w_a]
    if quant:
        in_specs.append(pl.BlockSpec((1, 2), row_a))       # (scale, zp)_a
        operands.append(meta_a.astype(jnp.float32))
    if has_b:
        in_specs.append(pl.BlockSpec((1, d), row_b))
        operands.append(w_b)
        if quant:
            in_specs.append(pl.BlockSpec((1, 2), row_b))
            operands.append(meta_b.astype(jnp.float32))
    if project:
        in_specs.append(pl.BlockSpec(proj.shape, pinned))  # stays resident
        operands.append(proj)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(prefetch),
        grid=(b, l),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, d_out), batch_row),
        scratch_shapes=[pltpu.VMEM((1, d), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, op=op, has_b=has_b, quant=quant,
                          project=project, l_steps=l, out_dtype=out_dtype,
                          pool_dtype=pool_dtype),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, d_out), out_dtype),
        interpret=interpret,
    )(*prefetch, *operands)
