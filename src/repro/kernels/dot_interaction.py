"""DLRM pairwise dot-interaction kernel (Pallas TPU).

DLRM concatenates the bottom-MLP output with all sparse embeddings into
``X ∈ (B, F, D)`` and feeds the strictly-lower-triangular entries of
``X·Xᵀ`` to the top MLP.  Per batch block this is a small MXU matmul
(``F×D @ D×F``) followed by a triangle extraction; fusing both keeps the
``(F, F)`` score matrix in VMEM and writes only the ``F(F-1)/2`` packed
entries.

Blocking: grid over batch; each step owns a ``(Bb, F, D)`` VMEM tile.  For
Criteo-scale DLRM (F=27, D=16..64) a whole batch block is a few KB, so
``Bb`` is chosen to make the matmul MXU-shaped (Bb·F ≥ 128 rows).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

__all__ = ["dot_interaction"]


def _kernel(flat_idx_ref, x_ref, out_ref):
    x = x_ref[...]  # (Bb, F, D)
    scores = jax.lax.dot_general(
        x, x,
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )  # (Bb, F, F)
    bb, f, _ = scores.shape
    flat = scores.reshape(bb, f * f)
    out_ref[...] = jnp.take(flat, flat_idx_ref[...], axis=1).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def dot_interaction(x, *, block_b: int = 8, interpret: bool = True):
    """Packed strictly-lower-triangle of batched ``X·Xᵀ``.

    Args: x: ``(B, F, D)``.  Returns: ``(B, F*(F-1)//2)``.
    ``B`` must be divisible by ``block_b`` (ops.py pads).  The packed
    triangle index vector rides along as a (tiny) replicated input — Pallas
    kernels cannot close over array constants.
    """
    b, f, d = x.shape
    tri_i, tri_j = np.tril_indices(f, k=-1)
    flat_idx = jnp.asarray(tri_i * f + tri_j, jnp.int32)
    p = len(tri_i)
    return pl.pallas_call(
        _kernel,
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((p,), lambda i: (0,)),
            pl.BlockSpec((block_b, f, d), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, p), x.dtype),
        interpret=interpret,
    )(flat_idx, x)
