"""Compatibility shims for optional third-party packages the environment
may lack (nothing here is imported by library code — only by tests)."""
