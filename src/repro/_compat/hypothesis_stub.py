"""Minimal, dependency-free stand-in for the ``hypothesis`` API this repo's
property tests use, installed by ``tests/conftest.py`` ONLY when the real
package is missing (the CI image has it; some sandboxes don't).

Supported surface: ``given``, ``settings(max_examples=, deadline=)`` and
``strategies.integers / lists / sampled_from / booleans / one_of / builds
/ data``.  Examples are drawn
from a PRNG seeded per test name, so runs are deterministic; integer
strategies emit their bounds as the first two examples so edge cases are
always exercised.  No shrinking — on failure the stub re-raises with the
generated arguments in the message.
"""

from __future__ import annotations

import random as _random

__all__ = ["given", "settings", "strategies", "install"]

_DEFAULT_MAX_EXAMPLES = 100


class _Strategy:
    def example(self, rng, index):  # pragma: no cover - abstract
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, lo, hi):
        self.lo, self.hi = int(lo), int(hi)

    def example(self, rng, index):
        if index == 0:
            return self.lo
        if index == 1:
            return self.hi
        return rng.randint(self.lo, self.hi)


class _SampledFrom(_Strategy):
    def __init__(self, seq):
        self.seq = list(seq)

    def example(self, rng, index):
        if index < len(self.seq):
            return self.seq[index]
        return rng.choice(self.seq)


class _Lists(_Strategy):
    def __init__(self, elem, min_size=0, max_size=10):
        self.elem, self.min_size = elem, min_size
        self.max_size = min_size + 10 if max_size is None else max_size

    def example(self, rng, index):
        n = rng.randint(self.min_size, self.max_size)
        return [self.elem.example(rng, 2) for _ in range(n)]


class _OneOf(_Strategy):
    def __init__(self, options):
        self.options = list(options)

    def example(self, rng, index):
        # early examples walk the branches in order so every alternative
        # is exercised; later ones pick a branch at random
        if index < len(self.options):
            return self.options[index].example(rng, 0)
        return rng.choice(self.options).example(rng, 2)


class _Builds(_Strategy):
    def __init__(self, target, arg_strats, kwarg_strats):
        self.target = target
        self.arg_strats, self.kwarg_strats = arg_strats, kwarg_strats

    def example(self, rng, index):
        args = [s.example(rng, index) for s in self.arg_strats]
        kwargs = {k: s.example(rng, index)
                  for k, s in self.kwarg_strats.items()}
        return self.target(*args, **kwargs)


class DataObject:
    """Lazily draws further examples mid-test (``st.data()``)."""

    def __init__(self, rng, example_index):
        self._rng = rng
        # bound/random schedule follows the EXAMPLE index, like top-level
        # strategies: example 0 draws bounds' lows, example 1 highs, the
        # rest random — a per-draw counter would pin every example's first
        # draw to the lower bound.
        self._index = example_index

    def draw(self, strategy, label=None):
        return strategy.example(self._rng, min(self._index, 2))


class _Data(_Strategy):
    def example(self, rng, index):
        return DataObject(rng, index)


class strategies:  # mirrors `from hypothesis import strategies as st`
    @staticmethod
    def integers(min_value, max_value):
        return _Integers(min_value, max_value)

    @staticmethod
    def lists(elements, min_size=0, max_size=None):
        return _Lists(elements, min_size, max_size)

    @staticmethod
    def sampled_from(seq):
        return _SampledFrom(seq)

    @staticmethod
    def booleans():
        return _SampledFrom([False, True])

    @staticmethod
    def one_of(*options):
        return _OneOf(options)

    @staticmethod
    def builds(target, *args, **kwargs):
        return _Builds(target, list(args), dict(kwargs))

    @staticmethod
    def just(value):
        return _SampledFrom([value])

    @staticmethod
    def data():
        return _Data()


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def deco(fn):
        fn._stub_settings = {"max_examples": max_examples}
        return fn
    return deco


def given(*strats):
    def deco(fn):
        def wrapper():
            conf = (getattr(wrapper, "_stub_settings", None)
                    or getattr(fn, "_stub_settings", None) or {})
            n = conf.get("max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = _random.Random(f"repro-hypothesis-stub:{fn.__module__}.{fn.__qualname__}")
            for i in range(n):
                args = [s.example(rng, i) for s in strats]
                try:
                    fn(*args)
                except Exception as e:
                    shown = [a if not isinstance(a, DataObject) else "<data>"
                             for a in args]
                    raise AssertionError(
                        f"falsified on example #{i}: {fn.__name__}{tuple(shown)!r}"
                    ) from e

        # plain attribute copy — functools.wraps would expose fn's signature
        # and make pytest treat the example parameters as fixtures
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        if hasattr(fn, "pytestmark"):
            wrapper.pytestmark = fn.pytestmark
        return wrapper
    return deco


def install():
    """Register this module as ``hypothesis`` (+``hypothesis.strategies``)."""
    import sys
    import types

    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    st_mod = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "lists", "sampled_from", "booleans", "one_of",
                 "builds", "just", "data"):
        setattr(st_mod, name, getattr(strategies, name))
    mod.strategies = st_mod
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
