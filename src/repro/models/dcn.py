"""Deep & Cross Network (Wang et al. 2017) — the paper's second test network.

Embeds all categoricals (via the same ``EmbeddingSpec`` machinery as DLRM),
concatenates with the dense features into x0, and runs a 6-layer cross
network ``x_{l+1} = x0 · (w_lᵀ x_l) + b_l + x_l`` in parallel with a deep
MLP; their concatenation feeds the CTR logit.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..core import CompositionalEmbedding, EmbeddingSpec
from .dlrm import _mlp_apply, _mlp_init, embed_features, proj_init, tables_for

__all__ = ["DCNConfig", "dcn_init", "dcn_forward", "dcn_loss_fn",
           "dcn_forward_from_features"]


@dataclasses.dataclass(frozen=True)
class DCNConfig:
    name: str = "dcn"
    dense_dim: int = 13
    table_sizes: tuple[int, ...] = ()
    emb_dim: int = 16
    cross_layers: int = 6
    deep_mlp: tuple[int, ...] = (512, 256, 64)
    embedding: EmbeddingSpec = EmbeddingSpec()
    param_dtype: Any = "float32"

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)


def _x0_dim(cfg, modules) -> int:
    d = cfg.dense_dim
    for m in modules:
        if cfg.embedding.kind == "feature" and isinstance(m, CompositionalEmbedding):
            d += cfg.emb_dim * len(m.partitions)
        else:
            d += cfg.emb_dim
    return d


def dcn_init(key, cfg: DCNConfig):
    modules = tables_for(cfg)
    kc, kd, ke, ko = jax.random.split(key, 4)
    ekeys = jax.random.split(ke, len(modules))
    d0 = _x0_dim(cfg, modules)
    ckeys = jax.random.split(kc, cfg.cross_layers)
    cross = [{"w": jax.random.normal(k, (d0,), cfg.pdtype) * (1.0 / d0) ** 0.5,
              "b": jnp.zeros((d0,), cfg.pdtype)} for k in ckeys]
    params = {
        "tables": [m.init(k) for m, k in zip(modules, ekeys)],
        "cross": cross,
        "deep": _mlp_init(kd, (d0,) + cfg.deep_mlp, cfg.pdtype),
        "out": _mlp_init(ko, (d0 + cfg.deep_mlp[-1], 1), cfg.pdtype),
    }
    proj = proj_init(ekeys, modules, cfg)
    if proj:  # mixed-dim plan: project narrow tables into the x0 width
        params["proj"] = proj
    return params


def dcn_forward_from_features(params, dense_x, feats, cfg: DCNConfig):
    """Cross + deep half given precomputed table features (``(B, F, D)``
    stacked or a list of ``(B, D)``) — the serving engine's dense stage."""
    dense_x = dense_x.astype(cfg.pdtype)
    if not isinstance(feats, (list, tuple)):
        feats = [feats[:, i, :] for i in range(feats.shape[1])]
    x0 = jnp.concatenate([dense_x] + [f.astype(dense_x.dtype) for f in feats],
                         axis=-1)
    x = x0
    for l in params["cross"]:
        x = x0 * (x @ l["w"])[:, None] + l["b"] + x
    deep = _mlp_apply(params["deep"], x0)
    out = jnp.concatenate([x, deep], axis=-1)
    return _mlp_apply(params["out"], out, final_linear=True)[:, 0]


def dcn_forward(params, dense_x, sparse_idx, cfg: DCNConfig, mask=None):
    feats = embed_features(params["tables"], sparse_idx, cfg, mask=mask,
                           proj=params.get("proj"))
    return dcn_forward_from_features(params, dense_x, feats, cfg)


def dcn_loss_fn(params, batch, cfg: DCNConfig):
    logits = dcn_forward(params, batch["dense"], batch["sparse"], cfg).astype(jnp.float32)
    y = batch["label"].astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    acc = jnp.mean((logits > 0) == (y > 0.5))
    return loss, {"bce": loss, "acc": acc}
