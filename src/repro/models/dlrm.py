"""Facebook DLRM (Naumov et al. 2019) — the paper's primary test network.

Bottom MLP over 13 dense features → pairwise dot interaction with the 26
categorical embeddings → top MLP → CTR logit.  Every embedding table is
built through ``repro.core.make_embedding``, so ``EmbeddingSpec`` switches
the whole model between full / hashing-trick / quotient-remainder /
mixed-radix / CRT / path-based embeddings and the feature-generation mode —
exactly the treatments compared in the paper's §5.

In ``feature`` mode each complementary partition contributes its own
feature vector to the interaction (paper §4 "feature generation approach"),
growing F instead of combining embeddings.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..core import (CompositionalEmbedding, EmbeddingSpec, FullEmbedding,
                    HashEmbedding, bag_pool, make_embedding)
from ..kernels import dlrm_interact, ops

__all__ = ["DLRMConfig", "dlrm_init", "dlrm_forward", "dlrm_loss_fn",
           "dlrm_num_params", "tables_for", "embed_features", "proj_init",
           "dlrm_forward_from_features"]


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm"
    dense_dim: int = 13
    table_sizes: tuple[int, ...] = ()
    emb_dim: int = 16
    bottom_mlp: tuple[int, ...] = (512, 256, 64)
    top_mlp: tuple[int, ...] = (512, 256)
    embedding: EmbeddingSpec = EmbeddingSpec()
    use_kernel: bool = False     # route interaction through the Pallas kernel
    param_dtype: Any = "float32"

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)


def tables_for(cfg) -> list:
    """Embedding module per categorical feature (threshold rule applies).

    ``cfg.embedding`` may be a single ``EmbeddingSpec`` (uniform strategy)
    or a ``repro.plan.MemoryPlan`` (per-feature strategies from the
    memory-budget planner — the feature index routes the lookup).
    """
    return [make_embedding(n, cfg.emb_dim, cfg.embedding, cfg.pdtype,
                           feature=i)
            for i, n in enumerate(cfg.table_sizes)]


def _feature_mode(cfg) -> bool:
    return cfg.embedding.kind == "feature"


def proj_init(key, modules, cfg):
    """Per-feature learned projections ``(d_i, D)`` for mixed-dimension
    plans — only features whose table width differs from ``cfg.emb_dim``
    get an entry (keyed by the feature index as a string), so uniform-dim
    configs keep a byte-identical param tree (no ``"proj"`` key at all).
    Keys are derived by ``fold_in`` from each feature's own table key, so
    adding a projection never reshuffles any existing draw."""
    out = {}
    for i, (mod, k) in enumerate(zip(modules, key)):
        d = mod.out_dim
        if d != cfg.emb_dim:
            pk = jax.random.fold_in(k, 7)
            out[str(i)] = jax.random.normal(pk, (d, cfg.emb_dim),
                                            cfg.pdtype) * (1.0 / d) ** 0.5
    return out


def _project(feat, proj, i):
    """Map one feature into the interaction width (identity when the
    table already is ``emb_dim`` wide — no entry, no matmul)."""
    w = None if proj is None else proj.get(str(i))
    return feat if w is None else feat @ w


def _mlp_init(key, dims, param_dtype):
    keys = jax.random.split(key, len(dims) - 1)
    return [{"w": jax.random.normal(k, (i, o), param_dtype) * (2.0 / i) ** 0.5,
             "b": jnp.zeros((o,), param_dtype)}
            for k, i, o in zip(keys, dims[:-1], dims[1:])]


def _mlp_apply(layers, x, final_linear=False):
    for i, l in enumerate(layers):
        x = x @ l["w"] + l["b"]
        if not (final_linear and i == len(layers) - 1):
            x = jax.nn.relu(x)
    return x


def _num_features(cfg, modules) -> int:
    f = 1  # bottom-MLP output participates in the interaction
    for mod in modules:
        if _feature_mode(cfg) and isinstance(mod, CompositionalEmbedding):
            f += len(mod.partitions)
        else:
            f += 1
    return f


def dlrm_init(key, cfg: DLRMConfig):
    modules = tables_for(cfg)
    kb, kt, ke = jax.random.split(key, 3)
    ekeys = jax.random.split(ke, len(modules))
    f = _num_features(cfg, modules)
    interact_dim = f * (f - 1) // 2 + cfg.emb_dim
    params = {
        "bottom": _mlp_init(kb, (cfg.dense_dim,) + cfg.bottom_mlp + (cfg.emb_dim,),
                            cfg.pdtype),
        "top": _mlp_init(kt, (interact_dim,) + cfg.top_mlp + (1,), cfg.pdtype),
        "tables": [m.init(k) for m, k in zip(modules, ekeys)],
    }
    proj = proj_init(ekeys, modules, cfg)
    if proj:  # mixed-dim plan: project narrow tables into the interaction
        params["proj"] = proj
    return params


def embed_features(table_params, sparse_idx, cfg, modules=None, mask=None,
                   proj=None, gathers=None):
    """Per-feature pooled embedding list — the serving stack's embed stage.

    ``sparse_idx``: one-hot ``(B, F)`` or multi-hot ``(B, F, L)`` with
    ``mask (B, F, L)`` (``bag_pool`` conventions: masked slots contribute
    nothing, so an empty bag — all-zero mask — pools to the exact zero
    vector, and bucket padding is exact).  Tables may be dense or
    row-quantized (``serve.quantize``); the kernel path routes quantized
    QR pairs through the fused int8-dequant gather.  ``proj`` is the
    mixed-dimension projection dict (``params["proj"]``): features whose
    table width differs from ``cfg.emb_dim`` are mapped through their
    learned ``(d_i, D)`` projection — identity (no entry, no matmul) when
    widths match.  Returns a list of ``(B, D)`` features (feature mode
    expands per partition, one-hot only).

    ``gathers`` (optional, one per feature, entries may be ``None``)
    substitutes each feature's row fetch (``core.compositional._gather``)
    — the sharded serve path routes remote rows through it; a feature
    with a hook always takes the jnp ``bag_pool`` path, never the fused
    kernel (which gathers locally by construction).
    """
    modules = tables_for(cfg) if modules is None else modules
    multihot = sparse_idx.ndim == 3
    use_kernel = getattr(cfg, "use_kernel", False)
    feats = []
    for i, mod in enumerate(modules):
        tp = table_params[i]
        qr2 = isinstance(mod, CompositionalEmbedding) \
            and len(mod.partitions) == 2 and mod.op in ("mult", "add")
        if multihot:
            idx = sparse_idx[:, i, :]
            mk = mask[:, i, :] if mask is not None \
                else jnp.ones(idx.shape, jnp.float32)
            if _feature_mode(cfg) and isinstance(mod, CompositionalEmbedding):
                raise NotImplementedError(
                    "feature-generation mode has no multi-hot serving path")
            g = None if gathers is None else gathers[i]
            single = isinstance(mod, (FullEmbedding, HashEmbedding))
            if use_kernel and g is None and (qr2 or single):
                # serving hot path: fused gather (+dequant) → pool →
                # projection in one VMEM pass (kernels/serve_path.py);
                # single tables pre-fold (hash: idx mod m) so the kernel
                # only ever sees in-range row ids
                w = None if proj is None else proj.get(str(i))
                if qr2:
                    pooled = ops.serve_bag_pool(idx, mk, tp["table_0"],
                                                tp["table_1"], op=mod.op,
                                                proj=w)
                else:
                    fold = idx % mod.m if isinstance(mod, HashEmbedding) \
                        else idx
                    pooled = ops.serve_bag_pool(fold, mk, tp["table"],
                                                proj=w)
                feats.append(pooled)
            else:
                pooled = bag_pool(mod, tp, idx, mk, gather=g)
                feats.append(_project(pooled, proj, i))
            continue
        idx = sparse_idx[:, i]
        if _feature_mode(cfg) and isinstance(mod, CompositionalEmbedding):
            feats.extend(mod.partition_embeddings(tp, idx))
        elif use_kernel and qr2:
            feats.append(_project(ops.qr_lookup(idx, tp["table_0"],
                                                tp["table_1"], op=mod.op),
                                  proj, i))
        else:
            feats.append(_project(mod.apply(tp, idx), proj, i))
    return feats


def dlrm_forward_from_features(params, dense_x, feats, cfg: DLRMConfig):
    """Dense half of the model: bottom MLP + interaction + top MLP.

    ``feats``: stacked table features ``(B, F-1, D)`` (or a list of
    ``(B, D)``).  Split out from ``dlrm_forward`` so the serving engine
    can source ``feats`` from the hot-row cache instead of the tables.
    """
    z = _mlp_apply(params["bottom"], dense_x.astype(cfg.pdtype))  # (B, D)
    if isinstance(feats, (list, tuple)):
        feats = jnp.stack(feats, axis=1)
    x = jnp.concatenate([z[:, None, :], feats.astype(z.dtype)], axis=1)
    inter = dlrm_interact(x) if cfg.use_kernel else _interact_ref(x)
    top_in = jnp.concatenate([z, inter], axis=-1)
    return _mlp_apply(params["top"], top_in, final_linear=True)[:, 0]


def dlrm_forward(params, dense_x, sparse_idx, cfg: DLRMConfig, mask=None):
    """dense_x: (B, 13) float; sparse_idx: (B, 26) int32 (or (B, 26, L)
    multi-hot with ``mask``) → logits (B,)."""
    feats = embed_features(params["tables"], sparse_idx, cfg, mask=mask,
                           proj=params.get("proj"))
    return dlrm_forward_from_features(params, dense_x, feats, cfg)


def _interact_ref(x):
    import numpy as np
    scores = jnp.einsum("bfd,bgd->bfg", x, x)
    i, j = np.tril_indices(x.shape[1], k=-1)
    return scores[:, i, j]


def dlrm_loss_fn(params, batch, cfg: DLRMConfig):
    """batch: dense (B,13), sparse (B,26) int32, label (B,) in {0,1}."""
    logits = dlrm_forward(params, batch["dense"], batch["sparse"], cfg)
    y = batch["label"].astype(jnp.float32)
    logits = logits.astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    acc = jnp.mean((logits > 0) == (y > 0.5))
    return loss, {"bce": loss, "acc": acc}


def dlrm_num_params(cfg: DLRMConfig) -> int:
    modules = tables_for(cfg)
    n = sum(m.num_params for m in modules)
    n += sum(m.out_dim * cfg.emb_dim for m in modules
             if m.out_dim != cfg.emb_dim)  # mixed-dim projections
    dims_b = (cfg.dense_dim,) + cfg.bottom_mlp + (cfg.emb_dim,)
    f = _num_features(cfg, modules)
    dims_t = (f * (f - 1) // 2 + cfg.emb_dim,) + cfg.top_mlp + (1,)
    for d in (dims_b, dims_t):
        n += sum(i * o + o for i, o in zip(d[:-1], d[1:]))
    return n
