"""Decoder-only LM covering the dense-GQA, MoE, and MLA assigned archs.

One config surface drives qwen3/tinyllama/yi/granite (dense), arctic
(MoE + parallel dense residual), and deepseek-v2 (MLA + shared-expert MoE).
Layers are homogeneous and stacked, executed with ``lax.scan`` (+ optional
remat) so the HLO stays O(1) in depth — essential for the 512-device
dry-run compiles.

The paper's technique enters through ``cfg.embedding`` (an
``EmbeddingSpec``): the token-vocabulary table — the model's one large
categorical embedding — is built by ``repro.core.make_embedding`` and can
be full / hashed / QR-compositional.  The LM head stays a dense projection
(logits need the full vocab rank); its memory is addressed by chunked
cross-entropy, never materialising (B, S, V) logits.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from ..core import EmbeddingSpec, make_embedding
from ..dist.sharding import constrain_batch
from ..nn.layers import (AttnConfig, attention, attention_init, dense,
                         dense_init, make_cache, mlp, mlp_init, rmsnorm,
                         rmsnorm_init)
from ..nn.mla import MLAConfig, mla_apply, mla_init, mla_make_cache
from ..nn.moe import MoEConfig, moe_apply, moe_init

__all__ = ["LMConfig", "init", "loss_fn", "forward_hidden", "make_decode_cache",
           "prefill", "decode_step", "chunked_xent"]


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str = "lm"
    vocab: int = 32000
    d_model: int = 2048
    n_layers: int = 22
    n_heads: int = 32
    n_kv_heads: int = 4
    d_head: int = 64
    d_ff: int = 5632
    ffn_kind: str = "swiglu"
    qk_norm: bool = False
    rope_theta: float = 1e4
    moe: Optional[MoEConfig] = None
    moe_parallel_dense: bool = False     # Arctic: dense FFN residual ∥ MoE
    n_shared_experts: int = 0            # DeepSeek: always-on experts (d_ff each)
    mla: Optional[MLAConfig] = None
    embedding: EmbeddingSpec = EmbeddingSpec()
    param_dtype: Any = "bfloat16"
    compute_dtype: Any = "bfloat16"
    xent_chunk: int = 512
    remat: bool = True

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def attn_cfg(self) -> AttnConfig:
        return AttnConfig(d_model=self.d_model, n_heads=self.n_heads,
                          n_kv_heads=self.n_kv_heads, d_head=self.d_head,
                          qk_norm=self.qk_norm, rope_theta=self.rope_theta)


# ------------------------------------------------------------------ init


def _layer_init(key, cfg: LMConfig):
    ka, km, kd, ksh = jax.random.split(key, 4)
    p = {"norm1": rmsnorm_init(cfg.d_model, cfg.pdtype),
         "norm2": rmsnorm_init(cfg.d_model, cfg.pdtype)}
    if cfg.mla is not None:
        p["attn"] = mla_init(ka, cfg.mla, cfg.pdtype)
    else:
        p["attn"] = attention_init(ka, cfg.attn_cfg(), cfg.pdtype)
    if cfg.moe is not None:
        p["moe"] = moe_init(km, cfg.moe, cfg.pdtype)
        if cfg.moe_parallel_dense:
            p["dense_mlp"] = mlp_init(kd, cfg.d_model, cfg.d_ff, cfg.pdtype, cfg.ffn_kind)
        if cfg.n_shared_experts:
            p["shared_mlp"] = mlp_init(
                ksh, cfg.d_model, cfg.n_shared_experts * cfg.moe.d_ff, cfg.pdtype, "swiglu")
    else:
        p["mlp"] = mlp_init(km, cfg.d_model, cfg.d_ff, cfg.pdtype, cfg.ffn_kind)
    return p


def init(key, cfg: LMConfig):
    ke, kl, kh = jax.random.split(key, 3)
    embed = make_embedding(cfg.vocab, cfg.d_model, cfg.embedding, cfg.pdtype)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    layers = jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys)
    return {
        "embed": embed.init(ke),
        "layers": layers,
        "final_norm": rmsnorm_init(cfg.d_model, cfg.pdtype),
        "lm_head": dense_init(kh, cfg.d_model, cfg.vocab, cfg.pdtype),
    }


def embed_module(cfg: LMConfig):
    return make_embedding(cfg.vocab, cfg.d_model, cfg.embedding, cfg.pdtype)


# ------------------------------------------------------------------ forward


def _ffn(lp, h2, cfg: LMConfig):
    """Post-attention block: dense MLP or MoE (+ shared / parallel-dense)."""
    if cfg.moe is None:
        return mlp(lp["mlp"], h2, cfg.cdtype, cfg.ffn_kind), 0.0
    out, aux = moe_apply(lp["moe"], h2, cfg.moe, cfg.cdtype)
    if cfg.moe_parallel_dense:
        out = out + mlp(lp["dense_mlp"], h2, cfg.cdtype, cfg.ffn_kind)
    if cfg.n_shared_experts:
        out = out + mlp(lp["shared_mlp"], h2, cfg.cdtype, "swiglu")
    return out, aux


def _layer_apply(lp, h, cfg: LMConfig, positions):
    h1 = rmsnorm(lp["norm1"], h)
    if cfg.mla is not None:
        attn_out = mla_apply(lp["attn"], h1, cfg.mla, cfg.cdtype, positions=positions)
    else:
        attn_out = attention(lp["attn"], h1, cfg.attn_cfg(), cfg.cdtype,
                             positions=positions)
    h = h + attn_out
    ffn_out, aux = _ffn(lp, rmsnorm(lp["norm2"], h), cfg)
    return h + ffn_out, aux


def forward_hidden(params, h, cfg: LMConfig, positions=None):
    """Run the layer stack on already-embedded inputs ``h`` (B, S, D)."""
    if positions is None:
        positions = jnp.arange(h.shape[1])[None, :]

    def body(carry, lp):
        out, aux = _layer_apply(lp, carry, cfg, positions)
        return constrain_batch(out), aux

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, auxs = lax.scan(body, h, params["layers"])
    return rmsnorm(params["final_norm"], h), auxs.sum()


def embed_tokens(params, tokens, cfg: LMConfig):
    h = embed_module(cfg).apply(params["embed"], tokens).astype(cfg.cdtype)
    return constrain_batch(h)


# ------------------------------------------------------------------ loss


def chunked_xent(h, labels, mask, head_w, chunk: int):
    """Mean masked next-token xent without materialising (B, S, V) logits."""
    b, s, d = h.shape
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    nc = (s + pad) // chunk
    hs = h.reshape(b, nc, chunk, d).swapaxes(0, 1)
    ls = labels.reshape(b, nc, chunk).swapaxes(0, 1)
    ms = mask.reshape(b, nc, chunk).swapaxes(0, 1)

    def body(tot, xs):
        hc, lc, mc = xs
        logits = (hc @ head_w.astype(hc.dtype)).astype(jnp.float32)
        logz = jax.nn.logsumexp(logits, axis=-1)
        # §Perf it.2: gold logit via one-hot contraction, NOT take_along_axis.
        # With a vocab-parallel (Megatron) head the logits' vocab dim is
        # model-sharded; take_along over the sharded dim made GSPMD
        # all-reduce the FULL (B, chunk, V) logits (1 GB/chunk on seamless).
        # The one-hot is built from a sharded iota (no comm) and the
        # contraction reduces over the sharded dim -> psum of (B, chunk).
        onehot = (jnp.arange(logits.shape[-1])[None, None, :] == lc[..., None])
        gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
        nll = (logz - gold) * mc
        return tot + nll.sum(), None

    total, _ = lax.scan(body, jnp.float32(0.0), (hs, ls, ms))
    return total / jnp.maximum(mask.sum(), 1.0)


def loss_fn(params, batch, cfg: LMConfig):
    """batch: tokens (B,S) int32, labels (B,S) int32, mask (B,S) f32."""
    h = embed_tokens(params, batch["tokens"], cfg)
    h, aux = forward_hidden(params, h, cfg)
    loss = chunked_xent(h, batch["labels"], batch["mask"],
                        params["lm_head"]["w"], cfg.xent_chunk)
    return loss + aux, {"xent": loss, "aux": aux}


# ------------------------------------------------------------------ serving


def make_decode_cache(cfg: LMConfig, batch: int, max_len: int):
    if cfg.mla is not None:
        one = lambda: mla_make_cache(batch, max_len, cfg.mla, cfg.cdtype)
    else:
        one = lambda: make_cache(batch, max_len, cfg.n_kv_heads, cfg.d_head, cfg.cdtype)
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape),
                        one())


def _layer_decode(lp, h, cache_l, cfg: LMConfig, positions, cache_index):
    h1 = rmsnorm(lp["norm1"], h)
    if cfg.mla is not None:
        attn_out, new_cache = mla_apply(lp["attn"], h1, cfg.mla, cfg.cdtype,
                                        positions=positions, cache=cache_l,
                                        cache_index=cache_index)
    else:
        attn_out, new_cache = attention(lp["attn"], h1, cfg.attn_cfg(), cfg.cdtype,
                                        positions=positions, cache=cache_l,
                                        cache_index=cache_index)
    h = h + attn_out
    ffn_out, _ = _ffn(lp, rmsnorm(lp["norm2"], h), cfg)
    return h + ffn_out, new_cache


def _run_with_cache(params, h, cache, cfg: LMConfig, positions, cache_index):
    def body(carry, xs):
        lp, cache_l = xs
        out, new_cache = _layer_decode(lp, carry, cache_l, cfg, positions, cache_index)
        return out, new_cache

    h, new_caches = lax.scan(body, h, (params["layers"], cache))
    return rmsnorm(params["final_norm"], h), new_caches


def prefill(params, tokens, cache, cfg: LMConfig):
    """Fill the cache from a prompt; returns (last-position logits, cache)."""
    h = embed_tokens(params, tokens, cfg)
    h, cache = _run_with_cache(params, h, cache, cfg,
                               jnp.arange(tokens.shape[1])[None, :], None)
    logits = dense(params["lm_head"], h[:, -1:], cfg.cdtype).astype(jnp.float32)
    return logits, cache


def decode_step(params, tokens, pos, cache, cfg: LMConfig):
    """One decode step.  tokens: (B, 1); pos: scalar index into the cache."""
    h = embed_tokens(params, tokens, cfg)
    positions = jnp.full((tokens.shape[0], 1), pos)
    h, cache = _run_with_cache(params, h, cache, cfg, positions, pos)
    logits = dense(params["lm_head"], h, cfg.cdtype).astype(jnp.float32)
    return logits, cache
