"""VLM wrapper (llava-next-34b): yi-34b backbone + anyres patch stub.

The vision tower is a STUB per the assignment: ``input_specs`` supplies
precomputed anyres patch embeddings ``(B, P, d_model)``.  The multimodal
projector (2-layer MLP, as in LLaVA) and the LM backbone are real.  Text
tokens go through the (QR-compressible) vocab embedding; patches bypass it
— image features are dense, so the paper's technique applies only to the
text side (noted in DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..dist.sharding import constrain_batch
from ..nn.layers import dense, dense_init
from . import lm as lm_mod
from .lm import LMConfig, chunked_xent

__all__ = ["VLMConfig", "vlm_init", "vlm_loss_fn", "vlm_make_cache",
           "vlm_prefill", "vlm_decode_step"]


@dataclasses.dataclass(frozen=True)
class VLMConfig:
    lm: LMConfig = LMConfig()
    n_patches: int = 1152  # anyres: e.g. 2 tiles × 576

    @property
    def name(self):
        return self.lm.name


def vlm_init(key, cfg: VLMConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.lm.d_model
    return {"lm": lm_mod.init(k1, cfg.lm),
            "proj1": dense_init(k2, d, d, cfg.lm.pdtype),
            "proj2": dense_init(k3, d, d, cfg.lm.pdtype)}


def _project(params, patches, cfg: VLMConfig):
    h = dense(params["proj1"], patches.astype(cfg.lm.cdtype), cfg.lm.cdtype)
    return dense(params["proj2"], jax.nn.gelu(h), cfg.lm.cdtype)


def _prefix_hidden(params, patches, tokens, cfg: VLMConfig):
    img = _project(params, patches, cfg)
    txt = lm_mod.embed_tokens(params["lm"], tokens, cfg.lm)
    return constrain_batch(jnp.concatenate([img, txt], axis=1))


def vlm_loss_fn(params, batch, cfg: VLMConfig):
    """batch: patches (B,P,D), tokens (B,St), labels (B,St), mask (B,St)."""
    h = _prefix_hidden(params, batch["patches"], batch["tokens"], cfg)
    h, aux = lm_mod.forward_hidden(params["lm"], h, cfg.lm)
    b, p = batch["patches"].shape[:2]
    labels = jnp.concatenate(
        [jnp.zeros((b, p), batch["labels"].dtype), batch["labels"]], axis=1)
    mask = jnp.concatenate([jnp.zeros((b, p), batch["mask"].dtype), batch["mask"]], axis=1)
    loss = chunked_xent(h, labels, mask, params["lm"]["lm_head"]["w"],
                        cfg.lm.xent_chunk)
    return loss + aux, {"xent": loss}


def vlm_make_cache(cfg: VLMConfig, batch: int, max_len: int):
    return lm_mod.make_decode_cache(cfg.lm, batch, max_len)


def vlm_prefill(params, patches, tokens, cache, cfg: VLMConfig):
    h = _prefix_hidden(params, patches, tokens, cfg)
    h, cache = lm_mod._run_with_cache(params["lm"], h, cache, cfg.lm,
                                      jnp.arange(h.shape[1])[None, :], None)
    logits = dense(params["lm"]["lm_head"], h[:, -1:], cfg.lm.cdtype).astype(jnp.float32)
    return logits, cache


def vlm_decode_step(params, tokens, pos, cache, cfg: VLMConfig):
    return lm_mod.decode_step(params["lm"], tokens, pos, cache, cfg.lm)
