"""SSM and hybrid-SSM language models: mamba2-370m and zamba2-1.2b.

``MambaLM`` — a pure Mamba2 stack (attention-free; the only assigned archs
legal for the long_500k decode shape, since their "KV cache" is an O(1)
(H, N, P) state + a (d_conv−1) conv window per layer).

``HybridLM`` (Zamba2-style) — Mamba2 backbone with a *shared* transformer
block (one set of attention+MLP weights) applied every ``block_len`` layers.
Structure: ``n_blocks`` × [block_len mamba layers → shared attn block] +
``n_tail`` trailing mamba layers.  The shared block's weights are scan
closure constants; its per-application KV caches are stacked on the scan
axis.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from ..core import EmbeddingSpec, make_embedding
from ..dist.sharding import constrain_batch
from ..nn.layers import (AttnConfig, attention, attention_init, dense,
                         dense_init, make_cache, mlp, mlp_init, rmsnorm,
                         rmsnorm_init)
from ..nn.ssm import SSMConfig, ssm_apply, ssm_decode, ssm_init, ssm_make_cache
from .lm import chunked_xent

__all__ = ["MambaLMConfig", "HybridLMConfig", "mamba_init", "mamba_loss_fn",
           "mamba_make_cache", "mamba_decode_step", "mamba_prefill",
           "hybrid_init", "hybrid_loss_fn", "hybrid_make_cache",
           "hybrid_decode_step"]


# ====================================================================== MambaLM


@dataclasses.dataclass(frozen=True)
class MambaLMConfig:
    name: str = "mamba2"
    vocab: int = 50280
    d_model: int = 1024
    n_layers: int = 48
    ssm: SSMConfig = SSMConfig(d_model=1024, d_state=128)
    embedding: EmbeddingSpec = EmbeddingSpec()
    param_dtype: Any = "bfloat16"
    compute_dtype: Any = "bfloat16"
    xent_chunk: int = 512
    remat: bool = True

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)


def _mamba_layer_init(key, cfg):
    return {"norm": rmsnorm_init(cfg.d_model, cfg.pdtype),
            "ssm": ssm_init(key, cfg.ssm, cfg.pdtype)}


def mamba_init(key, cfg: MambaLMConfig):
    ke, kl, kh = jax.random.split(key, 3)
    embed = make_embedding(cfg.vocab, cfg.d_model, cfg.embedding, cfg.pdtype)
    layers = jax.vmap(lambda k: _mamba_layer_init(k, cfg))(jax.random.split(kl, cfg.n_layers))
    return {"embed": embed.init(ke), "layers": layers,
            "final_norm": rmsnorm_init(cfg.d_model, cfg.pdtype),
            "lm_head": dense_init(kh, cfg.d_model, cfg.vocab, cfg.pdtype)}


def _mamba_forward(params, h, cfg: MambaLMConfig):
    def body(carry, lp):
        out = carry + ssm_apply(lp["ssm"], rmsnorm(lp["norm"], carry), cfg.ssm, cfg.cdtype)
        return constrain_batch(out), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = lax.scan(body, h, params["layers"])
    return rmsnorm(params["final_norm"], h)


def _embed(params, tokens, cfg):
    embed = make_embedding(cfg.vocab, cfg.d_model, cfg.embedding, cfg.pdtype)
    return constrain_batch(embed.apply(params["embed"], tokens).astype(cfg.cdtype))


def mamba_loss_fn(params, batch, cfg: MambaLMConfig):
    h = _mamba_forward(params, _embed(params, batch["tokens"], cfg), cfg)
    loss = chunked_xent(h, batch["labels"], batch["mask"],
                        params["lm_head"]["w"], cfg.xent_chunk)
    return loss, {"xent": loss}


def mamba_make_cache(cfg: MambaLMConfig, batch: int, max_len: int = 0):
    one = ssm_make_cache(batch, cfg.ssm, cfg.cdtype)
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), one)


def mamba_decode_step(params, tokens, pos, cache, cfg: MambaLMConfig):
    del pos  # SSM state is position-free
    h = _embed(params, tokens, cfg)

    def body(carry, xs):
        lp, cache_l = xs
        out, new_cache = ssm_decode(lp["ssm"], rmsnorm(lp["norm"], carry),
                                    cfg.ssm, cfg.cdtype, cache_l)
        return carry + out, new_cache

    h, new_caches = lax.scan(body, h, (params["layers"], cache))
    h = rmsnorm(params["final_norm"], h)
    logits = dense(params["lm_head"], h, cfg.cdtype).astype(jnp.float32)
    return logits, new_caches


def mamba_prefill(params, tokens, cache, cfg: MambaLMConfig):
    h = _embed(params, tokens, cfg)

    def body(carry, lp):
        out, st = ssm_apply(lp["ssm"], rmsnorm(lp["norm"], carry), cfg.ssm,
                            cfg.cdtype, return_state=True)
        return carry + out, st

    h, new_caches = lax.scan(body, h, params["layers"])
    h = rmsnorm(params["final_norm"], h)
    logits = dense(params["lm_head"], h[:, -1:], cfg.cdtype).astype(jnp.float32)
    return logits, new_caches


# ====================================================================== HybridLM


@dataclasses.dataclass(frozen=True)
class HybridLMConfig:
    name: str = "zamba2"
    vocab: int = 32000
    d_model: int = 2048
    n_blocks: int = 6
    block_len: int = 6
    n_tail: int = 2          # n_mamba = n_blocks*block_len + n_tail = 38
    ssm: SSMConfig = SSMConfig(d_model=2048, d_state=64)
    n_heads: int = 32
    n_kv_heads: int = 32
    d_head: int = 64
    d_ff: int = 8192
    rope_theta: float = 1e4
    embedding: EmbeddingSpec = EmbeddingSpec()
    param_dtype: Any = "bfloat16"
    compute_dtype: Any = "bfloat16"
    xent_chunk: int = 512
    remat: bool = True

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def attn_cfg(self) -> AttnConfig:
        return AttnConfig(d_model=self.d_model, n_heads=self.n_heads,
                          n_kv_heads=self.n_kv_heads, d_head=self.d_head,
                          rope_theta=self.rope_theta)


def hybrid_init(key, cfg: HybridLMConfig):
    ke, kb, kt, ks, kh = jax.random.split(key, 5)
    embed = make_embedding(cfg.vocab, cfg.d_model, cfg.embedding, cfg.pdtype)
    mcfg = MambaLMConfig(d_model=cfg.d_model, ssm=cfg.ssm,
                         param_dtype=cfg.param_dtype)
    bkeys = jax.random.split(kb, cfg.n_blocks * cfg.block_len).reshape(
        cfg.n_blocks, cfg.block_len, 2)
    blocks = jax.vmap(jax.vmap(lambda k: _mamba_layer_init(k, mcfg)))(bkeys)
    tail = jax.vmap(lambda k: _mamba_layer_init(k, mcfg))(jax.random.split(kt, cfg.n_tail))
    ka, km = jax.random.split(ks)
    shared = {"norm1": rmsnorm_init(cfg.d_model, cfg.pdtype),
              "attn": attention_init(ka, cfg.attn_cfg(), cfg.pdtype),
              "norm2": rmsnorm_init(cfg.d_model, cfg.pdtype),
              "mlp": mlp_init(km, cfg.d_model, cfg.d_ff, cfg.pdtype)}
    return {"embed": embed.init(ke), "blocks": blocks, "tail": tail,
            "shared": shared,
            "final_norm": rmsnorm_init(cfg.d_model, cfg.pdtype),
            "lm_head": dense_init(kh, cfg.d_model, cfg.vocab, cfg.pdtype)}


def _hybrid_forward(params, h, cfg: HybridLMConfig):
    shared = params["shared"]
    acfg = cfg.attn_cfg()

    def mamba_body(carry, lp):
        out = carry + ssm_apply(lp["ssm"], rmsnorm(lp["norm"], carry),
                                cfg.ssm, cfg.cdtype)
        return constrain_batch(out), None

    def block_body(carry, bp):
        h, _ = lax.scan(mamba_body, carry, bp)
        h = h + attention(shared["attn"], rmsnorm(shared["norm1"], h), acfg, cfg.cdtype)
        h = h + mlp(shared["mlp"], rmsnorm(shared["norm2"], h), cfg.cdtype)
        return h, None

    if cfg.remat:
        block_body = jax.checkpoint(block_body, prevent_cse=False)
    h, _ = lax.scan(block_body, h, params["blocks"])
    h, _ = lax.scan(mamba_body, h, params["tail"])
    return rmsnorm(params["final_norm"], h)


def hybrid_loss_fn(params, batch, cfg: HybridLMConfig):
    h = _hybrid_forward(params, _embed(params, batch["tokens"], cfg), cfg)
    loss = chunked_xent(h, batch["labels"], batch["mask"],
                        params["lm_head"]["w"], cfg.xent_chunk)
    return loss, {"xent": loss}


def hybrid_make_cache(cfg: HybridLMConfig, batch: int, max_len: int):
    ssm_one = ssm_make_cache(batch, cfg.ssm, cfg.cdtype)
    blocks = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_blocks, cfg.block_len) + x.shape), ssm_one)
    tail = jax.tree.map(lambda x: jnp.broadcast_to(x, (cfg.n_tail,) + x.shape), ssm_one)
    kv_one = make_cache(batch, max_len, cfg.n_kv_heads, cfg.d_head, cfg.cdtype)
    kv = jax.tree.map(lambda x: jnp.broadcast_to(x, (cfg.n_blocks,) + x.shape), kv_one)
    return {"blocks": blocks, "tail": tail, "kv": kv}


def hybrid_prefill(params, tokens, cache, cfg: HybridLMConfig):
    """Prefill: SSD chunked forward capturing states + attn KV cache fill."""
    h = _embed(params, tokens, cfg)
    shared = params["shared"]
    acfg = cfg.attn_cfg()

    def mamba_pre(carry, lp):
        out, st = ssm_apply(lp["ssm"], rmsnorm(lp["norm"], carry), cfg.ssm,
                            cfg.cdtype, return_state=True)
        return carry + out, st

    def block_step(carry, xs):
        bp, kv_cache = xs
        h, states = lax.scan(mamba_pre, carry, bp)
        attn_out, new_kv = attention(shared["attn"], rmsnorm(shared["norm1"], h),
                                     acfg, cfg.cdtype, cache=kv_cache)
        h = h + attn_out
        h = h + mlp(shared["mlp"], rmsnorm(shared["norm2"], h), cfg.cdtype)
        return h, (states, new_kv)

    h, (bstates, kvs) = lax.scan(block_step, h, (params["blocks"], cache["kv"]))
    h, tstates = lax.scan(mamba_pre, h, params["tail"])
    h = rmsnorm(params["final_norm"], h)
    logits = dense(params["lm_head"], h[:, -1:], cfg.cdtype).astype(jnp.float32)
    return logits, {"blocks": bstates, "tail": tstates, "kv": kvs}


def hybrid_decode_step(params, tokens, pos, cache, cfg: HybridLMConfig):
    h = _embed(params, tokens, cfg)
    shared = params["shared"]
    acfg = cfg.attn_cfg()
    positions = jnp.full((tokens.shape[0], 1), pos)

    def mamba_step(carry, xs):
        lp, cache_l = xs
        out, new_cache = ssm_decode(lp["ssm"], rmsnorm(lp["norm"], carry),
                                    cfg.ssm, cfg.cdtype, cache_l)
        return carry + out, new_cache

    def block_step(carry, xs):
        bp, bcache, kv_cache = xs
        h, new_bcache = lax.scan(mamba_step, carry, (bp, bcache))
        attn_out, new_kv = attention(shared["attn"], rmsnorm(shared["norm1"], h),
                                     acfg, cfg.cdtype, positions=positions,
                                     cache=kv_cache, cache_index=pos)
        h = h + attn_out
        h = h + mlp(shared["mlp"], rmsnorm(shared["norm2"], h), cfg.cdtype)
        return h, (new_bcache, new_kv)

    h, (new_blocks, new_kv) = lax.scan(
        block_step, h, (params["blocks"], cache["blocks"], cache["kv"]))
    h, new_tail = lax.scan(mamba_step, h, (params["tail"], cache["tail"]))
    h = rmsnorm(params["final_norm"], h)
    logits = dense(params["lm_head"], h, cfg.cdtype).astype(jnp.float32)
    return logits, {"blocks": new_blocks, "tail": new_tail, "kv": new_kv}
