"""Subsystem package."""
