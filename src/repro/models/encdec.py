"""Encoder–decoder backbone for seamless-m4t-large-v2 (audio → text).

The speech frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings ``(B, S_enc, d_model)`` (a real deployment
would put the conformer feature extractor there).  The backbone — a
full-attention encoder and a causal decoder with cross-attention — is real
and carries the 256k-row text vocabulary, the most embedding-dominated
table of all assigned archs (the paper's QR trick applies to it through
``cfg.embedding``).

Decode caches both the decoder self-attention KV *and* per-layer
cross-attention K/V computed once from encoder memory at prefill.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from ..core import EmbeddingSpec, make_embedding
from ..dist.sharding import constrain_batch
from ..nn.layers import (AttnConfig, attention, attention_init,
                         attention_with_kv, cross_kv, dense, dense_init,
                         make_cache, mlp, mlp_init, rmsnorm, rmsnorm_init)
from .lm import chunked_xent

__all__ = ["EncDecConfig", "encdec_init", "encdec_loss_fn", "encode",
           "encdec_make_cache", "encdec_prefill", "encdec_decode_step"]


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str = "seamless"
    vocab: int = 256206
    d_model: int = 1024
    enc_layers: int = 24
    dec_layers: int = 24
    n_heads: int = 16
    n_kv_heads: int = 16
    d_head: int = 64
    d_ff: int = 8192
    ffn_kind: str = "gelu"
    rope_theta: float = 1e4
    enc_ratio: int = 4           # S_enc = seq_len // enc_ratio
    embedding: EmbeddingSpec = EmbeddingSpec()
    param_dtype: Any = "bfloat16"
    compute_dtype: Any = "bfloat16"
    xent_chunk: int = 512
    remat: bool = True

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def attn_cfg(self, causal: bool) -> AttnConfig:
        return AttnConfig(d_model=self.d_model, n_heads=self.n_heads,
                          n_kv_heads=self.n_kv_heads, d_head=self.d_head,
                          rope_theta=self.rope_theta, causal=causal)


def _enc_layer_init(key, cfg):
    ka, km = jax.random.split(key)
    return {"norm1": rmsnorm_init(cfg.d_model, cfg.pdtype),
            "attn": attention_init(ka, cfg.attn_cfg(False), cfg.pdtype),
            "norm2": rmsnorm_init(cfg.d_model, cfg.pdtype),
            "mlp": mlp_init(km, cfg.d_model, cfg.d_ff, cfg.pdtype, cfg.ffn_kind)}


def _dec_layer_init(key, cfg):
    ka, kx, km = jax.random.split(key, 3)
    return {"norm1": rmsnorm_init(cfg.d_model, cfg.pdtype),
            "self_attn": attention_init(ka, cfg.attn_cfg(True), cfg.pdtype),
            "norm_x": rmsnorm_init(cfg.d_model, cfg.pdtype),
            "cross_attn": attention_init(kx, cfg.attn_cfg(False), cfg.pdtype),
            "norm2": rmsnorm_init(cfg.d_model, cfg.pdtype),
            "mlp": mlp_init(km, cfg.d_model, cfg.d_ff, cfg.pdtype, cfg.ffn_kind)}


def encdec_init(key, cfg: EncDecConfig):
    ke, kf, kenc, kdec, kh = jax.random.split(key, 5)
    embed = make_embedding(cfg.vocab, cfg.d_model, cfg.embedding, cfg.pdtype)
    enc = jax.vmap(lambda k: _enc_layer_init(k, cfg))(jax.random.split(kenc, cfg.enc_layers))
    dec = jax.vmap(lambda k: _dec_layer_init(k, cfg))(jax.random.split(kdec, cfg.dec_layers))
    return {"embed": embed.init(ke),
            "frontend_proj": dense_init(kf, cfg.d_model, cfg.d_model, cfg.pdtype),
            "encoder": enc, "enc_norm": rmsnorm_init(cfg.d_model, cfg.pdtype),
            "decoder": dec, "final_norm": rmsnorm_init(cfg.d_model, cfg.pdtype),
            "lm_head": dense_init(kh, cfg.d_model, cfg.vocab, cfg.pdtype)}


def encode(params, frames, cfg: EncDecConfig):
    """frames: (B, S_enc, d_model) stub embeddings → encoder memory."""
    h = constrain_batch(dense(params["frontend_proj"], frames.astype(cfg.cdtype), cfg.cdtype))
    acfg = cfg.attn_cfg(False)

    def body(carry, lp):
        h = carry + attention(lp["attn"], rmsnorm(lp["norm1"], carry), acfg, cfg.cdtype)
        h = h + mlp(lp["mlp"], rmsnorm(lp["norm2"], h), cfg.cdtype, cfg.ffn_kind)
        return constrain_batch(h), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = lax.scan(body, h, params["encoder"])
    return rmsnorm(params["enc_norm"], h)


def _decode_stack(params, h, memory, cfg: EncDecConfig):
    self_cfg, cross_cfg = cfg.attn_cfg(True), cfg.attn_cfg(False)

    def body(carry, lp):
        h = carry + attention(lp["self_attn"], rmsnorm(lp["norm1"], carry),
                              self_cfg, cfg.cdtype)
        h = h + attention(lp["cross_attn"], rmsnorm(lp["norm_x"], h), cross_cfg,
                          cfg.cdtype, kv_x=memory)
        h = h + mlp(lp["mlp"], rmsnorm(lp["norm2"], h), cfg.cdtype, cfg.ffn_kind)
        return constrain_batch(h), None

    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)
    h, _ = lax.scan(body, h, params["decoder"])
    return rmsnorm(params["final_norm"], h)


def _embed(params, tokens, cfg):
    embed = make_embedding(cfg.vocab, cfg.d_model, cfg.embedding, cfg.pdtype)
    return constrain_batch(embed.apply(params["embed"], tokens).astype(cfg.cdtype))


def encdec_loss_fn(params, batch, cfg: EncDecConfig):
    """batch: frames (B,Se,D), tokens (B,S), labels (B,S), mask (B,S)."""
    memory = encode(params, batch["frames"], cfg)
    h = _decode_stack(params, _embed(params, batch["tokens"], cfg), memory, cfg)
    loss = chunked_xent(h, batch["labels"], batch["mask"],
                        params["lm_head"]["w"], cfg.xent_chunk)
    return loss, {"xent": loss}


# ------------------------------------------------------------------ serving


def encdec_make_cache(cfg: EncDecConfig, batch: int, max_len: int):
    kv = make_cache(batch, max_len, cfg.n_kv_heads, cfg.d_head, cfg.cdtype)
    kv = jax.tree.map(lambda x: jnp.broadcast_to(x, (cfg.dec_layers,) + x.shape), kv)
    s_enc = max(1, max_len // cfg.enc_ratio)
    cross = jnp.zeros((cfg.dec_layers, batch, s_enc, cfg.n_kv_heads, cfg.d_head),
                      cfg.cdtype)
    return {"self": kv, "cross_k": cross, "cross_v": cross}


def encdec_prefill(params, frames, tokens, cache, cfg: EncDecConfig):
    """Encode audio, precompute cross K/V, prefill decoder self-attn cache."""
    memory = encode(params, frames, cfg)
    self_cfg, cross_cfg = cfg.attn_cfg(True), cfg.attn_cfg(False)
    h = _embed(params, tokens, cfg)

    def body(carry, xs):
        lp, self_cache = xs
        ck, cv = cross_kv(lp["cross_attn"], memory, cross_cfg, cfg.cdtype)
        h = carry
        attn_out, new_self = attention(lp["self_attn"], rmsnorm(lp["norm1"], h),
                                       self_cfg, cfg.cdtype, cache=self_cache)
        h = h + attn_out
        h = h + attention_with_kv(lp["cross_attn"], rmsnorm(lp["norm_x"], h),
                                  ck, cv, cross_cfg, cfg.cdtype)
        h = h + mlp(lp["mlp"], rmsnorm(lp["norm2"], h), cfg.cdtype, cfg.ffn_kind)
        return h, (new_self, ck, cv)

    h, (new_self, cks, cvs) = lax.scan(body, h, (params["decoder"], cache["self"]))
    h = rmsnorm(params["final_norm"], h)
    logits = dense(params["lm_head"], h[:, -1:], cfg.cdtype).astype(jnp.float32)
    return logits, {"self": new_self, "cross_k": cks, "cross_v": cvs}


def encdec_decode_step(params, tokens, pos, cache, cfg: EncDecConfig):
    self_cfg, cross_cfg = cfg.attn_cfg(True), cfg.attn_cfg(False)
    h = _embed(params, tokens, cfg)
    positions = jnp.full((tokens.shape[0], 1), pos)

    def body(carry, xs):
        lp, self_cache, ck, cv = xs
        h = carry
        attn_out, new_self = attention(lp["self_attn"], rmsnorm(lp["norm1"], h),
                                       self_cfg, cfg.cdtype, positions=positions,
                                       cache=self_cache, cache_index=pos)
        h = h + attn_out
        h = h + attention_with_kv(lp["cross_attn"], rmsnorm(lp["norm_x"], h),
                                  ck, cv, cross_cfg, cfg.cdtype)
        h = h + mlp(lp["mlp"], rmsnorm(lp["norm2"], h), cfg.cdtype, cfg.ffn_kind)
        return h, new_self

    h, new_self = lax.scan(body, h, (params["decoder"], cache["self"],
                                     cache["cross_k"], cache["cross_v"]))
    h = rmsnorm(params["final_norm"], h)
    logits = dense(params["lm_head"], h, cfg.cdtype).astype(jnp.float32)
    return logits, {"self": new_self, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"]}
