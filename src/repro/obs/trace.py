"""Span tracer with Chrome-trace / Perfetto JSON export.

``Tracer`` records *complete* events (name, begin, duration) on a
monotonic clock, either through the ``span("stage")`` context manager
(nesting tracked per thread — the train loop's usage) or through
``complete(name, t0, dur)`` when the caller already owns the boundary
timestamps (the serving engine's usage: its stage timers double as the
trace events, so tracing adds zero extra clock reads).

Export is the Chrome Trace Event JSON format (``{"traceEvents": [...]}``
with ``ph: "X"`` complete events, microsecond timestamps), which
``chrome://tracing`` and https://ui.perfetto.dev both load directly —
one wave renders as a ``wave`` bar with its stage bars nested inside.

Two honesty knobs:

* ``fence=True`` — ``tracer.fence(x)`` calls ``jax.block_until_ready``
  on ``x`` before the enclosing span closes, so a span around an async
  dispatch measures *device* time, not dispatch time.  Off by default:
  fencing serializes the pipeline and is a measurement mode, never a
  serving mode (with ``fence=False``, ``fence(x)`` is a no-op
  passthrough and dispatch stays fully async).
* ``jax_annotations=True`` — each ``span`` additionally enters a
  ``jax.profiler.TraceAnnotation``, so when a run is wrapped in
  ``jax.profiler.trace`` the engine's logical stages line up against
  XLA's own timeline.  Guarded import: without jax (or an old profiler
  API) the flag degrades to plain spans.

The tracer is append-only and bounded (``max_events``, oldest dropped);
``drain()`` hands the events over and clears, so a long-running engine
can stream trace chunks without unbounded growth.
"""

from __future__ import annotations

import contextlib
import json
import threading
import time
from typing import Optional

__all__ = ["Tracer"]


class Tracer:
    def __init__(self, *, fence: bool = False, jax_annotations: bool = False,
                 max_events: int = 200_000, pid: int = 0):
        self.fence_enabled = fence
        self.max_events = max_events
        self.pid = pid
        self.events: list[dict] = []
        self._lock = threading.Lock()
        self._local = threading.local()
        self._t0 = time.monotonic()
        self._annotate = None
        if jax_annotations:
            try:
                from jax.profiler import TraceAnnotation
                self._annotate = TraceAnnotation
            except Exception:  # jax absent or profiler API drifted
                self._annotate = None

    # ------------------------------------------------------------- recording

    def _now(self) -> float:
        return time.monotonic()

    def _depth(self) -> int:
        return getattr(self._local, "depth", 0)

    def complete(self, name: str, t0: float, dur_s: float, **args) -> None:
        """Record one complete event from caller-owned monotonic
        timestamps (``t0`` from ``time.monotonic()``, duration in
        seconds).  The hot-path entry point: no clock reads here."""
        ev = {"name": name, "ph": "X", "pid": self.pid,
              "tid": threading.get_ident() & 0xFFFF,
              "ts": (t0 - self._t0) * 1e6, "dur": dur_s * 1e6}
        if args:
            ev["args"] = args
        with self._lock:
            self.events.append(ev)
            if len(self.events) > self.max_events:
                del self.events[0]

    def instant(self, name: str, **args) -> None:
        ev = {"name": name, "ph": "i", "pid": self.pid,
              "tid": threading.get_ident() & 0xFFFF,
              "ts": (self._now() - self._t0) * 1e6, "s": "t"}
        if args:
            ev["args"] = args
        with self._lock:
            self.events.append(ev)
            if len(self.events) > self.max_events:
                del self.events[0]

    @contextlib.contextmanager
    def span(self, name: str, **args):
        """Context manager form: times the block, tracks nesting depth
        per thread (depth rides in ``args.depth`` so malformed nesting is
        assertable), optionally mirrors into a jax profiler annotation."""
        depth = self._depth()
        self._local.depth = depth + 1
        ctx = self._annotate(name) if self._annotate is not None else None
        if ctx is not None:
            ctx.__enter__()
        t0 = self._now()
        try:
            yield self
        finally:
            dur = self._now() - t0
            if ctx is not None:
                ctx.__exit__(None, None, None)
            self._local.depth = depth
            self.complete(name, t0, dur, depth=depth, **args)

    def fence(self, value):
        """Block on ``value`` (``jax.block_until_ready``) when fencing is
        enabled, so the enclosing span measures device completion, not
        async dispatch.  Passthrough when disabled."""
        if self.fence_enabled and value is not None:
            import jax
            jax.block_until_ready(value)
        return value

    # ------------------------------------------------------------- export

    def chrome_trace(self) -> dict:
        """The Chrome Trace Event payload (Perfetto-loadable)."""
        with self._lock:
            events = [dict(e) for e in self.events]
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def to_json(self) -> str:
        return json.dumps(self.chrome_trace())

    def save(self, path: str) -> str:
        import os
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            f.write(self.to_json())
        return path

    def drain(self) -> list[dict]:
        """Hand over and clear the event buffer (streaming export)."""
        with self._lock:
            events, self.events = self.events, []
        return events

    def __len__(self) -> int:
        with self._lock:
            return len(self.events)
