"""Zero-dependency metrics registry: counters, gauges, histograms.

The serving/training/dist layers each kept private stat dicts
(``RecsysEngine.metrics()``, ``CacheStats``, ``dist.accounting`` report
dicts) with no shared schema, no merge story for multi-engine runs, and
no sink.  This module is the one registry they all fold into:

* ``Counter`` — monotone accumulator (``inc``); merge = sum;
* ``Gauge``   — last-written value (``set``); merge = other wins;
* ``Histogram`` — raw-sample distribution (``observe``) with
  numpy-compatible linear-interpolation percentiles; merge = sample
  union.  Samples are kept raw (bounded by ``max_samples``, oldest
  dropped first) so percentile math is exact, not bucket-approximate —
  the obs tests pin ``percentile(q) == np.percentile(samples, q)``.

Every metric is labeled: ``hist.labels(stage="dense").observe(dt)``
binds a label set to a series once and returns a handle with zero
per-call dict hashing — the pattern the serving hot path uses so obs-on
stays within the 2% QPS overhead budget.  All mutation goes through one
``threading.Lock`` per registry (engines may reap on one thread while a
metrics scrape runs on another).

Sinks: ``snapshot()`` (plain nested dict), ``to_jsonl()`` /
``save_jsonl(path)`` (one JSON object per series — the CI artifact
format), ``merge(other)`` (fold another registry in, e.g. per-engine
registries of a multi-engine bench), ``reset(prefix=)`` (drop series —
what ``RecsysEngine.reset_metrics`` calls so warm-up traffic never
leaks into steady-state numbers).

Stdlib-only on purpose: importing ``repro.obs`` must never pull jax or
numpy into a process that only wants to read a metrics file.
"""

from __future__ import annotations

import json
import threading
from typing import Iterable, Optional

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


def _percentile(sorted_samples: list[float], q: float) -> float:
    """numpy's default ('linear') percentile on pre-sorted samples."""
    n = len(sorted_samples)
    if n == 0:
        raise ValueError("percentile of an empty series")
    if not 0.0 <= q <= 100.0:
        raise ValueError(f"q={q} outside [0, 100]")
    rank = (n - 1) * (q / 100.0)
    lo = int(rank)
    hi = min(lo + 1, n - 1)
    frac = rank - lo
    return sorted_samples[lo] * (1.0 - frac) + sorted_samples[hi] * frac


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


class _Series:
    """One (metric, labelset) time-series; subclasses hold the value."""

    def __init__(self, metric: "_Metric", labels: dict):
        self._metric = metric
        self._lock = metric._lock
        self.labels_dict = dict(labels)


class _CounterSeries(_Series):
    def __init__(self, metric, labels):
        super().__init__(metric, labels)
        self.value = 0.0

    def inc(self, value: float = 1.0) -> None:
        if value < 0:
            raise ValueError(f"counter increment must be >= 0, got {value}")
        with self._lock:
            self.value += value


class _GaugeSeries(_Series):
    def __init__(self, metric, labels):
        super().__init__(metric, labels)
        self.value = 0.0

    def set(self, value: float) -> None:
        with self._lock:
            self.value = float(value)

    def inc(self, value: float = 1.0) -> None:
        with self._lock:
            self.value += value


class _HistogramSeries(_Series):
    def __init__(self, metric, labels, max_samples: Optional[int]):
        super().__init__(metric, labels)
        self.max_samples = max_samples
        self.samples: list[float] = []
        self.count = 0
        self.sum = 0.0

    def observe(self, value: float) -> None:
        with self._lock:
            self.count += 1
            self.sum += value
            self.samples.append(float(value))
            if self.max_samples is not None \
                    and len(self.samples) > self.max_samples:
                del self.samples[0]

    def percentile(self, q: float) -> float:
        with self._lock:
            return _percentile(sorted(self.samples), q)

    def summary(self) -> dict:
        with self._lock:
            s = sorted(self.samples)
        out = {"count": self.count, "sum": self.sum}
        if s:
            out.update(min=s[0], max=s[-1],
                       p50=_percentile(s, 50), p99=_percentile(s, 99))
        return out


class _Metric:
    series_cls = _Series

    def __init__(self, name: str, help: str, lock: threading.Lock):
        self.name = name
        self.help = help
        self._lock = lock
        self._series: dict[tuple, _Series] = {}

    def _make_series(self, labels: dict) -> _Series:
        return self.series_cls(self, labels)

    def labels(self, **labels) -> _Series:
        """Bind a label set -> its series (created on first use).  Hold
        the returned handle on hot paths: repeated calls re-hash."""
        key = _label_key(labels)
        with self._lock:
            s = self._series.get(key)
            if s is None:
                s = self._make_series(labels)
                self._series[key] = s
            return s

    def series(self) -> list[_Series]:
        with self._lock:
            return list(self._series.values())


class Counter(_Metric):
    series_cls = _CounterSeries
    kind = "counter"

    def inc(self, value: float = 1.0, **labels) -> None:
        self.labels(**labels).inc(value)

    def value(self, **labels) -> float:
        return self.labels(**labels).value


class Gauge(_Metric):
    series_cls = _GaugeSeries
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        self.labels(**labels).set(value)

    def value(self, **labels) -> float:
        return self.labels(**labels).value


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name, help, lock, max_samples: Optional[int] = 65536):
        super().__init__(name, help, lock)
        self.max_samples = max_samples

    def _make_series(self, labels):
        return _HistogramSeries(self, labels, self.max_samples)

    def observe(self, value: float, **labels) -> None:
        self.labels(**labels).observe(value)

    def percentile(self, q: float, **labels) -> float:
        return self.labels(**labels).percentile(q)


class MetricsRegistry:
    """Name -> metric map with get-or-create constructors.

    Re-requesting a name returns the same object; re-requesting it as a
    *different* type raises (two subsystems silently sharing one name
    with different semantics is the bug registries exist to prevent).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, _Metric] = {}

    def _get(self, name: str, cls, help: str, **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, self._lock, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as "
                                f"{type(m).__name__}, not {cls.__name__}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help)

    def histogram(self, name: str, help: str = "",
                  max_samples: Optional[int] = 65536) -> Histogram:
        return self._get(name, Histogram, help, max_samples=max_samples)

    def metrics(self) -> list[_Metric]:
        with self._lock:
            return list(self._metrics.values())

    # ------------------------------------------------------------- sinks

    def snapshot(self) -> dict:
        """Plain nested dict of every series — JSON-safe, no live refs."""
        out: dict = {}
        for m in self.metrics():
            series = []
            for s in m.series():
                entry: dict = {"labels": dict(s.labels_dict)}
                if isinstance(s, _HistogramSeries):
                    entry.update(s.summary())
                else:
                    entry["value"] = s.value
                series.append(entry)
            out[m.name] = {"type": m.kind, "help": m.help, "series": series}
        return out

    def to_jsonl(self) -> str:
        """One JSON object per series (the CI artifact format)."""
        lines = []
        snap = self.snapshot()
        for name in sorted(snap):
            meta = snap[name]
            for s in meta["series"]:
                rec = {"name": name, "type": meta["type"], **s}
                lines.append(json.dumps(rec, sort_keys=True))
        return "\n".join(lines) + ("\n" if lines else "")

    def save_jsonl(self, path: str) -> str:
        import os
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            f.write(self.to_jsonl())
        return path

    # ------------------------------------------------------------- merge

    def merge(self, other: "MetricsRegistry") -> None:
        """Fold ``other`` into this registry: counters sum, gauges take
        the other's value (last writer wins), histograms union samples.
        Multi-engine runs keep one registry per engine and merge for the
        report, so per-engine resets never race a shared scrape."""
        for om in other.metrics():
            if isinstance(om, Counter):
                mine = self.counter(om.name, om.help)
                for s in om.series():
                    mine.labels(**s.labels_dict).inc(s.value)
            elif isinstance(om, Gauge):
                mine = self.gauge(om.name, om.help)
                for s in om.series():
                    mine.labels(**s.labels_dict).set(s.value)
            elif isinstance(om, Histogram):
                mine = self.histogram(om.name, om.help,
                                      max_samples=om.max_samples)
                for s in om.series():
                    dst = mine.labels(**s.labels_dict)
                    with self._lock:
                        dst.samples.extend(s.samples)
                        dst.count += s.count
                        dst.sum += s.sum
                        if dst.max_samples is not None:
                            excess = len(dst.samples) - dst.max_samples
                            if excess > 0:
                                del dst.samples[:excess]

    def reset(self, prefix: Optional[str] = None,
              names: Optional[Iterable[str]] = None) -> None:
        """Drop series: everything, a name ``prefix``, or explicit
        ``names``.  Metric objects stay registered (held handles keep
        working) — only their series are cleared."""
        sel = set(names) if names is not None else None
        for m in self.metrics():
            if prefix is not None and not m.name.startswith(prefix):
                continue
            if sel is not None and m.name not in sel:
                continue
            with self._lock:
                for s in m._series.values():
                    if isinstance(s, _HistogramSeries):
                        s.samples.clear()
                        s.count = 0
                        s.sum = 0.0
                    else:
                        s.value = 0.0
