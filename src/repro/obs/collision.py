"""Measured collision-mass telemetry — the planner's feedback signal.

The planner (``repro.plan``) chooses per-feature table structures by a
*predicted* collision mass: the frequency-weighted product-of-sharings
proxy (``plan.quality.proxy_loss``) evaluated on training-time frequency
stats.  Serving traffic is the ground truth that prediction is supposed
to describe — SCMA (PAPERS.md) frames memory allocation as driven by
live access statistics, and the Embedding Compression survey's core
warning is that compression choices must be validated against measured,
not modeled, quantities.  This module closes that loop:

``CollisionTelemetry`` accumulates the raw category ids each feature
actually served (the engine records every live ``(idx, mask)`` wave when
obs is on), then evaluates the *same* proxy formula on the observed
empirical distribution.  Predicted and measured are therefore directly
comparable numbers — same estimator, different distribution — so a gap
between them is a *traffic drift* signal, not a formula mismatch:

    predicted = proxy_loss(partitions, train_stats)     # plan time
    measured  = proxy_loss(partitions, observed_stats)  # serve time

``observed_stats`` returns honest ``plan.freq.FeatureStats``, so the
telemetry feeds straight back into the planner: ``build_plan(telemetry.
all_observed_stats(), ...)`` re-plans for the traffic the system is
*actually* serving (the ROADMAP's online re-planning item), and the
measured masses are exactly the calibration data the
``fit_width_exponent``-style hooks in ``plan.quality`` were waiting on.

Accumulation is O(wave) per wave (an append of the live ids) with
periodic ``np.unique`` compaction every ``compact_every`` waves, so a
long-running engine holds O(support) memory per feature, not O(traffic).
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

__all__ = ["CollisionTelemetry", "predicted_collision_mass"]


def predicted_collision_mass(module, stats) -> float:
    """The planner's predicted collision mass for one feature's module
    under ``stats`` (``plan.freq.FeatureStats``): ``proxy_loss`` over the
    module's own partition view — the number ``TablePlan.quality`` was
    derived from, recomputed here so benches can tabulate it next to the
    measured value without reloading a plan."""
    from ..plan.quality import module_partitions, proxy_loss
    return proxy_loss(module_partitions(module), stats)


class CollisionTelemetry:
    """Per-feature served-traffic histograms + measured collision mass.

    ``record(idx, mask)`` takes one padded wave (``(B, F, L)`` raw ids
    and its 0/1 mask) and accumulates every live id.  Ids are the *raw*
    category ids (pre any hashing) — the partition view is what folds
    them, exactly as it does for the planner's training stats.
    """

    _SHIFT = 44  # packed key: (feature << 44) | raw id — recsys's layout

    def __init__(self, table_sizes: Sequence[int], compact_every: int = 64):
        self.table_sizes = tuple(int(s) for s in table_sizes)
        self.compact_every = compact_every
        self._offsets = (np.arange(len(self.table_sizes), dtype=np.int64)
                         << self._SHIFT)
        self._pending: list[np.ndarray] = []   # 1-D packed live ids
        self._ids = np.empty(0, np.int64)      # packed, sorted unique
        self._counts = np.empty(0, np.int64)
        # support-novelty counter: fraction of served lookups whose raw id
        # was NOT in the baseline support the plan was solved from — ids
        # the planner never scored, the leading edge of traffic drift
        self._baseline: Optional[np.ndarray] = None   # packed, sorted
        self._lookups = np.zeros(len(self.table_sizes), np.int64)
        self._unseen = np.zeros(len(self.table_sizes), np.int64)
        self.waves = 0
        self.requests = 0

    # ------------------------------------------------------------ recording

    def record(self, idx: np.ndarray, mask: np.ndarray,
               live_rows: Optional[int] = None) -> None:
        """Accumulate one wave.  ``live_rows`` (the unpadded batch) trims
        padded batch rows; padded bag slots are excluded by the mask.
        Hot-path cost is two vectorized ops (pack + mask-select); the
        unique/merge work is deferred to periodic compaction."""
        if live_rows is not None:
            idx, mask = idx[:live_rows], mask[:live_rows]
        packed = (np.asarray(idx).astype(np.int64)
                  + self._offsets[None, :, None])[np.asarray(mask) > 0]
        self._pending.append(packed)
        feat = packed >> self._SHIFT
        self._lookups += np.bincount(feat, minlength=len(self.table_sizes))
        if self._baseline is not None and packed.size:
            pos = np.searchsorted(self._baseline, packed)
            pos_c = np.minimum(pos, max(self._baseline.size - 1, 0))
            seen = ((pos < self._baseline.size)
                    & (self._baseline.size > 0)
                    & (self._baseline[pos_c] == packed))
            self._unseen += np.bincount(feat[~seen],
                                        minlength=len(self.table_sizes))
        self.waves += 1
        self.requests += int(idx.shape[0])
        if len(self._pending) >= self.compact_every:
            self._compact()

    def reset(self) -> None:
        """Drop all accumulated traffic.  The online drift detector judges
        *windows*: the controller reads a window's measured masses, calls
        ``reset()``, and the next check sees only fresh traffic — while the
        long-horizon view lives in ``plan.freq.StreamingStats``, which the
        controller feeds from each window before resetting."""
        self._pending = []
        self._ids = np.empty(0, np.int64)
        self._counts = np.empty(0, np.int64)
        # the baseline is a plan-time reference, not traffic — it survives
        # the window reset; only the per-window counters restart
        self._lookups = np.zeros(len(self.table_sizes), np.int64)
        self._unseen = np.zeros(len(self.table_sizes), np.int64)
        self.waves = 0
        self.requests = 0

    def set_baseline(self, per_feature) -> None:
        """Install the baseline support for the novelty counter.

        ``per_feature`` is one entry per categorical feature: either a
        ``plan.freq.FeatureStats`` (its ``ids`` field is used — pass the
        exact stats the live plan was solved from) or a bare id array.
        Subsequent waves count, per feature, lookups whose id is outside
        this support; ``report()`` surfaces the rate."""
        if len(per_feature) != len(self.table_sizes):
            raise ValueError(f"baseline has {len(per_feature)} features, "
                             f"telemetry tracks {len(self.table_sizes)}")
        packed = [np.asarray(getattr(f, "ids", f), np.int64)
                  + (np.int64(i) << self._SHIFT)
                  for i, f in enumerate(per_feature)]
        self._baseline = np.unique(np.concatenate(packed)) if packed \
            else np.empty(0, np.int64)
        self._lookups = np.zeros(len(self.table_sizes), np.int64)
        self._unseen = np.zeros(len(self.table_sizes), np.int64)

    def unseen_id_rate(self, feature: int) -> Optional[float]:
        """Fraction of this feature's served lookups outside the baseline
        support (``None`` until ``set_baseline`` is called)."""
        if self._baseline is None:
            return None
        n = int(self._lookups[feature])
        return float(self._unseen[feature] / n) if n else 0.0

    def _compact(self) -> None:
        if not self._pending:
            return
        pending, self._pending = self._pending, []
        fresh = np.concatenate(pending)
        if not fresh.size:
            return
        ids, counts = np.unique(fresh, return_counts=True)
        merged = np.concatenate([self._ids, ids])
        weights = np.concatenate([self._counts, counts])
        uniq, inv = np.unique(merged, return_inverse=True)
        self._ids = uniq
        self._counts = np.bincount(inv, weights=weights).astype(np.int64)

    def _feature_slice(self, feature: int):
        lo = np.searchsorted(self._ids, feature << self._SHIFT)
        hi = np.searchsorted(self._ids, (feature + 1) << self._SHIFT)
        return (self._ids[lo:hi] - (feature << self._SHIFT),
                self._counts[lo:hi])

    # ------------------------------------------------------------ reading

    def observed_lookups(self, feature: int) -> int:
        self._compact()
        return int(self._feature_slice(feature)[1].sum())

    def observed_support(self, feature: int) -> int:
        self._compact()
        return int(self._feature_slice(feature)[0].size)

    def observed_stats(self, feature: int):
        """``plan.freq.FeatureStats`` of the served traffic for one
        feature — the planner-feedback hook (feed to ``build_plan`` to
        re-plan for live traffic)."""
        from ..plan.freq import FeatureStats
        self._compact()
        ids, counts = self._feature_slice(feature)
        total = counts.sum()
        probs = counts / total if total else counts.astype(np.float64)
        return FeatureStats(size=self.table_sizes[feature], ids=ids,
                            probs=probs)

    def all_observed_stats(self) -> list:
        return [self.observed_stats(i) for i in range(len(self.table_sizes))]

    def measured_collision_mass(self, module, feature: int) -> float:
        """``proxy_loss`` of ``module``'s partitions under the traffic
        this feature actually served — the measured twin of the
        planner's predicted value."""
        from ..plan.quality import module_partitions, proxy_loss
        return proxy_loss(module_partitions(module),
                          self.observed_stats(feature))

    def report(self, modules, predicted_stats=None, plan=None) -> list[dict]:
        """Per-feature predicted-vs-observed table (the ``BENCH_obs``
        payload).  ``modules`` are the engine's embedding modules;
        ``predicted_stats`` (optional, per-feature ``FeatureStats`` the
        plan was solved from) fills the predicted column; ``plan``
        (optional ``MemoryPlan``) annotates the planned kind/quality."""
        out = []
        for i, mod in enumerate(modules):
            row = {
                "feature": i,
                "size": self.table_sizes[i],
                "observed_lookups": self.observed_lookups(i),
                "observed_support": self.observed_support(i),
                "unseen_id_rate": self.unseen_id_rate(i),
                "measured_collision_mass":
                    self.measured_collision_mass(mod, i),
            }
            if predicted_stats is not None:
                row["predicted_collision_mass"] = predicted_collision_mass(
                    mod, predicted_stats[i])
            if plan is not None:
                t = plan.tables[i]
                row["kind"] = t.kind
                row["planned_quality"] = t.quality
                row["dim"] = t.dim or plan.emb_dim
            out.append(row)
        return out
