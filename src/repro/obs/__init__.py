"""repro.obs — unified metrics + tracing across serve/train/dist.

Three pieces, all zero-dependency and off by default:

* ``MetricsRegistry`` (``registry``) — labeled ``Counter`` / ``Gauge`` /
  ``Histogram`` with snapshot/JSONL sinks and multi-engine merge;
* ``Tracer`` (``trace``) — ``span()`` context managers and caller-timed
  ``complete()`` events exporting Chrome-trace/Perfetto JSON, with
  optional ``jax.block_until_ready`` fencing and a ``jax.profiler``
  annotation bridge;
* ``CollisionTelemetry`` (``collision``) — measured collision mass over
  served ids, the planner's predicted-vs-observed feedback signal.

``Obs`` bundles one of each — the single handle ``RecsysEngine``,
``Trainer``, and the launchers accept (``obs=None`` everywhere means
every instrumentation branch is skipped: the off-by-default contract).
"""

from __future__ import annotations

from typing import Optional, Sequence

from .collision import CollisionTelemetry, predicted_collision_mass
from .registry import Counter, Gauge, Histogram, MetricsRegistry
from .trace import Tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "Tracer",
    "CollisionTelemetry", "predicted_collision_mass", "Obs",
]


class Obs:
    """One observability bundle: registry + tracer (+ collision
    telemetry once an engine attaches table sizes).

    ``Obs(trace=True)`` turns span recording on; ``Obs(collisions=True)``
    asks the serving engine to accumulate served-id histograms (the
    engine calls ``attach_collisions(table_sizes)`` when it boots).
    """

    def __init__(self, *, trace: bool = False, collisions: bool = False,
                 fence: bool = False, jax_annotations: bool = False):
        self.registry = MetricsRegistry()
        self.tracer: Optional[Tracer] = (
            Tracer(fence=fence, jax_annotations=jax_annotations)
            if trace else None)
        self.want_collisions = collisions
        self.collisions: Optional[CollisionTelemetry] = None

    def attach_collisions(self, table_sizes: Sequence[int],
                          compact_every: int = 64) -> None:
        if self.want_collisions and self.collisions is None:
            self.collisions = CollisionTelemetry(
                table_sizes, compact_every=compact_every)

    # thin pass-throughs so call sites read ``obs.counter(...)``
    def counter(self, name: str, help: str = "") -> Counter:
        return self.registry.counter(name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self.registry.gauge(name, help)

    def histogram(self, name: str, help: str = "",
                  max_samples: Optional[int] = 65536) -> Histogram:
        return self.registry.histogram(name, help, max_samples=max_samples)

    def save(self, metrics_path: Optional[str] = None,
             trace_path: Optional[str] = None) -> None:
        if metrics_path:
            self.registry.save_jsonl(metrics_path)
        if trace_path and self.tracer is not None:
            self.tracer.save(trace_path)
