"""Optimizers from scratch (no optax in the container).

The paper trains with Adagrad and AMSGrad "with default hyperparameters"
(§5.2); production DLRM uses *row-wise* Adagrad on embedding tables (one
accumulator per row instead of per element — 1/D the optimizer memory for
tables, the same memory-trick family as the paper's).  All are provided,
plus Adam and Adafactor (factored second moment — what lets arctic-480b's
optimizer state fit HBM), global-norm clipping, and LR schedules.

Design: every optimizer is defined by *leaf-level* ``init_leaf(p)`` /
``update_leaf(g, s, p, step)`` functions; tree-level ``init``/``update``
flatten the param tree once and map over leaves.  That makes the
``partitioned`` combinator (different rules for different subtrees — e.g.
row-wise Adagrad on embedding tables, Adam elsewhere) a per-leaf dispatch
instead of a pytree surgery problem, and the optimizer state a flat list
that checkpoints/reshards like any other pytree.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["sgd", "adagrad", "rowwise_adagrad", "adam", "adafactor",
           "partitioned", "clip_by_global_norm", "cosine_schedule",
           "constant_schedule", "global_norm", "Optimizer", "leaf_paths",
           "state_structs"]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init_leaf: Callable    # p -> leaf_state (dict of arrays)
    update_leaf: Callable  # (g, s, p, step) -> (new_p, new_s)

    def init(self, params):
        return [self.init_leaf(p) for p in jax.tree.leaves(params)]

    def update(self, grads, state, params, step):
        leaves_g, treedef = jax.tree.flatten(grads)
        leaves_p = jax.tree.leaves(params)
        out = [self.update_leaf(g, s, p, step)
               for g, s, p in zip(leaves_g, state, leaves_p)]
        new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
        return new_params, [o[1] for o in out]


def constant_schedule(lr: float):
    return lambda step: lr


def cosine_schedule(lr: float, warmup: int, total: int, floor: float = 0.1):
    def fn(step):
        warm = lr * jnp.minimum(1.0, (step + 1) / max(warmup, 1))
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup, warm, lr * cos)
    return fn


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), norm


def _sched(lr):
    return lr if callable(lr) else constant_schedule(lr)


def _step_p(p, u):
    return (p.astype(jnp.float32) + u).astype(p.dtype)


def sgd(lr, momentum: float = 0.0, weight_decay: float = 0.0):
    sched = _sched(lr)

    def init_leaf(p):
        return {"m": jnp.zeros(p.shape, jnp.float32)} if momentum else {}

    def update_leaf(g, s, p, step):
        g32 = g.astype(jnp.float32) + weight_decay * p.astype(jnp.float32)
        if momentum:
            m = momentum * s["m"] + g32
            return _step_p(p, -sched(step) * m), {"m": m}
        return _step_p(p, -sched(step) * g32), s

    return Optimizer(init_leaf, update_leaf)


def adagrad(lr=1e-2, eps: float = 1e-10):
    """Duchi et al. 2011 — the paper's default optimizer."""
    sched = _sched(lr)

    def init_leaf(p):
        return {"acc": jnp.zeros(p.shape, jnp.float32)}

    def update_leaf(g, s, p, step):
        g32 = g.astype(jnp.float32)
        acc = s["acc"] + jnp.square(g32)
        return _step_p(p, -sched(step) * g32 / (jnp.sqrt(acc) + eps)), {"acc": acc}

    return Optimizer(init_leaf, update_leaf)


def rowwise_adagrad(lr=1e-2, eps: float = 1e-10):
    """Adagrad with one accumulator per table row (production-DLRM trick).

    For a (rows, D) table the state is (rows, 1) — 1/D the optimizer
    memory.  Non-2D leaves fall back to element-wise Adagrad.
    """
    sched = _sched(lr)

    def init_leaf(p):
        shape = (p.shape[0], 1) if p.ndim == 2 else p.shape
        return {"acc": jnp.zeros(shape, jnp.float32)}

    def update_leaf(g, s, p, step):
        g32 = g.astype(jnp.float32)
        if g.ndim == 2:
            acc = s["acc"] + jnp.mean(jnp.square(g32), axis=1, keepdims=True)
        else:
            acc = s["acc"] + jnp.square(g32)
        return _step_p(p, -sched(step) * g32 / (jnp.sqrt(acc) + eps)), {"acc": acc}

    return Optimizer(init_leaf, update_leaf)


def adam(lr=1e-3, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         amsgrad: bool = False, weight_decay: float = 0.0):
    """Adam / AMSGrad (Reddi et al. 2019) — the paper's second optimizer."""
    sched = _sched(lr)

    def init_leaf(p):
        z = jnp.zeros(p.shape, jnp.float32)
        s = {"m": z, "v": z}
        if amsgrad:
            s["vmax"] = z
        return s

    def update_leaf(g, s, p, step):
        t = step + 1
        g32 = g.astype(jnp.float32)
        m = b1 * s["m"] + (1 - b1) * g32
        v = b2 * s["v"] + (1 - b2) * jnp.square(g32)
        ns = {"m": m, "v": v}
        if amsgrad:
            vmax = jnp.maximum(s["vmax"], v)
            ns["vmax"] = vmax
            vhat = vmax
        else:
            vhat = v
        mhat = m / (1 - b1 ** t)
        vhat = vhat / (1 - b2 ** t)
        u = -sched(step) * mhat / (jnp.sqrt(vhat) + eps)
        if weight_decay:
            u = u - sched(step) * weight_decay * p.astype(jnp.float32)
        return _step_p(p, u), ns

    return Optimizer(init_leaf, update_leaf)


def adafactor(lr=1e-2, eps: float = 1e-30, clip_threshold: float = 1.0,
              decay: float = 0.8):
    """Factored second moment: O(rows+cols) state for ≥2-D leaves."""
    sched = _sched(lr)

    def init_leaf(p):
        if p.ndim >= 2:
            return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    def update_leaf(g, s, p, step):
        t = step + 1
        beta = 1.0 - (t.astype(jnp.float32) if hasattr(t, "astype") else float(t)) ** (-decay)
        g32 = g.astype(jnp.float32)
        g2 = jnp.square(g32) + eps
        if g.ndim >= 2:
            vr = beta * s["vr"] + (1 - beta) * g2.mean(axis=-1)
            vc = beta * s["vc"] + (1 - beta) * g2.mean(axis=-2)
            ns = {"vr": vr, "vc": vc}
            denom = (vr[..., None] * vc[..., None, :]
                     / jnp.maximum(vr.mean(axis=-1, keepdims=True), eps)[..., None])
            u = g32 / jnp.sqrt(jnp.maximum(denom, eps))
        else:
            v = beta * s["v"] + (1 - beta) * g2
            ns = {"v": v}
            u = g32 / jnp.sqrt(jnp.maximum(v, eps))
        rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
        u = u / jnp.maximum(1.0, rms / clip_threshold)
        return _step_p(p, -sched(step) * u), ns

    return Optimizer(init_leaf, update_leaf)


def leaf_paths(tree, is_leaf=None) -> list[str]:
    """'/'-joined string path per leaf, in ``jax.tree.leaves`` order.
    ``is_leaf`` matches the ``jax.tree`` parameter (e.g. to treat the
    serving stack's quantized-table dicts as single leaves)."""
    flat = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)[0]
    def keystr(k):
        for attr in ("key", "idx", "name"):
            if hasattr(k, attr):
                return str(getattr(k, attr))
        return str(k)
    return ["/".join(keystr(k) for k in path) for path, _ in flat]


def state_structs(optimizer: Optimizer, params_like):
    """Optimizer-state ShapeDtypeStructs without materialising the state.

    One per-param entry, in ``jax.tree.leaves`` order.  This is what the
    FSDP planner consults to pick a scatter dim each state leaf can be
    sliced along (row-wise Adagrad's ``(rows, 1)`` accumulator admits dim
    0 only; Adafactor's factored stats admit none) — keeping "what shape
    is the state" knowledge here rather than in the train loop.
    """
    return jax.eval_shape(optimizer.init, params_like)


def partitioned(rules, default: Optimizer):
    """Per-leaf optimizer dispatch by path predicate.

    ``rules``: [(predicate(path) -> bool, Optimizer)]; first match wins,
    ``default`` otherwise.  E.g. row-wise Adagrad on ``.*table.*`` leaves
    (embedding tables), AMSGrad elsewhere — the paper's configuration.
    """
    def pick(path):
        for pred, opt in rules:
            if pred(path):
                return opt
        return default

    class _Partitioned(Optimizer):
        def __init__(self):
            super().__init__(init_leaf=None, update_leaf=None)

        def init(self, params):
            paths = leaf_paths(params)
            return [pick(path).init_leaf(p)
                    for path, p in zip(paths, jax.tree.leaves(params))]

        def update(self, grads, state, params, step):
            paths = leaf_paths(params)
            leaves_g, treedef = jax.tree.flatten(grads)
            leaves_p = jax.tree.leaves(params)
            out = [pick(path).update_leaf(g, s, p, step)
                   for path, g, s, p in zip(paths, leaves_g, state, leaves_p)]
            new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
            return new_params, [o[1] for o in out]

    return _Partitioned()
