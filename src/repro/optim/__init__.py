"""Subsystem package."""
