"""Microbatched recsys inference engine over quantized compositional tables.

The LM path serves token waves (``serve.engine``); recommendation traffic
is different: each request is *one* scoring call carrying 13 dense floats
plus a variable-length multi-hot id bag per categorical feature.  The
engine:

* **queues** requests and drains them FIFO in microbatches of up to
  ``max_batch``;
* **pads + buckets** every microbatch to a fixed shape — batch and bag
  length each round up to a power of two — so the number of distinct
  compiled programs is ``O(log(max_batch) · log(max_bag))``: one jit per
  ``(B, L)`` bucket, never one per request shape.  Padded bag slots carry
  ``mask = 0`` (``bag_pool`` conventions: they contribute exactly nothing)
  and padded batch rows are sliced off before scores are assigned;
* runs the **quantized forward** (int8/bf16 tables via
  ``serve.quantize``; the fused dequant kernel when ``cfg.use_kernel``)
  with params placed under ``dist.INFERENCE_OVERRIDES`` when a mesh is
  given — read-only weights keep tensor-parallel placements only, no FSDP
  gather per step;
* optionally serves hot rows from a **host-side cache**
  (``serve.cache.HotRowCache``): the embed stage resolves each
  ``(table, quotient, remainder)`` pair against the cache, computes only
  the misses (dequantizing just those rows), pools on the host, and ships
  the pooled features to the jitted dense stage
  (``*_forward_from_features``);
* tracks per-wave wall time → **p50/p99 latency and QPS** via
  ``metrics()``.

Deterministic given (params, request stream): no sampling, logical-clock
cache, fixed bucket grid.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import CompositionalEmbedding, HashEmbedding
from ..models.dcn import DCNConfig, dcn_forward_from_features
from ..models.dlrm import (DLRMConfig, dlrm_forward_from_features,
                           embed_features, tables_for)
from .cache import HotRowCache

__all__ = ["RecRequest", "RecsysEngine"]


@dataclasses.dataclass
class RecRequest:
    uid: int
    dense: np.ndarray              # (dense_dim,)
    bags: list[list[int]]          # one multi-hot id bag per categorical
    score: Optional[float] = None
    done: bool = False


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def _dense_stage_for(cfg):
    if isinstance(cfg, DLRMConfig):
        return dlrm_forward_from_features
    if isinstance(cfg, DCNConfig):
        return dcn_forward_from_features
    raise TypeError(f"no recsys serving path for config {type(cfg).__name__}")


class RecsysEngine:
    def __init__(self, cfg, params, *, max_batch: int = 32,
                 cache: Optional[HotRowCache] = None, mesh=None):
        self.cfg = cfg
        self.modules = tables_for(cfg)
        if cfg.embedding.kind == "feature":
            raise NotImplementedError(
                "feature-generation mode has no serving path (F varies)")
        self.cache = cache
        self.max_batch = max_batch
        if mesh is not None:
            # inference placement: same rules minus FSDP (read-only weights)
            from ..dist.sharding import INFERENCE_OVERRIDES, tree_shardings
            params = jax.device_put(
                params, tree_shardings(params, mesh, INFERENCE_OVERRIDES))
        self.params = params
        dense_stage = _dense_stage_for(cfg)

        def full_fwd(params, dense, idx, mask):
            feats = embed_features(params["tables"], idx, cfg, mask=mask,
                                   proj=params.get("proj"))
            return dense_stage(params, dense, feats, cfg)

        self._full_fwd = jax.jit(full_fwd)
        self._dense_fwd = jax.jit(
            lambda params, dense, feats: dense_stage(params, dense, feats, cfg))
        self._queue: deque[RecRequest] = deque()
        self._next_uid = 0
        self.completed: dict[int, RecRequest] = {}
        self.wave_latencies_s: list[float] = []
        self.wave_sizes: list[int] = []
        self.buckets_seen: set[tuple[int, int]] = set()
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None

    # ------------------------------------------------------------- intake

    def submit(self, dense, bags: Sequence[Sequence[int]]) -> int:
        """Queue one request.  Bags may be empty (legal in Criteo-style
        traffic: a user with no history for that feature) — an empty bag
        pools to the exact zero vector (its mask row is all zero, and the
        ``bag_pool`` / cache paths both honor that)."""
        if len(bags) != len(self.modules):
            raise ValueError(f"expected {len(self.modules)} feature bags, "
                             f"got {len(bags)}")
        uid = self._next_uid
        self._next_uid += 1
        self._queue.append(RecRequest(
            uid, np.asarray(dense, np.float32), [list(b) for b in bags]))
        return uid

    # ------------------------------------------------------------- batching

    def _pad_wave(self, wave: list[RecRequest]):
        """(dense (Bb, 13), idx (Bb, F, Lb) int32, mask (Bb, F, Lb) f32).

        ``Lb`` is at least 1 even for an all-empty wave (every bag empty):
        the padded slots carry mask 0, so they pool to zero vectors."""
        f = len(self.modules)
        lb = _next_pow2(max((len(b) for r in wave for b in r.bags),
                            default=1) or 1)
        bb = min(_next_pow2(len(wave)), self.max_batch)
        dense = np.zeros((bb, wave[0].dense.shape[0]), np.float32)
        idx = np.zeros((bb, f, lb), np.int32)
        mask = np.zeros((bb, f, lb), np.float32)
        for b, r in enumerate(wave):
            dense[b] = r.dense
            for i, bag in enumerate(r.bags):
                idx[b, i, :len(bag)] = bag
                mask[b, i, :len(bag)] = 1.0
        self.buckets_seen.add((bb, lb))
        return dense, idx, mask

    # ------------------------------------------------------------- cache path

    def _row_key(self, feature: int, gid: int):
        """(table, quotient, remainder) cache key for one raw id,
        canonicalized through the module's own bucketing so ids that share
        an embedding row share a cache entry (hash tables fold mod m)."""
        mod = self.modules[feature]
        if isinstance(mod, CompositionalEmbedding) and len(mod.partitions) == 2:
            m = mod.partitions[0].num_buckets
            return (feature, gid // m, gid % m)
        if isinstance(mod, HashEmbedding):
            return (feature, 0, gid % mod.m)
        return (feature, 0, gid)

    def _embed_cached(self, idx: np.ndarray, mask: np.ndarray) -> np.ndarray:
        """Pooled features (Bb, F, D) via the hot-row cache.

        Cached unit: the *combined* (post-op, dequantized) f32 row per
        (table, quotient, remainder), at the table's **own width** —
        mixed-dimension plans cache narrow rows narrow, and the pooled
        bag is projected into the interaction width afterwards (pooling
        and projection are both linear, so pool-then-project matches the
        jitted in-graph path).  An empty bag has no live slots and stays
        the zero vector.  Misses are computed in one gather per feature
        over the unique missing ids and admitted.
        """
        bb, f, lb = idx.shape
        d = self.cfg.emb_dim
        proj = self.params.get("proj") if isinstance(self.params, dict) \
            else None
        feats = np.zeros((bb, f, d), np.float32)
        for i, mod in enumerate(self.modules):
            di = mod.out_dim
            pooled = np.zeros((bb, di), np.float32)
            live = np.argwhere(mask[:, i, :] > 0)
            gids = [int(idx[b, i, l]) for b, l in live]
            keys = [self._row_key(i, g) for g in gids]
            found, missing = self.cache.get_many(keys)
            if missing:
                miss_set = set(missing)
                miss_gids = sorted({g for g, k in zip(gids, keys)
                                    if k in miss_set})
                # pad the fill-gather to a power of two: the number of
                # distinct compiled gather shapes stays O(log max_batch)
                # instead of one per unique miss count
                padded = miss_gids + [miss_gids[-1]] * \
                    (_next_pow2(len(miss_gids)) - len(miss_gids))
                rows = np.asarray(mod.apply(
                    self.params["tables"][i],
                    jnp.asarray(padded, jnp.int32)), np.float32)
                for g, row in zip(miss_gids, rows):
                    found[self._row_key(i, g)] = row
                    self.cache.put(self._row_key(i, g), row)
            for (b, l), key in zip(live, keys):
                pooled[b] += mask[b, i, l] * found[key]
            w = None if proj is None else proj.get(str(i))
            feats[:, i, :] = pooled if w is None \
                else pooled @ np.asarray(w, np.float32)
        return feats

    # ------------------------------------------------------------- execution

    def step(self) -> list[RecRequest]:
        """Score one microbatch; returns the finished requests."""
        wave = [self._queue.popleft()
                for _ in range(min(self.max_batch, len(self._queue)))]
        if not wave:
            return []
        dense, idx, mask = self._pad_wave(wave)
        t0 = time.monotonic()
        if self.cache is not None:
            feats = self._embed_cached(idx, mask)
            logits = self._dense_fwd(self.params, jnp.asarray(dense),
                                     jnp.asarray(feats))
        else:
            logits = self._full_fwd(self.params, jnp.asarray(dense),
                                    jnp.asarray(idx), jnp.asarray(mask))
        logits = np.asarray(jax.block_until_ready(logits), np.float32)
        t1 = time.monotonic()
        self._t_first = t0 if self._t_first is None else self._t_first
        self._t_last = t1
        self.wave_latencies_s.append(t1 - t0)
        self.wave_sizes.append(len(wave))
        for b, r in enumerate(wave):  # padded rows beyond len(wave) discarded
            r.score = float(logits[b])
            r.done = True
            self.completed[r.uid] = r
        return wave

    def run_until_drained(self) -> dict[int, RecRequest]:
        while self._queue:
            self.step()
        return self.completed

    # ------------------------------------------------------------- metrics

    def reset_metrics(self) -> None:
        """Drop timing history (benches call this after bucket warm-up so
        p50/p99 measure steady-state serving, not jit compilation)."""
        self.wave_latencies_s = []
        self.wave_sizes = []
        self._t_first = self._t_last = None

    def metrics(self) -> dict:
        lat = np.asarray(self.wave_latencies_s or [0.0])
        wall = ((self._t_last - self._t_first)
                if self._t_first is not None else 0.0)
        out = {
            "requests": int(sum(self.wave_sizes)),
            "waves": len(self.wave_sizes),
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "qps": (sum(self.wave_sizes) / wall) if wall > 0 else 0.0,
            "buckets": sorted(self.buckets_seen),
        }
        if self.cache is not None:
            out["cache"] = self.cache.stats.as_dict()
        return out
