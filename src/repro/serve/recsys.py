"""Continuous-batching recsys inference engine over quantized tables.

The LM path serves token waves (``serve.engine``); recommendation traffic
is different: each request is *one* scoring call carrying 13 dense floats
plus a variable-length multi-hot id bag per categorical feature.  The
engine:

* **queues** requests and forms waves by **continuous batching**
  (``batching="continuous"``, the default): the head request anchors the
  wave's bag-length bucket and up to ``max_batch`` same-bucket requests
  from a bounded lookahead window ride along, so one long-bag request no
  longer drags every short request into its padded shape.  The head always
  ships in the next wave — no starvation.  ``batching="waves"`` keeps the
  legacy lock-step FIFO slices (and their exact wave/bucket accounting,
  which the padding tests pin);
* **pads + buckets** every wave to a fixed shape — batch and bag length
  each round up to a power of two — so the number of distinct compiled
  programs is ``O(log(max_batch) · log(max_bag))``.  Padded bag slots
  carry ``mask = 0`` (``bag_pool`` conventions: they contribute exactly
  nothing) and padded batch rows are sliced off before scores land;
* **pipelines** waves: up to ``max_inflight`` dispatched programs ride
  JAX's async dispatch before the engine blocks on the oldest, so host
  wave-formation overlaps device execution (continuous mode only —
  legacy mode reaps synchronously);
* runs the **quantized forward** (int8/bf16 tables via ``serve.quantize``;
  the fused serve kernel when ``cfg.use_kernel``) split into an embed
  stage and a dense stage — both cache paths and the cache-off path feed
  the *same* jitted dense executable, which is what makes cache-on/off
  scores bit-comparable;
* serves hot rows from the **hot-row cache** when given: a
  ``DeviceHotRowCache`` keeps combined dequantized rows resident in
  device slabs — the hit path is one packed ``np.unique`` on the host,
  one slot-array build, and a single jitted gather→pool→project program;
  only *miss* rows are ever computed from the tables.  A host
  ``HotRowCache`` still works (rows pooled on host, compat path);
* tracks per-wave dispatch→ready wall time → **p50/p99 latency and QPS**
  via ``metrics()``.

Deterministic given (params, request stream): no sampling, logical-clock
cache, fixed bucket grid, sorted unique keys.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import CompositionalEmbedding, HashEmbedding
from ..core.compositional import is_quantized_table
from ..models.dcn import DCNConfig, dcn_forward_from_features
from ..models.dlrm import (DLRMConfig, dlrm_forward_from_features,
                           embed_features, tables_for)
from .cache import CacheStats, DeviceHotRowCache, HotRowCache

__all__ = ["RecRequest", "RecsysEngine", "BATCHING_MODES"]

BATCHING_MODES = ("continuous", "waves")

_FEATURE_SHIFT = 44  # packed key: (feature << 44) | canonical row id
# ceiling on the device slot map (int32 per cacheable row, 64 MiB):
# configs whose total canonical id space exceeds it skip the in-graph
# probe and use the exact host-side lookup instead
_SLOT_MAP_ROWS_MAX = 1 << 24


@dataclasses.dataclass
class RecRequest:
    uid: int
    dense: np.ndarray              # (dense_dim,)
    bags: list[list[int]]          # one multi-hot id bag per categorical
    score: Optional[float] = None
    done: bool = False
    t_submit: Optional[float] = None   # monotonic enqueue time (obs-on only)


# stage names: the five partition stages tile the measured wave-latency
# interval [dispatch t0, reap t1] with contiguous boundary timestamps, so
# their sum equals the recorded latency by construction (the serve_bench
# obs lane asserts it within 10%); queue_wait and pad happen before t0
# and ride along as extra, non-partition stages
STAGE_PARTITION = ("probe", "dense", "inflight", "miss_gather", "flush")
STAGES = ("queue_wait", "pad") + STAGE_PARTITION


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


def _dense_stage_for(cfg):
    if isinstance(cfg, DLRMConfig):
        return dlrm_forward_from_features
    if isinstance(cfg, DCNConfig):
        return dcn_forward_from_features
    raise TypeError(f"no recsys serving path for config {type(cfg).__name__}")


def _row_dtype(tp):
    """Dtype of the combined row ``module.apply`` yields for this table's
    params: f32 once any side is row-quantized (dequant widens), else the
    stored table dtype — the slab forward casts its f32 pooled bag back to
    this, mirroring ``bag_pool``."""
    sub = tp.get("table", tp.get("table_0"))
    return jnp.float32 if is_quantized_table(sub) else sub.dtype


class RecsysEngine:
    def __init__(self, cfg, params, *, max_batch: int = 32,
                 cache: Optional[HotRowCache] = None, mesh=None,
                 batching: str = "continuous", max_inflight: int = 2,
                 lookahead: Optional[int] = None,
                 mesh_devices: Optional[int] = None, placement=None,
                 plan=None, obs=None):
        if batching not in BATCHING_MODES:
            raise ValueError(f"batching={batching!r} not in {BATCHING_MODES}")
        self.cfg = cfg
        self.modules = tables_for(cfg)
        if cfg.embedding.kind == "feature":
            raise NotImplementedError(
                "feature-generation mode has no serving path (F varies)")
        self.cache = cache
        self.max_batch = max_batch
        self.batching = batching
        self.max_inflight = max_inflight
        self.lookahead = lookahead or 4 * max_batch
        self._n_shards = int(mesh_devices or 1)
        if self._n_shards > 1:
            if getattr(cfg, "use_kernel", False):
                raise NotImplementedError(
                    "sharded serving uses the jnp embed path, not the fused "
                    "kernel — build the config with use_kernel=False")
            if cache is not None and not isinstance(cache,
                                                    DeviceHotRowCache):
                raise NotImplementedError(
                    "sharded serving supports DeviceHotRowCache only (host "
                    "cache rows are not locally resident on a mesh)")
            if max_batch % self._n_shards or max_batch < self._n_shards:
                raise ValueError(
                    f"max_batch={max_batch} must be a positive multiple of "
                    f"mesh_devices={self._n_shards}")
            params = self._init_sharded(params, placement, plan)
        elif mesh is not None:
            # inference placement: same rules minus FSDP (read-only weights)
            from ..dist.sharding import INFERENCE_OVERRIDES, tree_shardings
            params = jax.device_put(
                params, tree_shardings(params, mesh, INFERENCE_OVERRIDES))
        self._install_model(cfg, params)
        self._sharded_embed = self._sharded_dense = self._sharded_fast = None
        if self._n_shards > 1:
            self._smap_mirror = self._slab_mirror = None
            self._mirror_version = None
            self._build_sharded(self._dense_stage, self._space_arr,
                                self._off_arr, self._w_index,
                                self._feat_width, self._row_dtypes)
        self._queue: deque[RecRequest] = deque()
        self._inflight: deque[tuple] = deque()
        self._next_uid = 0
        self.completed: dict[int, RecRequest] = {}
        self.wave_latencies_s: list[float] = []
        self.wave_sizes: list[int] = []
        self.buckets_seen: set[tuple[int, int]] = set()
        self._t_first: Optional[float] = None
        self._t_last: Optional[float] = None

        # observability: everything below is skipped when obs is None
        # (the off-by-default contract — obs-off waves take zero extra
        # clock reads and zero registry work, which is how the obs-on
        # lane's 2% QPS budget stays honest as a comparison)
        self._obs = obs
        if obs is not None:
            obs.attach_collisions(cfg.table_sizes)
            # label handles bound once: the hot path never hashes a dict
            hs = obs.histogram("serve_stage_seconds",
                               "per-wave stage durations (see STAGES)")
            self._h_stage = {s: hs.labels(stage=s) for s in STAGES}
            self._h_wave = obs.histogram(
                "serve_wave_latency_seconds",
                "dispatch->ready wall time per wave").labels()
            self._c_req = obs.counter(
                "serve_requests_total", "requests scored").labels()
            self._c_waves = obs.counter(
                "serve_waves_total", "waves dispatched").labels()
            self._c_wire = obs.counter(
                "serve_wire_bytes_total",
                "serve-exchange bytes moved between devices").labels(
                    collective="serve_exchange") \
                if self._n_shards > 1 else None
            self._wire_by_bucket: dict[tuple[int, int], int] = {}

    # ------------------------------------------------------------- model

    def _install_model(self, cfg, params) -> None:
        """Bind (cfg, params) and (re)build every program derived from
        them: embed/dense jits, the slab forward, the flat canonical-id
        layout, and the in-graph slot-map probe.  Called once from
        ``__init__`` and again by ``swap_plan`` — everything that depends
        on the plan's table structures lives here so a swap replaces it
        atomically (between waves; in-flight waves closed over the old
        programs and drain unaffected)."""
        self.cfg = cfg
        self.modules = tables_for(cfg)
        self.params = params
        dense_stage = _dense_stage_for(cfg)
        self._dense_stage = dense_stage

        def embed_fwd(params, idx, mask):
            feats = embed_features(params["tables"], idx, cfg, mask=mask,
                                   proj=params.get("proj"))
            return jnp.stack(feats, axis=1)

        # embed and dense stages jit separately: every path (cache off,
        # host cache, device cache) funnels its (B, F, D) features through
        # the *same* dense executable, so cache choice cannot perturb the
        # dense math
        self._embed_fwd = jax.jit(embed_fwd)
        self._dense_fwd = jax.jit(
            lambda params, dense, feats: dense_stage(params, dense, feats, cfg))

        # device-slab forward: one program per (slot-shape, slab-shape)
        # bucket — gather each feature's rows from its width's slab,
        # mask-pool in f32 (bag_pool convention), project mixed-dim
        # features into the interaction width
        widths = tuple(sorted({mod.out_dim for mod in self.modules}))
        w_index = {d: wi for wi, d in enumerate(widths)}
        feat_width = tuple(mod.out_dim for mod in self.modules)
        row_dtypes = tuple(_row_dtype(tp) for tp in params["tables"]) \
            if isinstance(params, dict) else ()
        self._widths = widths
        self._w_index = w_index
        self._feat_width = feat_width
        self._row_dtypes = row_dtypes

        def slab_fwd(proj, slabs, slots, mask):
            feats = []
            for i in range(len(feat_width)):
                rows = jnp.take(slabs[w_index[feat_width[i]]],
                                slots[:, i, :], axis=0)      # (B, L, d_i)
                pooled = (rows * mask[:, i, :, None].astype(jnp.float32)
                          ).sum(axis=1).astype(row_dtypes[i])
                w = proj.get(str(i))
                feats.append(pooled if w is None else pooled @ w)
            return jnp.stack(feats, axis=1)

        self._slab_fwd = jax.jit(slab_fwd)

        # flat canonical-id layout for the device slot map: feature i's
        # canonical rows occupy [offset_i, offset_i + space_i), so one
        # int32 device array maps every cacheable row to its slab slot
        # (-1 = not resident) and the hit path probes it in-graph
        spaces = [mod.m if isinstance(mod, HashEmbedding) else size
                  for mod, size in zip(self.modules, cfg.table_sizes)]
        self._flat_offsets = np.concatenate(
            [[0], np.cumsum(spaces)[:-1]]).astype(np.int64)
        self._flat_total = int(sum(spaces))
        self._slot_map = None
        self._map_version = None

        # canonicalization is part of the probe program: hash features
        # fold mod m, QR/full ids are already < their space so the same
        # modulus is a no-op for them (everything stays int32)
        space_arr = jnp.asarray(spaces, jnp.int32)
        off_arr = jnp.asarray(self._flat_offsets, jnp.int32)
        self._space_arr = space_arr
        self._off_arr = off_arr

        def fast_fwd(smap, idx, mask, proj, slabs):
            flat = idx % space_arr[None, :, None] + off_arr[None, :, None]
            slots = jnp.take(smap, flat, axis=0)
            nmiss = jnp.sum((slots < 0) & (mask > 0))
            return slab_fwd(proj, slabs, slots, mask), nmiss

        # probe + gather + pool + project in ONE program: the fast path
        # costs the same number of dispatches as the in-graph embed
        self._fast_fwd = jax.jit(fast_fwd)

    def swap_plan(self, cfg, params, *, warm: bool = True) -> dict:
        """Hot-swap to a new plan's (cfg, params) without downtime.

        The zero-downtime contract, in dispatch order:

        1. **drain** — in-flight waves hold references to the old params,
           programs, and slabs, so they settle on the old plan (their
           scores are exactly what the old plan would have served);
        2. **invalidate** — every cached row is a *combined* row of the
           old structure, so the whole residency is dropped as
           invalidations (never evictions — capacity was not the cause;
           the cache property tests pin this), device slabs are released
           (widths may change), and ``residency_version`` moves so any
           slot-map consumer rebuilds;
        3. **install** — ``_install_model`` rebinds cfg/params and
           rebuilds every derived program and the flat id layout;
        4. **pre-warm** (``warm=True``) — every (batch, bag) bucket this
           engine has served is compiled against the new plan *now*,
           off the wave path, so post-swap p99 pays no XLA compiles.
           Warm traffic touches the cache (admitting each feature's row
           0) but never the obs telemetry — synthetic ids must not feed
           the drift detector.

        Single-host only (a sharded swap would need placement re-solve +
        resharding — see ROADMAP); the queue, uid space, metrics history,
        and completed map all survive the swap untouched.
        """
        if self._n_shards > 1:
            raise NotImplementedError(
                "swap_plan is single-host only: a sharded swap must also "
                "re-solve placement and reshard the tables")
        if cfg.embedding.kind == "feature":
            raise NotImplementedError(
                "feature-generation mode has no serving path (F varies)")
        if tuple(cfg.table_sizes) != tuple(self.cfg.table_sizes):
            raise ValueError("swap_plan keeps the feature set: table_sizes "
                             "must match the running config")
        while self._inflight:            # 1. drain on the old plan
            self._reap()
        dropped = 0
        if self.cache is not None:       # 2. stale residency out
            dropped = self.cache.invalidate_all()
        self._install_model(cfg, params)  # 3. new programs in
        if warm:                         # 4. compile before traffic lands
            self._warm_buckets()
        return {"invalidated_rows": dropped,
                "buckets_warmed": sorted(self.buckets_seen) if warm else [],
                "residency_version": getattr(self.cache,
                                             "residency_version", None)}

    def _warm_buckets(self) -> None:
        """Run one dummy wave per previously-seen (batch, bag) bucket
        through the same path selection as ``_dispatch`` — compiling the
        new plan's fast-probe, miss-gather, slab, and dense programs for
        every shape steady-state traffic will use.  Runs outside the
        wave/metrics/obs bookkeeping: latency histograms and collision
        telemetry never see these synthetic waves."""
        f = len(self.modules)
        dense_dim = getattr(self.cfg, "dense_dim", 13)
        for bb, lb in sorted(self.buckets_seen):
            dense = np.zeros((bb, dense_dim), np.float32)
            idx = np.zeros((bb, f, lb), np.int32)
            mask = np.zeros((bb, f, lb), np.float32)
            mask[:, :, 0] = 1.0  # one live slot: exercises the miss path
            if isinstance(self.cache, DeviceHotRowCache) \
                    and not self.cache.record_events:
                fast = self._embed_device_fast(idx, mask)
                feats = None
                if fast is not None:
                    feats, nmiss = fast
                    if int(nmiss):
                        feats = self._embed_device(idx, mask)
                if feats is None:
                    feats = self._embed_device(idx, mask)
            elif self.cache is not None:
                feats = jnp.asarray(self._embed_cached(idx, mask))
            else:
                feats = self._embed_fwd(self.params, jnp.asarray(idx),
                                        jnp.asarray(mask))
            jax.block_until_ready(
                self._dense_fwd(self.params, jnp.asarray(dense), feats))

    # ------------------------------------------------------------- sharding

    def _init_sharded(self, params, placement, plan):
        """Place the tables across a 1-D ``("data",)`` serve mesh per the
        plan-aware placement (``dist.serve_placement``): sub-tables below
        the replication threshold live on every device, big ones are
        row-sharded by quotient partition.  Returns the placed params."""
        from ..dist.serve_placement import place_params, plan_placement
        n = self._n_shards
        if jax.device_count() < n:
            raise ValueError(
                f"mesh_devices={n} but only {jax.device_count()} devices "
                "visible (CI emulates via --xla_force_host_platform_"
                "device_count)")
        self._serve_mesh = jax.make_mesh((n,), ("data",))
        if placement is None:
            placement = plan_placement(params, n, plan=plan)
        if placement.n_devices != n:
            raise ValueError(f"placement built for {placement.n_devices} "
                             f"devices, engine asked for {n}")
        self.placement = placement
        placed, self._param_specs = place_params(params, placement,
                                                 self._serve_mesh)
        # only fully-replicated features are cacheable: a row-sharded
        # feature's rows are not locally resident on every device, so the
        # device hot-row cache never admits them
        self._repl_live = placement.replicated_features(len(self.modules))
        return placed

    def _build_sharded(self, dense_stage, space_arr, off_arr, w_index,
                       feat_width, row_dtypes):
        """Sharded analogues of the single-host programs, same program
        boundaries (embed | dense | fast-probe) so each per-device
        computation is the *same XLA program* as its single-host
        counterpart at the per-device batch — that is what makes
        sharded-vs-single-host logits bit-identical (the serve_dist bench
        and tests assert it).  Row-sharded sub-tables fetch rows through
        the two-phase all-to-all exchange (``dist.serve_placement.
        exchange_rows``); everything else is local."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from ..core.compositional import bag_pool, table_rows
        from ..dist.serve_placement import exchange_rows
        from ..models.dlrm import _project, embed_features
        cfg, n = self.cfg, self._n_shards
        rpd = {(e.feature, e.table_key): self.placement.rows_per_device(e)
               for e in self.placement.sharded}
        repl = tuple(bool(x) for x in self._repl_live)

        def gather_for(i):
            if repl[i]:
                return None  # fully local feature: plain bag_pool gather

            def g(leaf, ids, key):
                r = rpd.get((i, key))
                if r is None:  # replicated sub-table of a sharded feature
                    return table_rows(leaf, ids)
                return exchange_rows(leaf, ids, n, r, axis="data")
            return g

        gathers = [gather_for(i) for i in range(len(self.modules))]

        def embed_sh(params, idx, mask):
            feats = embed_features(params["tables"], idx, cfg, mask=mask,
                                   proj=params.get("proj"), gathers=gathers)
            return jnp.stack(feats, axis=1)

        def dense_sh(params, dense, feats):
            return dense_stage(params, dense, feats, cfg)

        def fast_sh(params, idx, mask, smap, slabs):
            # replicated features ride the slot-map probe exactly as the
            # single-host fast path; sharded features always go to their
            # tables (they are never cached); the miss count only sees
            # cacheable slots and is psum'd so every device agrees
            flat = idx % space_arr[None, :, None] + off_arr[None, :, None]
            slots = jnp.take(smap, flat, axis=0)
            proj = params.get("proj")
            feats, nmiss = [], jnp.int32(0)
            for i in range(len(self.modules)):
                if repl[i]:
                    rows = jnp.take(slabs[w_index[feat_width[i]]],
                                    slots[:, i, :], axis=0)
                    pooled = (rows * mask[:, i, :, None]
                              .astype(jnp.float32)).sum(axis=1) \
                        .astype(row_dtypes[i])
                    feats.append(_project(pooled, proj, i))
                    nmiss = nmiss + jnp.sum((slots[:, i, :] < 0)
                                            & (mask[:, i, :] > 0))
                else:
                    pooled = bag_pool(self.modules[i], params["tables"][i],
                                      idx[:, i, :], mask[:, i, :],
                                      gather=gathers[i])
                    feats.append(_project(pooled, proj, i))
            return jnp.stack(feats, axis=1), jax.lax.psum(nmiss, "data")

        mesh, specs = self._serve_mesh, self._param_specs
        self._sharded_embed = jax.jit(shard_map(
            embed_sh, mesh=mesh,
            in_specs=(specs, P("data"), P("data")), out_specs=P("data")))
        self._sharded_dense = jax.jit(shard_map(
            dense_sh, mesh=mesh,
            in_specs=(specs, P("data"), P("data")), out_specs=P("data")))
        self._sharded_fast = jax.jit(shard_map(
            fast_sh, mesh=mesh,
            in_specs=(specs, P("data"), P("data"), P(), P()),
            out_specs=(P("data"), P())))

    def _sharded_cache_state(self):
        """Slot map + slabs mirrored to every mesh device (replicated
        NamedSharding), refreshed only when cache residency changes.  The
        mirror is a copy: admission's donated scatter consumes the
        cache's own slab buffer, never the mirror the in-flight waves
        read."""
        ver = self.cache.residency_version
        if self._mirror_version != ver:
            from jax.sharding import NamedSharding
            from jax.sharding import PartitionSpec as P
            rep = NamedSharding(self._serve_mesh, P())
            self._smap_mirror = jax.device_put(self._sync_slot_map(), rep)
            self._slab_mirror = tuple(
                jax.device_put(self.cache.slab(d), rep)
                for d in self._widths)
            self._mirror_version = ver
        return self._smap_mirror, self._slab_mirror

    def _admit_cacheable(self, idx: np.ndarray, mask: np.ndarray) -> None:
        """Sharded-mode admission half of ``_embed_device``: look up and
        admit this wave's *cacheable* (replicated-feature) rows with full
        per-key accounting, computing only the miss rows.  Features are
        not produced — the caller recomputes the wave through the pure
        sharded programs."""
        cache = self.cache
        f = idx.shape[1]
        live = (mask > 0) & np.asarray(self._repl_live)[None, :, None]
        canon = self._canonical(idx)
        packed = canon + (np.arange(f, dtype=np.int64)[None, :, None]
                          << _FEATURE_SHIFT)
        keys_live = packed[live]
        if not keys_live.size:
            return
        uniq, counts = np.unique(keys_live, return_counts=True)
        key_list = uniq.tolist()
        _, miss_u = cache.lookup_many(key_list, counts)
        if miss_u.any():
            rows = self._compute_miss_rows(uniq[miss_u])
            cache.put_many(uniq[miss_u].tolist(), rows, pinned=key_list)

    def _dispatch_sharded(self, dense, idx, mask):
        """Dispatch one wave through the sharded programs; returns
        ``(logits, check, ta)`` with the same speculative-probe contract
        as the single-host device-cache path (``ta`` is the probe/dense
        stage boundary timestamp, None when obs is off)."""
        check = None
        if (isinstance(self.cache, DeviceHotRowCache)
                and not self.cache.record_events
                and self._flat_total <= _SLOT_MAP_ROWS_MAX
                and bool(np.asarray(self._repl_live).any())):
            smap, slabs = self._sharded_cache_state()
            feats, nmiss = self._sharded_fast(
                self.params, jnp.asarray(np.asarray(idx, np.int32)),
                jnp.asarray(mask), smap, slabs)
            check = (dense, idx, mask, nmiss)
        else:
            if self.cache is not None:
                self._admit_cacheable(idx, mask)
            feats = self._sharded_embed(self.params, jnp.asarray(idx),
                                        jnp.asarray(mask))
        ta = time.monotonic() if self._obs is not None else None
        logits = self._sharded_dense(self.params, jnp.asarray(dense), feats)
        return logits, check, ta

    # ------------------------------------------------------------- intake

    def submit(self, dense, bags: Sequence[Sequence[int]]) -> int:
        """Queue one request.  Bags may be empty (legal in Criteo-style
        traffic: a user with no history for that feature) — an empty bag
        pools to the exact zero vector (its mask row is all zero, and the
        ``bag_pool`` / cache paths both honor that)."""
        if len(bags) != len(self.modules):
            raise ValueError(f"expected {len(self.modules)} feature bags, "
                             f"got {len(bags)}")
        uid = self._next_uid
        self._next_uid += 1
        self._queue.append(RecRequest(
            uid, np.asarray(dense, np.float32), [list(b) for b in bags],
            t_submit=(time.monotonic() if self._obs is not None else None)))
        return uid

    # ------------------------------------------------------------- batching

    @staticmethod
    def _bucket(r: RecRequest) -> int:
        return _next_pow2(max((len(b) for b in r.bags), default=1) or 1)

    def _form_wave(self) -> list[RecRequest]:
        """Next wave off the queue.

        Legacy mode: strict FIFO slice of up to ``max_batch``.  Continuous
        mode: the head request anchors the bag-length bucket; up to
        ``max_batch`` same-bucket requests within the first ``lookahead``
        queued requests join it, everything else keeps its place — the
        head always ships, so no request starves behind a hot bucket.
        """
        q = self._queue
        if not q:
            return []
        if self.batching == "waves":
            return [q.popleft() for _ in range(min(self.max_batch, len(q)))]
        anchor = self._bucket(q[0])
        wave: list[RecRequest] = []
        skipped: list[RecRequest] = []
        scanned = 0
        while q and len(wave) < self.max_batch and scanned < self.lookahead:
            r = q.popleft()
            scanned += 1
            if self._bucket(r) == anchor:
                wave.append(r)
            else:
                skipped.append(r)
        for r in reversed(skipped):
            q.appendleft(r)
        return wave

    def _pad_wave(self, wave: list[RecRequest]):
        """(dense (Bb, 13), idx (Bb, F, Lb) int32, mask (Bb, F, Lb) f32).

        ``Lb`` is at least 1 even for an all-empty wave (every bag empty):
        the padded slots carry mask 0, so they pool to zero vectors."""
        f = len(self.modules)
        lb = _next_pow2(max((len(b) for r in wave for b in r.bags),
                            default=1) or 1)
        if self._n_shards > 1:
            # bucket the *per-device* batch: the shard_map program each
            # device runs has batch Bb/n, and parity with a single-host
            # engine holds when that per-device batch equals its bucket
            per = -(-len(wave) // self._n_shards)
            bb = min(_next_pow2(per),
                     self.max_batch // self._n_shards) * self._n_shards
        else:
            bb = min(_next_pow2(len(wave)), self.max_batch)
        dense = np.zeros((bb, wave[0].dense.shape[0]), np.float32)
        idx = np.zeros((bb, f, lb), np.int32)
        mask = np.zeros((bb, f, lb), np.float32)
        for b, r in enumerate(wave):
            dense[b] = r.dense
            for i, bag in enumerate(r.bags):
                idx[b, i, :len(bag)] = bag
                mask[b, i, :len(bag)] = 1.0
        self.buckets_seen.add((bb, lb))
        return dense, idx, mask

    # ------------------------------------------------------------- cache path

    def _row_key(self, feature: int, gid: int):
        """(table, quotient, remainder) cache key for one raw id,
        canonicalized through the module's own bucketing so ids that share
        an embedding row share a cache entry (hash tables fold mod m)."""
        mod = self.modules[feature]
        if isinstance(mod, CompositionalEmbedding) and len(mod.partitions) == 2:
            m = mod.partitions[0].num_buckets
            return (feature, gid // m, gid % m)
        if isinstance(mod, HashEmbedding):
            return (feature, 0, gid % mod.m)
        return (feature, 0, gid)

    def _canonical(self, idx: np.ndarray) -> np.ndarray:
        """Fold raw ids (Bb, F, Lb) to canonical row ids per feature:
        hash tables share rows mod m; QR/full ids are already 1:1 with
        their (quotient, remainder) row, so the id itself canonicalizes."""
        canon = np.empty(idx.shape, np.int64)
        for i, mod in enumerate(self.modules):
            col = idx[:, i, :].astype(np.int64)
            canon[:, i, :] = col % mod.m if isinstance(mod, HashEmbedding) \
                else col
        return canon

    def _compute_miss_rows(self, miss_keys: np.ndarray) -> list:
        """Combined dequantized f32 rows for packed miss keys, one padded
        gather per feature (``module.apply`` is elementwise per row, so
        these rows are bit-identical to what the in-graph embed computes)."""
        feats_of = (miss_keys >> _FEATURE_SHIFT).astype(np.int64)
        gids = (miss_keys & ((1 << _FEATURE_SHIFT) - 1)).astype(np.int64)
        rows_out: list = [None] * len(miss_keys)
        for i in np.unique(feats_of):
            sel = np.flatnonzero(feats_of == i)
            ids = gids[sel]
            # pad the fill-gather to a floored power of two: the number of
            # distinct compiled gather shapes stays O(log) instead of one
            # per count, and the floor keeps small miss waves from
            # fragmenting into many tiny shape buckets
            n_pad = max(32, _next_pow2(len(ids)))
            padded = np.concatenate(
                [ids, np.repeat(ids[-1:], n_pad - len(ids))])
            rows = self.modules[int(i)].apply(
                self.params["tables"][int(i)], jnp.asarray(padded, jnp.int32))
            rows = jnp.asarray(rows, jnp.float32)
            for j, pos in enumerate(sel):
                rows_out[int(pos)] = rows[j]
        return rows_out

    def _sync_slot_map(self):
        """Device slot map (flat canonical id -> slab slot, -1 = miss),
        rebuilt from the cache's residency only when it changed — at a
        steady hit rate this is a no-op and the hit path never touches a
        Python dict."""
        ver = self.cache.residency_version
        if self._slot_map is None or ver != self._map_version:
            smap = np.full(self._flat_total, -1, np.int32)
            keys, slots = self.cache.slot_items()
            if len(keys):
                feats = keys >> _FEATURE_SHIFT
                canon = keys & ((1 << _FEATURE_SHIFT) - 1)
                smap[self._flat_offsets[feats] + canon] = slots
            self._slot_map = jnp.asarray(smap)
            self._map_version = ver
        return self._slot_map

    def _embed_device_fast(self, idx: np.ndarray, mask: np.ndarray):
        """Speculative wave via the in-graph slot-map probe: fold ids,
        probe the map, gather/pool/project from the slabs — all
        dispatched asynchronously with **zero** per-key host work and no
        host<->device sync.  Returns ``(feats, nmiss)`` where ``nmiss``
        is a device scalar the caller checks *at reap time* (it is ready
        by then): nonzero means some row was not resident, the
        speculative features are garbage, and the wave is recomputed
        through the exact path.  Returns ``None`` when the config's id
        space is too big to map.

        Dispatch order makes speculation safe: a later admission's
        donated scatter executes after this wave's gathers, so the slabs
        this program reads are exactly the slabs that were resident when
        it was dispatched.

        The fast path batches accounting: per-wave hit totals land in
        ``stats`` but per-key LFU/LRU freshness is only refreshed by the
        exact path (miss waves and ``record_events`` runs), so eviction
        order under pressure leans on admission-time frequencies.  Runs
        that need exact per-key accounting (the replay/property tests,
        anything setting ``record_events=True``) always take the exact
        path."""
        if self._flat_total > _SLOT_MAP_ROWS_MAX:
            return None
        smap = self._sync_slot_map()
        proj = self.params.get("proj") if isinstance(self.params, dict) \
            else None
        slabs = tuple(self.cache.slab(d) for d in self._widths)
        return self._fast_fwd(smap, jnp.asarray(np.asarray(idx, np.int32)),
                              jnp.asarray(mask), proj or {}, slabs)

    def _embed_device(self, idx: np.ndarray, mask: np.ndarray):
        """Wave features via the device-resident cache: one packed
        ``np.unique`` over the wave's live (feature, row) keys, slot
        lookups host-side, miss rows computed once and admitted through a
        batched donated scatter, then a single jitted slab
        gather→pool→project.  Rows never round-trip to the host.

        This is the *exact* path: it performs full per-key accounting
        (stats, LFU/LRU freshness, event log) with host semantics
        identical to ``HotRowCache``.  ``_dispatch`` first tries the
        speculative ``_embed_device_fast`` probe and only lands here for
        miss waves, oversized id spaces, or ``record_events`` runs.

        The whole wave's keys are pinned during admission so an in-wave
        eviction can never reassign a slot the gather is about to read;
        if admission is refused anyway (cache smaller than the wave's
        working set), the wave falls back to the in-graph embed — same
        bits, no cache."""
        cache = self.cache
        bb, f, lb = idx.shape
        canon = self._canonical(idx)
        packed = canon + (np.arange(f, dtype=np.int64)[None, :, None]
                          << _FEATURE_SHIFT)
        live = mask > 0
        keys_live = packed[live]
        if keys_live.size:
            uniq, inv, counts = np.unique(
                keys_live, return_inverse=True, return_counts=True)
        else:
            uniq = np.empty(0, np.int64)
            inv = np.empty(0, np.int64)
            counts = np.empty(0, np.int64)
        key_list = uniq.tolist()
        slots_u, miss_u = cache.lookup_many(key_list, counts)
        if miss_u.any():
            miss_keys = uniq[miss_u]
            rows = self._compute_miss_rows(miss_keys)
            admitted = cache.put_many(miss_keys.tolist(), rows,
                                      pinned=key_list)
            if len(admitted) != len(miss_keys):
                # working set exceeds the pinnable capacity: serve this
                # wave in-graph (identical math; stats already counted)
                return self._embed_fwd(self.params, jnp.asarray(idx),
                                       jnp.asarray(mask))
            # hit slots survive admission (the whole wave is pinned, so
            # no hit row was evicted): only the misses need re-resolving
            slots_u[miss_u] = cache.slots_for(miss_keys.tolist())
        slots = np.zeros((bb, f, lb), np.int32)
        if key_list:
            slots[live] = slots_u[inv]
        slabs = tuple(cache.slab(d) for d in self._widths)
        proj = self.params.get("proj") if isinstance(self.params, dict) \
            else None
        return self._slab_fwd(proj or {}, slabs, jnp.asarray(slots),
                              jnp.asarray(mask))

    def _embed_cached(self, idx: np.ndarray, mask: np.ndarray) -> np.ndarray:
        """Pooled features (Bb, F, D) via the host hot-row cache.

        Cached unit: the *combined* (post-op, dequantized) f32 row per
        (table, quotient, remainder), at the table's **own width** —
        mixed-dimension plans cache narrow rows narrow, and the pooled
        bag is projected into the interaction width afterwards (pooling
        and projection are both linear, so pool-then-project matches the
        jitted in-graph path).  An empty bag has no live slots and stays
        the zero vector.  Misses are computed in one gather per feature
        over the unique missing ids and admitted.
        """
        bb, f, lb = idx.shape
        d = self.cfg.emb_dim
        proj = self.params.get("proj") if isinstance(self.params, dict) \
            else None
        feats = np.zeros((bb, f, d), np.float32)
        for i, mod in enumerate(self.modules):
            di = mod.out_dim
            pooled = np.zeros((bb, di), np.float32)
            live = np.argwhere(mask[:, i, :] > 0)
            gids = [int(idx[b, i, l]) for b, l in live]
            keys = [self._row_key(i, g) for g in gids]
            found, missing = self.cache.get_many(keys)
            if missing:
                miss_set = set(missing)
                miss_gids = sorted({g for g, k in zip(gids, keys)
                                    if k in miss_set})
                # pad the fill-gather to a floored power of two: the number
                # of distinct compiled gather shapes stays O(log max_batch)
                # instead of one per unique miss count
                padded = miss_gids + [miss_gids[-1]] * \
                    (max(32, _next_pow2(len(miss_gids))) - len(miss_gids))
                rows = np.asarray(mod.apply(
                    self.params["tables"][i],
                    jnp.asarray(padded, jnp.int32)), np.float32)
                for g, row in zip(miss_gids, rows):
                    found[self._row_key(i, g)] = row
                    self.cache.put(self._row_key(i, g), row)
            for (b, l), key in zip(live, keys):
                pooled[b] += mask[b, i, l] * found[key]
            w = None if proj is None else proj.get(str(i))
            feats[:, i, :] = pooled if w is None \
                else pooled @ np.asarray(w, np.float32)
        return feats

    # ------------------------------------------------------------- execution

    def _dispatch(self, wave: list[RecRequest]) -> None:
        obs = self._obs
        tq = time.monotonic() if obs is not None else None
        dense, idx, mask = self._pad_wave(wave)
        t0 = time.monotonic()
        if obs is not None and obs.collisions is not None:
            # raw served ids for the measured collision mass; idx/mask
            # are this wave's own buffers, so holding references is safe
            obs.collisions.record(idx, mask, live_rows=len(wave))
        check = None
        if self._n_shards > 1:
            logits, check, ta = self._dispatch_sharded(dense, idx, mask)
        else:
            if isinstance(self.cache, DeviceHotRowCache):
                fast = None if self.cache.record_events \
                    else self._embed_device_fast(idx, mask)
                if fast is not None:
                    feats, nmiss = fast
                    check = (dense, idx, mask, nmiss)
                else:
                    feats = self._embed_device(idx, mask)
            elif self.cache is not None:
                feats = jnp.asarray(self._embed_cached(idx, mask))
            else:
                feats = self._embed_fwd(self.params, jnp.asarray(idx),
                                        jnp.asarray(mask))
            ta = time.monotonic() if obs is not None else None
            logits = self._dense_fwd(self.params, jnp.asarray(dense), feats)
        self._t_first = t0 if self._t_first is None else self._t_first
        oi = None
        if obs is not None:
            tb = time.monotonic()
            waits = [tq - r.t_submit for r in wave if r.t_submit is not None]
            oi = {"tq": tq, "t0": t0, "ta": ta, "tb": tb,
                  "queue_wait": max(waits) if waits else 0.0,
                  "n": len(wave), "bb": idx.shape[0], "lb": idx.shape[2]}
            if self._c_wire is not None:
                bucket = (idx.shape[0] // self._n_shards, idx.shape[2])
                wb = self._wire_by_bucket.get(bucket)
                if wb is None:
                    from ..dist.accounting import serve_wave_wire_bytes
                    wb = int(serve_wave_wire_bytes(
                        self.placement, bucket[0],
                        bucket[1])["total_bytes"])
                    self._wire_by_bucket[bucket] = wb
                self._c_wire.inc(wb)
        self._inflight.append((wave, logits, t0, check, oi))

    def _reap(self) -> list[RecRequest]:
        wave, logits, t0, check, oi = self._inflight.popleft()
        tc = time.monotonic() if oi is not None else None
        if check is not None:
            # settle the speculative probe: by reap time the async miss
            # count has materialized, so this blocks on nothing extra
            dense, idx, mask, nmiss = check
            if int(nmiss) and self._n_shards > 1:
                # some cacheable row was not resident: admit it with exact
                # accounting, then recompute through the pure programs
                self._admit_cacheable(idx, mask)
                feats = self._sharded_embed(self.params, jnp.asarray(idx),
                                            jnp.asarray(mask))
                logits = self._sharded_dense(self.params,
                                             jnp.asarray(dense), feats)
            elif int(nmiss):
                feats = self._embed_device(idx, mask)   # exact: admit+count
                logits = self._dense_fwd(self.params, jnp.asarray(dense),
                                         feats)
            else:
                live = mask > 0
                if self._n_shards > 1:  # only cacheable slots were probed
                    live = live & np.asarray(self._repl_live)[None, :, None]
                self.cache.stats.hits += int(live.sum())
        td = time.monotonic() if oi is not None else None
        logits = np.asarray(jax.block_until_ready(logits), np.float32)
        t1 = time.monotonic()
        self._t_last = t1
        self.wave_latencies_s.append(t1 - t0)
        self.wave_sizes.append(len(wave))
        if oi is not None:
            self._record_wave(oi, tc, td, t1)
        for b, r in enumerate(wave):  # padded rows beyond len(wave) discarded
            r.score = float(logits[b])
            r.done = True
            self.completed[r.uid] = r
        return wave

    def step(self) -> list[RecRequest]:
        """Form + dispatch one wave, reap what's due; returns finished
        requests.  Legacy mode reaps synchronously (wave in, scores out);
        continuous mode lets up to ``max_inflight`` waves ride JAX async
        dispatch and only blocks on the oldest beyond that (or drains when
        the queue is empty)."""
        wave = self._form_wave()
        if wave:
            self._dispatch(wave)
        limit = 0 if self.batching == "waves" else self.max_inflight
        done: list[RecRequest] = []
        while self._inflight and (len(self._inflight) > limit
                                  or not self._queue):
            done.extend(self._reap())
        return done

    def run_until_drained(self) -> dict[int, RecRequest]:
        while self._queue or self._inflight:
            self.step()
        return self.completed

    # ------------------------------------------------------------- metrics

    def _record_wave(self, oi: dict, tc: float, td: float, t1: float) -> None:
        """Fold one reaped wave's boundary timestamps into the registry
        (and tracer).  The five partition stages tile [t0, t1] exactly:
        probe (embed/cache-probe dispatch), dense (dense dispatch),
        inflight (async pipeline gap until reap), miss_gather (settling
        the speculative probe — recompute on miss, accounting on hit),
        flush (the block_until_ready sync)."""
        obs = self._obs
        t0, ta, tb = oi["t0"], oi["ta"], oi["tb"]
        stages = (("queue_wait", oi["tq"] - oi["queue_wait"],
                   oi["queue_wait"]),
                  ("pad", oi["tq"], t0 - oi["tq"]),
                  ("probe", t0, ta - t0),
                  ("dense", ta, tb - ta),
                  ("inflight", tb, tc - tb),
                  ("miss_gather", tc, td - tc),
                  ("flush", td, t1 - td))
        for name, _, dur in stages:
            self._h_stage[name].observe(dur)
        self._h_wave.observe(t1 - t0)
        self._c_req.inc(oi["n"])
        self._c_waves.inc()
        if obs.tracer is not None:
            tr = obs.tracer
            tr.complete("wave", t0, t1 - t0, requests=oi["n"],
                        batch=oi["bb"], bag=oi["lb"])
            for name, ts, dur in stages:
                tr.complete(name, ts, dur)

    def stage_summary(self) -> dict:
        """Per-stage latency summaries plus the partition check the obs
        lane asserts: the five partition stages must sum to the recorded
        wave latency (same clock reads, contiguous boundaries)."""
        if self._obs is None:
            raise RuntimeError("stage_summary() needs an Obs-enabled engine")
        out = {s: self._h_stage[s].summary() for s in STAGES}
        stage_sum = sum(out[s]["sum"] for s in STAGE_PARTITION)
        lat = self._h_wave.summary()
        out["partition"] = {
            "stage_sum_s": stage_sum,
            "latency_sum_s": lat["sum"],
            "ratio": (stage_sum / lat["sum"]) if lat["sum"] else 1.0,
        }
        return out

    def reset_metrics(self) -> None:
        """Drop timing history AND traffic counters (benches call this
        after bucket warm-up so p50/p99 — and cache hit rates — measure
        steady-state serving, not jit compilation or cold fills).  Cache
        residency (``bytes_cached``) survives: the rows are still
        resident, only the traffic counters restart.  Obs serve_* series
        reset too; bound label handles stay live."""
        self.wave_latencies_s = []
        self.wave_sizes = []
        self._t_first = self._t_last = None
        if self.cache is not None:
            self.cache.stats = CacheStats(
                bytes_cached=self.cache.stats.bytes_cached)
        if self._obs is not None:
            self._obs.registry.reset(prefix="serve_")

    def compile_count(self) -> dict:
        """Per-program jit compile counts — the pow2-bucket bound made
        introspectable.  Reads each wrapper's compile cache (no timing, no
        dispatch): the analyzer's jit-cache watcher and the regression
        test both gate on these numbers, so a padding bug that sneaks an
        unbucketed shape into the hot path shows up as an excess compile,
        not as a latency mystery.  ``swap_plan`` rebuilds the wrappers, so
        counts restart from zero at install (matching what the engine can
        recompile after a swap).  Returns ``{"per_program": {...},
        "total": n}``; wrappers whose cache the jax version cannot report
        are listed as ``None`` and excluded from the total."""
        wrappers = {"embed": self._embed_fwd, "dense": self._dense_fwd,
                    "slab": self._slab_fwd, "fast": self._fast_fwd,
                    "sharded_embed": self._sharded_embed,
                    "sharded_dense": self._sharded_dense,
                    "sharded_fast": self._sharded_fast}
        per: dict[str, Optional[int]] = {}
        total = 0
        for name, fn in wrappers.items():
            if fn is None:
                continue
            size = getattr(fn, "_cache_size", None)
            per[name] = int(size()) if callable(size) else None
            if per[name] is not None:
                total += per[name]
        return {"per_program": per, "total": total}

    def metrics(self) -> dict:
        lat = np.asarray(self.wave_latencies_s or [0.0])
        wall = ((self._t_last - self._t_first)
                if self._t_first is not None and self._t_last is not None
                else 0.0)
        out = {
            "requests": int(sum(self.wave_sizes)),
            "waves": len(self.wave_sizes),
            "batching": self.batching,
            "p50_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_ms": float(np.percentile(lat, 99) * 1e3),
            "qps": (sum(self.wave_sizes) / wall) if wall > 0 else 0.0,
            "buckets": sorted(self.buckets_seen),
        }
        if self.cache is not None:
            out["cache"] = self.cache.stats.as_dict()
            if self._obs is not None:
                # fold residency + traffic into gauges at scrape time
                # (never per wave: this walk is not hot-path work)
                g = self._obs.gauge("serve_cache_stat",
                                    "hot-row cache stats at last scrape")
                for k, v in out["cache"].items():
                    g.set(float(v), stat=k)
        return out
