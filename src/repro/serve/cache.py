"""Hot-row embedding cache for the recsys serving path.

Zipfian category traffic (the reason the paper's thresholding works) means
a tiny fraction of (quotient, remainder) pairs absorbs most lookups.  The
cache keeps the *combined, dequantized* f32 rows for those pairs on the
host: a hit skips both int8 gathers and the dequant+combine entirely; a
miss is computed once (by the engine) and admitted.

Keys are ``(table, quotient, remainder)`` triples — for non-compositional
tables the quotient slot is 0 and the remainder is the bucket index, so
one keyspace covers full / hash / QR tables.

Design constraints (all pinned by tests):

* **deterministic** — recency/admission use a logical op clock, never wall
  time, and every tie (equal LFU frequency) breaks by least-recent-use,
  then insertion order.  Replaying a key stream on a fresh cache
  reproduces the exact hit/miss/evict event sequence (``replay``), which
  is what makes cache behaviour assertable in CI.
* **accounted** — hits, misses, evictions, insertions, and resident bytes
  are first-class counters; the serve bench reports them per cell.
* **bounded** — ``capacity_rows`` rows max, and/or ``capacity_bytes``
  resident bytes max (sized against the row-bytes accounting in
  ``serve.quantize.row_bytes`` — cached rows are combined f32, 4·D each);
  admission beyond either bound evicts per ``policy`` ("lru" or "lfu").
  A row bigger than the whole byte budget is *rejected* (counted in
  ``stats.rejections``) rather than flushing the cache for an inadmissible
  key.  If the rejected key was already resident (an oversized *refresh*),
  the stale smaller value is dropped as an ``invalidate`` event counted in
  ``stats.invalidations`` — **not** an eviction: ``stats.evictions`` and
  ``evict`` events mean capacity pressure only, which is what keeps
  ``replay()`` logs comparable across capacity configs.
"""

from __future__ import annotations

import dataclasses
from typing import Hashable, Iterable, Optional

import numpy as np

__all__ = ["CacheStats", "HotRowCache"]

POLICIES = ("lru", "lfu")


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0             # capacity-pressure removals only
    insertions: int = 0
    rejections: int = 0            # rows larger than the whole byte budget
    # resident rows dropped by a rejected refresh (not capacity pressure)
    invalidations: int = 0
    bytes_cached: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "insertions": self.insertions,
                "rejections": self.rejections,
                "invalidations": self.invalidations,
                "bytes_cached": self.bytes_cached,
                "lookups": self.lookups, "hit_rate": self.hit_rate}


class HotRowCache:
    def __init__(self, capacity_rows: Optional[int] = 4096,
                 policy: str = "lfu", record_events: bool = False,
                 capacity_bytes: Optional[int] = None):
        if policy not in POLICIES:
            raise ValueError(f"policy={policy!r} not in {POLICIES}")
        if capacity_rows is None and capacity_bytes is None:
            raise ValueError("need capacity_rows and/or capacity_bytes")
        if capacity_rows is not None and capacity_rows < 1:
            raise ValueError("capacity_rows must be >= 1 (or None)")
        if capacity_bytes is not None and capacity_bytes < 1:
            raise ValueError("capacity_bytes must be >= 1 (or None)")
        self.capacity_rows = capacity_rows
        self.capacity_bytes = capacity_bytes
        self.policy = policy
        self.stats = CacheStats()
        self.record_events = record_events
        self.events: list[tuple[str, Hashable]] = []
        self._rows: dict[Hashable, np.ndarray] = {}
        self._freq: dict[Hashable, int] = {}
        self._used: dict[Hashable, int] = {}      # logical clock of last use
        self._inserted: dict[Hashable, int] = {}  # admission order
        self._clock = 0
        self._admissions = 0

    def __len__(self) -> int:
        return len(self._rows)

    def __contains__(self, key) -> bool:
        return key in self._rows

    def _event(self, kind: str, key) -> None:
        if self.record_events:
            self.events.append((kind, key))

    def get(self, key) -> Optional[np.ndarray]:
        """Row for ``key`` or None; counts the hit/miss and bumps recency."""
        self._clock += 1
        row = self._rows.get(key)
        if row is None:
            self.stats.misses += 1
            self._event("miss", key)
            return None
        self.stats.hits += 1
        self._freq[key] += 1
        self._used[key] = self._clock
        self._event("hit", key)
        return row

    def _victim(self, exclude: Hashable = None) -> Hashable:
        pool = (self._rows if exclude is None or exclude not in self._rows
                else [k for k in self._rows if k != exclude])
        if self.policy == "lru":
            return min(pool, key=lambda k: self._used[k])
        # lfu: least frequency, ties by least recent use, then admission order
        return min(pool,
                   key=lambda k: (self._freq[k], self._used[k],
                                  self._inserted[k]))

    def _remove(self, key: Hashable, kind: str = "evict") -> None:
        """Drop ``key`` with full bookkeeping.  ``kind="evict"`` is a
        capacity-pressure removal (counted in ``stats.evictions``);
        ``kind="invalidate"`` is a rejection-driven removal of a stale
        resident value (counted in ``stats.invalidations``) — keeping the
        two apart keeps eviction counts honest and ``replay()`` event
        logs unambiguous."""
        self.stats.bytes_cached -= self._rows[key].nbytes
        del self._rows[key], self._freq[key]
        del self._used[key], self._inserted[key]
        if kind == "evict":
            self.stats.evictions += 1
        else:
            self.stats.invalidations += 1
        self._event(kind, key)

    def _evict_one(self, exclude: Hashable = None) -> None:
        self._remove(self._victim(exclude))

    def _over_bytes(self, incoming: int) -> bool:
        return (self.capacity_bytes is not None
                and self.stats.bytes_cached + incoming > self.capacity_bytes)

    def put(self, key, row) -> None:
        """Admit ``row`` under ``key``, evicting per policy when full —
        by row count and/or resident bytes, whichever binds first."""
        row = np.asarray(row)
        if self.capacity_bytes is not None and row.nbytes > self.capacity_bytes:
            # inadmissible: even an empty cache couldn't hold it; refusing
            # beats flushing every resident row for a key we can't keep
            self.stats.rejections += 1
            self._event("reject", key)
            if key in self._rows:  # the stale smaller value must not linger —
                # dropped as an *invalidation*, not an eviction: nothing was
                # squeezed out by capacity pressure
                self._remove(key, kind="invalidate")
            return
        if key in self._rows:  # refresh in place (value update, not a use)
            self.stats.bytes_cached += row.nbytes - self._rows[key].nbytes
            self._rows[key] = row
            # a grown refresh can push past the budget: shed other rows
            while self._over_bytes(0) and len(self._rows) > 1:
                self._evict_one(exclude=key)
            return
        while (self.capacity_rows is not None
               and len(self._rows) >= self.capacity_rows):
            self._evict_one()
        while self._over_bytes(row.nbytes) and self._rows:
            self._evict_one()
        self._clock += 1
        self._admissions += 1
        self._rows[key] = row
        self._freq[key] = 1
        self._used[key] = self._clock
        self._inserted[key] = self._admissions
        self.stats.insertions += 1
        self.stats.bytes_cached += row.nbytes
        self._event("put", key)

    def get_many(self, keys: Iterable[Hashable]):
        """Batched get: ``(found: {key: row}, missing: [unique keys])``.

        ``missing`` preserves first-appearance order so the caller's
        fill-compute (and therefore admission order) is deterministic.
        """
        found: dict[Hashable, np.ndarray] = {}
        missing: list[Hashable] = []
        seen_missing = set()
        for key in keys:
            if key in found:
                # repeated key in one batch: count the extra hit, bump freq
                self._clock += 1
                self.stats.hits += 1
                self._freq[key] += 1
                self._used[key] = self._clock
                self._event("hit", key)
                continue
            row = self.get(key)
            if row is not None:
                found[key] = row
            elif key not in seen_missing:
                seen_missing.add(key)
                missing.append(key)
        return found, missing

    def replay(self, keys: Iterable[Hashable], row_bytes: int = 0
               ) -> list[tuple[str, Hashable]]:
        """Deterministic replay mode (tests): drive a raw key stream through
        the full get→miss→put cycle with placeholder rows and return the
        event log.  Two replays of the same stream on equal-config caches
        produce identical logs — the property the cache tests assert.
        """
        was_recording, self.record_events = self.record_events, True
        start = len(self.events)
        placeholder = np.zeros((max(row_bytes, 4) // 4,), np.float32)
        for key in keys:
            if self.get(key) is None:
                self.put(key, placeholder)
        self.record_events = was_recording
        return self.events[start:]
