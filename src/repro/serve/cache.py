"""Hot-row embedding cache for the recsys serving path.

Zipfian category traffic (the reason the paper's thresholding works) means
a tiny fraction of (quotient, remainder) pairs absorbs most lookups.  The
cache keeps the *combined, dequantized* f32 rows for those pairs on the
host: a hit skips both int8 gathers and the dequant+combine entirely; a
miss is computed once (by the engine) and admitted.

Keys are ``(table, quotient, remainder)`` triples — for non-compositional
tables the quotient slot is 0 and the remainder is the bucket index, so
one keyspace covers full / hash / QR tables.

Design constraints (all pinned by tests):

* **deterministic** — recency/admission use a logical op clock, never wall
  time, and every tie (equal LFU frequency) breaks by least-recent-use,
  then insertion order.  Replaying a key stream on a fresh cache
  reproduces the exact hit/miss/evict event sequence (``replay``), which
  is what makes cache behaviour assertable in CI.
* **accounted** — hits, misses, evictions, insertions, and resident bytes
  are first-class counters; the serve bench reports them per cell.
* **bounded** — ``capacity_rows`` rows max, and/or ``capacity_bytes``
  resident bytes max (sized against the row-bytes accounting in
  ``serve.quantize.row_bytes`` — cached rows are combined f32, 4·D each);
  admission beyond either bound evicts per ``policy`` ("lru" or "lfu").
  A row bigger than the whole byte budget is *rejected* (counted in
  ``stats.rejections``) rather than flushing the cache for an inadmissible
  key.  If the rejected key was already resident (an oversized *refresh*),
  the stale smaller value is dropped as an ``invalidate`` event counted in
  ``stats.invalidations`` — **not** an eviction: ``stats.evictions`` and
  ``evict`` events mean capacity pressure only, which is what keeps
  ``replay()`` logs comparable across capacity configs.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Hashable, Iterable, Optional, Sequence

import numpy as np

__all__ = ["CacheStats", "HotRowCache", "DeviceHotRowCache", "CachePinned"]

POLICIES = ("lru", "lfu")


class CachePinned(Exception):
    """Admission needs an eviction but every resident row is pinned (the
    engine pins this wave's hit rows so an in-flight device gather never
    reads a reassigned slot).  The caller skips the admission."""


@dataclasses.dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    evictions: int = 0             # capacity-pressure removals only
    insertions: int = 0
    rejections: int = 0            # rows larger than the whole byte budget
    # resident rows dropped by a rejected refresh (not capacity pressure)
    invalidations: int = 0
    bytes_cached: int = 0

    @property
    def lookups(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / self.lookups if self.lookups else 0.0

    def as_dict(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "insertions": self.insertions,
                "rejections": self.rejections,
                "invalidations": self.invalidations,
                "bytes_cached": self.bytes_cached,
                "lookups": self.lookups, "hit_rate": self.hit_rate}


class HotRowCache:
    def __init__(self, capacity_rows: Optional[int] = 4096,
                 policy: str = "lfu", record_events: bool = False,
                 capacity_bytes: Optional[int] = None):
        if policy not in POLICIES:
            raise ValueError(f"policy={policy!r} not in {POLICIES}")
        if capacity_rows is None and capacity_bytes is None:
            raise ValueError("need capacity_rows and/or capacity_bytes")
        if capacity_rows is not None and capacity_rows < 1:
            raise ValueError("capacity_rows must be >= 1 (or None)")
        if capacity_bytes is not None and capacity_bytes < 1:
            raise ValueError("capacity_bytes must be >= 1 (or None)")
        self.capacity_rows = capacity_rows
        self.capacity_bytes = capacity_bytes
        self.policy = policy
        self.stats = CacheStats()
        self.record_events = record_events
        self.events: list[tuple[str, Hashable]] = []
        self._rows: dict[Hashable, object] = {}   # key -> stored entry
        self._freq: dict[Hashable, int] = {}
        self._used: dict[Hashable, int] = {}      # logical clock of last use
        self._inserted: dict[Hashable, int] = {}  # admission order
        self._pinned: set = set()                 # exempt from eviction
        self._clock = 0
        self._admissions = 0

    def __len__(self) -> int:
        return len(self._rows)

    def __contains__(self, key) -> bool:
        return key in self._rows

    def _event(self, kind: str, key) -> None:
        if self.record_events:
            self.events.append((kind, key))

    # ---- storage hooks: the only seams DeviceHotRowCache overrides, so
    # ---- policy/accounting/event semantics are shared (and the property
    # ---- tests can assert host and device replay logs are identical)
    def _coerce(self, row):
        """Normalize an incoming row; ``.nbytes`` of the result is what the
        byte budget charges."""
        return np.asarray(row)

    def _store(self, key, row) -> None:
        """Bind ``row`` (already coerced) to ``key`` — insert or refresh."""
        self._rows[key] = row

    def _release(self, key) -> None:
        """Storage-side teardown before ``key``'s bookkeeping is dropped."""

    def _fetch(self, key):
        """Materialize the stored row for a host-side ``get``."""
        return self._rows[key]

    def get(self, key) -> Optional[np.ndarray]:
        """Row for ``key`` or None; counts the hit/miss and bumps recency."""
        self._clock += 1
        if key not in self._rows:
            self.stats.misses += 1
            self._event("miss", key)
            return None
        self.stats.hits += 1
        self._freq[key] += 1
        self._used[key] = self._clock
        self._event("hit", key)
        return self._fetch(key)

    def _victim(self, exclude: Hashable = None) -> Hashable:
        pool = [k for k in self._rows
                if k != exclude and k not in self._pinned]
        if not pool:
            raise CachePinned(f"{len(self._pinned)} pinned, no victim")
        if self.policy == "lru":
            return min(pool, key=lambda k: self._used[k])
        # lfu: least frequency, ties by least recent use, then admission order
        return min(pool,
                   key=lambda k: (self._freq[k], self._used[k],
                                  self._inserted[k]))

    def _remove(self, key: Hashable, kind: str = "evict") -> None:
        """Drop ``key`` with full bookkeeping.  ``kind="evict"`` is a
        capacity-pressure removal (counted in ``stats.evictions``);
        ``kind="invalidate"`` is a rejection-driven removal of a stale
        resident value (counted in ``stats.invalidations``) — keeping the
        two apart keeps eviction counts honest and ``replay()`` event
        logs unambiguous."""
        self.stats.bytes_cached -= self._rows[key].nbytes
        self._release(key)
        del self._rows[key], self._freq[key]
        del self._used[key], self._inserted[key]
        if kind == "evict":
            self.stats.evictions += 1
        else:
            self.stats.invalidations += 1
        self._event(kind, key)

    def _evict_one(self, exclude: Hashable = None) -> None:
        self._remove(self._victim(exclude))

    def _over_bytes(self, incoming: int) -> bool:
        return (self.capacity_bytes is not None
                and self.stats.bytes_cached + incoming > self.capacity_bytes)

    def put(self, key, row) -> None:
        """Admit ``row`` under ``key``, evicting per policy when full —
        by row count and/or resident bytes, whichever binds first."""
        row = self._coerce(row)
        if self.capacity_bytes is not None and row.nbytes > self.capacity_bytes:
            # inadmissible: even an empty cache couldn't hold it; refusing
            # beats flushing every resident row for a key we can't keep
            self.stats.rejections += 1
            self._event("reject", key)
            if key in self._rows:  # the stale smaller value must not linger —
                # dropped as an *invalidation*, not an eviction: nothing was
                # squeezed out by capacity pressure
                self._remove(key, kind="invalidate")
            return
        if key in self._rows:  # refresh in place (value update, not a use)
            self.stats.bytes_cached += row.nbytes - self._rows[key].nbytes
            self._store(key, row)
            # a grown refresh can push past the budget: shed other rows
            while self._over_bytes(0) and len(self._rows) > 1:
                self._evict_one(exclude=key)
            return
        while (self.capacity_rows is not None
               and len(self._rows) >= self.capacity_rows):
            self._evict_one()
        while self._over_bytes(row.nbytes) and self._rows:
            self._evict_one()
        self._clock += 1
        self._admissions += 1
        self._store(key, row)
        self._freq[key] = 1
        self._used[key] = self._clock
        self._inserted[key] = self._admissions
        self.stats.insertions += 1
        self.stats.bytes_cached += row.nbytes
        self._event("put", key)

    def invalidate_all(self) -> int:
        """Drop every resident row, counted as *invalidations* — the rows
        are not being squeezed out by capacity pressure, they are stale
        (``RecsysEngine.swap_plan`` installs a new plan whose combined
        rows the old residency no longer matches).  Returns the number of
        rows dropped; eviction counters are untouched."""
        keys = list(self._rows)
        for key in keys:
            self._remove(key, kind="invalidate")
        return len(keys)

    def get_many(self, keys: Iterable[Hashable]):
        """Batched get: ``(found: {key: row}, missing: [unique keys])``.

        ``missing`` preserves first-appearance order so the caller's
        fill-compute (and therefore admission order) is deterministic.
        """
        found: dict[Hashable, np.ndarray] = {}
        missing: list[Hashable] = []
        seen_missing = set()
        for key in keys:
            if key in found:
                # repeated key in one batch: count the extra hit, bump freq
                self._clock += 1
                self.stats.hits += 1
                self._freq[key] += 1
                self._used[key] = self._clock
                self._event("hit", key)
                continue
            row = self.get(key)
            if row is not None:
                found[key] = row
            elif key not in seen_missing:
                seen_missing.add(key)
                missing.append(key)
        return found, missing

    def replay(self, keys: Iterable[Hashable], row_bytes: int = 0
               ) -> list[tuple[str, Hashable]]:
        """Deterministic replay mode (tests): drive a raw key stream through
        the full get→miss→put cycle with placeholder rows and return the
        event log.  Two replays of the same stream on equal-config caches
        produce identical logs — the property the cache tests assert.
        """
        was_recording, self.record_events = self.record_events, True
        start = len(self.events)
        placeholder = np.zeros((max(row_bytes, 4) // 4,), np.float32)
        for key in keys:
            if self.get(key) is None:
                self.put(key, placeholder)
        self.record_events = was_recording
        return self.events[start:]


# --------------------------------------------------------------------------
# device-resident storage


@dataclasses.dataclass
class _Slot:
    """Bookkeeping record for one cached row living in a device slab.
    ``nbytes`` mirrors the ``np.ndarray`` attribute so the base class's
    byte accounting reads it without knowing rows moved off-host."""
    width: int
    slot: int
    nbytes: int


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1)).bit_length()


@functools.cache
def _scatter_fn():
    import jax

    @functools.partial(jax.jit, donate_argnums=0)
    def scatter(slab, slots, rows):
        return slab.at[slots].set(rows)

    return scatter


class DeviceHotRowCache(HotRowCache):
    """``HotRowCache`` with rows resident in device memory.

    Policy, accounting, and the event/replay contract are inherited
    unchanged — only storage moves: rows live in one f32 slab per table
    width (``(slots, d)`` jax arrays in HBM), admission/eviction stay
    host-side over the same LFU/LRU bookkeeping, and writes batch into a
    single donated in-place scatter per width per wave (``flush``), so the
    hit path is a pure device gather — no per-row host transfer, ever.

    The engine-facing batched API:

    * ``lookup_many(keys, counts)`` → ``(slots, miss_mask)`` — slot ids
      for resident keys (``-1`` for misses) with per-occurrence hit/miss
      accounting (one ``hit``/``miss`` event per *unique* key);
    * ``put_many(keys, rows, pinned=...)`` — admit computed miss rows,
      never evicting a pinned (this wave's hit) key; returns the admitted
      subset.  An admission that would require evicting a pinned row is
      skipped and counted as a rejection;
    * ``slab(width)`` / ``flush()`` — the gather target for the fused
      serve kernel path.

    Scalar ``get``/``put``/``replay`` still work (property tests assert
    host and device caches produce identical replay logs); ``get``
    flushes and copies the row back to host, so it is the compat path,
    not the hot path.
    """

    def __init__(self, capacity_rows: Optional[int] = 4096,
                 policy: str = "lfu", record_events: bool = False,
                 capacity_bytes: Optional[int] = None):
        super().__init__(capacity_rows=capacity_rows, policy=policy,
                         record_events=record_events,
                         capacity_bytes=capacity_bytes)
        self._slabs: dict[int, object] = {}        # width -> (slots, d) f32
        self._free: dict[int, list[int]] = {}      # width -> free slot ids
        self._pending: dict[int, list] = {}        # width -> [(slot, row)]
        # bumped whenever key->slot residency changes; the engine keeps a
        # device-resident slot map and rebuilds it only when this moves
        self.residency_version = 0

    # ---- storage hooks ----------------------------------------------------
    def _coerce(self, row):
        import jax.numpy as jnp
        row = jnp.asarray(row, jnp.float32)
        return row.reshape(-1)

    def _store(self, key, row) -> None:
        d = int(row.shape[-1])
        rec = self._rows.get(key)
        if rec is not None and rec.width == d:
            slot = rec.slot                        # refresh in place
        else:
            if rec is not None:                    # width changed: move
                self._free[rec.width].append(rec.slot)
            slot = self._alloc(d)
        self._rows[key] = _Slot(d, slot, row.nbytes)
        self._pending.setdefault(d, []).append((slot, row))
        self.residency_version += 1

    def _release(self, key) -> None:
        rec = self._rows[key]
        self._free.setdefault(rec.width, []).append(rec.slot)
        self.residency_version += 1

    def _fetch(self, key):
        rec = self._rows[key]
        self.flush()
        return np.asarray(self._slabs[rec.width][rec.slot])

    def invalidate_all(self) -> int:
        """Base-class semantics (every drop is an invalidation), plus the
        storage teardown a plan swap needs: pending (unflushed) writes are
        discarded and the slabs themselves are released — the new plan may
        use different table widths, and a swap must not strand HBM in
        slabs no width will ever touch again."""
        n = super().invalidate_all()   # releases every slot, bumps version
        self._slabs.clear()
        self._free.clear()
        self._pending.clear()
        self.residency_version += 1    # force slot-map rebuild even if empty
        return n

    # ---- slab management --------------------------------------------------
    def _max_rows(self, d: int) -> int:
        caps = []
        if self.capacity_rows is not None:
            caps.append(self.capacity_rows)
        if self.capacity_bytes is not None:
            caps.append(max(1, self.capacity_bytes // (4 * d)))
        return min(caps)

    # Slabs whose full capacity fits under this row count are allocated
    # at capacity up front: a stable slab shape means the jitted gather
    # and donated scatter compile once per wave shape instead of once per
    # (wave shape, slab size) pair — growth-triggered recompiles would
    # otherwise leak ~100ms XLA compiles into steady-state serving.
    _PREALLOC_ROWS = 1 << 20

    def _grow(self, d: int) -> bool:
        """Create or double the width-``d`` slab (capped by capacity);
        returns False when already saturated."""
        import jax.numpy as jnp
        cur = self._slabs.get(d)
        n = 0 if cur is None else cur.shape[0]
        cap = self._max_rows(d)
        grown = cap if cap <= self._PREALLOC_ROWS else min(cap, max(64, n * 2))
        if grown <= n:
            return False
        slab = jnp.zeros((grown, d), jnp.float32)
        if cur is not None:
            slab = slab.at[:n].set(cur)
        self._slabs[d] = slab
        # descending so pop() hands out the lowest slot first
        self._free.setdefault(d, []).extend(range(grown - 1, n - 1, -1))
        return True

    def _alloc(self, d: int) -> int:
        free = self._free.setdefault(d, [])
        while not free:
            if self._grow(d):
                continue
            # slab saturated for this width (mixed-width byte budget):
            # evict the policy victim *of this width* to free a slot
            victims = [k for k, r in self._rows.items()
                       if r.width == d and k not in self._pinned]
            if not victims:
                raise CachePinned(f"width-{d} slab saturated, all pinned")
            if self.policy == "lru":
                v = min(victims, key=lambda k: self._used[k])
            else:
                v = min(victims, key=lambda k: (self._freq[k],
                                                self._used[k],
                                                self._inserted[k]))
            self._remove(v)
        return free.pop()

    def slab(self, d: int):
        """The ``(slots, d)`` f32 device slab for width ``d`` (gather
        target for slot ids from ``lookup_many``); created empty on first
        touch so an all-empty wave can still gather (masked to zero)."""
        if d not in self._slabs:
            self._grow(d)
        return self._slabs[d]

    def slots_for(self, keys: Sequence[Hashable]) -> np.ndarray:
        """Slot ids for resident ``keys`` (KeyError if any is missing —
        the engine only calls this after pinning the whole wave)."""
        return np.asarray([self._rows[k].slot for k in keys], np.int32)

    def slot_items(self) -> tuple[np.ndarray, np.ndarray]:
        """Every resident (key, slot) pair as two aligned arrays — the
        bulk export the engine's device slot map is rebuilt from (keys
        are the engine's packed int64s)."""
        n = len(self._rows)
        keys = np.fromiter(self._rows.keys(), np.int64, n)
        slots = np.fromiter((rec.slot for rec in self._rows.values()),
                            np.int64, n).astype(np.int32)
        return keys, slots

    def flush(self) -> None:
        """Apply pending admissions as one donated scatter per width.

        Slots are deduped last-write-wins (a slot freed by an eviction and
        reused within the same wave must land the newer row), and the
        scatter pads to the next pow2 by repeating the final pair so the
        jit cache stays small."""
        import jax.numpy as jnp
        for d, writes in self._pending.items():
            if not writes:
                continue
            dedup = {}
            for slot, row in writes:
                dedup[slot] = row
            writes.clear()
            slots = np.fromiter(dedup.keys(), np.int64, len(dedup))
            rows = jnp.stack(list(dedup.values()))
            pad = max(64, _next_pow2(len(dedup))) - len(dedup)
            if pad:
                slots = np.concatenate([slots, np.repeat(slots[-1:], pad)])
                rows = jnp.concatenate(
                    [rows, jnp.repeat(rows[-1:], pad, axis=0)])
            self._slabs[d] = _scatter_fn()(
                self._slabs[d], jnp.asarray(slots, jnp.int32), rows)

    # ---- engine-facing batched API ---------------------------------------
    def lookup_many(self, keys: Sequence[Hashable], counts=None):
        """Slot ids for ``keys`` (``-1`` where missing) plus a miss mask.

        ``counts`` carries per-key occurrence counts (the engine passes
        ``np.unique`` counts) so hit/miss totals match the per-occurrence
        accounting of the host cache's ``get_many``."""
        n = len(keys)
        cnts = [1] * n if counts is None else \
            (counts.tolist() if hasattr(counts, "tolist") else list(counts))
        slot_list = [-1] * n
        miss_list = [False] * n
        # this loop runs once per unique key per wave on the serving hot
        # path — keep the body allocation-free and bind lookups to locals
        rows_get = self._rows.get
        freq, used = self._freq, self._used
        record, events = self.record_events, self.events
        clock = self._clock
        hits = misses = 0
        for i, key in enumerate(keys):
            c = cnts[i]
            clock += 1
            rec = rows_get(key)
            if rec is None:
                misses += c
                if record:
                    events.append(("miss", key))
                miss_list[i] = True
            else:
                hits += c
                freq[key] += c
                used[key] = clock
                if record:
                    events.append(("hit", key))
                slot_list[i] = rec.slot
        self._clock = clock
        self.stats.hits += hits
        self.stats.misses += misses
        return np.asarray(slot_list, np.int32), np.asarray(miss_list, bool)

    def put_many(self, keys: Sequence[Hashable], rows,
                 pinned: Iterable[Hashable] = ()) -> list:
        """Admit ``rows`` under ``keys`` without evicting ``pinned`` keys;
        flushes, then returns the keys actually admitted (an admission that
        could only proceed by evicting a pinned row is rejected)."""
        self._pinned = set(pinned)
        admitted = []
        try:
            for key, row in zip(keys, rows):
                try:
                    self.put(key, row)
                except CachePinned:
                    self.stats.rejections += 1
                    self._event("reject", key)
                    continue
                if key in self._rows:
                    admitted.append(key)
        finally:
            self._pinned = set()
            # a pinned-blocked shed can leave the budget transiently over;
            # restore the invariant now that pins are released
            while self._over_bytes(0) and len(self._rows) > 1:
                self._evict_one()
        self.flush()
        return admitted
