"""Batched serving engine: prefill + KV-cache decode over request waves.

Requests are queued, bucketed by prompt length (so right-padded garbage
never enters the causal cache — correctness over cleverness), and executed
in *waves*: one batched prefill, then lock-step batched decode until every
sequence in the wave hits EOS or its token budget.  Finished slots idle to
wave end; per-slot paged caches (continuous batching) are the documented
next step and don't change the lowering the dry-run measures — ``decode_32k``
lowers exactly this engine's ``decode_step``.

Greedy or temperature sampling; fully deterministic given (seed, queue).
"""

from __future__ import annotations

import dataclasses
from collections import defaultdict, deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["Request", "ServeEngine"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 16
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, *, prefill_fn: Callable, decode_fn: Callable,
                 make_cache_fn: Callable, batch_size: int, max_len: int,
                 eos_id: Optional[int] = None, temperature: float = 0.0,
                 seed: int = 0):
        self.prefill_fn = jax.jit(prefill_fn)
        self.decode_fn = jax.jit(decode_fn)
        self.make_cache_fn = make_cache_fn
        self.batch_size = batch_size
        self.max_len = max_len
        self.eos_id = eos_id
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self._queue: deque[Request] = deque()
        self._next_uid = 0
        self.completed: dict[int, Request] = {}

    def submit(self, prompt: list[int], max_new_tokens: int = 16) -> int:
        uid = self._next_uid
        self._next_uid += 1
        self._queue.append(Request(uid, list(prompt), max_new_tokens))
        return uid

    def _next_wave(self) -> list[Request]:
        if not self._queue:
            return []
        buckets: dict[int, list[Request]] = defaultdict(list)
        for r in self._queue:
            buckets[len(r.prompt)].append(r)
        # largest bucket first: best batch utilisation
        length = max(buckets, key=lambda k: len(buckets[k]))
        wave = buckets[length][: self.batch_size]
        for r in wave:
            self._queue.remove(r)
        return wave

    def _sample(self, logits: np.ndarray) -> np.ndarray:
        if self.temperature <= 0.0:
            return np.argmax(logits, axis=-1)
        self.key, k = jax.random.split(self.key)
        return np.asarray(jax.random.categorical(k, jnp.asarray(logits) / self.temperature))

    def step(self) -> list[Request]:
        """Run one full wave; returns the finished requests."""
        wave = self._next_wave()
        if not wave:
            return []
        b = self.batch_size
        plen = len(wave[0].prompt)
        prompts = np.zeros((b, plen), np.int32)
        for i, r in enumerate(wave):
            prompts[i] = r.prompt
        cache = self.make_cache_fn(b, self.max_len)
        logits, cache = self.prefill_fn(jnp.asarray(prompts), cache)
        logits = np.asarray(logits)[:, -1]  # (B, V)
        budget = max(r.max_new_tokens for r in wave)
        active = np.array([i < len(wave) for i in range(b)])
        pos = plen
        tok = self._sample(logits)
        for i, r in enumerate(wave):
            t = int(tok[i])
            r.output.append(t)
            if (self.eos_id is not None and t == self.eos_id) \
                    or len(r.output) >= r.max_new_tokens:
                r.done = True
                active[i] = False
        for _ in range(budget - 1):
            if not active.any() or pos >= self.max_len - 1:
                break
            logits, cache = self.decode_fn(jnp.asarray(tok[:, None], jnp.int32),
                                           pos, cache)
            pos += 1
            tok = self._sample(np.asarray(logits)[:, -1])
            for i, r in enumerate(wave):
                if not active[i] or r.done:
                    continue
                t = int(tok[i])
                r.output.append(t)
                if (self.eos_id is not None and t == self.eos_id) \
                        or len(r.output) >= r.max_new_tokens:
                    r.done = True
                    active[i] = False
        for r in wave:
            r.done = True
            self.completed[r.uid] = r
        return wave

    def run_until_drained(self) -> dict[int, Request]:
        while self._queue:
            self.step()
        return self.completed
