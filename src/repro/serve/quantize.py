"""Post-training row-wise quantization of compositional embedding tables.

The paper shrinks tables at *training* time (QR / complementary
partitions); this module multiplies that win at *serve* time with
post-training row-wise quantization ("Learning Compressed Embeddings for
On-Device Inference"-style): each table row gets its own affine int8 code

    w ≈ scale * (q - zp)        q int8 in [-127, 127], zp int8, scale bf16

so a ``(rows, D)`` f32 table becomes ``D + 3`` bytes per row instead of
``4·D`` (D=64: 0.262x; the serve bench's acceptance bar is 0.27x).  Design
choices that matter:

* **per-row** scale/zp — embedding rows differ in magnitude by orders of
  magnitude under Zipfian training (hot rows grow), so a per-tensor scale
  would burn the int8 budget on the hottest row;
* the row range is widened to include 0 (``lo = min(row, 0)``, ``hi =
  max(row, 0)``), which pins the zero-point into int8 range and makes
  padding rows exact;
* the scale is **rounded to bf16 before quantizing**, so dequantization
  with the stored scale reproduces exactly the grid the encoder used and
  the end-to-end error keeps the textbook round-to-nearest bound
  ``|dequant(w) - w| <= scale / 2`` per row (pinned by tests and by
  ``benchmarks/serve_bench.py``'s built-in check);
* integer zero-point (TFLite convention) — ``zp`` contributes no rounding
  error of its own.

A quantized table is a plain pytree: ``{"q": int8 (rows, D), "scale":
bf16 (rows, 1), "zp": int8 (rows, 1)}`` — it jits, shards (the rule
engine's ``table_\\d+`` pattern matches the parent path), and
checkpoints like any other params.  Lookups dequantize only the gathered
rows (``core.compositional.table_rows``); the fused Pallas path
(``kernels.qr_gather.qr_gather_quant``) does the dequant in VMEM during
the combine.

``mode="bf16"`` is the cheap alternative: matching leaves are cast to
bf16 arrays (0.5x bytes, ~3-decimal-digit rows) with no layout change.
"""

from __future__ import annotations

import math
import re
from typing import Sequence

import jax
import jax.numpy as jnp

from ..core.compositional import is_quantized_table, table_rows
from ..optim.optimizers import leaf_paths

__all__ = ["MODES", "TABLE_PATTERN", "quantize_table", "dequantize_rows",
           "dequantize_table", "is_quantized_table", "quantize_params",
           "table_bytes", "table_shapes", "memory_report",
           "paths_and_leaves", "row_bytes"]

MODES = ("f32", "bf16", "int8")

# Same path idiom as sharding.RULES / policy.POLICY_RULES: embedding and
# hash tables are the memory-dominant leaves quantization exists for.
TABLE_PATTERN = r"(^|/)(embed\w*|wte|tok_emb|tables?)(/|$)|(^|/)table_\d+($|/)"

# q and zp live in [-QMAX, QMAX]; the grid spans 2*QMAX - 2 steps so that
# rounding the zero-point to an integer can never push a code out of range.
_QMAX = 127
_STEPS = 2 * _QMAX - 2  # 252


def row_bytes(dim: int, mode: str = "int8") -> int:
    """Bytes per stored table row of width ``dim`` under ``mode``.

    The single bytes/row model shared by the serving stack (cache byte
    budgets, ``table_bytes``) and the memory planner's serve-cost domain:
    int8 rows carry ``dim`` q bytes + 2 (bf16 scale) + 1 (int8 zp).
    """
    if mode not in MODES:
        raise ValueError(f"unknown quantization mode {mode!r}; "
                         f"expected one of {MODES}")
    return {"f32": 4 * dim, "bf16": 2 * dim, "int8": dim + 3}[mode]


def quantize_table(w) -> dict:
    """Row-wise affine int8 quantization of a ``(rows, D)`` table.

    Returns ``{"q", "scale", "zp"}`` (see module docstring for the wire
    format and the ``scale/2`` per-row error bound).
    """
    if w.ndim != 2:
        raise ValueError(f"quantize_table expects (rows, D), got {w.shape}")
    w32 = w.astype(jnp.float32)
    lo = jnp.minimum(w32.min(axis=1, keepdims=True), 0.0)
    hi = jnp.maximum(w32.max(axis=1, keepdims=True), 0.0)
    scale = jnp.maximum((hi - lo) / _STEPS, jnp.finfo(jnp.float32).tiny)
    # round-trip through bf16 FIRST: the encoder and decoder must agree on
    # the grid, otherwise the stored-scale mismatch adds |w| * 2^-9 error
    scale = scale.astype(jnp.bfloat16)
    s32 = scale.astype(jnp.float32)
    zp = jnp.round(-(_QMAX - 1) - lo / s32)  # in [-(QMAX-1), QMAX-1]
    q = jnp.clip(jnp.round(w32 / s32 + zp), -_QMAX, _QMAX)
    return {"q": q.astype(jnp.int8), "scale": scale,
            "zp": zp.astype(jnp.int8)}


def dequantize_rows(qt: dict, idx):
    """Gather + dequantize rows ``idx`` from a quantized table (f32 out).

    Only the gathered rows are ever widened — the f32 table never
    materialises (the point of serving quantized).
    """
    return table_rows(qt, idx)


def dequantize_table(qt: dict):
    """Full-table dequantization (tests / error-bound checks only)."""
    return ((qt["q"].astype(jnp.float32) - qt["zp"].astype(jnp.float32))
            * qt["scale"].astype(jnp.float32))


def _match(path: str, patterns: Sequence[str]) -> bool:
    return any(re.search(p, path) for p in patterns)


def quantize_params(params, mode: str = "int8",
                    patterns: Sequence[str] = (TABLE_PATTERN,)):
    """Quantize every rank-2 table leaf of a param tree for serving.

    Leaves whose path matches ``patterns`` (default: the shared table
    pattern) are replaced by quantized-table dicts (``int8``) or cast to
    bf16 (``bf16``); everything else — MLPs, norms, biases — is returned
    untouched.  ``mode="f32"`` is the identity (so benches can treat the
    three modes uniformly).
    """
    if mode not in MODES:
        raise ValueError(f"unknown quantization mode {mode!r}; "
                         f"expected one of {MODES}")
    if mode == "f32":
        return params
    leaves, treedef = jax.tree.flatten(params)
    paths = leaf_paths(params)
    out = []
    for path, leaf in zip(paths, leaves):
        if getattr(leaf, "ndim", 0) == 2 and _match(path, patterns):
            out.append(quantize_table(leaf) if mode == "int8"
                       else leaf.astype(jnp.bfloat16))
        else:
            out.append(leaf)
    return jax.tree.unflatten(treedef, out)


def _leaf_bytes(leaf) -> int:
    if is_quantized_table(leaf):
        return sum(_leaf_bytes(v) for v in leaf.values())
    n = int(math.prod(leaf.shape)) if leaf.shape else 1
    return n * jnp.dtype(leaf.dtype).itemsize


def paths_and_leaves(params):
    """(path, leaf) pairs treating quantized-table dicts as single leaves —
    a quantized leaf keeps the path of the f32 leaf it replaced, so zipping
    the two trees by path pairs original and quantized tables exactly."""
    return list(zip(leaf_paths(params, is_leaf=is_quantized_table),
                    jax.tree.leaves(params, is_leaf=is_quantized_table)))


def table_bytes(params, patterns: Sequence[str] = (TABLE_PATTERN,)) -> int:
    """Total bytes of the table leaves (quantized dicts count q+scale+zp)."""
    return sum(_leaf_bytes(leaf) for path, leaf in paths_and_leaves(params)
               if is_quantized_table(leaf) or _match(path, patterns))


def table_shapes(params, patterns: Sequence[str] = (TABLE_PATTERN,)
                 ) -> list[tuple[str, int, int]]:
    """``(path, rows, width)`` per table leaf — mixed-dimension plans give
    every feature its own row width, and this is the report that makes the
    per-table layout auditable (quantized dicts report their ``q`` shape)."""
    out = []
    for path, leaf in paths_and_leaves(params):
        if is_quantized_table(leaf):
            out.append((path, int(leaf["q"].shape[0]),
                        int(leaf["q"].shape[1])))
        elif getattr(leaf, "ndim", 0) == 2 and _match(path, patterns):
            out.append((path, int(leaf.shape[0]), int(leaf.shape[1])))
    return out


def memory_report(params, qparams, placement=None) -> dict:
    """Bytes vs f32 for the table leaves: the number the paper + serving
    stack exist to shrink.  ``ratio`` is what the serve bench gates on;
    ``table_dims`` is the distinct-row-width set (singleton for uniform
    models, several entries under a mixed-dimension plan).

    With a ``placement`` (``dist.serve_placement.ServePlacement``) the
    report adds the sharded-serving view: per-device table bytes under
    that placement (replicated sub-tables count in full, row-sharded
    ones contribute their padded 1/N slice) and the per-device ratio
    against an even f32 split — the memory argument for serving a plan
    on N devices."""
    base = table_bytes(params)
    quant = table_bytes(qparams)
    report = {"f32_table_bytes": base, "quant_table_bytes": quant,
              "ratio": quant / base if base else 1.0,
              "table_dims": sorted({w for _, _, w in table_shapes(params)}),
              "model_bytes_f32": sum(_leaf_bytes(l) for l in
                                     jax.tree.leaves(params)),
              "model_bytes_quant": sum(
                  _leaf_bytes(l) for l in
                  jax.tree.leaves(qparams, is_leaf=is_quantized_table))}
    if placement is not None:
        n = placement.n_devices
        per_dev = placement.bytes_per_device()
        report["placement"] = {
            "n_devices": n,
            "table_bytes_per_device": per_dev,
            "replicated_bytes": placement.replicated_bytes(),
            "pad_bytes": placement.pad_bytes(),
            "ratio_per_device": (per_dev / (base / n)) if base else 1.0,
        }
    return report
