"""Serving subsystem: wave-batched LM engine, quantized recsys engine.

* ``serve.engine``   — prefill + KV-cache decode waves (LM families);
* ``serve.quantize`` — post-training row-wise int8/bf16 table quantization;
* ``serve.cache``    — deterministic hot-row embedding cache;
* ``serve.recsys``   — microbatched quantized DLRM/DCN scoring engine.
"""

from .cache import CacheStats, DeviceHotRowCache, HotRowCache
from .engine import Request, ServeEngine
from .quantize import (dequantize_rows, dequantize_table, is_quantized_table,
                       memory_report, quantize_params, quantize_table,
                       table_bytes)
from .recsys import RecRequest, RecsysEngine

__all__ = [
    "Request", "ServeEngine",
    "CacheStats", "HotRowCache", "DeviceHotRowCache",
    "quantize_table", "quantize_params", "dequantize_rows",
    "dequantize_table", "is_quantized_table", "table_bytes", "memory_report",
    "RecRequest", "RecsysEngine",
]
