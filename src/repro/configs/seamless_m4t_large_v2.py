"""seamless-m4t-large-v2: enc-dec audio->text, vocab 256,206 (the most
embedding-dominated assigned arch). [arXiv:2308.11596; hf]"""
from ..models.encdec import EncDecConfig
from .common import embedding_spec, encdec_api

ARCH, FAMILY, PARAMS_B = "seamless-m4t-large-v2", "audio", 1.9


def config(reduced: bool = False, embedding: str = "qr", num_collisions: int = 4):
    emb = embedding_spec(embedding, num_collisions)
    if reduced:
        return EncDecConfig(name=ARCH, vocab=512, d_model=64, enc_layers=2,
                            dec_layers=2, n_heads=4, n_kv_heads=2, d_head=16,
                            d_ff=128, enc_ratio=4, embedding=emb,
                            param_dtype="float32", compute_dtype="float32",
                            xent_chunk=16)
    return EncDecConfig(name=ARCH, vocab=256206, d_model=1024, enc_layers=24,
                        dec_layers=24, n_heads=16, n_kv_heads=16, d_head=64,
                        d_ff=8192, enc_ratio=4, embedding=emb)


def api(cfg):
    return encdec_api(cfg, PARAMS_B, accum=8)
