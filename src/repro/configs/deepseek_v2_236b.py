"""deepseek-v2-236b: MLA (kv_lora=512) + 160-expert top-6 MoE with 2 shared
experts. [arXiv:2405.04434; hf]"""
from ..models.lm import LMConfig
from ..nn.mla import MLAConfig
from ..nn.moe import MoEConfig
from .common import embedding_spec, lm_api

ARCH, FAMILY, PARAMS_B = "deepseek-v2-236b", "moe", 238.0


def config(reduced: bool = False, embedding: str = "qr", num_collisions: int = 4):
    emb = embedding_spec(embedding, num_collisions)
    if reduced:
        return LMConfig(name=ARCH, vocab=512, d_model=64, n_layers=2, n_heads=4,
                        n_kv_heads=4, d_head=16, d_ff=128,
                        mla=MLAConfig(d_model=64, n_heads=4, q_lora=32, kv_lora=16,
                                      d_nope=16, d_rope=8, d_v=16),
                        moe=MoEConfig(n_experts=8, top_k=2, d_model=64, d_ff=96,
                                      groups=8),
                        n_shared_experts=2, embedding=emb,
                        param_dtype="float32", compute_dtype="float32", xent_chunk=16)
    return LMConfig(name=ARCH, vocab=102400, d_model=5120, n_layers=60, n_heads=128,
                    n_kv_heads=128, d_head=128, d_ff=1536,
                    mla=MLAConfig(d_model=5120, n_heads=128, q_lora=1536,
                                  kv_lora=512, d_nope=128, d_rope=64, d_v=128),
                    moe=MoEConfig(n_experts=160, top_k=6, d_model=5120, d_ff=1536,
                                  groups=256, capacity_factor=1.25),
                    n_shared_experts=2, embedding=emb)


def api(cfg):
    return lm_api(cfg, PARAMS_B, accum=16)
