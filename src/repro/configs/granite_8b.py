"""granite-8b: llama-arch, code model, 36L x 4096. [arXiv:2405.04324; hf]"""
from ..models.lm import LMConfig
from .common import embedding_spec, lm_api

ARCH, FAMILY, PARAMS_B = "granite-8b", "dense", 8.0


def config(reduced: bool = False, embedding: str = "qr", num_collisions: int = 4):
    emb = embedding_spec(embedding, num_collisions)
    if reduced:
        return LMConfig(name=ARCH, vocab=512, d_model=64, n_layers=2, n_heads=4,
                        n_kv_heads=2, d_head=16, d_ff=128, embedding=emb,
                        param_dtype="float32", compute_dtype="float32", xent_chunk=16)
    return LMConfig(name=ARCH, vocab=49152, d_model=4096, n_layers=36, n_heads=32,
                    n_kv_heads=8, d_head=128, d_ff=14336, embedding=emb)


def api(cfg):
    return lm_api(cfg, PARAMS_B)
