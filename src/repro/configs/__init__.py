"""Architecture registry: ``--arch <id>`` resolves here."""

from . import (arctic_480b, dcn_criteo, deepseek_v2_236b, dlrm_criteo,
               granite_8b, llava_next_34b, mamba2_370m, qwen3_14b,
               seamless_m4t_large_v2, tinyllama_1_1b, yi_34b, zamba2_1_2b)
from .common import SHAPES, ModelApi, Shape, lowerables

_MODULES = [qwen3_14b, tinyllama_1_1b, yi_34b, granite_8b, llava_next_34b,
            zamba2_1_2b, mamba2_370m, seamless_m4t_large_v2, arctic_480b,
            deepseek_v2_236b, dlrm_criteo, dcn_criteo]

ARCHS = {m.ARCH: m for m in _MODULES}
ASSIGNED = [m.ARCH for m in _MODULES[:10]]  # the 10 graded architectures

# long_500k requires sub-quadratic sequence mixing (DESIGN.md §shape-skips)
LONG_OK = {"zamba2-1.2b", "mamba2-370m"}


def get_arch(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def cells():
    """All assigned (arch, shape) dry-run cells, with skips applied."""
    out = []
    for arch in ASSIGNED:
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            if shape == "long_500k" and arch not in LONG_OK:
                continue
            out.append((arch, shape))
    return out
