"""llava-next-34b: yi-34b backbone + anyres patch stub. [hf:llava-hf; unverified]"""
from ..models.lm import LMConfig
from ..models.vlm import VLMConfig
from .common import embedding_spec, vlm_api

ARCH, FAMILY, PARAMS_B = "llava-next-34b", "vlm", 34.8


def config(reduced: bool = False, embedding: str = "qr", num_collisions: int = 4):
    emb = embedding_spec(embedding, num_collisions)
    if reduced:
        lm = LMConfig(name=ARCH, vocab=512, d_model=64, n_layers=2, n_heads=4,
                      n_kv_heads=2, d_head=16, d_ff=128, embedding=emb,
                      param_dtype="float32", compute_dtype="float32", xent_chunk=16)
        return VLMConfig(lm=lm, n_patches=8)
    lm = LMConfig(name=ARCH, vocab=64000, d_model=7168, n_layers=60, n_heads=56,
                  n_kv_heads=8, d_head=128, d_ff=20480, embedding=emb)
    return VLMConfig(lm=lm, n_patches=1152)  # anyres: 2 tiles x 576


def api(cfg):
    return vlm_api(cfg, PARAMS_B)
