"""Shared machinery for architecture configs: the ModelApi adapter layer,
input-spec builders (ShapeDtypeStructs with production shardings), and the
assigned shape grid.

Every ``configs/<arch>.py`` exposes:
    ARCH, FAMILY
    config(reduced=False, embedding="qr") -> cfg dataclass
    api(cfg) -> ModelApi

The dry-run consumes ``lowerables(api, shape_name, mesh)`` which returns the
(callable, sharded arg structs) pairs per shape kind:
    train_*    → train_step(state, batch)
    prefill_*  → prefill(params, *inputs, cache)
    decode_* / long_* → decode_step(params, tokens, pos, cache)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..dist.sharding import batch_axes, spec_for, tree_shardings
from ..optim import optimizers as opt_mod
from ..optim.optimizers import leaf_paths
from ..train.loop import make_train_step

__all__ = ["SHAPES", "Shape", "ModelApi", "lowerables", "sds", "cache_spec",
           "batch_sharding", "param_structs", "state_structs",
           "embedding_spec", "resolve_plan"]


@dataclasses.dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass
class ModelApi:
    name: str
    cfg: Any
    init: Callable                      # key -> params
    loss_fn: Callable                   # (params, batch) -> (loss, metrics)
    optimizer: Any                      # repro Optimizer
    train_batch: Callable               # (shape: Shape) -> batch struct dict
    accum: int = 1                      # gradient-accumulation microbatches
    accum_dtype: str = "float32"        # grad accumulator dtype (bf16 for 100B+)
    prefill_inputs: Optional[Callable] = None   # (shape) -> tuple of structs (pre-cache)
    prefill: Optional[Callable] = None          # (params, *inputs, cache)
    make_cache: Optional[Callable] = None       # (batch, max_len) -> cache
    decode: Optional[Callable] = None           # (params, tokens, pos, cache)
    sub_quadratic: bool = False                 # may run long_500k
    batch_fn: Optional[Callable] = None         # (step, shape) -> real batch (smoke)
    predict: Optional[Callable] = None          # (params, batch) -> scores (rec)


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


# ------------------------------------------------------------------ shardings


def batch_sharding(mesh):
    return batch_axes(mesh)


def _with(mesh, struct, spec):
    return jax.ShapeDtypeStruct(struct.shape, struct.dtype,
                                sharding=NamedSharding(mesh, spec))


def _batch_like_spec(shape, batch, mesh):
    """Spec for batch-shaped inputs: batch dim over (pod,)data, rest replicated."""
    dp = batch_axes(mesh)
    n = int(np.prod([mesh.shape[a] for a in dp]))
    spec = [None] * len(shape)
    if shape and shape[0] % n == 0 and shape[0] >= n:
        spec[0] = dp if len(dp) > 1 else dp[0]
    return P(*spec)


def cache_spec(shape, batch, mesh, prefer_last: bool = False):
    """KV/state-cache sharding: stack dims unsharded, batch→data, one more
    dim→model.

    decode (default): the *largest* divisible dim takes ``model`` (usually
    the sequence axis — decode reads the whole cache, writes one slot).

    prefill (``prefer_last``): the *last* divisible dim takes ``model``
    (head/latent axis) — prefill writes the full sequence, and an S-sharded
    cache would force GSPMD to materialise a replicated copy at the
    dynamic-update-slice (measured +7.5 GB/chip on deepseek prefill_32k).
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = batch_axes(mesh)
    dp_n = int(np.prod([sizes[a] for a in dp]))
    model_n = sizes.get("model", 1)
    spec: list = [None] * len(shape)
    try:
        bi = list(shape).index(batch)
    except ValueError:
        bi = None
    data_placed = False
    if bi is not None and shape[bi] % dp_n == 0 and shape[bi] >= dp_n:
        spec[bi] = dp if len(dp) > 1 else dp[0]
        data_placed = True
    cand = [i for i in range(len(shape)) if i != bi and spec[i] is None]
    if prefer_last:
        cand.sort(key=lambda i: -i)  # rightmost (feature/head) dims first
    else:
        cand.sort(key=lambda i: -shape[i])
    for i in cand:
        if not data_placed and not prefer_last \
                and shape[i] % (dp_n * model_n) == 0 and shape[i] >= dp_n * model_n:
            spec[i] = tuple(dp) + ("model",)
            data_placed = True
            break
        if shape[i] % model_n == 0 and shape[i] >= model_n:
            spec[i] = "model"
            break
    return P(*spec)


def _tree_with_specs(mesh, structs, spec_fn):
    leaves, treedef = jax.tree.flatten(structs)
    out = [_with(mesh, l, spec_fn(l.shape)) for l in leaves]
    return jax.tree.unflatten(treedef, out)


def param_structs(api: ModelApi, mesh, overrides=None):
    structs = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    shardings = tree_shardings(structs, mesh, overrides)
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
        structs, shardings)


def _opt_sharding_like(pstructs, ostructs, mesh):
    """Optimizer-state shardings follow their parameter's spec where shapes
    allow (same-rank prefix match), else drop the incompatible axes."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    p_leaves = jax.tree.leaves(pstructs)
    p_paths = leaf_paths(pstructs)
    p_specs = [spec_for(path, l.shape, mesh) for path, l in zip(p_paths, p_leaves)]

    def fit(spec, shape):
        out = []
        for i, dim in enumerate(shape):
            ax = spec[i] if i < len(spec) else None
            if ax is None:
                out.append(None)
                continue
            n = int(np.prod([sizes[a] for a in (ax if isinstance(ax, tuple) else (ax,))]))
            out.append(ax if (dim % n == 0 and dim >= n) else None)
        return P(*out)

    # opt state is a list parallel to param leaves: state[i] is a dict of arrays
    out_state = []
    for i, leaf_state in enumerate(ostructs):
        spec = p_specs[i]
        out_state.append(jax.tree.map(
            lambda l: _with(mesh, l, fit(spec, l.shape)), leaf_state))
    return out_state


def state_structs(api: ModelApi, mesh):
    """Sharded ShapeDtypeStructs for the full train state."""
    pstructs = param_structs(api, mesh)
    ostructs = jax.eval_shape(api.optimizer.init, pstructs)
    ostructs = _opt_sharding_like(pstructs, ostructs, mesh)
    step = _with(mesh, sds((), jnp.int32), P())
    return {"params": pstructs, "opt": ostructs, "step": step}


# ------------------------------------------------------------------ lowerables


def lowerables(api: ModelApi, shape_name: str, mesh):
    """(callable, ordered arg structs) for one (arch × shape × mesh) cell."""
    from ..dist.sharding import set_batch_shard_axes
    set_batch_shard_axes(batch_axes(mesh), model_size=dict(
        zip(mesh.axis_names, mesh.devices.shape)).get("model", 1))
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        state = state_structs(api, mesh)
        batch = api.train_batch(shape)
        batch = _tree_with_specs(mesh, batch, lambda s: _batch_like_spec(s, shape.global_batch, mesh))
        step = make_train_step(api.loss_fn, api.optimizer, accum=api.accum,
                               accum_dtype=jnp.dtype(api.accum_dtype))
        return step, (state, batch)

    from ..dist.sharding import INFERENCE_OVERRIDES
    params = param_structs(api, mesh, overrides=INFERENCE_OVERRIDES)
    if shape.kind == "prefill":
        inputs = api.prefill_inputs(shape)
        inputs = _tree_with_specs(mesh, inputs, lambda s: _batch_like_spec(s, shape.global_batch, mesh))
        cache = jax.eval_shape(lambda: api.make_cache(shape.global_batch, shape.seq_len))
        cache = _tree_with_specs(mesh, cache, lambda s: cache_spec(
            s, shape.global_batch, mesh, prefer_last=True))
        return api.prefill, (params,) + tuple(inputs) + (cache,)

    # decode: one new token with a cache of seq_len
    tokens = _with(mesh, sds((shape.global_batch, 1), jnp.int32),
                   _batch_like_spec((shape.global_batch, 1), shape.global_batch, mesh))
    pos = _with(mesh, sds((), jnp.int32), P())
    cache = jax.eval_shape(lambda: api.make_cache(shape.global_batch, shape.seq_len))
    cache = _tree_with_specs(mesh, cache, lambda s: cache_spec(s, shape.global_batch, mesh))
    return api.decode, (params, tokens, pos, cache)


# ------------------------------------------------------------------ LM family


def lm_train_batch(cfg, shape: Shape):
    b, s = shape.global_batch, shape.seq_len
    return {"tokens": sds((b, s), jnp.int32), "labels": sds((b, s), jnp.int32),
            "mask": sds((b, s), jnp.float32)}


def default_optimizer(n_params_billion: float):
    """Adam below ~30B params; Adafactor above (state must fit HBM)."""
    if n_params_billion >= 30:
        return opt_mod.adafactor(1e-3)
    return opt_mod.adam(3e-4)


def default_accum(n_params_billion: float) -> int:
    """Gradient-accumulation microbatches for train_4k (batch 256)."""
    if n_params_billion >= 30:
        return 8
    if n_params_billion >= 1:
        return 2
    return 1


def lm_api(cfg, n_params_billion: float, accum: int | None = None) -> ModelApi:
    from ..data import lm as lm_data
    from ..models import lm as lm_mod
    return ModelApi(
        name=cfg.name, cfg=cfg,
        init=lambda key: lm_mod.init(key, cfg),
        loss_fn=lambda p, b: lm_mod.loss_fn(p, b, cfg),
        optimizer=default_optimizer(n_params_billion),
        accum=default_accum(n_params_billion) if accum is None else accum,
        accum_dtype="bfloat16" if n_params_billion >= 100 else "float32",
        train_batch=lambda shape: lm_train_batch(cfg, shape),
        prefill_inputs=lambda shape: (sds((shape.global_batch, shape.seq_len), jnp.int32),),
        prefill=lambda params, tokens, cache: lm_mod.prefill(params, tokens, cache, cfg),
        make_cache=lambda b, ml: lm_mod.make_decode_cache(cfg, b, ml),
        decode=lambda params, tokens, pos, cache: lm_mod.decode_step(
            params, tokens, pos, cache, cfg),
        sub_quadratic=False,
        batch_fn=lambda step, shape: lm_data.batch_at(
            0, step, shape.global_batch, shape.seq_len, cfg.vocab))


def mamba_api(cfg, n_params_billion: float, accum: int | None = None) -> ModelApi:
    from ..data import lm as lm_data
    from ..models import hybrid as hy
    return ModelApi(
        name=cfg.name, cfg=cfg,
        init=lambda key: hy.mamba_init(key, cfg),
        loss_fn=lambda p, b: hy.mamba_loss_fn(p, b, cfg),
        optimizer=default_optimizer(n_params_billion),
        accum=default_accum(n_params_billion) if accum is None else accum,
        train_batch=lambda shape: lm_train_batch(cfg, shape),
        prefill_inputs=lambda shape: (sds((shape.global_batch, shape.seq_len), jnp.int32),),
        prefill=lambda params, tokens, cache: hy.mamba_prefill(params, tokens, cache, cfg),
        make_cache=lambda b, ml: hy.mamba_make_cache(cfg, b, ml),
        decode=lambda params, tokens, pos, cache: hy.mamba_decode_step(
            params, tokens, pos, cache, cfg),
        sub_quadratic=True,
        batch_fn=lambda step, shape: lm_data.batch_at(
            0, step, shape.global_batch, shape.seq_len, cfg.vocab))


def hybrid_api(cfg, n_params_billion: float, accum: int | None = None) -> ModelApi:
    from ..data import lm as lm_data
    from ..models import hybrid as hy
    return ModelApi(
        name=cfg.name, cfg=cfg,
        init=lambda key: hy.hybrid_init(key, cfg),
        loss_fn=lambda p, b: hy.hybrid_loss_fn(p, b, cfg),
        optimizer=default_optimizer(n_params_billion),
        accum=default_accum(n_params_billion) if accum is None else accum,
        train_batch=lambda shape: lm_train_batch(cfg, shape),
        prefill_inputs=lambda shape: (sds((shape.global_batch, shape.seq_len), jnp.int32),),
        prefill=lambda params, tokens, cache: hy.hybrid_prefill(params, tokens, cache, cfg),
        make_cache=lambda b, ml: hy.hybrid_make_cache(cfg, b, ml),
        decode=lambda params, tokens, pos, cache: hy.hybrid_decode_step(
            params, tokens, pos, cache, cfg),
        sub_quadratic=True,
        batch_fn=lambda step, shape: lm_data.batch_at(
            0, step, shape.global_batch, shape.seq_len, cfg.vocab))


def encdec_api(cfg, n_params_billion: float, accum: int | None = None) -> ModelApi:
    from ..data import lm as lm_data
    from ..models import encdec as ed

    def train_batch(shape: Shape):
        b, s = shape.global_batch, shape.seq_len
        return {"frames": sds((b, s // cfg.enc_ratio, cfg.d_model), jnp.bfloat16),
                "tokens": sds((b, s), jnp.int32), "labels": sds((b, s), jnp.int32),
                "mask": sds((b, s), jnp.float32)}

    def batch_fn(step, shape: Shape):
        b = lm_data.batch_at(0, step, shape.global_batch, shape.seq_len, cfg.vocab)
        b["frames"] = lm_data.frames_at(0, step, shape.global_batch,
                                        max(1, shape.seq_len // cfg.enc_ratio),
                                        cfg.d_model).astype(jnp.bfloat16)
        return b

    return ModelApi(
        name=cfg.name, cfg=cfg,
        init=lambda key: ed.encdec_init(key, cfg),
        loss_fn=lambda p, b: ed.encdec_loss_fn(p, b, cfg),
        optimizer=default_optimizer(n_params_billion),
        accum=default_accum(n_params_billion) if accum is None else accum,
        train_batch=train_batch,
        prefill_inputs=lambda shape: (
            sds((shape.global_batch, shape.seq_len // cfg.enc_ratio, cfg.d_model),
                jnp.bfloat16),
            sds((shape.global_batch, shape.seq_len), jnp.int32)),
        prefill=lambda params, frames, tokens, cache: ed.encdec_prefill(
            params, frames, tokens, cache, cfg),
        make_cache=lambda b, ml: ed.encdec_make_cache(cfg, b, ml),
        decode=lambda params, tokens, pos, cache: ed.encdec_decode_step(
            params, tokens, pos, cache, cfg),
        sub_quadratic=False,
        batch_fn=batch_fn)


def vlm_api(cfg, n_params_billion: float, accum: int | None = None) -> ModelApi:
    from ..data import lm as lm_data
    from ..models import vlm as vl

    def train_batch(shape: Shape):
        b = shape.global_batch
        st = shape.seq_len - cfg.n_patches
        return {"patches": sds((b, cfg.n_patches, cfg.lm.d_model), jnp.bfloat16),
                "tokens": sds((b, st), jnp.int32), "labels": sds((b, st), jnp.int32),
                "mask": sds((b, st), jnp.float32)}

    def batch_fn(step, shape: Shape):
        st = shape.seq_len - cfg.n_patches
        b = lm_data.batch_at(0, step, shape.global_batch, st, cfg.lm.vocab)
        b["patches"] = lm_data.patches_at(0, step, shape.global_batch,
                                          cfg.n_patches, cfg.lm.d_model).astype(jnp.bfloat16)
        return b

    return ModelApi(
        name=cfg.name, cfg=cfg,
        init=lambda key: vl.vlm_init(key, cfg),
        loss_fn=lambda p, b: vl.vlm_loss_fn(p, b, cfg),
        optimizer=default_optimizer(n_params_billion),
        accum=default_accum(n_params_billion) if accum is None else accum,
        train_batch=train_batch,
        prefill_inputs=lambda shape: (
            sds((shape.global_batch, cfg.n_patches, cfg.lm.d_model), jnp.bfloat16),
            sds((shape.global_batch, shape.seq_len - cfg.n_patches), jnp.int32)),
        prefill=lambda params, patches, tokens, cache: vl.vlm_prefill(
            params, patches, tokens, cache, cfg),
        make_cache=lambda b, ml: vl.vlm_make_cache(cfg, b, ml),
        decode=lambda params, tokens, pos, cache: vl.vlm_decode_step(
            params, tokens, pos, cache, cfg),
        sub_quadratic=False,
        batch_fn=batch_fn)


def embedding_spec(embedding: str, num_collisions: int = 4):
    from ..core import EmbeddingSpec, factory
    kind = embedding if embedding in factory.KINDS else "qr"
    return EmbeddingSpec(kind=kind, num_collisions=num_collisions, op="mult")


def resolve_plan(plan, table_sizes):
    """A ``repro.plan.MemoryPlan`` (or a path to its JSON artifact) ready
    to serve as a config's ``embedding``: loads if needed and validates
    that it was solved for exactly these table sizes."""
    from ..plan import MemoryPlan
    if isinstance(plan, (str, bytes)):
        plan = MemoryPlan.load(plan)
    plan.validate_sizes(table_sizes)
    return plan
