"""zamba2-1.2b: Mamba2 backbone + shared attention blocks. [arXiv:2411.15242; hf]"""
from ..models.hybrid import HybridLMConfig
from ..nn.ssm import SSMConfig
from .common import embedding_spec, hybrid_api

ARCH, FAMILY, PARAMS_B = "zamba2-1.2b", "hybrid", 1.2


def config(reduced: bool = False, embedding: str = "qr", num_collisions: int = 4):
    emb = embedding_spec(embedding, num_collisions)
    if reduced:
        return HybridLMConfig(name=ARCH, vocab=512, d_model=64, n_blocks=2,
                              block_len=2, n_tail=1,
                              ssm=SSMConfig(d_model=64, d_state=8, headdim=8, chunk=16),
                              n_heads=4, n_kv_heads=4, d_head=16, d_ff=128,
                              embedding=emb, param_dtype="float32",
                              compute_dtype="float32", xent_chunk=16)
    # 6 blocks x 6 mamba layers + shared attn, + 2 tail = 38 mamba layers
    return HybridLMConfig(name=ARCH, vocab=32000, d_model=2048, n_blocks=6,
                          block_len=6, n_tail=2,
                          ssm=SSMConfig(d_model=2048, d_state=64, headdim=64),
                          n_heads=32, n_kv_heads=32, d_head=64, d_ff=8192,
                          embedding=emb)


def api(cfg):
    return hybrid_api(cfg, PARAMS_B)
