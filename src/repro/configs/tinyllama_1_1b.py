"""tinyllama-1.1b: llama2-arch small, 22L x 2048, GQA kv=4. [arXiv:2401.02385; hf]"""
from ..models.lm import LMConfig
from .common import embedding_spec, lm_api

ARCH, FAMILY, PARAMS_B = "tinyllama-1.1b", "dense", 1.1


def config(reduced: bool = False, embedding: str = "qr", num_collisions: int = 4):
    emb = embedding_spec(embedding, num_collisions)
    if reduced:
        return LMConfig(name=ARCH, vocab=512, d_model=64, n_layers=2, n_heads=4,
                        n_kv_heads=2, d_head=16, d_ff=128, embedding=emb,
                        param_dtype="float32", compute_dtype="float32", xent_chunk=16)
    return LMConfig(name=ARCH, vocab=32000, d_model=2048, n_layers=22, n_heads=32,
                    n_kv_heads=4, d_head=64, d_ff=5632, embedding=emb)


def api(cfg):
    return lm_api(cfg, PARAMS_B)
