"""Facebook DLRM on Criteo — the paper's own §5 model (bottom 512-256-64,
top 512-256, D=16)."""
import jax.numpy as jnp

from ..data.criteo import KAGGLE_TABLE_SIZES, CriteoSpec, batch_at
from ..models.dlrm import DLRMConfig, dlrm_forward, dlrm_init, dlrm_loss_fn
from ..optim import optimizers as opt
from .common import ModelApi, embedding_spec, resolve_plan, sds

ARCH, FAMILY, PARAMS_B = "dlrm-criteo", "rec", 0.54

REDUCED_SIZES = (1000, 200, 50000, 12000, 31, 24, 12517, 633, 3, 931)


def config(reduced: bool = False, embedding: str = "qr", num_collisions: int = 4,
           threshold: int = 0, op: str = "mult", path_hidden: int = 64,
           plan=None):
    sizes = REDUCED_SIZES if reduced else KAGGLE_TABLE_SIZES
    if plan is not None:
        # a MemoryPlan (or a path to one) overrides the uniform spec with
        # the planner's per-feature choices
        emb = resolve_plan(plan, sizes)
        return DLRMConfig(name=ARCH, table_sizes=sizes, emb_dim=emb.emb_dim,
                          bottom_mlp=(512, 256, 64), top_mlp=(512, 256),
                          embedding=emb)
    emb = embedding_spec(embedding, num_collisions)
    import dataclasses
    emb = dataclasses.replace(emb, threshold=threshold, op=op,
                              path_hidden=path_hidden)
    return DLRMConfig(name=ARCH, table_sizes=sizes, emb_dim=16,
                      bottom_mlp=(512, 256, 64), top_mlp=(512, 256), embedding=emb)


def api(cfg):
    spec = CriteoSpec(table_sizes=cfg.table_sizes, zipf=1.5, noise=0.5)

    def train_batch(shape):
        b = shape.global_batch
        return {"dense": sds((b, 13), jnp.float32),
                "sparse": sds((b, len(cfg.table_sizes)), jnp.int32),
                "label": sds((b,), jnp.float32)}

    return ModelApi(
        name=cfg.name, cfg=cfg,
        init=lambda key: dlrm_init(key, cfg),
        loss_fn=lambda p, b: dlrm_loss_fn(p, b, cfg),
        optimizer=opt.adagrad(1e-2),  # the paper's optimizer
        train_batch=train_batch,
        batch_fn=lambda step, shape: batch_at(0, step, shape.global_batch, spec),
        predict=lambda p, b: dlrm_forward(p, b["dense"], b["sparse"], cfg))
