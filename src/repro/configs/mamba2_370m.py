"""mamba2-370m: pure SSD stack, attention-free. [arXiv:2405.21060; unverified]"""
from ..models.hybrid import MambaLMConfig
from ..nn.ssm import SSMConfig
from .common import embedding_spec, mamba_api

ARCH, FAMILY, PARAMS_B = "mamba2-370m", "ssm", 0.37


def config(reduced: bool = False, embedding: str = "qr", num_collisions: int = 4):
    emb = embedding_spec(embedding, num_collisions)
    if reduced:
        return MambaLMConfig(name=ARCH, vocab=512, d_model=64, n_layers=2,
                             ssm=SSMConfig(d_model=64, d_state=8, headdim=8, chunk=16),
                             embedding=emb, param_dtype="float32",
                             compute_dtype="float32", xent_chunk=16)
    return MambaLMConfig(name=ARCH, vocab=50280, d_model=1024, n_layers=48,
                         ssm=SSMConfig(d_model=1024, d_state=128, headdim=64),
                         embedding=emb)


def api(cfg):
    return mamba_api(cfg, PARAMS_B, accum=2)
