"""Deep & Cross Network on Criteo — the paper's second §5 model (6 cross
layers, deep 512-256-64, D=16)."""
import jax.numpy as jnp

from ..data.criteo import KAGGLE_TABLE_SIZES, CriteoSpec, batch_at
from ..models.dcn import DCNConfig, dcn_forward, dcn_init, dcn_loss_fn
from ..optim import optimizers as opt
from .common import ModelApi, embedding_spec, resolve_plan, sds
from .dlrm_criteo import REDUCED_SIZES

ARCH, FAMILY, PARAMS_B = "dcn-criteo", "rec", 0.54


def config(reduced: bool = False, embedding: str = "qr", num_collisions: int = 4,
           threshold: int = 0, op: str = "mult", path_hidden: int = 64,
           plan=None):
    sizes = REDUCED_SIZES if reduced else KAGGLE_TABLE_SIZES
    if plan is not None:
        emb = resolve_plan(plan, sizes)
        return DCNConfig(name=ARCH, table_sizes=sizes, emb_dim=emb.emb_dim,
                         cross_layers=6, deep_mlp=(512, 256, 64), embedding=emb)
    emb = embedding_spec(embedding, num_collisions)
    import dataclasses
    emb = dataclasses.replace(emb, threshold=threshold, op=op,
                              path_hidden=path_hidden)
    return DCNConfig(name=ARCH, table_sizes=sizes, emb_dim=16, cross_layers=6,
                     deep_mlp=(512, 256, 64), embedding=emb)


def api(cfg):
    spec = CriteoSpec(table_sizes=cfg.table_sizes, zipf=1.5, noise=0.5)

    def train_batch(shape):
        b = shape.global_batch
        return {"dense": sds((b, 13), jnp.float32),
                "sparse": sds((b, len(cfg.table_sizes)), jnp.int32),
                "label": sds((b,), jnp.float32)}

    return ModelApi(
        name=cfg.name, cfg=cfg,
        init=lambda key: dcn_init(key, cfg),
        loss_fn=lambda p, b: dcn_loss_fn(p, b, cfg),
        optimizer=opt.adam(1e-3, amsgrad=True),  # AMSGrad: paper's best for mult
        train_batch=train_batch,
        batch_fn=lambda step, shape: batch_at(0, step, shape.global_batch, spec),
        predict=lambda p, b: dcn_forward(p, b["dense"], b["sparse"], cfg))
