"""qwen3-14b: dense GQA, qk_norm, 40L x 5120, vocab 151,936. [hf:Qwen/Qwen3-8B; hf]"""
from ..models.lm import LMConfig
from .common import embedding_spec, lm_api

ARCH, FAMILY, PARAMS_B = "qwen3-14b", "dense", 14.7


def config(reduced: bool = False, embedding: str = "qr", num_collisions: int = 4):
    emb = embedding_spec(embedding, num_collisions)
    if reduced:
        return LMConfig(name=ARCH, vocab=512, d_model=64, n_layers=2, n_heads=4,
                        n_kv_heads=2, d_head=16, d_ff=128, qk_norm=True,
                        rope_theta=1e6, embedding=emb, param_dtype="float32",
                        compute_dtype="float32", xent_chunk=16)
    return LMConfig(name=ARCH, vocab=151936, d_model=5120, n_layers=40, n_heads=40,
                    n_kv_heads=8, d_head=128, d_ff=17408, qk_norm=True,
                    rope_theta=1e6, embedding=emb)


def api(cfg):
    return lm_api(cfg, PARAMS_B)
