"""arctic-480b: 128-expert top-2 MoE with parallel dense residual FFN.
[hf:Snowflake/snowflake-arctic-base; hf]"""
from ..models.lm import LMConfig
from ..nn.moe import MoEConfig
from .common import embedding_spec, lm_api

ARCH, FAMILY, PARAMS_B = "arctic-480b", "moe", 476.0


def config(reduced: bool = False, embedding: str = "qr", num_collisions: int = 4):
    emb = embedding_spec(embedding, num_collisions)
    if reduced:
        return LMConfig(name=ARCH, vocab=512, d_model=64, n_layers=2, n_heads=4,
                        n_kv_heads=2, d_head=16, d_ff=128,
                        moe=MoEConfig(n_experts=8, top_k=2, d_model=64, d_ff=96,
                                      groups=8),
                        moe_parallel_dense=True, embedding=emb,
                        param_dtype="float32", compute_dtype="float32", xent_chunk=16)
    return LMConfig(name=ARCH, vocab=32000, d_model=7168, n_layers=35, n_heads=56,
                    n_kv_heads=8, d_head=128, d_ff=4864,
                    moe=MoEConfig(n_experts=128, top_k=2, d_model=7168, d_ff=4864,
                                  groups=256, capacity_factor=1.25),
                    moe_parallel_dense=True, embedding=emb)


def api(cfg):
    return lm_api(cfg, PARAMS_B, accum=8)
