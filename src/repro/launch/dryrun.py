import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# NOTE: the two lines above MUST run before any other import (jax locks the
# device count at first init).  Everything below is ordinary code.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves, without hardware, that the distribution config
is coherent: shardings propagate, collectives lower, and the per-chip
memory/compute footprint is what the roofline analysis consumes.

Artifacts: ``artifacts/dryrun/<arch>__<shape>__<mesh>.json`` with
  * memory_analysis (per-device argument/temp/output bytes),
  * XLA cost_analysis (unscaled) + our scan-aware HLO analysis
    (flops / HBM bytes / collective wire bytes per chip, collective mix),
  * lower/compile wall times.

Resumable: existing artifacts are skipped unless --force.

Usage:
  python -m repro.launch.dryrun --arch tinyllama-1.1b --shape decode_32k
  python -m repro.launch.dryrun --all                  # every cell, both meshes
  python -m repro.launch.dryrun --all --mesh single    # single-pod only
"""

import argparse
import json
import time
import traceback


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             force: bool = False, embedding: str = "qr") -> dict:
    import jax

    from ..configs import get_arch, lowerables
    from .hlo_analysis import analyze_compiled
    from .mesh import make_production_mesh

    mesh_name = "multipod_2x16x16" if multi_pod else "pod_16x16"
    tag = f"{arch}__{shape}__{mesh_name}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    os.makedirs(out_dir, exist_ok=True)

    record = {"arch": arch, "shape": shape, "mesh": mesh_name,
              "embedding": embedding, "ok": False}
    t0 = time.monotonic()
    try:
        mod = get_arch(arch)
        cfg = mod.config(embedding=embedding)
        api = mod.api(cfg)
        mesh = make_production_mesh(multi_pod=multi_pod)
        fn, args = lowerables(api, shape, mesh)
        from ..configs import SHAPES
        kind = SHAPES[shape].kind
        # donate the mutable aggregate (train state / decode+prefill cache):
        # without donation XLA double-buffers multi-GB state trees.
        donate = {"train": (0,), "prefill": (len(args) - 1,),
                  "decode": (3,)}[kind]
        with mesh:
            lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
            record["time_lower_s"] = round(time.monotonic() - t0, 2)
            t1 = time.monotonic()
            compiled = lowered.compile()
            record["time_compile_s"] = round(time.monotonic() - t1, 2)
            analysis = analyze_compiled(compiled, total_devices=mesh.size)
            import gzip
            with gzip.open(os.path.join(out_dir, tag + ".hlo.gz"), "wt") as hf:
                hf.write(compiled.as_text())
            print(compiled.memory_analysis())
            print({k: v for k, v in (analysis.get("xla_cost_analysis") or {}).items()})
        record.update(analysis)
        record["devices"] = mesh.size
        record["ok"] = True
    except Exception as e:  # record the failure — these are bugs to fix
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(record, f, indent=1, default=float)
    os.replace(tmp, path)
    status = "OK" if record["ok"] else "FAIL"
    print(f"[{status}] {tag} lower={record.get('time_lower_s')}s "
          f"compile={record.get('time_compile_s')}s "
          f"flops={record.get('flops_per_chip'):.3g}" if record["ok"] else
          f"[FAIL] {tag}: {record.get('error')}")
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--embedding", default="qr")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    from ..configs import cells
    todo = cells() if args.all else [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    n_ok = n_fail = 0
    for arch, shape in todo:
        for mp in meshes:
            rec = run_cell(arch, shape, mp, args.out, force=args.force,
                           embedding=args.embedding)
            n_ok += rec["ok"]
            n_fail += not rec["ok"]
    print(f"dry-run complete: {n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
