"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Boots a reduced-config model and drives the wave-batched engine with a
synthetic request stream (prompt lengths bucketed, greedy/temperature
sampling).  The decode step it runs is exactly what decode_32k lowers in
the dry-run.
"""

import argparse
import time

import jax


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    from ..configs import get_arch
    from ..serve.engine import ServeEngine

    mod = get_arch(args.arch)
    cfg = mod.config(reduced=True)
    api = mod.api(cfg)
    if api.prefill is None or api.decode is None:
        raise SystemExit(f"{args.arch} has no serving path")
    params = api.init(jax.random.PRNGKey(0))

    n_extra = len(api.prefill_inputs(
        __import__("repro.configs.common", fromlist=["Shape"]).Shape("x", 8, 1, "prefill"))) - 1

    def prefill_fn(tokens, cache):
        if n_extra:  # multimodal stubs: zero frames/patches
            import jax.numpy as jnp
            from ..configs.common import Shape
            structs = api.prefill_inputs(Shape("x", tokens.shape[1], tokens.shape[0], "prefill"))
            extra = tuple(jnp.zeros(s.shape, s.dtype) for s in structs[:-1])
            return api.prefill(params, *extra, tokens, cache)
        return api.prefill(params, tokens, cache)

    engine = ServeEngine(
        prefill_fn=prefill_fn,
        decode_fn=lambda tok, pos, cache: api.decode(params, tok, pos, cache),
        make_cache_fn=api.make_cache,
        batch_size=args.batch_size, max_len=args.max_len,
        temperature=args.temperature)

    for i in range(args.requests):
        plen = 4 if i % 3 else 7
        engine.submit(list(range(1, plen + 1)), max_new_tokens=args.max_new_tokens)
    t0 = time.monotonic()
    done = engine.run_until_drained()
    dt = time.monotonic() - t0
    toks = sum(len(r.output) for r in done.values())
    print(f"{args.arch}: served {len(done)} requests / {toks} tokens in {dt:.2f}s")
    for uid in sorted(done)[:3]:
        print(f"  req {uid}: {done[uid].output}")


if __name__ == "__main__":
    main()
