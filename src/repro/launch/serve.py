"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Dispatches on the arch's model *family* instead of assuming every model
speaks the LM prefill/decode interface:

* LM-family archs boot the wave-batched ``ServeEngine`` (prefill +
  KV-cache decode — exactly what ``decode_32k`` lowers in the dry-run);
* ``rec``-family archs (DLRM/DCN) boot the microbatched ``RecsysEngine``
  over post-training-quantized tables (``--quantize {f32,bf16,int8}``)
  with an optional hot-row cache (``--cache-rows N``, device- or
  host-resident via ``--cache-impl``) and continuous or lock-step wave
  batching (``--batching``), optionally sharded across a serving mesh
  (``--mesh-devices N``: plan-aware placement, remote rows over the
  all-to-all exchange), and report table bytes, p50/p99 latency, QPS,
  and cache hit rate.
"""

import argparse
import time

import jax


def _serve_lm(mod, args):
    from ..configs.common import Shape
    from ..serve.engine import ServeEngine

    cfg = mod.config(reduced=True)
    api = mod.api(cfg)
    if api.prefill is None or api.decode is None:
        raise SystemExit(f"{args.arch} has no LM serving path")
    params = api.init(jax.random.PRNGKey(0))

    n_extra = len(api.prefill_inputs(Shape("x", 8, 1, "prefill"))) - 1

    def prefill_fn(tokens, cache):
        if n_extra:  # multimodal stubs: zero frames/patches
            import jax.numpy as jnp
            structs = api.prefill_inputs(Shape("x", tokens.shape[1],
                                               tokens.shape[0], "prefill"))
            extra = tuple(jnp.zeros(s.shape, s.dtype) for s in structs[:-1])
            return api.prefill(params, *extra, tokens, cache)
        return api.prefill(params, tokens, cache)

    engine = ServeEngine(
        prefill_fn=prefill_fn,
        decode_fn=lambda tok, pos, cache: api.decode(params, tok, pos, cache),
        make_cache_fn=api.make_cache,
        batch_size=args.batch_size, max_len=args.max_len,
        temperature=args.temperature)

    for i in range(args.requests):
        plen = 4 if i % 3 else 7
        engine.submit(list(range(1, plen + 1)), max_new_tokens=args.max_new_tokens)
    t0 = time.monotonic()
    done = engine.run_until_drained()
    dt = time.monotonic() - t0
    toks = sum(len(r.output) for r in done.values())
    print(f"{args.arch}: served {len(done)} requests / {toks} tokens in {dt:.2f}s")
    for uid in sorted(done)[:3]:
        print(f"  req {uid}: {done[uid].output}")


def _serve_rec(mod, args):
    import numpy as np

    from ..serve.cache import DeviceHotRowCache, HotRowCache
    from ..serve.quantize import memory_report, quantize_params
    from ..serve.recsys import RecsysEngine
    from .plan_cli import resolve_plan_args

    obs = None
    if args.trace or args.metrics_out or args.replan_interval:
        from ..obs import Obs
        # the replan controller reads collision telemetry, so --replan-
        # interval forces obs on even without --trace/--metrics-out
        obs = Obs(trace=bool(args.trace), collisions=True)

    plan = resolve_plan_args(mod, args)
    cfg = (mod.config(reduced=True, plan=plan) if plan is not None
           else mod.config(reduced=True))
    api = mod.api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    qparams = quantize_params(params, mode=args.quantize)
    rep = memory_report(params, qparams)
    print(f"{args.arch}: tables {rep['f32_table_bytes']} B f32 -> "
          f"{rep['quant_table_bytes']} B {args.quantize} "
          f"({rep['ratio']:.3f}x)")

    # cache admits combined f32 rows: 4*D bytes each (quantize.row_bytes
    # is the same accounting the planner's serve-cost model uses).  With
    # only --cache-mb given, rows stay unbounded so the byte budget is
    # the binding limit, not a leftover row default; an explicit
    # --cache-rows 0 disables the cache outright, as documented.
    cache_bytes = (int(args.cache_mb * 2 ** 20)
                   if args.cache_mb is not None else None)
    if args.cache_rows == 0 or (cache_bytes is not None and cache_bytes <= 0):
        cache = None  # explicit zero (rows or bytes) disables the cache
    else:
        cache_rows = (args.cache_rows if args.cache_rows is not None
                      else (None if cache_bytes else 4096))
        cls = (DeviceHotRowCache if args.cache_impl == "device"
               else HotRowCache)
        cache = cls(capacity_rows=cache_rows, capacity_bytes=cache_bytes)
    if args.mesh_devices and args.mesh_devices > 1:
        # sharded serving: plan-aware placement over a 1-D serve mesh —
        # the engine places the tables itself (replicate small, row-shard
        # big) and routes remote rows through the all-to-all exchange
        if args.cache_impl == "host" and cache is not None:
            raise SystemExit("--mesh-devices needs --cache-impl device "
                             "(or --cache-rows 0)")
        # the engine requires max_batch % mesh_devices == 0 (each device
        # takes an equal wave slice); round the CLI default up rather
        # than bounce the user on an internal invariant
        n = args.mesh_devices
        batch = -(-args.batch_size // n) * n
        if batch != args.batch_size:
            print(f"  note: --batch-size {args.batch_size} -> {batch} "
                  f"(must be a multiple of --mesh-devices {n})")
        engine = RecsysEngine(cfg, qparams, max_batch=batch,
                              cache=cache, batching=args.batching,
                              mesh_devices=args.mesh_devices, plan=plan,
                              obs=obs)
        pl = engine.placement
        rep = memory_report(params, qparams, placement=pl)
        print(f"  placement: {len(pl.sharded)} sharded / "
              f"{len(pl.replicated)} replicated sub-tables over "
              f"{pl.n_devices} devices, "
              f"{rep['placement']['table_bytes_per_device']} B/device")
    else:
        mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))
        engine = RecsysEngine(cfg, qparams, max_batch=args.batch_size,
                              cache=cache, mesh=mesh,
                              batching=args.batching, obs=obs)

    ctrl = None
    if args.replan_interval:
        from ..online import ReplanController
        from ..plan.planner import full_table_bytes
        if args.mesh_devices and args.mesh_devices > 1:
            raise SystemExit("--replan-interval is single-host "
                             "(swap_plan contract); drop --mesh-devices")
        # re-solve budget: explicit flag > the plan's own budget > the
        # uncompressed f32 footprint (i.e. "no tighter than full tables")
        if args.replan_budget_mb is not None:
            budget = int(args.replan_budget_mb * 2 ** 20)
        elif plan is not None:
            budget = plan.budget_bytes
        else:
            budget = full_table_bytes(cfg.table_sizes, cfg.emb_dim)
        ctrl = ReplanController(engine, budget_bytes=budget,
                                quantize=args.quantize)
        print(f"  replan: every {args.replan_interval} requests, "
              f"budget {budget} B")

    # Zipfian synthetic request stream (the criteo generator's skew)
    rng = np.random.default_rng(0)
    sizes = cfg.table_sizes
    done = {}
    interval = args.replan_interval or args.requests
    for start in range(0, args.requests, interval):
        for _ in range(start, min(start + interval, args.requests)):
            dense = rng.normal(size=cfg.dense_dim)
            bags = []
            for s in sizes:
                ln = int(rng.integers(1, args.max_bag + 1))
                u = rng.random(ln)
                bags.append(list((np.floor((u ** 1.5) * s)).astype(np.int64)))
            engine.submit(dense, bags)
        done.update(engine.run_until_drained())
        if ctrl is not None:
            decision = ctrl.check()
            if decision is not None and decision.fired:
                rep = ctrl.replans[-1]
                print(f"  replan: drift on features {decision.over} -> "
                      f"swapped plan ({rep['plan']['total_bytes']} B, "
                      f"kinds {rep['plan']['kinds']})")
    if ctrl is not None:
        print(f"  replan: {ctrl.checks} windows checked, "
              f"{len(ctrl.replans)} plan swaps")
    m = engine.metrics()
    print(f"{args.arch}: served {len(done)} requests in {m['waves']} waves | "
          f"p50 {m['p50_ms']:.1f} ms  p99 {m['p99_ms']:.1f} ms  "
          f"qps {m['qps']:.1f}")
    if cache is not None:
        print(f"  cache: hit_rate {m['cache']['hit_rate']:.3f} "
              f"({m['cache']['hits']}/{m['cache']['lookups']}), "
              f"{m['cache']['bytes_cached']} B resident")
    if obs is not None:
        ss = engine.stage_summary()
        parts = "  ".join(
            f"{s} {ss[s]['sum'] * 1e3 / max(1, ss[s]['count']):.2f}ms"
            for s in ("probe", "dense", "inflight", "miss_gather", "flush"))
        print(f"  stages (mean/wave): {parts}")
        obs.save(metrics_path=args.metrics_out, trace_path=args.trace)
        for p in (args.metrics_out, args.trace):
            if p:
                print(f"  obs: wrote {p}")
    for uid in sorted(done)[:3]:
        print(f"  req {uid}: score {done[uid].score:+.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--batch-size", type=int, default=4)
    # LM knobs
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--max-new-tokens", type=int, default=12)
    ap.add_argument("--temperature", type=float, default=0.0)
    # recsys knobs
    ap.add_argument("--quantize", default="int8", choices=["f32", "bf16", "int8"])
    ap.add_argument("--cache-rows", type=int, default=None,
                    help="hot-row cache row capacity (0 disables the cache "
                         "entirely; default 4096, or unbounded rows when "
                         "--cache-mb alone is given so the byte budget "
                         "actually binds)")
    ap.add_argument("--cache-mb", type=float, default=None,
                    help="hot-row cache byte budget (admission stops at "
                         "this many MiB of resident f32 rows)")
    ap.add_argument("--cache-impl", default="device",
                    choices=["device", "host"],
                    help="hot-row cache storage: 'device' keeps rows in "
                         "HBM slabs with an in-graph slot-map probe (the "
                         "fast path), 'host' is the PR 3 host-dict cache")
    ap.add_argument("--batching", default="continuous",
                    choices=["continuous", "waves"],
                    help="'continuous' pipelines waves (dispatch ahead "
                         "while earlier waves settle), 'waves' is the "
                         "lock-step pow2 scheduler")
    ap.add_argument("--max-bag", type=int, default=4,
                    help="max multi-hot ids per categorical feature")
    ap.add_argument("--mesh-devices", type=int, default=None,
                    help="serve the tables sharded across this many "
                         "devices (plan-aware placement: replicate small "
                         "sub-tables, row-shard big ones; batch size must "
                         "be a multiple of it)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON of per-wave "
                         "stage timelines to PATH (rec family; implies "
                         "obs on)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics registry as JSONL to PATH "
                         "(rec family; implies obs on)")
    ap.add_argument("--replan-interval", type=int, default=None,
                    help="run the online drift controller: drain and run "
                         "one detector check every N requests, re-solving "
                         "and hot-swapping the plan when drift persists "
                         "(rec family, single-host; implies obs on; off "
                         "by default)")
    ap.add_argument("--replan-budget-mb", type=float, default=None,
                    help="byte budget for online re-solves in MiB "
                         "(default: the current plan's budget, or the "
                         "f32 table footprint when serving unplanned)")
    from .plan_cli import add_plan_args
    add_plan_args(ap)
    args = ap.parse_args()

    from ..configs import get_arch
    mod = get_arch(args.arch)
    if getattr(mod, "FAMILY", "lm") == "rec":
        _serve_rec(mod, args)
    else:
        _serve_lm(mod, args)


if __name__ == "__main__":
    main()
