"""Scan-aware cost analysis of optimized (post-SPMD) HLO text.

Why this exists: ``compiled.cost_analysis()`` visits each while body ONCE —
a 60-layer ``lax.scan`` transformer reports ~1/60 of its real FLOPs (we
verified this empirically).  Since the whole roofline methodology rests on
per-chip FLOPs / HBM bytes / collective wire bytes, we parse the optimized
HLO ourselves and multiply every while body by its trip count (XLA attaches
``backend_config={"known_trip_count":{"n":...}}`` to while ops).

Accounting rules (per-device program — SPMD shapes are already per-chip):
  * FLOPs: ``dot`` = 2 · |out| · K (K = product of lhs contracting dims);
    convolutions = 2 · |out| · K_window · C_in / groups; elementwise ignored
    (≪1% for these models).  Recurses into all called computations.
  * HBM bytes: per instruction = output + operand bytes, skipping pure
    plumbing (parameter/constant/tuple/get-tuple-element/bitcast) and
    *not* recursing into fusion bodies (fusion internals live in registers/
    cache — the fusion call site's operands/outputs are the HBM traffic).
    Recurses into while/conditional/call bodies with multipliers.
  * Collective wire bytes per chip, ring formulas with group size n:
      all-reduce       2·(n−1)/n · bytes
      all-gather       (n−1)/n · bytes        (result = gathered size)
      reduce-scatter   (n−1) · bytes          (result = scattered shard)
      all-to-all       (n−1)/n · bytes
      collective-permute   bytes
    ``*-start``/``*-done`` async pairs are counted once (at start).
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

__all__ = ["analyze_hlo", "HloCost"]

_ITEM = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
         "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
         "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
         "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1}

_SHAPE_RE = re.compile(r"(" + "|".join(_ITEM) + r")\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->\s+.*\{\s*$")
_INSTR_RE = re.compile(r"^\s*(ROOT\s+)?%([\w.\-]+)\s+=\s+(.*?)\s+([a-z][a-z0-9\-]*)\(")
_CALL_ATTRS = ("calls=", "body=", "condition=", "to_apply=", "branch_computations=")
_COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all"}
_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
               "while", "conditional", "call", "after-all", "partition-id",
               "replica-id", "custom-call", "copy-start", "copy-done", "opt-barrier"}


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _ITEM[dt]
    return total


def _type_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class _Instr:
    name: str
    type_str: str
    opcode: str
    line: str


def _parse(text: str):
    comps: dict[str, list[_Instr]] = {}
    entry = None
    cur: list[_Instr] | None = None
    for line in text.splitlines():
        m = _COMP_HDR.match(line)
        if m:
            name = m.group(2)
            comps[name] = cur = []
            if m.group(1):
                entry = name
            continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            cur.append(_Instr(mi.group(2), mi.group(3), mi.group(4), line))
    return comps, entry


def _called(instr: _Instr) -> list[str]:
    out = []
    for attr in _CALL_ATTRS:
        for m in re.finditer(re.escape(attr) + r"\{?%?([\w.\-]+)", instr.line):
            name = m.group(1)
            out.append(name)
        if attr == "branch_computations=":
            m = re.search(r"branch_computations=\{([^}]*)\}", instr.line)
            if m:
                out.extend(x.strip().lstrip("%") for x in m.group(1).split(","))
    return out


def _trip_count(instr: _Instr) -> int | None:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', instr.line)
    return int(m.group(1)) if m else None


def _group_size(instr: _Instr, total_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", instr.line)  # iota form
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", instr.line)
    if m:
        return len(m.group(1).split(","))
    return total_devices


def _dot_flops(instr: _Instr, symtab: dict[str, str]) -> float:
    out_elems = 1
    for d in _type_dims(instr.type_str):
        out_elems *= d
    # operands may carry inline types ("dot(f32[64,64]{1,0} %x, ...)") — take
    # the first %name after the opcode's paren, whatever precedes it
    ops = re.search(r"%([\w.\-]+)",
                    instr.line[instr.line.index(instr.opcode + "(") + len(instr.opcode) + 1:])
    lhs_name = ops.group(1) if ops else None
    k = 1
    mc = re.search(r"lhs_contracting_dims=\{([0-9, ]*)\}", instr.line)
    if lhs_name and lhs_name in symtab and mc and mc.group(1).strip():
        lhs_dims = _type_dims(symtab[lhs_name])
        for i in mc.group(1).split(","):
            idx = int(i)
            if idx < len(lhs_dims):
                k *= lhs_dims[idx]
    return 2.0 * out_elems * k


def _conv_flops(instr: _Instr, symtab: dict[str, str]) -> float:
    out_elems = 1
    for d in _type_dims(instr.type_str):
        out_elems *= d
    names = re.findall(r"%([\w.\-]+)",
                       instr.line[instr.line.index(instr.opcode + "(") + len(instr.opcode) + 1:])
    if len(names) < 2:
        return 0.0
    rhs = symtab.get(names[1], "")
    kdims = _type_dims(rhs)
    k = 1
    for d in kdims[:-1]:  # window dims * input features (approx; layout-dependent)
        k *= d
    return 2.0 * out_elems * k


def _operand_bytes_list(instr: _Instr, symtab: dict[str, str]) -> list[int]:
    seg = instr.line[instr.line.index(instr.opcode + "(") + len(instr.opcode) + 1:]
    # stop at attrs — operands are the leading %names
    out = []
    for m in re.finditer(r"%([\w.\-]+)", seg.split("), ")[0]):
        t = symtab.get(m.group(1))
        if t:
            out.append(_type_bytes(t))
    return out


# ops that touch only a slice of their big operand (in-place / gather):
# counting the full operand would charge a 35-layer weight stack per layer.
_SLICE_READS = {"dynamic-slice", "gather"}
_SLICE_WRITES = {"dynamic-update-slice", "scatter"}


def _instr_hbm_bytes(instr: _Instr, symtab: dict[str, str], comps) -> int:
    op = instr.opcode
    root_op = op
    if op == "fusion":
        callees = _called(instr)
        if callees:
            body = comps.get(callees[0], [])
            roots = [i for i in body if "ROOT" in i.line]
            if roots:
                root_op = roots[0].opcode
    out_b = _type_bytes(instr.type_str)
    ops_b = _operand_bytes_list(instr, symtab)
    if root_op in _SLICE_READS:
        return 2 * out_b  # read the slice + write the result
    if root_op in _SLICE_WRITES:
        # in-place: read+write the update region (operands minus the buffer)
        upd = sum(ops_b) - max(ops_b) if len(ops_b) > 1 else out_b
        return 2 * max(upd, 0)
    return out_b + sum(ops_b)


@dataclasses.dataclass
class HloCost:
    flops: float
    hbm_bytes: float
    collective_bytes: float
    collectives: dict
    notes: list


def analyze_hlo(text: str, total_devices: int = 1) -> HloCost:
    comps, entry = _parse(text)
    symtabs = {name: {i.name: i.type_str for i in instrs}
               for name, instrs in comps.items()}
    notes: list[str] = []
    coll_detail: dict[str, dict] = defaultdict(lambda: {"count": 0.0, "wire_bytes": 0.0})
    memo: dict[tuple[str, bool], tuple[float, float, float]] = {}

    def comp_cost(name: str, in_fusion: bool) -> tuple[float, float, float]:
        key = (name, in_fusion)
        if key in memo:
            return memo[key]
        memo[key] = (0.0, 0.0, 0.0)  # cycle guard
        flops = hbm = coll = 0.0
        symtab = symtabs.get(name, {})
        for instr in comps.get(name, []):
            op = instr.opcode
            base = op[:-6] if op.endswith("-start") else op
            if op.endswith("-done"):
                continue
            if op == "dot":
                flops += _dot_flops(instr, symtab)
            elif op == "convolution":
                flops += _conv_flops(instr, symtab)
            if base in _COLLECTIVES:
                n = _group_size(instr, total_devices)
                b = _type_bytes(instr.type_str)
                if base == "all-reduce":
                    wire = 2.0 * (n - 1) / n * b
                elif base == "all-gather":
                    wire = (n - 1) / n * b
                elif base == "reduce-scatter":
                    wire = float(n - 1) * b
                elif base in ("all-to-all", "ragged-all-to-all"):
                    wire = (n - 1) / n * b
                else:  # collective-permute
                    wire = float(b)
                coll += wire
                coll_detail[base]["count"] += 1
                coll_detail[base]["wire_bytes"] += wire
            if not in_fusion and op not in _SKIP_BYTES and base not in _COLLECTIVES:
                hbm += _instr_hbm_bytes(instr, symtab, comps)
            # recurse into called computations
            callees = _called(instr)
            if not callees:
                continue
            mult = 1.0
            child_fusion = in_fusion or op == "fusion" or op == "reduce" or op == "sort" \
                or op == "scatter" or op == "select-and-scatter" or op == "map"
            if op == "while":
                tc = _trip_count(instr)
                if tc is None:
                    tc = 1
                    notes.append(f"while {instr.name} in {name}: unknown trip count (×1)")
                mult = float(tc)
            for c in callees:
                cf, ch, cc = comp_cost(c, child_fusion)
                if op == "while":
                    # condition runs trips+1 times; body runs trips times — both ~tc
                    flops += cf * mult
                    hbm += ch * mult
                    coll += cc * mult
                    if cc:
                        _scale_last(coll_detail, cc, mult)
                else:
                    flops += cf
                    hbm += ch
                    coll += cc
        memo[key] = (flops, hbm, coll)
        return memo[key]

    def _scale_last(detail, child_bytes, mult):
        # while-body collectives already added once during recursion memo; add the
        # remaining (mult-1)× to the aggregate breakdown under a loop marker.
        detail["(in-loop-extra)"]["count"] += 0
        detail["(in-loop-extra)"]["wire_bytes"] += child_bytes * (mult - 1)

    if entry is None:
        return HloCost(0, 0, 0, {}, ["no ENTRY computation found"])
    flops, hbm, coll = comp_cost(entry, False)
    return HloCost(flops, hbm, coll, {k: dict(v) for k, v in coll_detail.items()},
                   notes)


_UPCAST_RE = re.compile(
    r"= f32\[([0-9,]+)\]\S*\s+(convert|fusion)\(%?\S*?param")


def cpu_upcast_bytes(text: str) -> int:
    """Bytes of hoisted bf16→f32 *weight copies* the XLA CPU backend makes
    because it has no native bf16 dot.  These buffers do not exist on TPU
    (bf16 is MXU-native), so the TPU-expected temp memory is
    ``temp_size - cpu_upcast_bytes``.  Heuristic: f32 converts/convert-
    fusions of parameters ≥ 1 MiB, counted once per distinct shape+source.
    """
    seen = set()
    total = 0
    for line in text.splitlines():
        m = _UPCAST_RE.search(line)
        if not m:
            continue
        dims = [int(x) for x in m.group(1).split(",") if x]
        n = 4
        for d in dims:
            n *= d
        if n < 1 << 20:
            continue
        key = line.strip().split(" = ")[0]
        if key in seen:
            continue
        seen.add(key)
        total += n
    return total


def analyze_compiled(compiled, total_devices: int = 1) -> dict:
    """Full record for a compiled executable: parser + XLA's own numbers."""
    cost = analyze_hlo(compiled.as_text(), total_devices)
    xla = {}
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):  # older jax returns [dict] per program
            ca = ca[0] if ca else {}
        xla = {k: float(v) for k, v in ca.items()
               if isinstance(v, (int, float)) and k in ("flops", "bytes accessed")}
    except Exception as e:  # pragma: no cover
        xla = {"error": str(e)}
    mem = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            if hasattr(ma, attr):
                mem[attr] = int(getattr(ma, attr))
        up = cpu_upcast_bytes(compiled.as_text())
        # liveness cap: at peak, at most one f32 copy of every bf16 weight
        # (= 2x the bf16 argument bytes) can be resident simultaneously.
        up = min(up, 2 * mem.get("argument_size_in_bytes", up))
        mem["cpu_bf16_upcast_bytes"] = up
        if "temp_size_in_bytes" in mem:
            mem["temp_tpu_expected_bytes"] = max(0, mem["temp_size_in_bytes"] - up)
    except Exception as e:  # pragma: no cover
        mem = {"error": str(e)}
    return {
        "flops_per_chip": cost.flops,
        "hbm_bytes_per_chip": cost.hbm_bytes,
        "collective_wire_bytes_per_chip": cost.collective_bytes,
        "collectives": cost.collectives,
        "notes": cost.notes,
        "xla_cost_analysis": xla,
        "memory_analysis": mem,
    }
