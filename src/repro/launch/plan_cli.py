"""Shared ``--plan`` / ``--plan-budget-mb`` CLI resolution for the
train/serve launchers.

``--plan <path>`` loads a solved ``MemoryPlan`` artifact; ``--plan-budget-mb
<float>`` synthesizes one on the fly against the arch's table sizes (the
synthetic Criteo frequency stream) and saves it under ``artifacts/plans/``
so the decision is auditable and reusable.  The two flags are mutually
exclusive; both yield a plan the arch's ``config(plan=...)`` consumes.
"""

from __future__ import annotations

__all__ = ["add_plan_args", "resolve_plan_args"]


def add_plan_args(ap) -> None:
    ap.add_argument("--plan", default=None,
                    help="path to a repro.plan MemoryPlan JSON: per-feature "
                         "table strategies replace the uniform --embedding")
    ap.add_argument("--plan-budget-mb", type=float, default=None,
                    help="synthesize a memory plan on the fly at this table "
                         "byte budget (saved under artifacts/plans/)")
    ap.add_argument("--plan-dims", default=None,
                    help="width ladder for --plan-budget-mb: 'mixed' for "
                         "the default {D/4, D/2, D} mixed-dimension axis, "
                         "or an explicit comma list like '4,8,16' "
                         "(default: uniform width = the arch's emb_dim)")


def resolve_plan_args(mod, args):
    """A MemoryPlan from the CLI flags, or None when neither is given."""
    plan_path_arg = getattr(args, "plan", None)
    budget_mb = getattr(args, "plan_budget_mb", None)
    if plan_path_arg is None and budget_mb is None:
        if getattr(args, "plan_dims", None) is not None:
            raise SystemExit("--plan-dims needs --plan-budget-mb (it sets "
                             "the width ladder for plan synthesis)")
        return None
    if plan_path_arg is not None and budget_mb is not None:
        raise SystemExit("--plan and --plan-budget-mb are mutually exclusive")
    if getattr(mod, "FAMILY", "lm") != "rec":
        # only the rec configs grow a plan= kwarg; fail with intent, not a
        # TypeError from config()
        raise SystemExit("--plan/--plan-budget-mb size categorical tables; "
                         f"{args.arch} is not a rec-family arch")
    from ..plan import MemoryPlan, dim_ladder, plan_for_config, plan_path
    if plan_path_arg is not None:
        if getattr(args, "plan_dims", None) is not None:
            raise SystemExit("--plan-dims only applies when synthesizing "
                             "via --plan-budget-mb (a loaded plan already "
                             "fixed its widths)")
        plan = MemoryPlan.load(plan_path_arg)
        print(f"plan: loaded {plan_path_arg} "
              f"({plan.total_bytes / 2**20:.2f} MiB of "
              f"{plan.budget_bytes / 2**20:.2f} MiB budget, "
              f"quality {plan.quality:.4f})")
        return plan
    budget = int(budget_mb * 2 ** 20)
    cfg = mod.config(reduced=getattr(args, "reduced", True))
    dims_arg = getattr(args, "plan_dims", None)
    if dims_arg is None:
        dims = None
    elif dims_arg == "mixed":
        dims = dim_ladder(cfg.emb_dim)
    else:
        dims = tuple(int(d) for d in dims_arg.split(","))
    plan = plan_for_config(cfg, budget, arch=args.arch, dims=dims)
    out = plan.save(plan_path(args.arch, budget))
    s = plan.summary()
    print(f"plan: solved {args.arch} at {budget_mb:g} MiB "
          f"({s['budget_frac_of_full']:.3f}x full tables) -> {out}")
    print(f"plan: quality {plan.quality:.4f} vs uniform-hash "
          f"{plan.baseline_quality:.4f}; kinds {s['kinds']}; "
          f"dims {s['dims']}; parked upgrades {s['parked']}")
    return plan
