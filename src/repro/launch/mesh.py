"""Production mesh builders.

Functions, not module-level constants — importing this module never touches
jax device state (smoke tests must keep seeing 1 CPU device; only
``dryrun.py`` forces 512 host devices via XLA_FLAGS before any jax import).

Production target: TPU v5e pods, 256 chips each.
  single pod : (data=16, model=16)
  multi-pod  : (pod=2, data=16, model=16)  — 512 chips
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "HW"]

# Hardware constants used by the roofline analysis (TPU v5e).
HW = {
    "peak_bf16_flops": 197e12,   # per chip
    "hbm_bw": 819e9,             # bytes/s per chip
    "ici_bw": 50e9,              # bytes/s per link
    "hbm_per_chip": 16 * 1024 ** 3,
}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests with forced host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))
