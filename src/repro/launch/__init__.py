"""Launch: mesh, dryrun, train, serve CLIs."""
