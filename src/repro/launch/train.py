"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it runs reduced configs end-to-end (the same code
path the production mesh lowers — pjit step, sharded loader, async
checkpoints, restart-safe).  On a real cluster the only changes are
``--mesh`` and full-scale ``--no-reduced``.
"""

import argparse

import jax
import numpy as np

from .plan_cli import add_plan_args, resolve_plan_args


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dlrm-criteo")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--embedding", default="qr")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--no-reduced", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=20)
    ap.add_argument("--compress-policy", default=None,
                    choices=["auto", "none", "bf16", "int8"],
                    help="gradient-compression policy for the explicit "
                         "data-parallel step (repro.dist.policy); omit for "
                         "the plain pjit step")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome-trace/Perfetto JSON of per-step "
                         "spans to PATH (implies obs on)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics registry as JSONL to PATH "
                         "(implies obs on)")
    add_plan_args(ap)
    args = ap.parse_args()

    from ..configs import get_arch
    from ..configs.common import Shape
    from ..train.loop import (TrainConfig, Trainer, init_dp_state, init_state,
                              make_dp_train_step, make_train_step)

    mod = get_arch(args.arch)
    plan = resolve_plan_args(mod, args)
    if plan is not None:
        cfg = mod.config(reduced=args.reduced, plan=plan)
    else:
        cfg = mod.config(reduced=args.reduced, embedding=args.embedding)
    api = mod.api(cfg)
    shape = Shape("cli", args.seq_len, args.batch, "train")

    params = api.init(jax.random.PRNGKey(0))
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    emb_desc = "plan" if plan is not None else args.embedding
    print(f"{args.arch}: {n:,} parameters (embedding={emb_desc})")

    if args.compress_policy is not None:
        # ROADMAP follow-up: the policy engine, selectable from the CLI.
        # Explicit shard_map DP step over every local device; "auto" is the
        # per-leaf rule table (int8 tables / bf16 dense / none small).
        n_dev = jax.device_count()
        if args.batch % n_dev:
            raise SystemExit(f"--batch {args.batch} must be a multiple of "
                             f"the device count {n_dev} for the dp step")
        mesh = jax.make_mesh((n_dev,), ("data",))
        state = init_dp_state(params, api.optimizer,
                              compress=args.compress_policy)
        step = make_dp_train_step(api.loss_fn, api.optimizer, mesh,
                                  compress=args.compress_policy)
        print(f"dp step over {n_dev} device(s), "
              f"compress={args.compress_policy}")
    else:
        state = init_state(params, api.optimizer)
        step = make_train_step(api.loss_fn, api.optimizer)
    obs = step_wire = None
    if args.trace or args.metrics_out:
        from ..obs import Obs
        obs = Obs(trace=bool(args.trace))
        if args.compress_policy is not None:
            # accounted per-leaf wire bytes of one dp step -> counters
            from ..dist.accounting import grad_wire_bytes
            step_wire = grad_wire_bytes(params, args.compress_policy,
                                        jax.device_count())
    tc = TrainConfig(num_steps=args.steps, log_every=args.log_every,
                     ckpt_every=max(50, args.steps // 4), ckpt_dir=args.ckpt_dir)
    trainer = Trainer(step, tc, batch_at=lambda s: api.batch_fn(s, shape),
                      obs=obs, step_wire=step_wire)
    state = trainer.resume_or(state)
    state, history = trainer.run(state)
    for step, loss in history:
        print(f"step {step:5d}  loss {loss:.4f}")
    if trainer.straggler_events:
        print("straggler events:", trainer.straggler_events)
    if obs is not None:
        obs.save(metrics_path=args.metrics_out, trace_path=args.trace)
        for p in (args.metrics_out, args.trace):
            if p:
                print(f"obs: wrote {p}")


if __name__ == "__main__":
    main()
