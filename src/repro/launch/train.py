"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it runs reduced configs end-to-end (the same code
path the production mesh lowers — pjit step, sharded loader, async
checkpoints, restart-safe).  On a real cluster the only changes are
``--mesh`` and full-scale ``--no-reduced``.
"""

import argparse
import os

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dlrm-criteo")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--embedding", default="qr")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--no-reduced", dest="reduced", action="store_false")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--log-every", type=int, default=20)
    args = ap.parse_args()

    from ..configs import get_arch
    from ..configs.common import Shape
    from ..train.loop import TrainConfig, Trainer, init_state, make_train_step

    mod = get_arch(args.arch)
    cfg = mod.config(reduced=args.reduced, embedding=args.embedding)
    api = mod.api(cfg)
    shape = Shape("cli", args.seq_len, args.batch, "train")

    params = api.init(jax.random.PRNGKey(0))
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"{args.arch}: {n:,} parameters (embedding={args.embedding})")

    state = init_state(params, api.optimizer)
    tc = TrainConfig(num_steps=args.steps, log_every=args.log_every,
                     ckpt_every=max(50, args.steps // 4), ckpt_dir=args.ckpt_dir)
    trainer = Trainer(make_train_step(api.loss_fn, api.optimizer), tc,
                      batch_at=lambda s: api.batch_fn(s, shape))
    state = trainer.resume_or(state)
    state, history = trainer.run(state)
    for step, loss in history:
        print(f"step {step:5d}  loss {loss:.4f}")
    if trainer.straggler_events:
        print("straggler events:", trainer.straggler_events)


if __name__ == "__main__":
    main()
