"""Serve a small LM (QR-compressed vocab) with batched requests.

Demonstrates the serving engine: queue → length-bucketed waves → batched
prefill → lock-step KV-cache decode, with greedy or temperature sampling.

Run: PYTHONPATH=src python examples/serve_lm.py
"""

import time

import jax

from repro.core import EmbeddingSpec
from repro.models import lm as lm_mod
from repro.models.lm import LMConfig
from repro.serve.engine import ServeEngine


def main():
    cfg = LMConfig(name="serve-demo", vocab=4096, d_model=256, n_layers=4,
                   n_heads=8, n_kv_heads=4, d_head=32, d_ff=704,
                   embedding=EmbeddingSpec(kind="qr", num_collisions=4),
                   param_dtype="float32", compute_dtype="float32")
    params = lm_mod.init(jax.random.PRNGKey(0), cfg)

    engine = ServeEngine(
        prefill_fn=lambda toks, cache: lm_mod.prefill(params, toks, cache, cfg),
        decode_fn=lambda tok, pos, cache: lm_mod.decode_step(params, tok, pos, cache, cfg),
        make_cache_fn=lambda b, ml: lm_mod.make_decode_cache(cfg, b, ml),
        batch_size=8, max_len=128, temperature=0.8, seed=0)

    # a burst of requests with two prompt lengths (two waves)
    prompts = [[1, 2, 3, 4, 5, 6, 7, 8]] * 10 + [[42, 43, 44]] * 5
    uids = [engine.submit(p, max_new_tokens=16) for p in prompts]
    t0 = time.monotonic()
    done = engine.run_until_drained()
    dt = time.monotonic() - t0
    total_tokens = sum(len(r.output) for r in done.values())
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens / dt:.0f} tok/s on CPU)")
    for uid in uids[:3]:
        print(f"request {uid}: {done[uid].output}")


if __name__ == "__main__":
    main()
