"""Quickstart: the paper's technique in 40 lines.

Builds a QR compositional embedding, shows uniqueness + compression, and
swaps it into a DLRM via EmbeddingSpec.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (EmbeddingSpec, FullEmbedding, codes_for,
                        is_complementary, qr_embedding, qr_partitions)

# --- 1. complementary partitions (paper §3) -------------------------------
size = 10_000
parts = qr_partitions(size, m=2500)  # quotient + remainder
assert is_complementary(parts, size)
print(f"partitions: {parts[0].num_buckets} remainder buckets, "
      f"{parts[1].num_buckets} quotient buckets")

# --- 2. compositional embedding (paper §2/§4) ------------------------------
emb = qr_embedding(size, dim=16, num_collisions=4, op="mult")
params = emb.init(jax.random.PRNGKey(0))
full = FullEmbedding(size, 16)
print(f"params: full={full.num_params:,} qr={emb.num_params:,} "
      f"({full.num_params / emb.num_params:.1f}x smaller)")

# every category still gets a UNIQUE embedding (Theorem 1)
rows = np.asarray(emb.apply(params, jnp.arange(size)))
assert len(np.unique(rows.round(8), axis=0)) == size
print("uniqueness: all", size, "categories map to distinct vectors")

# --- 3. drop into a model via EmbeddingSpec --------------------------------
from repro.data.criteo import CriteoSpec, batch_at
from repro.models.dlrm import DLRMConfig, dlrm_init, dlrm_loss_fn

spec = EmbeddingSpec(kind="qr", num_collisions=4, op="mult", threshold=200)
cfg = DLRMConfig(table_sizes=(1000, 50_000, 120, 8), embedding=spec)
model_params = dlrm_init(jax.random.PRNGKey(1), cfg)
batch = batch_at(0, 0, 32, CriteoSpec(table_sizes=cfg.table_sizes))
loss, metrics = jax.jit(lambda p, b: dlrm_loss_fn(p, b, cfg))(model_params, batch)
print(f"DLRM-with-QR forward: loss={float(loss):.4f} acc={float(metrics['acc']):.3f}")
print("quickstart OK")
