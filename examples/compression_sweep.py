"""Budget → plan → model: the planner-driven compression sweep.

The paper's Fig. 5 trade-off (params vs quality), but instead of
hand-enumerating per-feature specs, each point asks ``repro.plan`` for
the best allocation at a byte budget: frequency stats are streamed from
the synthetic Criteo generator, the Lagrangian-greedy knapsack picks
full / hash / QR / mixed-radix per feature, and the resulting
``MemoryPlan`` drops straight into ``DLRMConfig.embedding``.

Each budget prints the planner's analytic quality proxy next to the
*trained* loss of (a) the planned model and (b) the uniform-hashing
control at the same budget — the proxy's job is to rank allocations
without training, so the two orderings should agree.

Run: PYTHONPATH=src python examples/compression_sweep.py
"""

import jax
import numpy as np

from repro.data.criteo import CriteoSpec, batch_at
from repro.models.dlrm import DLRMConfig, dlrm_init, dlrm_loss_fn, dlrm_num_params
from repro.optim.optimizers import adagrad
from repro.plan import (build_plan, full_table_bytes, stats_from_criteo,
                        uniform_hash_plan)
from repro.train.loop import init_state, make_train_step

SIZES = (1000, 200, 50000, 12000, 31, 24, 12517, 633, 3, 931)
SPEC = CriteoSpec(table_sizes=SIZES, zipf=1.5, noise=0.5)
DIM = 16
BUDGET_FRACS = (0.05, 0.125, 0.25, 0.5)


def train(embedding, steps=250, batch=256):
    cfg = DLRMConfig(table_sizes=SIZES, emb_dim=DIM, embedding=embedding)
    params = dlrm_init(jax.random.PRNGKey(0), cfg)
    opt = adagrad(1e-2)
    state = init_state(params, opt)
    step = jax.jit(make_train_step(lambda p, b: dlrm_loss_fn(p, b, cfg), opt))
    for i in range(steps):
        state, _ = step(state, batch_at(0, i, batch, SPEC))
    ev = jax.jit(lambda p, b: dlrm_loss_fn(p, b, cfg))
    loss = np.mean([float(ev(state["params"], batch_at(0, i, batch, SPEC))[0])
                    for i in range(10_000, 10_008)])
    return dlrm_num_params(cfg), loss


def main():
    from repro.core import EmbeddingSpec
    stats = stats_from_criteo(SPEC, num_batches=16, batch_size=512)
    full = full_table_bytes(SIZES, DIM)
    n0, l0 = train(EmbeddingSpec(kind="full"))
    print(f"{'treatment':26s} {'params':>10s} {'ratio':>6s} "
          f"{'proxy':>8s} {'loss':>8s}")
    print(f"{'full':26s} {n0:>10,} {1.0:>6.1f} {1.0:>8.4f} {l0:>8.4f}")
    for frac in BUDGET_FRACS:
        budget = int(full * frac)
        uni = uniform_hash_plan(stats, DIM, budget, arch="dlrm-criteo")
        plan = build_plan(stats, DIM, budget, arch="dlrm-criteo",
                          baseline=uni)
        n_p, l_p = train(plan)
        n_u, l_u = train(uni)
        kinds = "+".join(f"{k}:{v}" for k, v in
                         sorted(plan.summary()["kinds"].items()))
        print(f"{'plan/' + f'{frac:g}x':26s} {n_p:>10,} {n0 / n_p:>6.1f} "
              f"{plan.quality:>8.4f} {l_p:>8.4f}   [{kinds}]")
        print(f"{'uniform-hash/' + f'{frac:g}x':26s} {n_u:>10,} "
              f"{n0 / n_u:>6.1f} {uni.quality:>8.4f} {l_u:>8.4f}")


if __name__ == "__main__":
    main()
