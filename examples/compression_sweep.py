"""Compression-vs-quality sweep (the paper's core trade-off, Fig. 5).

Trains a DLRM at several collision counts and operations, printing the
params/loss frontier.  A miniature of benchmarks/paper_tables.fig5.

Run: PYTHONPATH=src python examples/compression_sweep.py
"""

import jax
import numpy as np

from repro.core import EmbeddingSpec
from repro.data.criteo import CriteoSpec, batch_at
from repro.models.dlrm import DLRMConfig, dlrm_init, dlrm_loss_fn, dlrm_num_params
from repro.optim.optimizers import adagrad
from repro.train.loop import init_state, make_train_step

SIZES = (1000, 200, 50000, 12000, 31, 24, 12517, 633, 3, 931)
SPEC = CriteoSpec(table_sizes=SIZES, zipf=1.5, noise=0.5)


def run(embedding: EmbeddingSpec, steps=250, batch=256):
    cfg = DLRMConfig(table_sizes=SIZES, embedding=embedding)
    params = dlrm_init(jax.random.PRNGKey(0), cfg)
    opt = adagrad(1e-2)
    state = init_state(params, opt)
    step = jax.jit(make_train_step(lambda p, b: dlrm_loss_fn(p, b, cfg), opt))
    for i in range(steps):
        state, _ = step(state, batch_at(0, i, batch, SPEC))
    ev = jax.jit(lambda p, b: dlrm_loss_fn(p, b, cfg))
    loss = np.mean([float(ev(state["params"], batch_at(0, i, batch, SPEC))[0])
                    for i in range(10_000, 10_008)])
    return dlrm_num_params(cfg), loss


def main():
    n0, l0 = run(EmbeddingSpec(kind="full"))
    print(f"{'treatment':22s} {'params':>10s} {'ratio':>6s} {'loss':>8s}")
    print(f"{'full':22s} {n0:>10,} {1.0:>6.1f} {l0:>8.4f}")
    for c in (2, 4, 16):
        for kind, op in (("hash", "mult"), ("qr", "mult"), ("qr", "concat")):
            n, l = run(EmbeddingSpec(kind=kind, num_collisions=c, op=op))
            name = f"{kind}-{op}/c{c}" if kind == "qr" else f"hash/c{c}"
            print(f"{name:22s} {n:>10,} {n0 / n:>6.1f} {l:>8.4f}")


if __name__ == "__main__":
    main()
