"""End-to-end driver: train a ~100M-parameter DLRM with QR embeddings for a
few hundred steps, with checkpointing, restart, and eval — the paper's
training pipeline at example scale.

Run: PYTHONPATH=src python examples/train_dlrm_criteo.py [--steps 300]
"""

import argparse
import os

import jax
import numpy as np

from repro.core import EmbeddingSpec
from repro.data.criteo import CriteoSpec, batch_at
from repro.data.loader import ShardedLoader
from repro.models.dlrm import DLRMConfig, dlrm_init, dlrm_loss_fn, dlrm_num_params
from repro.optim.optimizers import adam, partitioned, rowwise_adagrad
from repro.train.loop import TrainConfig, Trainer, init_state, make_train_step

# ~100M params: mostly embeddings, like production DLRM
TABLE_SIZES = (400_000, 1_200_000, 800_000, 50_000, 21_000, 3_100_000,
               9_000, 110, 4, 960_000)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--embedding", default="qr", choices=["full", "qr", "hash"])
    ap.add_argument("--collisions", type=int, default=4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_dlrm_ckpt")
    args = ap.parse_args()

    spec = CriteoSpec(table_sizes=TABLE_SIZES, zipf=1.5, noise=0.5)
    cfg = DLRMConfig(
        table_sizes=TABLE_SIZES,
        embedding=EmbeddingSpec(kind=args.embedding, num_collisions=args.collisions,
                                op="mult", threshold=200))
    print(f"embedding={args.embedding}: {dlrm_num_params(cfg):,} parameters "
          f"(full would be {dlrm_num_params(DLRMConfig(table_sizes=TABLE_SIZES)):,})")

    params = dlrm_init(jax.random.PRNGKey(0), cfg)
    # the paper's production setup: row-wise adagrad on tables, AMSGrad elsewhere
    opt = partitioned([(lambda p: "tables" in p, rowwise_adagrad(1e-2))],
                      adam(1e-3, amsgrad=True))
    loss_fn = lambda p, b: dlrm_loss_fn(p, b, cfg)
    state = init_state(params, opt)

    tc = TrainConfig(num_steps=args.steps, log_every=25, ckpt_every=100,
                     ckpt_dir=args.ckpt_dir, keep=2)
    trainer = Trainer(make_train_step(loss_fn, opt, clip_norm=10.0), tc,
                      batch_at=lambda s: batch_at(0, s, args.batch, spec))
    state = trainer.resume_or(state)  # restart-safe
    if int(state["step"]) > 0:
        print(f"resumed from step {int(state['step'])}")
    state, history = trainer.run(state)
    for step, loss in history:
        print(f"step {step:5d}  loss {loss:.4f}")

    eval_fn = jax.jit(loss_fn)
    losses = [float(eval_fn(state["params"], batch_at(0, i, args.batch, spec))[0])
              for i in range(10_000, 10_010)]
    print(f"held-out loss: {np.mean(losses):.4f}")
    if trainer.straggler_events:
        print("straggler events:", trainer.straggler_events)


if __name__ == "__main__":
    main()
