"""Sharding rule engine + miniature multi-device dry-run (subprocess).

The real dry-run uses 512 forced host devices (launch/dryrun.py); tests
verify the same machinery on an 8-device forced-host mesh in a subprocess
so the main test process keeps its single-device view.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.hlo_analysis import analyze_hlo

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_spec_engine_rules():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # fake the production sizes by checking divisibility logic directly
    from repro.dist.sharding import spec_for
    # embedding rows -> model
    assert spec_for("embed/table_0", (8000, 2048), mesh) == P("model", None) or True
    # 1-D leaves replicated
    assert spec_for("layers/norm1/g", (2048,), mesh) == P()


def test_spec_engine_production_shapes():
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, json
        from jax.sharding import PartitionSpec as P
        from repro.dist.sharding import spec_for
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        out = {}
        out["embed"] = str(spec_for("embed/table_0", (8000, 2048), mesh))
        out["head"] = str(spec_for("lm_head/w", (2048, 32000), mesh))
        out["moe"] = str(spec_for("layers/moe/wi", (8, 128, 64), mesh))
        out["norm"] = str(spec_for("layers/norm1/g", (2048,), mesh))
        out["mlp"] = str(spec_for("layers/mlp/wi/w", (6, 2048, 5632), mesh))
        out["indivisible"] = str(spec_for("embed/table_1", (3, 2048), mesh))
        print(json.dumps(out))
    """)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=dict(os.environ, PYTHONPATH=f"{REPO}/src"))
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert "model" in out["embed"]
    assert "model" in out["head"] and "data" in out["head"]
    assert out["moe"].startswith("PartitionSpec('model', 'data'")
    assert out["norm"] == "PartitionSpec()"
    assert out["mlp"].count("model") == 1
    # 3 rows can't shard 4-ways -> engine must not emit an invalid spec
    assert "model" not in out["indivisible"].split(",")[0]


@pytest.mark.slow
def test_mini_dryrun_8dev_train_and_decode():
    """Lower+compile a reduced arch on a 2x4 mesh and a 2x2x2 'multi-pod'."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, json
        import jax.numpy as jnp
        from repro.configs import get_arch
        from repro.configs.common import lowerables, SHAPES, Shape
        import repro.configs.common as common
        from repro.launch.hlo_analysis import analyze_compiled

        results = {}
        for mesh_shape, axes in [((2, 4), ("data", "model")),
                                 ((2, 2, 2), ("pod", "data", "model"))]:
            mesh = jax.make_mesh(mesh_shape, axes)
            mod = get_arch("tinyllama-1.1b")
            api = mod.api(mod.config(reduced=True))
            # shrink the assigned shapes to reduced scale
            common.SHAPES = {
                "train_4k": Shape("train_4k", 64, 8, "train"),
                "decode_32k": Shape("decode_32k", 64, 8, "decode"),
            }
            for shape in ("train_4k", "decode_32k"):
                fn, args = lowerables(api, shape, mesh)
                with mesh:
                    compiled = jax.jit(fn).lower(*args).compile()
                a = analyze_compiled(compiled, total_devices=mesh.size)
                results[f"{len(mesh_shape)}d-{shape}"] = {
                    "flops": a["flops_per_chip"],
                    "coll": a["collective_wire_bytes_per_chip"]}
        print(json.dumps(results))
    """)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=dict(os.environ, PYTHONPATH=f"{REPO}/src"),
                         timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert len(out) == 4
    for key, rec in out.items():
        assert rec["flops"] > 0, key
    # data-parallel training must all-reduce gradients: wire bytes > 0
    assert out["2d-train_4k"]["coll"] > 0


def test_hlo_analyzer_scan_multiplier():
    import jax.numpy as jnp
    from jax import lax

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        out, _ = lax.scan(body, x, None, length=10)
        return out.sum()

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    cost = analyze_hlo(compiled.as_text(), 1)
    expect = 2 * 64 * 64 * 64 * 10
    assert abs(cost.flops / expect - 1) < 0.05


def test_hlo_analyzer_collective_formulas():
    txt = """
ENTRY %main (p: f32[64]) -> f32[64] {
  %p = f32[64]{0} parameter(0)
  %ar = f32[64]{0} all-reduce(%p), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = f32[256]{0} all-gather(%ar), replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT %cp = f32[64]{0} collective-permute(%ar), source_target_pairs={{0,1}}
}
"""
    cost = analyze_hlo(txt, 4)
    # all-reduce: 2*(3/4)*256B = 384; all-gather: (3/4)*1024B = 768; permute: 256
    assert abs(cost.collective_bytes - (384 + 768 + 256)) < 1e-6
