"""End-to-end system behaviour: the paper's full pipeline on CPU.

Covers: QR-compressed DLRM training end-to-end (the paper's headline
claim — QR quality ≥ hashing at equal compression), LM training with a
QR-compressed vocab, and train→checkpoint→serve round trip.
"""

import jax
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.core import EmbeddingSpec
from repro.data.criteo import CriteoSpec, batch_at
from repro.data.lm import batch_at as lm_batch_at
from repro.models import lm as lm_mod
from repro.models.dlrm import DLRMConfig, dlrm_init, dlrm_loss_fn
from repro.models.lm import LMConfig
from repro.optim.optimizers import adagrad, adam
from repro.serve.engine import ServeEngine
from repro.train.loop import init_state, make_train_step

SPEC = CriteoSpec(table_sizes=(1000, 20000, 50, 12000, 31), zipf=1.5, noise=0.5)


def _train_dlrm(embedding: EmbeddingSpec, steps=250, seed=0, batch=256):
    cfg = DLRMConfig(table_sizes=SPEC.table_sizes, embedding=embedding)
    params = dlrm_init(jax.random.PRNGKey(seed), cfg)
    opt = adagrad(1e-2)
    state = init_state(params, opt)
    step = jax.jit(make_train_step(lambda p, b: dlrm_loss_fn(p, b, cfg), opt))
    losses = []
    for i in range(steps):
        state, m = step(state, batch_at(7, i, batch, SPEC))
        losses.append(float(m["loss"]))
    return cfg, np.mean(losses[-25:])


def test_paper_headline_qr_beats_hash_at_equal_compression():
    """Paper §5.3/Fig.4: full <= QR <= hash in loss; QR ≈ hash in params."""
    _, full_loss = _train_dlrm(EmbeddingSpec(kind="full"))
    qr_cfg, qr_loss = _train_dlrm(EmbeddingSpec(kind="qr", num_collisions=4))
    hash_cfg, hash_loss = _train_dlrm(EmbeddingSpec(kind="hash", num_collisions=4))
    # compression ~4x on the embedding tables (the paper's metric; the
    # reduced config's MLPs dominate total params, so compare tables)
    from repro.models.dlrm import tables_for
    emb = lambda cfg: sum(m.num_params for m in tables_for(cfg))
    full_emb = emb(DLRMConfig(table_sizes=SPEC.table_sizes))
    assert emb(qr_cfg) < 0.30 * full_emb
    assert emb(hash_cfg) <= emb(qr_cfg)
    # quality ordering (small tolerance: stochastic training)
    assert full_loss <= qr_loss + 0.01
    assert qr_loss <= hash_loss + 0.005, (qr_loss, hash_loss)


def test_lm_with_qr_vocab_trains():
    cfg = LMConfig(name="sys", vocab=512, d_model=64, n_layers=2, n_heads=4,
                   n_kv_heads=2, d_head=16, d_ff=128,
                   embedding=EmbeddingSpec(kind="qr", num_collisions=4),
                   param_dtype="float32", compute_dtype="float32", xent_chunk=16)
    params = lm_mod.init(jax.random.PRNGKey(0), cfg)
    opt = adam(1e-3)
    state = init_state(params, opt)
    step = jax.jit(make_train_step(lambda p, b: lm_mod.loss_fn(p, b, cfg), opt))
    losses = []
    for i in range(60):
        state, m = step(state, lm_batch_at(0, i, 16, 32, cfg.vocab))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, (losses[0], losses[-1])


def test_train_checkpoint_serve_roundtrip(tmp_path):
    cfg = LMConfig(name="sys2", vocab=128, d_model=32, n_layers=2, n_heads=4,
                   n_kv_heads=2, d_head=8, d_ff=64,
                   embedding=EmbeddingSpec(kind="qr", num_collisions=4),
                   param_dtype="float32", compute_dtype="float32", xent_chunk=16)
    params = lm_mod.init(jax.random.PRNGKey(0), cfg)
    opt = adam(1e-3)
    state = init_state(params, opt)
    step = jax.jit(make_train_step(lambda p, b: lm_mod.loss_fn(p, b, cfg), opt))
    for i in range(10):
        state, _ = step(state, lm_batch_at(0, i, 8, 16, cfg.vocab))
    ckpt.save(str(tmp_path), 10, state["params"])
    restored, _ = ckpt.restore(str(tmp_path), 10, state["params"])
    eng = ServeEngine(
        prefill_fn=lambda toks, cache: lm_mod.prefill(restored, toks, cache, cfg),
        decode_fn=lambda tok, pos, cache: lm_mod.decode_step(restored, tok, pos, cache, cfg),
        make_cache_fn=lambda b, ml: lm_mod.make_decode_cache(cfg, b, ml),
        batch_size=2, max_len=32)
    uid = eng.submit([1, 2, 3, 4], max_new_tokens=4)
    out = eng.run_until_drained()[uid].output
    assert len(out) == 4 and all(0 <= t < cfg.vocab for t in out)
