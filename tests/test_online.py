"""Online re-planning: streaming stats, drift detection, migration, swap.

Four layers, mirroring ``src/repro/online``:

* streaming frequency stats (``plan.freq.merge_stats`` / ``StreamingStats``)
  and their crosscheck against ``obs.CollisionTelemetry``'s windowed view;
* the ``DriftDetector`` state machine (hysteresis, cooldown, abstention);
* migration invariants (Hypothesis properties: same-spec bitwise no-op,
  head-id exactness of structure folding, byte-budget preservation,
  per-leaf optimizer moment decisions);
* ``RecsysEngine.swap_plan`` (drain → invalidate → install → warm) and the
  ``ReplanController`` closed loop end to end.
"""

import dataclasses

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.factory import EmbeddingSpec, make_embedding
from repro.data.criteo import CriteoSpec, DriftSpec, batch_at, drifted_batch_at
from repro.models.dlrm import DLRMConfig, dlrm_init, tables_for
from repro.obs import Obs
from repro.obs.collision import CollisionTelemetry, predicted_collision_mass
from repro.online import (ReplanController, migrate_opt_state, migrate_params,
                          representative_ids)
from repro.online.drift import DriftDetector, DriftThresholds
from repro.optim import optimizers as opt
from repro.plan.freq import (FeatureStats, StreamingStats, merge_stats,
                             stats_from_batches)
from repro.plan.planner import build_plan, full_table_bytes
from repro.plan.quality import fit_collision_scale, module_partitions
from repro.serve.cache import DeviceHotRowCache, HotRowCache
from repro.serve.quantize import quantize_params
from repro.serve.recsys import RecsysEngine

SIZES = (60, 40, 500)


def _stats_of(ids, size):
    uq, ct = np.unique(np.asarray(ids, np.int64), return_counts=True)
    return FeatureStats(size=size, ids=uq,
                        probs=(ct / ct.sum()).astype(np.float64))


def _cfg(plan_or_spec=None, emb_dim=8):
    return DLRMConfig(name="dlrm-criteo", table_sizes=SIZES, emb_dim=emb_dim,
                      bottom_mlp=(8, 8), top_mlp=(8,), dense_dim=4,
                      embedding=plan_or_spec)


# --------------------------------------------------------- streaming stats


def test_merge_stats_weighted_union():
    a = _stats_of([0, 0, 1], 10)           # p = [2/3, 1/3]
    b = _stats_of([1, 2], 10)              # p = [1/2, 1/2]
    m = merge_stats(a, b, weight_a=3.0, weight_b=2.0)
    assert m.size == 10
    np.testing.assert_array_equal(m.ids, [0, 1, 2])
    np.testing.assert_allclose(m.probs, [2 / 5, 2 / 5, 1 / 5])
    assert abs(m.probs.sum() - 1.0) < 1e-12


def test_merge_stats_empty_sides():
    a = _stats_of([3, 3, 4], 10)
    empty = FeatureStats(size=10, ids=np.empty(0, np.int64),
                         probs=np.empty(0, np.float64))
    m = merge_stats(a, empty, weight_a=1.0, weight_b=5.0)
    np.testing.assert_array_equal(m.ids, a.ids)
    np.testing.assert_allclose(m.probs, a.probs)
    both = merge_stats(empty, empty)
    assert both.ids.size == 0


def test_streaming_no_decay_matches_batch_stats():
    spec = CriteoSpec(table_sizes=SIZES, dense_dim=4, zipf=1.5, noise=0.5)
    batches = [batch_at(0, t, 64, spec) for t in range(5)]
    want = stats_from_batches(batches, SIZES)
    stream = StreamingStats(SIZES, decay=1.0)
    for b in batches:
        stream.update(b)
    for i in range(len(SIZES)):
        got = stream.snapshot(i)
        np.testing.assert_array_equal(got.ids, want[i].ids)
        np.testing.assert_allclose(got.probs, want[i].probs, atol=1e-12)


def test_streaming_decay_forgets_old_traffic():
    stream = StreamingStats((100,), decay=0.1)
    stream.update({"sparse": np.full((50, 1), 7, np.int64)})
    stream.update({"sparse": np.full((50, 1), 9, np.int64)})
    s = stream.snapshot(0)
    p = dict(zip(s.ids.tolist(), s.probs.tolist()))
    assert p[9] > 0.85          # fresh traffic dominates
    assert 0 < p[7] < 0.15


def test_streaming_max_support_prunes_lowest_mass():
    stream = StreamingStats((100,), decay=1.0, max_support=3)
    ids = np.array([[0] * 8 + [1] * 4 + [2] * 2 + [3] * 1 + [4] * 1]).T
    stream.update({"sparse": ids})
    s = stream.snapshot(0)
    assert s.ids.size == 3
    assert set(s.ids.tolist()) == {0, 1, 2}
    assert abs(s.probs.sum() - 1.0) < 1e-12
    assert stream.pruned[0] > 0


def test_streaming_vs_telemetry_crosscheck():
    """Satellite check: the decayless streaming view and the telemetry's
    windowed view are the same estimator on the same id stream — support
    and top-mass must agree exactly."""
    spec = CriteoSpec(table_sizes=SIZES, dense_dim=4, zipf=1.5, noise=0.5)
    tele = CollisionTelemetry(SIZES, compact_every=2)  # force compactions
    stream = StreamingStats(SIZES, decay=1.0)
    for t in range(6):
        sparse = np.asarray(batch_at(0, t, 32, spec)["sparse"])
        idx = sparse[:, :, None]
        tele.record(idx, np.ones_like(idx, np.float32))
        stream.update({"sparse": sparse})
    for i in range(len(SIZES)):
        a, b = tele.observed_stats(i), stream.snapshot(i)
        assert a.support == b.support
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_allclose(a.probs, b.probs, atol=1e-12)
        assert abs(a.top_mass - b.top_mass) < 1e-12


def test_telemetry_reset_clears_window():
    tele = CollisionTelemetry(SIZES)
    idx = np.zeros((4, len(SIZES), 2), np.int64)
    tele.record(idx, np.ones_like(idx, np.float32))
    assert tele.waves == 1 and tele.observed_lookups(0) > 0
    tele.reset()
    assert tele.waves == 0
    assert all(tele.observed_lookups(i) == 0 for i in range(len(SIZES)))
    tele.record(idx, np.ones_like(idx, np.float32))
    assert tele.waves == 1     # keeps accumulating after reset


# -------------------------------------------------------- collision scale


def test_fit_collision_scale_recovers_k():
    assert abs(fit_collision_scale([(0.1, 0.2), (0.2, 0.4)]) - 2.0) < 1e-12
    # least squares through the origin, not a mean of ratios
    k = fit_collision_scale([(1.0, 1.1), (0.01, 0.05)])
    assert abs(k - (1.0 * 1.1 + 0.01 * 0.05) / (1.0 + 0.0001)) < 1e-12


def test_fit_collision_scale_rejects_bad_input():
    with pytest.raises(ValueError):
        fit_collision_scale([(0.0, 0.0)])       # no signal
    with pytest.raises(ValueError):
        fit_collision_scale([(-0.1, 0.2)])      # negative mass


# --------------------------------------------------------- drift detector


class _FakeTelemetry:
    """Duck-typed telemetry: fixed lookups + measured masses per feature."""

    def __init__(self, lookups, measured):
        self._lookups, self._measured = lookups, measured

    def observed_lookups(self, i):
        return self._lookups[i]

    def measured_collision_mass(self, module, i):
        return self._measured[i]


def test_detector_hysteresis_and_cooldown():
    th = DriftThresholds(rel_gap=0.5, abs_gap=0.0, min_lookups=10,
                         hysteresis=2, cooldown=2)
    det = DriftDetector(modules=[None], predicted=[0.1], thresholds=th)
    hot = _FakeTelemetry([100], [0.2])       # 2x predicted: over
    cold = _FakeTelemetry([100], [0.1])
    d1 = det.check(hot)
    assert d1.over == (0,) and not d1.fired and d1.streak == 1
    d2 = det.check(hot)                       # second consecutive: fires
    assert d2.fired and det.fires == 1 and d2.cooldown == 2
    d3 = det.check(hot)                       # cooldown blocks
    assert not d3.fired and d3.cooldown == 1
    d4 = det.check(cold)                      # quiet window resets streak
    assert d4.streak == 0 and d4.cooldown == 0
    det.check(hot)
    assert det.check(hot).fired               # re-arms after cooldown drains


def test_detector_abstains_below_min_lookups():
    th = DriftThresholds(min_lookups=1000, hysteresis=1)
    det = DriftDetector([None], [0.001], th)
    d = det.check(_FakeTelemetry([10], [0.9]))
    assert not d.fired and d.over == () and 0 not in d.gaps


def test_detector_collision_scale_calibrates_threshold():
    # measured 0.15 vs predicted 0.1: over at scale 1, calm at scale 1.5
    tele = _FakeTelemetry([100], [0.151])
    hot = DriftDetector([None], [0.1],
                        DriftThresholds(rel_gap=0.4, abs_gap=0.0,
                                        min_lookups=10, hysteresis=1))
    calm = DriftDetector([None], [0.1],
                         DriftThresholds(rel_gap=0.4, abs_gap=0.0,
                                         min_lookups=10, hysteresis=1,
                                         collision_scale=1.5))
    assert hot.check(tele).fired
    assert not calm.check(tele).fired


def test_detector_rebase_sets_full_cooldown():
    th = DriftThresholds(min_lookups=1, hysteresis=1, cooldown=3)
    det = DriftDetector([None], [0.1], th)
    det.rebase([None], [0.5])
    assert det.predicted == [0.5]
    d = det.check(_FakeTelemetry([100], [5.0]))
    assert not d.fired and d.cooldown == 2    # cooldown absorbed the over


# ------------------------------------------------------------- migration


def _spec_strategy():
    return st.one_of(
        st.just(EmbeddingSpec(kind="full")),
        st.builds(lambda c: EmbeddingSpec(kind="hash", num_collisions=c),
                  st.sampled_from([2, 4, 8])),
        st.builds(lambda c: EmbeddingSpec(kind="qr", num_collisions=c,
                                          threshold=1),
                  st.sampled_from([2, 4, 8])),
    )


@settings(max_examples=12, deadline=None)
@given(st.integers(20, 120), _spec_strategy())
def test_same_spec_migration_is_bitwise_noop(size, spec):
    mod = make_embedding(size, 8, spec)
    old = mod.init(jax.random.PRNGKey(0))
    fresh = mod.init(jax.random.PRNGKey(1))
    from repro.online.migrate import migrate_feature
    out, _, dec = migrate_feature(mod, old, mod, fresh)
    assert dec["decision"] == "copied"
    for k in old:
        np.testing.assert_array_equal(np.asarray(out[k]), np.asarray(old[k]))


@settings(max_examples=12, deadline=None)
@given(st.integers(30, 200), st.sampled_from([2, 4, 8]), st.booleans())
def test_fold_head_ids_are_exact(size, c, to_hash):
    """Folding full→hash / full→QR reproduces the old embedding exactly at
    every id below the new structure's head (all reps are the id itself)."""
    old_mod = make_embedding(size, 8, EmbeddingSpec(kind="full"))
    kind = "hash" if to_hash else "qr"
    new_mod = make_embedding(size, 8, EmbeddingSpec(kind=kind,
                                                    num_collisions=c,
                                                    threshold=1))
    old = old_mod.init(jax.random.PRNGKey(0))
    fresh = new_mod.init(jax.random.PRNGKey(1))
    from repro.online.migrate import migrate_feature
    out, _, dec = migrate_feature(old_mod, old, new_mod, fresh)
    assert dec["decision"] == "folded"
    head = min(p.num_buckets for p in module_partitions(new_mod))
    xs = np.arange(min(head, 32))
    want = np.asarray(old_mod.apply(old, xs.astype(np.int32)))
    got = np.asarray(new_mod.apply(out, xs.astype(np.int32)))
    np.testing.assert_allclose(got, want, atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.sampled_from([4, 6, 8]))
def test_migrated_tree_matches_new_init_bytes(seed, frac):
    """The migrated tree has exactly the fresh init's structure, shapes and
    dtypes — so the solver's byte budget transfers to the migrated state."""
    rng = np.random.default_rng(seed)
    stats = [_stats_of(rng.integers(0, s, 400), s) for s in SIZES]
    budget = full_table_bytes(SIZES, 8) // frac
    plan_old = build_plan(stats, 8, full_table_bytes(SIZES, 8), arch="t")
    plan_new = build_plan(stats, 8, budget, arch="t")
    assert plan_new.total_bytes <= budget
    old_cfg, new_cfg = _cfg(plan_old), _cfg(plan_new)
    old = dlrm_init(jax.random.PRNGKey(0), old_cfg)
    fresh = dlrm_init(jax.random.PRNGKey(1), new_cfg)
    mig, report = migrate_params(old_cfg, old, new_cfg, fresh)
    la, lb = jax.tree.leaves(mig), jax.tree.leaves(fresh)
    assert len(la) == len(lb)
    for a, b in zip(la, lb):
        assert a.shape == b.shape and a.dtype == b.dtype
    assert sum(report["counts"].values()) == len(SIZES)


def test_migration_dequantizes_int8_source():
    spec = EmbeddingSpec(kind="qr", num_collisions=4, threshold=1)
    mod = make_embedding(100, 8, spec)
    old = mod.init(jax.random.PRNGKey(0))
    qold = quantize_params({"tables": [old]}, mode="int8")["tables"][0]
    fresh = mod.init(jax.random.PRNGKey(1))
    from repro.online.migrate import migrate_feature
    out, _, dec = migrate_feature(mod, qold, mod, fresh)
    assert dec["decision"] == "copied"
    xs = np.arange(32, dtype=np.int32)
    np.testing.assert_allclose(np.asarray(mod.apply(out, xs)),
                               np.asarray(mod.apply(qold, xs)), atol=1e-6)


def test_migrate_opt_state_carries_matching_leaves():
    stats = [_stats_of(np.arange(s), s) for s in SIZES]
    plan_old = build_plan(stats, 8, full_table_bytes(SIZES, 8), arch="t")
    plan_new = build_plan(stats, 8, full_table_bytes(SIZES, 8) // 6,
                          arch="t")
    old_cfg, new_cfg = _cfg(plan_old), _cfg(plan_new)
    old = dlrm_init(jax.random.PRNGKey(0), old_cfg)
    fresh = dlrm_init(jax.random.PRNGKey(1), new_cfg)
    mig, _ = migrate_params(old_cfg, old, new_cfg, fresh)
    optimizer = opt.adagrad(1e-2)
    state = optimizer.init(old)
    # make the old moments distinguishable from a fresh init
    state = [jax.tree.map(lambda x: x + 7.0, s) for s in state]
    new_state, dec = migrate_opt_state(old, state, mig, optimizer)
    assert len(new_state) == len(jax.tree.leaves(mig))
    assert set(dec.values()) <= {"carried", "reset"}
    assert "carried" in dec.values() and "reset" in dec.values()
    from repro.optim.optimizers import leaf_paths
    by_path = dict(zip(leaf_paths(mig), new_state))
    for path, choice in dec.items():
        if choice == "carried":
            leaf0 = jax.tree.leaves(by_path[path])[0]
            assert float(np.min(np.asarray(leaf0))) >= 7.0
            break


def test_migrate_params_rejects_changed_feature_set():
    stats = [_stats_of(np.arange(s), s) for s in SIZES]
    plan = build_plan(stats, 8, full_table_bytes(SIZES, 8), arch="t")
    cfg = _cfg(plan)
    params = dlrm_init(jax.random.PRNGKey(0), cfg)
    other = dataclasses.replace(cfg, table_sizes=(60, 40, 400))
    with pytest.raises(ValueError):
        migrate_params(cfg, params, other, params)


def test_representative_ids_cover_arithmetic_families():
    from repro.core.partitions import (QuotientPartition, RemainderPartition,
                                       qr_partitions)
    r = RemainderPartition(size=50, num_buckets=7, m=7)
    np.testing.assert_array_equal(representative_ids(r), np.arange(7))
    q = QuotientPartition(size=50, num_buckets=8, m=7)
    np.testing.assert_array_equal(representative_ids(q),
                                  np.minimum(np.arange(8) * 7, 49))
    for p in qr_partitions(500, 16):
        reps = representative_ids(p)
        np.testing.assert_array_equal(np.asarray(p.bucket(reps)),
                                      np.arange(p.num_buckets))


# ------------------------------------------------------- drift generator


def test_drifted_batch_matches_batch_at_before_shift():
    spec = CriteoSpec(table_sizes=SIZES, dense_dim=4, zipf=1.5, noise=0.5)
    drift = DriftSpec(shift_step=10, zipf_after=0.7, rotate_frac=0.5)
    for t in (0, 5, 9):
        a, b = batch_at(3, t, 16, spec), drifted_batch_at(3, t, 16, spec,
                                                          drift)
        for k in a:
            np.testing.assert_array_equal(np.asarray(a[k]), np.asarray(b[k]))


def test_drifted_batch_shifts_after_step_and_is_deterministic():
    spec = CriteoSpec(table_sizes=SIZES, dense_dim=4, zipf=1.5, noise=0.5)
    drift = DriftSpec(shift_step=10, zipf_after=0.7, rotate_frac=0.5)
    a = drifted_batch_at(3, 20, 64, spec, drift)
    b = drifted_batch_at(3, 20, 64, spec, drift)
    np.testing.assert_array_equal(np.asarray(a["sparse"]),
                                  np.asarray(b["sparse"]))
    plain = batch_at(3, 20, 64, spec)
    assert not np.array_equal(np.asarray(a["sparse"]),
                              np.asarray(plain["sparse"]))
    # labels re-planted on the drifted ids (same planted model)
    assert a["label"].shape == plain["label"].shape


def test_flash_crowd_concentrates_traffic():
    spec = CriteoSpec(table_sizes=(1000,), dense_dim=4, zipf=1.5, noise=0.5)
    drift = DriftSpec(crowd_step=0, crowd_len=100, crowd_frac=0.6)
    batch = drifted_batch_at(0, 5, 512, spec, drift)
    ids, counts = np.unique(np.asarray(batch["sparse"]), return_counts=True)
    top = counts.max() / counts.sum()
    assert top > 0.3            # crowd id dominates
    after = drifted_batch_at(0, 200, 512, spec, drift)   # crowd over
    plain = batch_at(0, 200, 512, spec)
    np.testing.assert_array_equal(np.asarray(after["sparse"]),
                                  np.asarray(plain["sparse"]))


# ----------------------------------------------------------- plan hot-swap


def _concentrated_stats(rng):
    """Feature 2's plan-time traffic is near-point-mass, so the solver
    starves its table — the drift-detectable configuration."""
    out = []
    for i, s in enumerate(SIZES):
        ids = np.floor(rng.random(4000) ** 1.5 * s).astype(np.int64)
        if i == 2:
            ids[rng.random(4000) < 0.95] = 0
        out.append(_stats_of(ids, s))
    return out


def _requests(rng, n, spread=False):
    reqs = []
    for _ in range(n):
        bags = []
        for i, s in enumerate(SIZES):
            if spread and i == 2:
                ids = (np.floor(rng.random(3) ** 0.7 * s).astype(int)
                       + s // 2) % s
            else:
                ids = np.floor(rng.random(3) ** 1.5 * s).astype(int)
                if i == 2:
                    ids[rng.random(3) < 0.95] = 0
            bags.append(list(ids))
        reqs.append((rng.normal(size=4), bags))
    return reqs


def test_swap_plan_scores_match_fresh_engine():
    rng = np.random.default_rng(0)
    stats = _concentrated_stats(rng)
    full = full_table_bytes(SIZES, 8)
    cfg0 = _cfg(build_plan(stats, 8, full, arch="t"))
    cfg1 = _cfg(build_plan(stats, 8, full // 6, arch="t"))
    p0 = dlrm_init(jax.random.PRNGKey(0), cfg0)
    p1f = dlrm_init(jax.random.PRNGKey(1), cfg1)
    p1, _ = migrate_params(cfg0, p0, cfg1, p1f)
    eng = RecsysEngine(cfg0, quantize_params(p0, mode="int8"), max_batch=4,
                       cache=DeviceHotRowCache(capacity_rows=128),
                       batching="waves")
    reqs = _requests(rng, 8)
    for d, b in reqs:
        eng.submit(d, b)
    eng.run_until_drained()
    ver0 = eng.cache.residency_version
    info = eng.swap_plan(cfg1, quantize_params(p1, mode="int8"), warm=False)
    assert info["invalidated_rows"] >= 0
    assert eng.cache.residency_version > ver0
    uids = [eng.submit(d, b) for d, b in reqs]
    done = eng.run_until_drained()
    fresh = RecsysEngine(cfg1, quantize_params(p1, mode="int8"), max_batch=4,
                         batching="waves")
    fuids = [fresh.submit(d, b) for d, b in reqs]
    fdone = fresh.run_until_drained()
    for u, fu in zip(uids, fuids):
        assert abs(done[u].score - fdone[fu].score) < 1e-4


def test_swap_plan_drops_count_as_invalidations_not_evictions():
    rng = np.random.default_rng(1)
    stats = _concentrated_stats(rng)
    full = full_table_bytes(SIZES, 8)
    cfg0 = _cfg(build_plan(stats, 8, full, arch="t"))
    cfg1 = _cfg(build_plan(stats, 8, full // 6, arch="t"))
    p0 = dlrm_init(jax.random.PRNGKey(0), cfg0)
    p1, _ = migrate_params(cfg0, p0, cfg1,
                           dlrm_init(jax.random.PRNGKey(1), cfg1))
    cache = HotRowCache(capacity_rows=10_000)   # never evicts on capacity
    eng = RecsysEngine(cfg0, p0, max_batch=4, cache=cache, batching="waves")
    for d, b in _requests(rng, 8):
        eng.submit(d, b)
    eng.run_until_drained()
    rows_before = len(cache._rows)
    assert rows_before > 0
    info = eng.swap_plan(cfg1, p1, warm=False)
    s = cache.stats
    assert info["invalidated_rows"] == rows_before
    assert s.invalidations >= rows_before
    assert s.evictions == 0
    assert len(cache._rows) == 0 and s.bytes_cached == 0


def test_swap_plan_rejects_changed_feature_set():
    stats = [_stats_of(np.arange(s), s) for s in SIZES]
    cfg0 = _cfg(build_plan(stats, 8, full_table_bytes(SIZES, 8), arch="t"))
    p0 = dlrm_init(jax.random.PRNGKey(0), cfg0)
    eng = RecsysEngine(cfg0, p0, max_batch=4, batching="waves")
    bad = dataclasses.replace(cfg0, table_sizes=(60, 40, 400))
    with pytest.raises(ValueError):
        eng.swap_plan(bad, p0)


# -------------------------------------------------------- the closed loop


def test_controller_closed_loop_fires_and_swaps():
    rng = np.random.default_rng(2)
    stats = _concentrated_stats(rng)
    full = full_table_bytes(SIZES, 8)
    plan0 = build_plan(stats, 8, full // 6, arch="t")
    cfg0 = _cfg(plan0)
    p0 = dlrm_init(jax.random.PRNGKey(0), cfg0)
    eng = RecsysEngine(cfg0, quantize_params(p0, mode="int8"), max_batch=4,
                       cache=DeviceHotRowCache(capacity_rows=128),
                       batching="waves", obs=Obs(collisions=True))
    ctrl = ReplanController(
        eng, budget_bytes=full // 6,
        thresholds=DriftThresholds(min_lookups=16, hysteresis=2, cooldown=1,
                                   rel_gap=1.0),
        quantize="int8", plan_stats=stats)
    for _ in range(3):                       # stationary: quiet
        for d, b in _requests(rng, 12):
            eng.submit(d, b)
        eng.run_until_drained()
        decision = ctrl.check()
        assert decision is not None and not decision.fired
    assert not ctrl.replans
    for _ in range(4):                       # drifted: fires within 4 windows
        for d, b in _requests(rng, 12, spread=True):
            eng.submit(d, b)
        eng.run_until_drained()
        ctrl.check()
        if ctrl.replans:
            break
    assert len(ctrl.replans) == 1
    rep = ctrl.replans[0]
    assert rep["plan"]["total_bytes"] <= rep["plan"]["budget_bytes"]
    assert 2 in rep["trigger"]["over"]       # the starved feature fired
    assert eng.cfg.embedding is not plan0    # new plan is installed
    assert rep["swap"]["residency_version"] == eng.cache.residency_version
    # the detector rebased on the drifted streaming stats: continued
    # drifted traffic settles instead of thrashing through more swaps
    old_pred = predicted_collision_mass(tables_for(cfg0)[2], stats[2])
    assert ctrl.detector.predicted[2] > old_pred   # baseline absorbed drift
    for _ in range(3):
        for d, b in _requests(rng, 12, spread=True):
            eng.submit(d, b)
        eng.run_until_drained()
        ctrl.check()
    assert len(ctrl.replans) == 1
    # engine still serves after the swap
    uid = eng.submit(*_requests(rng, 1, spread=True)[0])
    done = eng.run_until_drained()
    assert np.isfinite(done[uid].score)


def test_controller_requires_collision_telemetry():
    stats = [_stats_of(np.arange(s), s) for s in SIZES]
    cfg = _cfg(build_plan(stats, 8, full_table_bytes(SIZES, 8), arch="t"))
    p = dlrm_init(jax.random.PRNGKey(0), cfg)
    eng = RecsysEngine(cfg, p, max_batch=4, batching="waves")
    with pytest.raises(ValueError):
        ReplanController(eng, budget_bytes=1 << 20)
