"""Compression policy engine + wire-bytes accounting + bench harness exit codes.

Multi-device behaviour (FSDP vs DP equivalence, HLO cross-checks) lives in
test_dist.py; these are the fast single-process properties.
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import accounting
from repro.dist.compress import (ef_psum_grads, init_error_state,
                                 resolve_modes)
from repro.dist.policy import AUTO, CompressionPolicy, resolve_policy
from repro.optim.optimizers import leaf_paths

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _params():
    return {
        "embed": {"table_0": jnp.zeros((4096, 64)),      # big table → int8
                  "table_1": jnp.zeros((16, 16))},       # tiny table → none
        "mlp": {"w": jnp.zeros((512, 256)),              # dense matmul → bf16
                "b": jnp.zeros((256,))},                 # bias (1-D) → none
        "norm": {"g": jnp.zeros((70, 70))},              # 2-D but norm-named
        "head": {"w": jnp.zeros((64, 8))},               # under threshold
    }


# ------------------------------------------------------------- rule table


def test_mode_for_rules():
    p = AUTO
    assert p.mode_for("embed/table_0", (4096, 64)) == "int8"
    assert p.mode_for("tables/3/table_1", (8000, 16)) == "int8"
    assert p.mode_for("layers/mlp/w", (512, 256)) == "bf16"
    assert p.mode_for("layers/norm1/g", (2048,)) == "none"       # rank gate
    assert p.mode_for("layers/norm1/g", (70, 70)) == "none"      # name rule
    assert p.mode_for("mlp/b", (256,)) == "none"                 # rank gate
    assert p.mode_for("head/w", (8, 8)) == "none"                # size gate
    # size gate beats the table rule: a tiny table is not worth compressing
    assert p.mode_for("embed/table_9", (16, 16)) == "none"


def test_policy_tree_and_modes_align_with_leaves():
    params = _params()
    modes = AUTO.modes(params)
    paths_modes = dict(zip(leaf_paths(params), modes))
    assert paths_modes["embed/table_0"] == "int8"
    assert paths_modes["embed/table_1"] == "none"
    assert paths_modes["mlp/w"] == "bf16"
    assert paths_modes["mlp/b"] == "none"
    assert paths_modes["norm/g"] == "none"
    assert paths_modes["head/w"] == "none"
    # tree form round-trips through resolve_modes
    assert resolve_modes(params, AUTO.tree(params)) == modes
    assert resolve_modes(params, AUTO) == modes


def test_policy_validation():
    with pytest.raises(ValueError):
        CompressionPolicy(default="fp4")
    with pytest.raises(ValueError):
        CompressionPolicy(rules=((r".*", "int4"),))
    with pytest.raises(ValueError):
        resolve_policy("int4")
    assert resolve_policy("auto") is AUTO
    assert resolve_policy("bf16") == "bf16"
    custom = CompressionPolicy(min_compress_elems=1, default="int8")
    assert resolve_policy(custom) is custom
    assert custom.mode_for("mlp/w", (4, 4)) == "int8"


def test_custom_rules_first_match_wins():
    p = CompressionPolicy(rules=((r"special", "none"),) + AUTO.rules,
                          min_compress_elems=1)
    assert p.mode_for("special/table_0", (4096, 64)) == "none"
    assert p.mode_for("embed/table_0", (4096, 64)) == "int8"


# ----------------------------------------------- per-leaf error state + EF


def test_error_state_allocated_only_for_compressed_leaves():
    params = _params()
    err = init_error_state(params, AUTO)
    shapes = {p: e.shape for p, e in zip(leaf_paths(params),
                                         jax.tree.leaves(err))}
    assert shapes["embed/table_0"] == (4096, 64)   # int8 → full residual
    assert shapes["mlp/w"] == (512, 256)           # bf16 → full residual
    assert shapes["embed/table_1"] == ()           # none → placeholder
    assert shapes["mlp/b"] == ()
    assert shapes["norm/g"] == ()
    # default (no mode): full residual everywhere, as in PR 1
    full = init_error_state(params)
    assert all(e.shape == l.shape for e, l in
               zip(jax.tree.leaves(full), jax.tree.leaves(params)))


def test_ef_per_leaf_modes_local():
    key = jax.random.PRNGKey(0)
    g = {"table": jax.random.normal(key, (64, 64)),
         "b": jax.random.normal(jax.random.fold_in(key, 1), (32,))}
    modes = {"table": "int8", "b": "none"}
    err = init_error_state(g, modes)
    out, new_err = ef_psum_grads(g, err, axis_name=None, mode=modes)
    # 'none' leaf is exact with a placeholder residual
    np.testing.assert_array_equal(np.asarray(out["b"]), np.asarray(g["b"]))
    assert new_err["b"].shape == ()
    # int8 leaf is quantised within half a step, residual is the difference
    scale = float(np.abs(np.asarray(g["table"])).max()) / 127.0
    err_abs = np.abs(np.asarray(out["table"]) - np.asarray(g["table"]))
    assert err_abs.max() <= scale * 0.5 + 1e-7
    np.testing.assert_allclose(np.asarray(new_err["table"]),
                               np.asarray(g["table"]) - np.asarray(out["table"]),
                               atol=1e-6)


def test_ef_rejects_mismatched_mode_tree():
    g = {"a": jnp.ones((4,)), "b": jnp.ones((4,))}
    with pytest.raises(ValueError):
        ef_psum_grads(g, init_error_state(g), axis_name=None,
                      mode=["bf16"])  # 1 mode for 2 leaves
    with pytest.raises(ValueError):
        resolve_modes(g, ["bf16", "fp4"])


# ------------------------------------------------------------- accounting


def test_leaf_reduce_bytes_formulas():
    n, e = 8, 1024
    none_ar = accounting.leaf_reduce_bytes("none", e, n)
    bf16_ar = accounting.leaf_reduce_bytes("bf16", e, n)
    int8_ar = accounting.leaf_reduce_bytes("int8", e, n)
    assert none_ar == pytest.approx(2 * 7 / 8 * 4 * e)
    assert bf16_ar == pytest.approx(2 * 7 / 8 * 2 * e)
    # two-phase int8: ~0.25× of f32 + two scalar scale all-reduces
    assert int8_ar == pytest.approx(2 * 7 / 8 * e + 2 * 2 * 7 / 8 * 4)
    assert int8_ar < 0.3 * none_ar
    # reduce-scatter pattern is half the all-reduce (no gather phase)
    assert accounting.leaf_reduce_bytes("none", e, n, pattern="reduce_scatter") \
        == pytest.approx(7 / 8 * 4 * e)
    assert accounting.leaf_reduce_bytes("int8", e, n, pattern="reduce_scatter") \
        == pytest.approx(7 / 8 * e + 2 * 7 / 8 * 4)
    # single device: nothing crosses a wire
    assert accounting.leaf_reduce_bytes("int8", e, 1) == 0.0


def test_tree_accounting_int8_policy_under_0p3():
    """The PR acceptance ratio, at the accounting level, on a DLRM-shaped
    tree: uniform int8 < 0.3× of mode='none'."""
    params = _params()
    none = accounting.dp_step_wire_bytes(params, "none", 8)
    int8 = accounting.dp_step_wire_bytes(params, "int8", 8)
    auto = accounting.dp_step_wire_bytes(params, AUTO, 8)
    assert int8["total_bytes"] < 0.3 * none["total_bytes"]
    assert none["total_bytes"] > auto["total_bytes"] > int8["total_bytes"]
    assert set(auto["per_mode"]) == {"int8", "bf16", "none"}


def test_fsdp_accounting_reports_param_gather():
    from repro.optim.optimizers import adagrad
    params = _params()
    mesh = jax.make_mesh((1,), ("data",))
    # trivial mesh: no wire at all
    acct = accounting.fsdp_step_wire_bytes(params, adagrad(1e-2), mesh, AUTO)
    assert acct["total_bytes"] == 0.0
    assert acct["n_leaves"] == len(jax.tree.leaves(params))


def test_fsdp_step_preserves_rank0_leaves():
    """Scalar params (learned temperature etc.) must come back rank-0: the
    'none'-mode residual placeholder is per-device 0-d, not (1,) — a (1,)
    residual would broadcast the whole update chain up a rank."""
    from repro.optim.optimizers import adagrad
    from repro.train.loop import init_fsdp_state, make_fsdp_train_step

    def loss_fn(p, b):
        pred = b["x"] @ p["w"] * p["temp"]
        loss = jnp.mean(jnp.square(pred - b["y"]))
        return loss, {"mse": loss}

    params = {"w": jnp.full((16, 8), 0.1), "temp": jnp.float32(1.0)}
    mesh = jax.make_mesh((1,), ("data",))
    opt = adagrad(1e-2)
    state = init_fsdp_state(params, opt, mesh, policy="auto")
    step = jax.jit(make_fsdp_train_step(loss_fn, opt, mesh, params,
                                        policy="auto"))
    b = {"x": jnp.ones((4, 16)), "y": jnp.zeros((4, 8))}
    with mesh:
        for _ in range(2):
            state, m = step(state, b)
    assert state["params"]["temp"].shape == ()
    assert state["params"]["w"].shape == (16, 8)
    assert np.isfinite(float(m["loss"]))


# ----------------------------------------------------- bench harness exits


def _run_bench(*args, env=None):
    e = dict(os.environ, PYTHONPATH=f"{REPO}/src")
    e.pop("REPRO_BENCH_INJECT_ERROR", None)
    if env:
        e.update(env)
    return subprocess.run([sys.executable, "-m", "benchmarks.run", *args],
                          capture_output=True, text=True, cwd=REPO, env=e,
                          timeout=600)


def test_benchmarks_run_exits_nonzero_on_error_row():
    """The CI bench lane can only gate on sections actually failing the
    process: inject an error, expect the /ERROR row AND exit code 1."""
    res = _run_bench("--only", "injected",
                     env={"REPRO_BENCH_INJECT_ERROR": "1"})
    assert res.returncode == 1, (res.stdout, res.stderr)
    assert "/ERROR" in res.stdout
    assert "injected benchmark failure" in res.stdout


def test_benchmarks_run_only_filter_green():
    """--only with no matching section runs nothing and exits 0 (the same
    path a fully-green run takes through the failure accounting)."""
    res = _run_bench("--only", "no_such_section")
    assert res.returncode == 0, (res.stdout, res.stderr)
    assert res.stdout.strip().splitlines()[0] == "name,us_per_call,derived"
