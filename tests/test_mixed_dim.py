"""Mixed-dimension planned embeddings end to end: factory width
resolution, per-feature projections in DLRM/DCN, byte-identical uniform
configs, training from a mixed plan, and quantized+cached serving."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EmbeddingSpec, make_embedding
from repro.models.dcn import DCNConfig, dcn_forward, dcn_init
from repro.models.dlrm import (DLRMConfig, dlrm_forward, dlrm_init,
                               dlrm_num_params, embed_features, tables_for)
from repro.plan import (build_plan, dim_ladder, full_table_bytes,
                        power_law_stats)
from repro.serve.cache import HotRowCache
from repro.serve.quantize import memory_report, quantize_params, table_shapes
from repro.serve.recsys import RecsysEngine

SIZES = (100, 500, 33, 2000)
DIM = 16


def _mixed_plan(frac=0.25):
    st = [power_law_stats(n, alpha=1.2) for n in SIZES]
    return build_plan(st, DIM, int(full_table_bytes(SIZES, DIM) * frac),
                      dims=dim_ladder(DIM), arch="test-mixed")


def _cfg(plan):
    return DLRMConfig(table_sizes=SIZES, emb_dim=DIM, bottom_mlp=(32, 16),
                      top_mlp=(32,), embedding=plan)


# ------------------------------------------------------------- factory


def test_make_embedding_builds_at_planned_width():
    plan = _mixed_plan()
    assert len(set(plan.table_dims)) >= 2, plan.table_dims  # genuinely mixed
    for i, n in enumerate(SIZES):
        mod = make_embedding(n, DIM, plan, feature=i)
        assert mod.out_dim == plan.dim_for(i)
        assert mod.num_params * 4 == plan.tables[i].train_bytes


def test_make_embedding_rejects_bad_plan_width():
    plan = _mixed_plan()
    bad = dataclasses.replace(plan.tables[0], dim=DIM + 4)
    plan.tables[0] = bad
    with pytest.raises(ValueError, match="width"):
        make_embedding(SIZES[0], DIM, plan, feature=0)


# ------------------------------------------------- byte-identical uniform path


def test_uniform_config_params_byte_identical():
    """The acceptance pin: a uniform-width config must produce exactly
    the pre-mixed-dim param tree — no ``proj`` key, identical draws
    (bottom/top from their own split keys, each table from its own
    subkey), and identical forward outputs through the (now
    projection-aware) embed path."""
    from repro.models.dlrm import _mlp_init
    cfg = DLRMConfig(table_sizes=SIZES, emb_dim=DIM, bottom_mlp=(32, 16),
                     top_mlp=(32,),
                     embedding=EmbeddingSpec(kind="qr", num_collisions=4,
                                             threshold=40))
    key = jax.random.PRNGKey(7)
    params = dlrm_init(key, cfg)
    assert set(params) == {"bottom", "top", "tables"}  # no proj key

    # reconstruct the exact historical key schedule by hand
    modules = tables_for(cfg)
    kb, kt, ke = jax.random.split(key, 3)
    ekeys = jax.random.split(ke, len(modules))
    want_tables = [m.init(k) for m, k in zip(modules, ekeys)]
    for got, want in zip(params["tables"], want_tables):
        for name in want:
            np.testing.assert_array_equal(np.asarray(got[name]),
                                          np.asarray(want[name]))
    want_bottom = _mlp_init(kb, (cfg.dense_dim,) + cfg.bottom_mlp
                            + (cfg.emb_dim,), cfg.pdtype)
    np.testing.assert_array_equal(np.asarray(params["bottom"][0]["w"]),
                                  np.asarray(want_bottom[0]["w"]))

    # forward: embed_features with proj=None is the identity path
    idx = jnp.asarray(np.stack([np.arange(4) % s for s in SIZES], 1))
    feats = embed_features(params["tables"], idx, cfg)
    direct = [m.apply(p, idx[:, i]) for i, (m, p)
              in enumerate(zip(modules, params["tables"]))]
    for f, d in zip(feats, direct):
        np.testing.assert_array_equal(np.asarray(f), np.asarray(d))


def test_uniform_width_plan_has_no_projections():
    """A plan solved without the dim ladder keeps every table at emb_dim:
    no proj entries, num_params matches the table sum exactly."""
    st = [power_law_stats(n, alpha=1.2) for n in SIZES]
    plan = build_plan(st, DIM, full_table_bytes(SIZES, DIM))
    assert set(plan.table_dims) == {DIM}
    params = dlrm_init(jax.random.PRNGKey(0), _cfg(plan))
    assert "proj" not in params


# ------------------------------------------------------------- models


def test_mixed_dim_dlrm_forward_and_num_params():
    plan = _mixed_plan()
    cfg = _cfg(plan)
    params = dlrm_init(jax.random.PRNGKey(0), cfg)
    narrow = [i for i in range(len(SIZES)) if plan.dim_for(i) != DIM]
    assert narrow, plan.table_dims
    assert set(params["proj"]) == {str(i) for i in narrow}
    for i in narrow:
        assert params["proj"][str(i)].shape == (plan.dim_for(i), DIM)
    total = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    assert total == dlrm_num_params(cfg)

    B = 6
    rng = np.random.default_rng(0)
    sp = np.stack([rng.integers(0, s, B) for s in SIZES], 1).astype(np.int32)
    logits = dlrm_forward(params, jnp.zeros((B, 13)), jnp.asarray(sp), cfg)
    assert logits.shape == (B,) and np.isfinite(np.asarray(logits)).all()
    # multi-hot with empty bags
    idx = np.zeros((B, len(SIZES), 2), np.int32)
    mask = np.zeros((B, len(SIZES), 2), np.float32)
    idx[:, :, 0] = sp
    mask[:, 0::2, 0] = 1.0  # half the features have empty bags
    ml = dlrm_forward(params, jnp.zeros((B, 13)), jnp.asarray(idx), cfg,
                      mask=jnp.asarray(mask))
    assert np.isfinite(np.asarray(ml)).all()


def test_mixed_dim_dcn_forward():
    plan = _mixed_plan()
    cfg = DCNConfig(table_sizes=SIZES, emb_dim=DIM, cross_layers=2,
                    deep_mlp=(32, 16), embedding=plan)
    params = dcn_init(jax.random.PRNGKey(1), cfg)
    assert "proj" in params
    B = 4
    sp = np.stack([np.arange(B) % s for s in SIZES], 1).astype(np.int32)
    logits = dcn_forward(params, jnp.zeros((B, 13)), jnp.asarray(sp), cfg)
    assert logits.shape == (B,) and np.isfinite(np.asarray(logits)).all()


def test_mixed_dim_dlrm_trains():
    """One jitted train step from a mixed-dim plan config: gradients flow
    through tables and projections alike."""
    from repro.data.criteo import CriteoSpec, batch_at
    from repro.models.dlrm import dlrm_loss_fn
    from repro.optim.optimizers import adagrad
    from repro.train.loop import init_state, make_train_step

    plan = _mixed_plan()
    cfg = _cfg(plan)
    params = dlrm_init(jax.random.PRNGKey(0), cfg)
    spec = CriteoSpec(table_sizes=SIZES, zipf=1.5, noise=0.5)
    state = init_state(params, adagrad(1e-2))
    step = jax.jit(make_train_step(lambda p, b: dlrm_loss_fn(p, b, cfg),
                                   adagrad(1e-2)))
    p0 = np.asarray(state["params"]["proj"][
        sorted(state["params"]["proj"])[0]]).copy()
    for i in range(3):
        state, m = step(state, batch_at(0, i, 32, spec))
        assert np.isfinite(float(m["loss"]))
    p1 = np.asarray(state["params"]["proj"][
        sorted(state["params"]["proj"])[0]])
    assert not np.array_equal(p0, p1), "projection got no gradient"


# ------------------------------------------------------------- serving


def test_mixed_dim_quantize_report_and_shapes():
    plan = _mixed_plan()
    cfg = _cfg(plan)
    params = dlrm_init(jax.random.PRNGKey(0), cfg)
    qp = quantize_params(params)
    rep = memory_report(params, qp)
    assert rep["table_dims"] == sorted(set(plan.table_dims))
    # quantized bytes equal the plan's serve_int8 domain exactly
    assert rep["quant_table_bytes"] \
        == sum(t.serve_bytes_int8 for t in plan.tables)
    # projections stay f32 (they are not table leaves)
    assert all(w.dtype == jnp.float32 for w in qp["proj"].values())
    # shapes report per-table widths, dense and quantized alike
    assert {w for _, _, w in table_shapes(params)} == set(plan.table_dims)
    assert {w for _, _, w in table_shapes(qp)} == set(plan.table_dims)


def test_mixed_dim_engine_cache_parity_empty_bags():
    """The full serving acceptance: mixed-dim planned model, int8 tables,
    cache on, request stream with empty bags — engine scores match the
    jnp oracle, and the cache caches rows at per-feature widths."""
    plan = _mixed_plan()
    cfg = _cfg(plan)
    params = dlrm_init(jax.random.PRNGKey(0), cfg)
    qp = quantize_params(params)
    rng = np.random.default_rng(5)
    reqs = []
    for _ in range(12):
        bags = [list(rng.integers(0, s, int(rng.integers(0, 3))))
                for s in SIZES]
        reqs.append((rng.normal(size=13), bags))
    cache = HotRowCache(capacity_rows=512)
    eng_c = RecsysEngine(cfg, qp, max_batch=4, cache=cache)
    eng_n = RecsysEngine(cfg, qp, max_batch=4)
    uids = [(eng_c.submit(d, b), eng_n.submit(d, b)) for d, b in reqs]
    done_c, done_n = eng_c.run_until_drained(), eng_n.run_until_drained()
    for (a, b), (dense, bags) in zip(uids, reqs):
        lmax = max([len(bg) for bg in bags] + [1])
        idx = np.zeros((1, len(bags), lmax), np.int32)
        mask = np.zeros((1, len(bags), lmax), np.float32)
        for i, bag in enumerate(bags):
            idx[0, i, :len(bag)] = bag
            mask[0, i, :len(bag)] = 1.0
        want = float(dlrm_forward(qp, jnp.asarray(dense[None], jnp.float32),
                                  jnp.asarray(idx), cfg,
                                  mask=jnp.asarray(mask))[0])
        assert abs(done_c[a].score - want) < 1e-3
        assert abs(done_n[b].score - want) < 1e-3
    # resident rows carry per-feature widths (cached pre-projection)
    row_widths = {row.shape[0] for row in cache._rows.values()}
    assert row_widths == {plan.dim_for(i) for i in range(len(SIZES))
                          if any(len(bags[i]) for _, bags in reqs)}
