"""repro.dist unit + property tests.

The multi-device cases run in a subprocess with 8 forced host devices
(mirroring the dry-run idiom in test_sharding_and_dryrun.py) so the main
test process keeps its single-device view.
"""

import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st
from jax.sharding import PartitionSpec as P

from repro.dist.compress import ef_psum_grads, init_error_state, quantize_int8
from repro.dist.sharding import (batch_axes, constrain, constrain_batch,
                                 fit_template, spec_for)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _axis_product(entry, sizes):
    if entry is None:
        return 1
    group = entry if isinstance(entry, tuple) else (entry,)
    return int(np.prod([sizes[a] for a in group]))


# ------------------------------------------------------------ rule engine


TEMPLATE_SYMBOLS = [None, "model", "dp", ("pod", "data"), "data", "pod"]


@settings(max_examples=200, deadline=None)
@given(st.data())
def test_fit_template_never_emits_indivisible_axis(data):
    """Property: every axis group in a fitted spec divides its dim, each
    mesh axis appears at most once, and the spec has full rank."""
    sizes = {"pod": data.draw(st.integers(1, 4)),
             "data": data.draw(st.integers(1, 8)),
             "model": data.draw(st.integers(1, 8))}
    rank = data.draw(st.integers(0, 4))
    shape = tuple(data.draw(st.integers(1, 400)) for _ in range(rank))
    template = tuple(data.draw(st.sampled_from(TEMPLATE_SYMBOLS))
                     for _ in range(data.draw(st.integers(0, 5))))
    spec = fit_template(template, shape, sizes, batch=("pod", "data"))
    if rank <= 1:
        assert spec == P()
        return
    assert len(spec) == rank
    seen = []
    for dim, entry in zip(shape, spec):
        assert dim % _axis_product(entry, sizes) == 0
        if entry is not None:
            seen.extend(entry if isinstance(entry, tuple) else (entry,))
    assert len(seen) == len(set(seen)), f"axis used twice: {spec}"


def test_fit_template_relocates_dropped_axis():
    sizes = {"data": 2, "model": 4}
    # 3 rows can't take model 4-ways; the 2048 column can
    assert fit_template(("model", None), (3, 2048), sizes) == P(None, "model")
    # nothing divides -> fully replicated, but still full-rank
    assert fit_template(("model", "dp"), (3, 5), sizes) == P(None, None)


def test_spec_for_single_device_mesh_and_1d():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert spec_for("layers/norm1/g", (2048,), mesh) == P()
    assert spec_for("anything/scalar", (), mesh) == P()
    # rank-2 leaves get full-rank specs on the trivial mesh
    assert len(spec_for("embed/table_0", (8000, 2048), mesh)) == 2


def test_batch_axes_excludes_model():
    mesh3 = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    assert batch_axes(mesh3) == ("pod", "data")
    mesh1 = jax.make_mesh((1,), ("data",))
    assert batch_axes(mesh1) == ("data",)


def test_spec_engine_8dev_property_sweep():
    """On real 2-D/3-D meshes: every emitted axis divides its dim; inference
    overrides never introduce data-parallel weight sharding."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import itertools, json, random
        import numpy as np
        import jax
        from repro.dist.sharding import INFERENCE_OVERRIDES, batch_axes, spec_for

        random.seed(0)
        paths = ["embed/table_0", "embed/table_7", "lm_head/w", "layers/moe/wi",
                 "layers/moe/wo", "layers/mlp/wi/w", "layers/attn/wq/w",
                 "layers/norm1/g", "tables/3/q", "frontend_proj/w"]
        meshes = [((2, 4), ("data", "model")), ((8, 1), ("data", "model")),
                  ((1, 8), ("data", "model")), ((2, 2, 2), ("pod", "data", "model"))]
        checked = 0
        for shape_mesh, axes in meshes:
            mesh = jax.make_mesh(shape_mesh, axes)
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            dp = batch_axes(mesh)
            for path in paths:
                for _ in range(30):
                    rank = random.randint(0, 3)
                    shape = tuple(random.randint(1, 600) for _ in range(rank))
                    for ov in (None, INFERENCE_OVERRIDES):
                        spec = spec_for(path, shape, mesh, overrides=ov)
                        assert len(spec) == (rank if rank > 1 else 0), (path, shape, spec)
                        for dim, ent in zip(shape, spec):
                            if ent is None:
                                continue
                            group = ent if isinstance(ent, tuple) else (ent,)
                            n = int(np.prod([sizes[a] for a in group]))
                            assert dim % n == 0, (path, shape, spec, mesh)
                            if ov is INFERENCE_OVERRIDES:
                                assert not (set(group) & set(dp)), \\
                                    ("inference spec uses dp axes", path, shape, spec)
                        checked += 1
        print(json.dumps({"checked": checked}))
    """)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=dict(os.environ, PYTHONPATH=f"{REPO}/src"),
                         timeout=300)
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["checked"] >= 2000


# ------------------------------------------------------------ constrain


def test_constrain_batch_noop_outside_mesh():
    x = jnp.arange(12.0).reshape(4, 3)
    assert constrain_batch(x) is x
    assert constrain(x, "dp", "model") is x
    assert constrain_batch(jnp.float32(1.0)) is not None  # scalars pass through


def test_constrain_noop_under_jit_without_mesh():
    x = jnp.ones((8, 4))
    f = jax.jit(lambda a: constrain(a, "dp", "model"))
    out = f(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x))


def test_constrain_is_identity_math_inside_mesh():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    x = jnp.arange(32.0).reshape(8, 4)
    with mesh:
        f = jax.jit(lambda a: constrain(a, "dp", "model") * 2.0)
        out = f(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x) * 2.0)


def test_constrain_skips_manual_axes_in_shard_map():
    """Inside shard_map every mesh axis is manual: constrain must degrade to
    identity instead of failing at lowering time."""
    from jax.experimental.shard_map import shard_map
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.ones((4, 4))

    def body(a):
        return constrain_batch(a) + 1.0

    with mesh:
        f = jax.jit(shard_map(body, mesh=mesh, in_specs=P("data"),
                              out_specs=P("data"), check_rep=False))
        out = f(x)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(x) + 1.0)


# ------------------------------------------------------------ compression


def test_quantize_int8_zero_and_constant_inputs():
    q, s = quantize_int8(jnp.zeros((16,)))
    assert np.isfinite(float(s)) and float(s) > 0
    np.testing.assert_array_equal(np.asarray(q), 0)
    q, s = quantize_int8(jnp.full((16,), -2.5))
    np.testing.assert_allclose(np.asarray(q, np.float32) * float(s), -2.5, rtol=1e-6)


def test_ef_mode_none_is_exact():
    g = {"a": jnp.arange(8.0), "b": {"c": jnp.ones((3, 3))}}
    err = init_error_state(g)
    out, new_err = ef_psum_grads(g, err, axis_name=None, mode="none")
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(g)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for e in jax.tree.leaves(new_err):
        np.testing.assert_array_equal(np.asarray(e), 0.0)


def test_ef_rejects_unknown_mode_and_mismatched_state():
    g = {"w": jnp.ones((4,))}
    with pytest.raises(ValueError):
        ef_psum_grads(g, init_error_state(g), axis_name=None, mode="fp4")
    with pytest.raises(ValueError):
        ef_psum_grads(g, [jnp.zeros((4,)), jnp.zeros((4,))], axis_name=None)


@pytest.mark.parametrize("mode", ["bf16", "int8"])
def test_ef_residual_bounded(mode):
    """Error feedback never lets the residual grow: it stays within one
    quantisation step of zero under repeated compression."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (256,)) * 1e-3}
    err = init_error_state(g)
    for _ in range(100):
        out, err = ef_psum_grads(g, err, axis_name=None, mode=mode)
    e = np.abs(np.asarray(err["w"]))
    v = np.abs(np.asarray(g["w"])) + e.max()
    # one ulp of bf16 at |v|, or one int8 step of the tensor's scale
    bound = (2 ** -8) * v.max() if mode == "bf16" else (v.max() / 127) * 0.5
    assert e.max() <= bound + 1e-7


@pytest.mark.slow
def test_fsdp_matches_dp_8dev_shard_map():
    """On a real 8-device mesh: make_fsdp_train_step must track the
    replicated make_dp_train_step losses step for step under the same
    policy — exactly for mode 'none' (same math, different collectives),
    and within phase-2-compression noise for the auto policy (the DP path
    re-compresses the reduced mean for its gather; FSDP doesn't need to).
    The FSDP executable must actually contain scatter/gather collectives."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax
        import numpy as np
        from repro.core import EmbeddingSpec
        from repro.data.criteo import CriteoSpec, batch_at
        from repro.launch.hlo_analysis import analyze_hlo
        from repro.models.dlrm import DLRMConfig, dlrm_init, dlrm_loss_fn
        from repro.optim.optimizers import adagrad
        from repro.train.loop import (init_dp_state, init_fsdp_state,
                                      make_dp_train_step, make_fsdp_train_step)

        SPEC = CriteoSpec(table_sizes=(100, 5000, 33))
        CFG = DLRMConfig(table_sizes=SPEC.table_sizes,
                         embedding=EmbeddingSpec(kind="qr", num_collisions=4,
                                                 threshold=50))
        loss_fn = lambda p, b: dlrm_loss_fn(p, b, CFG)
        mesh = jax.make_mesh((8,), ("data",))
        opt = adagrad(1e-2)
        params = dlrm_init(jax.random.PRNGKey(0), CFG)

        s_dp = init_dp_state(params, opt, compress="none")
        st_dp = jax.jit(make_dp_train_step(loss_fn, opt, mesh, compress="none"))
        s_fs = init_fsdp_state(params, opt, mesh, policy="none")
        fsdp_none = make_fsdp_train_step(loss_fn, opt, mesh, params,
                                         policy="none")
        st_fs = jax.jit(fsdp_none)
        s_dpa = init_dp_state(params, opt, compress="auto")
        st_dpa = jax.jit(make_dp_train_step(loss_fn, opt, mesh,
                                            compress="auto"))
        s_au = init_fsdp_state(params, opt, mesh, policy="auto")
        st_au = jax.jit(make_fsdp_train_step(loss_fn, opt, mesh, params,
                                             policy="auto"))
        max_dloss = max_dauto = 0.0
        with mesh:
            colls = analyze_hlo(jax.jit(fsdp_none)
                                .lower(s_fs, batch_at(0, 0, 64, SPEC))
                                .compile().as_text(), 8).collectives
            for i in range(8):
                batch = batch_at(0, i, 64, SPEC)
                s_dp, m_dp = st_dp(s_dp, batch)
                s_fs, m_fs = st_fs(s_fs, batch)
                s_dpa, m_dpa = st_dpa(s_dpa, batch)
                s_au, m_au = st_au(s_au, batch)
                max_dloss = max(max_dloss,
                                abs(float(m_dp["loss"]) - float(m_fs["loss"])))
                max_dauto = max(max_dauto,
                                abs(float(m_dpa["loss"]) - float(m_au["loss"]))
                                / max(1.0, float(m_dpa["loss"])))
        dparam = max(float(np.abs(np.asarray(a) - np.asarray(b)).max())
                     for a, b in zip(jax.tree.leaves(s_dp["params"]),
                                     jax.tree.leaves(s_fs["params"])))
        print(json.dumps({"max_dloss": max_dloss, "max_dparam": dparam,
                          "max_dauto": max_dauto,
                          "collectives": sorted(colls)}))
    """)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=dict(os.environ, PYTHONPATH=f"{REPO}/src"),
                         timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    # 'none' paths differ only by f32 reduction order (psum vs psum_scatter)
    assert out["max_dloss"] <= 1e-4, out
    assert out["max_dparam"] <= 1e-4, out
    # same policy, different collective paths: only phase-2 re-compression
    # of the already-reduced mean separates them (≤ one bf16 ulp / int8
    # step of the mean per leaf per step)
    assert out["max_dauto"] <= 0.05, out
    # the FSDP executable genuinely reduce-scatters and gathers
    assert "all-gather" in out["collectives"], out
    assert ("reduce-scatter" in out["collectives"]
            or "all-to-all" in out["collectives"]), out


@pytest.mark.slow
def test_fsdp_bf16_param_gather_halves_wire_8dev():
    """FSDP with param_gather_dtype='bfloat16': the param all-gather rides
    as 2 B/elem (bitcast uint16 — pinned against the compiled HLO, which
    must agree with accounting within 10%), accounting reports exactly
    half the f32 gather bytes, and training still tracks the replicated
    DP step (own-shard f32 master precision; only remote shards round)."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax
        from repro.core import EmbeddingSpec
        from repro.data.criteo import CriteoSpec, batch_at
        from repro.dist import accounting
        from repro.dist.policy import AUTO
        from repro.launch.hlo_analysis import analyze_hlo
        from repro.models.dlrm import DLRMConfig, dlrm_init, dlrm_loss_fn
        from repro.optim.optimizers import adagrad
        from repro.train.loop import (init_dp_state, init_fsdp_state,
                                      make_dp_train_step, make_fsdp_train_step)

        SPEC = CriteoSpec(table_sizes=(100, 5000, 33))
        CFG = DLRMConfig(table_sizes=SPEC.table_sizes,
                         embedding=EmbeddingSpec(kind="qr", num_collisions=4,
                                                 threshold=50))
        loss_fn = lambda p, b: dlrm_loss_fn(p, b, CFG)
        mesh = jax.make_mesh((8,), ("data",))
        opt = adagrad(1e-2)
        params = dlrm_init(jax.random.PRNGKey(0), CFG)

        acct_f32 = accounting.fsdp_step_wire_bytes(
            params, opt, mesh, AUTO, scalar_allreduces=3)
        acct_bf = accounting.fsdp_step_wire_bytes(
            params, opt, mesh, AUTO, scalar_allreduces=3,
            param_gather_dtype="bfloat16")
        step_bf = make_fsdp_train_step(loss_fn, opt, mesh, params,
                                       policy="auto",
                                       param_gather_dtype="bfloat16")
        s_bf = init_fsdp_state(params, opt, mesh, policy="auto")
        s_dp = init_dp_state(params, opt, compress="auto")
        st_dp = jax.jit(make_dp_train_step(loss_fn, opt, mesh,
                                           compress="auto"))
        st_bf = jax.jit(step_bf)
        with mesh:
            hlo = analyze_hlo(jax.jit(step_bf)
                              .lower(s_bf, batch_at(0, 0, 64, SPEC))
                              .compile().as_text(), 8)
            max_d = 0.0
            for i in range(6):
                b = batch_at(0, i, 64, SPEC)
                s_dp, m1 = st_dp(s_dp, b)
                s_bf, m2 = st_bf(s_bf, b)
                max_d = max(max_d, abs(float(m1["loss"]) - float(m2["loss"]))
                            / max(1.0, float(m1["loss"])))
        print(json.dumps({
            "gather_f32": acct_f32["param_gather_bytes"],
            "gather_bf16": acct_bf["param_gather_bytes"],
            "acct_total": acct_bf["total_bytes"],
            "hlo_total": hlo.collective_bytes,
            "max_rel_dloss": max_d}))
    """)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=dict(os.environ, PYTHONPATH=f"{REPO}/src"),
                         timeout=900)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["gather_bf16"] == pytest.approx(out["gather_f32"] / 2)
    rel = abs(out["acct_total"] - out["hlo_total"]) / out["hlo_total"]
    assert rel <= 0.10, out
    # bf16-rounded remote shards perturb the forward by ~one bf16 ulp
    assert out["max_rel_dloss"] <= 0.05, out


@pytest.mark.slow
def test_dist_bench_acceptance_dp():
    """benchmarks/dist_bench.py end to end (dp path, 4 steps): exits 0,
    BENCH_dist.json reports int8 < 0.3× none on the HLO cross-check, and
    accounting matches HLO within 10% for every row."""
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        out = os.path.join(td, "BENCH_dist.json")
        res = subprocess.run(
            [sys.executable, "-m", "benchmarks.dist_bench", "--steps", "4",
             "--paths", "dp", "--policies", "none,int8", "--out", out],
            capture_output=True, text=True, cwd=REPO,
            env=dict(os.environ, PYTHONPATH=f"{REPO}/src",
                     XLA_FLAGS="--xla_force_host_platform_device_count=8"),
            timeout=900)
        assert res.returncode == 0, (res.stdout[-2000:], res.stderr[-2000:])
        with open(out) as f:
            report = json.load(f)
    assert report["checks_failed"] == [], report["checks_failed"]
    assert report["int8_vs_none_ratio"] < 0.3, report["int8_vs_none_ratio"]
    for row in report["rows"]:
        rel = abs(row["wire_bytes"] - row["hlo_wire_bytes"]) \
            / row["hlo_wire_bytes"]
        assert rel <= 0.10, (row["path"], row["policy"], rel)


@pytest.mark.slow
def test_two_level_ef_tightens_int8_phase2_bias_8dev():
    """Two-level error feedback (phase-2 requant residual carried into the
    EF state) on the int8 two-phase exchange: with a *constant* per-device
    gradient, single-level EF converges to a standing bias of one int8
    step of the mean (phase 2 loses the same residual every step), while
    two-level telescopes it — the time-averaged output must land well
    inside the single-level floor, and replicas stay bitwise identical."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.dist.compress import ef_psum_grads

        mesh = jax.make_mesh((8,), ("data",))
        D = 64
        g_all = (jax.random.normal(jax.random.PRNGKey(0), (8, D)) * 3e-3
                 + jnp.linspace(-1e-3, 1e-3, 8)[:, None])
        true_mean = np.asarray(g_all).mean(axis=0)

        def run(two_level, T=60):
            def step(g_shard, err_shard, total_shard):
                g = {"w": g_shard.reshape(D)}
                err = {"w": err_shard.reshape(D)}
                out, new_err = ef_psum_grads(g, err, axis_name="data",
                                             mode="int8",
                                             two_level=two_level)
                return (new_err["w"][None],
                        (total_shard.reshape(D) + out["w"])[None])
            sharded = shard_map(step, mesh=mesh, in_specs=(P("data"),) * 3,
                                out_specs=(P("data"),) * 2, check_rep=False)
            err = jnp.zeros((8, D)); total = jnp.zeros((8, D))
            with mesh:
                fn = jax.jit(sharded)
                for _ in range(T):
                    err, total = fn(g_all, err, total)
            totals = np.asarray(total)
            for r in range(1, 8):
                np.testing.assert_array_equal(totals[r], totals[0])
            return float(np.abs(totals[0] / T - true_mean).max())

        print(json.dumps({"single": run(False), "two": run(True),
                          "scale": float(np.abs(true_mean).max())}))
    """)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=dict(os.environ, PYTHONPATH=f"{REPO}/src"),
                         timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    # two-level telescopes phase 2: decisively under the single-level
    # standing bias, and within EF's O(residual / T) envelope of the truth
    assert out["two"] <= out["single"] / 3, out
    assert out["two"] <= 5e-4 * out["scale"] + 1e-7, out


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["bf16", "int8"])
def test_ef_psum_unbiased_over_time_8dev_shard_map(mode):
    """Under a real 8-device shard_map psum with per-device-distinct
    gradients, the time-averaged EF-compressed reduction matches the true
    mean gradient, and every replica sees bitwise-identical output."""
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.dist.compress import ef_psum_grads, init_error_state

        mesh = jax.make_mesh((8,), ("data",))
        D = 64
        # per-device gradient rows, deliberately tiny to stress quantisation
        g_all = (jax.random.normal(jax.random.PRNGKey(0), (8, D)) * 3e-3
                 + jnp.linspace(-1e-3, 1e-3, 8)[:, None])
        true_mean = np.asarray(g_all).mean(axis=0)

        def step(g_shard, err_shard, total_shard):
            g = {{"w": g_shard.reshape(D)}}
            err = {{"w": err_shard.reshape(D)}}
            out, new_err = ef_psum_grads(g, err, axis_name="data", mode="{mode}")
            return new_err["w"][None], (total_shard.reshape(D) + out["w"])[None]

        sharded = shard_map(step, mesh=mesh,
                            in_specs=(P("data"), P("data"), P("data")),
                            out_specs=(P("data"), P("data")), check_rep=False)
        err = jnp.zeros((8, D))
        total = jnp.zeros((8, D))
        T = 60
        with mesh:
            fn = jax.jit(sharded)
            for _ in range(T):
                err, total = fn(g_all, err, total)
        totals = np.asarray(total)  # (8, D): per-replica accumulated output
        # every replica must hold the identical reduced gradient stream
        for r in range(1, 8):
            np.testing.assert_array_equal(totals[r], totals[0])
        avg = totals[0] / T
        err_abs = float(np.abs(avg - true_mean).max())
        # EF bound: |avg - true| <= max residual / T
        print(json.dumps({{"err_abs": err_abs,
                          "scale": float(np.abs(true_mean).max())}}))
    """)
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=dict(os.environ, PYTHONPATH=f"{REPO}/src"),
                         timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["err_abs"] <= 0.02 * out["scale"] + 1e-5, out
