"""Seeded EXC-001 violation: a bare except swallowing everything,
KeyboardInterrupt and worker faults included."""


def load_plan(path):
    try:
        with open(path) as f:
            return f.read()
    except:                                            # EXC-001  # noqa: E722
        return None
