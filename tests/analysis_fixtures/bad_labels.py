"""Seeded OBS-001 violation: an interpolated metric label — every new
value mints a fresh time series (unbounded cardinality)."""


def observe_wave(counter, feature_id, latency_us):
    counter.labels(feature=f"feat_{feature_id}").inc()   # OBS-001
    counter.labels(feature="all").observe(latency_us)
