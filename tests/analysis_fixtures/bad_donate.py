"""Seeded DON-001 violation: reading a buffer after passing it at a
donated position — XLA may already have reused its memory."""

import jax


def train_step(params, grads):
    update = jax.jit(lambda p, g: p, donate_argnums=(0,))
    new_params = update(params, grads)
    stale = params                                     # DON-001
    return new_params, stale
