"""Suppression round-trip fixture: the same JIT-001 shape as ``bad_jit``
but waived inline — the analyzer must report it as suppressed, not live."""

import jax


def per_call(fn, x):
    return jax.jit(fn)(x)   # repro: noqa[JIT-001] fixture: waiver round-trip
