"""Seeded ACC-001 violation: a kernel body that reduces ref-derived data
with no f32 upcast anywhere in the expression's dataflow."""

import jax.numpy as jnp


def pool_kernel(x_ref, mask_ref, o_ref):
    x = x_ref[...]
    w = mask_ref[...]
    o_ref[...] = (x * w[:, :, None]).sum(axis=1)       # ACC-001 here


def pool_kernel_ok(x_ref, mask_ref, o_ref):
    x = x_ref[...]
    w = mask_ref[...]
    o_ref[...] = (x * w[:, :, None]).astype(jnp.float32).sum(axis=1)
