"""Seeded DET-001 violation: wall-clock read in a kernel file — under
trace it freezes into a compile-time constant."""

import time


def stamp_rows(rows):
    t0 = time.monotonic()                              # DET-001
    return rows, t0
