"""Seeded JIT-001 violations: a wrapper built per loop iteration and a
jit-then-call in a single expression — both discard the compile cache."""

import jax


def per_iteration(fns, x):
    outs = []
    for fn in fns:
        jitted = jax.jit(fn)                           # JIT-001: in loop
        outs.append(jitted(x))
    return outs


def per_call(fn, x):
    return jax.jit(fn)(x)                              # JIT-001: immediate
