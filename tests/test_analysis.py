"""The static analyzer: seeded-violation fixtures each trip their rule,
the committed tree is clean, suppressions round-trip, the injectivity
certifier is exact on every structural family (brute-force crosschecked),
and the compile-count introspection is replay-stable."""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIXTURES = os.path.join("tests", "analysis_fixtures")


def _run_cli(argv):
    from repro.analysis.cli import run
    return run(argv)


def _layer1_findings(paths):
    from repro.analysis import load_passes
    from repro.analysis.findings import apply_suppressions
    from repro.analysis.registry import Context
    passes = load_passes("1")
    ctx = Context(root=REPO, paths=paths)
    findings = []
    for info in passes.values():
        if info.layer == 1:
            findings += info.fn(ctx)
    return apply_suppressions(findings, ctx.sources())


# ------------------------------------------------------- seeded fixtures

@pytest.mark.parametrize("fixture,rule,line", [
    ("kernels/bad_accum.py", "ACC-001", 10),
    ("bad_jit.py", "JIT-001", 10),
    ("bad_jit.py", "JIT-001", 16),
    ("bad_labels.py", "OBS-001", 6),
    ("bad_except.py", "EXC-001", 9),
    ("kernels/bad_clock.py", "DET-001", 8),
    ("bad_donate.py", "DON-001", 10),
])
def test_fixture_trips_rule(fixture, rule, line):
    found = _layer1_findings([os.path.join(FIXTURES, fixture)])
    live = [f for f in found if not f.suppressed]
    assert any(f.rule == rule and f.line == line for f in live), live


def test_fixture_dir_trips_every_rule_family():
    found = _layer1_findings([FIXTURES])
    rules = {f.rule for f in found if not f.suppressed}
    assert {"ACC-001", "JIT-001", "OBS-001", "DET-001",
            "EXC-001", "DON-001"} <= rules


def test_clean_tree_layer1_no_live_findings():
    found = _layer1_findings(None)   # default src/benchmarks/tests walk
    live = [f for f in found if not f.suppressed]
    assert live == [], live


# ----------------------------------------------------------- suppression

def test_noqa_roundtrip():
    found = _layer1_findings([os.path.join(FIXTURES, "noqa_ok.py")])
    assert len(found) == 1 and found[0].suppressed
    assert found[0].rule == "JIT-001"


def test_suppression_parsing():
    from repro.analysis.findings import suppressions_for
    text = ("x = 1\n"
            "y = f()   # repro: noqa[ACC-001, JIT-001] why\n"
            "z = g()   # repro: noqa\n")
    sup = suppressions_for(text)
    assert sup[2] == frozenset({"ACC-001", "JIT-001"})
    assert sup[3] is None and 1 not in sup


def test_formats_and_exit_codes(tmp_path):
    from repro.analysis.findings import Finding, format_findings
    fs = [Finding(rule="ACC-001", path="a.py", line=3, message="m"),
          Finding(rule="JIT-001", path="b.py", line=7, message="n",
                  suppressed=True)]
    human = format_findings(fs, "human")
    assert "a.py:3" in human and "[suppressed]" in human
    gh = format_findings(fs, "github")
    assert "::error file=a.py,line=3,title=ACC-001::" in gh
    assert "::notice file=b.py" in gh
    rep = json.loads(format_findings(fs, "json", root=REPO))
    assert rep["counts"] == {"total": 2, "unsuppressed": 1, "suppressed": 1}
    out = tmp_path / "r.json"
    rc = _run_cli(["--layer", "1", "--root", REPO, "--paths",
                   os.path.join(FIXTURES, "bad_except.py"),
                   "--out", str(out)])
    assert rc == 1
    assert json.loads(out.read_text())["ok"] is False


def test_cli_list_and_select():
    rc = _run_cli(["--list"])
    assert rc == 0
    # selecting a rule the fixture does not violate -> clean exit
    rc = _run_cli(["--layer", "1", "--root", REPO, "--select", "EXC-001",
                   "--paths", os.path.join(FIXTURES, "bad_jit.py")])
    assert rc == 0


# ------------------------------------------------- injectivity certifier

def test_certifier_structural_families_exact():
    from repro.analysis.injectivity import certify_partitions
    from repro.core.partitions import (crt_partitions,
                                       generalized_qr_partitions,
                                       naive_partition, qr_partitions)
    for parts, size in [
        (naive_partition(97), 97),
        (qr_partitions(1000, 32), 1000),
        (generalized_qr_partitions(500, (8, 8, 8)), 500),
        (crt_partitions(90, (9, 11)), 90),
    ]:
        cert = certify_partitions(parts, size)
        assert cert.injective and cert.exact, cert


def test_certifier_pigeonhole_exact_negative():
    from repro.analysis.injectivity import certify_partitions
    from repro.core.partitions import RemainderPartition
    cert = certify_partitions(
        [RemainderPartition(size=100, num_buckets=7, m=7)], 100)
    assert not cert.injective and cert.exact
    assert cert.method == "pigeonhole"


def test_certifier_matches_brute_force_on_random_families():
    from repro.analysis.injectivity import certify_partitions
    from repro.core.partitions import ExplicitPartition, is_complementary
    rng = np.random.default_rng(0)
    for _ in range(20):
        size = int(rng.integers(20, 200))
        k = int(rng.integers(1, 4))
        parts = []
        for _ in range(k):
            buckets = int(rng.integers(2, size + 1))
            parts.append(ExplicitPartition(
                size=size, num_buckets=buckets,
                table=rng.integers(0, buckets, size)))
        cert = certify_partitions(parts, size)
        assert cert.exact     # brute force below the cap is always exact
        assert cert.injective == is_complementary(parts, size)


def test_certifier_sampling_fallback_is_honest():
    from repro.analysis.injectivity import (COMPLEMENTARY_CHECK_MAX,
                                            certify_partitions)
    from repro.core.partitions import ExplicitPartition, RemainderPartition
    size = COMPLEMENTARY_CHECK_MAX + 50_000
    # an injective family the structural prover does not recognize
    # (explicit permutation table) above the brute cap: sampling finds no
    # collision and must NOT claim exactness
    perm = np.random.default_rng(1).permutation(size)
    cert = certify_partitions(
        [ExplicitPartition(size=size, num_buckets=size, table=perm)], size)
    assert cert.injective and not cert.exact and cert.method == "sampled"
    # a non-injective family above the cap: every id collides with its
    # partner at lcm distance, the sample catches one -> still exact
    parts = [RemainderPartition(size=size, num_buckets=m, m=m)
             for m in (500, 502)]
    cert = certify_partitions(parts, size)
    assert not cert.injective and cert.exact and cert.method == "sampled"


def test_bad_plan_artifact_reports_without_raising():
    from repro.analysis.injectivity import certify_plan
    from repro.plan.memory_plan import MemoryPlan
    plan = MemoryPlan.load(os.path.join(REPO, FIXTURES, "bad_plan.json"))
    findings, row = certify_plan(plan, "bad_plan.json")
    assert len(findings) == 1 and "table 0" in findings[0].message
    certs = {c["feature"]: c for c in row["certificates"]}
    assert certs[0]["injective"] is False and certs[0]["exact"] is True
    assert certs[1]["injective"] is True    # the qr table is fine


def test_hash_tables_are_lossy_by_design():
    from repro.analysis.injectivity import certify_table
    from repro.plan.memory_plan import TablePlan
    t = TablePlan(feature=0, num_categories=100, kind="hash",
                  num_collisions=4)
    required, cert, _ = certify_table(t, 16)
    assert not required and not cert.injective


# ------------------------------------------- compile-count introspection

def _small_engine():
    import jax
    from repro.core.factory import EmbeddingSpec
    from repro.models.dlrm import DLRMConfig, dlrm_init
    from repro.serve.quantize import quantize_params
    from repro.serve.recsys import RecsysEngine
    cfg = DLRMConfig(table_sizes=(100, 500, 33), emb_dim=16,
                     bottom_mlp=(32, 16), top_mlp=(32,),
                     embedding=EmbeddingSpec(kind="qr", num_collisions=4,
                                             threshold=40))
    params = quantize_params(dlrm_init(jax.random.PRNGKey(0), cfg))
    return RecsysEngine(cfg, params, max_batch=8)


def test_compile_count_counts_and_is_replay_stable():
    eng = _small_engine()
    reqs = [(np.zeros(13), [[1], [2, 3], [4]]) for _ in range(4)]
    for d, b in reqs:
        eng.submit(d, b)
    eng.run_until_drained()
    cc = eng.compile_count()
    assert set(cc["per_program"]) <= {"embed", "dense", "slab", "fast",
                                     "sharded_embed", "sharded_dense",
                                     "sharded_fast"}
    assert cc["total"] >= 1
    for d, b in reqs:                      # identical shapes: no growth
        eng.submit(d, b)
    eng.run_until_drained()
    assert eng.compile_count()["total"] == cc["total"]


def test_jit_cache_watcher_bounds_hold():
    from repro.analysis.jit_audit import replay_and_audit
    findings, tel = replay_and_audit(_small_engine())
    assert findings == []
    per = tel["first_pass"]["per_program"]
    assert per["embed"] <= tel["bounds"]["embed"]
    assert tel["replay"]["total"] == tel["first_pass"]["total"]


# -------------------------------------------------- support novelty rate

def test_unseen_id_rate_in_report():
    from repro.core.factory import EmbeddingSpec, make_embedding
    from repro.obs.collision import CollisionTelemetry
    sizes = (50, 60)
    spec = EmbeddingSpec(kind="qr", num_collisions=4, threshold=1)
    modules = [make_embedding(s, 8, spec) for s in sizes]
    t = CollisionTelemetry(sizes)
    assert t.unseen_id_rate(0) is None     # no baseline yet
    t.set_baseline([np.arange(25), np.arange(30)])
    idx = np.array([[[0, 24], [29, 30]],
                    [[49, 1], [31, 2]]])   # (B=2, F=2, L=2)
    t.record(idx, np.ones_like(idx, float))
    # feature 0 served {0,24,49,1}: 49 is novel -> 1/4
    # feature 1 served {29,30,31,2}: 30,31 novel -> 2/4
    assert t.unseen_id_rate(0) == pytest.approx(0.25)
    assert t.unseen_id_rate(1) == pytest.approx(0.5)
    rows = t.report(modules)
    assert rows[0]["unseen_id_rate"] == pytest.approx(0.25)
    t.reset()
    assert t.unseen_id_rate(0) == 0.0      # baseline survives the reset


# --------------------------------------------------- subprocess CI shape

def _cli_env(**extra):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
    env.update(extra)
    return env


@pytest.mark.slow
def test_cli_full_run_clean_tree_exits_zero(tmp_path):
    out = tmp_path / "report.json"
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--format", "github",
         "--out", str(out)],
        cwd=REPO, env=_cli_env(), capture_output=True, text=True,
        timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    rep = json.loads(out.read_text())
    assert rep["ok"] is True
    assert {p["id"] for p in rep["passes"]} >= {
        "ACC-001", "JIT-001", "OBS-001", "DET-001", "EXC-001", "DON-001",
        "ACC-002", "WIRE-001", "JIT-002", "INJ-001"}


@pytest.mark.slow
def test_cli_injected_wire_mismatch_exits_one():
    proc = subprocess.run(
        [sys.executable, "-m", "repro.analysis", "--select", "WIRE-001"],
        cwd=REPO, env=_cli_env(REPRO_ANALYSIS_INJECT="wire"),
        capture_output=True, text=True, timeout=600)
    assert proc.returncode == 1, proc.stdout + proc.stderr
    assert "WIRE-001" in proc.stdout
