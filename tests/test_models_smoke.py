"""Per-assigned-architecture smoke tests: reduced config, one forward/train
step on CPU, output shapes + no NaNs; decode step where applicable."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED
from repro.configs.common import Shape
from repro.optim.optimizers import sgd
from repro.train.loop import init_state, make_train_step

SMOKE_SHAPE = Shape("smoke", seq_len=32, global_batch=2, kind="train")


def _setup(arch):
    mod = ARCHS[arch]
    cfg = mod.config(reduced=True)
    api = mod.api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = api.batch_fn(0, SMOKE_SHAPE)
    return api, params, batch


@pytest.mark.parametrize("arch", ASSIGNED + ["dlrm-criteo", "dcn-criteo"])
def test_forward_and_train_step(arch):
    api, params, batch = _setup(arch)
    loss_fn = jax.jit(api.loss_fn)
    loss, metrics = loss_fn(params, batch)
    assert np.isfinite(float(loss)), arch
    assert float(loss) > 0
    # one SGD step must change params and keep loss finite
    opt = sgd(1e-2)
    state = init_state(params, opt)
    step = jax.jit(make_train_step(api.loss_fn, opt))
    state, m = step(state, batch)
    assert np.isfinite(float(m["loss"]))
    changed = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(state["params"])))
    assert changed, f"{arch}: parameters did not update"


@pytest.mark.parametrize("arch", [a for a in ASSIGNED])
def test_decode_step(arch):
    api, params, _ = _setup(arch)
    if api.decode is None:
        pytest.skip("no decode path")
    b, max_len = 2, 16
    cache = api.make_cache(b, max_len)
    tokens = jnp.zeros((b, 1), jnp.int32)
    decode = jax.jit(api.decode)
    logits, new_cache = decode(params, tokens, 3, cache)
    vocab = getattr(api.cfg, "vocab", None) or api.cfg.lm.vocab
    assert logits.shape == (b, 1, vocab)
    assert np.isfinite(np.asarray(logits)).all(), arch
    # cache must actually change
    same = all(np.allclose(np.asarray(a), np.asarray(x))
               for a, x in zip(jax.tree.leaves(cache), jax.tree.leaves(new_cache)))
    assert not same, f"{arch}: decode did not write the cache"


@pytest.mark.parametrize("arch", [a for a in ASSIGNED])
def test_prefill_consistency(arch):
    """Greedy next-token from prefill == argmax from teacher-forced logits."""
    api, params, _ = _setup(arch)
    if api.prefill is None:
        pytest.skip("no prefill path")
    b, s = 2, 16
    tokens = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, 64)
    cache = api.make_cache(b, s + 8)
    extra = ()
    if api.prefill_inputs is not None:
        structs = api.prefill_inputs(Shape("x", s, b, "prefill"))
        if len(structs) > 1:  # multimodal prefix (frames/patches)
            extra = tuple(jnp.zeros(st.shape, st.dtype) for st in structs[:-1])
    prefill = jax.jit(api.prefill)
    logits, cache2 = prefill(params, *extra, tokens, cache)
    assert logits.shape[0] == b and np.isfinite(np.asarray(logits)).all()


def test_embedding_variants_change_param_count():
    mod = ARCHS["tinyllama-1.1b"]
    sizes = {}
    for emb in ("full", "qr", "hash"):
        cfg = mod.config(reduced=True, embedding=emb)
        api = mod.api(cfg)
        params = api.init(jax.random.PRNGKey(0))
        sizes[emb] = sum(np.prod(l.shape) for l in jax.tree.leaves(params["embed"]))
    assert sizes["qr"] < sizes["full"]
    assert sizes["hash"] <= sizes["qr"]


def test_moe_arch_uses_moe_params():
    mod = ARCHS["arctic-480b"]
    cfg = mod.config(reduced=True)
    api = mod.api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    assert "moe" in params["layers"], "arctic must have MoE experts"
    assert "dense_mlp" in params["layers"], "arctic has a parallel dense branch"


def test_mla_arch_cache_is_latent():
    mod = ARCHS["deepseek-v2-236b"]
    cfg = mod.config(reduced=True)
    api = mod.api(cfg)
    cache = api.make_cache(2, 8)
    # MLA latent cache: ckv (L, B, S, kv_lora), no per-head K/V
    assert "ckv" in cache and cache["ckv"].shape[-1] == cfg.mla.kv_lora
