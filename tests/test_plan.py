"""repro.plan invariants: the planner's promises, pinned.

* budget is never exceeded, in either byte domain;
* every compositional choice is a complementary family (Definition 1);
* total quality is monotone non-decreasing in budget;
* a plan round-trips through JSON and ``make_embedding`` to the exact
  same ``num_params`` (cost model == built model);
* the planner strictly beats the uniform-hashing control under skew;
* the from-plan path trains (and ``launch.train --plan`` runs end to end).
"""

import json
import os
import subprocess
import sys

import jax
import numpy as np
import pytest

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import make_embedding
from repro.core.factory import EmbeddingSpec
from repro.core.partitions import (RemainderPartition, is_complementary,
                                   qr_partitions)
from repro.plan import (Candidate, FeatureStats, InfeasibleBudget, MemoryPlan,
                        build_plan, concave_frontier, dim_ladder,
                        dim_proxy_quality, enumerate_candidates,
                        fit_width_exponent, full_table_bytes,
                        module_partitions, power_law_stats, proxy_loss,
                        proxy_quality, required_dim, solve_budget,
                        stats_from_batches, uniform_hash_plan, width_factor)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SIZES = (1000, 200, 50000, 12000, 31, 24, 12517, 633, 3, 931)
DIM = 16


@pytest.fixture(scope="module")
def stats():
    return [power_law_stats(n, alpha=1.2) for n in SIZES]


# ------------------------------------------------------------ quality proxy


def test_proxy_full_table_is_perfect():
    st = power_law_stats(100, alpha=1.0)
    full = make_embedding(100, 8, EmbeddingSpec(kind="full"))
    from repro.plan import module_partitions
    assert proxy_quality(module_partitions(full), st) == 1.0


def test_proxy_hash_matches_collision_mass_brute_force():
    """k=1 (hashing): the proxy must equal sum_b M_b^2 - sum_i p_i^2 — the
    frequency-weighted collision mass — computed the slow way."""
    rng = np.random.default_rng(0)
    n, m = 97, 13
    probs = rng.random(n)
    probs /= probs.sum()
    st = FeatureStats(size=n, ids=np.arange(n), probs=probs)
    part = RemainderPartition(size=n, num_buckets=m, m=m)
    want = sum(probs[i] * sum(probs[j] for j in range(n)
                              if j != i and j % m == i % m)
               for i in range(n))
    assert abs(proxy_loss([part], st) - want) < 1e-12


def test_proxy_qr_below_hash_at_equal_rows():
    """A complementary QR pair must score strictly better than plain
    hashing with the same remainder table (the paper's core claim)."""
    st = power_law_stats(5000, alpha=1.1)
    m = 64
    hash_part = [RemainderPartition(size=5000, num_buckets=m, m=m)]
    qr = qr_partitions(5000, m)
    assert proxy_loss(qr, st) < proxy_loss(hash_part, st)
    assert proxy_quality(qr, st) > proxy_quality(hash_part, st)


def test_stats_from_batches_counts_and_multihot():
    batches = [{"sparse": np.array([[0, 1], [0, 2], [3, 1]])},
               {"sparse": np.array([[0, 2]])}]
    s = stats_from_batches(batches, table_sizes=(5, 4))
    assert s[0].size == 5 and s[0].support == 2
    np.testing.assert_allclose(s[0].probs, [0.75, 0.25])  # 0:3, 3:1
    # multi-hot with -1 padding is skipped
    mh = [{"sparse": np.array([[[0, -1], [1, 1]]])}]
    s2 = stats_from_batches(mh, table_sizes=(3, 3))
    assert s2[0].support == 1 and s2[1].support == 1
    np.testing.assert_allclose(s2[1].probs, [1.0])


# ------------------------------------------------------------ dim-aware proxy


def test_dim_quality_reduces_to_proxy_at_full_width():
    """At dim == full_dim both width factors are exactly 1, so dim-aware
    scoring equals the pre-dim proxy for every family."""
    st_ = power_law_stats(500, alpha=1.2)
    for spec in (EmbeddingSpec(kind="full"),
                 EmbeddingSpec(kind="hash", num_collisions=8),
                 EmbeddingSpec(kind="qr", num_collisions=4)):
        parts = module_partitions(make_embedding(500, DIM, spec))
        assert dim_proxy_quality(parts, st_, DIM, DIM) \
            == proxy_quality(parts, st_)


def test_dim_quality_monotone_and_concave_in_width():
    st_ = power_law_stats(5000, alpha=1.1)
    parts = module_partitions(
        make_embedding(5000, DIM, EmbeddingSpec(kind="hash",
                                                num_collisions=16)))
    qs = [dim_proxy_quality(parts, st_, d, 16) for d in (2, 4, 8, 16)]
    for a, b in zip(qs, qs[1:]):
        assert b >= a                       # wider is never worse
    gains = [b - a for a, b in zip(qs, qs[1:])]
    for g1, g2 in zip(gains, gains[1:]):
        assert g2 <= g1 + 1e-12             # concave: diminishing returns


def test_required_dim_tracks_perplexity():
    """A near-deterministic feature needs ~1 dim; flatter traffic needs
    more — and the width factor is free at/above the required dim."""
    peaked = FeatureStats(size=100, ids=np.arange(2),
                          probs=np.array([0.999, 0.001]))
    flat = FeatureStats(size=4096, ids=np.arange(4096),
                        probs=np.full(4096, 1 / 4096))
    assert required_dim(peaked) < 2 < required_dim(flat)
    assert width_factor(4, 16, peaked) == 1.0     # 4 >= d_req: free
    assert width_factor(4, 64, flat) < 1.0        # under-provisioned
    assert width_factor(64, 64, flat) == 1.0      # full width never penalized


def test_fit_width_exponent_recovers_beta():
    beta = 0.37
    samples = [(r, r ** beta) for r in (0.25, 0.5, 0.75, 1.0)]
    assert abs(fit_width_exponent(samples) - beta) < 1e-9
    with pytest.raises(ValueError):
        fit_width_exponent([(1.0, 1.0)])          # no signal
    with pytest.raises(ValueError):
        fit_width_exponent([(2.0, 0.5)])          # ratios out of range


def test_mixed_dim_strictly_beats_uniform_dim(stats):
    """The tentpole acceptance, on the fixture stats: with the {D/4, D/2,
    D} ladder the planner strictly beats its own uniform-width solve at
    the 0.125x budget (and never falls below it), builds genuinely mixed
    widths, and the byte claim survives the make_embedding round trip
    per table."""
    full = full_table_bytes(SIZES, DIM)
    for frac in (0.05, 0.125, 0.25):
        b = int(full * frac)
        uni = build_plan(stats, DIM, b)
        mix = build_plan(stats, DIM, b, dims=dim_ladder(DIM))
        assert mix.quality >= uni.quality - 1e-12, frac
        if frac == 0.125:
            assert mix.quality > uni.quality
            assert len(set(mix.table_dims)) >= 2, mix.table_dims
        assert mix.total_bytes <= b
        for i, (n, t) in enumerate(zip(SIZES, mix.tables)):
            mod = make_embedding(n, DIM, mix, feature=i)
            assert mod.num_params * 4 == t.train_bytes, (i, t)
            assert mod.out_dim == (t.dim or DIM)


def test_mixed_dim_plan_json_roundtrip(tmp_path, stats):
    plan = build_plan(stats, DIM, full_table_bytes(SIZES, DIM) // 8,
                      dims=dim_ladder(DIM), arch="mixed-rt")
    path = plan.save(str(tmp_path / "mixed.json"))
    loaded = MemoryPlan.load(path)
    assert loaded.to_json() == plan.to_json()
    assert loaded.table_dims == plan.table_dims
    assert loaded.notes == plan.notes
    n_loaded = sum(make_embedding(n, DIM, loaded, feature=i).num_params
                   for i, n in enumerate(SIZES))
    assert n_loaded * 4 == plan.total_bytes


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 40), st.integers(0, 10_000))
def test_dim_aware_frontier_monotone_in_budget(alpha10, seed):
    """Property: for random Zipf stats the dim-aware solve stays monotone
    non-decreasing in budget (the solver invariant the hull construction
    must preserve with the width cross-product folded in)."""
    rng = np.random.default_rng(seed)
    sizes = tuple(int(s) for s in rng.integers(3, 4000, size=4))
    alpha = alpha10 / 10.0
    sts = [power_law_stats(n, alpha=alpha) for n in sizes]
    full = full_table_bytes(sizes, DIM)
    qs = [build_plan(sts, DIM, max(int(full * f), len(sizes) * DIM),
                     dims=dim_ladder(DIM)).quality
          for f in (0.05, 0.1, 0.2, 0.4, 0.8, 1.0)]
    for a, b in zip(qs, qs[1:]):
        assert b >= a - 1e-12, (sizes, alpha, qs)
    assert qs[-1] == 1.0


# ------------------------------------------------------------ solver


def test_budget_never_exceeded(stats):
    full = full_table_bytes(SIZES, DIM)
    for frac in (0.02, 0.05, 0.1, 0.2, 0.4, 0.8):
        budget = int(full * frac)
        for domain in ("train_f32", "serve_int8"):
            b = (budget if domain == "train_f32"
                 else int(full_table_bytes(SIZES, DIM, domain) * frac))
            plan = build_plan(stats, DIM, b, bytes_domain=domain)
            assert plan.total_bytes <= b, (frac, domain)
            u = uniform_hash_plan(stats, DIM, b, bytes_domain=domain)
            assert u.total_bytes <= b, (frac, domain)


def test_infeasible_budget_raises(stats):
    with pytest.raises(InfeasibleBudget):
        build_plan(stats, DIM, len(SIZES) * DIM * 4 - 1)  # below 1 row/table


def test_infeasible_budget_message_names_floor(stats):
    """The error must carry both numbers an operator needs: the budget
    given and the floor allocation it missed."""
    budget = len(SIZES) * DIM * 4 - 1
    with pytest.raises(InfeasibleBudget) as ei:
        build_plan(stats, DIM, budget)
    msg = str(ei.value)
    assert str(budget) in msg
    assert "floor allocation" in msg and "cheapest" in msg
    assert str(len(SIZES) * DIM * 4) in msg  # the actual floor, in bytes


def test_single_candidate_ladders():
    """Degenerate input: every ladder has exactly one point — the solve
    must return it (no upgrades, nothing parked) or raise cleanly."""
    def cand(feature, cost, q):
        return Candidate(feature=feature, num_categories=10,
                         spec=EmbeddingSpec(kind="full"), rows=cost // 4,
                         train_bytes=cost, serve_bytes_int8=cost,
                         quality=q, dim=DIM)
    ladders = [[cand(0, 100, 0.5)], [cand(1, 60, 0.9)]]
    notes = {}
    chosen = solve_budget(ladders, 160, lambda c: c.train_bytes, notes=notes)
    assert [c.feature for c in chosen] == [0, 1]
    assert notes["parked"] == [] and notes["leftover_bytes"] == 0
    with pytest.raises(InfeasibleBudget):
        solve_budget(ladders, 159, lambda c: c.train_bytes)
    with pytest.raises(ValueError, match="at least one candidate"):
        solve_budget([[]], 100, lambda c: c.train_bytes)
    # a single-candidate frontier is that candidate
    assert concave_frontier([cand(0, 100, 0.5)],
                            lambda c: c.train_bytes) == [cand(0, 100, 0.5)]


def test_solver_notes_record_parked_upgrades():
    """A ladder where parking must occur: feature 0 can upgrade (cheap),
    feature 1's upgrade no longer fits — the solve reports it in notes
    and the emitted MemoryPlan carries the audit trail."""
    st_ = [power_law_stats(n, alpha=1.2) for n in (1000, 2000)]
    full = full_table_bytes((1000, 2000), DIM)
    # tight budget: something is always left mid-hull
    plan = build_plan(st_, DIM, int(full * 0.04))
    notes = plan.notes
    assert "parked" in notes and "leftover_bytes" in notes
    assert notes["hull_dropped"] >= 0
    assert notes["parked"], "a 4% budget must park at least one upgrade"
    for p in notes["parked"]:
        assert set(p) == {"feature", "upgrade", "extra_bytes", "dquality"}
        assert p["extra_bytes"] > notes["leftover_bytes"]  # truly didn't fit
        assert p["dquality"] > 0
    # full budget: nothing parked
    assert build_plan(st_, DIM, full).notes["parked"] == []


def test_quality_monotone_in_budget(stats):
    full = full_table_bytes(SIZES, DIM)
    qs = [build_plan(stats, DIM, int(full * f)).quality
          for f in (0.03, 0.05, 0.08, 0.125, 0.2, 0.25, 0.4, 0.5, 0.75, 1.0)]
    for a, b in zip(qs, qs[1:]):
        assert b >= a - 1e-12, qs
    assert qs[-1] == 1.0  # full budget -> every table full -> perfect proxy


def test_planner_beats_uniform_hash(stats):
    full = full_table_bytes(SIZES, DIM)
    for frac in (0.05, 0.125, 0.25, 0.5):
        p = build_plan(stats, DIM, int(full * frac))
        u = uniform_hash_plan(stats, DIM, int(full * frac))
        assert p.quality > u.quality, (frac, p.quality, u.quality)


def test_concave_frontier_slopes_decrease(stats):
    cands = enumerate_candidates(0, stats[2], DIM)  # the 50k feature
    cost = lambda c: c.train_bytes
    hull = concave_frontier(cands, cost)
    assert len(hull) >= 2
    for a, b in zip(hull, hull[1:]):
        assert cost(b) > cost(a) and b.quality > a.quality
    slopes = [(b.quality - a.quality) / (cost(b) - cost(a))
              for a, b in zip(hull, hull[1:])]
    for s1, s2 in zip(slopes, slopes[1:]):
        assert s2 < s1


# ------------------------------------------------------------ emitted plans


def test_compositional_choices_complementary(stats):
    full = full_table_bytes(SIZES, DIM)
    plan = build_plan(stats, DIM, int(full * 0.05))
    comp = [t for t in plan.tables if t.kind in ("qr", "mixed_radix", "crt")]
    assert comp, "a 5% budget must force compositional tables"
    for t in comp:
        mod = make_embedding(t.num_categories, DIM, t.spec())
        assert is_complementary(mod.partitions, t.num_categories), t
        assert t.complementary is True  # and the plan recorded it


def test_concat_cost_model_matches_built_bytes():
    """op='concat' sub-tables are dim/k wide — num_params is not a
    multiple of dim, which the physical (rows, width) accounting must
    survive: plan bytes == 4x the num_params make_embedding builds."""
    sizes = (1001, 500, 3331)
    st = [power_law_stats(n, alpha=1.2) for n in sizes]
    full = full_table_bytes(sizes, DIM)
    for frac in (0.1, 0.3):
        plan = build_plan(st, DIM, int(full * frac), op="concat")
        built = sum(make_embedding(n, DIM, plan, feature=i).num_params
                    for i, n in enumerate(sizes))
        assert built * 4 == plan.total_bytes
        assert plan.total_bytes <= int(full * frac)


def test_plan_json_roundtrip_same_num_params(tmp_path, stats):
    full = full_table_bytes(SIZES, DIM)
    plan = build_plan(stats, DIM, int(full * 0.125), arch="roundtrip")
    path = plan.save(str(tmp_path / "plan.json"))
    loaded = MemoryPlan.load(path)
    assert loaded.to_json() == plan.to_json()
    n_direct = sum(make_embedding(n, DIM, plan, feature=i).num_params
                   for i, n in enumerate(SIZES))
    n_loaded = sum(make_embedding(n, DIM, loaded, feature=i).num_params
                   for i, n in enumerate(SIZES))
    assert n_direct == n_loaded == plan.total_bytes // 4
    assert loaded.table_sizes == SIZES


def test_from_plan_path_validates(stats):
    plan = build_plan(stats, DIM, full_table_bytes(SIZES, DIM))
    with pytest.raises(ValueError, match="feature"):
        make_embedding(SIZES[0], DIM, plan)  # no feature index
    with pytest.raises(ValueError, match="categories"):
        make_embedding(SIZES[0] + 1, DIM, plan, feature=0)
    with pytest.raises(ValueError, match="emb_dim"):
        make_embedding(SIZES[0], DIM + 1, plan, feature=0)
    with pytest.raises(ValueError, match="no feature"):
        make_embedding(SIZES[0], DIM, plan, feature=len(SIZES))


def test_dlrm_trains_from_plan(stats):
    """config(plan=...) -> init -> one jitted train step: the end-to-end
    from-plan wiring models/configs/train all share."""
    from repro.data.criteo import CriteoSpec, batch_at
    from repro.train.loop import init_state, make_train_step

    small = (120, 77, 350)
    st_small = [power_law_stats(n, alpha=1.2) for n in small]
    plan = build_plan(st_small, DIM, full_table_bytes(small, DIM) // 5,
                      arch="dlrm-criteo")
    from repro.models.dlrm import DLRMConfig, dlrm_init, dlrm_loss_fn
    cfg = DLRMConfig(table_sizes=small, emb_dim=DIM, embedding=plan)
    params = dlrm_init(jax.random.PRNGKey(0), cfg)
    spec = CriteoSpec(table_sizes=small, zipf=1.5, noise=0.5)
    from repro.optim.optimizers import adagrad
    state = init_state(params, adagrad(1e-2))
    step = jax.jit(make_train_step(lambda p, b: dlrm_loss_fn(p, b, cfg),
                                   adagrad(1e-2)))
    losses = []
    for i in range(3):
        state, m = step(state, batch_at(0, i, 32, spec))
        losses.append(float(m["loss"]))
    assert all(np.isfinite(l) for l in losses), losses
    # size-mismatched plan fails loudly through config validation
    from repro.configs.common import resolve_plan
    with pytest.raises(ValueError, match="table sizes"):
        resolve_plan(plan, (120, 77, 351))


@pytest.mark.slow
def test_launch_train_cli_with_generated_plan(tmp_path):
    """The acceptance path: synthesize a plan for the reduced dlrm config,
    then ``launch.train --plan`` runs a smoke training from it."""
    from repro.configs import dlrm_criteo
    from repro.plan import plan_for_config

    cfg = dlrm_criteo.config(reduced=True)
    plan = plan_for_config(cfg, full_table_bytes(cfg.table_sizes,
                                                 cfg.emb_dim) // 8,
                           arch="dlrm-criteo", num_batches=8, batch_size=256)
    path = plan.save(str(tmp_path / "dlrm_plan.json"))
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "dlrm-criteo",
         "--steps", "3", "--batch", "32", "--log-every", "1",
         "--plan", path],
        capture_output=True, text=True, cwd=str(tmp_path),
        env=dict(os.environ, PYTHONPATH=f"{REPO}/src"), timeout=900)
    assert res.returncode == 0, (res.stdout[-2000:], res.stderr[-2000:])
    assert "embedding=plan" in res.stdout
    assert "loss" in res.stdout


@pytest.mark.slow
def test_plan_bench_acceptance():
    """benchmarks/plan_bench.py end to end: exits 0, BENCH_plan.json's own
    acceptance checks all pass, and the sweep covers every budget."""
    import tempfile
    with tempfile.TemporaryDirectory() as td:
        out = os.path.join(td, "BENCH_plan.json")
        res = subprocess.run(
            [sys.executable, "-m", "benchmarks.plan_bench",
             "--stats-batches", "6", "--batch-size", "256",
             "--no-save-plans", "--out", out],
            capture_output=True, text=True, cwd=REPO,
            env=dict(os.environ, PYTHONPATH=f"{REPO}/src"), timeout=900)
        assert res.returncode == 0, (res.stdout[-2000:], res.stderr[-2000:])
        with open(out) as f:
            report = json.load(f)
    assert report["checks_failed"] == [], report["checks_failed"]
    assert len(report["rows"]) == 8  # 2 archs x 4 budgets
    for r in report["rows"]:
        assert r["plan_bytes"] <= r["budget_bytes"], r
        assert r["quality"] > r["uniform_quality"], r
