"""Compositional/hash/path embeddings: semantics, params, factory (paper §2/§4)."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (CompositionalEmbedding, EmbeddingSpec, FullEmbedding,
                        HashEmbedding, PathBasedEmbedding, bag_pool,
                        make_embedding, qr_embedding, qr_partitions)


def test_qr_matches_manual_lookup():
    emb = qr_embedding(103, 8, num_collisions=4, op="mult")
    p = emb.init(jax.random.PRNGKey(0))
    idx = jnp.arange(103)
    m = emb.partitions[0].num_buckets
    want = p["table_0"][idx % m] * p["table_1"][idx // m]
    np.testing.assert_allclose(emb.apply(p, idx), want, rtol=1e-6)


@pytest.mark.parametrize("op", ["mult", "add", "concat"])
def test_ops_shapes_and_param_counts(op):
    emb = qr_embedding(1000, 16, num_collisions=10, op=op)
    p = emb.init(jax.random.PRNGKey(1))
    out = emb.apply(p, jnp.array([[0, 999], [5, 17]]))
    assert out.shape == (2, 2, 16)
    # QR total rows ~ m + ceil(S/m) << S
    assert emb.num_params < FullEmbedding(1000, 16).num_params / 5


def test_compression_ratio_matches_collisions():
    """Paper §5.3: c collisions ≈ c× fewer embedding parameters."""
    full = FullEmbedding(100000, 16)
    for c in (2, 4, 60):
        emb = qr_embedding(100000, 16, num_collisions=c)
        ratio = full.num_params / emb.num_params
        assert 0.8 * c <= ratio <= 1.2 * c, (c, ratio)


def test_hash_collides_qr_does_not():
    size, c = 64, 4
    hash_emb = HashEmbedding(size, 4, m=size // c)
    qr = qr_embedding(size, 4, num_collisions=c)
    hp = hash_emb.init(jax.random.PRNGKey(2))
    qp = qr.init(jax.random.PRNGKey(3))
    idx = jnp.arange(size)
    h_rows = np.asarray(hash_emb.apply(hp, idx))
    q_rows = np.asarray(qr.apply(qp, idx))
    assert len(np.unique(h_rows.round(6), axis=0)) < size  # hashing collides
    assert len(np.unique(q_rows.round(6), axis=0)) == size  # QR stays unique


def test_feature_generation_mode():
    emb = qr_embedding(100, 8, num_collisions=4)
    p = emb.init(jax.random.PRNGKey(4))
    feats = emb.partition_embeddings(p, jnp.arange(10))
    assert len(feats) == 2 and all(f.shape == (10, 8) for f in feats)


def test_path_based_embedding():
    pe = PathBasedEmbedding(100, 16, partitions=tuple(qr_partitions(100, 25)),
                            hidden=8)
    p = pe.init(jax.random.PRNGKey(5))
    out = pe.apply(p, jnp.arange(100))
    assert out.shape == (100, 16)
    assert np.isfinite(np.asarray(out)).all()
    # distinct categories in the same base bucket get different outputs
    # (different MLP path): 0 and 25 share remainder bucket? base is partition 0
    assert not np.allclose(np.asarray(out[0]), np.asarray(out[25]))


def test_factory_threshold_rule():
    spec = EmbeddingSpec(kind="qr", num_collisions=4, threshold=500)
    small = make_embedding(100, 16, spec)
    big = make_embedding(10000, 16, spec)
    assert isinstance(small, FullEmbedding)
    assert isinstance(big, CompositionalEmbedding)


def test_factory_kinds():
    for kind, cls in [("full", FullEmbedding), ("hash", HashEmbedding),
                      ("qr", CompositionalEmbedding),
                      ("mixed_radix", CompositionalEmbedding),
                      ("path", PathBasedEmbedding)]:
        emb = make_embedding(1000, 8, EmbeddingSpec(kind=kind))
        assert isinstance(emb, cls), kind
        p = emb.init(jax.random.PRNGKey(0))
        assert emb.apply(p, jnp.arange(5)).shape[-1] == 8


def test_crt_factory():
    emb = make_embedding(1000, 8, EmbeddingSpec(kind="crt", ms=(32, 33)))
    p = emb.init(jax.random.PRNGKey(0))
    out = emb.apply(p, jnp.arange(1000))
    assert len(np.unique(np.asarray(out).round(6), axis=0)) == 1000


def test_bag_pool_masking():
    emb = qr_embedding(50, 8, num_collisions=2)
    p = emb.init(jax.random.PRNGKey(6))
    idx = jnp.array([[1, 2, 3], [4, 5, 6]])
    mask = jnp.array([[1.0, 0.0, 1.0], [1.0, 1.0, 0.0]])
    out = bag_pool(emb, p, idx, mask)
    want0 = emb.apply(p, jnp.array(1)) + emb.apply(p, jnp.array(3))
    np.testing.assert_allclose(out[0], want0, rtol=1e-5)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 500), st.integers(1, 64), st.sampled_from(["mult", "add", "concat"]))
def test_uniqueness_property_all_ops(size, c, op):
    """All-categories embedding matrix has no duplicate rows (generic init)."""
    dim = 8 if op != "concat" else 8
    emb = qr_embedding(size, dim, num_collisions=min(c, size), op=op)
    p = emb.init(jax.random.PRNGKey(size * 31 + c))
    rows = np.asarray(emb.apply(p, jnp.arange(size)), np.float64)
    assert len(np.unique(rows.round(10), axis=0)) == size
