"""repro.serve v2: quantization, fused dequant kernel, hot-row cache,
and the microbatched RecsysEngine (bucket-padding correctness)."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EmbeddingSpec, table_rows
from repro.kernels import ops, ref
from repro.kernels.qr_gather import qr_gather_quant
from repro.models.dcn import DCNConfig, dcn_init
from repro.models.dlrm import (DLRMConfig, dlrm_forward, dlrm_init,
                               dlrm_loss_fn)
from repro.serve.cache import HotRowCache
from repro.serve.quantize import (dequantize_rows, dequantize_table,
                                  is_quantized_table, memory_report,
                                  paths_and_leaves, quantize_params,
                                  quantize_table)
from repro.serve.recsys import RecsysEngine

SIZES = (100, 500, 33)


def _cfg(**kw):
    base = dict(table_sizes=SIZES, emb_dim=16, bottom_mlp=(32, 16),
                top_mlp=(32,),
                embedding=EmbeddingSpec(kind="qr", num_collisions=4,
                                        threshold=40))
    base.update(kw)
    return DLRMConfig(**base)


def _requests(n, seed=0, sizes=SIZES, max_bag=3):
    rng = np.random.default_rng(seed)
    return [(rng.normal(size=13),
             [list(rng.integers(0, s, size=rng.integers(1, max_bag + 1)))
              for s in sizes])
            for _ in range(n)]


# ------------------------------------------------------------- quantization


def test_quantize_per_row_error_bound():
    """|dequant - w| <= scale/2 per row, even with per-row magnitude skew."""
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (64, 32)) \
        * jnp.exp(2.0 * jax.random.normal(jax.random.PRNGKey(1), (64, 1)))
    qt = quantize_table(w)
    err = np.abs(np.asarray(dequantize_table(qt)) - np.asarray(w, np.float32))
    bound = 0.5 * np.asarray(qt["scale"], np.float32)
    assert (err <= bound + 1e-7).all()
    # per-row scales actually differ (the point of row-wise quantization)
    scales = np.asarray(qt["scale"], np.float32).ravel()
    assert scales.max() / scales.min() > 10


def test_quantize_degenerate_rows():
    # all-zero row: exact; constant positive row: zero must stay on-grid
    w = jnp.stack([jnp.zeros((8,)), jnp.full((8,), 2.5),
                   jnp.full((8,), -1e-30)])
    qt = quantize_table(w)
    deq = np.asarray(dequantize_table(qt))
    np.testing.assert_array_equal(deq[0], 0.0)
    np.testing.assert_allclose(deq[1], 2.5, rtol=1e-2)
    assert np.isfinite(np.asarray(qt["scale"], np.float32)).all()
    assert qt["q"].dtype == jnp.int8 and qt["zp"].dtype == jnp.int8


def test_quantize_gathers_only_requested_rows():
    w = jax.random.normal(jax.random.PRNGKey(2), (20, 8))
    qt = quantize_table(w)
    idx = jnp.asarray([3, 3, 19, 0])
    rows = dequantize_rows(qt, idx)
    assert rows.shape == (4, 8) and rows.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(rows),
                               np.asarray(dequantize_table(qt))[np.asarray(idx)],
                               rtol=1e-6)
    # table_rows is the shared gather: dense and quantized agree to bound
    np.testing.assert_allclose(np.asarray(rows),
                               np.asarray(table_rows(qt, idx)), rtol=1e-6)


def test_quantize_params_only_touches_tables():
    cfg = _cfg()
    params = dlrm_init(jax.random.PRNGKey(0), cfg)
    qp = quantize_params(params)
    # every table leaf quantized, every MLP leaf untouched
    for path, leaf in paths_and_leaves(qp):
        if "table" in path:
            assert is_quantized_table(leaf), path
        else:
            assert not is_quantized_table(leaf) and leaf.dtype == jnp.float32, path
    # bf16 mode: same structure, tables cast
    bp = quantize_params(params, mode="bf16")
    for path, leaf in paths_and_leaves(bp):
        want = jnp.bfloat16 if "table" in path else jnp.float32
        assert leaf.dtype == want, path
    assert quantize_params(params, mode="f32") is params
    with pytest.raises(ValueError):
        quantize_params(params, mode="fp4")


def test_memory_report_int8_ratio_at_serve_dim():
    """At the deployment dim (D=64) int8 tables beat the 0.27x bar;
    bf16 is exactly 0.5x."""
    cfg = _cfg(emb_dim=64)
    params = dlrm_init(jax.random.PRNGKey(0), cfg)
    rep = memory_report(params, quantize_params(params))
    assert rep["ratio"] <= 0.27, rep
    rep_bf = memory_report(params, quantize_params(params, mode="bf16"))
    assert abs(rep_bf["ratio"] - 0.5) < 1e-6
    assert rep["model_bytes_quant"] < rep["model_bytes_f32"]


# ------------------------------------------------------- fused dequant kernel


@pytest.mark.parametrize("op", ["mult", "add"])
@pytest.mark.parametrize("m,q,d,n", [(7, 3, 16, 5), (64, 8, 128, 33)])
def test_qr_gather_quant_kernel_matches_oracle(op, m, q, d, n):
    """Kernel (int8 gather + VMEM dequant + combine) == jnp dequant oracle
    bitwise, and tracks the f32-table oracle within the propagated
    per-row-scale bound."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(3))
    wr = jax.random.normal(k1, (m, d))
    wq = jax.random.normal(k2, (q, d))
    qr_, qq_ = quantize_table(wr), quantize_table(wq)
    idx = jax.random.randint(jax.random.PRNGKey(4), (n,), 0, m * q)
    rem, quo = idx % m, idx // m
    meta_r = jnp.concatenate([qr_["scale"].astype(jnp.float32),
                              qr_["zp"].astype(jnp.float32)], axis=1)
    meta_q = jnp.concatenate([qq_["scale"].astype(jnp.float32),
                              qq_["zp"].astype(jnp.float32)], axis=1)
    got = qr_gather_quant(rem, quo, qr_["q"], qq_["q"], meta_r, meta_q, op=op)
    want = ref.qr_gather_quant_ref(rem, quo, qr_["q"], qq_["q"],
                                   meta_r, meta_q, op=op)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    f32 = ref.qr_gather_ref(rem, quo, wr, wq, op=op)
    a = np.asarray(jnp.take(wr, rem, axis=0))
    b = np.asarray(jnp.take(wq, quo, axis=0))
    da = 0.5 * np.asarray(qr_["scale"], np.float32)[np.asarray(rem)]
    db = 0.5 * np.asarray(qq_["scale"], np.float32)[np.asarray(quo)]
    if op == "mult":  # |a'b' - ab| <= |a| db + |b| da + da db
        bound = np.abs(a) * db + np.abs(b) * da + da * db
    else:
        bound = da + db
    err = np.abs(np.asarray(got) - np.asarray(f32, np.float32))
    assert (err <= bound + 1e-6).all()


def test_qr_lookup_routes_quantized_tables():
    wr = jax.random.normal(jax.random.PRNGKey(5), (40, 16))
    wq = jax.random.normal(jax.random.PRNGKey(6), (5, 16))
    qr_, qq_ = quantize_table(wr), quantize_table(wq)
    idx = jax.random.randint(jax.random.PRNGKey(7), (2, 9), 0, 200)
    got = ops.qr_lookup(idx, qr_, qq_)                     # fused kernel
    want = ops.qr_lookup(idx, qr_, qq_, use_kernel=False)  # dequant fallback
    assert got.shape == (2, 9, 16) and got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)
    # concat falls back without the kernel
    cat = ops.qr_lookup(idx, qr_, qq_, op="concat")
    assert cat.shape == (2, 9, 32)


def test_qr_bag_lookup_quantized_mask_semantics():
    """Masked slots of a quantized bag contribute exactly nothing."""
    wr = jax.random.normal(jax.random.PRNGKey(8), (40, 16))
    wq = jax.random.normal(jax.random.PRNGKey(9), (5, 16))
    qr_, qq_ = quantize_table(wr), quantize_table(wq)
    idx = jax.random.randint(jax.random.PRNGKey(10), (4, 6), 0, 200)
    mask = jnp.asarray(np.tile([1, 1, 1, 0, 0, 0], (4, 1)), jnp.float32)
    got = ops.qr_bag_lookup(idx, mask, qr_, qq_)
    # garbage in the masked tail must not change the pool
    idx_garbage = idx.at[:, 3:].set(199)
    got2 = ops.qr_bag_lookup(idx_garbage, mask, qr_, qq_)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(got2))
    want = ops.qr_bag_lookup(idx[:, :3], mask[:, :3], qr_, qq_)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-6)


# --------------------------------------------------------------- hot-row cache


def test_cache_lru_eviction_order():
    c = HotRowCache(capacity_rows=2, policy="lru", record_events=True)
    r = np.ones(4, np.float32)
    c.put("a", r)
    c.put("b", r)
    assert c.get("a") is not None          # a now more recent than b
    c.put("c", r)                          # evicts b
    assert "b" not in c and "a" in c and "c" in c
    assert ("evict", "b") in c.events
    assert c.stats.evictions == 1 and c.stats.insertions == 3


def test_cache_lfu_keeps_hot_key():
    c = HotRowCache(capacity_rows=2, policy="lfu")
    r = np.ones(4, np.float32)
    c.put("hot", r)
    for _ in range(5):
        c.get("hot")
    c.put("cold", r)
    c.put("new", r)                        # evicts cold (freq 1 < 6)
    assert "hot" in c and "cold" not in c
    assert c.stats.hit_rate == 1.0         # 5 hits, 0 misses so far


def test_cache_deterministic_replay():
    rng = np.random.default_rng(0)
    stream = [("t", int(k), int(k) % 7) for k in rng.integers(0, 40, 300)]
    a = HotRowCache(capacity_rows=16, policy="lfu").replay(stream)
    b = HotRowCache(capacity_rows=16, policy="lfu").replay(stream)
    assert a == b and len(a) >= 300
    lru_a = HotRowCache(capacity_rows=16, policy="lru").replay(stream)
    lru_b = HotRowCache(capacity_rows=16, policy="lru").replay(stream)
    assert lru_a == lru_b
    assert lru_a != a  # the policies genuinely differ on this stream


def test_cache_counters_and_bytes():
    c = HotRowCache(capacity_rows=8)
    row = np.ones(16, np.float32)
    assert c.get("x") is None
    c.put("x", row)
    assert c.get("x") is not None
    assert c.stats.hits == 1 and c.stats.misses == 1
    assert c.stats.bytes_cached == row.nbytes
    found, missing = c.get_many(["x", "y", "x"])
    assert set(found) == {"x"} and missing == ["y"]
    with pytest.raises(ValueError):
        HotRowCache(policy="mru")


def test_cache_byte_budget_admission():
    """capacity_bytes binds independently of capacity_rows: resident bytes
    never exceed the budget, eviction order stays the policy's."""
    from repro.serve.quantize import row_bytes
    d = 16
    row = np.ones(d, np.float32)          # 4*d = row_bytes(d, "f32") bytes
    assert row.nbytes == row_bytes(d, "f32")
    c = HotRowCache(capacity_rows=100, policy="lru",
                    capacity_bytes=3 * row.nbytes, record_events=True)
    for k in "abc":
        c.put(k, row)
    assert len(c) == 3 and c.stats.bytes_cached == 3 * row.nbytes
    c.put("d", row)                        # over budget: evicts LRU "a"
    assert "a" not in c and len(c) == 3
    assert c.stats.bytes_cached <= c.capacity_bytes
    assert ("evict", "a") in c.events


def test_cache_bytes_only_capacity_and_oversized_reject():
    c = HotRowCache(capacity_rows=None, capacity_bytes=100)
    small = np.ones(4, np.float32)         # 16 B
    for k in range(6):                     # 6*16 = 96 B fits
        c.put(k, small)
    assert len(c) == 6 and c.stats.bytes_cached == 96
    c.put(99, small)                       # 112 > 100: evicts one
    assert len(c) == 6 and c.stats.bytes_cached <= 100
    big = np.ones(64, np.float32)          # 256 B > whole budget
    c.put("big", big)                      # rejected, cache untouched
    assert "big" not in c and len(c) == 6
    assert c.stats.rejections == 1
    with pytest.raises(ValueError):
        HotRowCache(capacity_rows=None, capacity_bytes=None)


def test_cache_oversized_refresh_invalidates_not_evicts():
    """A rejected oversized *refresh* of a resident key drops the stale
    value as an invalidation — eviction counts stay capacity-pressure
    only, and the event sequence is pinned."""
    c = HotRowCache(capacity_rows=None, capacity_bytes=100,
                    record_events=True)
    small = np.ones(4, np.float32)         # 16 B
    big = np.ones(64, np.float32)          # 256 B > whole budget
    c.put("k", small)
    c.put("other", small)
    c.put("k", big)                        # oversized refresh of resident k
    assert "k" not in c and "other" in c   # stale value gone, no flush
    assert c.stats.rejections == 1
    assert c.stats.invalidations == 1
    assert c.stats.evictions == 0          # nothing was capacity-evicted
    assert c.events == [("put", "k"), ("put", "other"),
                        ("reject", "k"), ("invalidate", "k")]
    assert c.stats.bytes_cached == small.nbytes
    # a fresh oversized key is a plain rejection: no invalidation
    c.put("new", big)
    assert c.stats.rejections == 2 and c.stats.invalidations == 1
    assert c.stats.as_dict()["invalidations"] == 1


def test_cache_byte_budget_replay_deterministic():
    rng = np.random.default_rng(1)
    stream = [("t", int(k), int(k) % 5) for k in rng.integers(0, 30, 200)]
    kw = dict(capacity_rows=64, capacity_bytes=24 * 16, policy="lfu")
    a = HotRowCache(**kw).replay(stream, row_bytes=16)
    b = HotRowCache(**kw).replay(stream, row_bytes=16)
    assert a == b
    # the byte bound genuinely binds (smaller than the row bound alone)
    unbounded = HotRowCache(capacity_rows=64, policy="lfu")
    unbounded.replay(stream, row_bytes=16)
    bounded = HotRowCache(**kw)
    bounded.replay(stream, row_bytes=16)
    assert bounded.stats.bytes_cached <= 24 * 16
    assert bounded.stats.evictions > unbounded.stats.evictions


# -------------------------------------------------------------- RecsysEngine


def test_engine_bucket_padding_is_exact():
    """Padded bag slots and padded batch rows must not change any score:
    engine (padded/bucketed) == direct per-request forward (exact shapes)."""
    cfg = _cfg()
    params = dlrm_init(jax.random.PRNGKey(0), cfg)
    reqs = _requests(11)  # odd count -> batch padding in the last wave
    # legacy lock-step mode: FIFO slices make the wave/bucket accounting
    # below exact (continuous batching groups by bag-length bucket instead)
    eng = RecsysEngine(cfg, params, max_batch=4, batching="waves")
    uids = [eng.submit(d, b) for d, b in reqs]
    done = eng.run_until_drained()
    for uid, (dense, bags) in zip(uids, reqs):
        lmax = max(len(b) for b in bags)
        idx = np.zeros((1, len(bags), lmax), np.int32)
        mask = np.zeros((1, len(bags), lmax), np.float32)
        for i, bag in enumerate(bags):
            idx[0, i, :len(bag)] = bag
            mask[0, i, :len(bag)] = 1.0
        want = float(dlrm_forward(params, jnp.asarray(dense[None], jnp.float32),
                                  jnp.asarray(idx), cfg,
                                  mask=jnp.asarray(mask))[0])
        assert abs(done[uid].score - want) < 1e-4, uid
    m = eng.metrics()
    assert m["requests"] == 11 and m["waves"] == 3
    assert all(b in ((1, 1), (2, 2), (4, 4), (1, 2), (2, 4), (4, 2), (1, 4),
                     (2, 1), (4, 1)) for b in m["buckets"])


def test_engine_cache_parity_and_hit_rate():
    """Cache-on scores == cache-off scores; a repeated Zipfian stream hits."""
    cfg = _cfg()
    params = quantize_params(dlrm_init(jax.random.PRNGKey(0), cfg))
    reqs = _requests(16, seed=1) * 2  # repeat -> guaranteed reuse
    eng_c = RecsysEngine(cfg, params, max_batch=8,
                         cache=HotRowCache(capacity_rows=1024))
    eng_n = RecsysEngine(cfg, params, max_batch=8)
    for d, b in reqs:
        eng_c.submit(d, b)
        eng_n.submit(d, b)
    done_c = eng_c.run_until_drained()
    done_n = eng_n.run_until_drained()
    for uid in done_n:
        assert abs(done_c[uid].score - done_n[uid].score) < 1e-4
    stats = eng_c.metrics()["cache"]
    assert stats["hit_rate"] > 0 and stats["hits"] > 0
    assert stats["bytes_cached"] > 0


def test_engine_quantized_close_to_f32_and_dcn():
    cfg = _cfg()
    params = dlrm_init(jax.random.PRNGKey(0), cfg)
    qp = quantize_params(params)
    reqs = _requests(8, seed=2)
    scores = {}
    for tag, p in (("f32", params), ("int8", qp)):
        eng = RecsysEngine(cfg, p, max_batch=8)
        uids = [eng.submit(d, b) for d, b in reqs]
        done = eng.run_until_drained()
        scores[tag] = [done[u].score for u in uids]
    np.testing.assert_allclose(scores["int8"], scores["f32"], atol=5e-2)

    dcfg = DCNConfig(table_sizes=SIZES, emb_dim=16, cross_layers=2,
                     deep_mlp=(32, 16),
                     embedding=EmbeddingSpec(kind="qr", num_collisions=4,
                                             threshold=40))
    dparams = dcn_init(jax.random.PRNGKey(1), dcfg)
    eng = RecsysEngine(dcfg, quantize_params(dparams), max_batch=8,
                       cache=HotRowCache())
    uids = [eng.submit(d, b) for d, b in reqs]
    assert len(eng.run_until_drained()) == len(uids)


def test_engine_validates_requests():
    cfg = _cfg()
    params = dlrm_init(jax.random.PRNGKey(0), cfg)
    eng = RecsysEngine(cfg, params)
    with pytest.raises(ValueError):
        eng.submit(np.zeros(13), [[1], [2]])          # wrong feature count
    with pytest.raises(NotImplementedError):
        RecsysEngine(_cfg(embedding=EmbeddingSpec(kind="feature")), params)


def _oracle_score(params, cfg, dense, bags):
    """Direct per-request jnp forward at exact shapes (empty bags padded
    to one masked slot)."""
    lmax = max([len(b) for b in bags] + [1])
    idx = np.zeros((1, len(bags), lmax), np.int32)
    mask = np.zeros((1, len(bags), lmax), np.float32)
    for i, bag in enumerate(bags):
        idx[0, i, :len(bag)] = bag
        mask[0, i, :len(bag)] = 1.0
    return float(dlrm_forward(params, jnp.asarray(dense[None], jnp.float32),
                              jnp.asarray(idx), cfg,
                              mask=jnp.asarray(mask))[0])


def test_engine_empty_bags_match_oracle():
    """Empty multi-hot bags are legal Criteo traffic: the pooled feature
    must be the exact zero vector, end to end — mixed empty/non-empty
    bags through the engine == the jnp oracle, quantized tables and the
    hot-row cache both on (the acceptance path) and off."""
    cfg = _cfg()
    params = quantize_params(dlrm_init(jax.random.PRNGKey(0), cfg))
    rng = np.random.default_rng(3)
    reqs = []
    for _ in range(10):
        bags = [list(rng.integers(0, s, int(rng.integers(0, 3))))
                for s in SIZES]           # 0 => empty bag
        reqs.append((rng.normal(size=13), bags))
    reqs.append((rng.normal(size=13), [[] for _ in SIZES]))  # all empty
    reqs.append((rng.normal(size=13), [[1], [], [2]]))
    for cache in (None, HotRowCache(capacity_rows=256)):
        eng = RecsysEngine(cfg, params, max_batch=4, cache=cache)
        uids = [eng.submit(d, b) for d, b in reqs]
        done = eng.run_until_drained()
        for uid, (dense, bags) in zip(uids, reqs):
            want = _oracle_score(params, cfg, dense, bags)
            assert abs(done[uid].score - want) < 1e-4, (uid, cache)


def test_engine_all_empty_wave():
    """A whole wave of all-empty requests (the `max()`-over-empty-bags
    hardening in `_pad_wave`) serves, and its features are exactly the
    zero vectors — scores equal the oracle's zero-feature forward."""
    cfg = _cfg()
    params = dlrm_init(jax.random.PRNGKey(0), cfg)
    eng = RecsysEngine(cfg, params, max_batch=4)
    rng = np.random.default_rng(4)
    reqs = [(rng.normal(size=13), [[] for _ in SIZES]) for _ in range(5)]
    uids = [eng.submit(d, b) for d, b in reqs]
    done = eng.run_until_drained()
    for uid, (dense, bags) in zip(uids, reqs):
        want = _oracle_score(params, cfg, dense, bags)
        assert abs(done[uid].score - want) < 1e-5
    assert all(b[1] == 1 for b in eng.metrics()["buckets"])  # Lb floor = 1


def test_engine_inference_placement_smoke():
    """params placed under INFERENCE_OVERRIDES (mesh path) still serve."""
    cfg = _cfg()
    params = quantize_params(dlrm_init(jax.random.PRNGKey(0), cfg))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    eng = RecsysEngine(cfg, params, max_batch=4, mesh=mesh)
    uid = eng.submit(np.zeros(13), [[1], [2, 3], [4]])
    done = eng.run_until_drained()
    assert np.isfinite(done[uid].score)


# ------------------------------------------------- quantized model end-to-end


@pytest.mark.parametrize("mode", ["bf16", "int8"])
def test_quantized_dlrm_loss_close(mode):
    from repro.data.criteo import CriteoSpec, batch_at
    cfg = _cfg()
    spec = CriteoSpec(table_sizes=SIZES)
    params = dlrm_init(jax.random.PRNGKey(0), cfg)
    batch = batch_at(0, 0, 128, spec)
    base = float(dlrm_loss_fn(params, batch, cfg)[0])
    q = float(dlrm_loss_fn(quantize_params(params, mode=mode), batch, cfg)[0])
    assert abs(base - q) < 0.05, (base, q)


def test_quantized_dlrm_kernel_path_matches_ref_path():
    """use_kernel=True routes quantized QR pairs through the fused Pallas
    kernel; scores must match the jnp dequant path."""
    from repro.data.criteo import CriteoSpec, batch_at
    spec = CriteoSpec(table_sizes=SIZES)
    batch = batch_at(0, 3, 32, spec)
    cfg_k = _cfg(use_kernel=True)
    cfg_r = _cfg(use_kernel=False)
    params = dlrm_init(jax.random.PRNGKey(0), cfg_r)
    qp = quantize_params(params)
    got = dlrm_forward(qp, batch["dense"], batch["sparse"], cfg_k)
    want = dlrm_forward(qp, batch["dense"], batch["sparse"], cfg_r)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
