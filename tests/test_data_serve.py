"""Data pipeline determinism/skew + loader + serving engine behaviour."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.criteo import CriteoSpec, batch_at, read_tsv
from repro.data.lm import batch_at as lm_batch_at
from repro.data.loader import ShardedLoader, host_slice
from repro.models import lm as lm_mod
from repro.models.lm import LMConfig
from repro.serve.engine import ServeEngine

SPEC = CriteoSpec(table_sizes=(100, 5000, 33))


def test_criteo_deterministic_and_stepwise_distinct():
    a = batch_at(0, 7, 64, SPEC)
    b = batch_at(0, 7, 64, SPEC)
    c = batch_at(0, 8, 64, SPEC)
    assert (a["sparse"] == b["sparse"]).all()
    assert not (a["sparse"] == c["sparse"]).all()
    assert set(np.unique(np.asarray(a["label"]))) <= {0.0, 1.0}


def test_criteo_power_law_skew():
    b = batch_at(0, 0, 4096, SPEC)
    col = np.asarray(b["sparse"][:, 1])  # table of 5000 categories
    # uniform would put 10% below id 500; the zipf-ish draw puts ~46%
    assert (col < 500).mean() > 0.35, "zipf draw should concentrate on small ids"
    assert col.max() < 5000 and col.min() >= 0


def test_lm_stream_learnable_structure():
    b = lm_batch_at(0, 0, 8, 64, 100)
    assert b["tokens"].shape == (8, 64)
    assert (b["labels"][:, :-1] == b["tokens"][:, 1:]).all()
    b2 = lm_batch_at(0, 0, 8, 64, 100)
    assert (b["tokens"] == b2["tokens"]).all()


def test_tsv_reader(tmp_path):
    path = tmp_path / "criteo.tsv"
    rows = []
    for i in range(5):
        dense = "\t".join(str(i + j) for j in range(13))
        cats = "\t".join(format(i * 31 + j, "x") for j in range(3))
        rows.append(f"1\t{dense}\t{cats}")
    path.write_text("\n".join(rows) + "\n")
    batches = list(read_tsv(str(path), SPEC, batch_size=5))
    assert len(batches) == 1
    assert batches[0]["dense"].shape == (5, 13)
    assert batches[0]["sparse"].shape == (5, 3)
    assert (batches[0]["sparse"] < jnp.asarray(SPEC.table_sizes)).all()


def test_loader_prefetch_and_seek():
    loader = ShardedLoader(lambda step: {"x": jnp.full((4,), step)}, depth=2)
    it = iter(loader)
    got = [int(next(it)["x"][0]) for _ in range(3)]
    assert got == [0, 1, 2]
    loader.seek(10)
    got = [int(next(it)["x"][0]) for _ in range(2)]
    assert got == [10, 11]
    loader.close()


def test_host_slice_single_process_identity():
    batch = {"x": jnp.arange(8)}
    out = host_slice(batch, process_index=0, process_count=1)
    assert (out["x"] == batch["x"]).all()
    out = host_slice(batch, process_index=1, process_count=2)
    assert (out["x"] == jnp.arange(4, 8)).all()


def _tiny_engine(batch_size=4, temperature=0.0):
    cfg = LMConfig(name="tiny", vocab=64, d_model=32, n_layers=2, n_heads=4,
                   n_kv_heads=2, d_head=8, d_ff=64, param_dtype="float32",
                   compute_dtype="float32", xent_chunk=8)
    p = lm_mod.init(jax.random.PRNGKey(0), cfg)
    return ServeEngine(
        prefill_fn=lambda toks, cache: lm_mod.prefill(p, toks, cache, cfg),
        decode_fn=lambda tok, pos, cache: lm_mod.decode_step(p, tok, pos, cache, cfg),
        make_cache_fn=lambda b, ml: lm_mod.make_decode_cache(cfg, b, ml),
        batch_size=batch_size, max_len=48, temperature=temperature)


def test_engine_batches_and_completes():
    eng = _tiny_engine()
    uids = [eng.submit([1, 2, 3], max_new_tokens=5) for _ in range(6)]
    uids.append(eng.submit([9, 8, 7, 6, 5], max_new_tokens=3))
    done = eng.run_until_drained()
    assert set(done) == set(uids)
    assert all(len(r.output) in (3, 5) for r in done.values())


def test_engine_greedy_deterministic():
    out1 = _tiny_engine().submit([1, 2, 3], 6)
    e1 = _tiny_engine()
    u1 = e1.submit([1, 2, 3], 6)
    e2 = _tiny_engine()
    u2 = e2.submit([1, 2, 3], 6)
    r1 = e1.run_until_drained()[u1].output
    r2 = e2.run_until_drained()[u2].output
    assert r1 == r2


def test_engine_eos_stops_early():
    eng = _tiny_engine()
    # find what the model emits first, then use it as EOS
    probe = eng.submit([1, 2, 3], 4)
    first = eng.run_until_drained()[probe].output[0]
    eng2 = _tiny_engine()
    eng2.eos_id = first
    uid = eng2.submit([1, 2, 3], 10)
    out = eng2.run_until_drained()[uid].output
    assert out[0] == first and len(out) == 1
