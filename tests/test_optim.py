"""Optimizers: convergence on a quadratic, state shapes, partitioned dispatch."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.optimizers import (adafactor, adagrad, adam,
                                    clip_by_global_norm, constant_schedule,
                                    cosine_schedule, global_norm, partitioned,
                                    rowwise_adagrad, sgd)

TARGET = jnp.array([[1.0, -2.0], [3.0, 0.5]])


def _quad_loss(params):
    return jnp.sum((params["w"] - TARGET) ** 2), {}


@pytest.mark.parametrize("opt", [
    sgd(0.1), sgd(0.05, momentum=0.9), adagrad(0.5), rowwise_adagrad(0.5),
    adam(0.1), adam(0.1, amsgrad=True), adafactor(0.2),
])
def test_converges_on_quadratic(opt):
    params = {"w": jnp.zeros((2, 2))}
    state = opt.init(params)
    for step in range(300):
        grads = jax.grad(lambda p: _quad_loss(p)[0])(params)
        params, state = opt.update(grads, state, params, step)
    np.testing.assert_allclose(params["w"], TARGET, atol=0.2)


def test_rowwise_state_is_per_row():
    opt = rowwise_adagrad(0.1)
    params = {"table": jnp.zeros((100, 16)), "bias": jnp.zeros((7,))}
    state = opt.init(params)
    shapes = [s["acc"].shape for s in state]
    assert (7,) in shapes and (100, 1) in shapes


def test_adafactor_state_is_factored():
    opt = adafactor(0.1)
    params = {"w": jnp.zeros((64, 32))}
    st = opt.init(params)[0]
    assert st["vr"].shape == (64,) and st["vc"].shape == (32,)


def test_partitioned_routes_by_path():
    opt = partitioned([(lambda p: "tables" in p, rowwise_adagrad(0.5))],
                      adam(0.1))
    params = {"tables": [{"table_0": jnp.zeros((10, 4))}], "mlp": {"w": jnp.zeros((3, 3))}}
    state = opt.init(params)
    # dict keys flatten alphabetically: mlp (adam: m/v) before tables (rowwise acc)
    assert "m" in state[0] and state[0]["m"].shape == (3, 3)
    assert state[1]["acc"].shape == (10, 1)
    grads = jax.tree.map(jnp.ones_like, params)
    new_params, _ = opt.update(grads, state, params, 0)
    assert not np.allclose(np.asarray(new_params["mlp"]["w"]), 0.0)


def test_clip_by_global_norm():
    grads = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 100


def test_cosine_schedule_shape():
    sched = cosine_schedule(1.0, warmup=10, total=100)
    assert float(sched(0)) < 0.2
    assert abs(float(sched(10)) - 1.0) < 0.1
    assert float(sched(99)) < 0.2
    assert float(constant_schedule(0.3)(50)) == pytest.approx(0.3)


def test_bf16_params_stay_bf16():
    opt = adam(0.1)
    params = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
    state = opt.init(params)
    grads = {"w": jnp.ones((4, 4), jnp.bfloat16)}
    new_params, _ = opt.update(grads, state, params, 0)
    assert new_params["w"].dtype == jnp.bfloat16
