"""Attention/flash/SSM/MoE/MLA layer correctness (oracle comparisons)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.layers import (AttnConfig, attention, attention_init,
                             flash_attention, make_cache, rope)
from repro.nn.mla import MLAConfig, mla_apply, mla_init, mla_make_cache
from repro.nn.moe import MoEConfig, moe_apply, moe_init
from repro.nn.ssm import (SSMConfig, ssm_apply, ssm_decode, ssm_init,
                          ssm_make_cache)

B, S, H, HKV, DH = 2, 130, 8, 4, 16


def _qkv(dv=DH):
    q = jax.random.normal(jax.random.PRNGKey(0), (B, S, H, DH))
    k = jax.random.normal(jax.random.PRNGKey(1), (B, S, HKV, DH))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, HKV, dv))
    return q, k, v


def _ref_attn(q, k, v, causal=True):
    g = q.shape[2] // k.shape[2]
    qg = q.reshape(*q.shape[:2], k.shape[2], g, q.shape[-1])
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k) * q.shape[-1] ** -0.5
    if causal:
        mask = jnp.tril(jnp.ones((q.shape[1], k.shape[1])))
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, -1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return out.reshape(q.shape[0], q.shape[1], -1, v.shape[-1])


@pytest.mark.parametrize("bq,bk", [(32, 48), (64, 64), (130, 130), (16, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_forward(bq, bk, causal):
    q, k, v = _qkv()
    got = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    np.testing.assert_allclose(got, _ref_attn(q, k, v, causal),
                               rtol=3e-4, atol=3e-4)


def test_flash_dv_not_equal_dqk():
    q, k, v = _qkv(dv=24)
    got = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    np.testing.assert_allclose(got, _ref_attn(q, k, v), rtol=3e-4, atol=3e-4)


def test_flash_backward_matches_autodiff():
    q, k, v = _qkv()

    def lf(q, k, v):
        return (flash_attention(q, k, v, causal=True, block_q=32, block_k=48) ** 2).sum()

    def lr(q, k, v):
        return (_ref_attn(q, k, v) ** 2).sum()

    g1 = jax.grad(lf, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(lr, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=2e-3)


def test_attention_decode_matches_full():
    cfg = AttnConfig(d_model=32, n_heads=H, n_kv_heads=HKV, d_head=DH, qk_norm=True)
    p = attention_init(jax.random.PRNGKey(3), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (B, S, 32))
    xt = jax.random.normal(jax.random.PRNGKey(5), (B, 1, 32))
    out_full = attention(p, jnp.concatenate([x, xt], 1), cfg, jnp.float32)
    cache = make_cache(B, S + 8, HKV, DH, jnp.float32)
    _, cache = attention(p, x, cfg, jnp.float32, cache=cache)
    out_dec, _ = attention(p, xt, cfg, jnp.float32, cache=cache, cache_index=S,
                           positions=jnp.full((B, 1), S))
    np.testing.assert_allclose(out_dec[:, 0], out_full[:, -1], rtol=2e-3, atol=2e-3)


def test_rope_orthogonality():
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 8, 2, 16))
    pos = jnp.arange(8)[None, :]
    out = rope(x, pos)
    np.testing.assert_allclose(jnp.linalg.norm(out, axis=-1),
                               jnp.linalg.norm(x, axis=-1), rtol=1e-5)
    # relative property: <rope(q,i), rope(k,j)> depends only on i-j
    q = jax.random.normal(jax.random.PRNGKey(7), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(8), (1, 1, 1, 16))
    def dot_at(i, j):
        qi = rope(q, jnp.array([[i]]))
        kj = rope(k, jnp.array([[j]]))
        return float((qi * kj).sum())
    assert abs(dot_at(5, 3) - dot_at(12, 10)) < 1e-4


def test_ssm_chunked_vs_naive_and_decode():
    cfg = SSMConfig(d_model=32, d_state=8, headdim=8, chunk=16)
    p = ssm_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 48, 32)) * 0.5
    out = ssm_apply(p, u, cfg, jnp.float32)
    cache = ssm_make_cache(2, cfg, jnp.float32)
    outs = []
    for t in range(48):
        o, cache = ssm_decode(p, u[:, t:t + 1], cfg, jnp.float32, cache)
        outs.append(o)
    np.testing.assert_allclose(jnp.concatenate(outs, 1), out, rtol=2e-3, atol=2e-4)


def test_ssm_prefill_state_matches_decode_state():
    cfg = SSMConfig(d_model=32, d_state=8, headdim=8, chunk=16)
    p = ssm_init(jax.random.PRNGKey(2), cfg, jnp.float32)
    u = jax.random.normal(jax.random.PRNGKey(3), (2, 32, 32)) * 0.5
    _, st = ssm_apply(p, u, cfg, jnp.float32, return_state=True)
    cache = ssm_make_cache(2, cfg, jnp.float32)
    for t in range(32):
        _, cache = ssm_decode(p, u[:, t:t + 1], cfg, jnp.float32, cache)
    np.testing.assert_allclose(st["ssm"], cache["ssm"], rtol=2e-3, atol=1e-4)
    np.testing.assert_allclose(st["conv"], cache["conv"], rtol=1e-4, atol=1e-5)


def test_moe_matches_dense_oracle():
    cfg = MoEConfig(n_experts=8, top_k=2, d_model=16, d_ff=32, groups=4,
                    capacity_factor=8.0)
    p = moe_init(jax.random.PRNGKey(2), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 6, 16))
    out, aux = moe_apply(p, x, cfg, jnp.float32)

    logits = jnp.einsum("bsd,de->bse", x, p["router"]["w"])
    probs = jax.nn.softmax(logits, -1)
    g, idx = jax.lax.top_k(probs, 2)
    g = g / g.sum(-1, keepdims=True)
    h = jnp.einsum("bsd,edf->bsef", x, p["wi"])
    h = h * jax.nn.silu(jnp.einsum("bsd,edf->bsef", x, p["wg"]))
    eo = jnp.einsum("bsef,efd->bsed", h, p["wo"])
    want = (jnp.take_along_axis(eo, idx[..., None], axis=2) * g[..., None]).sum(2)
    np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-5)
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    cfg = MoEConfig(n_experts=2, top_k=1, d_model=8, d_ff=16, groups=1,
                    capacity_factor=0.25)  # tiny capacity forces drops
    p = moe_init(jax.random.PRNGKey(4), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 16, 8))
    out, _ = moe_apply(p, x, cfg, jnp.float32)
    # dropped tokens produce exactly zero output rows
    zero_rows = (np.abs(np.asarray(out[0])).sum(-1) < 1e-9).sum()
    assert zero_rows >= 8


def test_mla_decode_matches_prefill():
    mc = MLAConfig(d_model=32, n_heads=4, q_lora=16, kv_lora=8, d_nope=8,
                   d_rope=4, d_v=8)
    p = mla_init(jax.random.PRNGKey(4), mc, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 9, 32))
    full = mla_apply(p, x, mc, jnp.float32)
    cache = mla_make_cache(2, 16, mc, jnp.float32)
    pre, cache = mla_apply(p, x[:, :8], mc, jnp.float32, cache=cache)
    np.testing.assert_allclose(pre, full[:, :8], rtol=2e-3, atol=1e-4)
    dec, _ = mla_apply(p, x[:, 8:9], mc, jnp.float32,
                       positions=jnp.full((2, 1), 8), cache=cache, cache_index=8)
    np.testing.assert_allclose(dec[:, 0], full[:, 8], rtol=2e-3, atol=1e-4)
