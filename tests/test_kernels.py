"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dlrm_interact, qr_bag_lookup, qr_lookup
from repro.kernels import ref

DTYPES = [jnp.float32, jnp.bfloat16]
TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-5),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


def _tables(key, m, q, d, dtype):
    k1, k2 = jax.random.split(key)
    return (jax.random.normal(k1, (m, d), dtype),
            jax.random.normal(k2, (q, d), dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("m,q,d,n", [(7, 3, 16, 5), (128, 8, 128, 64),
                                     (33, 5, 256, 17), (1000, 4, 32, 200)])
@pytest.mark.parametrize("op", ["mult", "add"])
def test_qr_gather_sweep(dtype, m, q, d, n, op):
    wr, wq = _tables(jax.random.PRNGKey(0), m, q, d, dtype)
    idx = jax.random.randint(jax.random.PRNGKey(1), (n,), 0, m * q)
    got = qr_lookup(idx, wr, wq, op=op)
    want = ref.qr_gather_ref(idx % m, idx // m, wr, wq, op=op)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("b,l,m,q,d", [(4, 3, 11, 4, 16), (8, 16, 64, 8, 128),
                                       (3, 7, 29, 5, 64)])
def test_qr_bag_sweep(dtype, b, l, m, q, d):
    wr, wq = _tables(jax.random.PRNGKey(2), m, q, d, dtype)
    idx = jax.random.randint(jax.random.PRNGKey(3), (b, l), 0, m * q)
    mask = (jax.random.uniform(jax.random.PRNGKey(4), (b, l)) > 0.3).astype(dtype)
    got = qr_bag_lookup(idx, mask, wr, wq, op="mult")
    want = ref.qr_embedding_bag_ref(idx % m, idx // m, mask, wr, wq, op="mult")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("b,f,d", [(4, 27, 16), (13, 5, 32), (8, 27, 64), (1, 3, 8)])
def test_dot_interaction_sweep(dtype, b, f, d):
    x = jax.random.normal(jax.random.PRNGKey(5), (b, f, d), dtype)
    got = dlrm_interact(x)
    want = ref.dot_interaction_ref(x)
    assert got.shape == (b, f * (f - 1) // 2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_qr_lookup_multidim_indices():
    wr, wq = _tables(jax.random.PRNGKey(6), 10, 10, 8, jnp.float32)
    idx = jax.random.randint(jax.random.PRNGKey(7), (2, 3, 4), 0, 100)
    got = qr_lookup(idx, wr, wq)
    assert got.shape == (2, 3, 4, 8)
    want = ref.qr_gather_ref(idx % 10, idx // 10, wr, wq)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_concat_falls_back_to_ref():
    wr, wq = _tables(jax.random.PRNGKey(8), 10, 10, 8, jnp.float32)
    idx = jnp.arange(20)
    got = qr_lookup(idx, wr, wq, op="concat")
    assert got.shape == (20, 16)
    np.testing.assert_allclose(got[:, :8], wr[idx % 10], rtol=1e-6)


def test_kernel_grad_path():
    """Kernels participate in autodiff (interpret mode lowers to jnp ops)."""
    wr, wq = _tables(jax.random.PRNGKey(9), 10, 10, 8, jnp.float32)
    idx = jnp.arange(10)

    def loss(wr, wq):
        return (qr_lookup(idx, wr, wq, use_kernel=False) ** 2).sum()

    g1, g2 = jax.grad(loss, argnums=(0, 1))(wr, wq)
    assert np.isfinite(np.asarray(g1)).all() and np.isfinite(np.asarray(g2)).all()
