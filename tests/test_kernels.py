"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dlrm_interact, qr_bag_lookup, qr_lookup
from repro.kernels import ref

DTYPES = [jnp.float32, jnp.bfloat16]
TOL = {jnp.float32: dict(rtol=1e-5, atol=1e-5),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


def _tables(key, m, q, d, dtype):
    k1, k2 = jax.random.split(key)
    return (jax.random.normal(k1, (m, d), dtype),
            jax.random.normal(k2, (q, d), dtype))


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("m,q,d,n", [(7, 3, 16, 5), (128, 8, 128, 64),
                                     (33, 5, 256, 17), (1000, 4, 32, 200)])
@pytest.mark.parametrize("op", ["mult", "add"])
def test_qr_gather_sweep(dtype, m, q, d, n, op):
    wr, wq = _tables(jax.random.PRNGKey(0), m, q, d, dtype)
    idx = jax.random.randint(jax.random.PRNGKey(1), (n,), 0, m * q)
    got = qr_lookup(idx, wr, wq, op=op)
    want = ref.qr_gather_ref(idx % m, idx // m, wr, wq, op=op)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("b,l,m,q,d", [(4, 3, 11, 4, 16), (8, 16, 64, 8, 128),
                                       (3, 7, 29, 5, 64)])
def test_qr_bag_sweep(dtype, b, l, m, q, d):
    wr, wq = _tables(jax.random.PRNGKey(2), m, q, d, dtype)
    idx = jax.random.randint(jax.random.PRNGKey(3), (b, l), 0, m * q)
    mask = (jax.random.uniform(jax.random.PRNGKey(4), (b, l)) > 0.3).astype(dtype)
    got = qr_bag_lookup(idx, mask, wr, wq, op="mult")
    want = ref.qr_embedding_bag_ref(idx % m, idx // m, mask, wr, wq, op="mult")
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("b,f,d", [(4, 27, 16), (13, 5, 32), (8, 27, 64), (1, 3, 8)])
def test_dot_interaction_sweep(dtype, b, f, d):
    x = jax.random.normal(jax.random.PRNGKey(5), (b, f, d), dtype)
    got = dlrm_interact(x)
    want = ref.dot_interaction_ref(x)
    assert got.shape == (b, f * (f - 1) // 2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


def test_qr_lookup_multidim_indices():
    wr, wq = _tables(jax.random.PRNGKey(6), 10, 10, 8, jnp.float32)
    idx = jax.random.randint(jax.random.PRNGKey(7), (2, 3, 4), 0, 100)
    got = qr_lookup(idx, wr, wq)
    assert got.shape == (2, 3, 4, 8)
    want = ref.qr_gather_ref(idx % 10, idx // 10, wr, wq)
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_concat_falls_back_to_ref():
    wr, wq = _tables(jax.random.PRNGKey(8), 10, 10, 8, jnp.float32)
    idx = jnp.arange(20)
    got = qr_lookup(idx, wr, wq, op="concat")
    assert got.shape == (20, 16)
    np.testing.assert_allclose(got[:, :8], wr[idx % 10], rtol=1e-6)


# ----------------------------------------------------- accumulation audit
#
# The embedding-bag kernel audit found bf16 accumulation diverging from the
# f32 oracle at L=16, D=128 (ROADMAP).  These tests pin the convention for
# every pooling path: combine/accumulate in f32, round once at the end.
# Tolerances are set so a bf16 running sum (one rounding per add, worst case
# ~L·2⁻⁹ relative) fails while a single final cast (2⁻⁹) passes.

AUDIT_B, AUDIT_L, AUDIT_D = 8, 16, 128


def _audit_f32_oracle(idx, mask, wr, wq, op):
    rows_r = jnp.take(wr.astype(jnp.float32), idx % wr.shape[0], axis=0)
    rows_q = jnp.take(wq.astype(jnp.float32), idx // wr.shape[0], axis=0)
    rows = rows_r * rows_q if op == "mult" else rows_r + rows_q
    return (rows * mask[..., None].astype(jnp.float32)).sum(axis=1)


@pytest.mark.parametrize("op", ["mult", "add"])
@pytest.mark.parametrize("use_kernel", [True, False])
def test_bag_accumulates_f32_at_L16_D128(op, use_kernel):
    m, q = 64, 8
    wr, wq = _tables(jax.random.PRNGKey(10), m, q, AUDIT_D, jnp.bfloat16)
    # positive rows: no cancellation, so the running sum grows and bf16
    # accumulation error compounds past the tolerance below
    wr, wq = jnp.abs(wr) + 0.5, jnp.abs(wq) + 0.5
    idx = jax.random.randint(jax.random.PRNGKey(11), (AUDIT_B, AUDIT_L), 0, m * q)
    mask = jnp.ones((AUDIT_B, AUDIT_L), jnp.bfloat16)
    got = qr_bag_lookup(idx, mask, wr, wq, op=op, use_kernel=use_kernel)
    want = _audit_f32_oracle(idx, mask, wr, wq, op)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               rtol=5e-3, atol=0)


def test_bag_concat_accumulates_f32_at_L16_D128():
    m, q = 64, 8
    wr, wq = _tables(jax.random.PRNGKey(12), m, q, AUDIT_D, jnp.bfloat16)
    wr, wq = jnp.abs(wr) + 0.5, jnp.abs(wq) + 0.5
    idx = jax.random.randint(jax.random.PRNGKey(13), (AUDIT_B, AUDIT_L), 0, m * q)
    mask = jnp.ones((AUDIT_B, AUDIT_L), jnp.bfloat16)
    got = qr_bag_lookup(idx, mask, wr, wq, op="concat")
    rows = jnp.concatenate([jnp.take(wr.astype(jnp.float32), idx % m, axis=0),
                            jnp.take(wq.astype(jnp.float32), idx // m, axis=0)],
                           axis=-1)
    want = rows.sum(axis=1)
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               rtol=5e-3, atol=0)


def test_qr_gather_combines_f32_bf16_tables():
    """Single-row combine: the only rounding is the final cast back to bf16."""
    m, q = 64, 8
    wr, wq = _tables(jax.random.PRNGKey(14), m, q, AUDIT_D, jnp.bfloat16)
    idx = jax.random.randint(jax.random.PRNGKey(15), (AUDIT_L,), 0, m * q)
    got = qr_lookup(idx, wr, wq, op="mult")
    want = (jnp.take(wr.astype(jnp.float32), idx % m, axis=0)
            * jnp.take(wq.astype(jnp.float32), idx // m, axis=0))
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               rtol=5e-3, atol=1e-6)


def test_kernel_grad_path():
    """Kernels participate in autodiff (interpret mode lowers to jnp ops)."""
    wr, wq = _tables(jax.random.PRNGKey(9), 10, 10, 8, jnp.float32)
    idx = jnp.arange(10)

    def loss(wr, wq):
        return (qr_lookup(idx, wr, wq, use_kernel=False) ** 2).sum()

    g1, g2 = jax.grad(loss, argnums=(0, 1))(wr, wq)
    assert np.isfinite(np.asarray(g1)).all() and np.isfinite(np.asarray(g2)).all()
