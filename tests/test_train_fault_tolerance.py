"""Fault-tolerance integration: restart determinism, watchdog, compression."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import EmbeddingSpec
from repro.data.criteo import CriteoSpec, batch_at
from repro.dist.compress import ef_psum_grads, init_error_state, quantize_int8
from repro.models.dlrm import DLRMConfig, dlrm_init, dlrm_loss_fn
from repro.optim.optimizers import adam, adagrad, rowwise_adagrad, partitioned
from repro.train.loop import (SimulatedFailure, TrainConfig, Trainer,
                              init_state, make_train_step)

SPEC = CriteoSpec(table_sizes=(100, 5000, 33))
CFG = DLRMConfig(table_sizes=SPEC.table_sizes,
                 embedding=EmbeddingSpec(kind="qr", num_collisions=4, threshold=50))


def _loss_fn(p, b):
    return dlrm_loss_fn(p, b, CFG)


def _opt():
    return partitioned([(lambda p: "tables" in p, rowwise_adagrad(1e-2))],
                       adam(1e-3, amsgrad=True))


def test_kill_restart_bitwise_determinism(tmp_path):
    opt = _opt()
    state0 = init_state(dlrm_init(jax.random.PRNGKey(1), CFG), opt)
    tc = TrainConfig(num_steps=20, ckpt_every=10, ckpt_dir=str(tmp_path), log_every=5)
    batcher = lambda s: batch_at(0, s, 64, SPEC)

    tr = Trainer(make_train_step(_loss_fn, opt), tc, batch_at=batcher)
    with pytest.raises(SimulatedFailure):
        tr.run(state0, fail_at_step=15)
    # the step-10 checkpoint was issued 5 steps before the crash; let the
    # async writer finish (in real time-scales it completed long before)
    tr.checkpointer.wait()

    tr2 = Trainer(make_train_step(_loss_fn, opt), tc, batch_at=batcher)
    resumed = tr2.resume_or(state0)
    assert int(resumed["step"]) == 10
    final_resumed, _ = tr2.run(resumed)

    tr3 = Trainer(make_train_step(_loss_fn, opt),
                  TrainConfig(num_steps=20, ckpt_dir=None), batch_at=batcher)
    final_direct, _ = tr3.run(state0)
    for a, b in zip(jax.tree.leaves(final_resumed["params"]),
                    jax.tree.leaves(final_direct["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_training_reduces_loss():
    opt = adagrad(1e-2)
    state = init_state(dlrm_init(jax.random.PRNGKey(0), CFG), opt)
    step = jax.jit(make_train_step(_loss_fn, opt))
    losses = []
    for i in range(150):
        state, m = step(state, batch_at(0, i, 256, SPEC))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.05


def test_grad_accumulation_equivalent():
    """accum=4 must match accum=1 numerically (same global batch)."""
    opt = adagrad(1e-2)
    p0 = dlrm_init(jax.random.PRNGKey(2), CFG)
    batch = batch_at(0, 0, 64, SPEC)
    s1 = init_state(p0, opt)
    s4 = init_state(p0, opt)
    step1 = jax.jit(make_train_step(_loss_fn, opt, accum=1))
    step4 = jax.jit(make_train_step(_loss_fn, opt, accum=4))
    s1, m1 = step1(s1, batch)
    s4, m4 = step4(s4, batch)
    # losses are means over microbatches of per-microbatch means — equal for
    # equal-size microbatches.
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 5e-5
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_watchdog_flags_straggler(monkeypatch):
    opt = adagrad(1e-2)
    state = init_state(dlrm_init(jax.random.PRNGKey(0), CFG), opt)
    tc = TrainConfig(num_steps=12, watchdog_factor=2.5)
    tr = Trainer(make_train_step(_loss_fn, opt), tc,
                 batch_at=lambda s: batch_at(0, s, 32, SPEC))
    import time as _time
    orig_step = tr.train_step

    def slow_step(state, batch):
        if int(state["step"]) == 9:
            _time.sleep(1.0)  # injected straggler
        return orig_step(state, batch)

    tr.train_step = slow_step
    tr.run(state)
    assert any(step == 9 for step, _ in tr.straggler_events)


def test_quantize_int8_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,))
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(x) - np.asarray(q, np.float32) * float(scale))
    assert err.max() <= float(scale) * 0.5 + 1e-6


@pytest.mark.parametrize("mode", ["bf16", "int8"])
def test_error_feedback_is_unbiased_over_time(mode):
    """Sum of EF-compressed gradients converges to sum of true gradients."""
    g = {"w": jnp.full((64,), 0.003)}  # small values stress quantisation
    err = init_error_state(g)
    total = jnp.zeros((64,))
    for _ in range(50):
        out, err = ef_psum_grads(g, err, axis_name=None, mode=mode)
        total = total + out["w"]
    np.testing.assert_allclose(np.asarray(total), 0.003 * 50, rtol=0.02)


def test_dp_shard_map_compressed_training_runs():
    """shard_map DP path with bf16-compressed reduction on a 1-device mesh."""
    from repro.train.loop import init_dp_state, make_dp_train_step
    mesh = jax.make_mesh((1,), ("data",))
    opt = adagrad(1e-2)
    state = init_dp_state(dlrm_init(jax.random.PRNGKey(0), CFG), opt)
    step = jax.jit(make_dp_train_step(_loss_fn, opt, mesh, compress="bf16"))
    with mesh:
        for i in range(3):
            state, m = step(state, batch_at(0, i, 32, SPEC))
    assert np.isfinite(float(m["loss"]))
