"""Property tests (hypothesis) for complementary partitions — paper §3 + Thm 1."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (codes_for, crt_partitions,
                        generalized_qr_partitions, is_complementary,
                        min_collision_free_m, naive_partition, qr_partitions,
                        qr_embedding)


@settings(max_examples=60, deadline=None)
@given(st.integers(2, 400), st.data())
def test_qr_partitions_complementary(size, data):
    m = data.draw(st.integers(1, size))
    parts = qr_partitions(size, m)
    assert is_complementary(parts, size)


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 300), st.lists(st.integers(2, 7), min_size=2, max_size=4))
def test_generalized_qr_complementary(size, ms):
    prod = int(np.prod(ms))
    if prod < size:
        with pytest.raises(ValueError):
            generalized_qr_partitions(size, ms)
        return
    parts = generalized_qr_partitions(size, ms)
    assert is_complementary(parts, size)


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 200))
def test_crt_complementary(size):
    # coprime pair (m, m+1) with product >= size
    m = int(np.ceil(np.sqrt(size)))
    parts = crt_partitions(size, [m, m + 1])
    assert is_complementary(parts, size)


def test_crt_rejects_non_coprime():
    with pytest.raises(ValueError):
        crt_partitions(10, [4, 6])


def test_naive_partition_is_complementary():
    parts = naive_partition(17)
    assert is_complementary(parts, 17)
    assert parts[0].num_buckets == 17


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 300), st.data())
def test_theorem1_uniqueness(size, data):
    """Thm 1: with distinct per-table rows, concat embeddings are unique.
    Code tuples being injective is the discrete core of the theorem."""
    m = data.draw(st.integers(1, size))
    emb = qr_embedding(size, 8, num_collisions=max(1, size // m), op="concat")
    codes = np.asarray(codes_for(emb.partitions, jnp.arange(size)))
    assert len(np.unique(codes, axis=0)) == size


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 10000))
def test_min_collision_free_m(size):
    m = min_collision_free_m(size)
    assert m * m >= size  # m=ceil(sqrt) covers the set with the QR pair
    parts = qr_partitions(size, m)
    assert parts[0].num_buckets + parts[1].num_buckets <= 2 * m + 1


@settings(max_examples=60, deadline=None)
@given(st.integers(3, 500), st.data())
def test_qr_partitions_injective_for_nondivisible_sizes(size, data):
    """Complementarity = the bucket-tuple map is injective over [0, |S|).

    The fragile regime is |S| % m != 0: the last quotient bucket is ragged
    and an off-by-one in ceil-division silently merges two categories.
    Check injectivity directly (not just via is_complementary) on such m.
    """
    m = data.draw(st.integers(2, size - 1))
    if size % m == 0:  # steer onto the ragged case; m=size-1 divides only size=2
        m = size - 1
    assert size % m != 0
    parts = qr_partitions(size, m)
    codes = np.asarray(codes_for(parts, jnp.arange(size)))
    assert codes.shape[0] == size
    assert len(np.unique(codes, axis=0)) == size
    assert is_complementary(parts, size)


@settings(max_examples=40, deadline=None)
@given(st.integers(5, 400), st.data())
def test_qr_embedding_codes_injective_nondivisible_collisions(size, data):
    """End-to-end: qr_embedding built with |S| % num_collisions != 0 still
    assigns every category a unique (remainder, quotient) code pair."""
    c = data.draw(st.integers(2, size - 1))
    if size % c == 0:
        c = size - 1
    assert size % c != 0
    emb = qr_embedding(size, 4, num_collisions=c, op="concat")
    codes = np.asarray(codes_for(emb.partitions, jnp.arange(size)))
    assert len(np.unique(codes, axis=0)) == size


def test_paper_example_section3():
    """The concrete example from paper §3 is complementary."""
    import numpy as np

    from repro.core import ExplicitPartition
    p1 = ExplicitPartition(size=5, num_buckets=3, table=np.array([0, 1, 2, 1, 1]))
    p2 = ExplicitPartition(size=5, num_buckets=2, table=np.array([0, 0, 1, 0, 1]))
    p3 = ExplicitPartition(size=5, num_buckets=2, table=np.array([0, 1, 1, 0, 1]))
    assert is_complementary([p1, p2, p3], 5)
    # dropping the first partition breaks it (1 and 4 collide everywhere)
    assert not is_complementary([p2, p3], 5)
